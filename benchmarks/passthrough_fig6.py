"""Fig. 6 reproduction: 1-fault speedup vs (stage count x op size)."""
from __future__ import annotations

from repro.core.latency import passthrough_model, speedup_vs_sw

SIZES = [30_000, 60_000, 120_000, 200_000, 300_000]
STAGES = [3, 4, 6, 8, 9, 10, 12]


def run():
    rows = []
    for op in SIZES:
        for n in STAGES:
            s = speedup_vs_sw(passthrough_model(op, n), [0])
            rows.append((f"fig6_speedup@op={op}_n={n}", 0.0, f"{s:.2f}x"))
    # reported corners
    rows.append(("fig6_corner_30k_n9_paper3.3", 0.0,
                 f"{speedup_vs_sw(passthrough_model(30_000, 9), [0]):.2f}x"))
    rows.append(("fig6_corner_300k_n12_paper9.7", 0.0,
                 f"{speedup_vs_sw(passthrough_model(300_000, 12), [0]):.2f}x"))
    return rows


if __name__ == "__main__":
    import argparse
    ap = argparse.ArgumentParser(description=__doc__)
    # accepted for CI uniformity: this bench is closed-form (no RNG)
    ap.add_argument("--seed", type=int, default=0)
    ap.parse_args()
    for row in run():
        print("%s,%.1f,%s" % row)
