"""Fig. 7 reproduction: speedups under two (and more) faults."""
from __future__ import annotations

from repro.core.latency import passthrough_model, speedup_vs_sw

CASES = [(30_000, 6), (60_000, 6), (120_000, 8), (200_000, 10),
         (240_000, 12)]


def run():
    rows = []
    for op, n in CASES:
        m = passthrough_model(op, n)
        s1 = speedup_vs_sw(m, [0])
        s2 = speedup_vs_sw(m, [0, n // 2])
        rows.append((f"fig7_1fault@op={op}_n={n}", 0.0, f"{s1:.2f}x"))
        rows.append((f"fig7_2fault@op={op}_n={n}", 0.0, f"{s2:.2f}x"))
    # the paper's break-even observations
    m6 = passthrough_model(30_000, 6)
    rows.append(("fig7_30k_3fault_near_breakeven", 0.0,
                 f"{speedup_vs_sw(m6, [0, 2, 4]):.2f}x"))
    m12 = passthrough_model(240_000, 12)
    rows.append(("fig7_240k_8fault_still_wins", 0.0,
                 f"{speedup_vs_sw(m12, list(range(8))):.2f}x"))
    return rows


if __name__ == "__main__":
    import argparse
    ap = argparse.ArgumentParser(description=__doc__)
    # accepted for CI uniformity: this bench is closed-form (no RNG)
    ap.add_argument("--seed", type=int, default=0)
    ap.parse_args()
    for row in run():
        print("%s,%.1f,%s" % row)
