"""Chaos campaign bench: randomized fault-schedule soak + MTTR.

Drives ``repro.chaos.run_campaign`` — seeded randomized fault schedules
over the full taxonomy (transient upsets, persistent stage faults,
localized lane faults, device/host losses, spare-exhaustion bursts,
coordinator stalls) injected mid-run into a ``FleetServeEngine`` under
open-loop traffic (both failover modes), a data-parallel
``FleetTrainRunner`` with probation + checkpoint restore, and a
``KVCoordinator`` against a stalling peer.  Every run checks the
fault-tolerance invariants (zero non-expired drops, replayed-log
fingerprint agreement, degradation-ladder rungs, transient cleanup,
measured-vs-DegradationModel closure); ``run()`` raises on any
violation so a broken invariant can never ride a green bench.

Reported per section: mean per-event MTTR (virtual-clock for serve,
step-clock for train, wall-bounded-by-retry-budget for the
coordinator), which ``benchmarks/compare.py`` gates against growth the
same way it gates goodput drops.

``python benchmarks/chaos_bench.py [--smoke] [--seed N]`` prints the
full campaign report as one JSON object; ``run()`` returns the usual
``name,us_per_call,derived`` rows for ``benchmarks/run.py`` at smoke
sizing (same scenario coverage, smaller schedules).
"""
from __future__ import annotations

import json
import time

from repro.chaos.campaign import run_campaign
from repro.obs import report as obs_report


def _mttr_of(snap, section: str) -> float:
    mt = obs_report.mttr_summary(snap, section=section) or {}
    return float(mt.get("mean_s") or 0.0)


def run(seed: int = 0):
    """CSV rows for benchmarks/run.py (name, us_per_call, derived).

    ``us_per_call`` is wall time per injected fault event (the soak is
    dominated by engine steps between events); ``derived`` carries the
    deterministic campaign metrics — mean MTTR, event count, and the
    survival/closure evidence compare.py's gates watch — all read back
    from the campaign's telemetry snapshot (``obs.report``), not from
    the harnesses' private counters."""
    t0 = time.perf_counter()
    res = run_campaign(seed, smoke=True, raise_on_failure=True)
    wall = time.perf_counter() - t0
    snap = res["telemetry"]["metrics"]
    us_per_event = 1e6 * wall / max(res["events_total"], 1)
    rows = []
    for mode, sec in sorted(res["serve"].items()):
        g = obs_report.goodput_summary(snap, section=f"serve_{mode}")
        rows.append((
            f"chaos_serve_{mode}", us_per_event,
            f"mttr={_mttr_of(snap, f'serve_{mode}'):.4f};"
            f"events={sec['n_events']};"
            f"completed={g['completed']}/{sec['traffic']['requests']};"
            f"expired={g['expired']}"))
    tr = res["train"]
    rows.append((
        "chaos_train", us_per_event,
        f"mttr={_mttr_of(snap, 'train'):.4f};events={tr['n_events']};"
        f"steps={tr['steps']};trips={tr['guard_trips']}"))
    co = res["coordinator"]
    rows.append((
        "chaos_coordinator", us_per_event,
        f"mttr={_mttr_of(snap, 'coordinator'):.4f};"
        f"events={co['n_events']}"))
    c = obs_report.closure(res["telemetry"]["metrics"]) or {}
    rows.append((
        "chaos_closure", 0.0,
        f"measured={c.get('measured_ratio')};"
        f"analytic={c.get('analytic_ratio')};"
        f"rel_err={c.get('rel_err')};"
        f"dropped={len(res['closure']['dropped'])}"))
    return rows


def main(argv=None):
    import argparse
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--seed", type=int, default=0,
                    help="schedule/workload/init RNG seed")
    ap.add_argument("--smoke", action="store_true",
                    help="small CI sizing (same taxonomy coverage)")
    ap.add_argument("--ckpt-dir", default=None,
                    help="directory for the train campaign's checkpoint "
                         "restore drill (skipped when omitted)")
    ap.add_argument("--telemetry", default=None, metavar="PATH",
                    help="write the campaign's metrics+trace snapshot "
                         "here (readable by python -m repro.obs.report)")
    args = ap.parse_args(argv)
    out = run_campaign(args.seed, smoke=args.smoke,
                       ckpt_dir=args.ckpt_dir)
    telemetry = out.pop("telemetry")
    if args.telemetry:
        with open(args.telemetry, "w") as f:
            json.dump(telemetry, f, sort_keys=True,
                      separators=(",", ":"))
    print(json.dumps(out, indent=2, default=str))
    if not out["invariants"]["ok"]:
        raise SystemExit(1)


if __name__ == "__main__":
    main()
