"""Chaos campaign bench: randomized fault-schedule soak + MTTR.

Drives ``repro.chaos.run_campaign`` — seeded randomized fault schedules
over the full taxonomy (transient upsets, persistent stage faults,
localized lane faults, device/host losses, spare-exhaustion bursts,
coordinator stalls) injected mid-run into a ``FleetServeEngine`` under
open-loop traffic (both failover modes), a data-parallel
``FleetTrainRunner`` with probation + checkpoint restore, and a
``KVCoordinator`` against a stalling peer.  Every run checks the
fault-tolerance invariants (zero non-expired drops, replayed-log
fingerprint agreement, degradation-ladder rungs, transient cleanup,
measured-vs-DegradationModel closure); ``run()`` raises on any
violation so a broken invariant can never ride a green bench.

Reported per section: mean per-event MTTR (virtual-clock for serve,
step-clock for train, wall-bounded-by-retry-budget for the
coordinator), which ``benchmarks/compare.py`` gates against growth the
same way it gates goodput drops.

``python benchmarks/chaos_bench.py [--smoke] [--seed N]`` prints the
full campaign report as one JSON object; ``run()`` returns the usual
``name,us_per_call,derived`` rows for ``benchmarks/run.py`` at smoke
sizing (same scenario coverage, smaller schedules).
"""
from __future__ import annotations

import json
import time

from repro.chaos.campaign import run_campaign


def _mttr_of(section) -> float:
    mt = section.get("mttr_summary") or {}
    return float(mt.get("mean_s") or 0.0)


def run(seed: int = 0):
    """CSV rows for benchmarks/run.py (name, us_per_call, derived).

    ``us_per_call`` is wall time per injected fault event (the soak is
    dominated by engine steps between events); ``derived`` carries the
    deterministic campaign metrics — mean MTTR, event count, and the
    survival/closure evidence compare.py's gates watch."""
    t0 = time.perf_counter()
    res = run_campaign(seed, smoke=True, raise_on_failure=True)
    wall = time.perf_counter() - t0
    us_per_event = 1e6 * wall / max(res["events_total"], 1)
    rows = []
    for mode, sec in sorted(res["serve"].items()):
        t = sec["traffic"]
        rows.append((
            f"chaos_serve_{mode}", us_per_event,
            f"mttr={_mttr_of(sec):.4f};events={sec['n_events']};"
            f"completed={t['completed']}/{t['requests']};"
            f"expired={t['expired']}"))
    tr = res["train"]
    rows.append((
        "chaos_train", us_per_event,
        f"mttr={_mttr_of(tr):.4f};events={tr['n_events']};"
        f"steps={tr['steps']};trips={tr['guard_trips']}"))
    co = res["coordinator"]
    rows.append((
        "chaos_coordinator", us_per_event,
        f"mttr={_mttr_of(co):.4f};events={co['n_events']}"))
    c = res["closure"]
    rows.append((
        "chaos_closure", 0.0,
        f"measured={c['measured_ratio']};analytic={c['analytic_ratio']};"
        f"rel_err={c['rel_err']};dropped={len(c['dropped'])}"))
    return rows


def main(argv=None):
    import argparse
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--seed", type=int, default=0,
                    help="schedule/workload/init RNG seed")
    ap.add_argument("--smoke", action="store_true",
                    help="small CI sizing (same taxonomy coverage)")
    ap.add_argument("--ckpt-dir", default=None,
                    help="directory for the train campaign's checkpoint "
                         "restore drill (skipped when omitted)")
    args = ap.parse_args(argv)
    out = run_campaign(args.seed, smoke=args.smoke,
                       ckpt_dir=args.ckpt_dir)
    print(json.dumps(out, indent=2, default=str))
    if not out["invariants"]["ok"]:
        raise SystemExit(1)


if __name__ == "__main__":
    main()
