"""Fig. 2 reproduction: fixed-size fleet, replacements + throughput."""
from __future__ import annotations

import time

from repro.core.datacenter import (expected_replacements, fig2_sweep,
                                   simulate_fleet)

RATES = [1e-1, 1e-2, 1e-3, 1e-4, 1e-5, 1e-6, 1e-7]
DEG = (1.0, 0.38, 0.19)    # FFT case-study degradation curve


def run(seed: int = 0):
    rows = []
    t0 = time.perf_counter()
    table = fig2_sweep(RATES, degradation=DEG)
    dt = (time.perf_counter() - t0) / len(RATES) * 1e6
    for p, sfa_r, vfa_r, sfa_tp, vfa_tp in table:
        rows.append((f"fig2a_sfa_repl@p={p:g}", dt, f"{sfa_r:.2f}"))
        rows.append((f"fig2a_vfa_repl@p={p:g}", dt, f"{vfa_r:.4f}"))
        rows.append((f"fig2b_vfa_tp@p={p:g}", dt, f"{vfa_tp:.5f}"))
    # headline claims
    rows.append(("fig2_claim_sfa_gt50@1e-5", 0.0,
                 f"{expected_replacements(10_000, 1460, 1e-5, 1):.1f}"))
    rows.append(("fig2_claim_vfa_lt1@1e-5", 0.0,
                 f"{expected_replacements(10_000, 1460, 1e-5, 3):.4f}"))
    # Monte-Carlo cross-check at one rate
    t0 = time.perf_counter()
    mc = simulate_fleet(10_000, 1460, 1e-4, mode="vfa", degradation=DEG,
                        seed=seed)
    dt = (time.perf_counter() - t0) * 1e6
    rows.append(("fig2_mc_vfa_repl@1e-4", dt, f"{mc.replacements:.0f}"))
    return rows


if __name__ == "__main__":
    import argparse
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--seed", type=int, default=0,
                    help="Monte-Carlo cross-check seed")
    for row in run(seed=ap.parse_args().seed):
        print("%s,%.1f,%s" % row)
