"""Benchmark harness: one module per paper table/figure + system benches.

Prints ``name,us_per_call,derived`` CSV rows (per assignment contract).

The exit status is part of the contract: a sub-benchmark that crashes,
returns no rows, or returns malformed rows fails the whole run (exit 1)
— a broken bench can never silently vanish from the aggregate.
``--seed`` forwards to every module whose ``run()`` accepts one, so CI
runs are reproducible.
"""
from __future__ import annotations

import argparse
import math
import sys
import time
import traceback

MODULES = [
    "benchmarks.datacenter_fig2",    # Fig. 2 (a,b)
    "benchmarks.casestudy_fig5",     # Fig. 5 FFT/AES/DCT
    "benchmarks.passthrough_fig6",   # Fig. 6 stage x size sweep
    "benchmarks.multifault_fig7",    # Fig. 7 two-fault sweep
    "benchmarks.hotspare_fig8",      # Fig. 8 FPGA fallback
    "benchmarks.kernel_micro",       # per-kernel parity + wall
    "benchmarks.step_bench",         # staged train/serve under faults
    "benchmarks.serve_bench",        # continuous vs fixed-batch serving
    "benchmarks.fleet_bench",        # MC fault trace through the fleet
    "benchmarks.roofline",           # dry-run roofline summary
]


def _row_error(row) -> str:
    """Why ``row`` is not a valid (name, us_per_call, derived) row."""
    if not isinstance(row, (tuple, list)) or len(row) != 3:
        return "not a 3-tuple"
    name, us, _derived = row
    if not isinstance(name, str) or not name:
        return "empty/non-string name"
    if isinstance(us, bool) or not isinstance(us, (int, float)) \
            or not math.isfinite(us):
        return f"non-finite us_per_call {us!r}"
    return ""


def main(argv=None) -> None:
    import importlib
    import inspect

    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--seed", type=int, default=0,
                    help="forwarded to every bench run() that takes one")
    args = ap.parse_args(argv)
    failures = []
    print("name,us_per_call,derived")
    for modname in MODULES:
        t0 = time.time()
        try:
            mod = importlib.import_module(modname)
            kw = ({"seed": args.seed} if "seed" in
                  inspect.signature(mod.run).parameters else {})
            rows = list(mod.run(**kw))
            if not rows:
                raise RuntimeError(f"{modname}.run() returned no rows")
            bad = [(row, err) for row in rows
                   if (err := _row_error(row))]
            if bad:
                raise RuntimeError(
                    f"{modname} emitted malformed row(s): {bad[:3]}")
            for name, us, derived in rows:
                print(f"{name},{us:.1f},{derived}")
            print(f"# {modname} done in {time.time()-t0:.1f}s",
                  file=sys.stderr)
        except Exception:  # noqa: BLE001 - every failure must be counted
            failures.append(modname)
            print(f"# {modname} FAILED", file=sys.stderr)
            traceback.print_exc()
    if failures:
        print(f"# {len(failures)} benchmark(s) failed: "
              f"{', '.join(failures)}", file=sys.stderr)
        raise SystemExit(1)


if __name__ == "__main__":
    main()
