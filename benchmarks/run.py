"""Benchmark harness: one module per paper table/figure + system benches.

Prints ``name,us_per_call,derived`` CSV rows (per assignment contract).

The exit status is part of the contract: a sub-benchmark that crashes,
returns no rows, or returns malformed rows fails the whole run (exit 1)
— a broken bench can never silently vanish from the aggregate.
``--seed`` forwards to every module whose ``run()`` accepts one, so CI
runs are reproducible.

``--emit-json PATH`` additionally writes the machine-readable trajectory
snapshot (``BENCH_<n>.json``): per-bench ``us_per_call`` + ``derived``,
the backend fingerprint, tuner cache-hit stats, and a ``calibration_us``
reference timing (a fixed jitted matmul) that ``benchmarks/compare.py``
uses to normalize away CI-runner speed differences before applying its
regression thresholds.
"""
from __future__ import annotations

import argparse
import json
import math
import sys
import time
import traceback

MODULES = [
    "benchmarks.datacenter_fig2",    # Fig. 2 (a,b)
    "benchmarks.casestudy_fig5",     # Fig. 5 FFT/AES/DCT
    "benchmarks.passthrough_fig6",   # Fig. 6 stage x size sweep
    "benchmarks.multifault_fig7",    # Fig. 7 two-fault sweep
    "benchmarks.hotspare_fig8",      # Fig. 8 FPGA fallback
    "benchmarks.kernel_micro",       # per-kernel parity + wall
    "benchmarks.step_bench",         # staged train/serve under faults
    "benchmarks.serve_bench",        # continuous vs fixed-batch serving
    "benchmarks.traffic_bench",      # open-loop goodput/tail under faults
    "benchmarks.chaos_bench",        # randomized fault-schedule soak
    "benchmarks.fleet_bench",        # MC fault trace through the fleet
    "benchmarks.roofline",           # dry-run roofline summary
]


def _row_error(row) -> str:
    """Why ``row`` is not a valid (name, us_per_call, derived) row."""
    if not isinstance(row, (tuple, list)) or len(row) != 3:
        return "not a 3-tuple"
    name, us, _derived = row
    if not isinstance(name, str) or not name:
        return "empty/non-string name"
    if isinstance(us, bool) or not isinstance(us, (int, float)) \
            or not math.isfinite(us):
        return f"non-finite us_per_call {us!r}"
    return ""


def calibration_us(reps: int = 5) -> float:
    """Reference timing: fixed jitted 512x512 f32 matmul, best-of-reps.

    Scales with the host's raw compute speed the same way the benches
    do, so ``new_us / new_calibration`` is comparable across runners.
    """
    import jax
    import jax.numpy as jnp

    x = jnp.ones((512, 512), jnp.float32)
    f = jax.jit(lambda a: a @ a)
    f(x).block_until_ready()   # compile outside the timed region
    best = math.inf
    for _ in range(reps):
        t0 = time.perf_counter()
        f(x).block_until_ready()
        best = min(best, time.perf_counter() - t0)
    return best * 1e6


def main(argv=None) -> None:
    import importlib
    import inspect

    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--seed", type=int, default=0,
                    help="forwarded to every bench run() that takes one")
    ap.add_argument("--emit-json", metavar="PATH", default=None,
                    help="also write the BENCH_<n>.json trajectory "
                         "snapshot (see benchmarks/compare.py)")
    ap.add_argument("--only", metavar="SUBSTR", default=None,
                    help="run only modules whose name contains SUBSTR")
    args = ap.parse_args(argv)

    # The preset layer is the one sanctioned XLA_FLAGS surface; applying
    # here (before any bench imports jax) mirrors initialize_runtime.
    from repro.launch import xla_presets
    xla_presets.apply()

    modules = [m for m in MODULES
               if args.only is None or args.only in m]
    failures = []
    benches = {}
    modules_s = {}
    print("name,us_per_call,derived")
    for modname in modules:
        t0 = time.time()
        try:
            mod = importlib.import_module(modname)
            kw = ({"seed": args.seed} if "seed" in
                  inspect.signature(mod.run).parameters else {})
            rows = list(mod.run(**kw))
            if not rows:
                raise RuntimeError(f"{modname}.run() returned no rows")
            bad = [(row, err) for row in rows
                   if (err := _row_error(row))]
            if bad:
                raise RuntimeError(
                    f"{modname} emitted malformed row(s): {bad[:3]}")
            for name, us, derived in rows:
                print(f"{name},{us:.1f},{derived}")
                benches[name] = {"us_per_call": round(float(us), 1),
                                 "derived": str(derived),
                                 "module": modname}
            modules_s[modname] = round(time.time() - t0, 1)
            print(f"# {modname} done in {time.time()-t0:.1f}s",
                  file=sys.stderr)
        except Exception:  # noqa: BLE001 - every failure must be counted
            failures.append(modname)
            print(f"# {modname} FAILED", file=sys.stderr)
            traceback.print_exc()
    if args.emit_json:
        from repro.kernels import tuning
        from repro.kernels.tuning.cache import backend_fingerprint
        snap = {
            "schema": 1,
            "backend": backend_fingerprint(),
            "calibration_us": round(calibration_us(), 1),
            "tuner": tuning.stats(),
            "failures": failures,
            "modules_s": modules_s,
            "benches": benches,
        }
        with open(args.emit_json, "w") as f:
            json.dump(snap, f, indent=1, sort_keys=True)
            f.write("\n")
        print(f"# wrote {args.emit_json} ({len(benches)} benches)",
              file=sys.stderr)
    if failures:
        print(f"# {len(failures)} benchmark(s) failed: "
              f"{', '.join(failures)}", file=sys.stderr)
        raise SystemExit(1)


if __name__ == "__main__":
    main()
