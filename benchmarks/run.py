"""Benchmark harness: one module per paper table/figure + system benches.

Prints ``name,us_per_call,derived`` CSV rows (per assignment contract).
"""
from __future__ import annotations

import sys
import time
import traceback

MODULES = [
    "benchmarks.datacenter_fig2",    # Fig. 2 (a,b)
    "benchmarks.casestudy_fig5",     # Fig. 5 FFT/AES/DCT
    "benchmarks.passthrough_fig6",   # Fig. 6 stage x size sweep
    "benchmarks.multifault_fig7",    # Fig. 7 two-fault sweep
    "benchmarks.hotspare_fig8",      # Fig. 8 FPGA fallback
    "benchmarks.kernel_micro",       # per-kernel parity + wall
    "benchmarks.step_bench",         # staged train/serve under faults
    "benchmarks.serve_bench",        # continuous vs fixed-batch serving
    "benchmarks.fleet_bench",        # MC fault trace through the fleet
    "benchmarks.roofline",           # dry-run roofline summary
]


def main() -> None:
    import importlib
    failures = 0
    print("name,us_per_call,derived")
    for modname in MODULES:
        t0 = time.time()
        try:
            mod = importlib.import_module(modname)
            rows = mod.run()
            for name, us, derived in rows:
                print(f"{name},{us:.1f},{derived}")
            print(f"# {modname} done in {time.time()-t0:.1f}s",
                  file=sys.stderr)
        except Exception:  # noqa: BLE001
            failures += 1
            print(f"# {modname} FAILED", file=sys.stderr)
            traceback.print_exc()
    if failures:
        raise SystemExit(1)


if __name__ == "__main__":
    main()
