"""Bench-trajectory regression checker: old snapshot vs new snapshot.

Usage:
    python -m benchmarks.compare OLD.json NEW.json [--fail-ratio 2.0]

Both files are ``benchmarks.run --emit-json`` snapshots.  Each bench's
``us_per_call`` is first normalized by its snapshot's ``calibration_us``
(a fixed jitted matmul timed on the same runner), so a slower CI machine
does not read as a kernel regression.  Verdicts per bench:

  * ratio > ``--fail-ratio`` (default 2.0)  -> FAIL (exit 1)
  * ratio > ``--warn-ratio`` (default 1.25) -> WARN (printed, exit 0)
  * bench present in OLD but missing in NEW -> FAIL (a bench that
    silently disappears is a coverage regression, not a speedup)
  * bench only in NEW                       -> NEW (informational)

Rows whose old timing is below ``--min-us`` (default 1.0us) are skipped:
at that scale the measurement is dominated by dispatch noise and any
ratio is meaningless.  Self-comparison of a snapshot against itself is
the CI smoke contract: always exit 0.
"""
from __future__ import annotations

import argparse
import json
import sys


def load(path: str) -> dict:
    with open(path) as f:
        snap = json.load(f)
    for key in ("benches", "calibration_us"):
        if key not in snap:
            raise SystemExit(f"{path}: not a bench snapshot (no {key!r})")
    if not snap["calibration_us"] or snap["calibration_us"] <= 0:
        raise SystemExit(f"{path}: bad calibration_us "
                         f"{snap.get('calibration_us')!r}")
    return snap


def _derived_float(row: dict, key: str):
    """Parse a ``<key>=<float>`` entry out of a bench row's derived
    string."""
    for part in str(row.get("derived", "")).split(";"):
        if part.startswith(key + "="):
            try:
                return float(part.split("=", 1)[1])
            except ValueError:
                return None
    return None


def _goodput(row: dict):
    """The traffic benches carry virtual-clock goodput in derived."""
    return _derived_float(row, "goodput")


def _mttr(row: dict):
    """The chaos benches carry mean per-event recovery time in
    derived."""
    return _derived_float(row, "mttr")


def compare(old: dict, new: dict, *, fail_ratio: float = 2.0,
            warn_ratio: float = 1.25, min_us: float = 1.0,
            goodput_drop: float = 0.2, mttr_grow: float = 1.0):
    """Yield (verdict, name, ratio, old_us, new_us) per bench.

    ``ratio`` is calibration-normalized new/old time (>1 = slower); None
    for SKIP/MISSING/NEW rows where no ratio is defined.  Benches whose
    ``derived`` carries ``goodput=`` in both snapshots additionally get
    a GOODPUT row when the new goodput dropped more than
    ``goodput_drop`` — goodput is virtual-clock (deterministic per
    seed), so it is compared raw, with no calibration scaling.  Benches
    carrying ``mttr=`` in both snapshots get an MTTR row when the new
    mean recovery time grew more than ``mttr_grow`` (fractional; the
    chaos MTTRs are virtual/step-clock or retry-budget-bounded, so the
    generous default absorbs runner jitter while still catching a
    recovery path that stopped converging).
    """
    ocal, ncal = old["calibration_us"], new["calibration_us"]
    for name, orow in sorted(old["benches"].items()):
        ous = float(orow["us_per_call"])
        nrow = new["benches"].get(name)
        if nrow is None:
            yield "MISSING", name, None, ous, None
            continue
        nus = float(nrow["us_per_call"])
        og, ng = _goodput(orow), _goodput(nrow)
        if og and ng is not None and ng < og * (1.0 - goodput_drop):
            yield "GOODPUT", name, ng / og, og, ng
        om, nm = _mttr(orow), _mttr(nrow)
        if om and nm is not None and nm > om * (1.0 + mttr_grow):
            yield "MTTR", name, nm / om, om, nm
        if ous < min_us:
            yield "SKIP", name, None, ous, nus
            continue
        ratio = (nus / ncal) / (ous / ocal)
        verdict = ("FAIL" if ratio > fail_ratio
                   else "WARN" if ratio > warn_ratio else "ok")
        yield verdict, name, ratio, ous, nus
    for name, nrow in sorted(new["benches"].items()):
        if name not in old["benches"]:
            yield "NEW", name, None, None, float(nrow["us_per_call"])


def check_families(expected_path: str, telemetry_path: str) -> int:
    """Telemetry coverage gate: every metric family listed in
    ``expected_path`` (a JSON array of names) must be present in the
    telemetry snapshot — a family that silently disappears is an
    instrumentation regression, exactly like a vanished bench row."""
    with open(expected_path) as f:
        expected = json.load(f)
    if not isinstance(expected, list):
        raise SystemExit(f"{expected_path}: expected a JSON array of "
                         f"family names")
    with open(telemetry_path) as f:
        doc = json.load(f)
    snap = doc.get("metrics", doc)
    have = {fam.get("name") for fam in snap.get("families", [])}
    missing = sorted(set(expected) - have)
    print(f"# telemetry families: {len(have)} present, "
          f"{len(expected)} expected")
    for name in missing:
        print(f"MISSING  {name}")
    if missing:
        print(f"# REGRESSION: {len(missing)} metric family(ies) missing "
              f"from {telemetry_path}", file=sys.stderr)
        return 1
    return 0


def main(argv=None) -> int:
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("old", help="committed baseline snapshot "
                    "(BENCH_<n>.json), or the telemetry snapshot when "
                    "--families is given")
    ap.add_argument("new", nargs="?", default=None,
                    help="freshly emitted snapshot")
    ap.add_argument("--families", default=None, metavar="EXPECTED_JSON",
                    help="telemetry mode: check that the snapshot "
                         "(positional OLD) contains every metric family "
                         "named in EXPECTED_JSON; exit 1 on any missing")
    ap.add_argument("--fail-ratio", type=float, default=2.0)
    ap.add_argument("--warn-ratio", type=float, default=1.25)
    ap.add_argument("--min-us", type=float, default=1.0)
    ap.add_argument("--goodput-drop", type=float, default=0.2,
                    help="max tolerated fractional goodput drop for "
                         "rows carrying goodput= in derived")
    ap.add_argument("--mttr-grow", type=float, default=1.0,
                    help="max tolerated fractional MTTR growth for "
                         "rows carrying mttr= in derived")
    args = ap.parse_args(argv)

    if args.families is not None:
        return check_families(args.families, args.old)
    if args.new is None:
        ap.error("NEW snapshot required (or pass --families)")

    old, new = load(args.old), load(args.new)
    scale = new["calibration_us"] / old["calibration_us"]
    print(f"# calibration: old {old['calibration_us']:.1f}us  "
          f"new {new['calibration_us']:.1f}us  (runner {scale:.2f}x)")
    counts: dict = {}
    for verdict, name, ratio, ous, nus in compare(
            old, new, fail_ratio=args.fail_ratio,
            warn_ratio=args.warn_ratio, min_us=args.min_us,
            goodput_drop=args.goodput_drop, mttr_grow=args.mttr_grow):
        counts[verdict] = counts.get(verdict, 0) + 1
        if verdict in ("ok", "SKIP"):
            # SKIP rows are the analytic (0-us derived-metric) benches;
            # listing all of them would drown the actionable lines
            continue
        rtxt = f"{ratio:.2f}x" if ratio is not None else "-"
        unit = {"GOODPUT": "tok/s", "MTTR": "s"}.get(verdict, "us")
        prec = 4 if verdict == "MTTR" else 1
        otxt = f"{ous:.{prec}f}" if ous is not None else "-"
        ntxt = f"{nus:.{prec}f}" if nus is not None else "-"
        print(f"{verdict:8s} {name:40s} {rtxt:>8s}  "
              f"old {otxt}{unit}  new {ntxt}{unit}")
    total = sum(counts.values())
    print(f"# {total} benches: " + ", ".join(
        f"{v} {verdict.lower()}" for verdict, v in sorted(counts.items())))
    bad = (counts.get("FAIL", 0) + counts.get("MISSING", 0)
           + counts.get("GOODPUT", 0) + counts.get("MTTR", 0))
    if bad:
        print(f"# REGRESSION: {bad} bench(es) failed the "
              f">{args.fail_ratio:g}x gate (goodput drop, MTTR growth, "
              f"or went missing)", file=sys.stderr)
        return 1
    return 0


if __name__ == "__main__":
    raise SystemExit(main())
