"""Roofline report: reads artifacts/dryrun/*.json into the §Roofline table.

Usage:  PYTHONPATH=src python -m benchmarks.roofline [--mesh single]
Also exposes run() rows for benchmarks.run (summary stats only).
"""
from __future__ import annotations

import glob
import json
import os
from typing import Dict, List

ART = os.path.join(os.path.dirname(__file__), "..", "artifacts", "dryrun")


def load(mesh: str = "single", tag: str = "") -> List[Dict]:
    recs = []
    for fn in sorted(glob.glob(os.path.join(ART, f"*__{mesh}{tag}.json"))):
        base = os.path.basename(fn)
        # skip hillclimb-tagged files when loading baselines
        if not tag and base.count("__") != 2:
            continue
        with open(fn) as f:
            recs.append(json.load(f))
    return recs


def fmt_table(recs: List[Dict]) -> str:
    hdr = ("| arch | shape | status | compute_s | memory_s | coll_s | "
           "dominant | useful/HLO | frac(XLA) | frac(HW) | temp_GB |")
    sep = "|" + "---|" * 11
    lines = [hdr, sep]
    for r in sorted(recs, key=lambda x: (x["arch"], x["shape"])):
        if r["status"] != "ok":
            reason = r.get("reason", r.get("error", ""))[:60]
            lines.append(f"| {r['arch']} | {r['shape']} | {r['status']}: "
                         f"{reason} | | | | | | | | |")
            continue
        rf = r["roofline"]
        tempgb = r["memory"]["temp_bytes"] / 1e9
        hw = rf.get("hw_route", {}).get("roofline_fraction", float("nan"))
        lines.append(
            f"| {r['arch']} | {r['shape']} | ok "
            f"| {rf['compute_s']:.3e} | {rf['memory_s']:.3e} "
            f"| {rf['collective_s']:.3e} | {rf['dominant'].split('_')[0]} "
            f"| {rf['useful_flops_ratio']:.3f} "
            f"| {rf['roofline_fraction']:.4f} | {hw:.4f} | {tempgb:.1f} |")
    return "\n".join(lines)


def run():
    rows = []
    for mesh in ("single", "multi"):
        recs = load(mesh)
        ok = [r for r in recs if r["status"] == "ok"]
        skip = [r for r in recs if r["status"] == "skip"]
        fail = [r for r in recs if r["status"] == "fail"]
        rows.append((f"dryrun_{mesh}_cells_ok", 0.0,
                     f"{len(ok)} ok/{len(skip)} skip/{len(fail)} fail"))
        if ok:
            worst = min(ok, key=lambda r: r["roofline"]["roofline_fraction"])
            best = max(ok, key=lambda r: r["roofline"]["roofline_fraction"])
            rows.append((f"dryrun_{mesh}_best_roofline", 0.0,
                         f"{best['arch']}/{best['shape']}="
                         f"{best['roofline']['roofline_fraction']:.3f}"))
            rows.append((f"dryrun_{mesh}_worst_roofline", 0.0,
                         f"{worst['arch']}/{worst['shape']}="
                         f"{worst['roofline']['roofline_fraction']:.4f}"))
            for dom in ("compute_s", "memory_s", "collective_s"):
                n = sum(1 for r in ok if r["roofline"]["dominant"] == dom)
                rows.append((f"dryrun_{mesh}_dominated_by_{dom}", 0.0,
                             str(n)))
    return rows


if __name__ == "__main__":
    import argparse
    ap = argparse.ArgumentParser()
    ap.add_argument("--mesh", default="single")
    # accepted for CI uniformity: the dry-run analysis has no RNG
    ap.add_argument("--seed", type=int, default=0)
    args = ap.parse_args()
    print(fmt_table(load(args.mesh)))
