"""Regenerate the data-driven sections of EXPERIMENTS.md from
artifacts/dryrun/*.json.  Usage: PYTHONPATH=src python -m benchmarks.make_experiments
"""
from __future__ import annotations

import os
import re

from benchmarks.roofline import fmt_table, load

EXP = os.path.join(os.path.dirname(__file__), "..", "EXPERIMENTS.md")


def dryrun_summary() -> str:
    lines = []
    for mesh, label in [("single", "single-pod (16x16 = 256 chips)"),
                        ("multi", "multi-pod (2x16x16 = 512 chips)")]:
        recs = load(mesh)
        ok = [r for r in recs if r["status"] == "ok"]
        skip = [r for r in recs if r["status"] == "skip"]
        fail = [r for r in recs if r["status"] == "fail"]
        fits = [r for r in ok if r.get("fits_hbm")]
        if not recs:
            lines.append(f"* {label}: (not yet run)")
            continue
        lines.append(
            f"* **{label}**: {len(ok)} cells compile OK "
            f"({len(fits)} fit <=16 GB/chip), {len(skip)} skipped "
            f"(long_500k rule), {len(fail)} failed.")
        over = [r for r in ok if not r.get("fits_hbm")]
        if over:
            lines.append("  over-HBM cells: " + ", ".join(
                f"{r['arch']}/{r['shape']}"
                f" ({(r['memory']['temp_bytes']+r['memory']['argument_bytes'])/1e9:.0f} GB)"
                for r in over))
        comp = [r["compile_s"] for r in ok]
        if comp:
            lines.append(f"  compile time: median "
                         f"{sorted(comp)[len(comp)//2]:.0f}s, "
                         f"max {max(comp):.0f}s per cell.")
    return "\n".join(lines)


def main():
    with open(EXP) as f:
        text = f.read()
    text = re.sub(
        r"<!-- DRYRUN_SUMMARY -->.*?(?=\n## |\Z)",
        "<!-- DRYRUN_SUMMARY -->\n" + dryrun_summary() + "\n\n",
        text, flags=re.S) if "<!-- DRYRUN_SUMMARY -->" in text else text
    table = fmt_table(load("single"))
    text = re.sub(
        r"<!-- ROOFLINE_TABLE -->.*?(?=\nMethodology caveats)",
        "<!-- ROOFLINE_TABLE -->\n" + table + "\n",
        text, flags=re.S) if "<!-- ROOFLINE_TABLE -->" in text else text
    with open(EXP, "w") as f:
        f.write(text)
    print("EXPERIMENTS.md updated")


if __name__ == "__main__":
    main()
