"""Fig. 8 reproduction: hot-spare FPGA fallback vs software fallback."""
from __future__ import annotations

from repro.core.latency import passthrough_model, speedup_vs_sw

FPGA = [1, 35, 50, 100, 150, 200]


def run():
    rows = []
    m = passthrough_model(60_000, 6)     # the paper's operating point
    for f in FPGA:
        s = speedup_vs_sw(m, [0], fallback_speedup=f)
        rows.append((f"fig8_speedup@fpga={f}x", 0.0, f"{s:.2f}x"))
    # transmission-bottleneck claim: fpga gains saturate
    s35 = speedup_vs_sw(m, [0], fallback_speedup=35)
    s200 = speedup_vs_sw(m, [0], fallback_speedup=200)
    rows.append(("fig8_saturation_s200_over_s35", 0.0,
                 f"{s200/s35:.3f}"))
    # §V-G: a directly-connected hot spare retains ~80% of accel speed
    big = passthrough_model(600_000, 6)
    frac = speedup_vs_sw(big, [0], fallback_speedup=200,
                         direct_fallback=True) / speedup_vs_sw(big)
    rows.append(("fig8_direct_hotspare_frac_of_full_speed", 0.0,
                 f"{frac:.2f}"))
    return rows


if __name__ == "__main__":
    import argparse
    ap = argparse.ArgumentParser(description=__doc__)
    # accepted for CI uniformity: this bench is closed-form (no RNG)
    ap.add_argument("--seed", type=int, default=0)
    ap.parse_args()
    for row in run():
        print("%s,%.1f,%s" % row)
