"""Fig. 5 reproduction: FFT/AES/DCT execution time as % of software,
paired with *measured* staged-accelerator wall time on this host (the
functional pipelines are real JAX; the cycle model gives the %)."""
from __future__ import annotations

import time

import jax
import jax.numpy as jnp
import numpy as np

from repro.core.casestudies import (aes_accelerator, dct_accelerator,
                                    fft_accelerator)
from repro.core.latency import (aes_model, dct_model, fft_model,
                                speedup_vs_sw)


def _wall(fn, *args, n=20):
    fn(*args)
    t0 = time.perf_counter()
    for _ in range(n):
        out = fn(*args)
    jax.block_until_ready(out)
    return (time.perf_counter() - t0) / n * 1e6


def run(seed: int = 0):
    rows = []
    # analytic (paper-reported) points
    for name, m, fault in [("fft", fft_model(), [2]),
                           ("dct", dct_model(), [0])]:
        rows.append((f"fig5_{name}_nofault_pct_of_sw", 0.0,
                     f"{100/speedup_vs_sw(m):.1f}%"))
        rows.append((f"fig5_{name}_1fault_pct_of_sw", 0.0,
                     f"{100/speedup_vs_sw(m, fault):.1f}%"))
    for n in (3, 11):
        m = aes_model(n)
        rows.append((f"fig5_aes{n}_1fault_pct_of_sw", 0.0,
                     f"{100/speedup_vs_sw(m, [1]):.1f}%"))
    # measured wall time of the functional pipelines (healthy vs 1-fault
    # routing — outputs identical; the routing overhead is what's measured)
    rng = np.random.default_rng(seed)
    fft = fft_accelerator(64)
    x = jnp.asarray(rng.normal(size=(64, 64)) +
                    1j * rng.normal(size=(64, 64))).astype(jnp.complex64)
    healthy = jax.jit(lambda a: fft.run(a))
    sig = fft.healthy_signature().with_fault("fft_s3")
    faulted = jax.jit(lambda a: fft.run(a, sig))
    rows.append(("fft64_staged_healthy", _wall(healthy, x), "jit"))
    rows.append(("fft64_staged_1fault_routed", _wall(faulted, x), "jit"))
    dct = dct_accelerator()
    xd = jnp.asarray(rng.normal(size=(256, 8, 8)), jnp.float32)
    rows.append(("dct_staged_healthy",
                 _wall(jax.jit(lambda a: dct.run(a)), xd), "jit"))
    aes = aes_accelerator(np.arange(16, dtype=np.uint8), 11)
    xa = jnp.asarray(rng.integers(0, 256, size=(1024, 16)), jnp.uint8)
    rows.append(("aes11_staged_healthy",
                 _wall(jax.jit(lambda a: aes.run(a)), xa), "jit"))
    return rows


if __name__ == "__main__":
    import argparse
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--seed", type=int, default=0,
                    help="input-data RNG seed")
    for row in run(seed=ap.parse_args().seed):
        print("%s,%.1f,%s" % row)
