"""Per-kernel microbenchmarks: SW (XLA) wall time on this host + analytic
FLOPs; interpret-mode parity error as the 'derived' check column.

(Absolute kernel wall times are CPU-host numbers; the TPU story lives in
the roofline report.  What matters here: the harness runs, the Viscosity
contracts hold, and the SW lowering is a real jitted implementation.)
"""
from __future__ import annotations

import time

import jax
import jax.numpy as jnp
import numpy as np

from repro.kernels.flash_attention import ops as attn_ops
from repro.kernels.flash_attention.ref import attention_flops
from repro.kernels.mamba2_scan import ops as ssd_ops
from repro.kernels.rwkv6_scan import ops as wkv_ops
from repro.kernels.swiglu import ops as swiglu_ops
from repro.kernels.swiglu.ref import swiglu_flops
from repro.kernels.checksum import checksum, checksum_ref


def _wall(fn, *args, n=10, **kw):
    out = fn(*args, **kw)
    jax.block_until_ready(out)
    t0 = time.perf_counter()
    for _ in range(n):
        out = fn(*args, **kw)
    jax.block_until_ready(out)
    return (time.perf_counter() - t0) / n * 1e6


def run(seed: int = 0):
    rng = np.random.default_rng(seed)
    rows = []
    # attention
    B, S, H, Hkv, D = 2, 512, 8, 2, 64
    q = jnp.asarray(rng.normal(size=(B, S, H, D)), jnp.float32)
    k = jnp.asarray(rng.normal(size=(B, S, Hkv, D)), jnp.float32)
    v = jnp.asarray(rng.normal(size=(B, S, Hkv, D)), jnp.float32)
    sw = jax.jit(lambda *a: attn_ops.attention(*a, causal=True, route="sw"))
    us = _wall(sw, q, k, v)
    ref = sw(q, k, v)
    hw = attn_ops.attention(q, k, v, causal=True, route="interpret")
    err = float(jnp.abs(ref - hw).max())
    fl = attention_flops(B, S, S, H, D)
    rows.append((f"attn_sw_B{B}S{S}H{H}", us,
                 f"gflops={fl/us/1e3:.2f};interp_err={err:.1e}"))
    # ssd
    x = jnp.asarray(rng.normal(size=(2, 512, 4, 32)), jnp.float32)
    dt = jnp.asarray(rng.uniform(0.01, 0.2, size=(2, 512, 4)), jnp.float32)
    A = jnp.asarray(-rng.uniform(0.5, 2, size=(4,)), jnp.float32)
    Bm = jnp.asarray(rng.normal(size=(2, 512, 16)), jnp.float32)
    C = jnp.asarray(rng.normal(size=(2, 512, 16)), jnp.float32)
    sw = jax.jit(lambda *a: ssd_ops.ssd(*a, route="sw", chunk=64))
    us = _wall(sw, x, dt, A, Bm, C)
    err = float(jnp.abs(sw(x, dt, A, Bm, C) -
                        ssd_ops.ssd(x, dt, A, Bm, C, route="interpret",
                                    chunk=64)).max())
    rows.append(("ssd_sw_S512", us, f"interp_err={err:.1e}"))
    # wkv6
    r = jnp.asarray(rng.normal(size=(2, 256, 4, 16)), jnp.float32)
    kk = jnp.asarray(rng.normal(size=(2, 256, 4, 16)), jnp.float32)
    vv = jnp.asarray(rng.normal(size=(2, 256, 4, 16)), jnp.float32)
    lw = jnp.asarray(-rng.uniform(0.01, 3, size=(2, 256, 4, 16)),
                     jnp.float32)
    u = jnp.asarray(rng.normal(size=(4, 16)), jnp.float32)
    sw = jax.jit(lambda *a: wkv_ops.wkv6(*a, route="sw", chunk=16))
    us = _wall(sw, r, kk, vv, lw, u)
    err = float(jnp.abs(sw(r, kk, vv, lw, u) -
                        wkv_ops.wkv6(r, kk, vv, lw, u, route="interpret",
                                     chunk=16)).max())
    rows.append(("wkv6_sw_S256", us, f"interp_err={err:.1e}"))
    # swiglu
    M, Dm, F = 256, 256, 1024
    xm = jnp.asarray(rng.normal(size=(M, Dm)), jnp.float32)
    w1 = jnp.asarray(rng.normal(size=(Dm, F)) * 0.05, jnp.float32)
    w3 = jnp.asarray(rng.normal(size=(Dm, F)) * 0.05, jnp.float32)
    w2 = jnp.asarray(rng.normal(size=(F, Dm)) * 0.05, jnp.float32)
    sw = jax.jit(lambda *a: swiglu_ops.swiglu(*a, route="sw"))
    us = _wall(sw, xm, w1, w3, w2)
    err = float(jnp.abs(sw(xm, w1, w3, w2) -
                        swiglu_ops.swiglu(xm, w1, w3, w2,
                                          route="interpret")).max())
    fl = swiglu_flops(M, Dm, F)
    rows.append((f"swiglu_sw_M{M}F{F}", us,
                 f"gflops={fl/us/1e3:.2f};interp_err={err:.1e}"))
    # checksum
    big = jnp.asarray(rng.normal(size=(1 << 16,)), jnp.float32)
    sw = jax.jit(checksum_ref)
    us = _wall(sw, big)
    same = int(sw(big)) == int(checksum(big, route="interpret"))
    rows.append(("checksum_sw_64k", us, f"bitexact={same}"))

    # tuned vs default: inline-tune the SW chunk knobs (real XLA
    # tunables — chunking changes the lowered program) with a tiny
    # budget and report both walls side by side.  persist=False keeps
    # the bench hermetic: nothing is written to the on-disk cache.
    from repro.kernels import tuning
    from repro.kernels.tuning import tuner as ktuner

    def tuned_pair(name, kernel, shape, make_fn, arrays, default_cfg):
        measure = ktuner.jax_measure(make_fn, arrays, reps=3)
        default_us = measure(default_cfg)
        cfg, tuned_us = tuning.tune_kernel(
            kernel, "sw", shape, jnp.float32, measure=measure,
            budget=8, persist=False)
        knob = ";".join(f"{kk_}={vv_}" for kk_, vv_ in sorted(cfg.items()))
        dflt = ";".join(f"{kk_}={vv_}" for kk_, vv_ in
                        sorted(default_cfg.items()))
        return [(f"{name}_default", default_us, f"cfg={dflt}"),
                (f"{name}_tuned", tuned_us,
                 f"cfg={knob};speedup={default_us/max(tuned_us,1e-9):.2f}x")]

    rows += tuned_pair(
        "attn_sw_tune", "flash_attention", (B, S, S, H, Hkv, D),
        lambda cfg: jax.jit(lambda *a: attn_ops.attention(
            *a, causal=True, route="sw", kv_chunk=cfg["kv_chunk"])),
        (q, k, v), {"kv_chunk": 512})
    rows += tuned_pair(
        "ssd_sw_tune", "mamba2_ssd", (2, 512, 4, 32, 16),
        lambda cfg: jax.jit(lambda *a: ssd_ops.ssd(
            *a, route="sw", chunk=cfg["chunk"])),
        (x, dt, A, Bm, C), {"chunk": 128})
    rows += tuned_pair(
        "wkv6_sw_tune", "rwkv6_wkv", (2, 256, 4, 16, 16),
        lambda cfg: jax.jit(lambda *a: wkv_ops.wkv6(
            *a, route="sw", chunk=cfg["chunk"])),
        (r, kk, vv, lw, u), {"chunk": 16})
    return rows


if __name__ == "__main__":
    import argparse
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--seed", type=int, default=0,
                    help="input-data RNG seed")
    for row in run(seed=ap.parse_args().seed):
        print("%s,%.1f,%s" % row)
