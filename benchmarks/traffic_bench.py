"""Open-loop traffic benchmark: goodput + tail latency under faults.

Drives seeded open-loop workloads (Poisson, diurnal, flash-crowd — see
``repro.serve.traffic``) through the admission front end
(``repro.serve.frontend``) over a 2-device ``FleetServeEngine``, healthy
and with a mid-burst stage quarantine, in both failover modes.  This is
the paper's §II Fig. 2 claim measured the honest way: arrivals do not
wait for the system, so a quarantine that stalls the fleet shows up as
queue growth, blown deadlines, and a p99 spike — not just a longer wall
time.

Reported per scenario: goodput (virtual-clock tokens/s over completions
that met their deadline), p50/p99 end-to-end latency and TTFT, and
deadline-met counts.  The *closure* scenario checks the degradation
story end to end: under saturating Poisson load, the post-quarantine
throughput ratio measured from per-step decoded tokens must match the
``DegradationModel`` analytic capacity ratio within 15% relative error,
with zero dropped non-expired requests (``run()`` raises otherwise — a
silent miss can never ride a green bench).

``python benchmarks/traffic_bench.py [--smoke]`` prints one JSON object;
``run()`` returns the usual ``name,us_per_call,derived`` rows for
``benchmarks/run.py`` (goodput rides in ``derived`` where
``benchmarks/compare.py`` gates it against drops).
"""
from __future__ import annotations

import json
import time

import jax
import numpy as np

from repro.configs import get_config
from repro.core.datacenter import DegradationModel
from repro.models import build_model
from repro.obs import metrics as obs_metrics
from repro.obs import report as obs_report
from repro.serve import (BLOCK, RECOMPILE, RESIDENT, Diurnal, FlashCrowd,
                         FleetConfig, FleetServeEngine, Frontend,
                         FrontendConfig, LengthModel, Poisson, ServeConfig)
from repro.viscosity import INTERPRET

ARCH = "qwen1.5-4b"
# Interpreted healthy lowering so the injected fault is a *real* reroute
# (interpret -> SW oracle); with the SW route the ±fault comparison would
# measure nothing (same rationale as serve_bench).
HW_ROUTE = INTERPRET
MAX_LEN = 48
SLOTS = 3
DEVICES = 2
STEP_TIME_S = 0.05                   # virtual seconds per engine step
FAULT_STAGE = "flash_attention"


def _lengths(cfg):
    # few distinct prompt lengths: prefill compiles once per length
    return LengthModel(vocab_size=cfg.vocab_size, min_prompt=6,
                       max_prompt=12, min_new=4, max_new=9,
                       dist="pareto", alpha=1.8, clamp_len=MAX_LEN)


def _patterns(cfg, n):
    """(name, workload, fault_step): the fault step sits mid-burst /
    mid-arrival for each arrival process."""
    lm = _lengths(cfg)
    slack = dict(slack_s=3.0, slack_per_token_s=0.15)
    return [
        ("poisson",
         Poisson(n_requests=n, rate=14.0, lengths=lm, **slack), 10),
        ("diurnal",
         Diurnal(n_requests=n, base_rate=3.0, peak_rate=18.0,
                 period_s=4.0, lengths=lm, **slack), 14),
        ("flash_crowd",
         FlashCrowd(n_requests=n, base_rate=5.0, burst_factor=7.0,
                    burst_start_s=0.5, burst_dur_s=1.0, lengths=lm,
                    **slack), 16),
    ]


def _engine(cfg, params, failover):
    scfg = ServeConfig(max_len=MAX_LEN, max_slots=SLOTS,
                       hw_route=HW_ROUTE, failover=failover)
    fcfg = FleetConfig(n_devices=DEVICES, model=DegradationModel())
    return FleetServeEngine(cfg, params, scfg, fcfg)


def _run_one(eng, reqs, fault_step, *, section):
    """One frontend run; fault_step=None keeps the fleet healthy.
    Recovers the fleet afterwards so the engine (and its compile caches)
    is reusable across scenarios.  Goodput/throughput/counts are read
    back from the telemetry the run recorded under ``section`` — the
    snapshot, not the frontend's private stats dict, is the source of
    truth (the two are bit-equal by the obs.metrics contract; tails stay
    stats-side, histograms keep only exact count/sum/min/max)."""
    fe = Frontend(eng, FrontendConfig(step_time_s=STEP_TIME_S,
                                      max_queue=4 * DEVICES * SLOTS,
                                      shed=BLOCK))
    events = ({fault_step: [("stage", 0, FAULT_STAGE)]}
              if fault_step is not None else None)
    with obs_metrics.label_scope(section=section):
        t0 = time.perf_counter()
        comps, stats = fe.run(reqs, events=events)
        wall = time.perf_counter() - t0
    if fault_step is not None:
        eng.recover(0)
    n_tok = sum(len(c.tokens) for c in comps.values())
    g = obs_report.goodput_summary(obs_metrics.registry().snapshot(),
                                   section=section)
    return {
        "goodput_tok_s": round(g["goodput_tok_s"], 2),
        "throughput_tok_s": round(g["throughput_tok_s"], 2),
        "p50_latency_s": round(stats["p50_latency_s"], 4),
        "p99_latency_s": round(stats["p99_latency_s"], 4),
        "p50_ttft_s": round(stats["p50_ttft_s"], 4),
        "p99_ttft_s": round(stats["p99_ttft_s"], 4),
        "deadline_met": g["deadline_met"],
        "completed": g["completed"],
        "expired": g["expired"],
        "requests": len(reqs),
        "requeued": stats["engine"]["requeued"],
        "virtual_time_s": round(g["virtual_time_s"], 2),
        "wall_s": round(wall, 2),
        "wall_us_per_tok": round(1e6 * wall / max(n_tok, 1), 1),
    }


def _window_mean(xs, lo, hi):
    w = xs[lo:hi]
    return float(np.mean(w)) if w else 0.0


def closure(cfg, params, seed, *, n=40, failover=RESIDENT):
    """Measured-vs-analytic goodput closure under a mid-burst quarantine.

    Saturating Poisson load (offered rate far above fleet capacity), no
    deadlines, ``shed=BLOCK``: zero requests may be shed or expire.  The
    per-step decoded-token mean over the post-fault window, relative to
    the pre-fault window, must match the ``DegradationModel`` capacity
    ratio (slot-quantized, straight from the engine's per-step capacity
    trace) within 15%."""
    fault_step = 12
    wl = Poisson(n_requests=n, rate=60.0, lengths=_lengths(cfg))
    reqs = wl.build(seed)
    eng = _engine(cfg, params, failover)
    fe = Frontend(eng, FrontendConfig(step_time_s=STEP_TIME_S,
                                      max_queue=2 * n, shed=BLOCK))
    comps, stats = fe.run(
        reqs, events={fault_step: [("stage", 0, FAULT_STAGE)]})
    eng.recover(0)
    pst = stats["engine"]["per_step_tokens"]
    cap = stats["engine"]["capacity"]
    h_lo, h_hi = 4, fault_step                  # post-warmup, pre-fault
    f_lo = fault_step + 2                       # post-drain/requeue
    f_hi = min(f_lo + 20, int(0.8 * len(pst)))  # still saturated
    measured = _window_mean(pst, f_lo, f_hi) / \
        max(_window_mean(pst, h_lo, h_hi), 1e-9)
    analytic = _window_mean(cap, f_lo, f_hi) / \
        max(_window_mean(cap, h_lo, h_hi), 1e-9)
    obs_metrics.set_gauge("closure_ratio", measured, source="measured")
    obs_metrics.set_gauge("closure_ratio", analytic, source="analytic")
    rel_err = abs(measured - analytic) / max(analytic, 1e-9)
    dropped = [r.rid for r in reqs
               if r.rid not in comps or comps[r.rid].expired]
    return {
        "failover": failover,
        "n_requests": n,
        "fault_step": fault_step,
        "measured_ratio": round(measured, 4),
        "analytic_ratio": round(analytic, 4),
        "rel_err": round(rel_err, 4),
        "dropped_non_expired": dropped,
        "windows": {"healthy": [h_lo, h_hi], "fault": [f_lo, f_hi]},
    }


def bench(seed: int = 0, *, n: int = 20, closure_n: int = 40):
    cfg = get_config(ARCH).reduced()
    model = build_model(cfg)
    params = model.init(jax.random.PRNGKey(seed))
    out = {"workload": {"arch": ARCH, "devices": DEVICES, "slots": SLOTS,
                        "max_len": MAX_LEN, "requests": n, "seed": seed,
                        "step_time_s": STEP_TIME_S},
           "patterns": {}}
    # one bench run = one registry; each cell records under its own
    # section label, so the snapshot keeps every scenario separable
    reg = obs_metrics.Registry()
    with obs_metrics.use(reg):
        for mode in (RECOMPILE, RESIDENT):
            eng = _engine(cfg, params, mode)   # one engine per mode: the
            for name, wl, fault_step in _patterns(cfg, n):  # caches
                reqs = wl.build(seed)                       # span patterns
                cell = out["patterns"].setdefault(name, {})
                cell[mode] = {
                    "healthy": _run_one(eng, reqs, None,
                                        section=f"{name}_{mode}_healthy"),
                    "fault": _run_one(eng, reqs, fault_step,
                                      section=f"{name}_{mode}_fault"),
                }
        with obs_metrics.label_scope(section="closure"):
            out["closure"] = closure(cfg, params, seed, n=closure_n)
    out["telemetry"] = {"metrics": reg.snapshot()}
    return out


def run(seed: int = 0):
    """CSV rows for benchmarks/run.py (name, us_per_call, derived).

    ``us_per_call`` is wall time per decoded token (runner-dependent,
    calibration-normalized by compare.py); ``derived`` carries the
    virtual-clock goodput and tails (deterministic given the seed) that
    compare.py's goodput gate watches."""
    res = bench(seed, n=16, closure_n=36)
    rows = []
    for pattern, cell in res["patterns"].items():
        for mode, runs in cell.items():
            for label, m in runs.items():
                rows.append((
                    f"traffic_{pattern}_{mode}_{label}",
                    m["wall_us_per_tok"],
                    f"goodput={m['goodput_tok_s']:.1f};"
                    f"p50={m['p50_latency_s']*1e3:.0f}ms;"
                    f"p99={m['p99_latency_s']*1e3:.0f}ms;"
                    f"met={m['deadline_met']}/{m['requests']}"))
    c = res["closure"]
    if c["rel_err"] > 0.15 or c["dropped_non_expired"]:
        raise RuntimeError(
            f"goodput closure failed: rel_err={c['rel_err']} "
            f"(measured {c['measured_ratio']} vs analytic "
            f"{c['analytic_ratio']}), dropped={c['dropped_non_expired']}")
    rows.append(("traffic_goodput_closure", 0.0,
                 f"measured={c['measured_ratio']};"
                 f"analytic={c['analytic_ratio']};"
                 f"rel_err={c['rel_err']};dropped=0"))
    return rows


def main(argv=None):
    import argparse
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--seed", type=int, default=0,
                    help="workload/init RNG seed")
    ap.add_argument("--smoke", action="store_true",
                    help="small CI sizing (same scenario coverage)")
    ap.add_argument("--telemetry", default=None, metavar="PATH",
                    help="write the run's metrics snapshot here "
                         "(readable by python -m repro.obs.report)")
    args = ap.parse_args(argv)
    out = bench(args.seed, n=10 if args.smoke else 20,
                closure_n=30 if args.smoke else 40)
    telemetry = out.pop("telemetry")
    if args.telemetry:
        with open(args.telemetry, "w") as f:
            json.dump(telemetry, f, sort_keys=True,
                      separators=(",", ":"))
    print(json.dumps(out, indent=2))


if __name__ == "__main__":
    main()
