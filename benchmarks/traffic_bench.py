"""Open-loop traffic benchmark: goodput + tail latency under faults.

Drives seeded open-loop workloads (Poisson, diurnal, flash-crowd — see
``repro.serve.traffic``) through the admission front end
(``repro.serve.frontend``) over a 2-device ``FleetServeEngine``, healthy
and with a mid-burst stage quarantine, in both failover modes.  This is
the paper's §II Fig. 2 claim measured the honest way: arrivals do not
wait for the system, so a quarantine that stalls the fleet shows up as
queue growth, blown deadlines, and a p99 spike — not just a longer wall
time.

Reported per scenario: goodput (virtual-clock tokens/s over completions
that met their deadline), p50/p99 end-to-end latency and TTFT, and
deadline-met counts.  The *closure* scenario checks the degradation
story end to end: under saturating Poisson load, the post-quarantine
throughput ratio measured from per-step decoded tokens must match the
``DegradationModel`` analytic capacity ratio within 15% relative error,
with zero dropped non-expired requests (``run()`` raises otherwise — a
silent miss can never ride a green bench).

``python benchmarks/traffic_bench.py [--smoke]`` prints one JSON object;
``run()`` returns the usual ``name,us_per_call,derived`` rows for
``benchmarks/run.py`` (goodput rides in ``derived`` where
``benchmarks/compare.py`` gates it against drops).
"""
from __future__ import annotations

import json
import time

import jax
import numpy as np

from repro.configs import get_config
from repro.core.datacenter import DegradationModel
from repro.models import build_model
from repro.serve import (BLOCK, RECOMPILE, RESIDENT, Diurnal, FlashCrowd,
                         FleetConfig, FleetServeEngine, Frontend,
                         FrontendConfig, LengthModel, Poisson, ServeConfig)
from repro.viscosity import INTERPRET

ARCH = "qwen1.5-4b"
# Interpreted healthy lowering so the injected fault is a *real* reroute
# (interpret -> SW oracle); with the SW route the ±fault comparison would
# measure nothing (same rationale as serve_bench).
HW_ROUTE = INTERPRET
MAX_LEN = 48
SLOTS = 3
DEVICES = 2
STEP_TIME_S = 0.05                   # virtual seconds per engine step
FAULT_STAGE = "flash_attention"


def _lengths(cfg):
    # few distinct prompt lengths: prefill compiles once per length
    return LengthModel(vocab_size=cfg.vocab_size, min_prompt=6,
                       max_prompt=12, min_new=4, max_new=9,
                       dist="pareto", alpha=1.8, clamp_len=MAX_LEN)


def _patterns(cfg, n):
    """(name, workload, fault_step): the fault step sits mid-burst /
    mid-arrival for each arrival process."""
    lm = _lengths(cfg)
    slack = dict(slack_s=3.0, slack_per_token_s=0.15)
    return [
        ("poisson",
         Poisson(n_requests=n, rate=14.0, lengths=lm, **slack), 10),
        ("diurnal",
         Diurnal(n_requests=n, base_rate=3.0, peak_rate=18.0,
                 period_s=4.0, lengths=lm, **slack), 14),
        ("flash_crowd",
         FlashCrowd(n_requests=n, base_rate=5.0, burst_factor=7.0,
                    burst_start_s=0.5, burst_dur_s=1.0, lengths=lm,
                    **slack), 16),
    ]


def _engine(cfg, params, failover):
    scfg = ServeConfig(max_len=MAX_LEN, max_slots=SLOTS,
                       hw_route=HW_ROUTE, failover=failover)
    fcfg = FleetConfig(n_devices=DEVICES, model=DegradationModel())
    return FleetServeEngine(cfg, params, scfg, fcfg)


def _run_one(eng, reqs, fault_step):
    """One frontend run; fault_step=None keeps the fleet healthy.
    Recovers the fleet afterwards so the engine (and its compile caches)
    is reusable across scenarios."""
    fe = Frontend(eng, FrontendConfig(step_time_s=STEP_TIME_S,
                                      max_queue=4 * DEVICES * SLOTS,
                                      shed=BLOCK))
    events = ({fault_step: [("stage", 0, FAULT_STAGE)]}
              if fault_step is not None else None)
    t0 = time.perf_counter()
    comps, stats = fe.run(reqs, events=events)
    wall = time.perf_counter() - t0
    if fault_step is not None:
        eng.recover(0)
    n_tok = sum(len(c.tokens) for c in comps.values())
    return {
        "goodput_tok_s": round(stats["goodput_tok_s"], 2),
        "throughput_tok_s": round(stats["throughput_tok_s"], 2),
        "p50_latency_s": round(stats["p50_latency_s"], 4),
        "p99_latency_s": round(stats["p99_latency_s"], 4),
        "p50_ttft_s": round(stats["p50_ttft_s"], 4),
        "p99_ttft_s": round(stats["p99_ttft_s"], 4),
        "deadline_met": stats["deadline_met"],
        "completed": stats["completed"],
        "expired": stats["expired"],
        "requests": len(reqs),
        "requeued": stats["engine"]["requeued"],
        "virtual_time_s": round(stats["virtual_time_s"], 2),
        "wall_s": round(wall, 2),
        "wall_us_per_tok": round(1e6 * wall / max(n_tok, 1), 1),
    }


def _window_mean(xs, lo, hi):
    w = xs[lo:hi]
    return float(np.mean(w)) if w else 0.0


def closure(cfg, params, seed, *, n=40, failover=RESIDENT):
    """Measured-vs-analytic goodput closure under a mid-burst quarantine.

    Saturating Poisson load (offered rate far above fleet capacity), no
    deadlines, ``shed=BLOCK``: zero requests may be shed or expire.  The
    per-step decoded-token mean over the post-fault window, relative to
    the pre-fault window, must match the ``DegradationModel`` capacity
    ratio (slot-quantized, straight from the engine's per-step capacity
    trace) within 15%."""
    fault_step = 12
    wl = Poisson(n_requests=n, rate=60.0, lengths=_lengths(cfg))
    reqs = wl.build(seed)
    eng = _engine(cfg, params, failover)
    fe = Frontend(eng, FrontendConfig(step_time_s=STEP_TIME_S,
                                      max_queue=2 * n, shed=BLOCK))
    comps, stats = fe.run(
        reqs, events={fault_step: [("stage", 0, FAULT_STAGE)]})
    eng.recover(0)
    pst = stats["engine"]["per_step_tokens"]
    cap = stats["engine"]["capacity"]
    h_lo, h_hi = 4, fault_step                  # post-warmup, pre-fault
    f_lo = fault_step + 2                       # post-drain/requeue
    f_hi = min(f_lo + 20, int(0.8 * len(pst)))  # still saturated
    measured = _window_mean(pst, f_lo, f_hi) / \
        max(_window_mean(pst, h_lo, h_hi), 1e-9)
    analytic = _window_mean(cap, f_lo, f_hi) / \
        max(_window_mean(cap, h_lo, h_hi), 1e-9)
    rel_err = abs(measured - analytic) / max(analytic, 1e-9)
    dropped = [r.rid for r in reqs
               if r.rid not in comps or comps[r.rid].expired]
    return {
        "failover": failover,
        "n_requests": n,
        "fault_step": fault_step,
        "measured_ratio": round(measured, 4),
        "analytic_ratio": round(analytic, 4),
        "rel_err": round(rel_err, 4),
        "dropped_non_expired": dropped,
        "windows": {"healthy": [h_lo, h_hi], "fault": [f_lo, f_hi]},
    }


def bench(seed: int = 0, *, n: int = 20, closure_n: int = 40):
    cfg = get_config(ARCH).reduced()
    model = build_model(cfg)
    params = model.init(jax.random.PRNGKey(seed))
    out = {"workload": {"arch": ARCH, "devices": DEVICES, "slots": SLOTS,
                        "max_len": MAX_LEN, "requests": n, "seed": seed,
                        "step_time_s": STEP_TIME_S},
           "patterns": {}}
    for mode in (RECOMPILE, RESIDENT):
        eng = _engine(cfg, params, mode)   # one engine per mode: the
        for name, wl, fault_step in _patterns(cfg, n):  # compile caches
            reqs = wl.build(seed)                       # span patterns
            cell = out["patterns"].setdefault(name, {})
            cell[mode] = {
                "healthy": _run_one(eng, reqs, None),
                "fault": _run_one(eng, reqs, fault_step),
            }
    out["closure"] = closure(cfg, params, seed, n=closure_n)
    return out


def run(seed: int = 0):
    """CSV rows for benchmarks/run.py (name, us_per_call, derived).

    ``us_per_call`` is wall time per decoded token (runner-dependent,
    calibration-normalized by compare.py); ``derived`` carries the
    virtual-clock goodput and tails (deterministic given the seed) that
    compare.py's goodput gate watches."""
    res = bench(seed, n=16, closure_n=36)
    rows = []
    for pattern, cell in res["patterns"].items():
        for mode, runs in cell.items():
            for label, m in runs.items():
                rows.append((
                    f"traffic_{pattern}_{mode}_{label}",
                    m["wall_us_per_tok"],
                    f"goodput={m['goodput_tok_s']:.1f};"
                    f"p50={m['p50_latency_s']*1e3:.0f}ms;"
                    f"p99={m['p99_latency_s']*1e3:.0f}ms;"
                    f"met={m['deadline_met']}/{m['requests']}"))
    c = res["closure"]
    if c["rel_err"] > 0.15 or c["dropped_non_expired"]:
        raise RuntimeError(
            f"goodput closure failed: rel_err={c['rel_err']} "
            f"(measured {c['measured_ratio']} vs analytic "
            f"{c['analytic_ratio']}), dropped={c['dropped_non_expired']}")
    rows.append(("traffic_goodput_closure", 0.0,
                 f"measured={c['measured_ratio']};"
                 f"analytic={c['analytic_ratio']};"
                 f"rel_err={c['rel_err']};dropped=0"))
    return rows


def main(argv=None):
    import argparse
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--seed", type=int, default=0,
                    help="workload/init RNG seed")
    ap.add_argument("--smoke", action="store_true",
                    help="small CI sizing (same scenario coverage)")
    args = ap.parse_args(argv)
    out = bench(args.seed, n=10 if args.smoke else 20,
                closure_n=30 if args.smoke else 40)
    print(json.dumps(out, indent=2))


if __name__ == "__main__":
    main()
