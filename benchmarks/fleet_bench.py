"""Fleet benchmark: Monte-Carlo fault trace through the real serve fleet.

The executed version of Fig. 2/Fig. 8: ``simulate_fleet`` draws a fault
trace, ``replay_trace`` turns it into engine events + the analytic VFA
capacity curve, and ``FleetHarness`` measures the real
``FleetServeEngine``'s aggregate tokens/step against that curve — with and
without a hot-spare pool, so the spare's capacity retention is a measured
number, not just the analytic claim.

``python benchmarks/fleet_bench.py`` prints one JSON object (CI smoke
asserts it parses); ``run()`` returns the usual ``name,us_per_call,
derived`` rows for ``benchmarks/run.py``.
"""
from __future__ import annotations

import json
import time

import jax
import numpy as np

from repro.configs import get_config
from repro.core.datacenter import FleetHarness, replay_trace, simulate_fleet
from repro.models import build_model
from repro.serve import FleetConfig, FleetServeEngine, Request, ServeConfig
from repro.train.runner import model_stage_names

ARCH = "qwen1.5-4b"
N_WORKERS = 3
SLOTS = 6
MAX_LEN = 32
HORIZON = 20
DEGRADATION = (1.0, 0.38, 0.19)   # FFT case-study VFA curve
MAX_FAULTS = 3
P_FAULT = 0.02
SEED = 7


def _requests(cfg, rng, n_tokens: int):
    budget = 12
    return [Request(rid=i,
                    prompt=rng.integers(0, cfg.vocab_size,
                                        size=8).astype(np.int32),
                    max_new_tokens=budget)
            for i in range(max(1, n_tokens // budget))]


def run_scenario(n_spares: int):
    """The one scenario definition (CI smoke, the tier-1 acceptance test,
    and examples/datacenter_sim.py --replay all drive this): returns the
    full FleetHarness result dict plus the workload and model, so callers
    can also assert per-request bit-identity."""
    cfg = get_config(ARCH).reduced()
    params = build_model(cfg).init(jax.random.PRNGKey(0))
    stages = model_stage_names(cfg)
    mc = simulate_fleet(N_WORKERS, HORIZON, P_FAULT, max_faults=MAX_FAULTS,
                        degradation=DEGRADATION, replace_failed=False,
                        seed=SEED, record_trace=True)
    rep = replay_trace(mc.trace, n_workers=N_WORKERS, ticks=HORIZON,
                       stage_names=stages, degradation=DEGRADATION,
                       max_faults=MAX_FAULTS, n_spares=n_spares,
                       slots_per_device=SLOTS)
    eng = FleetServeEngine(
        cfg, params, ServeConfig(max_len=MAX_LEN, max_slots=SLOTS),
        FleetConfig(n_devices=N_WORKERS + n_spares, n_spares=n_spares,
                    degradation=DEGRADATION))
    rng = np.random.default_rng(1)
    reqs = _requests(cfg, rng, int(N_WORKERS * SLOTS * HORIZON * 1.5))
    t0 = time.perf_counter()
    out = FleetHarness(eng, rep, horizon=HORIZON).run(reqs)
    out.update(n_spares=n_spares, trace_faults=len(mc.trace),
               wall_s=time.perf_counter() - t0)
    return out, reqs, cfg, params


def bench(n_spares: int):
    out, reqs, _cfg, _params = run_scenario(n_spares)
    return {k: out[k] for k in (
        "n_spares", "trace_faults", "measured_ratio", "analytic_ratio",
        "rel_err", "healthy_tokens_per_step", "faulted_tokens_per_step",
        "requeued", "quarantined", "spares_in_service", "wall_s")} | {
        "completed": len(out["completions"][1])}


def run():
    """CSV rows for benchmarks/run.py (name, us_per_call, derived)."""
    rows = []
    for n_spares in (0, 1):
        r = bench(n_spares)
        rows.append((
            f"fleet_trace_spares{n_spares}",
            1e6 * r["wall_s"] / max(1, r["completed"]),
            f"measured={r['measured_ratio']:.3f};"
            f"analytic={r['analytic_ratio']:.3f};"
            f"rel_err={r['rel_err']:.3f};requeued={r['requeued']}"))
    return rows


def main():
    out = {"workload": {"arch": ARCH, "workers": N_WORKERS, "slots": SLOTS,
                        "horizon": HORIZON, "p_fault": P_FAULT,
                        "degradation": list(DEGRADATION)},
           "no_spares": bench(0),
           "hot_spare": bench(1)}
    print(json.dumps(out, indent=2))


if __name__ == "__main__":
    main()
