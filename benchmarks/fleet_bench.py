"""Fleet benchmark: Monte-Carlo fault trace through the real serve fleet.

The executed version of Fig. 2/Fig. 8: ``simulate_fleet`` draws a fault
trace, ``replay_trace`` turns it into engine events + the analytic VFA
capacity curve, and ``FleetHarness`` measures the real
``FleetServeEngine``'s aggregate tokens/step against that curve — with and
without a hot-spare pool, so the spare's capacity retention is a measured
number, not just the analytic claim.

``python benchmarks/fleet_bench.py`` prints one JSON object (CI smoke
asserts it parses); ``run()`` returns the usual ``name,us_per_call,
derived`` rows for ``benchmarks/run.py``.  ``--hosts N`` adds the
multi-host axis (the CI multihost smoke runs ``--hosts 2``): devices
partition into per-host blocks, a mid-horizon host loss drops one whole
block, and the analytic twin replays the same event log — so the
measured-vs-analytic closure covers host-level failure too.  ``--seed``
reseeds the Monte-Carlo trace for reproducible CI runs.
"""
from __future__ import annotations

import argparse
import json
import time

import jax
import numpy as np

from repro.configs import get_config
from repro.core.datacenter import FleetHarness, replay_trace, simulate_fleet
from repro.launch.distributed import HostTopology
from repro.models import build_model
from repro.serve import FleetConfig, FleetServeEngine, Request, ServeConfig
from repro.train.runner import model_stage_names

ARCH = "qwen1.5-4b"
N_WORKERS = 3
SLOTS = 6
MAX_LEN = 32
HORIZON = 20
DEGRADATION = (1.0, 0.38, 0.19)   # FFT case-study VFA curve
MAX_FAULTS = 3
P_FAULT = 0.02
SEED = 7


def _requests(cfg, rng, n_tokens: int):
    budget = 12
    return [Request(rid=i,
                    prompt=rng.integers(0, cfg.vocab_size,
                                        size=8).astype(np.int32),
                    max_new_tokens=budget)
            for i in range(max(1, n_tokens // budget))]


def run_scenario(n_spares: int, *, hosts: int = 1, seed: int = SEED):
    """The one scenario definition (CI smoke, the tier-1 acceptance test,
    and examples/datacenter_sim.py --replay all drive this): returns the
    full FleetHarness result dict plus the workload and model, so callers
    can also assert per-request bit-identity.

    ``hosts > 1`` partitions the fleet into host blocks (device count is
    padded to divide evenly), injects a whole-host loss halfway through
    the horizon on top of the Monte-Carlo trace, and replays the same
    event log through both the engine and the analytic twin.
    """
    cfg = get_config(ARCH).reduced()
    params = build_model(cfg).init(jax.random.PRNGKey(0))
    stages = model_stage_names(cfg)
    if hosts > 1:
        # pad to a host-divisible fleet; lose host 0 mid-horizon — its
        # block holds only workers, so with a spare (which lives in the
        # LAST block) one migrated device crosses the block boundary
        n_devices = hosts * -(-(N_WORKERS + n_spares) // hosts)
        n_workers = n_devices - n_spares
        host_loss = {HORIZON // 2: 0}
        topology = HostTopology(hosts, n_devices // hosts)
    else:
        n_workers, n_devices = N_WORKERS, N_WORKERS + n_spares
        host_loss = None
        topology = None
    mc = simulate_fleet(n_workers, HORIZON, P_FAULT, max_faults=MAX_FAULTS,
                        degradation=DEGRADATION, replace_failed=False,
                        seed=seed, record_trace=True)
    rep = replay_trace(mc.trace, n_workers=n_workers, ticks=HORIZON,
                       stage_names=stages, degradation=DEGRADATION,
                       max_faults=MAX_FAULTS, n_spares=n_spares,
                       slots_per_device=SLOTS, n_hosts=hosts,
                       host_loss=host_loss)
    eng = FleetServeEngine(
        cfg, params, ServeConfig(max_len=MAX_LEN, max_slots=SLOTS),
        FleetConfig(n_devices=n_devices, n_spares=n_spares,
                    degradation=DEGRADATION, topology=topology))
    rng = np.random.default_rng(1)
    reqs = _requests(cfg, rng, int(n_workers * SLOTS * HORIZON * 1.5))
    t0 = time.perf_counter()
    out = FleetHarness(eng, rep, horizon=HORIZON, num_hosts=hosts).run(reqs)
    out.update(n_spares=n_spares, trace_faults=len(mc.trace),
               wall_s=time.perf_counter() - t0)
    return out, reqs, cfg, params


def bench(n_spares: int, *, hosts: int = 1, seed: int = SEED):
    out, reqs, _cfg, _params = run_scenario(n_spares, hosts=hosts,
                                            seed=seed)
    return {k: out[k] for k in (
        "num_hosts", "n_spares", "trace_faults", "measured_ratio",
        "analytic_ratio", "rel_err", "healthy_tokens_per_step",
        "faulted_tokens_per_step", "requeued", "quarantined",
        "spares_in_service", "wall_s")} | {
        "completed": len(out["completions"][1])}


def run(seed: int = SEED):
    """CSV rows for benchmarks/run.py (name, us_per_call, derived)."""
    rows = []
    for n_spares in (0, 1):
        r = bench(n_spares, seed=seed)
        rows.append((
            f"fleet_trace_spares{n_spares}",
            1e6 * r["wall_s"] / max(1, r["completed"]),
            f"measured={r['measured_ratio']:.3f};"
            f"analytic={r['analytic_ratio']:.3f};"
            f"rel_err={r['rel_err']:.3f};requeued={r['requeued']}"))
    return rows


def main(argv=None):
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--hosts", type=int, default=1,
                    help="host blocks; >1 adds a mid-horizon host loss")
    ap.add_argument("--seed", type=int, default=SEED,
                    help="Monte-Carlo fault-trace seed")
    args = ap.parse_args(argv)
    out = {"workload": {"arch": ARCH, "workers": N_WORKERS, "slots": SLOTS,
                        "horizon": HORIZON, "p_fault": P_FAULT,
                        "degradation": list(DEGRADATION),
                        "hosts": args.hosts, "seed": args.seed},
           "no_spares": bench(0, hosts=args.hosts, seed=args.seed),
           "hot_spare": bench(1, hosts=args.hosts, seed=args.seed)}
    print(json.dumps(out, indent=2))


if __name__ == "__main__":
    main()
