"""System-level step benchmarks on this host: staged LM train/decode under
0/1/2 faults + the reconfiguration (recompile) cost — the framework-level
analogue of the paper's Fig. 5/6 measurement."""
from __future__ import annotations

import time

import jax
import jax.numpy as jnp
import numpy as np

from repro import optim
from repro.configs import get_config
from repro.data import DataConfig, SyntheticLM
from repro.serve import ServeConfig, ServeEngine
from repro.train import TrainConfig, TrainRunner
from repro.models import build_model


def run(seed: int = 0):
    rows = []
    cfg = get_config("gemma2-2b").reduced()
    data = SyntheticLM(DataConfig(vocab_size=cfg.vocab_size, batch=4,
                                  seq_len=64))
    r = TrainRunner(cfg, optim.AdamWConfig(),
                    TrainConfig(steps=1, seed=seed), data)
    params, opt, err = r.init_state()
    batch = data.device_batch(0)

    # NOTE: on this CPU host the healthy train route is already the SW
    # oracle, so fault plans equal the healthy plan and the dispatcher
    # dedupes them (reconfig_us on the *fault rows is a cache hit; the
    # degradation ratios bound measurement noise, not a real hw->sw gap).
    def timed_steps(plan, label):
        t0 = time.perf_counter()
        fn = r.dispatcher.get(plan)
        compile_us = (time.perf_counter() - t0) * 1e6
        # donation-safe fresh copies (the jitted step donates its inputs)
        pp = jax.tree_util.tree_map(jnp.copy, params)
        oo = jax.tree_util.tree_map(jnp.copy, opt)
        ee = jnp.zeros(())
        pp, oo, ee, m = fn(pp, oo, ee, batch)   # warm
        t0 = time.perf_counter()
        n = 5
        for _ in range(n):
            pp, oo, ee, m = fn(pp, oo, ee, batch)
        m["loss"].block_until_ready()
        step_us = (time.perf_counter() - t0) / n * 1e6
        rows.append((f"train_step_{label}", step_us,
                     f"reconfig_us={compile_us:.0f}"))
        return step_us

    plan0 = r.plan()
    t_h = timed_steps(plan0, "healthy")
    plan1 = plan0.with_fault("flash_attention")
    t_1 = timed_steps(plan1, "1fault")
    plan2 = plan1.with_fault("swiglu_mlp")
    t_2 = timed_steps(plan2, "2fault")
    rows.append(("train_degradation_1fault", 0.0, f"{t_1/t_h:.3f}x"))
    rows.append(("train_degradation_2fault", 0.0, f"{t_2/t_h:.3f}x"))

    # serving: decode latency + failover cost mid-stream.  The healthy
    # route must differ from the fallback for the fault to be a real
    # reconfiguration (plan-keyed dispatch dedupes identical routings),
    # so healthy stages run the interpreted kernel lowering on CPU.
    from repro.viscosity import INTERPRET
    model = build_model(cfg)
    params_s = model.init(jax.random.PRNGKey(seed))
    eng = ServeEngine(cfg, params_s, ServeConfig(max_len=96,
                                                 hw_route=INTERPRET))
    prompts = jax.random.randint(jax.random.PRNGKey(seed + 1), (4, 32), 0,
                                 cfg.vocab_size).astype(jnp.int32)
    toks, stats = eng.generate(prompts, 24,
                               fault_at_step=(12, "flash_attention"))
    st = stats["step_times"]
    rows.append(("decode_step_healthy", float(np.median(st[:12]) * 1e6),
                 "b=4"))
    rows.append(("decode_failover_spike", float(st[12] * 1e6),
                 "recompile-on-fault"))
    rows.append(("decode_step_post_fault", float(np.median(st[13:]) * 1e6),
                 "sw-routed stage"))
    return rows


if __name__ == "__main__":
    import argparse
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--seed", type=int, default=0,
                    help="init/data RNG seed")
    for row in run(seed=ap.parse_args().seed):
        print("%s,%.1f,%s" % row)
