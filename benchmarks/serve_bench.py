"""Serving benchmark: continuous batching vs fixed-batch, under faults.

Drives the same staggered workload (unequal prompt lengths, unequal token
budgets, arrivals spread over engine steps) through:

  * the **continuous-batching engine** (slot join/evict per step), and
  * a **fixed-batch baseline** (the pre-continuous behavior): wait for a
    full batch of arrivals, left-align to a common budget, decode the
    batch to completion, repeat — no join/evict.

each measured healthy and with a mid-stream quarantined stage, reporting
tokens/sec and p50/p99 request latency (wall seconds from queue-eligible
to last token).  ``python benchmarks/serve_bench.py`` prints one JSON
object; ``run()`` returns the usual ``name,us_per_call,derived`` rows so
``benchmarks/run.py`` can include it.
"""
from __future__ import annotations

import json
import time

import jax
import numpy as np

from repro.configs import get_config
from repro.models import build_model
from repro.serve import (RECOMPILE, RESIDENT, Request, ServeConfig,
                         ServeEngine, percentile, reference_decode,
                         synthetic_workload)
from repro.viscosity import INTERPRET

ARCH = "qwen1.5-4b"
# Healthy stages run the interpreted kernel lowering so the injected fault
# is a *real* reroute (interpret -> SW oracle) — with the SW route the plan
# would not change and the ±fault comparison would measure nothing.
HW_ROUTE = INTERPRET
N_REQUESTS = 16
MAX_LEN = 64
SLOTS = 4
FAULT = (6, "flash_attention")


def _workload(cfg, seed=0):
    return synthetic_workload(cfg.vocab_size, N_REQUESTS,
                              np.random.default_rng(seed), min_prompt=6,
                              max_prompt=23, min_new=6, max_new=15,
                              arrival_every=2)


def _lat_stats(n_tok, dt, lats):
    return {"tokens_per_s": n_tok / dt,
            "p50_latency_s": percentile(lats, 0.50),
            "p99_latency_s": percentile(lats, 0.99)}


def bench_continuous(cfg, params, reqs, failover, fault):
    eng = ServeEngine(cfg, params, ServeConfig(max_len=MAX_LEN,
                                               max_slots=SLOTS,
                                               hw_route=HW_ROUTE,
                                               failover=failover))
    t0 = time.perf_counter()
    done, stats = eng.serve(reqs, fault_at_step=fault)
    dt = time.perf_counter() - t0
    n_tok = sum(len(c.tokens) for c in done.values())
    out = _lat_stats(n_tok, dt, [c.latency_s for c in done.values()])
    out.update(recompiles=stats["recompiles"],
               mean_occupancy=float(np.mean(stats["occupancy"])),
               engine_steps=stats["steps"])
    return out, done


def bench_fixed_batch(cfg, params, reqs, fault):
    """Pre-continuous behavior, emulated on the same executables: take the
    requests SLOTS at a time, pad every budget to the batch max, decode
    the whole batch to completion, then start the next batch — no
    join/evict, so short requests idle their slot until the longest one
    finishes and later arrivals wait whole batches.  tokens/sec counts
    only *useful* (requested) tokens; the padding is the waste.

    Caveat on comparability: this baseline ignores arrival steps (batches
    run back-to-back, flattering its throughput) and charges each request
    latency from the bench start rather than from its own eligibility
    (since in a batch-synchronous server later arrivals really do wait
    for earlier batches to drain).  Directionally conservative for the
    throughput comparison; the latency gap partly reflects that queueing
    model rather than pure scheduling."""
    eng = ServeEngine(cfg, params, ServeConfig(max_len=MAX_LEN,
                                               max_slots=SLOTS,
                                               hw_route=HW_ROUTE))
    lats, n_useful = [], 0
    t_start = time.perf_counter()
    batches = [reqs[i:i + SLOTS] for i in range(0, len(reqs), SLOTS)]
    for bi, batch in enumerate(batches):
        budget = max(r.max_new_tokens for r in batch)
        padded = [Request(rid=r.rid, prompt=r.prompt,
                          max_new_tokens=budget) for r in batch]
        done, _ = eng.serve(padded,
                            fault_at_step=fault if bi == 0 else None)
        t_now = time.perf_counter()
        n_useful += sum(r.max_new_tokens for r in batch)
        lats.extend([t_now - t_start] * len(batch))
    dt = time.perf_counter() - t_start
    return _lat_stats(n_useful, dt, lats)


def bench(fault, seed: int = 0):
    cfg = get_config(ARCH).reduced()
    model = build_model(cfg)
    params = model.init(jax.random.PRNGKey(seed))
    reqs = _workload(cfg, seed)
    out = {}
    out["continuous_recompile"], done = bench_continuous(
        cfg, params, reqs, RECOMPILE, fault)
    out["continuous_resident"], done2 = bench_continuous(
        cfg, params, reqs, RESIDENT, fault)
    out["fixed_batch"] = bench_fixed_batch(cfg, params, reqs, fault)
    # correctness spot-checks ride along: the two failover modes agree on
    # every request, and an SW-routed engine matches reference decode
    out["failover_modes_agree"] = bool(all(
        np.array_equal(done[r.rid].tokens, done2[r.rid].tokens)
        for r in reqs))
    r = reqs[0]
    eng_sw = ServeEngine(cfg, params, ServeConfig(max_len=MAX_LEN,
                                                  max_slots=SLOTS))
    done_sw, _ = eng_sw.serve([r])
    ref = reference_decode(cfg, params, r.prompt, r.max_new_tokens,
                           max_len=MAX_LEN)
    out["continuous_matches_reference"] = bool(
        np.array_equal(done_sw[r.rid].tokens, ref))
    return out


def run(seed: int = 0):
    """CSV rows for benchmarks/run.py (name, us_per_call, derived)."""
    rows = []
    for label, fault in (("healthy", None), ("fault", FAULT)):
        res = bench(fault, seed=seed)
        for mode in ("continuous_recompile", "continuous_resident",
                     "fixed_batch"):
            m = res[mode]
            rows.append((f"serve_{mode}_{label}",
                         1e6 / max(m["tokens_per_s"], 1e-9),
                         f"tok_s={m['tokens_per_s']:.1f};"
                         f"p50={m['p50_latency_s']*1e3:.0f}ms;"
                         f"p99={m['p99_latency_s']*1e3:.0f}ms"))
        if fault is not None:
            rows.append(("serve_fault_recompiles",
                         0.0,
                         f"recompile_mode="
                         f"{res['continuous_recompile']['recompiles']};"
                         f"resident_mode="
                         f"{res['continuous_resident']['recompiles']}"))
    return rows


def main(argv=None):
    import argparse
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--seed", type=int, default=0,
                    help="workload/init RNG seed")
    args = ap.parse_args(argv)
    out = {"workload": {"arch": ARCH, "requests": N_REQUESTS,
                        "slots": SLOTS, "max_len": MAX_LEN,
                        "seed": args.seed},
           "healthy": bench(None, seed=args.seed),
           "fault": bench(FAULT, seed=args.seed)}
    print(json.dumps(out, indent=2))


if __name__ == "__main__":
    main()
