"""Elastic re-shard on the fleet API: train on a health-masked mesh, lose
a "pod" of devices (FleetPlan device faults), rebuild the mesh view from
the surviving fleet, restore the checkpoint onto it, and continue.

This script forces 8 host devices, so it must run as its own process:
    PYTHONPATH=src python examples/elastic_train.py
"""
import os

from repro.launch.xla_presets import force_host_device_count

force_host_device_count(8)
# Pin the CPU backend: off-TPU, probing the TPU plugin first burns minutes
# on metadata retries before falling back to CPU anyway.
os.environ.setdefault("JAX_PLATFORMS", "cpu")

import tempfile

import jax
import numpy as np
from jax.sharding import NamedSharding, PartitionSpec as P

from repro import optim
from repro.checkpoint import CheckpointManager
from repro.configs import get_config
from repro.core.routing import FleetPlan
from repro.data import DataConfig, SyntheticLM
from repro.launch.mesh import FleetMeshView
from repro.launch.partition import params_pspecs
from repro.models import build_model
from repro.train.runner import model_stage_names


def jit_step(model, ocfg, mesh, params):
    p_sh = jax.tree_util.tree_map(
        lambda s: NamedSharding(mesh, s), params_pspecs(params, mesh))
    b_sh = {"tokens": NamedSharding(mesh, P("data")),
            "targets": NamedSharding(mesh, P("data"))}

    def step(params, opt_state, batch):
        batch = jax.lax.with_sharding_constraint(batch, b_sh)
        (loss, _), grads = jax.value_and_grad(model.forward,
                                              has_aux=True)(params, batch)
        params, opt_state, om = optim.update(ocfg, grads, opt_state, params)
        return params, opt_state, loss

    # shardings are carried by the arrays themselves (device_put'd by the
    # caller); jit inherits them — simplest elastic-restore pattern
    return jax.jit(step), p_sh


def main():
    cfg = get_config("gemma3-1b").reduced()
    model = build_model(cfg)
    ocfg = optim.AdamWConfig(lr=5e-3, warmup_steps=5, total_steps=100)
    data = SyntheticLM(DataConfig(vocab_size=cfg.vocab_size, batch=8,
                                  seq_len=32))
    stages = model_stage_names(cfg)
    with tempfile.TemporaryDirectory() as tmp:
        ckpt = CheckpointManager(tmp)

        # --- phase 1: full healthy fleet -> (2, 4) health-masked mesh ---
        fleet = FleetPlan.healthy(8, stages)
        view1 = FleetMeshView.from_plan(fleet)
        mesh1 = view1.submesh(("data", "model"), model=4)
        print(f"phase 1 fleet: serving {view1.serving()} -> mesh "
              f"{mesh1.devices.shape}")
        with mesh1:
            params = model.init(jax.random.PRNGKey(0))
            step1, p_sh1 = jit_step(model, ocfg, mesh1, params)
            params = jax.device_put(params, p_sh1)
            opt_state = optim.init(params)   # inherits param shardings
            losses = []
            for s in range(10):
                b = data.device_batch(s)
                params, opt_state, loss = step1(params, opt_state, b)
                losses.append(float(loss))
        ckpt.save(10, {"params": params, "opt": opt_state})
        print(f"phase 1 (2x4 mesh): loss {losses[0]:.3f} -> {losses[-1]:.3f}"
              f"; checkpoint saved at step 10")

        # --- phase 2: a "pod" of 4 devices fails; the FleetPlan carries
        # the quarantine and the mesh view re-folds the survivors ---
        for d in (4, 5, 6, 7):
            fleet = fleet.with_device_fault(d)
        view2 = FleetMeshView.from_plan(fleet)
        assert view2.quarantined == (4, 5, 6, 7)
        mesh2 = view2.submesh(("data", "model"), model=4)
        print(f"phase 2 fleet: quarantined {view2.quarantined}, serving "
              f"{view2.serving()} -> mesh {mesh2.devices.shape}")
        with mesh2:
            like = {"params": params, "opt": opt_state}
            p_sh2 = jax.tree_util.tree_map(
                lambda s: NamedSharding(mesh2, s),
                params_pspecs(params, mesh2))
            o_sh2 = optim.AdamWState(
                count=NamedSharding(mesh2, P()),
                mu=p_sh2, nu=p_sh2)
            restored = ckpt.restore(10, like,
                                    shardings={"params": p_sh2,
                                               "opt": o_sh2})
            params2, opt2 = restored["params"], restored["opt"]
            assert int(opt2.count) == 10   # optimizer state continued
            step2, _ = jit_step(model, ocfg, mesh2, params2)
            losses2 = []
            for s in range(10, 20):
                b = data.device_batch(s)   # same data stream, replayed
                params2, opt2, loss = step2(params2, opt2, b)
                losses2.append(float(loss))
        print(f"phase 2 (1x4 mesh after pod loss): loss {losses2[0]:.3f} "
              f"-> {losses2[-1]:.3f}")
        assert np.isfinite(losses + losses2).all()
        print("OK: FleetPlan carried the pod loss as an explicit mask, the "
              "health-masked mesh view re-folded the survivors, and "
              "training continued from the checkpoint (optimizer step "
              "count preserved).")


if __name__ == "__main__":
    main()
