"""One lane-fault scenario, end to end — the tier-1 CI fault smoke.

A stuck-at lane fault is injected into the swiglu kernel's optimized
path, the canary checker detects AND lane-localizes it, routing walks
the degradation ladder (DEGRADED remap, then reduced-width on a second
fault), and the remapped output is checked bit-identical to an
uninjected run under the same plan — the paper's partial-degradation
claim (§III-A) exercised through the real registries, not mocks.

Run:  PYTHONPATH=src python examples/lane_fault_smoke.py

Prints a JSON summary; exits nonzero on any failed check.
"""
import json
import sys

import jax
import jax.numpy as jnp
import numpy as np

from repro.core import CanaryChecker, FaultState, RoutingPlan, Stage
from repro.kernels.swiglu import ops as _swiglu_ops  # noqa: F401 — registers
from repro.viscosity import (DEGRADED_REDUCED, DEGRADED_REMAP, INTERPRET,
                             REGISTRY, lanefault)

STAGE = "swiglu_mlp"
PORTS = (jax.ShapeDtypeStruct((64, 64), jnp.float32),
         jax.ShapeDtypeStruct((64, 128), jnp.float32),
         jax.ShapeDtypeStruct((64, 128), jnp.float32),
         jax.ShapeDtypeStruct((128, 64), jnp.float32))


def main() -> int:
    lanefault.reset()
    spec = REGISTRY.get(STAGE)
    stage = Stage(name=STAGE, spec=spec, ports=PORTS,
                  tol=max(spec.tol, 1e-3))
    x = stage.canary_inputs(seed=7)
    fault = lanefault.LaneFault(kind=lanefault.STUCK, lanes=(3, 7), width=64)
    summary = {"stage": STAGE, "injected_lanes": list(fault.lanes)}
    checks = {}

    plan = RoutingPlan.for_stages([STAGE], target=INTERPRET)
    sw = np.asarray(stage.run(*x, route=lanefault.SW))
    clean = np.asarray(stage.run(*x, route=plan))

    with lanefault.inject(STAGE, fault):
        # 1) the fault is real: the optimized path's output is corrupted
        bad = np.asarray(stage.run(*x, route=plan))
        checks["injection_corrupts"] = bool(np.abs(bad - clean).max() > 0)

        # 2) canary detects and lane-localizes it
        state = FaultState()
        chk = CanaryChecker([stage], route_hw=INTERPRET, localize=True)
        found = chk.sweep(state, step=1)
        located = lanefault.fault_map(STAGE)
        checks["canary_detects"] = found == [STAGE]
        checks["canary_localizes"] = (
            located is not None and located.lanes == fault.lanes
            and state.log[-1]["kind"] == "canary_localized")
        if located is None:
            print(json.dumps({**summary, "checks": checks, "ok": False}))
            return 1

        # 3) fault 1 -> DEGRADED remap; healed output is bit-identical to
        #    an uninjected run under the SAME degraded plan
        dplan = lanefault.degraded_plan(
            plan, state.counts([STAGE])).validate(registry=REGISTRY)
        checks["routes_degraded_remap"] = (
            dplan.target_for(STAGE) == DEGRADED_REMAP)
        healed = np.asarray(stage.run(*x, route=dplan))
        checks["remap_close_to_oracle"] = bool(
            np.abs(healed - sw).max() <= stage.tol)

        # 4) fault 2 -> reduced-width execution, still within tolerance
        state.mark(STAGE, kind="canary_localized", step=2)
        dplan2 = lanefault.degraded_plan(
            plan, state.counts([STAGE])).validate(registry=REGISTRY)
        checks["routes_degraded_reduced"] = (
            dplan2.target_for(STAGE) == DEGRADED_REDUCED)
        reduced = np.asarray(stage.run(*x, route=dplan2))
        checks["reduced_close_to_oracle"] = bool(
            np.abs(reduced - sw).max() <= stage.tol)

    # bit-identity across injection: corruption confined to mapped lanes
    # is healed exactly (traced fresh on both sides of the context)
    healed_clean = np.asarray(stage.run(*x, route=dplan))
    checks["remap_bit_identical"] = bool(np.array_equal(healed, healed_clean))

    # 5) deterministic log stamps: logical (step, origin, seq), no wall clock
    checks["log_is_logical"] = all(
        set(e) == {"stage", "replica", "kind", "step", "origin", "seq"}
        for e in state.log)

    lanefault.reset()
    ok = all(checks.values())
    print(json.dumps({**summary, "checks": checks, "ok": ok}, indent=2))
    return 0 if ok else 1


if __name__ == "__main__":
    sys.exit(main())
