"""The paper's case studies, end to end: FFT / AES / DCT staged
accelerators with fault injection, canary detection, quarantine, and
latency-model reporting (Fig. 5 numbers).

Run:  PYTHONPATH=src python examples/casestudy_faults.py
"""
import jax.numpy as jnp
import numpy as np

from repro.core import CanaryChecker, FaultState, StagedAccelerator, inject
from repro.core.casestudies import (aes_accelerator, dct_accelerator,
                                    dct_reference, fft_accelerator,
                                    fft_reference)
from repro.core.latency import (aes_model, dct_model, fft_model,
                                speedup_vs_sw)


def demo(name, acc, x, reference, model, fault_stage_idx):
    ref = np.asarray(reference)
    stage = acc.stages[fault_stage_idx].name
    # 1) break the hardware path of one stage
    stages = list(acc.stages)
    stages[fault_stage_idx] = inject(stages[fault_stage_idx], kind="gain",
                                     magnitude=0.25)
    broken = StagedAccelerator(name, stages)
    err_bad = np.abs(np.asarray(broken.run(x)) - ref).max()
    # 2) canary detection -> quarantine
    state = FaultState()
    found = CanaryChecker(broken.stages).sweep(state)
    sig = state.signature(broken.stage_names)
    # 3) reroute: output restored
    err_fixed = np.abs(np.asarray(broken.run(x, sig)) - ref).max()
    s0 = speedup_vs_sw(model)
    s1 = speedup_vs_sw(model, [fault_stage_idx])
    print(f"{name.upper():>5}: fault in {stage} -> output err {err_bad:.2e}"
          f" | canary found {found} | rerouted err {err_fixed:.2e}")
    print(f"       speedup vs software: {s0:.2f}x healthy -> {s1:.2f}x "
          f"under one fault (paper Fig. 5)")
    assert err_bad > 1e-4 and err_fixed < 1e-3 and found == [stage]


def main():
    rng = np.random.default_rng(0)
    x = jnp.asarray(rng.normal(size=(4, 64)) +
                    1j * rng.normal(size=(4, 64))).astype(jnp.complex64)
    fft = fft_accelerator(64)
    demo("fft", fft, x, fft_reference(x), fft_model(), 3)

    xd = jnp.asarray(rng.normal(size=(4, 8, 8)), jnp.float32)
    dct = dct_accelerator()
    demo("dct", dct, xd, dct_reference(xd), dct_model(), 4)

    # AES: integer datapath -> use a stuck-at corruption + checksum canary
    key = np.arange(16, dtype=np.uint8)
    aes = aes_accelerator(key, 11)
    xa = jnp.asarray(rng.integers(0, 256, size=(4, 16)), jnp.uint8)
    ref = np.asarray(aes.run(xa))
    stages = list(aes.stages)

    def corrupt_round(fn):
        def bad(s):
            out = fn(s)
            return out ^ jnp.uint8(0x40)   # stuck bit in the datapath
        return bad

    from repro.core.stage import Stage
    s5 = stages[5]
    stages[5] = Stage(name=s5.name, hw=corrupt_round(s5.hw), sw=s5.sw,
                      ports=s5.ports, tol=0.0)
    broken = StagedAccelerator("aes", stages)
    state = FaultState()
    found = CanaryChecker(broken.stages).sweep(state)
    sig = state.signature(broken.stage_names)
    fixed = np.asarray(broken.run(xa, sig))
    m = aes_model(3)
    print(f"  AES: checksum canary found {found}; rerouted output exact: "
          f"{bool((fixed == ref).all())}; 1-fault time "
          f"{100/speedup_vs_sw(m, [1]):.0f}% of software (paper: 58%)")
    assert found == ["aes_s5"] and (fixed == ref).all()
    print("OK: all three case studies detect, quarantine, and recover.")


if __name__ == "__main__":
    main()
