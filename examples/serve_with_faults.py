"""Continuous-batching serving under faults: the paper's guarantee, live.

A staggered stream of requests (unequal prompt lengths, unequal token
budgets) flows through a 3-slot continuous-batching engine.  Mid-stream,
the attention stage is quarantined.

Part 1 routes healthy stages through the *interpreted kernel* lowering so
the fault is a real reroute (interpret -> SW oracle), shown under both
failover modes:

  * recompile (queue reconfiguration): the dispatcher compiles the
    rerouted decode program exactly once; in-flight sequences continue;
  * resident (hot-spare): the same executable keeps running — failover is
    one flipped bit in the health-mask input, zero recompiles.

Both modes apply the same routing history, so their tokens are identical.

Part 2 runs the CPU production config (healthy route == SW oracle): there
the fault does not change the RoutingPlan at all (plan-keyed dispatch
dedupes it) and every completion is bit-identical to a single-request
reference decode — the end-to-end Viscosity guarantee.

Run:  PYTHONPATH=src python examples/serve_with_faults.py
"""
import time

import jax
import numpy as np

from repro.configs import get_config
from repro.models import build_model
from repro.serve import (RECOMPILE, RESIDENT, ServeConfig, ServeEngine,
                         reference_decode, synthetic_workload)
from repro.viscosity import INTERPRET


def main():
    cfg = get_config("qwen1.5-4b").reduced()
    model = build_model(cfg)
    params = model.init(jax.random.PRNGKey(0))
    reqs = synthetic_workload(cfg.vocab_size, 8, np.random.default_rng(7),
                              min_prompt=6, max_prompt=23, min_new=6,
                              max_new=15, arrival_every=2)

    # Part 1: a real reroute (interpret -> SW), both failover mechanisms.
    outs = {}
    for mode in (RECOMPILE, RESIDENT):
        eng = ServeEngine(cfg, params, ServeConfig(max_len=64, max_slots=3,
                                                   hw_route=INTERPRET,
                                                   failover=mode))
        t0 = time.perf_counter()
        done, stats = eng.serve(reqs, fault_at_step=(9, "flash_attention"))
        dt = time.perf_counter() - t0
        outs[mode] = done
        n_tok = sum(len(c.tokens) for c in done.values())
        print(f"[{mode:9s}] {len(done)}/{len(reqs)} requests, {n_tok} "
              f"tokens in {dt:.2f}s, occupancy "
              f"{float(np.mean(stats['occupancy'])):.2f}/3, "
              f"recompiles {stats['recompiles']}")
        assert len(done) == len(reqs)
        assert stats["recompiles"] == (1 if mode == RECOMPILE else 0)
    same = all(np.array_equal(outs[RECOMPILE][r.rid].tokens,
                              outs[RESIDENT][r.rid].tokens) for r in reqs)
    print(f"recompile and resident tokens identical: {same}")
    assert same

    # Part 2: CPU production config — bit-identity with reference decode.
    eng = ServeEngine(cfg, params, ServeConfig(max_len=64, max_slots=3))
    done, stats = eng.serve(reqs, fault_at_step=(9, "flash_attention"))
    exact = all(
        np.array_equal(done[r.rid].tokens,
                       reference_decode(cfg, params, r.prompt,
                                        r.max_new_tokens, max_len=64))
        for r in reqs)
    print(f"[sw-route ] fault plan deduped (recompiles "
          f"{stats['recompiles']}), bit-identical to single-request "
          f"reference decode: {exact}")
    assert exact and stats["recompiles"] == 0
    print("OK: mid-stream stage faults rerouted in-flight decodes under "
          "both failover modes.")


if __name__ == "__main__":
    main()
