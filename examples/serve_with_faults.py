"""Serving under faults: the paper's functional guarantee, live.

Decodes a batch greedily; at step 8 the attention stage is quarantined.
The engine recompiles with the SW fallback routed in — and the generated
tokens are bit-identical to a fault-free run (Viscosity equivalence).

Run:  PYTHONPATH=src python examples/serve_with_faults.py
"""
import time

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs import get_config
from repro.models import build_model
from repro.serve import ServeConfig, ServeEngine


def main():
    cfg = get_config("qwen1.5-4b").reduced()
    model = build_model(cfg)
    params = model.init(jax.random.PRNGKey(0))
    prompts = jax.random.randint(jax.random.PRNGKey(1), (4, 24), 0,
                                 cfg.vocab_size).astype(jnp.int32)

    eng = ServeEngine(cfg, params, ServeConfig(max_len=64))
    base, _ = eng.generate(prompts, 20)

    eng2 = ServeEngine(cfg, params, ServeConfig(max_len=64))
    t0 = time.perf_counter()
    faulted, stats = eng2.generate(prompts, 20,
                                   fault_at_step=(8, "flash_attention"))
    dt = time.perf_counter() - t0

    same = bool((base == faulted).all())
    spike = stats["step_times"][8]
    steady = float(np.median(stats["step_times"][10:]))
    print(f"generated 4x20 tokens in {dt:.2f}s")
    print(f"fault at decode step 8 -> recompiles: {stats['recompiles']}")
    print(f"failover step: {spike*1e3:.0f}ms (reconfiguration), "
          f"steady decode: {steady*1e3:.1f}ms")
    print(f"tokens bit-identical across routings: {same}")
    assert same and stats["recompiles"] == 1
    print("OK: serving survived a mid-stream stage fault with identical "
          "output.")


if __name__ == "__main__":
    main()
