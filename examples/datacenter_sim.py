"""Paper §II / Fig. 2: data-center fleet simulation CLI — now closing the
loop through the real engines.

Analytic / Monte-Carlo sweep (the original Fig. 2 math):
    PYTHONPATH=src python examples/datacenter_sim.py [--mc]

Executed replay (the fleet layer): draw a Monte-Carlo fault trace, replay
it through the real FleetServeEngine, and compare measured aggregate
throughput with the analytic VFA degradation curve — with and without a
hot spare (Fig. 8):
    PYTHONPATH=src python examples/datacenter_sim.py --replay
"""
import argparse

from repro.core.datacenter import chips_to_buy, fig2_sweep
from repro.core.latency import fft_model, throughput_factor

RATES = [1e-2, 1e-3, 1e-4, 1e-5, 1e-6]


def sweep(args):
    deg = tuple(throughput_factor(fft_model(), k) for k in range(3))
    print(f"VFA degradation curve (FFT case study): "
          f"{[round(d, 3) for d in deg]}")
    print(f"{'p/tick':>10} {'SFA repl':>12} {'VFA repl':>12} "
          f"{'SFA tput':>9} {'VFA tput':>9}")
    rows = fig2_sweep(RATES, n_chips=args.chips, ticks=args.ticks,
                      degradation=deg, monte_carlo=args.mc)
    for p, sr, vr, st, vt in rows:
        print(f"{p:>10.0e} {sr:>12.1f} {vr:>12.4f} {st:>9.4f} {vt:>9.4f}")
    print("\nFixed-throughput purchases (100 faulted chips):")
    for name, r in [("SFA (lose all)", 0.0), ("half perf kept", 0.5),
                    ("1/3 perf lost", 2 / 3)]:
        print(f"  {name:>16}: buy {chips_to_buy(100, r):.1f} chips")


def replay(args):
    """Fault trace -> real serve fleet -> measured vs analytic.

    One scenario definition only: this drives the same ``bench`` the CI
    smoke asserts on (benchmarks/fleet_bench.py), so the example's output
    can never drift from what CI checks."""
    import os
    import sys

    sys.path.insert(0, os.path.dirname(os.path.dirname(
        os.path.abspath(__file__))))
    from benchmarks.fleet_bench import bench

    for n_spares in (0, 1):
        out = bench(n_spares)
        if n_spares == 0:
            print(f"Monte-Carlo trace: {out['trace_faults']} faults")
        print(f"\nspares={n_spares}: measured {out['measured_ratio']:.3f} "
              f"vs analytic {out['analytic_ratio']:.3f} "
              f"(rel err {out['rel_err']:.1%}); "
              f"requeued {out['requeued']} requests, "
              f"quarantined {out['quarantined']}, "
              f"spares in service {out['spares_in_service']}")
    print("\nOK: the Fig. 2 degradation math is now an executed scenario — "
          "the real engine's aggregate throughput tracks the analytic "
          "curve, and a hot spare buys back the migrated device's share "
          "(Fig. 8).")


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--mc", action="store_true", help="Monte-Carlo mode")
    ap.add_argument("--replay", action="store_true",
                    help="replay a fault trace through the real engines")
    ap.add_argument("--chips", type=int, default=10_000)
    ap.add_argument("--ticks", type=int, default=1460)
    args = ap.parse_args()
    if args.replay:
        replay(args)
    else:
        sweep(args)


if __name__ == "__main__":
    main()
