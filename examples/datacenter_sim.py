"""Paper §II / Fig. 2: data-center fleet simulation CLI.

Run:  PYTHONPATH=src python examples/datacenter_sim.py [--mc]
"""
import argparse

from repro.core.datacenter import chips_to_buy, fig2_sweep
from repro.core.latency import fft_model, throughput_factor

RATES = [1e-2, 1e-3, 1e-4, 1e-5, 1e-6]


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--mc", action="store_true", help="Monte-Carlo mode")
    ap.add_argument("--chips", type=int, default=10_000)
    ap.add_argument("--ticks", type=int, default=1460)
    args = ap.parse_args()

    deg = tuple(throughput_factor(fft_model(), k) for k in range(3))
    print(f"VFA degradation curve (FFT case study): "
          f"{[round(d, 3) for d in deg]}")
    print(f"{'p/tick':>10} {'SFA repl':>12} {'VFA repl':>12} "
          f"{'SFA tput':>9} {'VFA tput':>9}")
    rows = fig2_sweep(RATES, n_chips=args.chips, ticks=args.ticks,
                      degradation=deg, monte_carlo=args.mc)
    for p, sr, vr, st, vt in rows:
        print(f"{p:>10.0e} {sr:>12.1f} {vr:>12.4f} {st:>9.4f} {vt:>9.4f}")
    print("\nFixed-throughput purchases (100 faulted chips):")
    for name, r in [("SFA (lose all)", 0.0), ("half perf kept", 0.5),
                    ("1/3 perf lost", 2 / 3)]:
        print(f"  {name:>16}: buy {chips_to_buy(100, r):.1f} chips")


if __name__ == "__main__":
    main()
