"""Quickstart: fault-tolerant LM training end to end.

Trains a reduced gemma2-family model on the synthetic Markov corpus,
injects a non-transient fault into the attention stage mid-run (step 60),
and shows the Oobleck response: the stage is quarantined onto its SW
oracle, the loss trajectory is identical, training never stops.  On this
CPU host the healthy route already *is* the SW oracle, so the RoutingPlan
is unchanged and the plan-keyed dispatcher dedupes the reconfiguration to
zero recompiles (on a TPU deployment the fault would be exactly one).

Run:  PYTHONPATH=src python examples/quickstart.py
"""
import tempfile

import numpy as np

from repro import optim
from repro.configs import get_config
from repro.data import DataConfig, SyntheticLM
from repro.train import TrainConfig, TrainRunner


def main():
    cfg = get_config("gemma2-2b").reduced()
    print(f"arch: {cfg.name} ({cfg.num_layers}L d={cfg.d_model})")
    data = SyntheticLM(DataConfig(vocab_size=cfg.vocab_size, batch=8,
                                  seq_len=64))
    with tempfile.TemporaryDirectory() as ckpt_dir:
        runner = TrainRunner(
            cfg,
            optim.AdamWConfig(lr=1e-2, warmup_steps=10, total_steps=120),
            TrainConfig(steps=120, ckpt_every=25, ckpt_dir=ckpt_dir,
                        canary_every=40),
            data)
        params, opt, err = runner.init_state()

        def log(step, row):
            if step % 20 == 0:
                print(f"  step {step:4d} loss {row['loss']:.4f} "
                      f"faults={row['n_faults']} "
                      f"compiles={row['compiles']}")
            if step == 60:
                print("  !! non-transient fault detected in "
                      "'flash_attention' -> quarantining (SW fallback)")
                runner.inject_fault("flash_attention")

        runner.run(params, opt, err, on_step=log)
        losses = [h["loss"] for h in runner.history]
        print(f"\nloss: {losses[0]:.3f} -> {losses[-1]:.3f} "
              f"(decreasing: {np.mean(losses[-10:]) < np.mean(losses[:10])})")
        print(f"reconfigurations (compiles): {runner.dispatcher.compiles} "
              "(fault plan == healthy plan on CPU: deduped)")
        print(f"fault log: {runner.fault_state.log}")
        assert runner.dispatcher.compiles == 1
        assert runner.signature().faulty() == {"flash_attention"}
        assert np.isfinite(losses).all()
        print("OK: training survived a mid-run stage fault.")


if __name__ == "__main__":
    main()
