"""Open-loop traffic generators (ROADMAP "open-loop traffic").

The paper's datacenter claim (§II Fig. 2) is about *aggregate* throughput
under faults; showing that tail latency survives a mid-burst quarantine
needs open-loop load — arrivals that do not wait for the system.  This
module generates those workloads as data: every ``Workload`` is a frozen
dataclass whose ``build(seed)`` returns a plain ``List[Request]``,
deterministic given the seed, so a bench scenario is replayable from its
parameters alone.

The family:

  * ``ClosedLoop`` — the legacy staggered fixed list (arrival measured in
    engine steps, no virtual-clock times): a degenerate arrival process.
    ``synthetic_workload`` (the old ``serve.engine`` helper) builds
    exactly this, bit-identical to the historical draws.
  * ``Poisson`` — memoryless arrivals at a constant rate.
  * ``Diurnal`` — inhomogeneous Poisson under a raised-cosine day curve
    (Lewis–Shedler thinning, still one rng stream).
  * ``FlashCrowd`` — baseline Poisson plus a rate-multiplied burst
    window: the mid-burst-quarantine scenario.

Prompt/output lengths come from a ``LengthModel``: uniform (the legacy
distribution) or bounded-Pareto (heavy-tailed, inverse-CDF sampled).
Arrival times are drawn *before* per-request lengths, so two workloads
differing only in arrival process still decode the same sequences.

Deadlines are attached by the workload (``slack_s`` +
``slack_per_token_s`` × budget past the arrival), giving the admission
front end (``serve.frontend``) per-request SLOs to schedule against.
"""
from __future__ import annotations

import math
from dataclasses import dataclass, replace
from typing import List, Optional, Sequence

import numpy as np

from repro.serve.engine import Request

__all__ = [
    "LengthModel", "Workload", "ClosedLoop", "Poisson", "Diurnal",
    "FlashCrowd", "bounded_pareto", "synthetic_workload",
]


def _as_rng(seed_or_rng) -> np.random.Generator:
    if isinstance(seed_or_rng, np.random.Generator):
        return seed_or_rng
    return np.random.default_rng(seed_or_rng)


def bounded_pareto(rng: np.random.Generator, lo: int, hi: int,
                   alpha: float) -> int:
    """One draw from a bounded Pareto(alpha) on [lo, hi] via inverse CDF
    — the standard heavy-tail model for prompt/output lengths (most
    requests short, a fat tail of near-``hi`` ones)."""
    if hi <= lo:
        return int(lo)
    u = float(rng.random())
    ratio = (lo / hi) ** alpha
    x = lo / (1.0 - u * (1.0 - ratio)) ** (1.0 / alpha)
    return int(min(hi, max(lo, math.floor(x))))


@dataclass(frozen=True)
class LengthModel:
    """Per-request prompt/budget sampler.

    ``dist="uniform"`` reproduces the legacy ``synthetic_workload``
    draws (and their exact rng order: prompt-size, prompt tokens,
    budget); ``dist="pareto"`` makes both lengths heavy-tailed with
    index ``alpha``.  ``clamp_len`` caps prompt+budget to an engine's
    ``max_len`` without disturbing the draw sequence."""

    vocab_size: int = 331
    min_prompt: int = 4
    max_prompt: int = 20
    min_new: int = 3
    max_new: int = 10
    dist: str = "uniform"            # "uniform" | "pareto"
    alpha: float = 1.5               # pareto tail index
    clamp_len: Optional[int] = None  # cap prompt+budget (engine max_len)

    def __post_init__(self):
        if self.dist not in ("uniform", "pareto"):
            raise ValueError(f"unknown length dist {self.dist!r}; "
                             f"expected 'uniform' or 'pareto'")

    def _draw(self, rng, lo: int, hi: int) -> int:
        if self.dist == "pareto":
            return bounded_pareto(rng, lo, hi, self.alpha)
        return int(rng.integers(lo, hi + 1))

    def sample(self, rng: np.random.Generator):
        """-> (prompt ndarray, max_new_tokens).  Draw order is part of
        the contract (ClosedLoop bit-compatibility)."""
        plen = self._draw(rng, self.min_prompt, self.max_prompt)
        prompt = rng.integers(0, self.vocab_size, size=plen
                              ).astype(np.int32)
        budget = self._draw(rng, self.min_new, self.max_new)
        if self.clamp_len is not None and plen + budget > self.clamp_len:
            budget = max(1, self.clamp_len - plen)
        return prompt, budget


@dataclass(frozen=True)
class Workload:
    """Base workload: subclasses define the arrival process.

    ``build(seed)`` draws arrivals first, then per-request lengths, and
    returns ``Request``s sorted by arrival.  When ``slack_s`` is set,
    every open-loop request gets ``deadline = arrival_time + slack_s +
    slack_per_token_s * budget`` — a size-aware SLO the front end
    schedules EDF on."""

    n_requests: int = 16
    lengths: LengthModel = LengthModel()
    slack_s: Optional[float] = None
    slack_per_token_s: float = 0.0
    rid_base: int = 0

    # -- subclass hooks ----------------------------------------------
    def _arrival_times(self, rng) -> Optional[Sequence[float]]:
        """Virtual-clock arrival seconds (None: closed-loop, step-based
        arrivals via ``_arrival_step``).  Called before any length
        draw."""
        raise NotImplementedError

    def _arrival_step(self, i: int) -> int:
        return 0

    # -- builder ------------------------------------------------------
    def build(self, seed_or_rng=0) -> List[Request]:
        rng = _as_rng(seed_or_rng)
        times = self._arrival_times(rng)
        reqs: List[Request] = []
        for i in range(self.n_requests):
            prompt, budget = self.lengths.sample(rng)
            t = None if times is None else float(times[i])
            deadline = None
            if t is not None and self.slack_s is not None:
                deadline = t + self.slack_s + \
                    self.slack_per_token_s * budget
            reqs.append(Request(
                rid=self.rid_base + i, prompt=prompt,
                max_new_tokens=budget, arrival=self._arrival_step(i),
                arrival_time=t, deadline=deadline))
        return sorted(reqs, key=lambda r: (r.arrival_time or 0.0,
                                           r.arrival, r.rid))


@dataclass(frozen=True)
class ClosedLoop(Workload):
    """The legacy staggered fixed list: ``per_arrival`` requests every
    ``arrival_every`` engine steps, no virtual-clock times — a
    closed-loop workload is just a degenerate arrival process."""

    arrival_every: int = 2
    per_arrival: int = 1

    def _arrival_times(self, rng):
        return None                  # no draw: keeps legacy rng order

    def _arrival_step(self, i: int) -> int:
        return (i // self.per_arrival) * self.arrival_every


@dataclass(frozen=True)
class Poisson(Workload):
    """Memoryless open-loop arrivals at ``rate`` requests/second."""

    rate: float = 10.0

    def _arrival_times(self, rng):
        if self.rate <= 0:
            raise ValueError(f"Poisson rate must be > 0, got {self.rate}")
        gaps = rng.exponential(1.0 / self.rate, size=self.n_requests)
        return np.cumsum(gaps)


def _thinned_arrivals(rng, n: int, rate_fn, rate_max: float
                      ) -> np.ndarray:
    """First ``n`` arrivals of an inhomogeneous Poisson process with
    intensity ``rate_fn(t) <= rate_max`` (Lewis–Shedler thinning)."""
    out: List[float] = []
    t = 0.0
    while len(out) < n:
        t += float(rng.exponential(1.0 / rate_max))
        if float(rng.random()) * rate_max <= rate_fn(t):
            out.append(t)
    return np.asarray(out)


@dataclass(frozen=True)
class Diurnal(Workload):
    """Inhomogeneous Poisson under a raised-cosine day curve: intensity
    swings ``base_rate`` -> ``peak_rate`` -> ``base_rate`` over each
    ``period_s`` (peak at period/2)."""

    base_rate: float = 2.0
    peak_rate: float = 20.0
    period_s: float = 10.0

    def _arrival_times(self, rng):
        if not 0 < self.base_rate <= self.peak_rate:
            raise ValueError(
                f"need 0 < base_rate <= peak_rate, got "
                f"{self.base_rate}/{self.peak_rate}")
        base, peak, period = self.base_rate, self.peak_rate, self.period_s

        def rate(t):
            phase = 0.5 * (1.0 - math.cos(2.0 * math.pi * t / period))
            return base + (peak - base) * phase

        return _thinned_arrivals(rng, self.n_requests, rate, peak)


@dataclass(frozen=True)
class FlashCrowd(Workload):
    """Baseline Poisson plus a flash-crowd burst: intensity jumps to
    ``base_rate * burst_factor`` on ``[burst_start_s, burst_start_s +
    burst_dur_s)`` — the arrival pattern for the mid-burst-quarantine
    scenario."""

    base_rate: float = 5.0
    burst_factor: float = 8.0
    burst_start_s: float = 1.0
    burst_dur_s: float = 2.0

    def _arrival_times(self, rng):
        if self.base_rate <= 0 or self.burst_factor < 1:
            raise ValueError(
                f"need base_rate > 0 and burst_factor >= 1, got "
                f"{self.base_rate}/{self.burst_factor}")
        lo, hi = self.burst_start_s, self.burst_start_s + self.burst_dur_s
        base, burst = self.base_rate, self.base_rate * self.burst_factor

        def rate(t):
            return burst if lo <= t < hi else base

        return _thinned_arrivals(rng, self.n_requests, rate, burst)


def with_deadlines(requests: Sequence[Request], *, slack_s: float,
                   slack_per_token_s: float = 0.0) -> List[Request]:
    """Attach size-aware deadlines to an already-built request list
    (whatever its source): ``arrival_time + slack_s +
    slack_per_token_s * budget``."""
    out = []
    for r in requests:
        t0 = r.arrival_time if r.arrival_time is not None else 0.0
        out.append(replace(
            r, deadline=t0 + slack_s +
            slack_per_token_s * r.max_new_tokens))
    return out


def synthetic_workload(vocab_size: int, n_requests: int, rng, *,
                       min_prompt: int = 4, max_prompt: int = 20,
                       min_new: int = 3, max_new: int = 10,
                       arrival_every: int = 2, per_arrival: int = 1
                       ) -> List[Request]:
    """Staggered random workload (legacy builder, now ``ClosedLoop``):
    ``n_requests`` requests with prompt lengths in [min_prompt,
    max_prompt], budgets in [min_new, max_new], arriving
    ``per_arrival`` at a time every ``arrival_every`` engine steps.
    Request lists are bit-identical to the pre-traffic-layer builder
    for the same rng state."""
    wl = ClosedLoop(
        n_requests=n_requests,
        lengths=LengthModel(vocab_size=vocab_size, min_prompt=min_prompt,
                            max_prompt=max_prompt, min_new=min_new,
                            max_new=max_new),
        arrival_every=arrival_every, per_arrival=per_arrival)
    return wl.build(rng)
