from repro.serve.engine import (RECOMPILE, RESIDENT, Completion, FleetConfig,
                                FleetServeEngine, Request, ServeConfig,
                                ServeEngine, percentile, reference_decode,
                                synthetic_workload)

__all__ = ["ServeConfig", "ServeEngine", "Request", "Completion",
           "RECOMPILE", "RESIDENT", "reference_decode",
           "synthetic_workload", "percentile", "FleetConfig",
           "FleetServeEngine"]
