from repro.serve.engine import (RECOMPILE, RESIDENT, Completion,
                                EngineSession, FleetConfig,
                                FleetServeEngine, FleetSession, Request,
                                ServeConfig, ServeEngine, ServeSession,
                                percentile, reference_decode,
                                validate_requests)
from repro.serve.frontend import (BLOCK, EDF, FIFO, REJECT, SHED_LATEST,
                                  Frontend, FrontendConfig, summarize)
from repro.serve.traffic import (ClosedLoop, Diurnal, FlashCrowd,
                                 LengthModel, Poisson, Workload,
                                 bounded_pareto, synthetic_workload,
                                 with_deadlines)

__all__ = ["ServeConfig", "ServeEngine", "Request", "Completion",
           "RECOMPILE", "RESIDENT", "reference_decode",
           "synthetic_workload", "percentile", "FleetConfig",
           "FleetServeEngine", "validate_requests",
           # streaming session API
           "ServeSession", "EngineSession", "FleetSession",
           # traffic generators
           "Workload", "ClosedLoop", "Poisson", "Diurnal", "FlashCrowd",
           "LengthModel", "bounded_pareto", "with_deadlines",
           # admission front end
           "Frontend", "FrontendConfig", "summarize",
           "BLOCK", "REJECT", "SHED_LATEST", "EDF", "FIFO"]
