"""Async admission front end over the streaming serve sessions.

``Frontend`` is the open-loop half of the serve stack: it runs a
*virtual-clock* event loop (one engine step = ``step_time_s`` virtual
seconds) that releases each request to the engine when the clock reaches
its ``arrival_time``, holds released-but-unadmitted work in a bounded
admission queue with a shedding policy, orders admissions EDF on
per-request deadlines, and evicts expired work — queued *and* in-flight
(``ServeSession.cancel`` frees the slot immediately) — so a request that
can no longer meet its SLO never starves one that can.

Virtual time makes the whole loop deterministic: scheduling depends only
on step indices, never on wall-clock timings, so a traffic scenario
(arrivals × faults × policies) replays bit-identically — including
across the fleet engine's multi-host deterministic replication, whose
contract is exactly that scheduling is value- and wall-time-independent.

Time conventions (``t = step * step_time_s``): a request released and
admitted at step ``k`` was admitted at clock ``k*dt``; its first token
(the prefill argmax) exists by ``(k+1)*dt``; a sequence finishing at
step ``f`` finished at ``(f+1)*dt``.  Expiry is checked at the top of
each step: ``clock > deadline`` evicts.

Works over both engines through the one session API
(``ServeEngine.session`` / ``FleetServeEngine.session``); fleet fault
events are threaded per-step exactly as in ``FleetServeEngine.serve``.
"""
from __future__ import annotations

import dataclasses
from dataclasses import dataclass
from typing import (Any, Dict, List, Mapping, Optional, Sequence,
                    Tuple)

import numpy as np

from repro.obs import metrics
from repro.obs import trace as obs_trace
from repro.serve.engine import (Completion, FleetServeEngine, Request,
                                percentile, validate_requests)

# shedding policies for a full admission queue
BLOCK = "block"                      # backpressure: delay further releases
REJECT = "reject"                    # drop the incoming request
SHED_LATEST = "latest_deadline"      # drop whoever can wait longest

# admission orders
EDF = "edf"                          # earliest deadline first
FIFO = "fifo"                        # release order

_POLICIES = (BLOCK, REJECT, SHED_LATEST)
_ORDERS = (EDF, FIFO)


@dataclass(frozen=True)
class FrontendConfig:
    """Virtual-clock admission policy.

    ``step_time_s`` converts engine steps to virtual seconds — calibrate
    it to a measured per-tick decode time to make virtual latencies
    physical.  ``max_queue`` bounds the released-but-unadmitted queue;
    ``shed`` picks what happens when it is full.  ``expire`` turns on
    deadline-expiry eviction (queued and in-flight).
    ``default_slack_s`` assigns a deadline to open-loop requests that
    arrived without one (None: such requests never expire)."""

    step_time_s: float = 0.05
    max_queue: int = 64
    shed: str = BLOCK
    order: str = EDF
    expire: bool = True
    default_slack_s: Optional[float] = None
    max_steps: int = 200_000

    def __post_init__(self):
        if self.shed not in _POLICIES:
            raise ValueError(f"unknown shed policy {self.shed!r}; "
                             f"expected one of {_POLICIES}")
        if self.order not in _ORDERS:
            raise ValueError(f"unknown admission order {self.order!r}; "
                             f"expected one of {_ORDERS}")
        if self.step_time_s <= 0:
            raise ValueError(f"step_time_s must be > 0, got "
                             f"{self.step_time_s}")
        if self.max_queue < 1:
            raise ValueError(f"max_queue must be >= 1, got "
                             f"{self.max_queue}")


class Frontend:
    """Admission front end over one engine (single-device or fleet)."""

    def __init__(self, engine, cfg: Optional[FrontendConfig] = None):
        self.engine = engine
        self.cfg = cfg or FrontendConfig()

    # ------------------------------------------------------------ run
    def run(self, requests: Sequence[Request], *,
            events: Optional[Mapping[int, Sequence[Tuple]]] = None,
            fault_at_step: Optional[Tuple[int, str]] = None
            ) -> Tuple[Dict[int, Completion], Dict[str, Any]]:
        """Drive the workload through the virtual-clock loop.

        ``events`` (fleet engines) / ``fault_at_step`` (single-device)
        inject faults mid-run exactly as the engines' own ``serve``.
        Returns ({rid: Completion}, stats); completions carry
        virtual-clock ``queue_wait_s`` / ``ttft_s`` / ``latency_s`` and
        their ``deadline_met`` verdicts, so goodput is a filter away.
        """
        cfg = self.cfg
        validate_requests(requests, self.engine.scfg.max_len)
        is_fleet = isinstance(self.engine, FleetServeEngine)
        if events and not is_fleet:
            raise ValueError("events= is the fleet fault interface; "
                             "single-device engines take fault_at_step=")
        if fault_at_step is not None and is_fleet:
            raise ValueError("fault_at_step= is the single-device fault "
                             "interface; fleet engines take events=")
        events = dict(events or {})
        dt = cfg.step_time_s

        # arrivals in time order; requests without arrival_time arrive
        # at t=0 (a closed-loop list open-loops degenerately)
        def t_of(r: Request) -> float:
            return r.arrival_time if r.arrival_time is not None else 0.0

        def deadline_of(r: Request) -> Optional[float]:
            if r.deadline is not None:
                return r.deadline
            if cfg.default_slack_s is not None:
                return t_of(r) + cfg.default_slack_s
            return None

        pending: List[Request] = sorted(requests,
                                        key=lambda r: (t_of(r), r.rid))
        queue: List[Request] = []    # released, not yet admitted
        sess = self.engine.session()
        completions: Dict[int, Completion] = {}
        meta: Dict[int, Request] = {r.rid: r for r in requests}
        live: set = set()            # submitted to the engine, not done
        stats: Dict[str, Any] = {
            "released": 0, "submitted": 0,
            "shed": [], "expired_queued": [], "expired_in_flight": [],
            "queue_depth": [],
        }

        def shed(r: Request, clock: float, kind: str):
            stats[kind].append(r.rid)
            if kind == "shed":
                metrics.inc("serve_shed_total")
            else:
                metrics.inc("serve_evicted_total",
                            where=kind.replace("expired_", ""))
            obs_trace.emit(int(round(clock / dt)), name=kind, rid=r.rid)
            completions[r.rid] = Completion(
                rid=r.rid, tokens=np.asarray((), np.int32),
                prompt_len=len(r.prompt), arrival=r.arrival,
                admitted_step=-1, finished_step=-1,
                latency_s=max(0.0, clock - t_of(r)),
                queue_wait_s=max(0.0, clock - t_of(r)), ttft_s=0.0,
                deadline=deadline_of(r), deadline_met=False,
                expired=True)

        step = 0
        while pending or queue or sess.pending():
            clock = step * dt
            if fault_at_step is not None and step == fault_at_step[0]:
                self.engine.inject_fault(fault_at_step[1])
            # ---- release arrivals whose time has come -------------
            while pending and t_of(pending[0]) <= clock:
                if len(queue) >= cfg.max_queue:
                    if cfg.shed == BLOCK:
                        break        # backpressure the source
                    if cfg.shed == REJECT:
                        shed(pending.pop(0), clock, "shed")
                        continue
                    # SHED_LATEST: whoever can wait longest goes —
                    # no-deadline requests can wait forever
                    pool = queue + [pending[0]]
                    keys = [(deadline_of(r) is None,
                             deadline_of(r) or 0.0, r.rid)
                            for r in pool]
                    j = keys.index(max(keys))
                    victim = pool[j]
                    if j == len(queue):
                        pending.pop(0)
                    else:
                        del queue[j]
                        queue.append(pending.pop(0))
                    shed(victim, clock, "shed")
                    continue
                r = pending.pop(0)
                stats["released"] += 1
                metrics.inc("serve_released_total")
                queue.append(r)
            # ---- deadline expiry (queued, then in-flight) ---------
            if cfg.expire:
                for j in range(len(queue) - 1, -1, -1):
                    d = deadline_of(queue[j])
                    if d is not None and clock > d:
                        shed(queue[j], clock, "expired_queued")
                        del queue[j]
                for rid in sorted(live):
                    d = deadline_of(meta[rid])
                    if d is not None and clock > d:
                        sess.cancel(rid)   # frees the slot this step
                        stats["expired_in_flight"].append(rid)
                        metrics.inc("serve_evicted_total",
                                    where="in_flight")
                        obs_trace.emit(step, kind=obs_trace.SPAN_END,
                                       name=f"req:{rid}", expired=True)
                        live.discard(rid)
            # ---- EDF admission into free engine slots -------------
            if cfg.order == EDF:
                queue.sort(key=lambda r: (
                    deadline_of(r) is None, deadline_of(r) or 0.0,
                    t_of(r), r.rid))
            k = min(sess.free_slots(), len(queue))
            for r in queue[:k]:
                # arrival=step: the engine's own gate opens now
                sess.submit(dataclasses.replace(r, arrival=step),
                            _validated=True)
                live.add(r.rid)
                stats["submitted"] += 1
                metrics.inc("serve_admitted_total")
                obs_trace.emit(step, kind=obs_trace.SPAN_START,
                               name=f"req:{r.rid}",
                               prompt_len=len(r.prompt))
            del queue[:k]
            stats["queue_depth"].append(len(queue))
            metrics.set_gauge("serve_queue_depth", len(queue))
            # ---- one engine tick ----------------------------------
            if is_fleet:
                sess.step(events.pop(step, ()))
            else:
                sess.step()
            for c in sess.poll():
                completions[c.rid] = c
                live.discard(c.rid)
                obs_trace.emit(step, kind=obs_trace.SPAN_END,
                               name=f"req:{c.rid}",
                               tokens=len(c.tokens))
            step += 1
            if step > cfg.max_steps:
                raise RuntimeError(
                    f"frontend did not converge in {cfg.max_steps} "
                    f"steps (pending {len(pending)}, queue "
                    f"{len(queue)}, in-flight {len(live)})")

        engine_stats = (sess.close(late_events=events) if is_fleet
                        else sess.close())
        for c in sess.poll():        # multi-host: post-close merge
            completions[c.rid] = c
        self._stamp_virtual_times(completions, meta, deadline_of, dt)
        stats["virtual_time_s"] = step * dt
        stats["steps"] = step
        stats["engine"] = engine_stats
        stats.update(summarize(completions, step * dt))
        metrics.set_gauge("serve_virtual_time_seconds", step * dt)
        return completions, stats

    # ------------------------------------------------- virtual stamps
    def _stamp_virtual_times(self, completions, meta, deadline_of, dt):
        """Rewrite wall timings with virtual-clock ones (Completion
        documents this switch): queue wait, TTFT, end-to-end latency,
        and the deadline verdict."""
        for rid, c in completions.items():
            r = meta.get(rid)
            if r is None or c.admitted_step < 0:
                continue             # shed/expired-queued: stamped at shed
            t0 = r.arrival_time if r.arrival_time is not None else 0.0
            c.queue_wait_s = max(0.0, c.admitted_step * dt - t0)
            c.ttft_s = max(0.0, (c.admitted_step + 1) * dt - t0)
            finish = (c.finished_step + 1) * dt
            c.latency_s = max(0.0, finish - t0)
            c.deadline = deadline_of(r)
            c.deadline_met = (not c.expired
                              and (c.deadline is None
                                   or finish <= c.deadline))


def summarize(completions: Mapping[int, Completion],
              virtual_time_s: float) -> Dict[str, Any]:
    """Goodput / tail-latency rollup over a finished run.  *Goodput*
    counts only tokens of completions that met their deadline — the
    paper's constant-aggregate-throughput claim is only interesting if
    it holds for work that was still useful."""
    done = [c for c in completions.values() if not c.expired]
    good = [c for c in done if c.deadline_met]
    lat = sorted(c.latency_s for c in good)
    ttft = sorted(c.ttft_s for c in good)
    span = max(virtual_time_s, 1e-9)
    # Telemetry mirror: obs.report.goodput_summary reproduces the
    # goodput/throughput values below exactly from these counters (same
    # integer token sums, same division by the virtual-time gauge).
    metrics.inc("serve_completed_total", len(done))
    metrics.inc("serve_deadline_met_total", len(good))
    metrics.inc("serve_expired_total",
                sum(c.expired for c in completions.values()))
    metrics.inc("serve_goodput_tokens_total",
                sum(len(c.tokens) for c in good))
    metrics.inc("serve_tokens_total",
                sum(len(c.tokens) for c in completions.values()))
    for c in good:
        metrics.observe("serve_latency_seconds", c.latency_s)
        metrics.observe("serve_ttft_seconds", c.ttft_s)
    return {
        "completed": len(done),
        "deadline_met": len(good),
        "expired": sum(c.expired for c in completions.values()),
        "goodput_tokens": sum(len(c.tokens) for c in good),
        "goodput_tok_s": sum(len(c.tokens) for c in good) / span,
        "throughput_tok_s": sum(len(c.tokens)
                                for c in completions.values()) / span,
        "p50_latency_s": percentile(lat, 0.50) if lat else 0.0,
        "p99_latency_s": percentile(lat, 0.99) if lat else 0.0,
        "p50_ttft_s": percentile(ttft, 0.50) if ttft else 0.0,
        "p99_ttft_s": percentile(ttft, 0.99) if ttft else 0.0,
    }
