"""Fault-aware continuous-batching serve engine (paper §III at traffic scale).

Requests arrive over time with independent prompt lengths and token budgets;
the engine keeps a fixed pool of decode *slots* (each a single-sequence KV
lane), admits queued requests into free slots (per-request prefill), runs one
vmapped decode step across all slots per tick, and evicts finished sequences
so their slots immediately take new traffic — continuous batching.

Routing flows through the unified ``RoutingPlan`` IR end to end, and two
failover modes mirror the paper's two mechanisms:

  * ``RECOMPILE`` (queue reconfiguration): the decode executable is keyed by
    the current RoutingPlan in a Dispatcher; a detected fault produces a new
    plan -> one recompile, after which in-flight decodes continue on the
    rerouted program.  Zero overhead while healthy.
  * ``RESIDENT`` (hot-spare residency): one decode executable carries *both*
    lowerings of every stage behind ``lax.cond`` on a ``health_mask`` input;
    failover is flipping one bit in that array — O(µs), no recompile — so a
    mid-stream fault reroutes in-flight decodes without dropping them.

Decoded tokens are bit-identical across routings and across batching
schedules because the lowerings are Viscosity-equivalent and every slot is
an independent lane (the tests assert both).
"""
from __future__ import annotations

import collections
import time
from dataclasses import dataclass
from typing import Any, Dict, List, Optional, Sequence, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs.base import ModelConfig
from repro.core.fault import FaultState
from repro.core.oobleck import Dispatcher
from repro.core.routing import RoutingPlan
from repro.models import build_model
from repro.train.runner import model_stage_names
from repro.viscosity import REGISTRY, SW

# Failover modes (paper §III: queue reconfiguration vs hot-spare residency).
RECOMPILE = "recompile"
RESIDENT = "resident"


@dataclass(frozen=True)
class Request:
    """One serving request: a prompt, a token budget, an arrival time
    (measured in engine steps, so workloads are deterministic)."""
    rid: int
    prompt: Any                      # (P,) int32 array-like
    max_new_tokens: int
    arrival: int = 0


@dataclass
class Completion:
    rid: int
    tokens: np.ndarray               # (max_new_tokens,) int32
    prompt_len: int
    arrival: int
    admitted_step: int
    finished_step: int
    latency_s: float                 # wall: queue-eligible -> last token


@dataclass
class _Slot:
    rid: int
    prompt_len: int
    arrival: int
    remaining: int
    out: List[int]
    admitted_step: int
    eligible_wall: float


@dataclass
class ServeConfig:
    max_len: int = 256               # KV capacity per slot (prompt + new)
    max_slots: int = 4               # concurrent sequences per decode tick
    hw_route: str = SW               # healthy-stage target (HW on real TPUs)
    failover: str = RECOMPILE        # RECOMPILE | RESIDENT


class ServeEngine:
    """Continuous-batching engine; all routing flows through RoutingPlan."""

    def __init__(self, cfg: ModelConfig, params, scfg: ServeConfig):
        if scfg.failover not in (RECOMPILE, RESIDENT):
            raise ValueError(f"unknown failover mode {scfg.failover!r}; "
                             f"expected {RECOMPILE!r} or {RESIDENT!r}")
        self.cfg = cfg
        self.params = params
        self.scfg = scfg
        self.fault_state = FaultState()
        self.stage_names = model_stage_names(cfg)
        # Route-free model instance, used only for cache/shape structure.
        self._shape_model = build_model(cfg)
        self._prefill = Dispatcher(self._build_prefill)
        self._decode = Dispatcher(self._build_decode)
        # Zero KV template, shared by every admission (prefill does not
        # donate its inputs, so one allocation serves the engine lifetime).
        self._cache0 = self._shape_model.init_cache(1, scfg.max_len)
        # Donating jitted slot insert: writing a prefilled lane into the
        # S-slot pool must not copy the whole pool per admission.
        self._insert = jax.jit(
            lambda full, one, i: jax.tree_util.tree_map(
                lambda f, o: jax.lax.dynamic_update_index_in_dim(f, o, i, 0),
                full, one),
            donate_argnums=(0,))

    # ------------------------------------------------------------- plans
    def plan(self) -> RoutingPlan:
        """RoutingPlan for the current fault state (the one IR every layer
        shares): healthy stages take the deployment target, quarantined
        stages their SW fallback."""
        return RoutingPlan.from_signature(
            self.fault_state.signature(self.stage_names),
            healthy=self.scfg.hw_route).validate(registry=REGISTRY)

    def _decode_key(self) -> RoutingPlan:
        if self.scfg.failover == RESIDENT:
            # One resident executable, keyed by the all-healthy plan; the
            # health-mask input does the rerouting at runtime.
            return RoutingPlan.for_stages(self.stage_names,
                                          target=self.scfg.hw_route)
        return self.plan()

    def health_mask(self) -> jax.Array:
        return jnp.asarray([not self.fault_state.is_faulty(s)
                            for s in self.stage_names], dtype=bool)

    def inject_fault(self, stage: str):
        if stage not in self.stage_names:
            raise ValueError(f"unknown stage {stage!r}; this model's stages:"
                             f" {self.stage_names}")
        self.fault_state.mark(stage, 0, kind="injected")

    # ------------------------------------------------------------ builds
    def _build_prefill(self, plan: RoutingPlan):
        if self.scfg.failover == RESIDENT:
            # Admissions after a fault must not stall in-flight decodes on
            # a recompile either: prefill is resident too (one executable
            # per prompt length, rerouted by the same health mask).
            names = list(self.stage_names)
            cfg = self.cfg

            def prefill(params, batch, mask):
                routes = plan.resident_routes(mask, names)
                return build_model(cfg, routes=routes).prefill(params, batch)

            return jax.jit(prefill)
        model = build_model(self.cfg, routes=plan)
        return jax.jit(model.prefill)

    def _run_prefill(self, params, batch):
        key = self._decode_key()
        if self.scfg.failover == RESIDENT:
            return self._prefill.get(key)(params, batch, self.health_mask())
        return self._prefill.get(key)(params, batch)

    def _build_decode(self, plan: RoutingPlan):
        if self.scfg.failover == RESIDENT:
            names = list(self.stage_names)
            cfg = self.cfg

            def step(params, cache, tokens, t, mask):
                routes = plan.resident_routes(mask, names)
                model = build_model(cfg, routes=routes)
                return model.decode_step(params, cache, tokens, t)

            return jax.jit(jax.vmap(step, in_axes=(None, 0, 0, 0, None)),
                           donate_argnums=(1,))
        model = build_model(self.cfg, routes=plan)
        return jax.jit(jax.vmap(model.decode_step, in_axes=(None, 0, 0, 0)),
                       donate_argnums=(1,))

    # --------------------------------------------------------- admission
    def _validate(self, requests: Sequence[Request]):
        rids = [r.rid for r in requests]
        if len(set(rids)) != len(rids):
            raise ValueError("duplicate request ids")
        for r in requests:
            if len(r.prompt) < 1:
                raise ValueError(f"request {r.rid}: prompt must be "
                                 f"non-empty")
            if r.max_new_tokens < 1:
                raise ValueError(f"request {r.rid}: max_new_tokens must be "
                                 f">= 1, got {r.max_new_tokens}")
            if len(r.prompt) + r.max_new_tokens > self.scfg.max_len:
                raise ValueError(
                    f"request {r.rid}: prompt ({len(r.prompt)}) + budget "
                    f"({r.max_new_tokens}) exceeds max_len "
                    f"{self.scfg.max_len}")

    def _admit(self, req: Request, i: int, caches, toks, tvec):
        prompt = jnp.asarray(req.prompt, jnp.int32)[None]
        P = prompt.shape[1]
        logits, cache = self._run_prefill(
            self.params, {"tokens": prompt, "cache": self._cache0})
        first = jnp.argmax(logits[:, -1], -1).astype(jnp.int32)   # (1,)
        caches = self._insert(caches, cache, jnp.int32(i))
        toks = toks.at[i].set(first[:, None])
        tvec = tvec.at[i].set(P)
        return caches, toks, tvec, int(first[0])

    # -------------------------------------------------------------- run
    def serve(self, requests: Sequence[Request], *,
              fault_at_step: Optional[Tuple[int, str]] = None
              ) -> Tuple[Dict[int, Completion], Dict[str, Any]]:
        """Run a workload to completion.

        ``fault_at_step=(k, stage)`` quarantines ``stage`` just before
        engine step ``k`` (admissions and the decode tick at ``k`` already
        run rerouted).  Returns ({rid: Completion}, stats).
        """
        scfg = self.scfg
        S = scfg.max_slots
        self._validate(requests)
        queue = collections.deque(
            sorted(requests, key=lambda r: (r.arrival, r.rid)))
        caches = jax.tree_util.tree_map(lambda a: jnp.stack([a] * S),
                                        self._cache0)
        toks = jnp.zeros((S, 1, 1), jnp.int32)
        tvec = jnp.zeros((S,), jnp.int32)
        slots: List[Optional[_Slot]] = [None] * S
        eligible_wall: Dict[int, float] = {}
        completions: Dict[int, Completion] = {}
        decode_keys = set()
        prefill_compiles0 = self._prefill.compiles
        stats: Dict[str, Any] = {"step_times": [], "occupancy": [],
                                 "admitted": 0, "steps": 0}
        step = 0
        while queue or any(sl is not None for sl in slots):
            if fault_at_step is not None and step == fault_at_step[0]:
                self.inject_fault(fault_at_step[1])
            now = time.perf_counter()
            for r in queue:
                if r.arrival <= step and r.rid not in eligible_wall:
                    eligible_wall[r.rid] = now
            # admission: arrived requests claim free slots (join)
            for i in range(S):
                if slots[i] is None and queue and queue[0].arrival <= step:
                    req = queue.popleft()
                    caches, toks, tvec, first = self._admit(
                        req, i, caches, toks, tvec)
                    slots[i] = _Slot(rid=req.rid, prompt_len=len(req.prompt),
                                     arrival=req.arrival,
                                     remaining=req.max_new_tokens - 1,
                                     out=[first], admitted_step=step,
                                     eligible_wall=eligible_wall.get(req.rid,
                                                                     now))
                    stats["admitted"] += 1
                    if slots[i].remaining == 0:       # single-token request
                        self._finish(slots, i, step, completions)
            active = [i for i in range(S) if slots[i] is not None]
            if not active:
                step += 1            # idle tick: waiting on future arrivals
                continue
            key = self._decode_key()
            fn = self._decode.get(key)
            decode_keys.add(key)
            t0 = time.perf_counter()
            if scfg.failover == RESIDENT:
                logits, caches = fn(self.params, caches, toks, tvec,
                                    self.health_mask())
            else:
                logits, caches = fn(self.params, caches, toks, tvec)
            nxt = jnp.argmax(logits[:, 0, -1], -1).astype(jnp.int32)  # (S,)
            nxt.block_until_ready()
            stats["step_times"].append(time.perf_counter() - t0)
            stats["occupancy"].append(len(active))
            toks = nxt[:, None, None]
            active_mask = np.zeros((S,), np.int32)
            active_mask[active] = 1
            tvec = tvec + jnp.asarray(active_mask)
            nxt_np = np.asarray(nxt)
            for i in active:
                sl = slots[i]
                sl.out.append(int(nxt_np[i]))
                sl.remaining -= 1
                if sl.remaining == 0:                 # evict finished
                    self._finish(slots, i, step, completions)
            step += 1
        stats["steps"] = step
        stats["recompiles"] = max(0, len(decode_keys) - 1)
        stats["decode_compiles"] = self._decode.compiles
        stats["prefill_compiles"] = self._prefill.compiles - prefill_compiles0
        return completions, stats

    @staticmethod
    def _finish(slots, i, step, completions):
        sl = slots[i]
        completions[sl.rid] = Completion(
            rid=sl.rid, tokens=np.asarray(sl.out, np.int32),
            prompt_len=sl.prompt_len, arrival=sl.arrival,
            admitted_step=sl.admitted_step, finished_step=step,
            latency_s=time.perf_counter() - sl.eligible_wall)
        slots[i] = None

    # ------------------------------------------------- fixed-batch compat
    def generate(self, prompts, n_new: int, *,
                 fault_at_step: Optional[Tuple[int, str]] = None
                 ) -> Tuple[np.ndarray, Dict[str, Any]]:
        """Fixed-batch convenience wrapper: every row of ``prompts`` (B, P)
        arrives at step 0 and decodes ``n_new`` tokens; returns (B, n_new)
        greedy tokens (row i = prompt i).  ``fault_at_step`` indexes decode
        steps, as in the pre-continuous engine."""
        prompts = np.asarray(prompts)
        B = prompts.shape[0]
        if B > self.scfg.max_slots:
            raise ValueError(f"batch {B} exceeds max_slots "
                             f"{self.scfg.max_slots}")
        reqs = [Request(rid=i, prompt=prompts[i], max_new_tokens=n_new)
                for i in range(B)]
        completions, stats = self.serve(reqs, fault_at_step=fault_at_step)
        toks = np.stack([completions[i].tokens for i in range(B)])
        return toks, stats


def percentile(xs: Sequence[float], q: float) -> float:
    """Nearest-rank percentile (one convention for every latency report)."""
    xs = sorted(xs)
    return xs[min(len(xs) - 1, int(len(xs) * q))]


def synthetic_workload(vocab_size: int, n_requests: int, rng, *,
                       min_prompt: int = 4, max_prompt: int = 20,
                       min_new: int = 3, max_new: int = 10,
                       arrival_every: int = 2, per_arrival: int = 1
                       ) -> List[Request]:
    """Staggered random workload: ``n_requests`` requests with prompt
    lengths in [min_prompt, max_prompt], budgets in [min_new, max_new],
    arriving ``per_arrival`` at a time every ``arrival_every`` engine
    steps.  One builder for the tests, examples, launcher, and benches."""
    return [Request(rid=i,
                    prompt=rng.integers(0, vocab_size,
                                        size=int(rng.integers(
                                            min_prompt, max_prompt + 1))
                                        ).astype(np.int32),
                    max_new_tokens=int(rng.integers(min_new, max_new + 1)),
                    arrival=(i // per_arrival) * arrival_every)
            for i in range(n_requests)]


def reference_decode(cfg: ModelConfig, params, prompt, n_new: int, *,
                     max_len: int, routes: Optional[RoutingPlan] = None
                     ) -> np.ndarray:
    """Single-request greedy decode straight on the model — no slots, no
    vmap, no engine.  The per-request oracle the batching engine must match
    bit-for-bit (used by tests and serve_bench)."""
    model = build_model(cfg, routes=routes)
    prompt = jnp.asarray(prompt, jnp.int32)[None]
    P = prompt.shape[1]
    cache = model.init_cache(1, max_len)
    logits, state = jax.jit(model.prefill)(
        params, {"tokens": prompt, "cache": cache})
    tok = jnp.argmax(logits[:, -1], -1).astype(jnp.int32)[:, None]
    out = [int(tok[0, 0])]
    step_fn = jax.jit(model.decode_step)
    for i in range(n_new - 1):
        logits, state = step_fn(params, state, tok, jnp.int32(P + i))
        tok = jnp.argmax(logits[:, -1], -1).astype(jnp.int32)[:, None]
        out.append(int(tok[0, 0]))
    return np.asarray(out, np.int32)
