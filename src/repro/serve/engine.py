"""Fault-aware continuous-batching serve engine (paper §III at traffic scale).

Requests arrive over time with independent prompt lengths and token budgets;
the engine keeps a fixed pool of decode *slots* (each a single-sequence KV
lane), admits queued requests into free slots (per-request prefill), runs one
vmapped decode step across all slots per tick, and evicts finished sequences
so their slots immediately take new traffic — continuous batching.

Routing flows through the unified ``RoutingPlan`` IR end to end, and two
failover modes mirror the paper's two mechanisms:

  * ``RECOMPILE`` (queue reconfiguration): the decode executable is keyed by
    the current RoutingPlan in a Dispatcher; a detected fault produces a new
    plan -> one recompile, after which in-flight decodes continue on the
    rerouted program.  Zero overhead while healthy.
  * ``RESIDENT`` (hot-spare residency): one decode executable carries *both*
    lowerings of every stage behind ``lax.cond`` on a ``health_mask`` input;
    failover is flipping one bit in that array — O(µs), no recompile — so a
    mid-stream fault reroutes in-flight decodes without dropping them.

Decoded tokens are bit-identical across routings and across batching
schedules because the lowerings are Viscosity-equivalent and every slot is
an independent lane (the tests assert both).

The fleet layer (paper §II Fig. 2, §V Fig. 8) stacks on the same engine:
``FleetServeEngine`` runs one slot pool per *device*, scheduling admissions
across the per-device pools, with every device consulting its own
``RoutingPlan`` out of a shared ``FleetPlan``.  The pools share one pair of
Dispatchers, so two devices with the same routing share compiled
executables (the FleetPlan compile-key multiset).  A faulted device's work
migrates to a hot spare when one is free (its in-flight slots drain and
re-admit — greedy decode makes the re-decoded tokens bit-identical);
otherwise the device degrades in place exactly like the single-device
engine.

**Multi-host mode** (``FleetConfig.topology`` + a coordinator): the fleet
spans processes by deterministic replication.  Every host runs the same
scheduling loop over the same request list, but only *executes* the slot
pools of its own device block — remote devices are ``_ShadowWorker``
bookkeeping twins whose admissions/ticks/evictions replay the identical
deterministic schedule (slot assignment, budgets, and eviction order never
depend on token values), so the global queue, capacities, and occupancy
stay bit-identical across hosts without exchanging any tensor data.
Fleet-health transitions are agreed through the ordered event log
(``launch.distributed.EventChannel``): each step every host publishes its
locally observed events and applies the canonical merge, so one FleetPlan
exists fleet-wide and a quarantined device on host A re-admits its
in-flight work on a spare owned by host B — the collective drain/re-admit
is just the shared queue, no request ever dropped.  ``merge_completions``
resolves each host's placeholder completions against the owning host's
real tokens at the end.
"""
from __future__ import annotations

import collections
import json
import time
import warnings
from dataclasses import dataclass
from typing import Any, Dict, List, Mapping, Optional, Sequence, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs.base import ModelConfig
from repro.core.datacenter import DegradationModel
from repro.core.fault import FaultState
from repro.core.oobleck import Dispatcher
from repro.core.routing import FleetPlan, RoutingPlan, rung_occupancy
from repro.launch.distributed import EventChannel, HostTimeoutError, \
    HostTopology, fleet_fingerprint
from repro.models import build_model
from repro.obs import metrics
from repro.obs import trace as obs_trace
from repro.train.runner import model_stage_names
from repro.viscosity import REGISTRY, SW, lanefault

# Failover modes (paper §III: queue reconfiguration vs hot-spare residency).
RECOMPILE = "recompile"
RESIDENT = "resident"


@dataclass(frozen=True)
class Request:
    """One serving request: a prompt, a token budget, an arrival time
    (measured in engine steps, so workloads are deterministic).

    Open-loop traffic adds two optional fields: ``arrival_time`` is the
    request's arrival on the *virtual clock* (seconds; the admission
    front end releases it to the engine when the clock reaches it — the
    step-based ``arrival`` stays the engine's own admission gate), and
    ``deadline`` is the per-request SLO on the same clock (the front end
    schedules EDF on it and evicts expired work)."""
    rid: int
    prompt: Any                      # (P,) int32 array-like
    max_new_tokens: int
    arrival: int = 0
    arrival_time: Optional[float] = None   # virtual-clock seconds
    deadline: Optional[float] = None       # virtual-clock SLO deadline


@dataclass
class Completion:
    rid: int
    tokens: np.ndarray               # (max_new_tokens,) int32
    prompt_len: int
    arrival: int
    admitted_step: int
    finished_step: int
    latency_s: float                 # wall: queue-eligible -> last token
    device: int = -1                 # fleet device that decoded it
    placeholder: bool = False        # True: decoded on a remote host —
    #                                  merge_completions fills in tokens
    # SLO fields (virtual-clock seconds once a Frontend ran the workload;
    # wall seconds when the engine ran bare).  ``expired`` completions
    # were evicted at their deadline with only the tokens decoded so far.
    queue_wait_s: float = 0.0        # arrival/eligible -> admission
    ttft_s: float = 0.0              # arrival/eligible -> first token
    deadline: Optional[float] = None
    deadline_met: bool = True
    expired: bool = False


@dataclass
class _Slot:
    rid: int
    prompt_len: int
    arrival: int
    remaining: int
    out: List[int]
    admitted_step: int
    eligible_wall: float
    req: Optional[Request] = None    # original request (fleet drain/requeue)


@dataclass
class ServeConfig:
    max_len: int = 256               # KV capacity per slot (prompt + new)
    max_slots: int = 4               # concurrent sequences per decode tick
    hw_route: str = SW               # healthy-stage target (HW on real TPUs)
    failover: str = RECOMPILE        # RECOMPILE | RESIDENT


def validate_requests(requests: Sequence[Request], max_len: int):
    """Request sanity shared by every engine front door.

    Every rejection names the offending request id and field, so a bad
    request in a 10k-request open-loop workload is findable from the
    message alone."""
    seen = set()
    for r in requests:
        if r.rid in seen:
            raise ValueError(f"request {r.rid}: duplicate request id "
                             f"(field 'rid')")
        seen.add(r.rid)
        if len(r.prompt) < 1:
            raise ValueError(f"request {r.rid}: field 'prompt' must be "
                             f"non-empty")
        if r.max_new_tokens < 1:
            raise ValueError(f"request {r.rid}: field 'max_new_tokens' "
                             f"must be >= 1, got {r.max_new_tokens}")
        if len(r.prompt) + r.max_new_tokens > max_len:
            raise ValueError(
                f"request {r.rid}: fields 'prompt' ({len(r.prompt)}) + "
                f"'max_new_tokens' ({r.max_new_tokens}) exceed max_len "
                f"{max_len}")
        if r.arrival < 0:
            raise ValueError(f"request {r.rid}: field 'arrival' must be "
                             f">= 0, got {r.arrival}")
        if r.arrival_time is not None and not r.arrival_time >= 0:
            raise ValueError(f"request {r.rid}: field 'arrival_time' must "
                             f"be >= 0, got {r.arrival_time}")
        if r.deadline is not None:
            if not r.deadline >= 0:
                raise ValueError(f"request {r.rid}: field 'deadline' must "
                                 f"be >= 0, got {r.deadline}")
            t0 = r.arrival_time if r.arrival_time is not None else 0.0
            if r.deadline <= t0:
                raise ValueError(
                    f"request {r.rid}: field 'deadline' ({r.deadline}) "
                    f"must be after field 'arrival_time' ({t0}) — the "
                    f"request would expire before it arrives")


class _SlotPool:
    """Slot bookkeeping shared by the real engine and its shadow twins.

    Everything here is value-independent: slot choice (lowest free),
    eviction (budget exhausted), drain order (youngest first) — so a
    remote host replaying only this bookkeeping stays in lockstep with
    the host actually decoding.  Subclasses set ``scfg``, ``placeholder``
    and ``device_index`` and call ``_init_pool``.
    """

    placeholder = False              # shadow pools emit placeholder
    device_index = -1                # completions; fleet sets the index

    def _init_pool(self):
        self._slots: List[Optional[_Slot]] = [None] * self.scfg.max_slots
        self.capacity = self.scfg.max_slots   # admission ceiling

    def occupancy(self) -> int:
        return sum(sl is not None for sl in self._slots)

    def has_free_slot(self) -> bool:
        return (self.occupancy() < self.capacity
                and any(sl is None for sl in self._slots))

    def free_slots(self) -> int:
        """Admissions this pool can take right now (capacity- and
        physical-slot-limited) — the admission front end sizes its EDF
        batch with this."""
        free = sum(sl is None for sl in self._slots)
        return max(0, min(self.capacity - self.occupancy(), free))

    def active_slots(self) -> List[int]:
        return [i for i, sl in enumerate(self._slots) if sl is not None]

    def drain(self) -> List[Request]:
        """Evict every in-flight sequence and hand back the original
        requests for re-admission elsewhere (fleet migration).  Partial
        outputs are discarded — greedy decode makes the re-decoded tokens
        bit-identical to an uninterrupted run."""
        drained = [sl.req for sl in self._slots
                   if sl is not None and sl.req is not None]
        for i in range(len(self._slots)):
            self._slots[i] = None
        return drained

    def drain_excess(self) -> List[Request]:
        """Evict just enough in-flight sequences to fit a reduced
        capacity (fleet degradation), youngest first — the least
        re-decoded work is thrown away."""
        excess = self.occupancy() - self.capacity
        if excess <= 0:
            return []
        victims = sorted(self.active_slots(),
                         key=lambda i: len(self._slots[i].out))[:excess]
        out = [self._slots[i].req for i in victims
               if self._slots[i].req is not None]
        for i in victims:
            self._slots[i] = None
        return out

    def _finish(self, i: int, step: int, completions: Dict[int,
                                                           "Completion"],
                *, expired: bool = False):
        sl = self._slots[i]
        completions[sl.rid] = Completion(
            rid=sl.rid,
            tokens=np.asarray(() if self.placeholder else sl.out, np.int32),
            prompt_len=sl.prompt_len, arrival=sl.arrival,
            admitted_step=sl.admitted_step, finished_step=step,
            latency_s=time.perf_counter() - sl.eligible_wall,
            device=self.device_index, placeholder=self.placeholder,
            deadline=(sl.req.deadline if sl.req is not None else None),
            deadline_met=not expired, expired=expired)
        self._slots[i] = None

    def evict_rid(self, rid: int, step: int,
                  completions: Dict[int, "Completion"]) -> bool:
        """Deadline-expiry eviction: free the slot holding ``rid`` *now*
        and emit an expired Completion carrying whatever tokens were
        already decoded.  Returns False when ``rid`` holds no slot here.
        Value-independent (slot lookup by rid only), so shadow twins
        replay it in lockstep."""
        for i, sl in enumerate(self._slots):
            if sl is not None and sl.rid == rid:
                self._finish(i, step, completions, expired=True)
                return True
        return False


class ServeEngine(_SlotPool):
    """Continuous-batching engine; all routing flows through RoutingPlan.

    Slot-pool state lives on the instance (``reset_pool`` / ``admit`` /
    ``decode_tick`` / ``drain``), so the same pool machinery serves both
    the single-device ``serve`` loop and the per-device workers of
    ``FleetServeEngine``.
    """

    def __init__(self, cfg: ModelConfig, params, scfg: ServeConfig, *,
                 dispatchers: Optional[Tuple[Dispatcher, Dispatcher]] = None,
                 template: Optional["ServeEngine"] = None,
                 classifier=None):
        if scfg.failover not in (RECOMPILE, RESIDENT):
            raise ValueError(f"unknown failover mode {scfg.failover!r}; "
                             f"expected {RECOMPILE!r} or {RESIDENT!r}")
        self.cfg = cfg
        self.params = params
        self.scfg = scfg
        self.classifier = classifier   # core.fault.FaultClassifier | None
        self.fault_state = FaultState()
        self.stage_names = model_stage_names(cfg)
        if dispatchers is None:
            self._prefill = Dispatcher(self._build_prefill)
            self._decode = Dispatcher(self._build_decode)
        else:                        # fleet workers share one compile cache
            self._prefill, self._decode = dispatchers
        if template is not None:
            # Fleet workers share the route-free shape model, the zero KV
            # template, and the jitted slot insert — only pool *state* is
            # per-device (jit caches are per-function-instance, so a
            # private _insert would recompile once per worker).
            self._shape_model = template._shape_model
            self._cache0 = template._cache0
            self._insert = template._insert
        else:
            # Route-free model instance, for cache/shape structure only.
            self._shape_model = build_model(cfg)
            # Zero KV template, shared by every admission (prefill does
            # not donate its inputs, so one allocation serves the engine
            # lifetime).
            self._cache0 = self._shape_model.init_cache(1, scfg.max_len)
            # Donating jitted slot insert: writing a prefilled lane into
            # the S-slot pool must not copy the whole pool per admission.
            self._insert = jax.jit(
                lambda full, one, i: jax.tree_util.tree_map(
                    lambda f, o: jax.lax.dynamic_update_index_in_dim(
                        f, o, i, 0),
                    full, one),
                donate_argnums=(0,))
        self.reset_pool()

    # --------------------------------------------------------- pool state
    def reset_pool(self):
        """Fresh slot pool: no admitted sequences, full capacity."""
        S = self.scfg.max_slots
        self._caches = jax.tree_util.tree_map(
            lambda a: jnp.stack([a] * S), self._cache0)
        self._toks = jnp.zeros((S, 1, 1), jnp.int32)
        self._tvec = jnp.zeros((S,), jnp.int32)
        self._init_pool()

    # ------------------------------------------------------------- plans
    def plan(self) -> RoutingPlan:
        """RoutingPlan for the current fault state (the one IR every layer
        shares): healthy stages take the deployment target; quarantined
        stages walk the degradation ladder when detection has localized a
        lane map (fault 1 -> remap, 2 -> reduced width, then SW), or drop
        straight to the SW fallback without one."""
        base = RoutingPlan.from_signature(
            self.fault_state.signature(self.stage_names),
            healthy=self.scfg.hw_route)
        return lanefault.degraded_plan(
            base, self.fault_state.counts(self.stage_names)
        ).validate(registry=REGISTRY)

    def _decode_key(self) -> RoutingPlan:
        if self.scfg.failover == RESIDENT:
            # One resident executable, keyed by the all-healthy plan; the
            # health-mask input does the rerouting at runtime.
            return RoutingPlan.for_stages(self.stage_names,
                                          target=self.scfg.hw_route)
        return self.plan()

    def health_mask(self) -> jax.Array:
        return jnp.asarray([not self.fault_state.is_faulty(s)
                            for s in self.stage_names], dtype=bool)

    def inject_fault(self, stage: str):
        if stage not in self.stage_names:
            raise ValueError(f"unknown stage {stage!r}; this model's stages:"
                             f" {self.stage_names}")
        self.fault_state.mark(stage, 0, kind="injected")

    def observe_fault(self, stage: str, *, step: int = 0) -> bool:
        """Route one detection through the probation classifier (when the
        engine has one).  The stage is marked first — probation must not
        race new work onto the suspect path — then its canary re-executes
        under the classifier's backoff budget.  A transient verdict
        (canary went clean) clears the mark, so the next ``plan()``
        restores the HW route with zero residual quarantine; persistent
        keeps the mark and the degradation ladder walks exactly as an
        ``inject_fault`` would.  Returns True when transient."""
        if stage not in self.stage_names:
            raise ValueError(f"unknown stage {stage!r}; this model's stages:"
                             f" {self.stage_names}")
        self.fault_state.mark(stage, 0, kind="detected", step=step)
        if self.classifier is None:
            return False
        res = self.classifier.classify(stage, replica=0, step=step,
                                       state=self.fault_state)
        if res.transient:
            self.fault_state.clear(stage, 0, step=step)
            return True
        return False

    # ------------------------------------------------------------ builds
    def _build_prefill(self, plan: RoutingPlan):
        if self.scfg.failover == RESIDENT:
            # Admissions after a fault must not stall in-flight decodes on
            # a recompile either: prefill is resident too (one executable
            # per prompt length, rerouted by the same health mask).
            names = list(self.stage_names)
            cfg = self.cfg

            def prefill(params, batch, mask):
                routes = plan.resident_routes(mask, names)
                return build_model(cfg, routes=routes).prefill(params, batch)

            return jax.jit(prefill)
        model = build_model(self.cfg, routes=plan)
        return jax.jit(model.prefill)

    def _run_prefill(self, params, batch):
        key = self._decode_key()
        if self.scfg.failover == RESIDENT:
            return self._prefill.get(key)(params, batch, self.health_mask())
        return self._prefill.get(key)(params, batch)

    def _build_decode(self, plan: RoutingPlan):
        if self.scfg.failover == RESIDENT:
            names = list(self.stage_names)
            cfg = self.cfg

            def step(params, cache, tokens, t, mask):
                routes = plan.resident_routes(mask, names)
                model = build_model(cfg, routes=routes)
                return model.decode_step(params, cache, tokens, t)

            return jax.jit(jax.vmap(step, in_axes=(None, 0, 0, 0, None)),
                           donate_argnums=(1,))
        model = build_model(self.cfg, routes=plan)
        return jax.jit(jax.vmap(model.decode_step, in_axes=(None, 0, 0, 0)),
                       donate_argnums=(1,))

    # --------------------------------------------------------- admission
    def _validate(self, requests: Sequence[Request]):
        validate_requests(requests, self.scfg.max_len)

    def admit(self, req: Request, step: int, eligible_wall: float,
              completions: Dict[int, Completion]) -> int:
        """Prefill ``req`` into the lowest free slot (caller checks
        ``has_free_slot``); single-token requests complete immediately.
        Returns the number of tokens emitted (always 1: the prefill
        argmax)."""
        i = next(idx for idx, sl in enumerate(self._slots) if sl is None)
        prompt = jnp.asarray(req.prompt, jnp.int32)[None]
        P = prompt.shape[1]
        logits, cache = self._run_prefill(
            self.params, {"tokens": prompt, "cache": self._cache0})
        first = jnp.argmax(logits[:, -1], -1).astype(jnp.int32)   # (1,)
        self._caches = self._insert(self._caches, cache, jnp.int32(i))
        self._toks = self._toks.at[i].set(first[:, None])
        self._tvec = self._tvec.at[i].set(P)
        self._slots[i] = _Slot(rid=req.rid, prompt_len=len(req.prompt),
                               arrival=req.arrival,
                               remaining=req.max_new_tokens - 1,
                               out=[int(first[0])], admitted_step=step,
                               eligible_wall=eligible_wall, req=req)
        if self._slots[i].remaining == 0:         # single-token request
            self._finish(i, step, completions)
        return 1

    # ------------------------------------------------------------- ticks
    def decode_tick(self, step: int,
                    completions: Dict[int, Completion]) -> Dict[str, Any]:
        """One vmapped decode step across the pool; appends a token to
        every active slot, evicts finished sequences.  Returns per-tick
        metrics (``active`` = 0 means the pool was idle: no decode ran)."""
        active = self.active_slots()
        if not active:
            return {"active": 0, "dt": 0.0, "key": None, "tokens": 0}
        key = self._decode_key()
        fn = self._decode.get(key)
        t0 = time.perf_counter()
        if self.scfg.failover == RESIDENT:
            logits, self._caches = fn(self.params, self._caches, self._toks,
                                      self._tvec, self.health_mask())
        else:
            logits, self._caches = fn(self.params, self._caches, self._toks,
                                      self._tvec)
        nxt = jnp.argmax(logits[:, 0, -1], -1).astype(jnp.int32)      # (S,)
        nxt.block_until_ready()
        dt = time.perf_counter() - t0
        metrics.observe("serve_decode_tick_seconds", dt)
        self._toks = nxt[:, None, None]
        S = self.scfg.max_slots
        active_mask = np.zeros((S,), np.int32)
        active_mask[active] = 1
        self._tvec = self._tvec + jnp.asarray(active_mask)
        nxt_np = np.asarray(nxt)
        for i in active:
            sl = self._slots[i]
            sl.out.append(int(nxt_np[i]))
            sl.remaining -= 1
            if sl.remaining == 0:                 # evict finished
                self._finish(i, step, completions)
        return {"active": len(active), "dt": dt, "key": key,
                "tokens": len(active)}

    # -------------------------------------------------------------- run
    def session(self) -> "EngineSession":
        """Open a streaming serve session (resets the slot pool).  The
        common front door for open-loop traffic: ``submit`` requests at
        any time, ``step`` one engine tick, ``poll`` finished
        completions, ``close`` for the final stats."""
        return EngineSession(self)

    def serve(self, requests: Sequence[Request], *,
              fault_at_step: Optional[Tuple[int, str]] = None
              ) -> Tuple[Dict[int, Completion], Dict[str, Any]]:
        """Run a workload to completion (closed-loop wrapper over the
        streaming session API — completions are bit-identical to driving
        ``session()`` by hand).

        ``fault_at_step=(k, stage)`` quarantines ``stage`` just before
        engine step ``k`` (admissions and the decode tick at ``k`` already
        run rerouted).  Returns ({rid: Completion}, stats).
        """
        self._validate(requests)
        sess = self.session()
        for r in sorted(requests, key=lambda r: (r.arrival, r.rid)):
            sess.submit(r, _validated=True)
        while sess.pending():
            if fault_at_step is not None and \
                    sess.step_count == fault_at_step[0]:
                self.inject_fault(fault_at_step[1])
            sess.step()
        stats = sess.close()
        return {c.rid: c for c in sess.poll()}, stats

    # ------------------------------------------------- fixed-batch compat
    def generate(self, prompts, n_new: int, *,
                 fault_at_step: Optional[Tuple[int, str]] = None
                 ) -> Tuple[np.ndarray, Dict[str, Any]]:
        """Fixed-batch convenience wrapper: every row of ``prompts`` (B, P)
        arrives at step 0 and decodes ``n_new`` tokens; returns (B, n_new)
        greedy tokens (row i = prompt i).  ``fault_at_step`` indexes decode
        steps, as in the pre-continuous engine."""
        prompts = np.asarray(prompts)
        B = prompts.shape[0]
        if B > self.scfg.max_slots:
            raise ValueError(f"batch {B} exceeds max_slots "
                             f"{self.scfg.max_slots}")
        reqs = [Request(rid=i, prompt=prompts[i], max_new_tokens=n_new)
                for i in range(B)]
        completions, stats = self.serve(reqs, fault_at_step=fault_at_step)
        toks = np.stack([completions[i].tokens for i in range(B)])
        return toks, stats


# ==========================================================================
# Fleet layer (paper §II Fig. 2, §V Fig. 8)
# ==========================================================================
@dataclass
class FleetConfig:
    """Fleet shape + degradation policy for ``FleetServeEngine``.

    ``degradation[k]`` is the relative capacity of a device carrying ``k``
    fallback-routed stages (the paper's VFA throughput curve); ``None``
    keeps every serving device at full slot capacity.  Capacity is
    quantized to whole slots (``capacity_for``) — the fleet harness uses
    the same quantization on the analytic side, so measured-vs-analytic
    comparisons are slot-exact.

    ``topology`` partitions the devices across hosts (multi-host mode):
    with ``topology.host_id`` set, this process executes only its own
    device block and shadows the rest; ``host_id=None`` keeps everything
    local while still enabling host-indexed events (single-process
    emulation, the benches' ``--hosts`` mode).

    ``model`` upgrades the scalar curve to a ``DegradationModel``: a
    device whose plan routes stages through the DEGRADED family is
    charged those stages' per-rung partial factors instead of full curve
    steps (pass the device's RoutingPlan to ``capacity_for``)."""

    n_devices: int = 2
    n_spares: int = 0
    degradation: Optional[Sequence[float]] = None
    topology: Optional[HostTopology] = None
    model: Optional[DegradationModel] = None

    def capacity_for(self, n_faults: int, max_slots: int,
                     plan: Optional[RoutingPlan] = None) -> int:
        if self.model is not None:
            rungs = (DegradationModel.rungs_of(plan)
                     if plan is not None else ())
            return max(0, int(self.model.slot_cap(max_slots, n_faults,
                                                  rungs)))
        if self.degradation is None:
            return max_slots
        deg = list(self.degradation)
        f = deg[min(n_faults, len(deg) - 1)]
        return max(0, int(round(max_slots * f)))


class _ShadowWorker(_SlotPool):
    """Bookkeeping twin of a remote host's ``ServeEngine`` slot pool.

    Replays the value-independent half of the pool — admission into the
    lowest free slot, one budget decrement per tick, eviction at zero —
    so this host's scheduler stays in lockstep with the host actually
    decoding.  Completions it emits are placeholders (no tokens);
    ``merge_completions`` resolves them against the owning host."""

    placeholder = True

    def __init__(self, scfg: ServeConfig):
        self.scfg = scfg
        self.fault_state = FaultState()
        self.reset_pool()

    def reset_pool(self):
        self._init_pool()

    def admit(self, req: Request, step: int, eligible_wall: float,
              completions: Dict[int, Completion]) -> int:
        i = next(idx for idx, sl in enumerate(self._slots) if sl is None)
        self._slots[i] = _Slot(rid=req.rid, prompt_len=len(req.prompt),
                               arrival=req.arrival,
                               remaining=req.max_new_tokens - 1,
                               out=[0], admitted_step=step,
                               eligible_wall=eligible_wall, req=req)
        if self._slots[i].remaining == 0:         # single-token request
            self._finish(i, step, completions)
        return 1

    def decode_tick(self, step: int,
                    completions: Dict[int, Completion]) -> Dict[str, Any]:
        active = self.active_slots()
        if not active:
            return {"active": 0, "dt": 0.0, "key": None, "tokens": 0}
        for i in active:
            sl = self._slots[i]
            sl.out.append(0)         # keeps drain_excess age order exact
            sl.remaining -= 1
            if sl.remaining == 0:
                self._finish(i, step, completions)
        return {"active": len(active), "dt": 0.0, "key": None,
                "tokens": len(active)}


def merge_completions(coordinator, completions: Dict[int, Completion]
                      ) -> Dict[int, Completion]:
    """All-to-all exchange of locally decoded completions: every host
    publishes its real (non-placeholder) completions and resolves its
    placeholders against the owning hosts'.  Loud error if any request
    ends up with no real tokens anywhere — a dropped request can never
    masquerade as a merge artifact."""
    local = [[c.rid, np.asarray(c.tokens).tolist(), c.prompt_len,
              c.arrival, c.admitted_step, c.finished_step, c.latency_s,
              c.device, c.queue_wait_s, c.ttft_s, c.deadline,
              c.deadline_met, c.expired]
             for c in completions.values() if not c.placeholder]
    payloads = coordinator.exchange(json.dumps(local))
    merged = dict(completions)
    for host, payload in enumerate(payloads):
        if host == coordinator.host_id or payload is None:
            continue             # None: a peer marked dead mid-run
        for rid, toks, plen, arr, astep, fstep, lat, dev, qw, ttft, \
                dl, dmet, exp in json.loads(payload):
            merged[rid] = Completion(
                rid=rid, tokens=np.asarray(toks, np.int32),
                prompt_len=plen, arrival=arr, admitted_step=astep,
                finished_step=fstep, latency_s=lat, device=dev,
                queue_wait_s=qw, ttft_s=ttft, deadline=dl,
                deadline_met=dmet, expired=exp)
    unresolved = sorted(r for r, c in merged.items() if c.placeholder)
    if unresolved:
        raise RuntimeError(f"no host decoded request(s) {unresolved}: "
                           "the fleet schedules desynced across hosts")
    return merged


class FleetServeEngine:
    """Device-indexed serve fleet: one slot pool per device, all consulting
    a shared ``FleetPlan``.

    Admission scans the serving devices in index order and places the
    queue head on the first device with free capacity; a quarantined
    device's pool drains and its requests re-admit (on its hot spare when
    the pool has one — Fig. 8 — otherwise on whatever capacity survives).
    The per-device pools share one Dispatcher pair, so devices with equal
    RoutingPlans share compiled executables.
    """

    def __init__(self, cfg: ModelConfig, params, scfg: ServeConfig,
                 fcfg: FleetConfig, *, coordinator=None, classifier=None,
                 watchdog=None):
        if fcfg.n_devices < 1:
            raise ValueError(f"fleet needs >= 1 device, got {fcfg.n_devices}")
        self.cfg = cfg
        self.params = params
        self.scfg = scfg
        self.fcfg = fcfg
        self.classifier = classifier   # core.fault.FaultClassifier | None
        self.watchdog = watchdog       # core.fault.StragglerWatchdog | None
        self._suspected: set = set()   # devices under watchdog suspicion
        self._pending_suspects: List[Tuple] = []   # resolve next step
        self.topology = fcfg.topology
        if self.topology is not None and \
                self.topology.n_devices != fcfg.n_devices:
            raise ValueError(
                f"topology covers {self.topology.n_devices} device(s), "
                f"fleet has {fcfg.n_devices}")
        self.coordinator = coordinator
        self.channel: Optional[EventChannel] = None
        if coordinator is not None and coordinator.num_hosts > 1:
            if self.topology is None or self.topology.host_id is None:
                raise ValueError("a multi-host coordinator needs "
                                 "FleetConfig.topology with host_id set")
            if coordinator.host_id != self.topology.host_id:
                raise ValueError(
                    f"coordinator is host {coordinator.host_id} but the "
                    f"topology claims host {self.topology.host_id}")
            self.channel = EventChannel(coordinator)
        self.stage_names = model_stage_names(cfg)
        self.fleet = FleetPlan.healthy(fcfg.n_devices, self.stage_names,
                                       target=scfg.hw_route,
                                       n_spares=fcfg.n_spares)
        # Real slot pools for this host's device block, bookkeeping
        # shadows for everyone else's (single-host: everything is real).
        self.workers: List[_SlotPool] = []
        shared: Optional[Tuple[Dispatcher, Dispatcher]] = None
        template: Optional[ServeEngine] = None
        for d in range(fcfg.n_devices):
            if self.topology is None or self.topology.is_local(d):
                w = ServeEngine(cfg, params, scfg, dispatchers=shared,
                                template=template)
                if shared is None:
                    shared = (w._prefill, w._decode)
                if template is None:
                    template = w
            else:
                w = _ShadowWorker(scfg)
            w.device_index = d
            self.workers.append(w)
        self._prefill, self._decode = shared if shared else (None, None)
        self.event_log: List[dict] = []
        self._sync_capacity()

    # ----------------------------------------------------- fleet health
    def _sync_capacity(self):
        serving = set(self.fleet.serving())
        for d, w in enumerate(self.workers):
            if d in serving:
                w.capacity = self.fcfg.capacity_for(
                    self.fleet.n_faults(d), self.scfg.max_slots,
                    plan=self.fleet.plans[d])
            else:
                w.capacity = 0
        for rung, n in rung_occupancy(self.fleet).items():
            metrics.set_gauge("fleet_rung_devices", n, rung=rung)

    def _apply(self, event: Tuple, step: int, *,
               strict: bool = True) -> List[Request]:
        """Apply one fault event to the FleetPlan; returns requests drained
        from newly-quarantined devices (for re-admission).

        ``strict=False`` (merged multi-host logs) tolerates transitions
        that no longer apply — two hosts reporting the same device fault
        must converge, not desync — recording them as dropped."""
        kind, device = event[0], event[1]
        if kind not in ("stage", "device", "host", "recover"):
            raise ValueError(f"unknown fleet event kind {kind!r}")
        if kind == "stage" and event[2] not in self.stage_names:
            raise ValueError(f"unknown stage {event[2]!r}; this model's "
                             f"stages: {self.stage_names}")
        if kind == "host" and self.topology is None:
            raise ValueError("host events need FleetConfig.topology")
        before = set(self.fleet.quarantined)
        try:
            if kind == "stage":
                self.fleet = self.fleet.with_stage_fault(device, event[2])
                self.workers[device].fault_state.mark(event[2], 0,
                                                      kind="injected")
            elif kind == "device":
                self.fleet = self.fleet.with_device_fault(device)
            elif kind == "host":
                self.fleet = self.fleet.with_host_fault(
                    self.topology.devices_of(device))
            else:                    # recover
                spare = self.fleet.pool.spare_for(device)
                stage = event[2] if len(event) > 2 else ""
                if stage:
                    # Stage-scoped (probation verdict: transient) — undo
                    # exactly one rung; other faults on the device stay.
                    self.fleet = self.fleet.with_stage_recovery(
                        device, stage, target=self.scfg.hw_route)
                    self.workers[device].fault_state.clear(stage, 0,
                                                           step=step)
                else:                # full repair: fresh hardware
                    self.fleet = self.fleet.with_recovery(
                        device, self.stage_names, target=self.scfg.hw_route)
                    self.workers[device].fault_state = FaultState()
                self._suspected.discard(device)
                if spare is not None and \
                        device not in self.fleet.quarantined:
                    # spare returns to the idle pool; its slots re-admit
                    drained = self.workers[spare].drain()  # on the
                    self.event_log.append({"step": step, "event": event,
                                           "drained": len(drained)})
                    self._sync_capacity()  # recovered device
                    return drained
        except ValueError:
            if strict:
                raise
            self.event_log.append({"step": step, "event": event,
                                   "dropped": True})
            return []
        newly_gone = set(self.fleet.quarantined) - before
        drained: List[Request] = []
        for d in sorted(newly_gone):
            drained.extend(self.workers[d].drain())
        self.event_log.append({"step": step, "event": event,
                               "drained": len(drained)})
        obs_trace.emit(step, name=f"fleet:{kind}", device=device,
                       stage=event[2] if kind in ("stage", "recover")
                       and len(event) > 2 else "",
                       drained=len(drained))
        self._sync_capacity()
        return drained

    # ---------------------------------------------- probation & watchdog
    def _probe(self, device: int, stage: str, step: int) -> List[Tuple]:
        """Probate one detection into the event tuples every host folds.
        Transient -> the ("stage", d, s) / ("recover", d, s) pair: the
        rung down AND back up both ride the ordered log, so probation
        state agrees fleet-wide.  Persistent -> the fault alone, and the
        ladder walks exactly as before.  Without a classifier every
        detection is persistent (the pre-probation behavior)."""
        if self.classifier is None:
            return [("stage", device, stage)]
        res = self.classifier.classify(
            stage, replica=device, step=step,
            state=self.workers[device].fault_state)
        if res.transient:
            return [("stage", device, stage), ("recover", device, stage)]
        return [("stage", device, stage)]

    def _resolve_suspect(self, device: int, step: int) -> List[Tuple]:
        """A watchdog suspicion names a device, not a stage: canary every
        stage there and probate the failing ones.  An all-clean suspicion
        (transient straggle — contention, GC pause) clears with a log
        entry and no routing change."""
        out: List[Tuple] = []
        if self.classifier is not None:
            for s in self.classifier.checker.stages:
                if not self.classifier.checker.check_stage(s):
                    out.extend(self._probe(device, s.name, step))
        if not out:
            self.workers[device].fault_state.note(
                "<watchdog>", device, kind="suspected_cleared", step=step)
        self._suspected.discard(device)
        return out

    def _watchdog_tick(self, device: int, tick: Mapping, step: int):
        """Feed one real decode tick to the straggler watchdog; newly
        flagged devices get a ``suspected`` fault-log entry and a pending
        suspect event the next session step resolves through the
        classifier."""
        wd = self.watchdog
        if wd is None or not tick["active"]:
            return
        if self.workers[device].placeholder:
            return                   # shadows don't decode: dt is fake
        wd.record(device, tick["dt"])
        for d in wd.stragglers():
            if d in self._suspected:
                continue
            self._suspected.add(d)
            self.workers[d].fault_state.note(
                "<watchdog>", d, kind="suspected", step=step)
            self._pending_suspects.append(("suspect", d))

    # convenience wrappers (usable between serve() calls or via events)
    def inject_stage_fault(self, device: int, stage: str):
        return self._apply(("stage", device, stage), step=-1)

    def inject_device_fault(self, device: int):
        return self._apply(("device", device), step=-1)

    def recover(self, device: int):
        return self._apply(("recover", device), step=-1)

    # -------------------------------------------------------------- run
    def session(self) -> "FleetSession":
        """Open a streaming serve session across the fleet (resets every
        slot pool).  Same submit/step/poll/close surface as the
        single-device ``ServeEngine.session`` — ``step`` additionally
        takes this step's fault events."""
        return FleetSession(self)

    def serve(self, requests: Sequence[Request], *,
              events: Optional[Mapping[int, Sequence[Tuple]]] = None
              ) -> Tuple[Dict[int, Completion], Dict[str, Any]]:
        """Run a workload to completion across the fleet (closed-loop
        wrapper over the streaming session API — completions are
        bit-identical to driving ``session()`` by hand).

        ``events[k]`` is a list of fault events applied just before engine
        step ``k``: ``("stage", device, stage_name)``,
        ``("device", device)``, ``("host", host)``, or
        ``("recover", device)``.  No request is ever dropped: draining
        re-queues at the front, and completions are bit-identical to the
        healthy single-device reference (greedy decode + Viscosity
        equivalence).

        With a multi-host coordinator, ``events`` holds only this host's
        *locally observed* events; each step every host publishes its
        slice through the shared event log and applies the canonical
        merged order, so all hosts fold the same transitions over the
        same FleetPlan.  Completions are merged across hosts before
        returning.
        """
        validate_requests(requests, self.scfg.max_len)
        events = dict(events or {})
        sess = self.session()
        for r in sorted(requests, key=lambda r: (r.arrival, r.rid)):
            sess.submit(r, _validated=True)
        while sess.pending():
            sess.step(events.pop(sess.step_count, ()))
        stats = sess.close(late_events=events)
        return {c.rid: c for c in sess.poll()}, stats


# ==========================================================================
# Streaming session API (the one serve front door; ROADMAP "open-loop
# traffic").  ``ServeEngine.serve`` / ``ServeEngine.generate`` /
# ``FleetServeEngine.serve`` are thin closed-loop wrappers over these.
# ==========================================================================
class ServeSession:
    """Streaming serve session: ``submit`` requests at any time (open-loop
    admission), ``step`` the engine one tick, ``poll`` completions
    finished since the last poll, ``close`` for the final stats.

    Built entirely on the value-independent ``_SlotPool`` primitives, so
    one session implementation serves both the single-device engine and
    the fleet (and the fleet's multi-host deterministic replication keeps
    working: scheduling never depends on token values or wall time).
    ``cancel`` is deadline-expiry eviction — it frees a queued or
    in-flight request immediately, emitting an expired Completion with
    whatever tokens were already decoded.
    """

    def __init__(self, engine):
        self.engine = engine
        self.scfg = engine.scfg
        self._queue: collections.deque = collections.deque()
        self._rids: set = set()
        self._eligible_wall: Dict[int, float] = {}
        self._completions: Dict[int, Completion] = {}
        self._delivered: set = set()
        self.step_count = 0
        self.closed = False
        self.stats: Dict[str, Any] = {}

    # -------------------------------------------------------- admission
    def submit(self, req: Request, *, _validated: bool = False) -> None:
        """Queue one request.  ``req.arrival`` is the earliest engine
        step it may be admitted; requests submitted mid-session join the
        live queue (open-loop traffic).  Admission from the queue is
        FIFO in submission order once arrivals gate open — an SLO-aware
        caller (``serve.frontend.Frontend``) orders its submissions."""
        if self.closed:
            raise RuntimeError("session is closed")
        if not _validated:
            validate_requests([req], self.scfg.max_len)
        if req.rid in self._rids:
            raise ValueError(f"request {req.rid}: duplicate request id "
                             f"(field 'rid') in this session")
        self._rids.add(req.rid)
        self._queue.append(req)

    def pending(self) -> bool:
        """True while any submitted request is queued or in flight."""
        return bool(self._queue) or self._occupancy() > 0

    def poll(self) -> List[Completion]:
        """Completions finished since the last poll (ascending rid)."""
        out = [c for r, c in sorted(self._completions.items())
               if r not in self._delivered]
        self._delivered.update(c.rid for c in out)
        return out

    def cancel(self, rid: int) -> bool:
        """Deadline-expiry eviction: abort a queued or in-flight request,
        freeing its slot for work that can still meet its SLO.  Emits an
        expired Completion (partial tokens if it was decoding).  Returns
        False when ``rid`` is not live in this session."""
        for i, r in enumerate(self._queue):
            if r.rid == rid:
                del self._queue[i]
                now = time.perf_counter()
                self._completions[rid] = Completion(
                    rid=rid, tokens=np.asarray((), np.int32),
                    prompt_len=len(r.prompt), arrival=r.arrival,
                    admitted_step=-1, finished_step=self.step_count,
                    latency_s=now - self._eligible_wall.get(rid, now),
                    deadline=r.deadline, deadline_met=False, expired=True)
                return True
        return self._evict(rid)

    # hooks ------------------------------------------------------------
    def _occupancy(self) -> int:
        raise NotImplementedError

    def _evict(self, rid: int) -> bool:
        raise NotImplementedError

    def free_slots(self) -> int:
        raise NotImplementedError

    def _mark_eligible(self, now: float):
        for r in self._queue:
            if r.arrival <= self.step_count and \
                    r.rid not in self._eligible_wall:
                self._eligible_wall[r.rid] = now


class EngineSession(ServeSession):
    """Streaming session over one ``ServeEngine`` slot pool."""

    def __init__(self, engine: "ServeEngine"):
        super().__init__(engine)
        engine.reset_pool()
        self._decode_keys: set = set()
        self._prefill0 = engine._prefill.compiles
        self.stats = {"step_times": [], "occupancy": [],
                      "admitted": 0, "steps": 0}

    def _occupancy(self) -> int:
        return self.engine.occupancy()

    def free_slots(self) -> int:
        return self.engine.free_slots()

    def _evict(self, rid: int) -> bool:
        return self.engine.evict_rid(rid, self.step_count,
                                     self._completions)

    def step(self, events: Sequence[Tuple] = ()) -> Dict[str, Any]:
        """One engine step: admit arrived requests into free slots, then
        one vmapped decode tick.  Returns the tick metrics (``active`` =
        0 means the pool idled waiting on future arrivals)."""
        if events:
            raise ValueError("single-engine sessions take no fleet "
                             "events; use ServeEngine.inject_fault (or "
                             "serve's fault_at_step)")
        eng, step = self.engine, self.step_count
        now = time.perf_counter()
        self._mark_eligible(now)
        # admission: arrived requests claim free slots (join)
        while (eng.has_free_slot() and self._queue
               and self._queue[0].arrival <= step):
            req = self._queue.popleft()
            eng.admit(req, step, self._eligible_wall.get(req.rid, now),
                      self._completions)
            self.stats["admitted"] += 1
        tick = eng.decode_tick(step, self._completions)
        self.step_count += 1
        if tick["active"]:
            self._decode_keys.add(tick["key"])
            self.stats["step_times"].append(tick["dt"])
            self.stats["occupancy"].append(tick["active"])
        return tick

    def close(self) -> Dict[str, Any]:
        if self.closed:
            return self.stats
        self.closed = True
        eng, s = self.engine, self.stats
        s["steps"] = self.step_count
        s["recompiles"] = max(0, len(self._decode_keys) - 1)
        s["decode_compiles"] = eng._decode.compiles
        s["prefill_compiles"] = eng._prefill.compiles - self._prefill0
        return s


class FleetSession(ServeSession):
    """Streaming session across a ``FleetServeEngine``'s per-device slot
    pools.  ``step(events)`` additionally folds this step's fault events
    (and, multi-host, the canonical merged event log) before admission —
    drained requests from newly-quarantined devices re-queue at the
    front, so no request is ever dropped."""

    def __init__(self, engine: "FleetServeEngine"):
        super().__init__(engine)
        for w in engine.workers:
            w.reset_pool()
        engine._sync_capacity()
        self._prefill0 = engine._prefill.compiles if engine._prefill else 0
        self._decode0 = engine._decode.compiles if engine._decode else 0
        self.stats = {"admitted": 0, "steps": 0, "requeued": 0,
                      "per_step_tokens": [], "occupancy": [], "capacity": [],
                      "per_device_tokens": [0] * engine.fcfg.n_devices}

    def _occupancy(self) -> int:
        return sum(w.occupancy() for w in self.engine.workers)

    def free_slots(self) -> int:
        return sum(self.engine.workers[d].free_slots()
                   for d in self.engine.fleet.serving())

    def _evict(self, rid: int) -> bool:
        for w in self.engine.workers:
            if w.evict_rid(rid, self.step_count, self._completions):
                return True
        return False

    def _exchange_guarded(self, exchange_fn, local_events: List[Tuple]):
        """Run one channel exchange, converting a peer's typed
        ``HostTimeoutError`` into a ``("host", host_id)`` event: the dead
        peer is marked on the coordinator (its future payload slots turn
        ``None``) and the exchange retries with the host-fault appended,
        so the survivors re-fold and keep serving instead of inheriting
        the hang.  Deterministic across survivors because the KV store is
        shared — a silent peer is silent for every reader.  Coordinators
        without ``mark_dead`` (or a fleet with no surviving peer) get the
        error raised through."""
        eng = self.engine
        for _ in range(max(1, eng.coordinator.num_hosts)):
            try:
                return exchange_fn()
            except HostTimeoutError as exc:
                if not hasattr(eng.coordinator, "mark_dead"):
                    raise
                eng.coordinator.mark_dead(exc.host_id)
                local_events.append(("host", exc.host_id))
                self.stats.setdefault("host_timeouts", []).append(
                    {"step": self.step_count, "host": exc.host_id})
        raise HostTimeoutError(
            eng.coordinator.host_id,
            "every peer exhausted its retry budget; no fleet left to "
            "agree with")

    def step(self, events: Sequence[Tuple] = ()) -> Dict[str, Any]:
        """One fleet step: fold fault events, drain/re-queue, admit
        across the serving devices' pools, one decode tick per device."""
        eng, step = self.engine, self.step_count
        s = self.stats
        step_tokens = 0
        # ("suspect", device[, stage]) tuples — watchdog suspicions from
        # the previous tick plus any caller-injected ones — resolve
        # through the probation classifier BEFORE the exchange: only the
        # verdict (the fault / fault+recover pair) enters the shared log.
        pend, eng._pending_suspects = eng._pending_suspects, []
        step_events: List[Tuple] = []
        for ev in list(pend) + list(events):
            if ev and ev[0] == "suspect":
                d = int(ev[1])
                if len(ev) > 2 and ev[2]:
                    step_events.extend(eng._probe(d, ev[2], step))
                else:
                    step_events.extend(eng._resolve_suspect(d, step))
            else:
                step_events.append(tuple(ev))
        if eng.channel is not None:
            # one shared ordered log: publish the locally observed
            # slice, apply the canonical merge — every host folds the
            # same transitions in the same order
            local = list(step_events)
            merged = self._exchange_guarded(
                lambda: eng.channel.exchange(step, list(local)), local)
            step_events = [e.engine_tuple() for e in merged]
        drained: List[Request] = []
        for ev in step_events:
            drained.extend(eng._apply(ev, step,
                                      strict=eng.channel is None))
        if step_events:
            # degradation shrank some pools: drain the overflow too,
            # so capacity changes take effect this step, not after the
            # old residents happen to finish
            for d in eng.fleet.serving():
                drained.extend(eng.workers[d].drain_excess())
        if drained:
            s["requeued"] += len(drained)
            self._queue.extendleft(sorted(drained,
                                          key=lambda r: (r.arrival, r.rid),
                                          reverse=True))
        now = time.perf_counter()
        self._mark_eligible(now)
        # admission: queue head goes to the first device with capacity
        serving = eng.fleet.serving()
        for d in serving:
            w = eng.workers[d]
            while (w.has_free_slot() and self._queue
                   and self._queue[0].arrival <= step):
                req = self._queue.popleft()
                step_tokens += w.admit(
                    req, step, self._eligible_wall.get(req.rid, now),
                    self._completions)
                s["admitted"] += 1
                s["per_device_tokens"][d] += 1
        occupancy = 0
        for d in serving:
            tick = eng.workers[d].decode_tick(step, self._completions)
            eng._watchdog_tick(d, tick, step)
            occupancy += tick["active"]
            step_tokens += tick["tokens"]
            s["per_device_tokens"][d] += tick["tokens"]
        s["per_step_tokens"].append(step_tokens)
        s["occupancy"].append(occupancy)
        s["capacity"].append(sum(eng.workers[d].capacity for d in serving))
        self.step_count += 1
        if self.step_count > 100_000:
            raise RuntimeError("fleet serve did not converge (queue "
                               f"{len(self._queue)}, occupancy "
                               f"{occupancy})")
        return {"active": occupancy, "dt": 0.0, "key": None,
                "tokens": step_tokens}

    def close(self, *, late_events: Optional[Mapping[int, Sequence[Tuple]]]
              = None) -> Dict[str, Any]:
        """Finalize: apply events scheduled past the drain point (a
        recovery at step 40 must not be silently lost because the
        workload finished at 35), then — multi-host — merge completions
        across hosts.  Poll *after* close in multi-host mode, so
        placeholders are resolved."""
        if self.closed:
            return self.stats
        self.closed = True
        eng, s = self.engine, self.stats
        late_events = dict(late_events or {})
        if eng.channel is not None:
            extra: List[Tuple] = []

            def _do():
                ev_map = {k: list(v) for k, v in late_events.items()}
                if extra:
                    ev_map[self.step_count] = (
                        list(ev_map.get(self.step_count, ())) + list(extra))
                return eng.channel.exchange_many(ev_map)

            late = self._exchange_guarded(_do, extra)
            for e in late:
                eng._apply(e.engine_tuple(), step=e.step, strict=False)
            s["late_events"] = len(late)
        else:
            for k in sorted(late_events):
                for ev in late_events[k]:
                    eng._apply(ev, step=k)
            s["late_events"] = sum(len(v) for v in late_events.values())
        s["steps"] = self.step_count
        s["decode_compiles"] = (eng._decode.compiles - self._decode0
                                if eng._decode else 0)
        s["prefill_compiles"] = (eng._prefill.compiles - self._prefill0
                                 if eng._prefill else 0)
        s["quarantined"] = list(eng.fleet.quarantined)
        s["spares_in_service"] = list(eng.fleet.pool.in_service())
        if eng.channel is not None:
            # merged result + cross-host plan agreement witness
            s["fleet_fingerprint"] = fleet_fingerprint(eng.fleet)
            ph = {r for r, c in self._completions.items()
                  if c.placeholder}
            self._completions = merge_completions(eng.coordinator,
                                                  self._completions)
            # placeholders polled mid-run re-deliver resolved: a
            # streaming caller's post-close poll() gets the real tokens
            self._delivered -= ph
        else:
            # host-partitioned but uncoordinated (shadow-bookkeeping
            # mode): remote completions are placeholders with no tokens.
            # Legitimate for schedule tests — but never silent, so a
            # forgotten coordinator cannot read as empty decodes.
            unresolved = sorted(r for r, c in self._completions.items()
                                if c.placeholder)
            s["unresolved_placeholders"] = unresolved
            if unresolved:
                warnings.warn(
                    f"FleetServeEngine returned {len(unresolved)} "
                    "placeholder completion(s) decoded on remote shadow "
                    "devices; pass a coordinator to merge real tokens "
                    "across hosts", stacklevel=2)
        return s


def percentile(xs: Sequence[float], q: float) -> float:
    """Nearest-rank percentile (one convention for every latency report)."""
    xs = sorted(xs)
    return xs[min(len(xs) - 1, int(len(xs) * q))]


def synthetic_workload(vocab_size: int, n_requests: int, rng, *,
                       min_prompt: int = 4, max_prompt: int = 20,
                       min_new: int = 3, max_new: int = 10,
                       arrival_every: int = 2, per_arrival: int = 1
                       ) -> List[Request]:
    """Compatibility shim: the workload builders live in
    ``repro.serve.traffic`` now (this staggered closed-loop shape is
    ``ClosedLoop``).  Kept so old import paths and call sites produce
    bit-identical request lists.  Imported lazily — traffic.py imports
    Request from this module."""
    from repro.serve.traffic import synthetic_workload as _sw
    return _sw(vocab_size, n_requests, rng, min_prompt=min_prompt,
               max_prompt=max_prompt, min_new=min_new, max_new=max_new,
               arrival_every=arrival_every, per_arrival=per_arrival)


def reference_decode(cfg: ModelConfig, params, prompt, n_new: int, *,
                     max_len: int, routes: Optional[RoutingPlan] = None
                     ) -> np.ndarray:
    """Single-request greedy decode straight on the model — no slots, no
    vmap, no engine.  The per-request oracle the batching engine must match
    bit-for-bit (used by tests and serve_bench)."""
    model = build_model(cfg, routes=routes)
    prompt = jnp.asarray(prompt, jnp.int32)[None]
    P = prompt.shape[1]
    cache = model.init_cache(1, max_len)
    logits, state = jax.jit(model.prefill)(
        params, {"tokens": prompt, "cache": cache})
    tok = jnp.argmax(logits[:, -1], -1).astype(jnp.int32)[:, None]
    out = [int(tok[0, 0])]
    step_fn = jax.jit(model.decode_step)
    for i in range(n_new - 1):
        logits, state = step_fn(params, state, tok, jnp.int32(P + i))
        tok = jnp.argmax(logits[:, -1], -1).astype(jnp.int32)[:, None]
        out.append(int(tok[0, 0]))
    return np.asarray(out, np.int32)
