"""Batched serving engine with per-stage fault failover.

Prefill + greedy decode over a fixed request batch; both executables are
signature-keyed through the Dispatcher (a detected fault reroutes the
faulty stage and recompiles — the serving analogue of the paper's queue
reconfiguration; decoded tokens are bit-identical across routings because
the lowerings are Viscosity-equivalent, which the tests assert).
"""
from __future__ import annotations

import time
from dataclasses import dataclass
from typing import Any, Callable, Dict, List, Optional, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs.base import ModelConfig
from repro.core.fault import FaultSignature, FaultState
from repro.core.oobleck import Dispatcher
from repro.models import build_model
from repro.train.runner import model_stage_names
from repro.viscosity import SW


@dataclass
class ServeConfig:
    max_len: int = 256
    hw_route: str = "sw"


class ServeEngine:
    def __init__(self, cfg: ModelConfig, params, scfg: ServeConfig):
        self.cfg = cfg
        self.params = params
        self.scfg = scfg
        self.fault_state = FaultState()
        self.stage_names = model_stage_names(cfg)
        self._prefill = Dispatcher(self._build_prefill)
        self._decode = Dispatcher(self._build_decode)

    def _routes(self, signature: FaultSignature) -> Dict[str, str]:
        return {s: (self.scfg.hw_route if r == "hw" else SW)
                for s, r in signature.routes}

    def _model(self, signature):
        return build_model(self.cfg, routes=self._routes(signature))

    def _build_prefill(self, signature) -> Callable:
        model = self._model(signature)
        return jax.jit(model.prefill)

    def _build_decode(self, signature) -> Callable:
        model = self._model(signature)
        return jax.jit(model.decode_step, donate_argnums=(1,))

    def signature(self) -> FaultSignature:
        return self.fault_state.signature(self.stage_names)

    def inject_fault(self, stage: str):
        self.fault_state.mark(stage, 0, kind="injected")

    def generate(self, prompts: jax.Array, n_new: int,
                 *, fault_at_step: Optional[Tuple[int, str]] = None
                 ) -> Tuple[np.ndarray, Dict[str, Any]]:
        """Greedy decode. prompts (B, P) int32. Returns (B, n_new) tokens."""
        B, P = prompts.shape
        model = self._model(self.signature())
        cache = model.init_cache(B, self.scfg.max_len)
        logits, state = self._prefill.get(self.signature())(
            self.params, {"tokens": prompts, "cache": cache})
        out = []
        tok = jnp.argmax(logits[:, -1], -1).astype(jnp.int32)[:, None]
        stats = {"step_times": [], "recompiles": 0}
        for i in range(n_new):
            out.append(np.asarray(tok))
            if fault_at_step and i == fault_at_step[0]:
                self.inject_fault(fault_at_step[1])
            t0 = time.perf_counter()
            logits, state = self._decode.get(self.signature())(
                self.params, state, tok, jnp.int32(P + i))
            logits.block_until_ready()
            stats["step_times"].append(time.perf_counter() - t0)
            tok = jnp.argmax(logits[:, -1], -1).astype(jnp.int32)[:, None]
        stats["recompiles"] = self._decode.compiles - 1
        return np.concatenate(out, axis=1), stats
