"""Value-level lane faults and the DEGRADED route family (paper §III-A).

The binary routing story (healthy Pallas kernel vs full SW oracle) treats a
faulted sub-accelerator as all-or-nothing.  The related work does better:
permanent-fault systolic arrays remap around dead MAC columns (arxiv
1802.04657) and RedMulE-FT reconfigures redundancy on demand (arxiv
2504.14399).  This module is the TPU-native equivalent:

  * ``LaneFault`` describes a *value-level* defect on the lane (minor) axis
    of a kernel's output tile: a stuck-at lane, a dropped-MAC column
    (accumulates nothing -> 0), or a gain-skewed sublane.  It is
    deterministic and shape-aware — it only touches arrays whose lane axis
    matches its declared ``width``.
  * An **injection registry** (``inject``/``injection``): each kernel
    family's ``ops.py`` consults it on the HW path and threads the fault
    into the Pallas kernel body as a masked corruption of the output tile.
    With nothing registered the kernel body is untouched at trace time, so
    healthy paths compile identically.
  * A **lane-map registry** (``known_map``/``fault_map``): what detection
    has *localized*.  Routing consults it — ``FleetPlan.with_stage_fault``
    prefers a DEGRADED target over the SW oracle when a lane map is known,
    and ``RoutingPlan.validate`` rejects a DEGRADED target with no map.
  * The **DEGRADED lowerings** (``lower_degraded``), registered per stage
    through ``OpSpec.lower``:

      - ``DEGRADED_REMAP``: run the (corrupted) HW path at full width,
        recompute the dead lanes' outputs via the SW oracle and scatter
        them in — corruption confined to the mapped lanes is healed
        exactly, so completions stay bit-identical to an uninjected run
        under the same plan.
      - ``DEGRADED_REDUCED``: shrink the tile to the surviving lanes —
        ops that declare a ``lane_slicer`` run their kernel on a
        lane-sliced operand window (the Pallas kernels derive their
        output width from the sliced operand), dead lanes come from the
        oracle.  Ops without a slicer fall back to remap semantics
        (functionally identical; the capacity model still charges the
        reduced-width factor).

The injection and map registries are process-global and keyed by stage
name — they model *this host's* silicon.  Both are consulted at trace
time: a plan traced under injection stays corrupted (like the silicon it
emulates), and a degraded plan is one more Dispatcher compile key, not a
new mechanism.
"""
from __future__ import annotations

import contextlib
import functools
import operator
from dataclasses import dataclass
from typing import Callable, Dict, Mapping, Optional, Tuple

import jax
import jax.numpy as jnp

from repro.viscosity.lang import (DEGRADED_REDUCED, DEGRADED_REMAP,
                                  DEGRADED_TARGETS, HW, INTERPRET, SW)

# Fault kinds (the value-level defects a LaneFault can describe).
STUCK = "stuck"                # lane pinned to ``value``
DROPPED_MAC = "dropped_mac"    # dead MAC column: accumulates nothing -> 0
GAIN = "gain"                  # lane scaled by ``gain``
KINDS = (STUCK, DROPPED_MAC, GAIN)

# The degradation ladder: fault k on a lane-mapped stage lands on rung k.
RUNGS = (DEGRADED_REMAP, DEGRADED_REDUCED, SW)


@dataclass(frozen=True)
class LaneFault:
    """One value-level defect on the lane (minor) axis of a stage's output.

    ``width`` is the lane-axis width the map refers to; ``apply`` touches
    only arrays whose minor axis matches it, so the same descriptor threads
    safely through wrappers that see tensors of several shapes.  ``value``
    defaults to a *nonzero* stuck-at level: a stuck-at-zero lane over a
    zero activation is undetectable (the FaultInjector no-op bug class).
    """

    kind: str
    lanes: Tuple[int, ...]
    width: int
    value: float = 1.5
    gain: float = 1.25

    def __post_init__(self):
        if self.kind not in KINDS:
            raise ValueError(f"unknown lane-fault kind {self.kind!r}; "
                             f"expected one of {KINDS}")
        if self.width < 2:
            raise ValueError(f"lane width must be >= 2, got {self.width}")
        lanes = tuple(sorted(set(int(x) for x in self.lanes)))
        object.__setattr__(self, "lanes", lanes)
        if not lanes:
            raise ValueError("a LaneFault must name at least one lane")
        if lanes[0] < 0 or lanes[-1] >= self.width:
            raise ValueError(f"lanes {lanes} out of range for width "
                             f"{self.width}")
        if len(lanes) >= self.width:
            raise ValueError(f"all {self.width} lanes dead: that is a device "
                             "fault, not a lane fault")

    # ------------------------------------------------------------ queries
    def survivors(self) -> Tuple[int, ...]:
        dead = set(self.lanes)
        return tuple(i for i in range(self.width) if i not in dead)

    def lane_mask(self, x) -> jax.Array:
        """Boolean mask over ``x`` (True on faulted lanes of the minor
        axis).  Uses ``broadcasted_iota`` so it lowers inside Pallas
        kernel bodies too."""
        idx = jax.lax.broadcasted_iota(jnp.int32, x.shape, x.ndim - 1)
        return functools.reduce(operator.or_,
                                [idx == lane for lane in self.lanes])

    # ----------------------------------------------------------- corrupt
    def apply(self, x):
        """Masked corruption of ``x``'s minor axis; identity for arrays
        whose minor axis is not this fault's ``width`` (shape-aware)."""
        if not hasattr(x, "dtype") or not jnp.issubdtype(x.dtype,
                                                         jnp.inexact):
            return x
        if x.ndim < 1 or x.shape[-1] != self.width:
            return x
        mask = self.lane_mask(x)
        if self.kind == STUCK:
            return jnp.where(mask, jnp.asarray(self.value, x.dtype), x)
        if self.kind == DROPPED_MAC:
            return jnp.where(mask, jnp.zeros((), x.dtype), x)
        return jnp.where(mask, x * jnp.asarray(self.gain, x.dtype), x)

    def corrupt_tree(self, out):
        return jax.tree_util.tree_map(self.apply, out)


# ---------------------------------------------------------------- registry
# Two separate tables, because detection and physics are separate things:
#   _INJECT: the defect *active in the silicon* — kernels corrupt with it.
#   _MAPS:   the defect *detection has localized* — routing degrades with
#            it (fault, base-target the degraded lowering wraps).
_INJECT: Dict[str, LaneFault] = {}
_MAPS: Dict[str, Tuple[LaneFault, str]] = {}


def set_injection(stage: str, fault: LaneFault):
    _INJECT[stage] = fault


def clear_injection(stage: str):
    _INJECT.pop(stage, None)


def injection(stage: str) -> Optional[LaneFault]:
    """The fault actively corrupting ``stage``'s HW path (None = healthy).
    Consulted by the kernel wrappers at trace time."""
    return _INJECT.get(stage)


@contextlib.contextmanager
def inject(stage: str, fault: LaneFault):
    """Corrupt ``stage``'s HW path for the duration of the context.
    Trace-time: executables compiled inside stay corrupted (they model the
    silicon), executables compiled outside stay clean."""
    set_injection(stage, fault)
    try:
        yield fault
    finally:
        clear_injection(stage)


def set_map(stage: str, fault: LaneFault, base: str = HW):
    """Record a localized lane map for ``stage``.  ``base`` is the
    optimized target the DEGRADED lowerings wrap (HW on TPU, INTERPRET or
    SW on CPU hosts)."""
    if base not in (HW, SW, INTERPRET):
        raise ValueError(f"degraded base target must be one of "
                         f"{(HW, SW, INTERPRET)}, got {base!r}")
    _MAPS[stage] = (fault, base)


def clear_map(stage: str):
    _MAPS.pop(stage, None)


def fault_map(stage: str) -> Optional[LaneFault]:
    rec = _MAPS.get(stage)
    return rec[0] if rec else None


def map_base(stage: str) -> Optional[str]:
    rec = _MAPS.get(stage)
    return rec[1] if rec else None


@contextlib.contextmanager
def known_map(stage: str, fault: LaneFault, base: str = HW):
    set_map(stage, fault, base)
    try:
        yield fault
    finally:
        clear_map(stage)


def reset():
    """Drop every registered injection and lane map (test hygiene)."""
    _INJECT.clear()
    _MAPS.clear()


# ---------------------------------------------------------------- kernels
def apply_fault(x, fault: Optional[LaneFault]):
    """Kernel-side hook: masked corruption of one output tile.  Pure jnp
    (``broadcasted_iota`` + ``where``), so it lowers inside Pallas kernel
    bodies; a None fault is the healthy path — no ops are emitted and the
    compiled artifact is byte-identical to a build without injection."""
    if fault is None:
        return x
    return fault.apply(x)


# ----------------------------------------------------------------- ladder
def rung_for(n_faults: int) -> str:
    """Target for the ``n_faults``-th fault on a lane-mapped stage:
    remap -> reduced-width -> full SW oracle (and it stays there)."""
    if n_faults < 1:
        raise ValueError(f"rung_for needs >= 1 fault, got {n_faults}")
    return RUNGS[min(n_faults - 1, len(RUNGS) - 1)]


def degraded_plan(plan, counts: Mapping[str, int]):
    """Ladder a RoutingPlan by per-stage fault counts: stages with a known
    lane map take the count's rung; unmapped stages keep whatever binary
    fallback the plan already assigned them."""
    for stage, n in sorted(counts.items()):
        if n > 0 and fault_map(stage) is not None:
            plan = plan.with_target(stage, rung_for(n))
    return plan


# -------------------------------------------------------------- lowerings
def lower_degraded(spec, target: str) -> Callable:
    """Lower one OpSpec to a DEGRADED target using its registered lane map.

    remap:   out = scatter(oracle -> dead lanes, base HW path elsewhere)
    reduced: run the kernel on the surviving-lane operand window (via the
             op's ``lane_slicer``) and scatter into the oracle's dead-lane
             values; no slicer -> remap semantics.
    """
    if target not in DEGRADED_TARGETS:
        raise ValueError(f"{target!r} is not a DEGRADED target")
    rec = _MAPS.get(spec.name)
    if rec is None:
        raise ValueError(
            f"stage {spec.name!r} routed to {target!r} but no lane map is "
            "registered; detection must localize the fault first "
            "(lanefault.set_map / known_map)")
    fault, base = rec
    hw_fn = spec.lower(base)
    ref_fn = spec.ref

    def _scatter_full(hw_out, ref_out):
        def leaf(h, r):
            if (hasattr(h, "dtype") and jnp.issubdtype(h.dtype, jnp.inexact)
                    and h.ndim >= 1 and h.shape[-1] == fault.width):
                return jnp.where(fault.lane_mask(h), r.astype(h.dtype), h)
            return h
        return jax.tree_util.tree_map(leaf, hw_out, ref_out)

    def remap(*args, **kw):
        return _scatter_full(hw_fn(*args, **kw), ref_fn(*args, **kw))

    if target == DEGRADED_REMAP or getattr(spec, "lane_slicer", None) is None:
        return remap

    keep = fault.survivors()
    slicer = spec.lane_slicer

    def reduced(*args, **kw):
        nargs, nkw = slicer(args, dict(kw), keep)
        narrow = hw_fn(*nargs, **nkw)
        ref_out = ref_fn(*args, **kw)
        idx = jnp.asarray(keep, jnp.int32)

        def leaf(n, r):
            if (hasattr(r, "dtype") and jnp.issubdtype(r.dtype, jnp.inexact)
                    and r.ndim >= 1 and r.shape[-1] == fault.width
                    and n.shape[-1] == len(keep)):
                return r.at[..., idx].set(n.astype(r.dtype))
            return n
        return jax.tree_util.tree_map(leaf, narrow, ref_out)

    return reduced
