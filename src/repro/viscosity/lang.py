"""Viscosity: single-description, dual-lowering op layer (paper §III-B).

The paper's Viscosity ADL lowers one description of each sub-accelerator to
both Verilog (hardware) and C (software fallback), guaranteeing logical
equivalence.  The TPU-native equivalent implemented here:

  * the **software** lowering is the pure-jnp reference (``ref``) — compiled
    by XLA, runs on any backend, including quarantined/degraded devices;
  * the **hardware** lowering is the Pallas TPU kernel (``kernel``) —
    hand-tiled for VMEM/MXU (``target='pallas'``), with ``'interpret'``
    executing the same kernel body in Python for CPU validation;
  * equivalence between the two lowerings is a *contract* (`tol`), enforced
    by property tests and checked online by the fault detector's canaries.

An OpSpec also carries the paper's valid/ready notion: ``valid(out)`` is a
cheap predicate over outputs (e.g. "finite") used by detectors.

Routing is static per compilation: a ``route`` selects the lowering at
trace time, exactly mirroring the paper's per-sub-accelerator queue
(re)configuration — changing a route is a reconfiguration (recompile),
not a redesign.  A route is one of
  * a target string (HW / SW / INTERPRET),
  * a ``core.routing.RoutingPlan`` (the unified routing IR) — the op looks
    up its own stage entry (duck-typed via ``target_for`` so this module
    stays dependency-free), or
  * a ``core.routing.ResidentRoute`` handle (duck-typed via ``select``) —
    the hot-spare lowering: both paths resident behind ``lax.cond``.
"""
from __future__ import annotations

from dataclasses import dataclass
from typing import Any, Callable, Dict, Optional

import jax

# Routes (per-stage state in a FaultSignature).
HW = "hw"              # optimized path (Pallas kernel on TPU; fused XLA here)
SW = "sw"              # software fallback: the jnp oracle
INTERPRET = "interpret"  # kernel body, interpreter mode (CPU validation)

# The DEGRADED route family (partial degradation, paper §III-A; permanent-
# fault remapping a la arxiv 1802.04657): intermediate rungs between the
# optimized path and the full SW oracle, available once detection has
# localized a lane map for the stage (``viscosity.lanefault``).
DEGRADED_REMAP = "degraded_remap"      # HW full width; oracle heals dead lanes
DEGRADED_REDUCED = "degraded_reduced"  # kernel shrunk to surviving lanes
DEGRADED_TARGETS = (DEGRADED_REMAP, DEGRADED_REDUCED)


@dataclass(frozen=True)
class OpSpec:
    """One op described once; lowered to hardware and software paths."""
    name: str
    ref: Callable[..., Any]                       # the single source of truth
    kernel: Optional[Callable[..., Any]] = None   # pallas path (same signature)
    interpret: Optional[Callable[..., Any]] = None
    valid: Optional[Callable[[Any], Any]] = None  # validity predicate on outputs
    tol: float = 2e-2                             # hw-vs-sw allclose contract (bf16)
    flops: Optional[Callable[..., int]] = None    # analytic flop model (roofline)
    # Reduced-width support (DEGRADED_REDUCED): (args, kw, keep_lanes) ->
    # (args, kw) with the lane-axis operands sliced to the surviving lanes;
    # the kernel then derives its output width from the sliced operand.
    lane_slicer: Optional[Callable[..., Any]] = None

    def lower(self, target) -> Callable[..., Any]:
        if hasattr(target, "target_for"):   # RoutingPlan: my stage's entry
            target = target.target_for(self.name)
        if hasattr(target, "select"):       # ResidentRoute: runtime cond
            return target.select(self)
        if target == SW or self.kernel is None:
            return self.ref
        if target == HW:
            return self.kernel
        if target == INTERPRET:
            return self.interpret or self.kernel
        if target in DEGRADED_TARGETS:      # lane-mapped partial degradation
            from repro.viscosity import lanefault
            return lanefault.lower_degraded(self, target)
        raise ValueError(f"unknown lowering target {target!r} for op {self.name}")

    def __call__(self, *args, route=SW, **kw):
        return self.lower(route)(*args, **kw)


class Registry:
    def __init__(self):
        self._ops: Dict[str, OpSpec] = {}

    def register(self, spec: OpSpec) -> OpSpec:
        if spec.name in self._ops:
            raise ValueError(f"duplicate viscosity op {spec.name!r}")
        self._ops[spec.name] = spec
        return spec

    def get(self, name: str) -> OpSpec:
        return self._ops[name]

    def names(self):
        return sorted(self._ops)

    def __contains__(self, name):
        return name in self._ops


REGISTRY = Registry()


def defop(name: str, *, ref, kernel=None, interpret=None, valid=None,
          tol: float = 2e-2, flops=None, lane_slicer=None) -> OpSpec:
    """Declare an op once; both lowerings become available framework-wide."""
    return REGISTRY.register(OpSpec(name=name, ref=ref, kernel=kernel,
                                    interpret=interpret, valid=valid,
                                    tol=tol, flops=flops,
                                    lane_slicer=lane_slicer))


def finite_valid(out) -> jax.Array:
    """Default validity predicate: every leaf is finite (paper's `valid`)."""
    import jax.numpy as jnp
    leaves = jax.tree_util.tree_leaves(out)
    ok = jnp.array(True)
    for leaf in leaves:
        if hasattr(leaf, "dtype") and jnp.issubdtype(leaf.dtype, jnp.floating):
            ok = ok & jnp.all(jnp.isfinite(leaf))
    return ok
