from repro.viscosity.lang import (HW, INTERPRET, REGISTRY, SW, OpSpec, defop,
                                  finite_valid)

__all__ = ["HW", "INTERPRET", "REGISTRY", "SW", "OpSpec", "defop",
           "finite_valid"]
