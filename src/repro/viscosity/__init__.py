from repro.viscosity.lang import (DEGRADED_REDUCED, DEGRADED_REMAP,
                                  DEGRADED_TARGETS, HW, INTERPRET, REGISTRY,
                                  SW, OpSpec, defop, finite_valid)

__all__ = ["DEGRADED_REDUCED", "DEGRADED_REMAP", "DEGRADED_TARGETS", "HW",
           "INTERPRET", "REGISTRY", "SW", "OpSpec", "defop", "finite_valid"]
