from repro.train.runner import TrainConfig, TrainRunner, canary_stages, model_stage_names

__all__ = ["TrainConfig", "TrainRunner", "canary_stages", "model_stage_names"]
