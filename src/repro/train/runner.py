"""Fault-aware training runner: the Oobleck methodology applied to a
training step (detection -> quarantine -> reroute -> continue).

Per step:
  * the executable for the current FaultSignature comes from the
    Dispatcher (compile-per-signature, LRU; the no-fault program is fully
    fused — the paper's queue bypass);
  * StepGuard checks loss/grad finiteness; a trip restores the last
    checkpoint and re-runs (transient) or quarantines a stage (persistent,
    two consecutive trips);
  * CanaryChecker sweeps each Viscosity stage's HW path against its SW
    oracle every ``canary_every`` steps (cheap; catches silent wrong-value
    faults that never produce NaNs);
  * StragglerWatchdog tracks step times (multi-replica deployments feed
    per-replica times; single-process runs feed synthetic replica ids).

Checkpoints are async + checksummed; restore is elastic (any mesh).
"""
from __future__ import annotations

import time
from dataclasses import dataclass
from typing import Any, Callable, Dict, List, Mapping, Optional

import jax
import jax.numpy as jnp

from repro import optim
from repro.checkpoint.manager import CheckpointManager
from repro.configs.base import ModelConfig
from repro.core.fault import (CanaryChecker, FaultClassifier,
                              FaultSignature, FaultState, ProbationPolicy,
                              StepGuard, StragglerWatchdog)
from repro.core.oobleck import Dispatcher
from repro.core.routing import FleetPlan, RoutingPlan
from repro.core.stage import Stage
from repro.data.pipeline import SyntheticLM
from repro.launch.distributed import (FleetEvent, HostTopology, HostView,
                                      fleet_fingerprint)
from repro.launch.sharding import shard_bounds
from repro.models import build_model
from repro.obs import metrics as obs_metrics
from repro.viscosity import INTERPRET, REGISTRY, SW, lanefault

PyTree = Any


def model_stage_names(cfg: ModelConfig) -> List[str]:
    """The Viscosity stages this architecture actually exercises."""
    names = []
    if not cfg.attn_free or cfg.shared_attn_every:
        names.append("flash_attention")
    if cfg.gated_mlp and cfg.moe is None:
        names.append("swiglu_mlp")
    if cfg.family == "hybrid":
        names.append("mamba2_ssd")
    if cfg.family == "ssm" and cfg.layer_pattern and cfg.layer_pattern[0] == 3:
        names.append("rwkv6_wkv")
    return names


def canary_stages(cfg: ModelConfig, hw_route: str = INTERPRET
                  ) -> List[Stage]:
    """Small-port canary stages for the arch's Viscosity ops."""
    hd = 32
    ports = {
        "flash_attention": (jax.ShapeDtypeStruct((2, 64, 4, hd), jnp.float32),
                            jax.ShapeDtypeStruct((2, 64, 2, hd), jnp.float32),
                            jax.ShapeDtypeStruct((2, 64, 2, hd), jnp.float32)),
        "swiglu_mlp": (jax.ShapeDtypeStruct((64, 64), jnp.float32),
                       jax.ShapeDtypeStruct((64, 128), jnp.float32),
                       jax.ShapeDtypeStruct((64, 128), jnp.float32),
                       jax.ShapeDtypeStruct((128, 64), jnp.float32)),
        "mamba2_ssd": (jax.ShapeDtypeStruct((2, 64, 2, 16), jnp.float32),
                       jax.ShapeDtypeStruct((2, 64, 2), jnp.float32),
                       jax.ShapeDtypeStruct((2,), jnp.float32),
                       jax.ShapeDtypeStruct((2, 64, 8), jnp.float32),
                       jax.ShapeDtypeStruct((2, 64, 8), jnp.float32)),
        "rwkv6_wkv": (jax.ShapeDtypeStruct((2, 32, 2, 16), jnp.float32),
                      jax.ShapeDtypeStruct((2, 32, 2, 16), jnp.float32),
                      jax.ShapeDtypeStruct((2, 32, 2, 16), jnp.float32),
                      jax.ShapeDtypeStruct((2, 32, 2, 16), jnp.float32),
                      jax.ShapeDtypeStruct((2, 16), jnp.float32)),
    }
    stages = []
    for name in model_stage_names(cfg):
        spec = REGISTRY.get(name)
        stages.append(Stage(name=name, spec=spec, ports=ports[name],
                            tol=max(spec.tol, 1e-3)))
    return stages


@dataclass
class TrainConfig:
    steps: int = 100
    log_every: int = 10
    ckpt_every: int = 50
    canary_every: int = 0          # 0 = disabled
    canary_localize: bool = False  # lane-localize canary faults (DEGRADED)
    ckpt_dir: Optional[str] = None
    compression: bool = False      # int8 EF gradient compression
    hw_route: str = SW             # production: HW; CPU tests: SW/INTERPRET
    seed: int = 0
    # Probation (transient-vs-persistent classification): a detection
    # re-executes under backoff before any capacity is surrendered.
    # 0 retries = disabled (every detection is persistent, the
    # pre-probation behavior).
    probation_retries: int = 0
    probation_backoff_s: float = 0.0

    def probation_policy(self) -> Optional[ProbationPolicy]:
        if self.probation_retries <= 0:
            return None
        return ProbationPolicy(retries=self.probation_retries,
                               backoff_base_s=self.probation_backoff_s)


class TrainRunner:
    def __init__(self, cfg: ModelConfig, opt_cfg: optim.AdamWConfig,
                 tcfg: TrainConfig, data: SyntheticLM):
        self.cfg = cfg
        self.opt_cfg = opt_cfg
        self.tcfg = tcfg
        self.data = data
        self.fault_state = FaultState()
        self.stage_names = model_stage_names(cfg)
        self.dispatcher = Dispatcher(self._build)
        self.guard_trips = 0
        self.watchdog = StragglerWatchdog()
        self.ckpt = (CheckpointManager(tcfg.ckpt_dir)
                     if tcfg.ckpt_dir else None)
        self.history: List[Dict[str, float]] = []

    # ------------------------------------------------------------ build
    def _build(self, plan: RoutingPlan) -> Callable:
        model = build_model(self.cfg, routes=plan)
        use_comp = self.tcfg.compression

        def step(params, opt_state, err, batch):
            (loss, metrics), grads = jax.value_and_grad(
                model.forward, has_aux=True)(params, batch)
            if use_comp:
                grads, err = optim.compress_tree(grads, err)
            params, opt_state, om = optim.update(self.opt_cfg, grads,
                                                 opt_state, params)
            return params, opt_state, err, {**metrics, **om}

        return jax.jit(step, donate_argnums=(0, 1, 2))

    # ------------------------------------------------------------ state
    def init_state(self, key=None):
        key = key if key is not None else jax.random.PRNGKey(self.tcfg.seed)
        model = build_model(self.cfg)
        params = model.init(key)
        opt_state = optim.init(params)
        err = optim.init_error(params) if self.tcfg.compression else \
            jnp.zeros(())
        return params, opt_state, err

    def signature(self) -> FaultSignature:
        return self.fault_state.signature(self.stage_names)

    def plan(self) -> RoutingPlan:
        """The RoutingPlan for the current fault state: healthy stages take
        the deployment's optimized target; quarantined ones walk the
        degradation ladder when a lane map is localized (remap -> reduced
        width -> SW), or drop straight to the SW oracle without one.
        Hashable — it is the Dispatcher cache key."""
        base = RoutingPlan.from_signature(
            self.signature(), healthy=self.tcfg.hw_route)
        return lanefault.degraded_plan(
            base, self.fault_state.counts(self.stage_names)).validate(
                registry=REGISTRY)

    def inject_fault(self, stage: str, kind: str = "injected"):
        if stage not in self.stage_names:
            raise ValueError(f"unknown stage {stage!r}; this model's stages:"
                             f" {self.stage_names}")
        self.fault_state.mark(stage, 0, kind=kind)

    # -------------------------------------------------------------- run
    def run(self, params, opt_state, err, *, start_step: int = 0,
            steps: Optional[int] = None,
            on_step: Optional[Callable[[int, dict], None]] = None):
        tcfg = self.tcfg
        steps = steps if steps is not None else tcfg.steps
        step_i = start_step
        last_good = start_step - 1
        while step_i < start_step + steps:
            batch = self.data.device_batch(step_i)
            fn = self.dispatcher.get(self.plan())
            t0 = time.perf_counter()
            new = fn(params, opt_state, err, batch)
            new[-1]["loss"].block_until_ready()
            dt = time.perf_counter() - t0
            obs_metrics.observe("train_step_seconds", dt)
            self.watchdog.record(0, dt)
            params2, opt2, err2, metrics = new
            if not StepGuard.ok({"loss": metrics["loss"],
                                 "grad_norm": metrics["grad_norm"]}):
                self.guard_trips += 1
                # Logical (step, origin, seq) stamp — never wall clock:
                # the fault log is a deterministic function of the run.
                self.fault_state.note("<step>", kind="nan_guard",
                                      step=step_i)
                if self.ckpt and last_good >= 0 and self.ckpt.steps():
                    s = self.ckpt.latest_step()
                    self.ckpt.wait()
                    like = {"params": jax.tree_util.tree_map(
                        lambda x: jax.ShapeDtypeStruct(x.shape, x.dtype),
                        params),
                        "opt": jax.tree_util.tree_map(
                        lambda x: jax.ShapeDtypeStruct(x.shape, x.dtype),
                        opt_state)}
                    r0 = time.perf_counter()
                    restored = self.ckpt.restore(s, like)
                    obs_metrics.observe("ckpt_restore_seconds",
                                        time.perf_counter() - r0)
                    params, opt_state = restored["params"], restored["opt"]
                    # inputs of the failed call were donated; rebuild err
                    err = (optim.init_error(params)
                           if self.tcfg.compression else jnp.zeros(()))
                    step_i = s
                    continue
                raise FloatingPointError("non-finite step with no checkpoint")
            params, opt_state, err = params2, opt2, err2
            row = {k: float(v) for k, v in metrics.items()
                   if jnp.ndim(v) == 0}
            row.update(step=step_i, dt=dt,
                       n_faults=self.signature().n_faults(),
                       compiles=self.dispatcher.compiles)
            self.history.append(row)
            if on_step:
                on_step(step_i, row)
            if tcfg.canary_every and (step_i + 1) % tcfg.canary_every == 0:
                chk = CanaryChecker(canary_stages(self.cfg),
                                    route_hw=tcfg.hw_route,
                                    localize=tcfg.canary_localize)
                found = chk.sweep(self.fault_state, step=step_i)
                policy = tcfg.probation_policy()
                if found and policy is not None:
                    # Probation: re-canary each detection under backoff.
                    # Transient (clean re-run) clears the quarantine — the
                    # next plan() restores the HW route; persistent walks
                    # the ladder exactly as before.
                    clf = FaultClassifier(chk, policy)
                    for name in found:
                        res = clf.classify(name, step=step_i,
                                           state=self.fault_state)
                        if res.transient:
                            self.fault_state.clear(name, step=step_i)
            if self.ckpt and (step_i + 1) % tcfg.ckpt_every == 0:
                s0 = time.perf_counter()
                self.ckpt.save_async(step_i + 1,
                                     {"params": params, "opt": opt_state},
                                     extra={"data_step": step_i + 1})
                obs_metrics.observe("ckpt_save_seconds",
                                    time.perf_counter() - s0)
                last_good = step_i + 1
            step_i += 1
        if self.ckpt:
            self.ckpt.wait()
        return params, opt_state, err


# ==========================================================================
# Fleet layer (paper §II Fig. 2, §V Fig. 8): data-parallel steps where each
# shard consults its own RoutingPlan out of a shared FleetPlan.
# ==========================================================================
@dataclass
class FleetTrainConfig:
    n_devices: int = 2
    n_spares: int = 0
    # Host axis (multi-host fleets): devices partition into contiguous
    # per-host blocks; a host loss quarantines the whole block in one
    # FleetPlan transition and the survivors re-fold the mesh.
    topology: Optional[HostTopology] = None


class FleetTrainRunner:
    """Data-parallel training across a device-indexed fleet.

    Per step the global batch shards across the FleetPlan's *serving*
    devices (``launch.sharding.shard_bounds`` — quarantined devices and
    idle spares get no slice); each shard's gradients come from an
    executable keyed by that shard's own ``RoutingPlan`` in one shared
    Dispatcher, so devices with equal routing share a single compile.
    Detection follows the Oobleck loop per shard: a non-finite shard loss
    quarantines that device (detect), its work migrates to a hot spare
    when one is free (Fig. 8) or its slice redistributes over the
    survivors (quarantine -> migrate-or-reroute), and the step re-runs
    (continue).  Stage-level faults reroute only the faulted device's
    plan — the other shards keep their fully-fused fast path.
    """

    def __init__(self, cfg: ModelConfig, opt_cfg: optim.AdamWConfig,
                 tcfg: TrainConfig, data: SyntheticLM,
                 fcfg: FleetTrainConfig):
        self.cfg = cfg
        self.opt_cfg = opt_cfg
        self.tcfg = tcfg
        self.data = data
        self.fcfg = fcfg
        if fcfg.topology is not None and \
                fcfg.topology.n_devices != fcfg.n_devices:
            raise ValueError(
                f"topology covers {fcfg.topology.n_devices} device(s), "
                f"fleet has {fcfg.n_devices}")
        self.stage_names = model_stage_names(cfg)
        self.fleet = FleetPlan.healthy(fcfg.n_devices, self.stage_names,
                                       target=tcfg.hw_route,
                                       n_spares=fcfg.n_spares)
        self.dispatcher = Dispatcher(self._build_grads)
        self.guard_trips = 0
        self.history: List[Dict[str, float]] = []
        # Ordered transition log (the multi-host runtime replays this):
        # every quarantine/migration the runner performs is one event.
        self.fleet_log: List[FleetEvent] = []
        # Probation bookkeeping rides the same logical-stamp log dialect
        # as the single-device runner.
        self.fault_state = FaultState()
        self.classifier: Optional[FaultClassifier] = None
        policy = tcfg.probation_policy()
        if policy is not None:
            self.classifier = FaultClassifier(
                CanaryChecker(canary_stages(cfg), route_hw=tcfg.hw_route),
                policy)
        # Fleet-owned checkpoints: checksummed async saves on the
        # ckpt_every cadence; host-fault recovery restores the latest
        # onto the survivor mesh (restore-then-continue).
        self.ckpt = (CheckpointManager(tcfg.ckpt_dir)
                     if tcfg.ckpt_dir else None)
        self._update = jax.jit(
            lambda grads, opt_state, params: optim.update(
                self.opt_cfg, grads, opt_state, params))

    # ------------------------------------------------------------ build
    def _build_grads(self, plan: RoutingPlan) -> Callable:
        model = build_model(self.cfg, routes=plan)

        def grads_fn(params, batch):
            (loss, metrics), grads = jax.value_and_grad(
                model.forward, has_aux=True)(params, batch)
            return grads, loss, metrics

        return jax.jit(grads_fn)

    # ------------------------------------------------------------ state
    def init_state(self, key=None):
        key = key if key is not None else jax.random.PRNGKey(self.tcfg.seed)
        params = build_model(self.cfg).init(key)
        return params, optim.init(params)

    def _log_event(self, step: int, kind: str, device: int,
                   stage: str = ""):
        topo = self.fcfg.topology
        origin = 0 if topo is None or topo.host_id is None else topo.host_id
        self.fleet_log.append(FleetEvent(step=step, origin=origin,
                                         seq=len(self.fleet_log),
                                         kind=kind, device=device,
                                         stage=stage))

    def inject_stage_fault(self, device: int, stage: str, *,
                           step: int = -1):
        if stage not in self.stage_names:
            raise ValueError(f"unknown stage {stage!r}; this model's stages:"
                             f" {self.stage_names}")
        self.fleet = self.fleet.with_stage_fault(device, stage)
        self._log_event(step, "stage", device, stage)

    def inject_device_fault(self, device: int, *, step: int = -1):
        self.fleet = self.fleet.with_device_fault(device)
        self._log_event(step, "device", device)

    def inject_host_fault(self, host: int, *, step: int = -1):
        """A whole host drops out: quarantine its device block in ONE
        FleetPlan transition (spares outside the block absorb what they
        can); the next step re-folds the mesh over the survivors."""
        if self.fcfg.topology is None:
            raise ValueError("host faults need FleetTrainConfig.topology")
        self.fleet = self.fleet.with_host_fault(
            self.fcfg.topology.devices_of(host))
        self._log_event(step, "host", host)

    def host_view(self) -> HostView:
        """The fleet's health projected onto the host partition."""
        if self.fcfg.topology is None:
            raise ValueError("host_view needs FleetTrainConfig.topology")
        return HostView.of(self.fleet, self.fcfg.topology)

    # -------------------------------------------------------------- run
    def _shard_step(self, params, batch, poison_device: Optional[int]):
        """Grads per serving shard; returns (avg_grads, metrics, tripped)
        where ``tripped`` is the first device whose shard failed the
        StepGuard (None when the step is clean)."""
        B = batch["tokens"].shape[0]
        bounds = shard_bounds(B, self.fleet.device_mask())
        total = jax.tree_util.tree_map(jnp.zeros_like, params)
        losses, n_rows = [], 0
        for d, (lo, hi) in bounds.items():
            if hi == lo:
                continue
            shard = {k: v[lo:hi] for k, v in batch.items()}
            fn = self.dispatcher.get(self.fleet.plan_for(d))
            grads, loss, metrics = fn(params, shard)
            if d == poison_device:       # emulated datapath blowup
                loss = loss * jnp.nan
            if not StepGuard.ok({"loss": loss, "grads": grads}):
                return None, {"device": d}, d
            w = float(hi - lo)
            total = jax.tree_util.tree_map(
                lambda t, g: t + w * g, total, grads)
            losses.append(w * float(loss))
            n_rows += hi - lo
        avg = jax.tree_util.tree_map(lambda t: t / n_rows, total)
        return avg, {"loss": sum(losses) / n_rows}, None

    def _probe_shard(self, params, batch, device: int,
                     poison_device: Optional[int]) -> bool:
        """Probation probe: re-execute just ``device``'s shard and guard
        the result (RedMulE-FT re-execution-on-demand).  True = clean."""
        B = batch["tokens"].shape[0]
        bounds = shard_bounds(B, self.fleet.device_mask())
        lo, hi = bounds.get(device, (0, 0))
        if hi == lo:
            return True
        shard = {k: v[lo:hi] for k, v in batch.items()}
        fn = self.dispatcher.get(self.fleet.plan_for(device))
        grads, loss, _metrics = fn(params, shard)
        if device == poison_device:
            loss = loss * jnp.nan
        return StepGuard.ok({"loss": loss, "grads": grads})

    def _restore_latest(self, params, opt_state, step_i: int):
        """Host-fault recovery: restore the latest checksummed checkpoint
        onto whatever mesh survives (restore is elastic — params are
        replicated, so the shard re-fold is just shard_bounds following
        the new mask).  Returns (params, opt_state, resume_step)."""
        self.ckpt.wait()
        s = self.ckpt.latest_step()
        like = {"params": jax.tree_util.tree_map(
            lambda x: jax.ShapeDtypeStruct(x.shape, x.dtype), params),
            "opt": jax.tree_util.tree_map(
            lambda x: jax.ShapeDtypeStruct(x.shape, x.dtype), opt_state)}
        r0 = time.perf_counter()
        restored = self.ckpt.restore(s, like)
        obs_metrics.observe("ckpt_restore_seconds",
                            time.perf_counter() - r0)
        self.fault_state.note("<ckpt>", kind="checkpoint_restored",
                              step=step_i)
        return restored["params"], restored["opt"], s

    def run(self, params, opt_state, *, steps: Optional[int] = None,
            poison: Optional[Mapping[int, int]] = None,
            transient: Optional[Mapping[int, int]] = None,
            host_loss: Optional[Mapping[int, int]] = None):
        """``poison[step] = device`` injects a non-finite shard loss at
        that step (the detect -> quarantine -> migrate loop, test-drivable
        without real broken silicon).  ``transient[step] = device`` is the
        single-upset variant: it poisons only the *first* execution of
        that step, so with probation enabled (``TrainConfig
        .probation_retries > 0``) the re-executed shard comes back clean
        and the fleet keeps its capacity — logged ``transient_recovered``,
        zero quarantines.  ``host_loss[step] = host`` drops a whole host
        just before that step: its device block quarantines in one
        transition and the surviving hosts' shards absorb the batch (the
        mesh re-fold is automatic — shard_bounds follows the mask); with
        a CheckpointManager attached, the latest checkpoint restores onto
        the survivor mesh first (restore-then-continue).
        """
        steps = steps if steps is not None else self.tcfg.steps
        poison = dict(poison or {})
        transient = dict(transient or {})
        host_loss = dict(host_loss or {})
        step_i = 0
        while step_i < steps:
            if step_i in host_loss:
                self.inject_host_fault(host_loss.pop(step_i), step=step_i)
                if self.ckpt and self.ckpt.steps():
                    params, opt_state, step_i = self._restore_latest(
                        params, opt_state, step_i)
                    continue
            batch = self.data.device_batch(step_i)
            t0 = time.perf_counter()
            pd = poison.get(step_i)
            if pd is None and step_i in transient:
                pd = transient.pop(step_i)   # upset hits one execution only
            grads, metrics, tripped = self._shard_step(params, batch, pd)
            if tripped is not None:
                # detect -> probate -> quarantine-or-recover; a transient
                # verdict re-runs the step with no capacity surrendered,
                # persistent migrates to a spare / reroutes the survivors.
                self.guard_trips += 1
                if self.classifier is not None:
                    res = self.classifier.probate(
                        lambda: self._probe_shard(params, batch, tripped,
                                                  poison.get(step_i)),
                        stage="<step>", replica=tripped, step=step_i,
                        state=self.fault_state)
                    if res.transient:
                        continue
                poison.pop(step_i, None)     # the bad device is now gone
                self.fleet = self.fleet.with_device_fault(tripped)
                self._log_event(step_i, "device", tripped)
                continue
            params, opt_state, om = self._update(grads, opt_state, params)
            fleet_dt = time.perf_counter() - t0
            obs_metrics.observe("train_step_seconds", fleet_dt)
            row = {
                "step": step_i, "loss": metrics["loss"],
                "dt": fleet_dt,
                "n_serving": len(self.fleet.serving()),
                "n_quarantined": len(self.fleet.quarantined),
                "compiles": self.dispatcher.compiles}
            if self.fcfg.topology is not None:
                row["hosts_serving"] = len(self.host_view().hosts_serving())
            self.history.append(row)
            step_i += 1
            if self.ckpt and step_i % self.tcfg.ckpt_every == 0:
                s0 = time.perf_counter()
                self.ckpt.save_async(
                    step_i, {"params": params, "opt": opt_state},
                    extra={"data_step": step_i,
                           "fingerprint": fleet_fingerprint(self.fleet)})
                obs_metrics.observe("ckpt_save_seconds",
                                    time.perf_counter() - s0)
        if self.ckpt:
            self.ckpt.wait()
        return params, opt_state
