from repro.kernels.checksum.ops import CHECKSUM, checksum
from repro.kernels.checksum.ref import (checksum_ref, checksum_tree,
                                        popcount_fig4)

__all__ = ["CHECKSUM", "checksum", "checksum_ref", "checksum_tree",
           "popcount_fig4"]
