"""Jit'd wrapper + Viscosity registration for the checksum detector."""
from __future__ import annotations

import functools

from repro import viscosity
from repro.kernels.checksum import ref as _ref
from repro.kernels.checksum.kernel import checksum_pallas_words


def _hw(x, *, interpret: bool = False):
    return checksum_pallas_words(_ref.as_words(x), interpret=interpret)


CHECKSUM = viscosity.defop(
    "checksum",
    ref=_ref.checksum_ref,
    kernel=_hw,
    interpret=functools.partial(_hw, interpret=True),
    tol=0.0,  # bit-exact contract
)


def checksum(x, *, route: str = viscosity.SW, **kw):
    return CHECKSUM(x, route=route, **kw)
