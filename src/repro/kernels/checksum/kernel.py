"""Pallas TPU kernel for the Fig. 4 checksum module.

Grid over row blocks of the word view; each block reduces to a single
partial popcount (VPU bit ops, no MXU); the host-side wrapper sums the
per-block partials.  This is the cheap always-on detector the paper routes
through the Cohort queues; here it runs over stage outputs / canaries.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl


def _checksum_kernel(x_ref, o_ref, *, block_rows: int):
    x = x_ref[...].astype(jnp.uint32)
    x = (x & 0x55555555) + ((x >> 1) & 0x55555555)
    x = (x & 0x33333333) + ((x >> 2) & 0x33333333)
    x = (x & 0x0F0F0F0F) + ((x >> 4) & 0x0F0F0F0F)
    x = (x & 0x00FF00FF) + ((x >> 8) & 0x00FF00FF)
    x = (x & 0x0000FFFF) + ((x >> 16) & 0x0000FFFF)
    o_ref[0, 0] = jnp.sum(x.astype(jnp.uint32))


def checksum_pallas_words(words, *, block_rows: int = 64, lanes: int = 128,
                          interpret: bool = False) -> jax.Array:
    """words: flat uint32 array -> uint32 checksum."""
    n = words.shape[0]
    per_block = block_rows * lanes
    nb = max(1, -(-n // per_block))
    padded = jnp.zeros((nb * per_block,), jnp.uint32).at[:n].set(words)
    x = padded.reshape(nb * block_rows, lanes)
    partials = pl.pallas_call(
        functools.partial(_checksum_kernel, block_rows=block_rows),
        grid=(nb,),
        in_specs=[pl.BlockSpec((block_rows, lanes), lambda i: (i, 0))],
        out_specs=pl.BlockSpec((1, 1), lambda i: (i, 0)),
        out_shape=jax.ShapeDtypeStruct((nb, 1), jnp.uint32),
        interpret=interpret,
    )(x)
    return jnp.sum(partials.astype(jnp.uint32))
