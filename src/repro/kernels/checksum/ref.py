"""Pure-jnp oracle for the paper's Fig. 4 checksum (popcount) module.

The paper's Viscosity example computes a popcount via the classic
mask-and-add bit tricks; Oobleck uses checksums to compare hardware and
software stage outputs cheaply (fault detection canaries).  Here the
checksum of a tensor is the total popcount of its bit pattern, mod 2^32 —
bit-exact across lowerings, so a single integer compare detects any
stuck-at discrepancy between the HW and SW paths on identical inputs.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

_UINT = {1: jnp.uint8, 2: jnp.uint16, 4: jnp.uint32, 8: jnp.uint32}


def as_words(x) -> jax.Array:
    """Flatten any tensor to a uint32 word view of its bit pattern."""
    x = jnp.asarray(x)
    if x.dtype == jnp.bool_:
        x = x.astype(jnp.uint8)
    nbytes = x.dtype.itemsize
    u = jax.lax.bitcast_convert_type(x, _UINT.get(nbytes, jnp.uint32))
    return u.reshape(-1).astype(jnp.uint32)


def checksum_ref(x) -> jax.Array:
    """Total popcount of the bit pattern (uint32)."""
    w = as_words(x)
    return jnp.sum(jax.lax.population_count(w).astype(jnp.uint32))


def checksum_tree(tree) -> jax.Array:
    """Checksum of a pytree (order-dependent fold over leaves)."""
    total = jnp.uint32(0)
    for leaf in jax.tree_util.tree_leaves(tree):
        total = total * jnp.uint32(1000003) + checksum_ref(leaf)
    return total


def popcount_fig4(x: jax.Array) -> jax.Array:
    """The paper's Fig. 4 bit-trick sequence on uint32 words (oracle for
    the kernel body; equals lax.population_count)."""
    x = x.astype(jnp.uint32)
    x = (x & 0x55555555) + ((x >> 1) & 0x55555555)
    x = (x & 0x33333333) + ((x >> 2) & 0x33333333)
    x = (x & 0x0F0F0F0F) + ((x >> 4) & 0x0F0F0F0F)
    x = (x & 0x00FF00FF) + ((x >> 8) & 0x00FF00FF)
    x = (x & 0x0000FFFF) + ((x >> 16) & 0x0000FFFF)
    return x
