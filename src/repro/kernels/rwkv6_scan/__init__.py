from repro.kernels.rwkv6_scan.ops import WKV6, wkv6
from repro.kernels.rwkv6_scan.ref import (wkv6_chunked, wkv6_flops,
                                          wkv6_scan_ref, wkv6_step)

__all__ = ["WKV6", "wkv6", "wkv6_chunked", "wkv6_scan_ref", "wkv6_step",
           "wkv6_flops"]
