"""Jit'd wrapper + Viscosity registration for the RWKV-6 WKV stage."""
from __future__ import annotations

import functools

import jax.numpy as jnp

from repro import viscosity
from repro.kernels import tuning
from repro.kernels.rwkv6_scan import ref as _ref
from repro.kernels.rwkv6_scan.kernel import wkv6_chunked_pallas
from repro.viscosity import lanefault


def _tuned_chunk(kind, r, v, default):
    cfg = tuning.lookup(
        "rwkv6_wkv", kind,
        (r.shape[0], r.shape[1], r.shape[2], r.shape[3], v.shape[-1]),
        r.dtype) or {}
    return cfg.get("chunk") or default


def _sw(r, k, v, lw, u, *, chunk=None):
    chunk = chunk or _tuned_chunk("sw", r, v, 16)
    o, _ = _ref.wkv6_chunked(r, k, v, lw, u, chunk=chunk)
    return o


def _hw(r, k, v, lw, u, *, chunk=None, interpret: bool = False):
    chunk = chunk or _tuned_chunk("hw", r, v, 16)
    S = r.shape[1]
    L = min(chunk, S)
    if S % L:
        pad = L - S % L
        pad4 = ((0, 0), (0, pad), (0, 0), (0, 0))
        r, k, v, lw = (jnp.pad(a, pad4) for a in (r, k, v, lw))
    o = wkv6_chunked_pallas(r, k, v, lw, u, chunk=L, interpret=interpret,
                            lane_fault=lanefault.injection("rwkv6_wkv"))
    return o[:, :S]


def _lane_slicer(args, kw, keep):
    # o's value lane j depends only on v[..., j] (scores/state-decay mix
    # over K and sequence, never across V): slicing v is exact.
    r, k, v, lw, u = args
    return (r, k, v[..., jnp.asarray(keep, jnp.int32)], lw, u), kw


WKV6 = viscosity.defop(
    "rwkv6_wkv",
    ref=_sw,
    kernel=_hw,
    interpret=functools.partial(_hw, interpret=True),
    valid=viscosity.finite_valid,
    tol=2e-2,
    flops=lambda r, k, v, *a, **kw: _ref.wkv6_flops(
        r.shape[0], r.shape[1], r.shape[2], r.shape[3], v.shape[-1]),
    lane_slicer=_lane_slicer,
)


def wkv6(r, k, v, lw, u, *, route: str = viscosity.SW, **kw):
    return WKV6(r, k, v, lw, u, route=route, **kw)
