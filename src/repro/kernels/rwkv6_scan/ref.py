"""Pure-jnp oracles for the RWKV-6 "Finch" WKV recurrence.

Shapes: r, k, lw (B, S, H, K); v (B, S, H, V); u (H, K).
``lw`` is the per-token, per-channel LOG decay (non-positive; the model
computes lw = -exp(w0 + lora(x)) and clamps to [-4, 0] so the chunked
factorized form stays inside f32 range for chunk lengths <= 16 — see
kernel.py for the derivation).

Recurrence (state S: (B, H, K, V)):
    o_t = r_t . (S_{t-1} + diag(u) k_t v_t^T)
    S_t = diag(exp(lw_t)) S_{t-1} + k_t v_t^T
"""
from __future__ import annotations

import jax
import jax.numpy as jnp


def wkv6_scan_ref(r, k, v, lw, u):
    B, S, H, K = r.shape
    V = v.shape[-1]
    rf, kf = r.astype(jnp.float32), k.astype(jnp.float32)
    vf, lwf = v.astype(jnp.float32), lw.astype(jnp.float32)
    uf = u.astype(jnp.float32)

    def step(Sst, inp):
        rt, kt, vt, lwt = inp                        # (B,H,K) .. (B,H,V)
        kv = kt[..., :, None] * vt[..., None, :]     # (B,H,K,V)
        o = jnp.einsum("bhk,bhkv->bhv", rt, Sst + uf[None, :, :, None] * kv)
        Sst = jnp.exp(lwt)[..., None] * Sst + kv
        return Sst, o

    S0 = jnp.zeros((B, H, K, V), jnp.float32)
    xs = (rf.transpose(1, 0, 2, 3), kf.transpose(1, 0, 2, 3),
          vf.transpose(1, 0, 2, 3), lwf.transpose(1, 0, 2, 3))
    ST, os = jax.lax.scan(step, S0, xs)
    return os.transpose(1, 0, 2, 3).astype(r.dtype), ST


def wkv6_chunked(r, k, v, lw, u, *, chunk: int = 16):
    """Chunked factorized WKV (matmul form) — software path / XLA lowering."""
    B, S, H, K = r.shape
    V = v.shape[-1]
    L = min(chunk, S)
    if S % L:
        pad = L - S % L
        r = jnp.pad(r, ((0, 0), (0, pad), (0, 0), (0, 0)))
        k = jnp.pad(k, ((0, 0), (0, pad), (0, 0), (0, 0)))
        v = jnp.pad(v, ((0, 0), (0, pad), (0, 0), (0, 0)))
        lw = jnp.pad(lw, ((0, 0), (0, pad), (0, 0), (0, 0)))
    Sp = r.shape[1]
    nc = Sp // L

    def resh(x):
        return x.astype(jnp.float32).reshape(B, nc, L, H, -1).transpose(1, 0, 3, 2, 4)

    rc, kc, vc, lwc = resh(r), resh(k), resh(v), resh(lw)   # (nc,B,H,L,·)
    uf = u.astype(jnp.float32)
    mask = jnp.tril(jnp.ones((L, L), bool), k=-1)           # strict lower

    def body(Sst, inp):
        rt, kt, vt, lwt = inp                               # (B,H,L,·)
        la = jnp.cumsum(lwt, axis=2)                        # (B,H,L,K)
        la_prev = la - lwt                                  # exclusive cumsum
        qexp = rt * jnp.exp(la_prev)
        kexp = kt * jnp.exp(-la)
        scores = jnp.einsum("bhlk,bhsk->bhls", qexp, kexp)
        scores = jnp.where(mask[None, None], scores, 0.0)
        bonus = jnp.einsum("bhlk,hk,bhlk->bhl", rt, uf, kt)
        o = jnp.einsum("bhls,bhsv->bhlv", scores, vt) + \
            jnp.einsum("bhlk,bhkv->bhlv", qexp, Sst) + \
            bonus[..., None] * vt
        tot = la[:, :, -1:, :]                              # (B,H,1,K)
        kscale = kt * jnp.exp(tot - la)
        Sst = jnp.exp(tot[:, :, 0, :])[..., None] * Sst + \
            jnp.einsum("bhlk,bhlv->bhkv", kscale, vt)
        return Sst, o

    S0 = jnp.zeros((B, H, K, V), jnp.float32)
    ST, os = jax.lax.scan(body, S0, (rc, kc, vc, lwc))
    o = os.transpose(1, 0, 3, 2, 4).reshape(B, Sp, H, V)[:, :S]
    return o.astype(r.dtype), ST


def wkv6_step(state, r_t, k_t, v_t, lw_t, u):
    """Single decode step. state (B,H,K,V)."""
    kv = k_t[..., :, None].astype(jnp.float32) * \
        v_t[..., None, :].astype(jnp.float32)
    o = jnp.einsum("bhk,bhkv->bhv", r_t.astype(jnp.float32),
                   state + u.astype(jnp.float32)[None, :, :, None] * kv)
    state = jnp.exp(lw_t.astype(jnp.float32))[..., None] * state + kv
    return o.astype(r_t.dtype), state


def wkv6_flops(B, S, H, K, V, chunk=16) -> int:
    L = min(chunk, S)
    per_chunk = 2 * L * L * K + 2 * L * L * V + 4 * L * K * V
    return int(B * H * (S // max(L, 1)) * per_chunk)
