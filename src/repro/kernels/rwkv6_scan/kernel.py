"""Pallas TPU kernel for the chunked RWKV-6 WKV recurrence.

Same TPU pattern as the SSD kernel: grid (B, H, n_chunks), chunk axis
minor-most, (K, V) state in VMEM scratch carried across chunk iterations.
Matmul (MXU) form with per-channel decays factored into q/k:

    qexp = r * exp(la_prev),  kexp = k * exp(-la)
    o    = mask(qexp @ kexp^T) @ v  +  qexp @ S  +  bonus * v
    S'   = exp(la_L) * S + (k * exp(la_L - la))^T @ v

f32-range analysis: |la| <= chunk * max|lw|; the model clamps lw >= -4 and
the default chunk is 16, so exp(-la) <= e^64 < f32 max (e^~88) and the
masked upper-triangle garbage stays finite.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

from repro.viscosity.lanefault import apply_fault


def _wkv_kernel(r_ref, k_ref, v_ref, lw_ref, u_ref, o_ref, state_scr, *,
                L: int, K: int, V: int, lane_fault=None):
    ci = pl.program_id(2)

    @pl.when(ci == 0)
    def _init():
        state_scr[...] = jnp.zeros_like(state_scr)

    r = r_ref[0, :, 0, :].astype(jnp.float32)     # (L, K)
    k = k_ref[0, :, 0, :].astype(jnp.float32)
    v = v_ref[0, :, 0, :].astype(jnp.float32)     # (L, V)
    lw = lw_ref[0, :, 0, :].astype(jnp.float32)   # (L, K)
    u = u_ref[0].astype(jnp.float32)              # (K,)

    la = jnp.cumsum(lw, axis=0)
    la_prev = la - lw
    qexp = r * jnp.exp(la_prev)
    kexp = k * jnp.exp(-la)
    scores = jax.lax.dot_general(qexp, kexp, (((1,), (1,)), ((), ())),
                                 preferred_element_type=jnp.float32)  # (L,L)
    rows = jax.lax.broadcasted_iota(jnp.int32, (L, L), 0)
    cols = jax.lax.broadcasted_iota(jnp.int32, (L, L), 1)
    scores = jnp.where(rows > cols, scores, 0.0)
    bonus = jnp.sum(r * u[None, :] * k, axis=1, keepdims=True)        # (L,1)

    state = state_scr[...]                         # (K, V)
    o = jax.lax.dot_general(scores, v, (((1,), (0,)), ((), ())),
                            preferred_element_type=jnp.float32)
    o += jax.lax.dot_general(qexp, state, (((1,), (0,)), ((), ())),
                             preferred_element_type=jnp.float32)
    o += bonus * v
    # Value-level fault injection (lanefault): masked corruption of the
    # chunk's value-lane axis; absent from the trace when healthy.
    o_ref[0, :, 0, :] = apply_fault(o, lane_fault).astype(o_ref.dtype)

    tot = la[L - 1]                                # (K,)
    kscale = k * jnp.exp(tot[None, :] - la)
    upd = jax.lax.dot_general(kscale, v, (((0,), (0,)), ((), ())),
                              preferred_element_type=jnp.float32)
    state_scr[...] = jnp.exp(tot)[:, None] * state + upd


def wkv6_chunked_pallas(r, k, v, lw, u, *, chunk: int = 16,
                        interpret: bool = False, lane_fault=None):
    """r/k/lw (B,S,H,K); v (B,S,H,V); u (H,K). S % chunk == 0."""
    B, S, H, K = r.shape
    V = v.shape[-1]
    L = min(chunk, S)
    assert S % L == 0, (S, L)
    nc = S // L

    kernel = functools.partial(_wkv_kernel, L=L, K=K, V=V,
                               lane_fault=lane_fault)
    grid = (B, H, nc)
    spec_k = pl.BlockSpec((1, L, 1, K), lambda b, h, ci: (b, ci, h, 0))
    spec_v = pl.BlockSpec((1, L, 1, V), lambda b, h, ci: (b, ci, h, 0))
    return pl.pallas_call(
        kernel,
        grid=grid,
        in_specs=[spec_k, spec_k, spec_v, spec_k,
                  pl.BlockSpec((1, K), lambda b, h, ci: (h, 0))],
        out_specs=spec_v,
        out_shape=jax.ShapeDtypeStruct((B, S, H, V), r.dtype),
        scratch_shapes=[pltpu.VMEM((K, V), jnp.float32)],
        interpret=interpret,
    )(r, k, v, lw, u)
