from repro.kernels.flash_attention.ops import ATTENTION, attention
from repro.kernels.flash_attention.ref import (attention_chunked,
                                               attention_flops,
                                               attention_naive,
                                               attention_ref_blocked)

__all__ = ["ATTENTION", "attention", "attention_chunked", "attention_naive",
           "attention_ref_blocked", "attention_flops"]
