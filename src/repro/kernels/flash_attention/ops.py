"""Jit'd wrapper + Viscosity registration for the attention stage.

``attention(...)`` is the stage entry point used by the models: the route
argument selects the lowering (paper: per-sub-accelerator queue config):
  * HW        -> Pallas flash kernel (TPU)
  * INTERPRET -> same kernel body, interpreter mode (CPU validation)
  * SW        -> chunked online-softmax jnp fallback (production software)
"""
from __future__ import annotations

import functools

import jax.numpy as jnp

from repro import viscosity
from repro.kernels import tuning
from repro.kernels.flash_attention import ref as _ref
from repro.kernels.flash_attention.kernel import flash_attention_bhsd
from repro.viscosity import lanefault


def _pad_to(x, m, axis):
    s = x.shape[axis]
    if s % m == 0:
        return x, s
    pad = [(0, 0)] * x.ndim
    pad[axis] = (0, m - s % m)
    return jnp.pad(x, pad), s


def _kernel_path(q, k, v, *, causal=True, window=0, softcap=0.0, scale=0.0,
                 q_offset=None, kv_len=None, kv_chunk=0, bq=None, bk=None,
                 interpret=False):
    fault = lanefault.injection("flash_attention")
    if q_offset is not None or kv_len is not None:
        # decode-style calls carry dynamic positions; the kernel targets
        # train/prefill. Fall back to the software lowering (still correct).
        # This branch IS the HW lowering for decode, so an active lane
        # fault corrupts it too (wrapper-level: same masked-where).
        out = _ref.attention_chunked(q, k, v, causal=causal, window=window,
                                     softcap=softcap, scale=scale,
                                     q_offset=q_offset, kv_len=kv_len)
        return fault.corrupt_tree(out) if fault is not None else out
    B, Sq, H, D = q.shape
    Skv = k.shape[1]
    # Tuned score-tile (bq, bk) for this (shape, dtype, active routing
    # plan) when cached; explicit knobs win; no entry -> the historical
    # 128x128 MXU tile.  tuning.lookup is fail-open by construction.
    if bq is None and bk is None:
        cfg = tuning.lookup("flash_attention", "hw",
                            (B, Sq, Skv, H, k.shape[2], D), q.dtype) or {}
    else:
        cfg = {}
    bq = bq or cfg.get("bq") or 128
    bk = bk or cfg.get("bk") or 128
    bq = min(bq, max(8, Sq))
    bk = min(bk, max(8, Skv))
    qt = q.transpose(0, 2, 1, 3)
    kt = k.transpose(0, 2, 1, 3)
    vt = v.transpose(0, 2, 1, 3)
    qt, _ = _pad_to(qt, bq, 2)
    kt, real_kv = _pad_to(kt, bk, 2)
    vt, _ = _pad_to(vt, bk, 2)
    out = flash_attention_bhsd(qt, kt, vt, causal=causal, window=window,
                               softcap=softcap, scale=scale, kv_len=real_kv,
                               bq=bq, bk=bk, interpret=interpret,
                               lane_fault=fault)
    return out[:, :, :Sq, :].transpose(0, 2, 1, 3)


def _lane_slicer(args, kw, keep):
    # attention output lane j depends only on v[..., j] (softmax weights
    # come from q@k): slicing v's head_dim is exact reduced-width execution.
    q, k, v = args
    return (q, k, v[..., jnp.asarray(keep, jnp.int32)]), kw


def _sw_path(q, k, v, *, kv_chunk=None, bq=128, bk=128, interpret=False,
             **kw):
    if not kv_chunk:
        B, Sq, H, D = q.shape
        cfg = tuning.lookup("flash_attention", "sw",
                            (B, Sq, k.shape[1], H, k.shape[2], D),
                            q.dtype) or {}
        kv_chunk = cfg.get("kv_chunk") or 512
    return _ref.attention_chunked(q, k, v, kv_chunk=kv_chunk, **kw)


ATTENTION = viscosity.defop(
    "flash_attention",
    ref=_sw_path,
    kernel=_kernel_path,
    interpret=functools.partial(_kernel_path, interpret=True),
    valid=viscosity.finite_valid,
    tol=2e-2,
    flops=lambda q, k, *a, **kw: _ref.attention_flops(
        q.shape[0], q.shape[1], k.shape[1], q.shape[2], q.shape[3]),
    lane_slicer=_lane_slicer,
)


def attention(q, k, v, *, route: str = viscosity.SW, **kw):
    return ATTENTION(q, k, v, route=route, **kw)
