"""Pure-jnp oracles for attention (the Viscosity "software" lowering).

Two implementations:
  * ``attention_naive`` — the simple masked-softmax oracle used as the
    ground-truth in tests (never used at scale);
  * ``attention_chunked`` — the memory-efficient online-softmax jnp version
    (lax.scan over KV chunks).  This is the production software fallback and
    the XLA path lowered by the dry-run.

Both support: causal masking, sliding windows (``window > 0``), GQA
(``Hkv`` divides ``H``), gemma-style logit softcapping, and explicit
query/key positions (decode: ``q_pos`` is the absolute position of the
query tokens; ``kv_len`` masks the unwritten tail of a preallocated cache).

Layout: q (B, Sq, H, D); k, v (B, Skv, Hkv, D); output (B, Sq, H, D).
"""
from __future__ import annotations

from typing import Optional

import jax
import jax.numpy as jnp

NEG_INF = -1e30


def _softcap(scores, cap: float):
    if cap and cap > 0.0:
        return jnp.tanh(scores / cap) * cap
    return scores


def _positions(B, S, offset):
    pos = jnp.arange(S, dtype=jnp.int32)[None, :]
    if offset is not None:
        pos = pos + offset.astype(jnp.int32).reshape(-1, 1)
    return jnp.broadcast_to(pos, (B, S))


def _mask(q_pos, k_pos, *, causal: bool, window: int,
          kv_len: Optional[jax.Array], explicit_kpos: bool = False):
    """(B, Sq, Skv) boolean admissibility mask."""
    m = jnp.ones((q_pos.shape[0], q_pos.shape[1], k_pos.shape[1]), bool)
    qp = q_pos[:, :, None]
    kp = k_pos[:, None, :]
    if causal:
        m &= kp <= qp
    if window and window > 0:
        m &= kp > qp - window
    if kv_len is not None:
        m &= kp < kv_len.astype(jnp.int32).reshape(-1, 1, 1)
    if explicit_kpos:
        m &= kp >= 0  # ring-buffer slots not yet written carry position -1
    return m


def _repeat_kv(k, H):
    Hkv = k.shape[2]
    if Hkv == H:
        return k
    assert H % Hkv == 0, (H, Hkv)
    return jnp.repeat(k, H // Hkv, axis=2)


def attention_naive(q, k, v, *, causal: bool = True, window: int = 0,
                    softcap: float = 0.0, scale: float = 0.0,
                    q_offset: Optional[jax.Array] = None,
                    kv_len: Optional[jax.Array] = None,
                    k_positions: Optional[jax.Array] = None) -> jax.Array:
    """O(Sq*Skv) oracle. Compute in f32, return q.dtype.

    ``k_positions`` (B, Skv): explicit absolute key positions (ring-buffer
    caches); slots marked -1 are masked out.
    """
    B, Sq, H, D = q.shape
    Skv = k.shape[1]
    sc = scale or (1.0 / D ** 0.5)
    # mixed precision: keep K/V in their storage dtype (bf16 caches read
    # once, no f32 copies) and accumulate the dots in f32 (MXU-native)
    kf = _repeat_kv(k, H)
    vf = _repeat_kv(v, H)
    qf = (q.astype(jnp.float32) * sc).astype(q.dtype)
    scores = jnp.einsum("bqhd,bkhd->bhqk", qf, kf,
                        preferred_element_type=jnp.float32)
    scores = _softcap(scores, softcap)
    q_pos = _positions(B, Sq, q_offset)
    k_pos = (k_positions.astype(jnp.int32) if k_positions is not None
             else _positions(B, Skv, None))
    mask = _mask(q_pos, k_pos, causal=causal, window=window, kv_len=kv_len,
                 explicit_kpos=k_positions is not None)
    scores = jnp.where(mask[:, None, :, :], scores, NEG_INF)
    probs = jax.nn.softmax(scores, axis=-1)
    out = jnp.einsum("bhqk,bkhd->bqhd", probs.astype(vf.dtype), vf,
                     preferred_element_type=jnp.float32)
    return out.astype(q.dtype)


def attention_chunked(q, k, v, *, causal: bool = True, window: int = 0,
                      softcap: float = 0.0, scale: float = 0.0,
                      q_offset: Optional[jax.Array] = None,
                      kv_len: Optional[jax.Array] = None,
                      kv_chunk: int = 512) -> jax.Array:
    """Online-softmax over KV chunks: peak activation O(Sq * kv_chunk).

    The production software fallback; equals ``attention_naive`` to f32
    rounding (tested).  Used by the dry-run as the XLA attention path.
    """
    B, Sq, H, D = q.shape
    Skv = k.shape[1]
    Dv = v.shape[-1]   # may be < D under reduced-width (surviving lanes)
    C = min(kv_chunk, Skv)
    if Skv % C:  # pad KV to a chunk multiple; padding masked via kv_len
        pad = C - Skv % C
        k = jnp.pad(k, ((0, 0), (0, pad), (0, 0), (0, 0)))
        v = jnp.pad(v, ((0, 0), (0, pad), (0, 0), (0, 0)))
        kv_len = (kv_len if kv_len is not None
                  else jnp.full((B,), Skv, jnp.int32))
        Skv = Skv + pad
    nC = Skv // C
    sc = scale or (1.0 / D ** 0.5)
    qf = q.astype(jnp.float32) * sc
    q_pos = _positions(B, Sq, q_offset)

    kc = _repeat_kv(k, H).reshape(B, nC, C, H, D).transpose(1, 0, 2, 3, 4)
    vc = _repeat_kv(v, H).reshape(B, nC, C, H, Dv).transpose(1, 0, 2, 3, 4)

    def body(carry, xs):
        m_prev, l_prev, acc = carry
        ci, kb, vb = xs
        scores = jnp.einsum("bqhd,bkhd->bhqk", qf.astype(kb.dtype), kb,
                            preferred_element_type=jnp.float32)
        scores = _softcap(scores, softcap)
        k_pos = (ci * C + jnp.arange(C, dtype=jnp.int32))[None, :]
        k_pos = jnp.broadcast_to(k_pos, (B, C))
        mask = _mask(q_pos, k_pos, causal=causal, window=window, kv_len=kv_len)
        scores = jnp.where(mask[:, None, :, :], scores, NEG_INF)
        m_cur = jnp.max(scores, axis=-1)
        m_new = jnp.maximum(m_prev, m_cur)
        p = jnp.exp(scores - m_new[..., None])
        l_corr = jnp.exp(m_prev - m_new)
        l_new = l_prev * l_corr + jnp.sum(p, axis=-1)
        acc = acc * l_corr[..., None] + jnp.einsum(
            "bhqk,bkhd->bhqd", p.astype(vb.dtype), vb,
            preferred_element_type=jnp.float32)
        return (m_new, l_new, acc), None

    m0 = jnp.full((B, H, Sq), NEG_INF, jnp.float32)
    l0 = jnp.zeros((B, H, Sq), jnp.float32)
    a0 = jnp.zeros((B, H, Sq, Dv), jnp.float32)
    ci = jnp.arange(nC, dtype=jnp.int32)
    # checkpoint the chunk body: backward residuals are then one chunk's
    # (m, l, acc) carry instead of every chunk's (B,H,Sq,C) score tensors
    (m, l, acc), _ = jax.lax.scan(jax.checkpoint(body), (m0, l0, a0),
                                  (ci, kc, vc))
    out = acc / jnp.maximum(l, 1e-30)[..., None]
    return out.transpose(0, 2, 1, 3).astype(q.dtype)


def attention_ref_blocked(q, k, v, *, causal: bool = True, window: int = 0,
                          softcap: float = 0.0, scale: float = 0.0,
                          kv_len: int = 0, bq: int = 128, bk: int = 128):
    """Pure-jnp replica of the Pallas flash kernel's *blocked* algorithm.

    Layout (B, H, S, D) like ``kernel.flash_attention_bhsd``; same block
    skipping, same masks, same f32 online-softmax update order, same
    GQA head mapping — interpret-mode kernel output must match this
    oracle **bit-for-bit** for every admissible (bq, bk).  The parity
    tests sweep the tuner's whole config space against it.
    """
    B, H, Sq, D = q.shape
    _, Hkv, Skv, _ = k.shape
    assert Sq % bq == 0 and Skv % bk == 0, (Sq, bq, Skv, bk)
    nq, nk = Sq // bq, Skv // bk
    sc = scale or (1.0 / D ** 0.5)
    kv_len = kv_len or Skv
    out = jnp.zeros((B, H, Sq, D), q.dtype)
    for b in range(B):
        for h in range(H):
            kh = h * Hkv // H  # the kernel's GQA BlockSpec index map
            for qi in range(nq):
                q_start = qi * bq
                m = jnp.full((bq, 1), NEG_INF, jnp.float32)
                l = jnp.zeros((bq, 1), jnp.float32)
                acc = jnp.zeros((bq, D), jnp.float32)
                for ki in range(nk):
                    k_start = ki * bk
                    run = k_start < kv_len
                    if causal:
                        run &= k_start <= q_start + bq - 1
                    if window and window > 0:
                        run &= (k_start + bk - 1) > (q_start - window)
                    if not run:
                        continue
                    qb = q[b, h, q_start:q_start + bq].astype(
                        jnp.float32) * sc
                    kb = k[b, kh, k_start:k_start + bk].astype(jnp.float32)
                    vb = v[b, kh, k_start:k_start + bk].astype(jnp.float32)
                    s = jax.lax.dot_general(
                        qb, kb, (((1,), (1,)), ((), ())),
                        preferred_element_type=jnp.float32)
                    if softcap and softcap > 0.0:
                        s = jnp.tanh(s / softcap) * softcap
                    qp = q_start + jax.lax.broadcasted_iota(
                        jnp.int32, (bq, bk), 0)
                    kp = k_start + jax.lax.broadcasted_iota(
                        jnp.int32, (bq, bk), 1)
                    mask = kp < kv_len
                    if causal:
                        mask &= kp <= qp
                    if window and window > 0:
                        mask &= kp > qp - window
                    s = jnp.where(mask, s, NEG_INF)
                    m_cur = jnp.max(s, axis=1, keepdims=True)
                    m_new = jnp.maximum(m, m_cur)
                    p = jnp.exp(s - m_new)
                    corr = jnp.exp(m - m_new)
                    l = l * corr + jnp.sum(p, axis=1, keepdims=True)
                    m = m_new
                    pv = jax.lax.dot_general(
                        p, vb, (((1,), (0,)), ((), ())),
                        preferred_element_type=jnp.float32)
                    acc = acc * corr + pv
                o = (acc / jnp.maximum(l, 1e-30)).astype(q.dtype)
                out = out.at[b, h, q_start:q_start + bq].set(o)
    return out


def attention_flops(B, Sq, Skv, H, D, causal=True) -> int:
    """Analytic useful-FLOP model (used by the roofline report)."""
    frac = 0.5 if (causal and Sq == Skv) else 1.0
    return int(4 * B * H * Sq * Skv * D * frac)
