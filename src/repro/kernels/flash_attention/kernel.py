"""Pallas TPU flash-attention kernel (the Viscosity "hardware" lowering).

TPU-native design notes (vs. the usual CUDA flash kernels):
  * grid = (B, H, nQ, nK) with nK minor-most: TPU grids execute
    sequentially minor-to-major, so the online-softmax running state
    (m, l, acc) lives in VMEM scratch and persists across the nK loop —
    the analogue of a warp-resident accumulator on GPU.
  * blocks are MXU-aligned (128x128 score tiles); both dot products use
    ``preferred_element_type=f32`` so the MXU accumulates in f32.
  * causal / sliding-window block skipping via ``pl.when`` on grid indices:
    skipped blocks issue no MXU work (the structural analogue of warp
    early-exit).
  * GQA is resolved in the k/v BlockSpec index maps (q head h reads kv head
    h * Hkv // H) — no materialized head repetition in HBM.

Supports: causal, sliding window, logit softcap, GQA, tail padding via a
static ``kv_len``.  Layout inside the kernel: (B, H, S, D).
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

from repro.viscosity.lanefault import apply_fault

NEG_INF = -1e30


def _attn_kernel(q_ref, k_ref, v_ref, o_ref, m_scr, l_scr, acc_scr, *,
                 scale: float, causal: bool, window: int, softcap: float,
                 bq: int, bk: int, nk: int, kv_len: int, lane_fault=None):
    qi = pl.program_id(2)
    ki = pl.program_id(3)
    q_start = qi * bq
    k_start = ki * bk

    @pl.when(ki == 0)
    def _init():
        m_scr[...] = jnp.full_like(m_scr, NEG_INF)
        l_scr[...] = jnp.zeros_like(l_scr)
        acc_scr[...] = jnp.zeros_like(acc_scr)

    # Block-level admissibility (static grid indices -> cheap scalar preds).
    run = k_start < kv_len
    if causal:
        run &= k_start <= q_start + bq - 1
    if window and window > 0:
        run &= (k_start + bk - 1) > (q_start - window)

    @pl.when(run)
    def _compute():
        q = q_ref[0, 0].astype(jnp.float32) * scale              # (bq, D)
        k = k_ref[0, 0].astype(jnp.float32)                      # (bk, D)
        v = v_ref[0, 0].astype(jnp.float32)                      # (bk, D)
        s = jax.lax.dot_general(q, k, (((1,), (1,)), ((), ())),
                                preferred_element_type=jnp.float32)
        if softcap and softcap > 0.0:
            s = jnp.tanh(s / softcap) * softcap
        qp = q_start + jax.lax.broadcasted_iota(jnp.int32, (bq, bk), 0)
        kp = k_start + jax.lax.broadcasted_iota(jnp.int32, (bq, bk), 1)
        mask = kp < kv_len
        if causal:
            mask &= kp <= qp
        if window and window > 0:
            mask &= kp > qp - window
        s = jnp.where(mask, s, NEG_INF)

        m_prev = m_scr[...]                                       # (bq, 1)
        l_prev = l_scr[...]
        m_cur = jnp.max(s, axis=1, keepdims=True)
        m_new = jnp.maximum(m_prev, m_cur)
        p = jnp.exp(s - m_new)                                    # (bq, bk)
        corr = jnp.exp(m_prev - m_new)
        l_scr[...] = l_prev * corr + jnp.sum(p, axis=1, keepdims=True)
        m_scr[...] = m_new
        pv = jax.lax.dot_general(p, v, (((1,), (0,)), ((), ())),
                                 preferred_element_type=jnp.float32)
        acc_scr[...] = acc_scr[...] * corr + pv

    @pl.when(ki == nk - 1)
    def _finalize():
        l = jnp.maximum(l_scr[...], 1e-30)
        # Value-level fault injection (lanefault): masked corruption of the
        # normalized output tile's head_dim lanes, only present in the
        # trace when a fault is registered.
        o_ref[0, 0] = apply_fault(acc_scr[...] / l,
                                  lane_fault).astype(o_ref.dtype)


def flash_attention_bhsd(q, k, v, *, causal: bool = True, window: int = 0,
                         softcap: float = 0.0, scale: float = 0.0,
                         kv_len: int = 0, bq: int = 128, bk: int = 128,
                         interpret: bool = False, lane_fault=None):
    """q: (B, H, Sq, D); k: (B, Hkv, Skv, D); v: (B, Hkv, Skv, Dv).
    Sq % bq == Skv % bk == 0.  The output head_dim is ``v.shape[3]`` —
    normally D, narrower under DEGRADED_REDUCED (reduced-width execution
    slices v to the surviving lanes)."""
    B, H, Sq, D = q.shape
    _, Hkv, Skv, _ = k.shape
    Dv = v.shape[3]
    assert Sq % bq == 0 and Skv % bk == 0, (Sq, bq, Skv, bk)
    assert H % Hkv == 0
    nq, nk = Sq // bq, Skv // bk
    sc = scale or (1.0 / D ** 0.5)
    kv_len = kv_len or Skv

    kernel = functools.partial(
        _attn_kernel, scale=sc, causal=causal, window=window,
        softcap=softcap, bq=bq, bk=bk, nk=nk, kv_len=kv_len,
        lane_fault=lane_fault)

    grid = (B, H, nq, nk)
    return pl.pallas_call(
        kernel,
        grid=grid,
        in_specs=[
            pl.BlockSpec((1, 1, bq, D), lambda b, h, qi, ki: (b, h, qi, 0)),
            pl.BlockSpec((1, 1, bk, D),
                         lambda b, h, qi, ki, Hkv=Hkv, H=H: (b, h * Hkv // H, ki, 0)),
            pl.BlockSpec((1, 1, bk, Dv),
                         lambda b, h, qi, ki, Hkv=Hkv, H=H: (b, h * Hkv // H, ki, 0)),
        ],
        out_specs=pl.BlockSpec((1, 1, bq, Dv), lambda b, h, qi, ki: (b, h, qi, 0)),
        out_shape=jax.ShapeDtypeStruct((B, H, Sq, Dv), q.dtype),
        scratch_shapes=[
            pltpu.VMEM((bq, 1), jnp.float32),
            pltpu.VMEM((bq, 1), jnp.float32),
            pltpu.VMEM((bq, Dv), jnp.float32),
        ],
        interpret=interpret,
    )(q, k, v)
