"""Pallas TPU kernels (the Oobleck "hardware" lowerings) + jnp oracles.

Each subpackage: kernel.py (pl.pallas_call + BlockSpec), ops.py (jit'd
wrapper + Viscosity registration), ref.py (pure-jnp oracle / fallback).
"""
