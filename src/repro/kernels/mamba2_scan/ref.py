"""Pure-jnp oracles for the Mamba2 SSD scan.

Shapes (ngroups = 1):
  x  (B, S, H, P)   inner activations split into H heads of dim P
  dt (B, S, H)      positive step sizes (softplus applied upstream)
  A  (H,)           negative per-head decay
  B_ (B, S, N)      input projection onto N-dim state
  C  (B, S, N)      output projection
  y  (B, S, H, P);  state (B, H, N, P)

Recurrence:  h_t = exp(dt_t A) h_{t-1} + dt_t * (B_t outer x_t)
             y_t = C_t . h_t
"""
from __future__ import annotations

import jax
import jax.numpy as jnp


def ssd_scan_ref(x, dt, A, B_, C):
    """Naive token-by-token scan (oracle)."""
    Bt, S, H, P = x.shape
    N = B_.shape[-1]
    xf, dtf = x.astype(jnp.float32), dt.astype(jnp.float32)
    Bf, Cf = B_.astype(jnp.float32), C.astype(jnp.float32)
    Af = A.astype(jnp.float32)

    def step(h, inp):
        xt, dtt, bt, ct = inp                    # (B,H,P) (B,H) (B,N) (B,N)
        decay = jnp.exp(dtt * Af[None, :])       # (B,H)
        upd = dtt[..., None, None] * bt[:, None, :, None] * xt[:, :, None, :]
        h = h * decay[..., None, None] + upd     # (B,H,N,P)
        y = jnp.einsum("bn,bhnp->bhp", ct, h)
        return h, y

    h0 = jnp.zeros((Bt, H, N, P), jnp.float32)
    xs = (xf.transpose(1, 0, 2, 3), dtf.transpose(1, 0, 2),
          Bf.transpose(1, 0, 2), Cf.transpose(1, 0, 2))
    hT, ys = jax.lax.scan(step, h0, xs)
    return ys.transpose(1, 0, 2, 3).astype(x.dtype), hT


def ssd_chunked(x, dt, A, B_, C, *, chunk: int = 128):
    """Chunked SSD (matmul form) — production software path / XLA lowering.

    All decays are exp of non-positive quantities (A<0, dt>0): numerically
    safe in f32 without log-space tricks.
    """
    Bt, S, H, P = x.shape
    N = B_.shape[-1]
    L = min(chunk, S)
    if S % L:
        pad = L - S % L
        x = jnp.pad(x, ((0, 0), (0, pad), (0, 0), (0, 0)))
        dt = jnp.pad(dt, ((0, 0), (0, pad), (0, 0)))
        B_ = jnp.pad(B_, ((0, 0), (0, pad), (0, 0)))
        C = jnp.pad(C, ((0, 0), (0, pad), (0, 0)))
    Sp = x.shape[1]
    nc = Sp // L

    xf = x.astype(jnp.float32).reshape(Bt, nc, L, H, P)
    dtf = dt.astype(jnp.float32).reshape(Bt, nc, L, H)
    Bf = B_.astype(jnp.float32).reshape(Bt, nc, L, N)
    Cf = C.astype(jnp.float32).reshape(Bt, nc, L, N)
    Af = A.astype(jnp.float32)
    xdt = xf * dtf[..., None]

    mask = jnp.tril(jnp.ones((L, L), bool))

    def body(state, inp):
        xc, bc, cc, dac = inp          # (B,L,H,P) (B,L,N) (B,L,N) (B,L,H)
        cum = jnp.cumsum(dac, axis=1)                       # (B,L,H)
        cb = jnp.einsum("bln,bsn->bls", cc, bc)             # (B,L,L)
        dec = jnp.exp(cum[:, :, None, :] - cum[:, None, :, :])   # (B,L,L,H)
        w = cb[..., None] * dec * mask[None, :, :, None]
        y_intra = jnp.einsum("blsh,bshp->blhp", w, xc)
        y_state = jnp.einsum("bln,bhnp->blhp", cc, state) * \
            jnp.exp(cum).transpose(0, 1, 2)[..., None]
        tot = cum[:, -1:, :]                                 # (B,1,H)
        bscale = jnp.exp(tot - cum)                          # (B,L,H)
        upd = jnp.einsum("bln,blhp->bhnp", bc[..., :], xc * bscale[..., None])
        state = state * jnp.exp(tot)[:, 0, :, None, None] + upd
        return state, y_intra + y_state

    h0 = jnp.zeros((Bt, H, N, P), jnp.float32)
    da = dtf * Af[None, None, None, :]
    xs = (xdt.transpose(1, 0, 2, 3, 4), Bf.transpose(1, 0, 2, 3),
          Cf.transpose(1, 0, 2, 3), da.transpose(1, 0, 2, 3))
    hT, ys = jax.lax.scan(body, h0, xs)
    y = ys.transpose(1, 0, 2, 3, 4).reshape(Bt, Sp, H, P)[:, :S]
    return y.astype(x.dtype), hT


def ssd_step(state, x_t, dt_t, A, B_t, C_t):
    """Single decode step. state (B,H,N,P); returns (y_t, state)."""
    decay = jnp.exp(dt_t.astype(jnp.float32) * A[None, :])
    upd = dt_t[..., None, None].astype(jnp.float32) * \
        B_t[:, None, :, None].astype(jnp.float32) * \
        x_t[:, :, None, :].astype(jnp.float32)
    state = state * decay[..., None, None] + upd
    y = jnp.einsum("bn,bhnp->bhp", C_t.astype(jnp.float32), state)
    return y.astype(x_t.dtype), state


def ssd_flops(B, S, H, P, N, chunk=128) -> int:
    L = min(chunk, S)
    per_chunk = 2 * L * L * N + 2 * L * L * P * H + 4 * L * N * P * H
    return int(B * (S // max(L, 1)) * per_chunk)
