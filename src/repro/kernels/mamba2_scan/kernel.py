"""Pallas TPU kernel for the chunked Mamba2 SSD scan.

TPU-native design: grid = (B, H, n_chunks) with the chunk axis minor-most;
the (N, P) SSM state lives in VMEM scratch and persists across sequential
chunk iterations (the recurrent carry).  All per-chunk work is expressed as
MXU matmuls on (L, N)/(L, P) tiles:

    CB     = C @ B^T                      (L, L)  MXU
    y_in   = (CB * decay * mask) @ xdt    (L, L)@(L, P)  MXU
    y_st   = (C @ state) * exp(cum)       (L, N)@(N, P)  MXU
    state' = exp(tot) * state + (B*scale)^T @ xdt  (N, L)@(L, P)  MXU

Inputs are pre-scaled outside the kernel: ``xdt = x * dt`` and
``da = dt * A`` so the kernel touches only dense, layout-friendly operands.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

from repro.viscosity.lanefault import apply_fault


def _ssd_kernel(xdt_ref, da_ref, b_ref, c_ref, y_ref, state_scr, *,
                L: int, N: int, P: int, lane_fault=None):
    ci = pl.program_id(2)

    @pl.when(ci == 0)
    def _init():
        state_scr[...] = jnp.zeros_like(state_scr)

    xdt = xdt_ref[0, :, 0, :].astype(jnp.float32)      # (L, P)
    da = da_ref[0, :, 0].astype(jnp.float32)           # (L,)
    b = b_ref[0].astype(jnp.float32)                   # (L, N)
    c = c_ref[0].astype(jnp.float32)                   # (L, N)

    cum = jnp.cumsum(da)                               # (L,)
    cb = jax.lax.dot_general(c, b, (((1,), (1,)), ((), ())),
                             preferred_element_type=jnp.float32)  # (L, L)
    rows = jax.lax.broadcasted_iota(jnp.int32, (L, L), 0)
    cols = jax.lax.broadcasted_iota(jnp.int32, (L, L), 1)
    dec = jnp.exp(cum[:, None] - cum[None, :])
    w = jnp.where(rows >= cols, cb * dec, 0.0)
    y_intra = jax.lax.dot_general(w, xdt, (((1,), (0,)), ((), ())),
                                  preferred_element_type=jnp.float32)
    state = state_scr[...]                             # (N, P)
    y_state = jax.lax.dot_general(c, state, (((1,), (0,)), ((), ())),
                                  preferred_element_type=jnp.float32)
    y = y_intra + y_state * jnp.exp(cum)[:, None]
    # Value-level fault injection (lanefault): masked corruption of the
    # chunk's head-channel lane axis; absent from the trace when healthy.
    y_ref[0, :, 0, :] = apply_fault(y, lane_fault).astype(y_ref.dtype)

    tot = cum[L - 1]
    bscale = b * jnp.exp(tot - cum)[:, None]           # (L, N)
    upd = jax.lax.dot_general(bscale, xdt, (((0,), (0,)), ((), ())),
                              preferred_element_type=jnp.float32)
    state_scr[...] = state * jnp.exp(tot) + upd


def ssd_chunked_pallas(x, dt, A, B_, C, *, chunk: int = 128,
                       interpret: bool = False, lane_fault=None):
    """x (B,S,H,P), dt (B,S,H), A (H,), B_/C (B,S,N) -> y (B,S,H,P).

    S must be a multiple of ``chunk`` (ops wrapper pads).  Final state is
    not returned by the kernel path (training does not need it; decode uses
    ``ssd_step``).
    """
    Bt, S, H, P = x.shape
    N = B_.shape[-1]
    L = min(chunk, S)
    assert S % L == 0, (S, L)
    nc = S // L

    xdt = (x.astype(jnp.float32) * dt.astype(jnp.float32)[..., None])
    da = dt.astype(jnp.float32) * A.astype(jnp.float32)[None, None, :]

    kernel = functools.partial(_ssd_kernel, L=L, N=N, P=P,
                               lane_fault=lane_fault)
    grid = (Bt, H, nc)
    y = pl.pallas_call(
        kernel,
        grid=grid,
        in_specs=[
            pl.BlockSpec((1, L, 1, P), lambda b, h, ci: (b, ci, h, 0)),
            pl.BlockSpec((1, L, 1), lambda b, h, ci: (b, ci, h)),
            pl.BlockSpec((1, L, N), lambda b, h, ci: (b, ci, 0)),
            pl.BlockSpec((1, L, N), lambda b, h, ci: (b, ci, 0)),
        ],
        out_specs=pl.BlockSpec((1, L, 1, P), lambda b, h, ci: (b, ci, h, 0)),
        out_shape=jax.ShapeDtypeStruct((Bt, S, H, P), x.dtype),
        scratch_shapes=[pltpu.VMEM((N, P), jnp.float32)],
        interpret=interpret,
    )(xdt, da, B_, C)
    return y
