"""Jit'd wrapper + Viscosity registration for the Mamba2 SSD stage."""
from __future__ import annotations

import functools

import jax.numpy as jnp

from repro import viscosity
from repro.kernels import tuning
from repro.kernels.mamba2_scan import ref as _ref
from repro.kernels.mamba2_scan.kernel import ssd_chunked_pallas
from repro.viscosity import lanefault


def _tuned_chunk(kind, x, B_, default):
    cfg = tuning.lookup(
        "mamba2_ssd", kind,
        (x.shape[0], x.shape[1], x.shape[2], x.shape[3], B_.shape[-1]),
        x.dtype) or {}
    return cfg.get("chunk") or default


def _sw(x, dt, A, B_, C, *, chunk=None):
    chunk = chunk or _tuned_chunk("sw", x, B_, 128)
    y, _ = _ref.ssd_chunked(x, dt, A, B_, C, chunk=chunk)
    return y


def _hw(x, dt, A, B_, C, *, chunk=None, interpret: bool = False):
    chunk = chunk or _tuned_chunk("hw", x, B_, 128)
    S = x.shape[1]
    L = min(chunk, S)
    if S % L:
        pad = L - S % L
        x = jnp.pad(x, ((0, 0), (0, pad), (0, 0), (0, 0)))
        dt = jnp.pad(dt, ((0, 0), (0, pad), (0, 0)))
        B_ = jnp.pad(B_, ((0, 0), (0, pad), (0, 0)))
        C = jnp.pad(C, ((0, 0), (0, pad), (0, 0)))
    y = ssd_chunked_pallas(x, dt, A, B_, C, chunk=L, interpret=interpret,
                           lane_fault=lanefault.injection("mamba2_ssd"))
    return y[:, :S]


def _lane_slicer(args, kw, keep):
    # y's head-channel lane j depends only on x[..., j] (the SSD mixes over
    # sequence/state, never across P): slicing x is exact reduced width.
    x, dt, A, B_, C = args
    return (x[..., jnp.asarray(keep, jnp.int32)], dt, A, B_, C), kw


SSD = viscosity.defop(
    "mamba2_ssd",
    ref=_sw,
    kernel=_hw,
    interpret=functools.partial(_hw, interpret=True),
    valid=viscosity.finite_valid,
    tol=2e-2,
    flops=lambda x, dt, A, B_, C, **kw: _ref.ssd_flops(
        x.shape[0], x.shape[1], x.shape[2], x.shape[3], B_.shape[-1]),
    lane_slicer=_lane_slicer,
)


def ssd(x, dt, A, B_, C, *, route: str = viscosity.SW, **kw):
    return SSD(x, dt, A, B_, C, route=route, **kw)
