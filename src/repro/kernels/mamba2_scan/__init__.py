from repro.kernels.mamba2_scan.ops import SSD, ssd
from repro.kernels.mamba2_scan.ref import (ssd_chunked, ssd_flops,
                                           ssd_scan_ref, ssd_step)

__all__ = ["SSD", "ssd", "ssd_chunked", "ssd_scan_ref", "ssd_step",
           "ssd_flops"]
