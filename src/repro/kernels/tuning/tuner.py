"""Sweep + hillclimb autotuner for kernel block sizes.

The search loop is the same shape as ``launch/hillclimb.py``'s variant
search — measure a baseline, measure candidates, keep the best, propose
neighbors — specialized from roofline terms down to wall time:

  1. **sweep**: measure every admissible config on a coarse grid (the
     space's declared choices), capped by ``budget``;
  2. **hillclimb**: from the sweep's argmin, walk one-knob/one-step
     neighbors until no move improves (coordinate descent over the
     choice lattice) or the budget runs out.

Measurement is wall time, best-of-``reps`` after a warmup call (the
warmup also pays compilation, so jit time never pollutes the score).
The kernel's *current default* config is always seeded into the sweep,
so a persisted tuned config is never worse than the default up to
measurement noise.

``tune`` accepts an injectable ``measure`` callable (tests drive the
search with synthetic cost surfaces; no compilation needed).
"""
from __future__ import annotations

import time
from typing import Callable, Dict, Mapping, Optional, Sequence, Tuple

from repro.kernels.tuning.space import KernelSpace, space_for


def measure_wall_us(fn: Callable[[], object], *, reps: int = 5,
                    warmup: int = 1) -> float:
    """Best-of-``reps`` wall time of ``fn()`` in microseconds.

    ``fn`` must block until its result is ready (callers close over
    ``jax.block_until_ready``); best-of suppresses scheduler noise, which
    matters more than averaging for CI-grade comparisons.
    """
    for _ in range(warmup):
        fn()
    best = float("inf")
    for _ in range(reps):
        t0 = time.perf_counter()
        fn()
        best = min(best, time.perf_counter() - t0)
    return best * 1e6


def _as_key(cfg: Mapping[str, int]) -> Tuple[Tuple[str, int], ...]:
    return tuple(sorted((k, int(v)) for k, v in cfg.items()))


def tune(kernel: str, kind: str, shape: Sequence[int], *,
         space: Optional[KernelSpace] = None,
         measure: Callable[[Dict[str, int]], float],
         seed_cfgs: Sequence[Mapping[str, int]] = (),
         budget: int = 24,
         log: Optional[Callable[[str], None]] = None
         ) -> Tuple[Dict[str, int], float, int]:
    """Search ``space`` for the fastest admissible config.

    ``measure(cfg) -> us`` scores one config (lower is better); a config
    whose measurement raises is discarded — a crashing tile choice must
    never abort the search, the kernel simply keeps its default.

    Returns ``(best_cfg, best_us, evals)``.  Raises only when *no*
    config could be measured at all.
    """
    space = space or space_for(kernel, kind)
    if space is None:
        raise KeyError(f"no declared search space for ({kernel}, {kind})")
    shape = tuple(int(d) for d in shape)

    seen: Dict[Tuple, float] = {}
    evals = 0

    def score(cfg: Dict[str, int]) -> Optional[float]:
        nonlocal evals
        key = _as_key(cfg)
        if key in seen:
            return seen[key]
        if evals >= budget:
            return None
        evals += 1
        try:
            us = float(measure(cfg))
        except Exception as e:  # noqa: BLE001 - bad tile != failed search
            if log:
                log(f"tune[{kernel}/{kind}]: {cfg} failed: {e!r}")
            seen[key] = float("inf")
            return None
        seen[key] = us
        if log:
            log(f"tune[{kernel}/{kind}]: {cfg} -> {us:.1f}us")
        return us

    # ----------------------------------------------------------- sweep
    candidates = []
    for cfg in seed_cfgs:
        if space.admissible(cfg, shape):
            candidates.append(dict(cfg))
    if space.defaults and space.admissible(space.defaults, shape):
        candidates.append(dict(space.defaults))
    candidates.extend(space.configs(shape))

    best_cfg: Optional[Dict[str, int]] = None
    best_us = float("inf")
    for cfg in candidates:
        us = score(cfg)
        if us is not None and us < best_us:
            best_cfg, best_us = cfg, us
        if evals >= budget:
            break
    if best_cfg is None:
        raise RuntimeError(
            f"tuner measured no admissible config for {kernel}/{kind} "
            f"shape={shape} within budget={budget}")

    # ------------------------------------------------------- hillclimb
    improved = True
    while improved and evals < budget:
        improved = False
        for cand in space.neighbors(best_cfg, shape):
            us = score(cand)
            if us is not None and us < best_us:
                best_cfg, best_us = cand, us
                improved = True
                break  # greedy: re-propose around the new optimum
    return best_cfg, best_us, evals


def jax_measure(make_fn: Callable[[Dict[str, int]], Callable],
                args: Tuple, *, reps: int = 5
                ) -> Callable[[Dict[str, int]], float]:
    """Standard measure closure: build + jit per config, time blocked.

    ``make_fn(cfg)`` returns a callable over ``args`` (typically a
    ``jax.jit`` with the config's tile sizes baked in as static values).
    """
    import jax

    def _measure(cfg: Dict[str, int]) -> float:
        fn = make_fn(cfg)

        def call():
            return jax.block_until_ready(fn(*args))

        return measure_wall_us(call, reps=reps)

    return _measure
