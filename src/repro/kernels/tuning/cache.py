"""Deterministic on-disk JSON cache for tuned kernel configs.

One file (``tuning_cache.json`` under the cache directory) holds every
tuned entry, grouped by **backend fingerprint** — jax version + platform
+ device kind — so a cache written on one backend can never leak tile
choices onto another: a fingerprint change is a cold miss, not a wrong
answer.  Writes are deterministic (sorted keys, stable separators) so a
committed cache diffs cleanly.

Entry keys are flat strings::

    <kernel>|<kind>|<shape as AxBxC>|<dtype>|<plan>

where ``plan`` is ``default`` or the short digest of the routing-plan
compile key the Dispatcher was building under (see ``tuning.plan_scope``)
— the RedMulE-FT observation that a degraded plan can prefer different
tiling than the healthy one, made concrete in the key.
"""
from __future__ import annotations

import hashlib
import json
import os
import tempfile
import threading
from typing import Dict, Mapping, Optional, Sequence, Tuple

SCHEMA = 1
DEFAULT_PLAN = "default"


def backend_fingerprint() -> str:
    """jax version + platform + device kind: the cache partition key."""
    try:
        import jax

        dev = jax.devices()[0]
        kind = getattr(dev, "device_kind", dev.platform)
        return f"jax-{jax.__version__}/{dev.platform}/{kind}"
    except Exception:
        return "jax-unknown/none/none"


def plan_digest(plan_key) -> str:
    """Short, process-stable digest of a Dispatcher plan key.

    RoutingPlan / FleetPlan.compile_key() are frozen tuples with
    deterministic reprs; the builtin ``hash`` is salted per process, so
    the digest hashes the repr instead.
    """
    if plan_key is None:
        return DEFAULT_PLAN
    return hashlib.sha256(repr(plan_key).encode()).hexdigest()[:12]


def entry_key(kernel: str, kind: str, shape: Sequence[int], dtype,
              plan: Optional[str] = None) -> str:
    shape_s = "x".join(str(int(d)) for d in shape)
    dtype_s = getattr(dtype, "name", None) or str(dtype)
    return f"{kernel}|{kind}|{shape_s}|{dtype_s}|{plan or DEFAULT_PLAN}"


def default_cache_dir() -> str:
    env = os.environ.get("REPRO_TUNING_CACHE")
    if env:
        return env
    # repo-root artifacts/tuning (three levels up from this file's package)
    here = os.path.dirname(os.path.abspath(__file__))
    root = os.path.dirname(os.path.dirname(os.path.dirname(
        os.path.dirname(here))))
    return os.path.join(root, "artifacts", "tuning")


class TuningCache:
    """Load-once, write-atomically JSON cache of tuned configs.

    ``get`` returns the stored config dict (plus ``us`` measurement
    metadata under ``_meta``-prefixed keys stripped) or None; it never
    raises — a corrupt or unreadable cache behaves as empty, because a
    missing tuning entry must only ever cost performance, not correctness.
    """

    def __init__(self, path: Optional[str] = None,
                 fingerprint: Optional[str] = None):
        self.dir = path or default_cache_dir()
        self.path = os.path.join(self.dir, "tuning_cache.json")
        self.fingerprint = fingerprint or backend_fingerprint()
        self._lock = threading.Lock()
        self._doc: Optional[Dict] = None

    # ----------------------------------------------------------- loading
    def _load(self) -> Dict:
        if self._doc is None:
            try:
                with open(self.path) as f:
                    doc = json.load(f)
                if not isinstance(doc, dict) or \
                        not isinstance(doc.get("by_backend"), dict):
                    raise ValueError("malformed tuning cache")
            except Exception:
                doc = {"schema": SCHEMA, "by_backend": {}}
            self._doc = doc
        return self._doc

    def invalidate(self) -> None:
        """Drop the in-memory copy (re-read on next access)."""
        with self._lock:
            self._doc = None

    # ------------------------------------------------------------ access
    def _section(self) -> Dict:
        return self._load()["by_backend"].setdefault(self.fingerprint, {})

    def get(self, kernel: str, kind: str, shape: Sequence[int], dtype,
            plan: Optional[str] = None) -> Optional[Dict[str, int]]:
        try:
            with self._lock:
                entry = self._section().get(
                    entry_key(kernel, kind, shape, dtype, plan))
            if not isinstance(entry, dict):
                return None
            return {k: v for k, v in entry.items()
                    if not k.startswith("_")}
        except Exception:
            return None

    def entries(self) -> Dict[str, Dict]:
        """This backend's full section (tests / bench stats)."""
        with self._lock:
            return dict(self._section())

    def put(self, kernel: str, kind: str, shape: Sequence[int], dtype,
            cfg: Mapping[str, int], *, plan: Optional[str] = None,
            us: Optional[float] = None, evals: Optional[int] = None,
            persist: bool = True) -> None:
        entry = {k: int(v) for k, v in sorted(cfg.items())}
        if us is not None:
            entry["_us"] = round(float(us), 3)
        if evals is not None:
            entry["_evals"] = int(evals)
        with self._lock:
            self._section()[entry_key(kernel, kind, shape, dtype, plan)] \
                = entry
            if persist:
                self._flush()

    # --------------------------------------------------------- persisting
    def _flush(self) -> None:
        doc = self._load()
        os.makedirs(self.dir, exist_ok=True)
        fd, tmp = tempfile.mkstemp(dir=self.dir, suffix=".tmp")
        try:
            with os.fdopen(fd, "w") as f:
                json.dump(doc, f, sort_keys=True, indent=1,
                          separators=(",", ": "))
                f.write("\n")
            os.replace(tmp, self.path)
        except Exception:
            try:
                os.unlink(tmp)
            except OSError:
                pass
            raise


# Stats shared by every lookup path (surfaced in BENCH_*.json).
class TunerStats:
    def __init__(self):
        self.hits = 0
        self.misses = 0
        self.tuned = 0

    def as_dict(self) -> Dict[str, int]:
        return {"hits": self.hits, "misses": self.misses,
                "tuned": self.tuned}

    def reset(self) -> None:
        self.hits = self.misses = self.tuned = 0


STATS = TunerStats()


def shape_key(kernel: str, args: Tuple) -> Tuple[int, ...]:
    """Canonical shape tuple for a kernel call (documented in space.py)."""
    if kernel == "flash_attention":
        q, k = args[0], args[1]
        B, Sq, H, D = q.shape
        return (B, Sq, k.shape[1], H, k.shape[2], D)
    if kernel == "swiglu_mlp":
        x, w1 = args[0], args[1]
        return (x.shape[0], x.shape[1], w1.shape[1])
    if kernel == "mamba2_ssd":
        x, B_ = args[0], args[3]
        return (x.shape[0], x.shape[1], x.shape[2], x.shape[3],
                B_.shape[-1])
    if kernel == "rwkv6_wkv":
        r, v = args[0], args[2]
        return (r.shape[0], r.shape[1], r.shape[2], r.shape[3],
                v.shape[-1])
    raise KeyError(f"no canonical shape for kernel {kernel!r}")
