"""Autotuned kernel block sizes (the ROADMAP "raw-speed program").

Three pieces:

  * ``space``  — per-(kernel, lowering-kind) search spaces + the MXU/grid
    admissibility predicate;
  * ``cache``  — deterministic on-disk JSON cache keyed by backend
    fingerprint (and, per entry, by shape/dtype/routing-plan digest);
  * ``tuner``  — sweep + hillclimb search (the ``launch/hillclimb.py``
    loop, specialized to wall time).

This module is the facade the kernel ``ops.py`` entry points consult:

    cfg = tuning.lookup("swiglu_mlp", "hw", (M, D, F), x.dtype)
    bm = (cfg or {}).get("bm", 128)

``lookup`` is **fail-open by construction**: no cache file, no entry,
corrupt JSON, different backend — every failure mode returns None and
the kernel keeps its hardcoded default.  A missing tuning entry costs
performance, never correctness.

Plan-aware tuning: the Dispatcher wraps each plan-keyed build/call in
``plan_scope(plan_key)``; lookups made while tracing under that scope
first try the plan-specific entry, then fall back to the plan-agnostic
``default`` entry.  A kernel running under a degraded RoutingPlan can
therefore carry different tiles than the healthy one (RedMulE-FT's
observation that fault-tolerance modes shift the throughput optimum).
"""
from __future__ import annotations

import contextlib
import contextvars
import os
from typing import Callable, Dict, Optional, Sequence, Tuple

from repro.kernels.tuning import tuner as _tuner
from repro.kernels.tuning.cache import (DEFAULT_PLAN, STATS, TuningCache,
                                        backend_fingerprint, plan_digest,
                                        shape_key)
from repro.kernels.tuning.space import SPACES, admissible, space_for

__all__ = [
    "DEFAULT_PLAN", "SPACES", "TuningCache", "admissible",
    "backend_fingerprint", "current_plan_key", "get_cache", "lookup",
    "plan_digest", "plan_scope", "reset", "set_cache", "shape_key",
    "space_for", "stats", "tune_kernel",
]

# ------------------------------------------------------------ plan scope
_PLAN: contextvars.ContextVar = contextvars.ContextVar(
    "repro_tuning_plan", default=None)


@contextlib.contextmanager
def plan_scope(plan_key):
    """Tag tuner lookups made inside with the active routing-plan key."""
    token = _PLAN.set(plan_key)
    try:
        yield
    finally:
        _PLAN.reset(token)


def current_plan_key():
    return _PLAN.get()


def scoped(plan_key, fn: Callable) -> Callable:
    """``fn`` with every invocation run under ``plan_scope(plan_key)``
    (how the Dispatcher threads its compile key to kernel lookups)."""

    def call(*args, **kw):
        with plan_scope(plan_key):
            return fn(*args, **kw)

    return call


# --------------------------------------------------------- cache handle
_CACHE: Optional[TuningCache] = None


def get_cache() -> TuningCache:
    global _CACHE
    if _CACHE is None:
        _CACHE = TuningCache()
    return _CACHE


def set_cache(cache: Optional[TuningCache]) -> None:
    """Swap the process cache (tests point it at tmp dirs; None resets)."""
    global _CACHE
    _CACHE = cache


def reset() -> None:
    """Drop cache handle + stats (test isolation)."""
    set_cache(None)
    STATS.reset()


def _enabled() -> bool:
    return os.environ.get("REPRO_TUNER", "on").lower() not in (
        "off", "0", "false")


# -------------------------------------------------------------- lookups
def lookup(kernel: str, kind: str, shape: Sequence[int], dtype
           ) -> Optional[Dict[str, int]]:
    """Tuned config for this call site, or None (use the defaults).

    Tries the active plan-scope entry first, then the plan-agnostic
    entry.  Counts hits/misses in ``stats()``.  Never raises.
    """
    if not _enabled():
        return None
    try:
        cache = get_cache()
        plan = plan_digest(current_plan_key())
        cfg = cache.get(kernel, kind, shape, dtype, plan)
        if cfg is None and plan != DEFAULT_PLAN:
            cfg = cache.get(kernel, kind, shape, dtype, DEFAULT_PLAN)
        if cfg is not None and not admissible(kernel, kind, cfg, shape):
            cfg = None  # stale entry from an older space: ignore it
        if cfg is None:
            STATS.misses += 1
        else:
            STATS.hits += 1
        return cfg
    except Exception:
        STATS.misses += 1
        return None


def stats() -> Dict[str, int]:
    return STATS.as_dict()


# --------------------------------------------------------------- tuning
def tune_kernel(kernel: str, kind: str, shape: Sequence[int], dtype, *,
                measure: Callable[[Dict[str, int]], float],
                plan_key=None, budget: int = 24, persist: bool = True,
                cache: Optional[TuningCache] = None,
                log: Optional[Callable[[str], None]] = None
                ) -> Tuple[Dict[str, int], float]:
    """Run the sweep+hillclimb search and record the winner in the cache.

    ``measure(cfg) -> us`` is the scoring callable (see
    ``tuner.jax_measure`` for the standard jit-and-time closure).
    Returns ``(best_cfg, best_us)``.
    """
    cache = cache or get_cache()
    seed = cache.get(kernel, kind, shape, dtype, plan_digest(plan_key))
    best_cfg, best_us, evals = _tuner.tune(
        kernel, kind, shape, measure=measure,
        seed_cfgs=(seed,) if seed else (), budget=budget, log=log)
    cache.put(kernel, kind, shape, dtype, best_cfg,
              plan=plan_digest(plan_key), us=best_us, evals=evals,
              persist=persist)
    STATS.tuned += 1
    return best_cfg, best_us
