"""Search spaces + admissibility for tunable kernel block parameters.

Every Pallas kernel (and its XLA software twin) exposes a small set of
integer tile knobs — flash attention's (bq, bk) score tile, swiglu's
(bm, bf, bs) output/hidden tiles, the scan kernels' chunk length, the
software paths' chunk sizes.  This module is the single declaration of

  * which knobs each kernel has, per lowering kind (``HW`` = Pallas
    block sizes, ``SW`` = XLA-path chunking), and the candidate values
    the tuner may sweep;
  * the **admissibility predicate**: MXU/sublane alignment, grid
    divisibility, and a VMEM budget — the same constraints the kernels
    assert at call time, checked *before* a config is ever measured so
    the tuner can never persist a config the kernel would reject.

Shapes are canonical tuples (the same ones ``tuning.lookup`` keys on):

  flash_attention  (B, Sq, Skv, H, Hkv, D)
  swiglu_mlp       (M, D, F)
  mamba2_ssd       (B, S, H, P, N)
  rwkv6_wkv        (B, S, H, K, V)
"""
from __future__ import annotations

import itertools
from dataclasses import dataclass, field
from typing import Callable, Dict, Mapping, Optional, Sequence, Tuple

# Lowering kinds a space is declared for (mirrors viscosity HW/SW without
# importing it: this module stays a leaf).
HW = "hw"
SW = "sw"

# TPU geometry the admissibility rules encode (see guides/pallas_guide.md):
# MXU is 128x128, the f32 min tile is (8, 128), VMEM is ~16 MB/core — we
# budget half of it for the blocks a single grid step holds live.
MXU_LANE = 128
SUBLANE_F32 = 8
VMEM_BUDGET_BYTES = 8 * 1024 * 1024


@dataclass(frozen=True)
class KernelSpace:
    """The tunable knobs of one (kernel, lowering-kind) pair.

    ``params`` maps knob name -> ordered candidate values (ascending, so
    the hillclimber's neighbor move is "one index up/down").
    ``admissible(cfg, shape)`` is the hard constraint; ``vmem(cfg, shape)``
    estimates live block bytes for the VMEM budget (HW spaces only).
    """

    kernel: str
    kind: str
    params: Mapping[str, Tuple[int, ...]]
    check: Optional[Callable[[Dict[str, int], Tuple[int, ...]], bool]] = None
    vmem: Optional[Callable[[Dict[str, int], Tuple[int, ...]], int]] = None
    defaults: Mapping[str, int] = field(default_factory=dict)

    def admissible(self, cfg: Mapping[str, int],
                   shape: Tuple[int, ...]) -> bool:
        """Is ``cfg`` one the kernel will accept for ``shape``?"""
        for name, choices in self.params.items():
            if name not in cfg or cfg[name] not in choices:
                return False
        cfg = dict(cfg)
        if self.check is not None and not self.check(cfg, tuple(shape)):
            return False
        if self.vmem is not None and self.vmem(cfg, tuple(shape)) > \
                VMEM_BUDGET_BYTES:
            return False
        return True

    def configs(self, shape: Tuple[int, ...]):
        """All admissible configs for ``shape`` (the sweep grid)."""
        names = sorted(self.params)
        for vals in itertools.product(*(self.params[n] for n in names)):
            cfg = dict(zip(names, vals))
            if self.admissible(cfg, shape):
                yield cfg

    def neighbors(self, cfg: Mapping[str, int], shape: Tuple[int, ...]):
        """Admissible one-step moves (one knob, one choice index up/down)
        — the hillclimber's proposal set."""
        for name in sorted(self.params):
            choices = self.params[name]
            i = choices.index(cfg[name])
            for j in (i - 1, i + 1):
                if 0 <= j < len(choices):
                    cand = dict(cfg)
                    cand[name] = choices[j]
                    if self.admissible(cand, shape):
                        yield cand


# ------------------------------------------------------------ flash attn
def _roundup(n: int, m: int) -> int:
    return -(-n // m) * m


def _flash_hw_check(cfg, shape):
    _B, Sq, Skv, _H, _Hkv, _D = shape
    bq, bk = cfg["bq"], cfg["bk"]
    # ops.py pads S up to a block multiple; a block is admissible when it
    # is sublane-aligned and no larger than the padded sequence extent
    # (anything bigger is pure padding work the tuner must not propose).
    return (bq % SUBLANE_F32 == 0 and bk % SUBLANE_F32 == 0
            and bq <= _roundup(max(SUBLANE_F32, Sq), SUBLANE_F32)
            and bk <= _roundup(max(SUBLANE_F32, Skv), SUBLANE_F32))


def _flash_hw_vmem(cfg, shape):
    _B, _Sq, _Skv, _H, _Hkv, D = shape
    bq, bk = cfg["bq"], cfg["bk"]
    # live blocks: q (bq, D), k/v (bk, D), scores (bq, bk), acc (bq, D)
    return 4 * (bq * D + 2 * bk * D + bq * bk + bq * D + 2 * bq)


def _flash_sw_check(cfg, shape):
    _B, _Sq, Skv, _H, _Hkv, _D = shape
    # attention_chunked clamps to min(kv_chunk, Skv) and pads: any positive
    # chunk runs, but chunks beyond Skv are equivalent to Skv.
    return 0 < cfg["kv_chunk"] <= max(128, 2 * Skv)


# ---------------------------------------------------------------- swiglu
def _swiglu_hw_check(cfg, shape):
    M, _D, F = shape
    bm, bf, bs = cfg["bm"], cfg["bf"], cfg["bs"]
    # kernel.py asserts M % bm == 0 and F % bf == 0 (after clamping to the
    # dims) and streams the hidden tile in bs sub-columns: bs | bf.
    bm, bf = min(bm, M), min(bf, F)
    return M % bm == 0 and F % bf == 0 and bf % min(bs, bf) == 0


def _swiglu_hw_vmem(cfg, shape):
    M, D, F = shape
    bm, bf = min(cfg["bm"], M), min(cfg["bf"], F)
    bs = min(cfg["bs"], bf)
    # x (bm, D), w1/w3 (D, bf), w2 (bf, D), acc (bm, D), gate tile (bm, bs)
    return 4 * (bm * D + 3 * D * bf + bm * D + 2 * bm * bs)


# ------------------------------------------------------------ scan chunks
def _chunk_check(cfg, shape):
    S = shape[1]
    return 0 < cfg["chunk"] <= max(16, S)


SPACES: Dict[Tuple[str, str], KernelSpace] = {}


def _declare(space: KernelSpace) -> KernelSpace:
    SPACES[(space.kernel, space.kind)] = space
    return space


_declare(KernelSpace(
    kernel="flash_attention", kind=HW,
    params={"bq": (8, 16, 32, 64, 128, 256),
            "bk": (8, 16, 32, 64, 128, 256, 512)},
    check=_flash_hw_check, vmem=_flash_hw_vmem,
    defaults={"bq": 128, "bk": 128},
))
_declare(KernelSpace(
    kernel="flash_attention", kind=SW,
    params={"kv_chunk": (64, 128, 256, 512, 1024, 2048)},
    check=_flash_sw_check,
    defaults={"kv_chunk": 512},
))
_declare(KernelSpace(
    kernel="swiglu_mlp", kind=HW,
    params={"bm": (8, 16, 32, 64, 128, 256),
            "bf": (128, 256, 512, 1024),
            "bs": (128, 256, 512)},
    check=_swiglu_hw_check, vmem=_swiglu_hw_vmem,
    defaults={"bm": 128, "bf": 512, "bs": 128},
))
_declare(KernelSpace(
    kernel="mamba2_ssd", kind=HW,
    params={"chunk": (16, 32, 64, 128, 256)},
    check=_chunk_check,
    defaults={"chunk": 128},
))
_declare(KernelSpace(
    kernel="mamba2_ssd", kind=SW,
    params={"chunk": (16, 32, 64, 128, 256)},
    check=_chunk_check,
    defaults={"chunk": 128},
))
_declare(KernelSpace(
    kernel="rwkv6_wkv", kind=HW,
    params={"chunk": (8, 16, 32, 64, 128)},
    check=_chunk_check,
    defaults={"chunk": 16},
))
_declare(KernelSpace(
    kernel="rwkv6_wkv", kind=SW,
    params={"chunk": (8, 16, 32, 64, 128)},
    check=_chunk_check,
    defaults={"chunk": 16},
))


def space_for(kernel: str, kind: str) -> Optional[KernelSpace]:
    return SPACES.get((kernel, kind))


def admissible(kernel: str, kind: str, cfg: Mapping[str, int],
               shape: Sequence[int]) -> bool:
    """Module-level predicate (what the property tests call)."""
    space = space_for(kernel, kind)
    return space is not None and space.admissible(cfg, tuple(shape))
