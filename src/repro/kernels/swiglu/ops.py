"""Jit'd wrapper + Viscosity registration for the fused gated-MLP stage."""
from __future__ import annotations

import functools

import jax.numpy as jnp

from repro import viscosity
from repro.kernels import tuning
from repro.kernels.swiglu import ref as _ref
from repro.kernels.swiglu.kernel import swiglu_pallas
from repro.viscosity import lanefault


def _hw(x, w1, w3, w2, *, act: str = "silu", interpret: bool = False,
        bm=None, bf=None, bs=None):
    M, D = x.shape
    F = w1.shape[1]
    # Tuned tiles when the cache has an entry for this (shape, dtype,
    # active routing plan); explicit knobs always win; no entry -> the
    # historical hardcoded defaults.  Never fails: tuning.lookup is
    # fail-open by construction.
    if bm is None and bf is None and bs is None:
        cfg = tuning.lookup("swiglu_mlp", "hw", (M, D, F), x.dtype) or {}
    else:
        cfg = {}
    if bm is None:
        bm = cfg.get("bm") or (128 if M % 128 == 0 else
                               (8 if M % 8 == 0 else 1))
    if bf is None:
        bf = cfg.get("bf") or (512 if F % 512 == 0 else
                               (128 if F % 128 == 0 else F))
    if bs is None:
        bs = cfg.get("bs") or (128 if min(bf, F) % 128 == 0 else bf)
    return swiglu_pallas(x, w1, w3, w2, act=act, bm=bm, bf=bf, bs=bs,
                         interpret=interpret,
                         lane_fault=lanefault.injection("swiglu_mlp"))


def _lane_slicer(args, kw, keep):
    # Output lane j depends only on w2[:, j]: slicing w2's columns to the
    # surviving lanes is exact reduced-width execution.
    x, w1, w3, w2 = args
    return (x, w1, w3, w2[:, jnp.asarray(keep, jnp.int32)]), kw


SWIGLU = viscosity.defop(
    "swiglu_mlp",
    ref=_ref.swiglu_ref,
    kernel=_hw,
    interpret=functools.partial(_hw, interpret=True),
    valid=viscosity.finite_valid,
    tol=2e-2,
    flops=lambda x, w1, *a, **kw: _ref.swiglu_flops(
        x.shape[0], x.shape[1], w1.shape[1]),
    lane_slicer=_lane_slicer,
)


def swiglu(x, w1, w3, w2, *, route: str = viscosity.SW, **kw):
    return SWIGLU(x, w1, w3, w2, route=route, **kw)
