"""Jit'd wrapper + Viscosity registration for the fused gated-MLP stage."""
from __future__ import annotations

import functools

from repro import viscosity
from repro.kernels.swiglu import ref as _ref
from repro.kernels.swiglu.kernel import swiglu_pallas


def _hw(x, w1, w3, w2, *, act: str = "silu", interpret: bool = False):
    M = x.shape[0]
    bm = 128 if M % 128 == 0 else (8 if M % 8 == 0 else 1)
    F = w1.shape[1]
    bf = 512 if F % 512 == 0 else (128 if F % 128 == 0 else F)
    return swiglu_pallas(x, w1, w3, w2, act=act, bm=bm, bf=bf,
                         interpret=interpret)


SWIGLU = viscosity.defop(
    "swiglu_mlp",
    ref=_ref.swiglu_ref,
    kernel=_hw,
    interpret=functools.partial(_hw, interpret=True),
    valid=viscosity.finite_valid,
    tol=2e-2,
    flops=lambda x, w1, *a, **kw: _ref.swiglu_flops(
        x.shape[0], x.shape[1], w1.shape[1]),
)


def swiglu(x, w1, w3, w2, *, route: str = viscosity.SW, **kw):
    return SWIGLU(x, w1, w3, w2, route=route, **kw)
