"""Pure-jnp oracle for the fused gated-MLP (SwiGLU / GeGLU) stage.

y = act(x @ w1) * (x @ w3) @ w2 ;  x (M, D), w1/w3 (D, F), w2 (F, D).
"""
from __future__ import annotations

import jax
import jax.numpy as jnp


def _act(h, act: str):
    if act == "silu":
        return jax.nn.silu(h)
    if act == "gelu":
        return jax.nn.gelu(h, approximate=True)
    raise ValueError(act)


def swiglu_ref(x, w1, w3, w2, *, act: str = "silu"):
    xf = x.astype(jnp.float32)
    h = _act(xf @ w1.astype(jnp.float32), act) * (xf @ w3.astype(jnp.float32))
    return (h @ w2.astype(jnp.float32)).astype(x.dtype)


def swiglu_flops(M, D, F) -> int:
    return int(6 * M * D * F)
