"""Pure-jnp oracle for the fused gated-MLP (SwiGLU / GeGLU) stage.

y = act(x @ w1) * (x @ w3) @ w2 ;  x (M, D), w1/w3 (D, F), w2 (F, D).
"""
from __future__ import annotations

import jax
import jax.numpy as jnp


def _act(h, act: str):
    if act == "silu":
        return jax.nn.silu(h)
    if act == "gelu":
        return jax.nn.gelu(h, approximate=True)
    raise ValueError(act)


def gate(h1, act: str):
    """The kernel's exact activation arithmetic (shared so the blocked
    oracle below is bit-for-bit the kernel's algorithm)."""
    if act == "silu":
        return h1 * jax.lax.logistic(h1)
    if act == "gelu":  # tanh-approx gelu, the kernel's formula
        return 0.5 * h1 * (1.0 + jnp.tanh(0.7978845608028654 *
                                          (h1 + 0.044715 * h1 * h1 * h1)))
    raise ValueError(act)


def swiglu_ref(x, w1, w3, w2, *, act: str = "silu"):
    xf = x.astype(jnp.float32)
    h = _act(xf @ w1.astype(jnp.float32), act) * (xf @ w3.astype(jnp.float32))
    return (h @ w2.astype(jnp.float32)).astype(x.dtype)


def swiglu_ref_blocked(x, w1, w3, w2, *, act: str = "silu", bm: int = 128,
                       bf: int = 512, bs: int = 128):
    """Pure-jnp replica of the Pallas kernel's *blocked* algorithm.

    Same tiles, same dot shapes, same f32 accumulation order as
    ``kernel.swiglu_pallas`` — so interpret-mode kernel output must match
    this oracle **bit-for-bit** for every admissible (bm, bf, bs).  The
    parity tests sweep the tuner's whole config space against it.
    """
    M, D = x.shape
    F = w1.shape[1]
    bm, bf = min(bm, M), min(bf, F)
    bs = min(bs, bf)
    assert M % bm == 0 and F % bf == 0 and bf % bs == 0, (M, bm, F, bf, bs)
    def dot(a, b):  # the kernel's exact dot_general call
        return jax.lax.dot_general(a, b, (((1,), (0,)), ((), ())),
                                   preferred_element_type=jnp.float32)
    rows = []
    for mi in range(M // bm):
        xb = x[mi * bm:(mi + 1) * bm].astype(jnp.float32)
        acc = jnp.zeros((bm, D), jnp.float32)
        for fi in range(F // bf):
            for j in range(bf // bs):
                lo = fi * bf + j * bs
                cols = slice(lo, lo + bs)
                h1 = dot(xb, w1[:, cols].astype(jnp.float32))
                h3 = dot(xb, w3[:, cols].astype(jnp.float32))
                g = gate(h1, act) * h3
                acc = acc + dot(g, w2[cols, :].astype(jnp.float32))
        rows.append(acc.astype(x.dtype))
    return jnp.concatenate(rows, axis=0)


def swiglu_flops(M, D, F) -> int:
    return int(6 * M * D * F)
