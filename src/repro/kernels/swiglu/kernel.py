"""Pallas TPU kernel for the fused gated MLP.

Fuses both matmuls of the gated MLP so the (M, F) hidden activations never
round-trip to HBM: grid (nM, nF), F minor-most; the (BM, D) output
accumulator persists in VMEM scratch across the F loop and is flushed once
per M block.  Arithmetic-intensity argument: the unfused pair reads/writes
2*M*F hidden values through HBM; fusion removes that traffic entirely,
which is what pushes this stage from memory- toward compute-bound at the
d_ff sizes in the assigned configs.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu


def _swiglu_kernel(x_ref, w1_ref, w3_ref, w2_ref, o_ref, acc_scr, *,
                   nf: int, act: str):
    fi = pl.program_id(1)

    @pl.when(fi == 0)
    def _init():
        acc_scr[...] = jnp.zeros_like(acc_scr)

    x = x_ref[...].astype(jnp.float32)         # (BM, D)
    w1 = w1_ref[...].astype(jnp.float32)       # (D, BF)
    w3 = w3_ref[...].astype(jnp.float32)
    w2 = w2_ref[...].astype(jnp.float32)       # (BF, D)
    h1 = jax.lax.dot_general(x, w1, (((1,), (0,)), ((), ())),
                             preferred_element_type=jnp.float32)
    h3 = jax.lax.dot_general(x, w3, (((1,), (0,)), ((), ())),
                             preferred_element_type=jnp.float32)
    if act == "silu":
        g = h1 * jax.lax.logistic(h1)
    else:  # tanh-approx gelu
        g = 0.5 * h1 * (1.0 + jnp.tanh(0.7978845608028654 *
                                       (h1 + 0.044715 * h1 * h1 * h1)))
    h = g * h3                                  # (BM, BF)
    acc_scr[...] += jax.lax.dot_general(h, w2, (((1,), (0,)), ((), ())),
                                        preferred_element_type=jnp.float32)

    @pl.when(fi == nf - 1)
    def _flush():
        o_ref[...] = acc_scr[...].astype(o_ref.dtype)


def swiglu_pallas(x, w1, w3, w2, *, act: str = "silu", bm: int = 128,
                  bf: int = 512, interpret: bool = False):
    """x (M, D); w1/w3 (D, F); w2 (F, D). M % bm == 0, F % bf == 0."""
    M, D = x.shape
    F = w1.shape[1]
    bm = min(bm, M)
    bf = min(bf, F)
    assert M % bm == 0 and F % bf == 0, (M, bm, F, bf)
    grid = (M // bm, F // bf)
    return pl.pallas_call(
        functools.partial(_swiglu_kernel, nf=F // bf, act=act),
        grid=grid,
        in_specs=[
            pl.BlockSpec((bm, D), lambda mi, fi: (mi, 0)),
            pl.BlockSpec((D, bf), lambda mi, fi: (0, fi)),
            pl.BlockSpec((D, bf), lambda mi, fi: (0, fi)),
            pl.BlockSpec((bf, D), lambda mi, fi: (fi, 0)),
        ],
        out_specs=pl.BlockSpec((bm, D), lambda mi, fi: (mi, 0)),
        out_shape=jax.ShapeDtypeStruct((M, D), x.dtype),
        scratch_shapes=[pltpu.VMEM((bm, D), jnp.float32)],
        interpret=interpret,
    )(x, w1, w3, w2)
