"""Pallas TPU kernel for the fused gated MLP.

Fuses both matmuls of the gated MLP so the (M, F) hidden activations never
round-trip to HBM — and, inside each grid step, streams the hidden tile in
``bs``-column sub-tiles so the gate product ``act(x@w1) * (x@w3)`` is never
materialized wider than (bm, bs): each sub-tile is activated, gated, and
immediately contracted against its w2 rows in a **single pass over the
hidden dim**.  Grid (nM, nF), F minor-most; the (BM, D) output accumulator
persists in VMEM scratch across the F loop and is flushed once per M block.

Arithmetic-intensity argument: the unfused pair reads/writes 2*M*F hidden
values through HBM; fusion removes that traffic entirely, and the sub-tile
pass caps the live gate intermediate at bm*bs values, which is what lets
the tuner push ``bf`` up (weight-reuse) without blowing the VMEM budget.

Tile knobs (bm, bf, bs) are swept by ``kernels/tuning`` — see
``space.py`` for the admissibility rules this kernel asserts.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

from repro.kernels.swiglu.ref import gate
from repro.viscosity.lanefault import apply_fault


def _swiglu_kernel(x_ref, w1_ref, w3_ref, w2_ref, o_ref, acc_scr, *,
                   nf: int, bs: int, act: str, lane_fault=None):
    fi = pl.program_id(1)

    @pl.when(fi == 0)
    def _init():
        acc_scr[...] = jnp.zeros_like(acc_scr)

    x = x_ref[...].astype(jnp.float32)         # (BM, D)
    bf = w1_ref.shape[1]
    # Single pass over this grid step's hidden tile: activate, gate, and
    # contract one (BM, bs) sub-tile at a time (static unroll, bf/bs small).
    for j in range(bf // bs):
        cols = slice(j * bs, (j + 1) * bs)
        w1 = w1_ref[:, cols].astype(jnp.float32)   # (D, bs)
        w3 = w3_ref[:, cols].astype(jnp.float32)
        w2 = w2_ref[cols, :].astype(jnp.float32)   # (bs, D)
        h1 = jax.lax.dot_general(x, w1, (((1,), (0,)), ((), ())),
                                 preferred_element_type=jnp.float32)
        h3 = jax.lax.dot_general(x, w3, (((1,), (0,)), ((), ())),
                                 preferred_element_type=jnp.float32)
        g = gate(h1, act) * h3                     # (BM, bs): never wider
        acc_scr[...] += jax.lax.dot_general(
            g, w2, (((1,), (0,)), ((), ())),
            preferred_element_type=jnp.float32)

    @pl.when(fi == nf - 1)
    def _flush():
        # Value-level fault injection (lanefault): a static LaneFault
        # corrupts the output tile's lane axis at the single flush point —
        # the masked-where only exists in the trace when a fault is
        # registered, so healthy builds are byte-identical.
        o_ref[...] = apply_fault(acc_scr[...],
                                 lane_fault).astype(o_ref.dtype)


def swiglu_pallas(x, w1, w3, w2, *, act: str = "silu", bm: int = 128,
                  bf: int = 512, bs: int = 128, interpret: bool = False,
                  lane_fault=None):
    """x (M, D); w1/w3 (D, F); w2 (F, Do). M % bm == 0, F % bf == 0,
    bf % bs == 0 (after clamping each knob to its dim).  The output width
    is ``w2.shape[1]`` — normally D, narrower under DEGRADED_REDUCED
    (reduced-width execution slices w2 to the surviving lanes)."""
    M, D = x.shape
    F = w1.shape[1]
    Do = w2.shape[1]
    bm = min(bm, M)
    bf = min(bf, F)
    bs = min(bs, bf)
    assert M % bm == 0 and F % bf == 0, (M, bm, F, bf)
    assert bf % bs == 0, (bf, bs)
    grid = (M // bm, F // bf)
    return pl.pallas_call(
        functools.partial(_swiglu_kernel, nf=F // bf, bs=bs, act=act,
                          lane_fault=lane_fault),
        grid=grid,
        in_specs=[
            pl.BlockSpec((bm, D), lambda mi, fi: (mi, 0)),
            pl.BlockSpec((D, bf), lambda mi, fi: (0, fi)),
            pl.BlockSpec((D, bf), lambda mi, fi: (0, fi)),
            pl.BlockSpec((bf, Do), lambda mi, fi: (fi, 0)),
        ],
        out_specs=pl.BlockSpec((bm, Do), lambda mi, fi: (mi, 0)),
        out_shape=jax.ShapeDtypeStruct((M, Do), x.dtype),
        scratch_shapes=[pltpu.VMEM((bm, Do), jnp.float32)],
        interpret=interpret,
    )(x, w1, w3, w2)
