from repro.kernels.swiglu.ops import SWIGLU, swiglu
from repro.kernels.swiglu.ref import (swiglu_flops, swiglu_ref,
                                      swiglu_ref_blocked)

__all__ = ["SWIGLU", "swiglu", "swiglu_ref", "swiglu_ref_blocked",
           "swiglu_flops"]
