"""Parameter / cache / batch PartitionSpecs for the production mesh.

Name-driven rules (we control every param name):
  * column-sharded projections (last dim over "model"): wq wk wv wg wr w1 w3
    cwk cwr in_proj bq bk bv conv_w conv_b lm_head.w
  * row-sharded projections (dim -2 over "model"): wo w2 cwv out_proj and
    the embedding table (vocab dim)
  * per-head vectors (dim -1): A_log D dt_bias u ln/norm/mix replicated
Indivisible dims fall back to replication (recorded; a hillclimb target).

Batch inputs shard over ("pod","data"); decode caches shard batch over
("pod","data") and kv-heads over "model" when divisible.
"""
from __future__ import annotations

from typing import Any, Dict

import numpy as np

import jax
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P

COL = {"wq", "wk", "wv", "wg", "wr", "w1", "w3", "cwk", "cwr", "in_proj",
       "router", "w_lora_a"}
ROW = {"wo", "w2", "cwv", "out_proj", "table"}
VEC = {"bq", "bk", "bv", "conv_b", "A_log", "D", "dt_bias", "conv_w",
       "w_lora_b"}
HEAD2 = {"u"}
LM_HEAD = {"w"}


def _div(n: int, m: int) -> bool:
    return m > 0 and n % m == 0


def _model_size(mesh: Mesh) -> int:
    return mesh.shape.get("model", 1)


def _axis_size(mesh: Mesh, axis) -> int:
    if axis is None:
        return 1
    if isinstance(axis, tuple):
        out = 1
        for a in axis:
            out *= mesh.shape.get(a, 1)
        return out
    return mesh.shape.get(axis, 1)


# Axis assignment per parameter family; variants (EXPERIMENTS.md §Perf)
# override these (e.g. 2D attention sharding, expert parallelism).
DEFAULT_AXES = {"attn": "model", "ffn": "model", "vocab": "model",
                "expert": None, "ssm": "model"}


def _batch_axes(mesh: Mesh):
    axes = tuple(a for a in ("pod", "data") if a in mesh.shape)
    return axes if axes else None


ATTN_NAMES = {"wq", "wk", "wv", "wo", "bq", "bk", "bv", "wg", "wr"}


def param_pspec(path: str, shape, mesh: Mesh, axes=None) -> P:
    axes = axes or DEFAULT_AXES
    name = path.split("/")[-1]
    is_moe = "moe" in path and name in ("w1", "w2", "w3")
    if name in ATTN_NAMES:
        ax = axes["attn"]
    elif name in LM_HEAD or name == "table":
        ax = axes["vocab"]
    elif name in ("in_proj", "out_proj", "conv_w", "conv_b", "A_log", "D",
                  "dt_bias"):
        ax = axes["ssm"]
    else:
        ax = axes["ffn"]
    m = _axis_size(mesh, ax)
    nd = len(shape)
    spec = [None] * nd
    if is_moe and axes.get("expert") and nd >= 3 and \
            _div(shape[-3], _axis_size(mesh, axes["expert"])):
        spec[-3] = axes["expert"]
    if name in COL and nd >= 2:
        if _div(shape[-1], m):
            spec[-1] = ax
    elif name in ROW and nd >= 2:
        if _div(shape[-2], m):
            spec[-2] = ax
    elif name in LM_HEAD and nd >= 2 and "lm_head" in path:
        if _div(shape[-1], m):
            spec[-1] = ax
    elif name in VEC or name in HEAD2:
        if nd >= 1 and _div(shape[-1], m) and shape[-1] >= m:
            if name in HEAD2 and nd >= 2:
                if _div(shape[-2], m):
                    spec[-2] = ax
            else:
                spec[-1] = ax
    return P(*spec)


def tree_pspecs(tree, mesh: Mesh, fn) -> Any:
    flat = jax.tree_util.tree_flatten_with_path(tree)[0]
    _, tdef = jax.tree_util.tree_flatten(tree)
    specs = []
    for path, leaf in flat:
        key = "/".join(str(getattr(p, "key", getattr(p, "idx", p)))
                       for p in path)
        specs.append(fn(key, leaf.shape, mesh))
    return jax.tree_util.tree_unflatten(tdef, specs)


def params_pspecs(params, mesh: Mesh, axes=None):
    return tree_pspecs(params, mesh,
                       lambda p, s, m: param_pspec(p, s, m, axes))


def params_shardings(params, mesh: Mesh, axes=None):
    return jax.tree_util.tree_map(
        lambda s: NamedSharding(mesh, s), params_pspecs(params, mesh, axes))


def opt_pspecs(opt_state, params_specs):
    """AdamW moments mirror params; count replicated."""
    from repro.optim.adamw import AdamWState
    return AdamWState(count=P(), mu=params_specs, nu=params_specs)


# ------------------------------------------------------------- activations
def batch_pspec(path: str, shape, mesh: Mesh) -> P:
    b_axes = _batch_axes(mesh)
    total = int(np.prod([mesh.shape[a] for a in (b_axes or ())])) or 1
    nd = len(shape)
    spec = [None] * nd
    if nd >= 1 and b_axes and _div(shape[0], total):
        spec[0] = b_axes
    return P(*spec)


def cache_pspec(path: str, shape, mesh: Mesh) -> P:
    """Decode cache leaves: stacked (L, B, ...) or per-app (B, ...).

    Heuristic: the batch dim is the first dim whose size matches the known
    batch (handled by the caller passing concrete shapes through
    ``make_cache_pspec_fn``); here we shard dim (kv-heads / ssm-heads) over
    model when a dim is divisible and looks like a head axis.
    """
    raise NotImplementedError  # replaced by make_cache_pspec_fn


def make_cache_pspec_fn(batch: int, mesh: Mesh, attn_axis="model"):
    b_axes = _batch_axes(mesh)
    total = int(np.prod([mesh.shape[a] for a in (b_axes or ())])) or 1
    m = _axis_size(mesh, attn_axis)

    def fn(path: str, shape, _mesh) -> P:
        nd = len(shape)
        spec = [None] * nd
        # find the batch dim (first dim equal to the serving batch)
        b_dim = None
        for i, s in enumerate(shape[:3]):
            if s == batch:
                b_dim = i
                break
        if b_dim is not None and b_axes and _div(batch, total):
            spec[b_dim] = b_axes
        name = path.split("/")[-1]
        if name in ("k", "v") and nd >= 2 and b_dim is not None:
            # (..., B, S, Hkv, D): shard kv-heads over model if divisible;
            # else shard the SEQ dim (flash-decode style partial softmax —
            # XLA inserts the max/sum combines). Without this, MHA caches
            # (e.g. qwen1.5 kv=20) replicate and overflow HBM at 32k x 128.
            if _div(shape[-2], m):
                spec[-2] = attn_axis
            elif _div(shape[-3], m):
                spec[-3] = attn_axis
        elif name == "pos" and nd >= 2 and b_dim is not None:
            if _div(shape[-1], m):
                spec[-1] = attn_axis
        elif name == "ssm" and nd >= 3:
            # (L, B, H, N, P): ssm heads over model
            if _div(shape[-3], m):
                spec[-3] = attn_axis
        elif name == "wkv" and nd >= 3:
            if _div(shape[-3], m):
                spec[-3] = attn_axis
        elif name == "conv" and nd >= 1 and _div(shape[-1], m):
            spec[-1] = attn_axis
        elif name in ("shift_tm", "shift_cm") and _div(shape[-1], m):
            spec[-1] = attn_axis
        return P(*spec)

    return fn


def rules_for(cfg, mesh: Mesh) -> Dict[str, Any]:
    """Per-arch logical-axis rules: drop indivisible shardings (recorded as
    replication; the roofline flags these as hillclimb targets)."""
    from repro.launch.sharding import DEFAULT_RULES
    rules = dict(DEFAULT_RULES)
    m = _model_size(mesh)
    if cfg.num_heads % m:
        rules["heads"] = None
    if cfg.num_kv_heads % m:
        rules["kv_heads"] = None
    if cfg.d_ff % m:
        rules["mlp"] = None
    if cfg.vocab_size % m:
        rules["vocab"] = None
    if cfg.ssm is not None:
        d_inner = cfg.ssm.expand * cfg.d_model
        if d_inner % m:
            rules["ssm_inner"] = None
        nheads = (d_inner // cfg.ssm.head_dim if cfg.family == "hybrid"
                  else cfg.d_model // max(cfg.ssm.rwkv_head_dim, 1))
        if nheads % m:
            rules["ssm_heads"] = None
    return rules
