from repro.launch.xla_presets import force_host_device_count
force_host_device_count(512)  # MUST precede any jax import (dry-run mesh)
"""§Perf hillclimb runner: baseline vs variant roofline comparison.

Usage:
  python -m repro.launch.hillclimb --arch mixtral-8x7b --shape train_4k \
      --variant moe_combine_first [--microbatch 8]

Artifacts are tagged ``@<variant>`` next to the baselines; the comparison
table prints the three roofline terms and the dominant-term delta.

The sweep/measure/keep-best loop here is the template the kernel
autotuner (``repro.kernels.tuning``) specializes down to block-size wall
time; this runner stays the whole-program (roofline-level) instance.
"""
import argparse
import contextlib
import dataclasses
import sys

from repro.launch import dryrun
from repro.launch.variants import VARIANTS, variant_mesh
from repro.obs.logging import configure as obs_configure, get_logger

log = get_logger("launch.hillclimb")


@contextlib.contextmanager
def patched_dryrun(build, make_mesh):
    """Swap ``dryrun.build_lowered`` / ``make_production_mesh`` for the
    duration of one search step — exception-safe, so a mid-search crash
    can never leave ``dryrun`` permanently monkey-patched (a patched
    module would silently poison every later baseline in this process).
    """
    orig_build = dryrun.build_lowered
    orig_mesh = dryrun.make_production_mesh
    dryrun.build_lowered = build
    dryrun.make_production_mesh = make_mesh
    try:
        yield
    finally:
        dryrun.build_lowered = orig_build
        dryrun.make_production_mesh = orig_mesh


def run_variant(arch: str, shape: str, variant: str, *, multi_pod=False,
                microbatch=None, force=False):
    v = VARIANTS[variant]
    overrides = dict(v.get("overrides", {}))
    if v.get("moe_combine_first"):
        from repro.configs import get_config
        cfg = get_config(arch)
        overrides["moe"] = dataclasses.replace(cfg.moe, combine_first=True)

    orig_build = dryrun.build_lowered

    def build(arch_, shape_, mesh_, **kw):
        kw["rules"] = v.get("rules", kw.get("rules"))
        kw["axes"] = v.get("axes", kw.get("axes"))
        kw.update(v.get("train_kw", {}))
        return orig_build(arch_, shape_, mesh_, **kw)

    def make_mesh(*, multi_pod=False):
        return variant_mesh(v, multi_pod)

    with patched_dryrun(build, make_mesh):
        rec = dryrun.run_cell(arch, shape, multi_pod,
                              microbatch=microbatch or v.get("microbatch"),
                              overrides=overrides,
                              force=force, tag=f"@{variant}")
    return rec


def compare(base, var, label):
    rows = []
    for k in ("compute_s", "memory_s", "collective_s"):
        b = base["roofline"][k]
        w = var["roofline"][k]
        rows.append(f"  {k:14s} {b:9.3e} -> {w:9.3e}  "
                    f"({(w/b - 1)*100 if b else 0:+.1f}%)")
    bf = base["roofline"]["roofline_fraction"]
    wf = var["roofline"]["roofline_fraction"]
    sys.stdout.write("\n".join(
        [f"== {label}"] + rows +
        [f"  roofline_frac  {bf:.4f} -> {wf:.4f} "
         f"({(wf/bf if bf else 0):.2f}x)",
         f"  dominant       {base['roofline']['dominant']} -> "
         f"{var['roofline']['dominant']}"]) + "\n")
    return wf, bf


def main():
    obs_configure(stream=sys.stdout)
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", required=True)
    ap.add_argument("--shape", required=True)
    ap.add_argument("--variant", required=True, choices=list(VARIANTS))
    ap.add_argument("--microbatch", type=int, default=None)
    ap.add_argument("--multi", action="store_true")
    ap.add_argument("--force", action="store_true")
    args = ap.parse_args()

    base = dryrun.run_cell(args.arch, args.shape, args.multi)
    if base["status"] != "ok":
        raise SystemExit(f"baseline not ok: {base}")
    var = run_variant(args.arch, args.shape, args.variant,
                      multi_pod=args.multi, microbatch=args.microbatch,
                      force=args.force)
    if var["status"] != "ok":
        log.error("variant_failed", error=var.get("error"),
                  trace=var.get("trace", "")[-2000:])
        raise SystemExit(1)
    compare(base, var, f"{args.arch}/{args.shape} + {args.variant}")


if __name__ == "__main__":
    main()
