"""Serving launcher: ``python -m repro.launch.serve --arch <id> [...]``.

Drives the continuous-batching engine on a synthetic workload: requests
with independent prompt lengths and staggered arrivals stream through a
fixed slot pool, with optional mid-stream fault injection under either
failover mode (dispatcher-keyed recompile or resident health-mask).  With
``--verify`` every completion is checked bit-for-bit against a
single-request reference decode.
"""
from __future__ import annotations

import argparse
import sys
import time

import jax
import numpy as np

from repro.configs import ARCH_NAMES, get_config
from repro.models import build_model
from repro.obs.logging import configure as obs_configure, get_logger
from repro.serve import (RECOMPILE, RESIDENT, ServeConfig, ServeEngine,
                         percentile, reference_decode, synthetic_workload)
from repro.viscosity import HW, INTERPRET, SW

log = get_logger("launch.serve")


def main():
    obs_configure(stream=sys.stdout)
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="qwen1.5-4b", choices=list(ARCH_NAMES))
    ap.add_argument("--requests", type=int, default=16)
    ap.add_argument("--slots", type=int, default=4)
    ap.add_argument("--prompt-len", type=int, default=32,
                    help="max prompt length (lengths are drawn in "
                         "[4, prompt-len])")
    ap.add_argument("--new-tokens", type=int, default=32,
                    help="max token budget (budgets drawn in "
                         "[4, new-tokens])")
    ap.add_argument("--arrival-every", type=int, default=2,
                    help="one request arrives every N engine steps")
    ap.add_argument("--failover", default=RECOMPILE,
                    choices=[RECOMPILE, RESIDENT])
    ap.add_argument("--hw-route", default=SW, choices=[HW, SW, INTERPRET])
    ap.add_argument("--fault-at", type=int, default=-1,
                    help="engine step at which to quarantine --fault-stage")
    ap.add_argument("--fault-stage", default="flash_attention")
    ap.add_argument("--verify", action="store_true",
                    help="check every request against single-request "
                         "reference decode")
    ap.add_argument("--seed", type=int, default=0)
    args = ap.parse_args()

    cfg = get_config(args.arch).reduced()
    if cfg.is_encdec or cfg.stub_frontend:
        raise SystemExit("serve demo targets decoder-only LM archs")
    model = build_model(cfg)
    params = model.init(jax.random.PRNGKey(0))
    reqs = synthetic_workload(cfg.vocab_size, args.requests,
                              np.random.default_rng(args.seed),
                              max_prompt=args.prompt_len, min_new=4,
                              max_new=args.new_tokens,
                              arrival_every=args.arrival_every)
    max_len = args.prompt_len + args.new_tokens + 1
    eng = ServeEngine(cfg, params, ServeConfig(
        max_len=max_len, max_slots=args.slots, hw_route=args.hw_route,
        failover=args.failover))
    fault = ((args.fault_at, args.fault_stage)
             if args.fault_at >= 0 else None)
    t0 = time.perf_counter()
    done, stats = eng.serve(reqs, fault_at_step=fault)
    dt = time.perf_counter() - t0
    n_tok = sum(len(c.tokens) for c in done.values())
    lat = [c.latency_s for c in done.values()]
    log.info("served", requests=f"{len(done)}/{len(reqs)}", tokens=n_tok,
             wall_s=round(dt, 2), tok_s=round(n_tok / dt, 1),
             steps=stats["steps"],
             occupancy=round(float(np.mean(stats["occupancy"]))
                             if stats["occupancy"] else 0.0, 2))
    log.info("latency", failover=args.failover,
             recompiles=stats["recompiles"],
             p50_ms=round(percentile(lat, 0.50) * 1e3),
             p99_ms=round(percentile(lat, 0.99) * 1e3))
    if args.verify:
        if args.hw_route != SW:
            raise SystemExit(
                "--verify requires --hw-route sw: across lowerings tokens "
                "are only tol-equivalent (Viscosity contract), not "
                "bit-exact against the SW reference decode")
        for r in reqs:
            ref = reference_decode(cfg, params, r.prompt, r.max_new_tokens,
                                   max_len=max_len)
            if not np.array_equal(done[r.rid].tokens, ref):
                raise SystemExit(f"request {r.rid}: tokens diverge from "
                                 f"reference decode")
        log.info("verified", requests=len(reqs),
                 detail="bit-identical-to-reference-decode")


if __name__ == "__main__":
    main()
