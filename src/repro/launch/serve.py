"""Serving launcher: ``python -m repro.launch.serve --arch <id> [...]``.

Batched greedy decoding with optional mid-stream fault injection: the
engine reroutes the faulty stage through its software lowering and the
generated tokens are bit-identical (asserted when --verify is given).
"""
from __future__ import annotations

import argparse
import time

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs import ARCH_NAMES, get_config
from repro.models import build_model
from repro.serve import ServeConfig, ServeEngine


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="qwen1.5-4b", choices=list(ARCH_NAMES))
    ap.add_argument("--batch", type=int, default=4)
    ap.add_argument("--prompt-len", type=int, default=32)
    ap.add_argument("--new-tokens", type=int, default=32)
    ap.add_argument("--fault-at", type=int, default=-1)
    ap.add_argument("--fault-stage", default="flash_attention")
    ap.add_argument("--verify", action="store_true",
                    help="also decode fault-free and assert identical tokens")
    args = ap.parse_args()

    cfg = get_config(args.arch).reduced()
    if cfg.is_encdec or cfg.stub_frontend:
        raise SystemExit("serve demo targets decoder-only LM archs")
    model = build_model(cfg)
    params = model.init(jax.random.PRNGKey(0))
    prompts = jax.random.randint(jax.random.PRNGKey(1),
                                 (args.batch, args.prompt_len), 0,
                                 cfg.vocab_size).astype(jnp.int32)
    eng = ServeEngine(cfg, params, ServeConfig(
        max_len=args.prompt_len + args.new_tokens + 1))
    fault = ((args.fault_at, args.fault_stage)
             if args.fault_at >= 0 else None)
    t0 = time.perf_counter()
    toks, stats = eng.generate(prompts, args.new_tokens, fault_at_step=fault)
    dt = time.perf_counter() - t0
    print(f"generated {toks.shape} in {dt:.2f}s, "
          f"recompiles={stats['recompiles']}, "
          f"mean step {np.mean(stats['step_times'])*1e3:.1f}ms")
    print("tokens[0]:", toks[0][:16].tolist())
    if args.verify and fault:
        eng2 = ServeEngine(cfg, params, ServeConfig(
            max_len=args.prompt_len + args.new_tokens + 1))
        toks2, _ = eng2.generate(prompts, args.new_tokens)
        same = bool((toks == toks2).all())
        print("fault-free tokens identical:", same)
        if not same:
            raise SystemExit(1)


if __name__ == "__main__":
    main()
