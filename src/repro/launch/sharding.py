"""Logical-axis sharding (t5x-style rules), mesh-aware and test-safe.

Model code annotates activations with *logical* axis names
(``constrain(x, "batch", "seq", "embed")``).  Inside an ``axis_rules``
context those names map to mesh axes and become
``with_sharding_constraint``; outside (CPU smoke tests) it is a no-op.

The rules are the primary perf-iteration control surface: the hillclimbs in
EXPERIMENTS.md §Perf mostly edit this table, not the model code.
"""
from __future__ import annotations

import contextlib
import threading
from typing import Dict, Optional, Sequence, Tuple, Union

import jax
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P

from repro.obs.logging import get_logger

log = get_logger("launch.sharding")

Axis = Union[None, str, Tuple[str, ...]]

# Baseline rules for the production mesh ("pod" present only multi-pod).
DEFAULT_RULES: Dict[str, Axis] = {
    "batch": ("pod", "data"),
    "seq": None,
    "embed": None,
    "heads": "model",
    "kv_heads": "model",     # dropped per-arch when kv % model != 0
    "kv_seq": None,
    "head_dim": None,
    "mlp": "model",
    "vocab": "model",
    "experts": None,
    "expert_cap": None,
    "ssm_inner": "model",
    "ssm_state": None,
    "ssm_heads": "model",
}

_state = threading.local()


def _rules() -> Optional[Dict[str, Axis]]:
    return getattr(_state, "rules", None)


def _mesh() -> Optional[Mesh]:
    return getattr(_state, "mesh", None)


@contextlib.contextmanager
def axis_rules(rules: Dict[str, Axis], mesh: Optional[Mesh] = None):
    prev = (_rules(), _mesh())
    _state.rules, _state.mesh = dict(rules), mesh
    try:
        yield
    finally:
        _state.rules, _state.mesh = prev


def resolve(*names: Optional[str]) -> P:
    """Logical names -> PartitionSpec under the active rules."""
    rules = _rules() or {}
    mesh = _mesh()
    mesh_axes = set(mesh.axis_names) if mesh is not None else None
    out = []
    for n in names:
        ax = rules.get(n) if n else None
        if isinstance(ax, tuple) and mesh_axes is not None:
            ax = tuple(a for a in ax if a in mesh_axes) or None
            if isinstance(ax, tuple) and len(ax) == 1:
                ax = ax[0]
        elif isinstance(ax, str) and mesh_axes is not None and ax not in mesh_axes:
            ax = None
        out.append(ax)
    return P(*out)


def constrain(x, *names: Optional[str]):
    """with_sharding_constraint via logical names; no-op without rules."""
    if _rules() is None:
        return x
    try:
        return jax.lax.with_sharding_constraint(x, resolve(*names))
    except (ValueError, TypeError) as e:
        # Shape/axis mismatch inside exotic paths: stay unsharded.  Only
        # the expected spec errors are swallowed (and logged) — anything
        # else is a real bug and propagates.
        log.debug("constrain_unsharded", names=names,
                  error=type(e).__name__, detail=str(e))
        return x


def named_sharding(mesh: Mesh, *names: Optional[str]) -> NamedSharding:
    return NamedSharding(mesh, resolve(*names))


# ----------------------------------------------------- fleet health view
def shard_bounds(n_items: int, device_mask: Sequence[bool], *,
                 owned: Optional[Sequence[int]] = None
                 ) -> Dict[int, Tuple[int, int]]:
    """Partition ``n_items`` rows across the *serving* devices of a fleet.

    ``device_mask`` is the FleetPlan/FleetMeshView health mask (True =
    serving).  Returns ``{device_index: (start, stop)}`` covering
    [0, n_items) contiguously, remainder spread one row at a time over the
    first shards — quarantined devices and idle spares get no slice, so a
    shrinking fleet automatically rebalances the same global batch.

    ``owned`` makes the split host-aware: the bounds are still computed
    over the *global* mask (every host agrees on the same partition of
    the same batch), but only the listed device indices are returned —
    a multi-host process passes its HostTopology block and executes
    exactly its slice.
    """
    serving = [i for i, ok in enumerate(device_mask) if ok]
    if not serving:
        raise ValueError("no serving devices: the whole fleet is "
                         "quarantined or idle spares")
    base, rem = divmod(n_items, len(serving))
    bounds: Dict[int, Tuple[int, int]] = {}
    start = 0
    for k, dev in enumerate(serving):
        size = base + (1 if k < rem else 0)
        bounds[dev] = (start, start + size)
        start += size
    if owned is not None:
        bounds = {d: b for d, b in bounds.items() if d in set(owned)}
    return bounds
