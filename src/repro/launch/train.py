"""Training launcher: ``python -m repro.launch.train --arch <id> [...]``.

Runs the fault-aware TrainRunner on a reduced (CPU-runnable) or full
config.  On real hardware the same entry point runs the full config on the
production mesh (--mesh data,model); this container is CPU-only, so the
default is the reduced config on a single device.
"""
from __future__ import annotations

import argparse
import json
import sys

from repro import optim
from repro.configs import ARCH_NAMES, get_config
from repro.data import DataConfig, SyntheticLM
from repro.obs.logging import configure as obs_configure, get_logger
from repro.train import TrainConfig, TrainRunner
from repro.viscosity import HW, INTERPRET, SW

log = get_logger("launch.train")


def main():
    obs_configure(stream=sys.stdout)
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="qwen1.5-4b", choices=list(ARCH_NAMES))
    ap.add_argument("--steps", type=int, default=200)
    ap.add_argument("--batch", type=int, default=8)
    ap.add_argument("--seq", type=int, default=128)
    ap.add_argument("--lr", type=float, default=1e-2)
    ap.add_argument("--full", action="store_true",
                    help="full config (requires accelerator hardware)")
    ap.add_argument("--ckpt-dir", default=None)
    ap.add_argument("--ckpt-every", type=int, default=100)
    ap.add_argument("--canary-every", type=int, default=50)
    ap.add_argument("--compression", action="store_true")
    ap.add_argument("--inject-fault-at", type=int, default=-1)
    ap.add_argument("--inject-stage", default="flash_attention")
    ap.add_argument("--hw-route", default=SW,
                    choices=[HW, SW, INTERPRET])
    args = ap.parse_args()

    cfg = get_config(args.arch)
    if not args.full:
        cfg = cfg.reduced()
    data = SyntheticLM(DataConfig(vocab_size=cfg.vocab_size,
                                  batch=args.batch, seq_len=args.seq))
    ocfg = optim.AdamWConfig(lr=args.lr, warmup_steps=20,
                             total_steps=args.steps)
    tcfg = TrainConfig(steps=args.steps, ckpt_every=args.ckpt_every,
                       ckpt_dir=args.ckpt_dir,
                       canary_every=args.canary_every,
                       compression=args.compression,
                       hw_route=args.hw_route)
    runner = TrainRunner(cfg, ocfg, tcfg, data)
    params, opt_state, err = runner.init_state()

    def on_step(step, row):
        if step % 10 == 0:
            log.info("step", step=step, loss=round(row["loss"], 4),
                     gnorm=round(row["grad_norm"], 2),
                     dt_ms=round(row["dt"] * 1e3),
                     faults=row["n_faults"], compiles=row["compiles"])
        if args.inject_fault_at == step:
            log.warning("injecting_fault", stage=args.inject_stage)
            runner.inject_fault(args.inject_stage)

    runner.run(params, opt_state, err, on_step=on_step)
    sys.stdout.write(json.dumps(
        {"final_loss": runner.history[-1]["loss"],
         "compiles": runner.dispatcher.compiles,
         "fault_log": runner.fault_state.log}, default=str) + "\n")


if __name__ == "__main__":
    main()
