"""Trip-count-aware analysis of post-SPMD compiled HLO.

XLA's built-in ``cost_analysis`` visits while (lax.scan) bodies ONCE, which
undercounts layer-scanned transformers by ~num_layers x (verified in this
repo's tests).  This module parses ``compiled.as_text()`` — the SPMD
program, so all shapes are already per-device — and aggregates with loop
trip counts:

  * FLOPs: dot ops (2 * out_elems * contracted_size); convolutions approx.
  * HBM traffic proxy: every materializing op's result, write+read (2x) —
    parameters counted once as reads.  Fusions count only their root
    (internal values stay in registers/VMEM — the right model for traffic).
  * Collectives: per-device link-bytes by type (ring algorithms):
      all-reduce 2*S*(g-1)/g | all-gather / all-to-all S*(g-1)/g
      reduce-scatter S_out*(g-1) | collective-permute S
  * While trip counts: max integer constant in the loop condition
    computation (the scan pattern; validated against known-length scans).
"""
from __future__ import annotations

import re
from dataclasses import dataclass, field
from typing import Dict, List, Optional, Tuple

_DTYPE_BYTES = {
    "pred": 1, "s8": 1, "u8": 1, "s16": 2, "u16": 2, "f16": 2, "bf16": 2,
    "s32": 4, "u32": 4, "f32": 4, "s64": 8, "u64": 8, "f64": 8, "c64": 8,
    "c128": 16, "f8e4m3fn": 1, "f8e5m2": 1,
}

_SHAPE_RE = re.compile(r"(\w+)\[([\d,]*)\]")
_INST_RE = re.compile(
    r"^\s*(?:ROOT\s+)?%?([\w\.\-]+)\s*=\s*(.+?)\s+"
    r"([\w\-]+)\((.*)$")
_GROUPS_IOTA_RE = re.compile(r"replica_groups=\[(\d+),(\d+)\]<=")
_GROUPS_LIST_RE = re.compile(r"replica_groups=\{\{([\d,]+)\}")
_CONST_RE = re.compile(r"[su]32\[\]\s+constant\((\d+)\)")
_CALLED_RE = re.compile(r"(?:condition|body|to_apply|called_computations)="
                        r"\{?%?([\w\.\-]+)")


def _parse_shape(type_str: str) -> Tuple[int, int]:
    """-> (elements, bytes) of the first array shape in the type string.

    For tuple types, sums all member arrays.
    """
    total_e = total_b = 0
    for m in _SHAPE_RE.finditer(type_str):
        dt, dims = m.group(1), m.group(2)
        if dt not in _DTYPE_BYTES:
            continue
        elems = 1
        if dims:
            for d in dims.split(","):
                elems *= int(d)
        total_e += elems
        total_b += elems * _DTYPE_BYTES[dt]
    return total_e, total_b


@dataclass
class Instruction:
    name: str
    kind: str
    type_str: str
    rest: str

    @property
    def elems_bytes(self):
        return _parse_shape(self.type_str)


@dataclass
class CompStats:
    flops: float = 0.0
    bytes_hbm: float = 0.0
    coll_bytes: Dict[str, float] = field(default_factory=dict)
    n_coll: Dict[str, int] = field(default_factory=dict)
    # profiling detail: effective (trip-multiplied) bytes per op kind and
    # the heaviest individual instructions — drives the §Perf hypotheses
    bytes_by_kind: Dict[str, float] = field(default_factory=dict)
    top_ops: List[Tuple[float, str]] = field(default_factory=list)

    def merge_scaled(self, sub: "CompStats", scale: float):
        self.flops += scale * sub.flops
        self.bytes_hbm += scale * sub.bytes_hbm
        for k, v in sub.coll_bytes.items():
            self.coll_bytes[k] = self.coll_bytes.get(k, 0.0) + scale * v
        for k, v in sub.n_coll.items():
            self.n_coll[k] = self.n_coll.get(k, 0) + int(scale * v)
        for k, v in sub.bytes_by_kind.items():
            self.bytes_by_kind[k] = self.bytes_by_kind.get(k, 0.0) \
                + scale * v
        for b, desc in sub.top_ops:
            self.top_ops.append((scale * b, desc))
        self.top_ops = sorted(self.top_ops, reverse=True)[:24]


class HLOModule:
    def __init__(self, text: str):
        self.computations: Dict[str, List[Instruction]] = {}
        self.entry: Optional[str] = None
        self.shapes: Dict[str, str] = {}          # inst name -> type string
        self._parse(text)

    def _parse(self, text: str):
        cur: Optional[List[Instruction]] = None
        header = re.compile(
            r"^(ENTRY\s+)?%?([\w\.\-]+)\s*\((.*)\)\s*->")
        for line in text.splitlines():
            if not line.startswith((" ", "\t", "}")):
                m = header.match(line)
                if m and line.rstrip().endswith("{"):
                    name = m.group(2)
                    cur = []
                    self.computations[name] = cur
                    if m.group(1):
                        self.entry = name
                continue
            if line.startswith("}"):
                cur = None
                continue
            if cur is None:
                continue
            im = _INST_RE.match(line)
            if not im:
                continue
            name, type_str, kind, rest = im.groups()
            cur.append(Instruction(name, kind, type_str, rest))
            self.shapes[name] = type_str

    # ---------------------------------------------------------- helpers
    def _trip_count(self, cond_name: str) -> int:
        consts = []
        for inst in self.computations.get(cond_name, []):
            for m in _CONST_RE.finditer(inst.type_str + " constant" +
                                        inst.rest if inst.kind == "constant"
                                        else ""):
                consts.append(int(m.group(1)))
            if inst.kind == "constant":
                m = re.search(r"constant\((\d+)\)", "constant(" + inst.rest)
                if m and ("s32[]" in inst.type_str or
                          "u32[]" in inst.type_str):
                    consts.append(int(m.group(1)))
        return max(consts) if consts else 1

    def _group_size(self, rest: str, world: int) -> int:
        m = _GROUPS_IOTA_RE.search(rest)
        if m:
            return int(m.group(2))
        m = _GROUPS_LIST_RE.search(rest)
        if m:
            return len(m.group(1).split(","))
        return world

    def _operand_shape(self, rest: str, idx: int) -> Optional[str]:
        m = re.match(r"([^)]*)\)", rest)
        if not m:
            return None
        region = m.group(1)
        # newer XLA prints operand types inline —
        # "dot(f32[64,128]{1,0} %a, f32[128,128]{1,0} %b)" — in which case
        # the types ARE the operand list (comma-splitting would break on
        # the commas inside shapes); older text is names-only, looked up
        # in the recorded shape table.
        typed = [t.group(0) for t in _SHAPE_RE.finditer(region)]
        if typed:
            return typed[idx] if idx < len(typed) else None
        ops = [o.strip().lstrip("%") for o in region.split(",")]
        if idx >= len(ops):
            return None
        return self.shapes.get(ops[idx])

    def _dus_update_bytes(self, inst: Instruction) -> Optional[int]:
        """In-place loop writes: a dynamic-update-slice (or a fusion rooted
        at one) writes only its UPDATE operand, not the whole buffer.
        Counting the full output per loop iteration overstates HBM traffic
        by the trip count (verified: 40x for 40-layer residual stacks).
        Returns the update-operand bytes, or None if not a DUS pattern."""
        if inst.kind == "dynamic-update-slice":
            upd = self._operand_shape(inst.rest, 1)
            if upd:
                return _parse_shape(upd)[1]
            return None
        if inst.kind != "fusion":
            return None
        cm = re.search(r"calls=%?([\w\.\-]+)", inst.rest)
        if not cm or cm.group(1) not in self.computations:
            return None
        body = self.computations[cm.group(1)]
        root = next((i for i in reversed(body)
                     if i.kind not in ("parameter", "constant")), None)
        if root is None:
            return None
        if root.kind == "dynamic-update-slice":
            upd = self._operand_shape(root.rest, 1)
            if upd:
                # update may itself be a fused computation's value; fall
                # back to the smallest parameter if lookup fails
                return _parse_shape(upd)[1]
            params = [i for i in body if i.kind == "parameter"]
            if params:
                return min(_parse_shape(p.type_str)[1] for p in params)
        if root.kind == "tuple":
            # multi-output fusion: DUS members count their update operand;
            # other members count full size
            members = re.match(r"([^)]*)\)", root.rest)
            if not members:
                return None
            names = [o.strip().lstrip("%")
                     for o in members.group(1).split(",")]
            by_name = {i.name: i for i in body}
            if not any(by_name.get(n) is not None and
                       by_name[n].kind == "dynamic-update-slice"
                       for n in names):
                return None
            total = 0
            for n in names:
                mi = by_name.get(n)
                if mi is None:
                    return None
                if mi.kind == "dynamic-update-slice":
                    upd = self._operand_shape(mi.rest, 1)
                    if upd is None:
                        return None
                    total += _parse_shape(upd)[1]
                else:
                    total += _parse_shape(mi.type_str)[1]
            return total
        return None

    # ------------------------------------------------------------ stats
    _SKIP = {"parameter", "constant", "tuple", "get-tuple-element",
             "bitcast", "after-all", "iota", "partition-id", "replica-id"}
    _COLL = {"all-reduce", "all-gather", "reduce-scatter", "all-to-all",
             "collective-permute", "all-reduce-start", "all-gather-start",
             "collective-permute-start"}

    def stats(self, world: int = 1) -> CompStats:
        memo: Dict[str, CompStats] = {}
        assert self.entry, "no ENTRY computation found"
        return self._comp_stats(self.entry, world, memo)

    def _comp_stats(self, comp: str, world: int,
                    memo: Dict[str, CompStats]) -> CompStats:
        if comp in memo:
            return memo[comp]
        st = CompStats()
        memo[comp] = st

        def add_bytes(kind, nbytes, desc):
            st.bytes_hbm += 2.0 * nbytes
            st.bytes_by_kind[kind] = st.bytes_by_kind.get(kind, 0.0) \
                + 2.0 * nbytes
            st.top_ops.append((2.0 * nbytes, desc))

        for inst in self.computations.get(comp, []):
            kind = inst.kind
            if kind == "while":
                cm = re.search(r"condition=%?([\w\.\-]+)", inst.rest)
                bm = re.search(r"body=%?([\w\.\-]+)", inst.rest)
                cond = cm.group(1) if cm else None
                body = bm.group(1) if bm else None
                trips = self._trip_count(cond) if cond else 1
                if body:
                    st.merge_scaled(self._comp_stats(body, world, memo),
                                    trips)
                continue
            if kind in ("conditional", "call", "async-start"):
                for cname in _CALLED_RE.findall(inst.rest):
                    if cname in self.computations:
                        st.merge_scaled(
                            self._comp_stats(cname, world, memo), 1.0)
                continue
            if kind in self._SKIP:
                continue
            elems, nbytes = inst.elems_bytes
            if kind in self._COLL:
                g = self._group_size(inst.rest, world)
                base = kind.replace("-start", "")
                if base == "all-reduce":
                    link = 2.0 * nbytes * (g - 1) / max(g, 1)
                elif base in ("all-gather", "all-to-all"):
                    link = nbytes * (g - 1) / max(g, 1)
                elif base == "reduce-scatter":
                    link = nbytes * (g - 1)
                else:  # collective-permute
                    link = float(nbytes)
                st.coll_bytes[base] = st.coll_bytes.get(base, 0.0) + link
                st.n_coll[base] = st.n_coll.get(base, 0) + 1
                add_bytes(base, nbytes,
                          f"{base} {inst.type_str[:48]} in {comp[:40]}")
                continue
            if kind == "dot":
                lhs = self._operand_shape(inst.rest, 0)
                contract = 1
                cm = re.search(r"lhs_contracting_dims=\{([\d,]*)\}",
                               inst.rest)
                if lhs and cm and cm.group(1):
                    lm = _SHAPE_RE.search(lhs)
                    if lm and lm.group(2):
                        dims = [int(x) for x in lm.group(2).split(",")]
                        for d in cm.group(1).split(","):
                            di = int(d)
                            if di < len(dims):
                                contract *= dims[di]
                st.flops += 2.0 * elems * contract
                add_bytes("dot", nbytes,
                          f"dot {inst.type_str[:48]} in {comp[:40]}")
                continue
            if kind == "convolution":
                st.flops += 2.0 * elems * 64  # coarse; convs are rare here
                add_bytes("convolution", nbytes, f"conv in {comp[:40]}")
                continue
            dus_bytes = self._dus_update_bytes(inst)
            if dus_bytes is not None:
                add_bytes("in-place-update", dus_bytes / 2.0,
                          f"dus-update({dus_bytes/1e6:.0f}MB) "
                          f"{inst.type_str[:40]} in {comp[:40]}")
                continue
            # generic materializing op (fusion root, copy, custom-call, ...)
            add_bytes(kind if kind in ("fusion", "copy", "custom-call",
                                       "broadcast",
                                       "transpose", "reshape", "scatter",
                                       "gather", "reduce", "select",
                                       "dynamic-slice", "concatenate")
                      else "other", nbytes,
                      f"{kind} {inst.type_str[:48]} in {comp[:40]}")
        st.top_ops = sorted(st.top_ops, reverse=True)[:24]
        return st


def analyze(compiled_text: str, world: int = 1) -> CompStats:
    return HLOModule(compiled_text).stats(world)


def matched_bytes(module: HLOModule, pred) -> float:
    """Effective (trip-multiplied) HBM bytes of instructions whose result
    shape satisfies ``pred(dims: tuple) -> bool``.

    Used by the HW-route roofline: on TPU the Pallas flash kernel keeps the
    (.., Sq, kv_chunk) score tensors in VMEM, so their XLA-path HBM traffic
    is subtracted when projecting the kernel route (EXPERIMENTS.md §Perf).
    """
    memo: Dict[str, float] = {}

    def comp_bytes(comp: str) -> float:
        if comp in memo:
            return memo[comp]
        memo[comp] = 0.0
        total = 0.0
        for inst in module.computations.get(comp, []):
            if inst.kind == "while":
                cm = re.search(r"condition=%?([\w\.\-]+)", inst.rest)
                bm = re.search(r"body=%?([\w\.\-]+)", inst.rest)
                trips = module._trip_count(cm.group(1)) if cm else 1
                if bm:
                    total += trips * comp_bytes(bm.group(1))
                continue
            if inst.kind in ("conditional", "call", "async-start"):
                for cname in _CALLED_RE.findall(inst.rest):
                    if cname in module.computations:
                        total += comp_bytes(cname)
                continue
            if inst.kind in HLOModule._SKIP:
                continue
            m = _SHAPE_RE.search(inst.type_str)
            if not m or not m.group(2):
                continue
            dims = tuple(int(x) for x in m.group(2).split(","))
            if pred(dims):
                _, nbytes = inst.elems_bytes
                total += 2.0 * nbytes
        memo[comp] = total
        return total

    assert module.entry
    return comp_bytes(module.entry)


def score_tensor_bytes(compiled_text: str, attn_chunk: int,
                       min_rows: int = 1024) -> float:
    """Attention score/probability tensor traffic in the XLA path."""
    mod = HLOModule(compiled_text)

    def pred(dims):
        return (len(dims) >= 2 and dims[-1] == attn_chunk
                and dims[-2] >= min_rows)

    return matched_bytes(mod, pred)
