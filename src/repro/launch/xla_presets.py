"""Per-backend XLA-flag presets: THE place ``XLA_FLAGS`` is written.

Every runner used to mutate ``os.environ["XLA_FLAGS"]`` ad hoc (and the
dry-run prepended its device-count flag on every import, accumulating
duplicates).  This module is the config layer instead:

  * ``PRESETS`` declares the per-backend flag sets — the GPU set is the
    latency-hiding scheduler / async collectives / triton-gemm trio
    (jax gpu_performance_tips; the bayespec exemplar in SNIPPETS.md);
  * ``apply()`` merges a preset into ``XLA_FLAGS`` **idempotently**:
    flags are deduped by name and an already-set flag keeps its value,
    so a user's explicit environment always wins;
  * ``force_host_device_count(n)`` is the one knob the CPU dry-run
    stack needs (512 virtual host devices).

Import rules: this module must stay importable *before* jax (no jax
import at module scope) — callers apply presets, then import jax.
Writing ``XLA_FLAGS`` after jax initialized its backends is a silent
no-op, so ``apply`` records what it did (``applied_presets``) and the
callers that own process startup (``launch/dryrun.py``,
``launch/hillclimb.py``, ``launch/distributed.initialize_runtime``,
``benchmarks/run.py``) call it first thing.

No other module may write ``os.environ["XLA_FLAGS"]``; the only
exceptions are generated subprocess scripts in tests, which are their
own process entry points.
"""
from __future__ import annotations

import os
import sys
from typing import Dict, Iterable, List, Optional, Tuple

# jax gpu_performance_tips flag set (communication/compute overlap +
# triton gemm autotuning) — see SNIPPETS.md (bayespec config.py).
GPU_PRESET: Tuple[str, ...] = (
    "--xla_gpu_enable_latency_hiding_scheduler=true",
    "--xla_gpu_enable_async_collectives=true",
    "--xla_gpu_enable_highest_priority_async_stream=true",
    "--xla_gpu_enable_triton_softmax_fusion=true",
    "--xla_gpu_triton_gemm_any=True",
)

# CPU/TPU carry no blanket flags: the CPU stack's only knob is the
# virtual device count (see force_host_device_count), and TPU's
# latency-hiding defaults are already on in current libtpu — an unknown
# flag in XLA_FLAGS is a *fatal* init error, so presets only list flags
# known-good for their backend.
CPU_PRESET: Tuple[str, ...] = ()
TPU_PRESET: Tuple[str, ...] = ()

PRESETS: Dict[str, Tuple[str, ...]] = {
    "gpu": GPU_PRESET,
    "cuda": GPU_PRESET,
    "rocm": GPU_PRESET,
    "cpu": CPU_PRESET,
    "tpu": TPU_PRESET,
}

# What apply() actually merged this process (introspection / tests).
applied_presets: List[str] = []


def _flag_name(flag: str) -> str:
    return flag.split("=", 1)[0]


def _merge(existing: str, new_flags: Iterable[str]) -> str:
    """Append flags whose *names* are not already present (user wins)."""
    parts = [p for p in existing.split() if p]
    have = {_flag_name(p) for p in parts}
    for flag in new_flags:
        if _flag_name(flag) not in have:
            parts.append(flag)
            have.add(_flag_name(flag))
    return " ".join(parts)


def detect_backend() -> str:
    """Best pre-jax backend guess: the JAX_PLATFORMS pin, else cpu.

    Deliberately conservative — presets are opt-in per backend, and
    guessing "gpu" on a cpu host would inject flags that are never
    exercised.  Runners that know their backend pass it explicitly.
    """
    plat = os.environ.get("JAX_PLATFORMS") or os.environ.get("JAX_PLATFORM_NAME")
    if plat:
        return plat.split(",")[0].strip().lower() or "cpu"
    return "cpu"


def preset_flags(backend: Optional[str] = None) -> Tuple[str, ...]:
    backend = (backend or detect_backend()).lower()
    return PRESETS.get(backend, ())


def apply(backend: Optional[str] = None, *,
          host_device_count: Optional[int] = None,
          extra_flags: Iterable[str] = ()) -> str:
    """Merge the backend preset (+ extras) into ``XLA_FLAGS``.

    Idempotent; returns the final ``XLA_FLAGS`` value.  If jax is already
    imported the merge still happens (harmless) but is recorded with a
    ``late:`` marker so tests can flag ordering bugs.
    """
    backend = (backend or detect_backend()).lower()
    flags = list(preset_flags(backend))
    if host_device_count is not None:
        flags.append(
            f"--xla_force_host_platform_device_count={int(host_device_count)}")
    flags.extend(extra_flags)
    merged = _merge(os.environ.get("XLA_FLAGS", ""), flags)
    if merged:
        os.environ["XLA_FLAGS"] = merged
    tag = f"{backend}:{len(flags)}"
    if "jax" in sys.modules:
        tag = "late:" + tag
    applied_presets.append(tag)
    return merged


def force_host_device_count(n: int) -> str:
    """The dry-run stack's knob: ``n`` virtual CPU devices.

    Must run before the first jax import (jax locks the device count on
    backend init); keeps any count already pinned in the environment.
    """
    return apply("cpu", host_device_count=n)
