from repro.launch.xla_presets import force_host_device_count
force_host_device_count(512)
# ^ MUST precede any jax import: jax locks the device count on first init.
import os
"""Multi-pod dry-run: lower + compile every (arch x shape x mesh) cell.

For each cell this driver builds the real jitted program (train_step with
AdamW update / prefill / decode_step) with explicit in/out shardings on the
production mesh, compiles it AOT (no allocation), and records:
  * memory_analysis()   — per-device argument/output/temp bytes (fits?)
  * cost_analysis()     — XLA's flops/bytes (loop bodies counted once)
  * trip-count-aware HLO stats (launch/hlo_analysis.py): per-device FLOPs,
    HBM-traffic proxy, per-collective link bytes  -> the roofline terms
  * the roofline terms themselves (seconds) + dominant bottleneck.

Usage:
  python -m repro.launch.dryrun --arch mixtral-8x7b --shape train_4k
  python -m repro.launch.dryrun --all [--mesh single|multi|both] [--force]

Results are cached as JSON under artifacts/dryrun/.
"""
import argparse
import json
import sys
import time
import traceback
from typing import Any, Dict, Optional

import numpy as np

import jax
import jax.numpy as jnp
from jax.sharding import NamedSharding, PartitionSpec as P

from repro import optim
from repro.configs import ARCH_NAMES, SHAPES, applicable, get_config
from repro.launch import hlo_analysis
from repro.launch.mesh import (HBM_BW, ICI_BW, PEAK_FLOPS_BF16,
                               make_production_mesh)
from repro.launch.partition import (batch_pspec, make_cache_pspec_fn,
                                    params_pspecs, rules_for, tree_pspecs)
from repro.launch.sharding import axis_rules
from repro.models import build_model, input_specs, params_specs
from repro.obs.logging import configure as obs_configure, get_logger

log = get_logger("launch.dryrun")

ART_DIR = os.path.join(os.path.dirname(__file__), "..", "..", "..",
                       "artifacts", "dryrun")


def _ns(mesh, spec_tree):
    return jax.tree_util.tree_map(
        lambda s: NamedSharding(mesh, s), spec_tree,
        is_leaf=lambda x: isinstance(x, P))


def _count_params(params_sds) -> int:
    return int(sum(np.prod(l.shape) for l in
                   jax.tree_util.tree_leaves(params_sds)))


def _active_params(cfg, params_sds) -> int:
    total = _count_params(params_sds)
    if cfg.moe is None:
        return total
    flat = jax.tree_util.tree_flatten_with_path(params_sds)[0]
    expert = 0
    for path, leaf in flat:
        keys = [str(getattr(p, "key", "")) for p in path]
        if "moe" in keys and keys[-1] in ("w1", "w2", "w3"):
            expert += int(np.prod(leaf.shape))
    active = total - expert + expert * cfg.moe.top_k // cfg.moe.num_experts
    return active


def model_flops(cfg, shape, params_sds) -> float:
    n = _active_params(cfg, params_sds)
    if shape.kind == "train":
        toks = shape.global_batch * shape.seq_len
        return 6.0 * n * toks
    if shape.kind == "prefill":
        toks = shape.global_batch * shape.seq_len
        return 2.0 * n * toks
    return 2.0 * n * shape.global_batch  # decode: one token per sequence


def build_lowered(arch: str, shape_name: str, mesh,
                  loss_chunk: Optional[int] = None,
                  microbatch: Optional[int] = None,
                  overrides: Optional[Dict[str, Any]] = None,
                  rules: Optional[Dict[str, Any]] = None,
                  axes: Optional[Dict[str, Any]] = None,
                  grad_unreduced: bool = False,
                  zero1: bool = False):
    """Build and lower the cell's program. Returns (lowered, meta).

    ``rules``/``axes``: sharding-variant overrides (§Perf hillclimbs) —
    logical-axis rules for activations and axis assignment for params.
    """
    cfg = get_config(arch)
    import dataclasses
    if loss_chunk:
        cfg = dataclasses.replace(cfg, loss_chunk=loss_chunk)
    if overrides:
        cfg = dataclasses.replace(cfg, **overrides)
    shape = SHAPES[shape_name]
    ok, why = applicable(cfg, shape)
    if not ok:
        raise SkipCell(why)
    rules = rules if rules is not None else rules_for(cfg, mesh)
    attn_axis = (axes or {}).get("attn", "model")
    model = build_model(cfg)
    with mesh, axis_rules(rules, mesh):
        p_sds = params_specs(model)
        p_sh = _ns(mesh, params_pspecs(p_sds, mesh, axes))
        specs = input_specs(cfg, shape, model)
        if shape.kind == "train":
            opt_sds = jax.eval_shape(optim.init, p_sds)
            o_specs = tree_pspecs(opt_sds, mesh,
                                  lambda p, s, m: P())  # rebuilt below
            o_sh = _ns(mesh, optim.AdamWState(
                count=P(), mu=params_pspecs(p_sds, mesh, axes),
                nu=params_pspecs(p_sds, mesh, axes)))
            b_sds = specs["batch"]
            b_sh = _ns(mesh, tree_pspecs(b_sds, mesh, batch_pspec))
            ocfg = optim.AdamWConfig()
            # microbatch count: keep per-device micro batch ~4 sequences
            dp = int(np.prod([mesh.shape[a] for a in ("pod", "data")
                              if a in mesh.shape]))
            b_local = max(1, shape.global_batch // dp)
            k = microbatch if microbatch else max(1, b_local // 4)

            def grads_of(params, mb):
                (loss, _), g = jax.value_and_grad(
                    model.forward, has_aux=True)(params, mb)
                return loss, g

            # §Perf HC-A: keep per-microbatch grads UNREDUCED over the data
            # axes so the cross-replica all-reduce runs once per step, not
            # once per microbatch (jax 'unreduced' PartitionSpec).
            dp_axes = tuple(a for a in ("pod", "data") if a in mesh.shape)
            g_specs = params_pspecs(p_sds, mesh, axes)
            def _isP(x):
                return isinstance(x, P)

            def _extend(s, shape):
                """Additionally shard a free dim over the data axes
                (ZeRO-style: grads reduce-scatter, moments stay sharded)."""
                lst = list(s) + [None] * (len(shape) - len(s))
                dp_total = max(1, int(np.prod([mesh.shape[a]
                                               for a in dp_axes])))
                for i, d in enumerate(shape):
                    if lst[i] is None and d % dp_total == 0:
                        lst[i] = dp_axes if len(dp_axes) > 1 else dp_axes[0]
                        break
                return P(*lst)

            def _extended_sh():
                flatspecs = jax.tree_util.tree_flatten(g_specs, is_leaf=_isP)
                flatleaves = jax.tree_util.tree_leaves(p_sds)
                ext = [NamedSharding(mesh, _extend(s, l.shape))
                       for s, l in zip(flatspecs[0], flatleaves)]
                return jax.tree_util.tree_unflatten(flatspecs[1], ext)

            g_unred_sh = g_red_sh = None
            if grad_unreduced == "unreduced":  # needs Explicit-mode mesh
                g_unred_sh = jax.tree_util.tree_map(
                    lambda s: NamedSharding(mesh, P(*s,
                                                    unreduced=set(dp_axes))),
                    g_specs, is_leaf=_isP)
            elif grad_unreduced or zero1:
                # data-sharded accumulator: per-microbatch partial sums
                # land via reduce-scatter (half the all-reduce bytes);
                # one all-gather restores replication at the update.
                g_unred_sh = _extended_sh()
                grad_unreduced = True
            g_red_sh = _ns(mesh, g_specs)
            if zero1:
                # ZeRO-1: AdamW moments sharded over data too — the only
                # way a 46B-param MoE's f32 optimizer fits 16 GB chips
                o_sh = optim.AdamWState(
                    count=NamedSharding(mesh, P()),
                    mu=_extended_sh(), nu=_extended_sh())

            def train_step(params, opt_state, batch):
                if k > 1:
                    mbs = jax.tree_util.tree_map(
                        lambda a: a.reshape((k, a.shape[0] // k)
                                            + a.shape[1:]), batch)

                    def body(carry, mb):
                        g_acc, l_acc = carry
                        loss, g = grads_of(params, mb)
                        if grad_unreduced:
                            g = jax.lax.with_sharding_constraint(
                                g, g_unred_sh)
                        g_acc = jax.tree_util.tree_map(
                            lambda A, B: A + B.astype(jnp.float32),
                            g_acc, g)
                        return (g_acc, l_acc + loss), None

                    g0 = jax.tree_util.tree_map(
                        lambda p: jnp.zeros(p.shape, jnp.float32), params)
                    if grad_unreduced:
                        g0 = jax.lax.with_sharding_constraint(g0, g_unred_sh)
                    (grads, loss), _ = jax.lax.scan(
                        body, (g0, jnp.float32(0)), mbs)
                    if grad_unreduced and not zero1:  # reduce once, here
                        grads = jax.lax.with_sharding_constraint(
                            grads, g_red_sh)
                    # zero1: grads STAY data-sharded; the optimizer update
                    # runs on sharded moments and the param delta is
                    # all-gathered once (the ZeRO-1 pattern)
                    grads = jax.tree_util.tree_map(lambda g: g / k, grads)
                    loss = loss / k
                else:
                    loss, grads = grads_of(params, batch)
                params, opt_state, om = optim.update(ocfg, grads,
                                                     opt_state, params)
                return params, opt_state, loss, om["grad_norm"]

            fn = jax.jit(train_step, in_shardings=(p_sh, o_sh, b_sh),
                         out_shardings=(p_sh, o_sh, None, None),
                         donate_argnums=(0, 1))
            lowered = fn.lower(p_sds, opt_sds, b_sds)
        elif shape.kind == "prefill":
            b_sds = specs["batch"]
            cache_fn = make_cache_pspec_fn(shape.global_batch, mesh,
                                           attn_axis=attn_axis)
            b_spec = {}
            for k, v in b_sds.items():
                if k == "cache":
                    b_spec[k] = tree_pspecs(v, mesh, cache_fn)
                else:
                    b_spec[k] = tree_pspecs(v, mesh, batch_pspec)
            b_sh = _ns(mesh, b_spec)
            state_sh = None  # prefill output sharding: let XLA propagate
            fn = jax.jit(model.prefill, in_shardings=(p_sh, b_sh),
                         donate_argnums=(1,))
            lowered = fn.lower(p_sds, b_sds)
        else:  # decode
            cache_sds, tok_sds, t_sds = (specs["cache"], specs["tokens"],
                                         specs["t"])
            cache_fn = make_cache_pspec_fn(shape.global_batch, mesh,
                                           attn_axis=attn_axis)
            c_spec = tree_pspecs(cache_sds, mesh, cache_fn)
            c_sh = _ns(mesh, c_spec)
            tok_sh = NamedSharding(mesh, batch_pspec("tokens",
                                                     tok_sds.shape, mesh))
            t_sh = NamedSharding(mesh, P())
            fn = jax.jit(model.decode_step,
                         in_shardings=(p_sh, c_sh, tok_sh, t_sh),
                         out_shardings=(None, c_sh),
                         donate_argnums=(1,))
            lowered = fn.lower(p_sds, cache_sds, tok_sds, t_sds)
    meta = {"arch": arch, "shape": shape_name, "kind": shape.kind,
            "params": _count_params(p_sds),
            "active_params": _active_params(cfg, p_sds),
            "model_flops": model_flops(cfg, shape, p_sds),
            "microbatch": (microbatch or "auto") if shape.kind == "train"
            else None,
            "rules": {k: (list(v) if isinstance(v, tuple) else v)
                      for k, v in rules.items()}}
    return lowered, meta


class SkipCell(Exception):
    pass


def roofline_terms(stats: hlo_analysis.CompStats, n_chips: int,
                   mfl: float) -> Dict[str, Any]:
    coll = float(sum(stats.coll_bytes.values()))
    t_comp = stats.flops / PEAK_FLOPS_BF16        # per-device flops already
    t_mem = stats.bytes_hbm / HBM_BW
    t_coll = coll / ICI_BW
    terms = {"compute_s": t_comp, "memory_s": t_mem, "collective_s": t_coll}
    dom = max(terms, key=terms.get)
    bound = max(t_comp, t_mem, t_coll)
    mfu = (mfl / n_chips / PEAK_FLOPS_BF16) / bound if bound > 0 else 0.0
    return {**terms, "dominant": dom,
            "useful_flops_ratio": (mfl / n_chips) / max(stats.flops, 1.0),
            "roofline_fraction": mfu,
            "coll_bytes": {k: float(v) for k, v in stats.coll_bytes.items()},
            "n_coll": {k: int(v) for k, v in stats.n_coll.items()}}


def run_cell(arch: str, shape_name: str, multi_pod: bool,
             out_dir: str = ART_DIR, force: bool = False,
             loss_chunk: Optional[int] = None,
             microbatch: Optional[int] = None,
             overrides: Optional[Dict[str, Any]] = None,
             tag: str = "") -> Dict[str, Any]:
    mesh_name = "multi" if multi_pod else "single"
    os.makedirs(out_dir, exist_ok=True)
    path = os.path.join(out_dir,
                        f"{arch}__{shape_name}__{mesh_name}{tag}.json")
    if os.path.exists(path) and not force:
        with open(path) as f:
            cached = json.load(f)
        if cached.get("status") != "fail":   # failed cells retry
            return cached
    t0 = time.time()
    rec: Dict[str, Any] = {"arch": arch, "shape": shape_name,
                           "mesh": mesh_name}
    HBM_LIMIT = 15.5e9   # v5e 16 GB minus runtime reserve
    try:
        mesh = make_production_mesh(multi_pod=multi_pod)
        n_chips = int(np.prod(list(mesh.shape.values())))
        dp = int(np.prod([mesh.shape[a] for a in ("pod", "data")
                          if a in mesh.shape]))
        b_local = max(1, SHAPES[shape_name].global_batch // dp)
        # train cells: auto-bump gradient-accumulation microbatches until
        # the per-device temp memory fits HBM (an OOM-at-compile is a bug)
        k = microbatch or max(1, b_local // 4)
        while True:
            lowered, meta = build_lowered(arch, shape_name, mesh,
                                          loss_chunk=loss_chunk,
                                          microbatch=k,
                                          overrides=overrides)
            t_lower = time.time() - t0
            compiled = lowered.compile()
            t_compile = time.time() - t0 - t_lower
            ma = compiled.memory_analysis()
            temp = getattr(ma, "temp_size_in_bytes", 0)
            if (SHAPES[shape_name].kind != "train" or temp <= HBM_LIMIT
                    or k >= b_local or microbatch):
                break
            k = min(b_local, k * 2)
        meta["microbatch"] = k if SHAPES[shape_name].kind == "train" \
            else None
        ca = compiled.cost_analysis()
        ca = ca[0] if isinstance(ca, list) else (ca or {})
        txt = compiled.as_text()
        # cache the SPMD HLO so analyzer changes re-analyze without
        # recompiling (compiles are minutes; parses are seconds)
        import gzip
        hlo_dir = os.path.join(os.path.dirname(out_dir), "hlo")
        os.makedirs(hlo_dir, exist_ok=True)
        with gzip.open(os.path.join(
                hlo_dir, f"{arch}__{shape_name}__{mesh_name}{tag}.txt.gz"),
                "wt") as zf:
            zf.write(txt)
        stats = hlo_analysis.analyze(txt, world=n_chips)
        # HW-route projection: the Pallas flash kernel keeps score tensors
        # in VMEM on TPU; subtract their XLA-path HBM traffic (score shapes
        # are (.., >=1024, attn_chunk); see hlo_analysis.score_tensor_bytes)
        cfg_now = get_config(arch)
        score_b = hlo_analysis.score_tensor_bytes(txt, cfg_now.attn_chunk)
        rec.update(meta)
        rec.update({
            "status": "ok", "n_chips": n_chips,
            "lower_s": round(t_lower, 2), "compile_s": round(t_compile, 2),
            "memory": {
                "argument_bytes": getattr(ma, "argument_size_in_bytes", 0),
                "output_bytes": getattr(ma, "output_size_in_bytes", 0),
                "temp_bytes": getattr(ma, "temp_size_in_bytes", 0),
                "alias_bytes": getattr(ma, "alias_size_in_bytes", 0),
            },
            # resident = args (donated outputs alias them) + non-aliased
            # outputs + temps; donation avoids DOUBLING, not residency
            "fits_hbm": bool(getattr(ma, "argument_size_in_bytes", 0)
                             + getattr(ma, "output_size_in_bytes", 0)
                             - getattr(ma, "alias_size_in_bytes", 0)
                             + getattr(ma, "temp_size_in_bytes", 0)
                             <= 16e9),
            "xla_cost": {k: float(v) for k, v in dict(ca).items()
                         if isinstance(v, (int, float))},
            "hlo": {"flops_per_dev": stats.flops,
                    "hbm_bytes_per_dev": stats.bytes_hbm,
                    "bytes_by_kind": {k: float(v) for k, v in
                                      sorted(stats.bytes_by_kind.items(),
                                             key=lambda kv: -kv[1])},
                    "top_ops": [[round(b / 1e9, 3), d]
                                for b, d in stats.top_ops[:16]]},
            "roofline": roofline_terms(stats, n_chips, meta["model_flops"]),
        })
        rec["roofline"]["score_bytes_per_dev"] = score_b
        hw_mem = max(stats.bytes_hbm - score_b, 0.0) / HBM_BW
        terms = {"compute_s": rec["roofline"]["compute_s"],
                 "memory_s": hw_mem,
                 "collective_s": rec["roofline"]["collective_s"]}
        bound = max(terms.values())
        rec["roofline"]["hw_route"] = {
            **terms,
            "dominant": max(terms, key=terms.get),
            "roofline_fraction":
                (meta["model_flops"] / n_chips / PEAK_FLOPS_BF16) / bound
                if bound > 0 else 0.0}
    except SkipCell as e:
        rec.update({"status": "skip", "reason": str(e)})
    except Exception as e:  # noqa: BLE001 — record the failure, keep going
        rec.update({"status": "fail", "error": f"{type(e).__name__}: {e}",
                    "trace": traceback.format_exc()[-4000:]})
    rec["wall_s"] = round(time.time() - t0, 2)
    with open(path, "w") as f:
        json.dump(rec, f, indent=1)
    return rec


def main():
    obs_configure(stream=sys.stdout)
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default=None)
    ap.add_argument("--shape", default=None)
    ap.add_argument("--mesh", default="both",
                    choices=["single", "multi", "both"])
    ap.add_argument("--all", action="store_true")
    ap.add_argument("--force", action="store_true")
    ap.add_argument("--out", default=ART_DIR)
    args = ap.parse_args()

    cells = []
    archs = ARCH_NAMES if (args.all or not args.arch) else [args.arch]
    shapes = list(SHAPES) if (args.all or not args.shape) else [args.shape]
    meshes = {"single": [False], "multi": [True],
              "both": [False, True]}[args.mesh]
    for arch in archs:
        for shape in shapes:
            for mp in meshes:
                cells.append((arch, shape, mp))
    t0 = time.time()
    n_ok = n_skip = n_fail = 0
    for i, (arch, shape, mp) in enumerate(cells):
        rec = run_cell(arch, shape, mp, out_dir=args.out, force=args.force)
        n_ok += rec["status"] == "ok"
        n_skip += rec["status"] == "skip"
        n_fail += rec["status"] == "fail"
        dom = rec.get("roofline", {}).get("dominant", "-")
        log.info("cell", i=f"{i + 1}/{len(cells)}", arch=arch,
                 shape=shape, mesh="multi" if mp else "single",
                 status=rec["status"], wall_s=rec["wall_s"], dom=dom)
        if rec["status"] == "fail":
            log.error("cell_failed", arch=arch, shape=shape,
                      error=rec["error"][:300])
    log.info("done", wall_s=round(time.time() - t0), ok=n_ok,
             skip=n_skip, fail=n_fail)
    if n_fail:
        raise SystemExit(1)


if __name__ == "__main__":
    main()
