"""Re-derive roofline stats from cached HLO (no recompilation).

Usage: PYTHONPATH=src python -m repro.launch.reanalyze [--mesh single]
Updates the hlo/roofline fields of each artifacts/dryrun/*.json in place.
"""
from __future__ import annotations

import argparse
import glob
import gzip
import json
import os

import sys

from repro.configs import get_config
from repro.launch import hlo_analysis
from repro.launch.dryrun import ART_DIR, roofline_terms
from repro.launch.mesh import HBM_BW, PEAK_FLOPS_BF16
from repro.obs.logging import configure as obs_configure, get_logger

log = get_logger("launch.reanalyze")


def reanalyze_one(json_path: str, hlo_path: str) -> bool:
    with open(json_path) as f:
        rec = json.load(f)
    if rec.get("status") != "ok" or not os.path.exists(hlo_path):
        return False
    with gzip.open(hlo_path, "rt") as zf:
        txt = zf.read()
    n_chips = rec["n_chips"]
    stats = hlo_analysis.analyze(txt, world=n_chips)
    cfg = get_config(rec["arch"])
    score_b = hlo_analysis.score_tensor_bytes(txt, cfg.attn_chunk)
    rec["hlo"] = {
        "flops_per_dev": stats.flops,
        "hbm_bytes_per_dev": stats.bytes_hbm,
        "bytes_by_kind": {k: float(v) for k, v in
                          sorted(stats.bytes_by_kind.items(),
                                 key=lambda kv: -kv[1])},
        "top_ops": [[round(b / 1e9, 3), d] for b, d in stats.top_ops[:16]],
    }
    rec["roofline"] = roofline_terms(stats, n_chips, rec["model_flops"])
    rec["roofline"]["score_bytes_per_dev"] = score_b
    hw_mem = max(stats.bytes_hbm - score_b, 0.0) / HBM_BW
    terms = {"compute_s": rec["roofline"]["compute_s"], "memory_s": hw_mem,
             "collective_s": rec["roofline"]["collective_s"]}
    bound = max(terms.values())
    rec["roofline"]["hw_route"] = {
        **terms, "dominant": max(terms, key=terms.get),
        "roofline_fraction":
            (rec["model_flops"] / n_chips / PEAK_FLOPS_BF16) / bound
            if bound > 0 else 0.0}
    with open(json_path, "w") as f:
        json.dump(rec, f, indent=1)
    return True


def main():
    obs_configure(stream=sys.stdout)
    ap = argparse.ArgumentParser()
    ap.add_argument("--mesh", default=None)
    args = ap.parse_args()
    hlo_dir = os.path.join(os.path.dirname(ART_DIR), "hlo")
    n = 0
    for jp in sorted(glob.glob(os.path.join(ART_DIR, "*.json"))):
        base = os.path.basename(jp)[:-5]
        if args.mesh and not base.endswith(args.mesh):
            pass
        hp = os.path.join(hlo_dir, base + ".txt.gz")
        if reanalyze_one(jp, hp):
            n += 1
            log.info("reanalyzed", cell=base)
    log.info("done", cells=n)


if __name__ == "__main__":
    main()
