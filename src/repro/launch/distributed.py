"""Multi-host fleet runtime: one FleetPlan agreed by every process.

PR 2's fleet layer mapped logical devices onto a single process.  This
module lifts it across process boundaries, which is where the paper's
cost argument actually lives (§II Fig. 2 is a *fleet* claim): a fleet of
hosts coordinating one ``FleetPlan`` so a quarantined device on host A
migrates its in-flight work to a hot spare owned by host B without
dropping a request.

The design is deterministic replication.  Fleet health transitions are
not applied locally and gossiped; they are *events* in one totally
ordered log, and every host folds the same log over the same initial
``FleetPlan``:

  * ``FleetEvent`` — one transition (``with_stage_fault`` /
    ``with_device_fault`` / ``with_recovery`` / host loss), stamped with
    (step, origin host, per-origin sequence number).  That stamp is a
    total order: sorting any multiset of events yields one canonical
    log, independent of network arrival interleaving.
  * ``EventChannel`` — per-step all-to-all exchange of locally observed
    events through a ``HostCoordinator``; returns the merged, ordered
    slice every host applies identically.
  * ``HostCoordinator`` — the transport.  ``KVCoordinator`` rides the
    jax.distributed coordination-service key-value store (works on CPU
    backends where cross-process XLA collectives may not), and
    ``LocalCoordinator`` is the trivial single-process instance.

``HostTopology`` names the device→host partition and ``HostView``
extends ``FleetMeshView`` with per-host masks and global→local device
index translation, so ``launch/sharding.shard_bounds`` can partition a
global batch while each host executes only its owned slice.

``initialize_runtime`` wraps ``jax.distributed.initialize`` (and turns
on gloo CPU collectives where available) so the whole thing is drivable
by ``num_processes >= 2`` subprocess tests with ``JAX_PLATFORMS=cpu``.
"""

from __future__ import annotations

import hashlib
import json
import random
import time
from dataclasses import dataclass
from typing import Dict, List, Mapping, Optional, Sequence, Tuple

from repro.core.routing import FleetPlan
from repro.launch.mesh import FleetMeshView, _mesh
from repro.launch.sharding import shard_bounds
from repro.obs import metrics as obs_metrics
from repro.obs.logging import get_logger, set_host
from repro.viscosity.lang import HW, SW

log = get_logger("launch.distributed")

# Event kinds, mirroring the FleetPlan transitions (plus host loss, which
# expands to one with_host_fault transition over the host's device block).
STAGE = "stage"
DEVICE = "device"
RECOVER = "recover"
HOST = "host"
EVENT_KINDS = (STAGE, DEVICE, RECOVER, HOST)


# --------------------------------------------------------------- runtime
@dataclass(frozen=True)
class DistributedRuntime:
    """What ``initialize_runtime`` established for this process."""

    num_processes: int
    process_id: int
    coordinator_address: Optional[str] = None


def initialize_runtime(
    coordinator_address: Optional[str] = None,
    num_processes: int = 1,
    process_id: int = 0,
    *,
    cpu_collectives: Optional[str] = "gloo",
    xla_preset: Optional[str] = "auto",
) -> DistributedRuntime:
    """Wrap ``jax.distributed.initialize`` for the fleet runtime.

    Call before any jax computation (backends must not be initialized
    yet); per-process local device count comes from the environment
    (``XLA_FLAGS=--xla_force_host_platform_device_count=N`` on CPU).
    ``num_processes <= 1`` with no coordinator address is the
    single-process no-op, so the same entry point serves tests and
    real launches.  ``cpu_collectives`` selects the CPU cross-process
    collective backend (gloo) where this jax exposes the knob — without
    it, CPU cross-process *computations* fail but the coordination
    service (and so ``KVCoordinator``) still works.  ``xla_preset``
    merges the per-backend XLA flag preset (``launch/xla_presets.py``)
    before jax initializes — "auto" infers the backend from the
    environment pin, an explicit name selects that preset, and None
    skips the layer entirely (ad-hoc ``XLA_FLAGS`` mutation is not a
    supported path; the preset layer is the one config surface).
    """
    from repro.launch import xla_presets

    if xla_preset is not None:
        xla_presets.apply(None if xla_preset == "auto" else xla_preset)

    import jax

    set_host(process_id)
    if num_processes <= 1 and coordinator_address is None:
        return DistributedRuntime(num_processes=1, process_id=0)
    if cpu_collectives is not None:
        try:
            jax.config.update("jax_cpu_collectives_implementation", cpu_collectives)
        except Exception:  # noqa: BLE001 - probing a version-dependent jax
            pass  # config knob; absence is expected, not an error path
    jax.distributed.initialize(
        coordinator_address=coordinator_address,
        num_processes=num_processes,
        process_id=process_id,
    )
    return DistributedRuntime(
        num_processes=jax.process_count(),
        process_id=jax.process_index(),
        coordinator_address=coordinator_address,
    )


# -------------------------------------------------------------- topology
@dataclass(frozen=True)
class HostTopology:
    """The device→host partition: ``num_hosts`` hosts own contiguous
    blocks of ``devices_per_host`` logical fleet devices.

    ``host_id`` is this process's slot; ``None`` means single-process
    emulation (this process owns every host's devices — the benches and
    in-process tests exercise the host-axis semantics that way).
    """

    num_hosts: int
    devices_per_host: int
    host_id: Optional[int] = None

    def __post_init__(self):
        if self.num_hosts < 1 or self.devices_per_host < 1:
            raise ValueError(
                f"topology needs >= 1 host and >= 1 device/host, got "
                f"{self.num_hosts} x {self.devices_per_host}"
            )
        if self.host_id is not None and not (0 <= self.host_id < self.num_hosts):
            raise ValueError(
                f"host_id {self.host_id} out of range for "
                f"{self.num_hosts} host(s)"
            )

    @classmethod
    def current(cls, devices_per_host: Optional[int] = None) -> "HostTopology":
        """The topology of the initialized jax.distributed runtime."""
        import jax

        return cls(
            num_hosts=jax.process_count(),
            devices_per_host=(
                len(jax.local_devices())
                if devices_per_host is None
                else devices_per_host
            ),
            host_id=jax.process_index(),
        )

    @property
    def n_devices(self) -> int:
        return self.num_hosts * self.devices_per_host

    def host_of(self, device: int) -> int:
        if not 0 <= device < self.n_devices:
            raise ValueError(
                f"device {device} out of range for {self.n_devices} "
                f"fleet device(s)"
            )
        return device // self.devices_per_host

    def local_index(self, device: int) -> int:
        """Global fleet index → index among its host's devices."""
        self.host_of(device)
        return device % self.devices_per_host

    def global_index(self, host: int, local: int) -> int:
        if not 0 <= local < self.devices_per_host:
            raise ValueError(
                f"local index {local} out of range for "
                f"{self.devices_per_host} device(s)/host"
            )
        return host * self.devices_per_host + local

    def devices_of(self, host: Optional[int] = None) -> Tuple[int, ...]:
        """The device block a host owns (default: this host)."""
        host = self.host_id if host is None else host
        if host is None:
            raise ValueError(
                "topology has no host_id: pass devices_of(host) "
                "explicitly in single-process emulation"
            )
        lo = host * self.devices_per_host
        return tuple(range(lo, lo + self.devices_per_host))

    def is_local(self, device: int) -> bool:
        """Does this process execute ``device``?  Always true in
        single-process emulation (``host_id is None``)."""
        if self.host_id is None:
            return True
        return self.host_of(device) == self.host_id


# ------------------------------------------------------------- host view
@dataclass(frozen=True)
class HostView(FleetMeshView):
    """A ``FleetMeshView`` that knows the device→host partition.

    Adds per-host mask slices and global→local device-index translation
    on top of the fleet health mask, so multi-host launch code can build
    local submeshes and pick its slice of ``shard_bounds`` without ever
    re-deriving the partition.
    """

    topology: Optional[HostTopology] = None

    def __post_init__(self):
        if self.topology is None:
            raise ValueError("HostView requires a HostTopology")
        if self.topology.n_devices != len(self.mask):
            raise ValueError(
                f"topology covers {self.topology.n_devices} device(s), "
                f"fleet mask has {len(self.mask)}"
            )

    @classmethod
    def of(cls, fleet_plan, topology: HostTopology) -> "HostView":
        """Project a FleetPlan onto the host partition (the multi-host
        sibling of ``FleetMeshView.from_plan``)."""
        base = FleetMeshView.from_plan(fleet_plan)
        return cls(
            mask=base.mask,
            quarantined=base.quarantined,
            idle_spares=base.idle_spares,
            topology=topology,
        )

    # ------------------------------------------------------- host slices
    def host_mask(self, host: int) -> Tuple[bool, ...]:
        """The health mask restricted to ``host``'s device block."""
        devs = self.topology.devices_of(host)
        return tuple(self.mask[d] for d in devs)

    def serving_on(self, host: int) -> Tuple[int, ...]:
        return tuple(d for d in self.topology.devices_of(host) if self.mask[d])

    def hosts_serving(self) -> Tuple[int, ...]:
        """Hosts with at least one serving device (a fully lost host
        drops out of this tuple — the surviving hosts re-fold)."""
        return tuple(h for h in range(self.topology.num_hosts) if self.serving_on(h))

    def local_serving(self) -> Tuple[int, ...]:
        """Serving devices this process owns (global indices)."""
        if self.topology.host_id is None:
            return self.serving()
        return self.serving_on(self.topology.host_id)

    # --------------------------------------------- local mesh / sharding
    def local_serving_devices(self) -> List:
        """This process's physical devices behind its serving indices
        (``jax.local_devices``-indexed via the topology translation).

        In single-process emulation (``host_id is None``) every logical
        index is local, so the mapping is identity — translating
        through ``local_index`` there would alias the per-host blocks
        onto the same physical devices."""
        import jax

        local = jax.local_devices()
        if self.topology.host_id is None:
            return self.serving_devices(local)
        serving = self.local_serving()
        need = max((self.topology.local_index(d) for d in serving), default=-1)
        if need >= len(local):
            raise RuntimeError(
                f"host view needs local device {need}, process has "
                f"{len(local)}: short {need + 1 - len(local)} device(s)"
            )
        return [local[self.topology.local_index(d)] for d in serving]

    def local_submesh(self, axes: Sequence[str] = ("data",)):
        """1-D mesh over this host's serving devices only."""
        devs = self.local_serving_devices()
        if not devs:
            raise RuntimeError(
                f"host {self.topology.host_id} has no serving devices "
                f"(quarantined={self.quarantined})"
            )
        return _mesh((len(devs),), tuple(axes), devices=devs)

    def shard_bounds(self, n_items: int) -> Dict[int, Tuple[int, int]]:
        """Global-batch partition over the whole fleet mask, filtered to
        the devices this process owns — every host computes the same
        global split and takes its own slice."""
        owned = None if self.topology.host_id is None else self.topology.devices_of()
        return shard_bounds(n_items, self.mask, owned=owned)


# ------------------------------------------------------------- event log
@dataclass(frozen=True, order=True)
class FleetEvent:
    """One fleet transition with its total-order stamp.

    ``(step, origin, seq)`` orders any multiset of events canonically:
    ``step`` is the engine step the event takes effect at, ``origin``
    the host that observed it, ``seq`` that host's running counter.
    ``device`` holds the host index when ``kind == "host"``.
    """

    step: int
    origin: int
    seq: int
    kind: str
    device: int
    stage: str = ""

    def __post_init__(self):
        if self.kind not in EVENT_KINDS:
            raise ValueError(
                f"unknown fleet event kind {self.kind!r}; expected one "
                f"of {EVENT_KINDS}"
            )
        if self.kind == STAGE and not self.stage:
            raise ValueError("stage events must name the faulted stage")

    # ------------------------------------------------- wire / engine form
    def to_wire(self) -> list:
        return [
            self.step,
            self.origin,
            self.seq,
            self.kind,
            self.device,
            self.stage,
        ]

    @staticmethod
    def from_wire(wire: Sequence) -> "FleetEvent":
        step, origin, seq, kind, device, stage = wire
        return FleetEvent(
            step=int(step),
            origin=int(origin),
            seq=int(seq),
            kind=str(kind),
            device=int(device),
            stage=str(stage),
        )

    def engine_tuple(self) -> Tuple:
        """The event in the FleetServeEngine's tuple dialect."""
        if self.kind == STAGE:
            return (STAGE, self.device, self.stage)
        if self.kind == RECOVER and self.stage:
            # Stage-scoped recovery (probation verdict: transient) —
            # undoes exactly one rung, not the whole device.
            return (RECOVER, self.device, self.stage)
        return (self.kind, self.device)

    @staticmethod
    def from_engine(step: int, origin: int, seq: int, event: Sequence) -> "FleetEvent":
        kind = event[0]
        stage = event[2] if kind in (STAGE, RECOVER) and len(event) > 2 else ""
        return FleetEvent(
            step=step,
            origin=origin,
            seq=seq,
            kind=kind,
            device=int(event[1]),
            stage=stage,
        )


def merge_event_logs(
    *logs: Sequence[FleetEvent],
) -> Tuple[FleetEvent, ...]:
    """Canonical merge: the sorted, deduplicated union of per-host logs.

    Deterministic under ANY arrival interleaving — the stamp is a total
    order, so every host that sees the same event multiset produces the
    same log (the property test permutes arrivals and asserts this).
    """
    merged = set()
    for log in logs:
        merged.update(log)
    return tuple(sorted(merged))


def apply_event(
    plan: FleetPlan,
    event: FleetEvent,
    stage_names: Sequence[str],
    *,
    target: str = HW,
    fallback: str = SW,
    topology: Optional[HostTopology] = None,
) -> Tuple[FleetPlan, bool]:
    """Fold one event over a FleetPlan; ``(plan, False)`` when the
    transition no longer applies (e.g. two hosts both reported a device
    that the first report already quarantined) — merged logs tolerate
    benign duplicates instead of desyncing the fleet."""
    try:
        if event.kind == STAGE:
            return plan.with_stage_fault(event.device, event.stage, fallback), True
        if event.kind == DEVICE:
            return plan.with_device_fault(event.device), True
        if event.kind == RECOVER:
            if event.stage:
                return (
                    plan.with_stage_recovery(event.device, event.stage, target=target),
                    True,
                )
            return plan.with_recovery(event.device, stage_names, target=target), True
        if topology is None:
            raise ValueError("host events need a HostTopology for the block")
        return plan.with_host_fault(topology.devices_of(event.device)), True
    except (ValueError, KeyError):
        return plan, False


def replay_log(
    plan: FleetPlan,
    events: Sequence[FleetEvent],
    stage_names: Sequence[str],
    *,
    target: str = HW,
    fallback: str = SW,
    topology: Optional[HostTopology] = None,
) -> Tuple[FleetPlan, Tuple[FleetEvent, ...]]:
    """Fold an ordered log over a plan; returns the final plan and the
    events that were dropped as inapplicable."""
    dropped: List[FleetEvent] = []
    for ev in merge_event_logs(events):
        plan, applied = apply_event(
            plan,
            ev,
            stage_names,
            target=target,
            fallback=fallback,
            topology=topology,
        )
        if not applied:
            dropped.append(ev)
    return plan, tuple(dropped)


def fleet_fingerprint(plan: FleetPlan) -> str:
    """Stable digest of a FleetPlan's full state — hosts exchange this
    to assert they agreed on the same plan (the hash() builtin is salted
    per process, so it cannot cross a process boundary)."""
    doc = {
        "plans": [list(p.assignments) + [p.default] for p in plan.plans],
        "spares": list(plan.pool.spares),
        "assignments": [list(a) for a in plan.pool.assignments],
        "quarantined": list(plan.quarantined),
        "fault_counts": list(plan.fault_counts),
    }
    return hashlib.sha256(json.dumps(doc, sort_keys=True).encode()).hexdigest()[:16]


# ----------------------------------------------------------- coordinators
class HostTimeoutError(RuntimeError):
    """A peer host failed to publish within the bounded retry budget.

    Typed — carrying the missing ``host_id`` — so the fleet layer can
    convert the silent peer into a ``with_host_fault`` event (survivors
    re-fold and keep serving) instead of inheriting an opaque hang.
    """

    def __init__(self, host_id: int, message: Optional[str] = None):
        super().__init__(message or f"host {host_id} timed out")
        self.host_id = int(host_id)


_CLIENT_ERRORS: Optional[Tuple[type, ...]] = None


def coordination_client_errors() -> Tuple[type, ...]:
    """Error types the coordination-service client raises (timeouts,
    disconnects, missing keys).  Probed lazily because the taxonomy
    varies across jaxlibs; ``RuntimeError`` is the floor every known
    client satisfies.  This is the *only* exception set coordination
    code may catch broadly — anything outside it is a genuine bug and
    must propagate."""
    global _CLIENT_ERRORS
    if _CLIENT_ERRORS is None:
        errs: List[type] = [RuntimeError]
        try:
            from jax._src.lib import xla_client as _xc

            err = getattr(_xc, "XlaRuntimeError", None)
            if isinstance(err, type) and issubclass(err, Exception):
                errs.append(err)
        except Exception:  # noqa: BLE001 - probing a version-dependent
            pass  # jax internal; absence is expected
        try:
            import jax

            err = getattr(getattr(jax, "errors", None), "JaxRuntimeError", None)
            if isinstance(err, type) and issubclass(err, Exception):
                errs.append(err)
        except Exception:  # noqa: BLE001 - same version probe
            pass
        _CLIENT_ERRORS = tuple(dict.fromkeys(errs))
    return _CLIENT_ERRORS


class LocalCoordinator:
    """The trivial single-host transport (exchange = identity)."""

    num_hosts = 1
    host_id = 0

    def exchange(self, payload: str) -> List[str]:
        return [payload]


class KVCoordinator:
    """All-to-all string exchange over the jax.distributed coordination
    service's key-value store.

    Works wherever ``jax.distributed.initialize`` succeeded — including
    CPU backends whose XLA cross-process *computations* are unavailable
    — so fleet coordination never depends on device collectives.  Every
    call advances a round counter shared by construction (hosts make the
    same deterministic sequence of exchanges), giving each exchange a
    fresh key namespace.
    """

    def __init__(
        self,
        num_hosts: Optional[int] = None,
        host_id: Optional[int] = None,
        *,
        client=None,
        timeout_ms: int = 120_000,
        attempt_timeout_ms: int = 5_000,
        max_attempts: int = 6,
        backoff_base_s: float = 0.05,
        backoff_factor: float = 2.0,
        namespace: str = "fleet",
    ):
        import jax

        self.num_hosts = jax.process_count() if num_hosts is None else num_hosts
        self.host_id = jax.process_index() if host_id is None else host_id
        if client is None:
            from jax._src import distributed as _jax_distributed

            client = _jax_distributed.global_state.client
            if client is None:
                raise RuntimeError(
                    "jax.distributed is not initialized; call "
                    "initialize_runtime() first"
                )
        if max_attempts < 1:
            raise ValueError(f"max_attempts must be >= 1, got {max_attempts}")
        self._client = client
        self._timeout_ms = timeout_ms
        self._attempt_timeout_ms = attempt_timeout_ms
        self._max_attempts = max_attempts
        self._backoff_base_s = backoff_base_s
        self._backoff_factor = backoff_factor
        self._namespace = namespace
        self._round = 0
        self._dead: set = set()

    def mark_dead(self, host: int) -> None:
        """Stop waiting on ``host``: the fleet layer calls this after it
        converted the peer's ``HostTimeoutError`` into a host-fault
        event.  The dead peer's slot in every later exchange is ``None``
        (consumers skip it) — the survivors keep lockstep rounds without
        re-paying the retry budget each step."""
        self._dead.add(int(host))

    def _get_with_retry(self, key: str, peer: int, round_idx: int) -> str:
        """Bounded retries with jittered exponential backoff under the
        overall ``timeout_ms`` deadline.  A peer that never publishes
        surfaces as a typed ``HostTimeoutError(host_id)`` after at most
        ``max_attempts`` short gets — not one opaque 120 s block."""
        deadline = time.monotonic() + self._timeout_ms / 1000.0
        # Deterministically seeded jitter: distinct per (round, peer,
        # self) so hosts don't thundering-herd the service in sync.
        rng = random.Random(round_idx * 1009 + peer * 31 + self.host_id)
        last: Optional[BaseException] = None
        attempts = 0
        for attempt in range(self._max_attempts):
            remaining_ms = int((deadline - time.monotonic()) * 1000)
            if remaining_ms <= 0:
                break
            attempts += 1
            budget = min(self._attempt_timeout_ms, remaining_ms)
            try:
                return self._client.blocking_key_value_get(f"{key}/{peer}", budget)
            except coordination_client_errors() as e:
                last = e
                obs_metrics.inc("kv_retries_total", op="get")
                obs_metrics.set_gauge("coord_attempt_timeout_seconds",
                                      budget / 1000.0, host=str(peer))
                if attempt + 1 >= self._max_attempts:
                    break
                backoff = min(
                    self._backoff_base_s * self._backoff_factor**attempt,
                    max(0.0, deadline - time.monotonic()),
                )
                if backoff > 0:
                    time.sleep(backoff * (0.5 + rng.random()))
        obs_metrics.inc("coord_timeouts_total", host=str(peer))
        log.warning("host_timeout", host=peer, round=round_idx,
                    attempts=attempts)
        raise HostTimeoutError(
            peer,
            f"host {peer} did not publish round {round_idx} within "
            f"{attempts} attempt(s) (budget {self._max_attempts} x "
            f"{self._attempt_timeout_ms} ms, deadline {self._timeout_ms} ms)",
        ) from last

    def exchange(self, payload: str) -> List[Optional[str]]:
        r = self._round
        self._round += 1
        key = f"{self._namespace}/x{r}"
        self._client.key_value_set(f"{key}/{self.host_id}", payload)
        out: List[Optional[str]] = []
        for h in range(self.num_hosts):
            if h == self.host_id:
                out.append(payload)
            elif h in self._dead:
                out.append(None)
            else:
                out.append(self._get_with_retry(key, h, r))
        # Garbage-collect this host's key from two rounds back: rounds
        # are lockstep (every host makes the same exchange sequence), so
        # a peer still reading round r-1 has finished r-2 entirely —
        # deleting r-2 can never race a reader.  Without this the
        # coordination service accumulates one key per host per step
        # for the life of the runtime.  Cleanup is best-effort, but only
        # for the *client's* error taxonomy — anything else is a real
        # bug and propagates.
        if r >= 2 and hasattr(self._client, "key_value_delete"):
            try:
                self._client.key_value_delete(
                    f"{self._namespace}/x{r - 2}/{self.host_id}"
                )
            except coordination_client_errors() as e:
                log.debug("kv_gc_failed", round=r - 2, error=str(e))
        return out


class EventChannel:
    """Per-step event agreement over a coordinator.

    Each host publishes the transitions it *locally* observed this step;
    every host receives the union and applies the canonical merge order.
    ``log`` accumulates the agreed history — the fleet's event log.
    """

    def __init__(self, coordinator):
        self.coordinator = coordinator
        self.log: List[FleetEvent] = []
        self._seq = 0

    def _stamp(self, step: int, local_events: Sequence[Sequence]) -> List[FleetEvent]:
        stamped = []
        for ev in local_events:
            host = self.coordinator.host_id
            stamped.append(FleetEvent.from_engine(step, host, self._seq, ev))
            self._seq += 1
        return stamped

    def _merge_payloads(
        self, payloads: Sequence[Optional[str]]
    ) -> Tuple[FleetEvent, ...]:
        # None slots are peers the coordinator marked dead — their
        # history is already folded; nothing new can arrive from them.
        logs = [
            tuple(FleetEvent.from_wire(w) for w in json.loads(p))
            for p in payloads
            if p is not None
        ]
        merged = merge_event_logs(*logs)
        self.log.extend(merged)
        return merged

    def exchange(
        self, step: int, local_events: Sequence[Sequence]
    ) -> Tuple[FleetEvent, ...]:
        """Agree on this step's events (call once per step, every host)."""
        stamped = self._stamp(step, local_events)
        payload = json.dumps([e.to_wire() for e in stamped])
        return self._merge_payloads(self.coordinator.exchange(payload))

    def exchange_many(
        self, step_events: Mapping[int, Sequence[Sequence]]
    ) -> Tuple[FleetEvent, ...]:
        """One exchange covering several steps (the late-event flush
        after a workload drains)."""
        stamped: List[FleetEvent] = []
        for step in sorted(step_events):
            stamped.extend(self._stamp(step, step_events[step]))
        payload = json.dumps([e.to_wire() for e in stamped])
        return self._merge_payloads(self.coordinator.exchange(payload))
