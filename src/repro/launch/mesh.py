"""Production meshes (assignment spec) + the fleet-health mesh view.

``make_production_mesh`` is a FUNCTION so importing this module never
touches jax device state.  Shapes: single pod = (16, 16) ("data","model");
multi-pod = (2, 16, 16) ("pod","data","model") — 2 pods x 256 chips.

``FleetMeshView`` is the fleet layer's device view: a ``FleetPlan``'s
explicit health mask (serving / quarantined / idle-spare) applied to the
process's physical devices, from which health-masked submeshes are built —
the mesh only ever contains devices that are actually taking traffic.
"""
from __future__ import annotations

import math
from dataclasses import dataclass
from typing import List, Sequence, Tuple

import jax


def _mesh(shape: Tuple[int, ...], axes: Tuple[str, ...], devices=None):
    n = math.prod(shape)
    devices = list(jax.devices() if devices is None else devices)
    if len(devices) < n:
        raise RuntimeError(
            f"mesh {shape} needs {n} devices, have {len(devices)}: short "
            f"{n - len(devices)} device(s) — the "
            "dry-run must set XLA_FLAGS=--xla_force_host_platform_device_count"
            " before importing jax (see launch/dryrun.py)")
    kw = {}
    if hasattr(jax.sharding, "AxisType"):   # added after jax 0.4.x; Auto is
        kw["axis_types"] = (jax.sharding.AxisType.Auto,) * len(axes)
    return jax.make_mesh(shape, axes, devices=devices[:n], **kw)


def make_production_mesh(*, multi_pod: bool = False):
    shape = (2, 16, 16) if multi_pod else (16, 16)
    axes = ("pod", "data", "model") if multi_pod else ("data", "model")
    return _mesh(shape, axes)


def make_mesh(shape: Sequence[int], axes: Sequence[str]):
    """Arbitrary mesh (tests use small ones, e.g. (2, 4))."""
    return _mesh(tuple(shape), tuple(axes))


# ------------------------------------------------------- fleet health view
@dataclass(frozen=True)
class FleetMeshView:
    """A fleet's health state projected onto this process's devices.

    ``mask[i]`` is True iff logical device ``i`` is serving traffic;
    quarantined devices and idle spares are carried explicitly (never
    silently dropped), so schedulers can reason about capacity and
    recovery, and ``submesh`` only ever builds meshes over serving
    hardware.
    """

    mask: Tuple[bool, ...]
    quarantined: Tuple[int, ...] = ()
    idle_spares: Tuple[int, ...] = ()

    @staticmethod
    def from_plan(fleet_plan) -> "FleetMeshView":
        """Project a FleetPlan's device table onto the mesh layer."""
        return FleetMeshView(
            mask=tuple(fleet_plan.device_mask()),
            quarantined=tuple(fleet_plan.quarantined),
            idle_spares=tuple(fleet_plan.pool.free()))

    @property
    def n_devices(self) -> int:
        return len(self.mask)

    def serving(self) -> Tuple[int, ...]:
        return tuple(i for i, ok in enumerate(self.mask) if ok)

    def serving_devices(self, devices=None) -> List[jax.Device]:
        """The physical devices behind the serving logical indices; the
        view must fit the device list (loud error otherwise).

        ``devices`` defaults to ``jax.devices()`` — under an initialized
        ``jax.distributed`` runtime that is the *global* device list, so
        logical fleet index i maps to global device i across hosts (the
        per-host slice lives on ``launch.distributed.HostView``)."""
        devices = list(jax.devices() if devices is None else devices)
        if self.n_devices > len(devices):
            raise RuntimeError(
                f"fleet view covers {self.n_devices} devices, process has "
                f"{len(devices)}: short {self.n_devices - len(devices)} "
                "device(s)")
        return [devices[i] for i in self.serving()]

    def submesh(self, axes: Sequence[str] = ("data",), *,
                model: int = 1, devices=None):
        """Health-masked mesh over the serving devices only.

        1-D by default (pure data parallel); ``model > 1`` folds the
        serving devices into a (data, model) grid — serving count must be
        divisible, and the error names the shortfall."""
        devs = self.serving_devices(devices)
        n = len(devs)
        if model > 1:
            if n % model:
                raise RuntimeError(
                    f"{n} serving device(s) do not fold into model={model} "
                    f"groups: short {model - n % model} device(s) (or "
                    f"quarantine {n % model} more)")
            return _mesh((n // model, model), tuple(axes), devices=devs)
        return _mesh((n,), tuple(axes), devices=devs)


# Hardware constants for the roofline (assignment-provided, TPU v5e-class).
PEAK_FLOPS_BF16 = 197e12      # per chip
HBM_BW = 819e9                # bytes/s per chip
ICI_BW = 50e9                 # bytes/s per link
