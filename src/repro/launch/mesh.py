"""Production meshes (assignment spec).

``make_production_mesh`` is a FUNCTION so importing this module never
touches jax device state.  Shapes: single pod = (16, 16) ("data","model");
multi-pod = (2, 16, 16) ("pod","data","model") — 2 pods x 256 chips.
"""
from __future__ import annotations

import math
from typing import Optional, Sequence, Tuple

import jax


def _mesh(shape: Tuple[int, ...], axes: Tuple[str, ...]):
    n = math.prod(shape)
    devices = jax.devices()
    if len(devices) < n:
        raise RuntimeError(
            f"mesh {shape} needs {n} devices, have {len(devices)} — the "
            "dry-run must set XLA_FLAGS=--xla_force_host_platform_device_count"
            " before importing jax (see launch/dryrun.py)")
    kw = {}
    if hasattr(jax.sharding, "AxisType"):   # added after jax 0.4.x; Auto is
        kw["axis_types"] = (jax.sharding.AxisType.Auto,) * len(axes)
    return jax.make_mesh(shape, axes, devices=devices[:n], **kw)


def make_production_mesh(*, multi_pod: bool = False):
    shape = (2, 16, 16) if multi_pod else (16, 16)
    axes = ("pod", "data", "model") if multi_pod else ("data", "model")
    return _mesh(shape, axes)


def make_mesh(shape: Sequence[int], axes: Sequence[str]):
    """Arbitrary mesh (tests use small ones, e.g. (2, 4))."""
    return _mesh(tuple(shape), tuple(axes))


# Hardware constants for the roofline (assignment-provided, TPU v5e-class).
PEAK_FLOPS_BF16 = 197e12      # per chip
HBM_BW = 819e9                # bytes/s per chip
ICI_BW = 50e9                 # bytes/s per link
