"""§Perf hillclimb variants: named sharding/structure configurations.

Each variant gives: optional mesh override (shape+axes), activation rules,
param-axis assignment, and config overrides.  launch/hillclimb.py runs a
cell under a variant and compares roofline terms against the baseline.
"""
from __future__ import annotations

from typing import Any, Dict


def _rules_2d(h_ax, f_ax):
    both = (h_ax, f_ax)
    return {
        "batch": ("data",), "seq": None, "embed": None,
        "heads": h_ax, "kv_heads": h_ax, "kv_seq": None, "head_dim": None,
        "mlp": both, "vocab": both,
        "experts": None, "expert_cap": None,
        "ssm_inner": both, "ssm_state": None, "ssm_heads": h_ax,
    }


VARIANTS: Dict[str, Dict[str, Any]] = {
    # HC-A: avoid re-running TP collectives in the backward recompute
    "remat_coll": dict(overrides={"remat_policy": "collectives"}),
    # HC-A: dots-saveable (max compute reuse; memory cost measured)
    "remat_dots": dict(overrides={"remat_policy": "dots"}),
    # HC-C: 2D attention sharding — heads over a 4-way sub-axis (divides
    # qwen's 20 heads), FFN/vocab over the full 16-way product.  Attention
    # replication drops 16x -> 4x.
    "attn2d": dict(mesh_shape=(16, 4, 4),
                   mesh_axes=("data", "model_h", "model_f"),
                   rules=_rules_2d("model_h", "model_f"),
                   axes={"attn": "model_h",
                         "ffn": ("model_h", "model_f"),
                         "vocab": ("model_h", "model_f"),
                         "ssm": ("model_h", "model_f"),
                         "expert": None}),
    # HC-B: expert parallelism — model axis refactored into expert x tp
    "ep": dict(mesh_shape=(16, 8, 2),
               mesh_axes=("data", "expert", "tp"),
               rules={**_rules_2d("expert", "tp"),
                      "heads": ("expert", "tp"), "kv_heads": "expert",
                      "mlp": "tp", "experts": "expert"},
               axes={"attn": ("expert", "tp"), "ffn": "tp",
                     "vocab": ("expert", "tp"), "ssm": "tp",
                     "expert": "expert"}),
    # HC-B: combine expert outputs BEFORE the TP all-reduce
    "moe_combine_first": dict(overrides={}, moe_combine_first=True),
    # bigger attention chunk (fewer scan trips, same score traffic)
    "chunk2k": dict(overrides={"attn_chunk": 2048}),
    # HC-A: accumulate per-microbatch grads UNREDUCED over data axes;
    # the cross-replica all-reduce runs once per step
    "grad_unreduced": dict(train_kw={"grad_unreduced": True}),
    # composite: RS grad accumulation + collectives-saving remat
    "hc_a": dict(train_kw={"grad_unreduced": True},
                 overrides={"remat_policy": "collectives"}),
    # composite + bigger microbatch (memory headroom from neither saving
    # activations twice nor replicating grads)
    "hc_a_mb8": dict(train_kw={"grad_unreduced": True},
                     overrides={"remat_policy": "collectives"},
                     microbatch=8),
    "hc_a_mb4": dict(train_kw={"grad_unreduced": True},
                     overrides={"remat_policy": "collectives"},
                     microbatch=4),
    # HC-B composite: EP mesh + combine-first + RS grads + remat_coll
    "hc_b": dict(mesh_shape=(16, 8, 2),
                 mesh_axes=("data", "expert", "tp"),
                 rules={**_rules_2d("expert", "tp"),
                        "heads": ("expert", "tp"), "kv_heads": "expert",
                        "mlp": "tp", "experts": "expert"},
                 axes={"attn": ("expert", "tp"), "ffn": "tp",
                       "vocab": ("expert", "tp"), "ssm": "tp",
                       "expert": "expert"},
                 train_kw={"grad_unreduced": True},
                 overrides={"remat_policy": "collectives"},
                 moe_combine_first=True,
                 microbatch=8),
    # HC-B v2: EP + RS grads + remat_coll, WITHOUT combine_first
    "hc_b2": dict(mesh_shape=(16, 8, 2),
                  mesh_axes=("data", "expert", "tp"),
                  rules={**_rules_2d("expert", "tp"),
                         "heads": ("expert", "tp"), "kv_heads": "expert",
                         "mlp": "tp", "experts": "expert"},
                  axes={"attn": ("expert", "tp"), "ffn": "tp",
                        "vocab": ("expert", "tp"), "ssm": "tp",
                        "expert": "expert"},
                  train_kw={"grad_unreduced": True},
                  overrides={"remat_policy": "collectives"},
                  microbatch=8),
    "hc_b3": dict(mesh_shape=(16, 8, 2),
                  mesh_axes=("data", "expert", "tp"),
                  rules={**_rules_2d("expert", "tp"),
                         "heads": ("expert", "tp"), "kv_heads": "expert",
                         "mlp": "tp", "experts": "expert"},
                  axes={"attn": ("expert", "tp"), "ffn": "tp",
                        "vocab": ("expert", "tp"), "ssm": "tp",
                        "expert": "expert"},
                  train_kw={"grad_unreduced": True},
                  overrides={"remat_policy": "collectives"},
                  microbatch=16),
    # HC-B final: EP + ZeRO-1 sharded optimizer + RS grads + remat_coll
    "hc_b_zero1": dict(mesh_shape=(16, 8, 2),
                       mesh_axes=("data", "expert", "tp"),
                       rules={**_rules_2d("expert", "tp"),
                              "heads": ("expert", "tp"),
                              "kv_heads": "expert",
                              "mlp": "tp", "experts": "expert"},
                       axes={"attn": ("expert", "tp"), "ffn": "tp",
                             "vocab": ("expert", "tp"), "ssm": "tp",
                             "expert": "expert"},
                       train_kw={"zero1": True},
                       overrides={"remat_policy": "collectives"},
                       microbatch=16),
    # ZeRO-1 alone on the production mesh (applies to every train cell)
    "zero1": dict(train_kw={"zero1": True}),
    "hc_a_zero1": dict(train_kw={"zero1": True},
                       overrides={"remat_policy": "collectives"},
                       microbatch=8),
    # HC-B final+: bf16 params (f32 moments = master copy) + EP + ZeRO-1
    "hc_b_final": dict(mesh_shape=(16, 8, 2),
                       mesh_axes=("data", "expert", "tp"),
                       rules={**_rules_2d("expert", "tp"),
                              "heads": ("expert", "tp"),
                              "kv_heads": "expert",
                              "mlp": "tp", "experts": "expert"},
                       axes={"attn": ("expert", "tp"), "ffn": "tp",
                             "vocab": ("expert", "tp"), "ssm": "tp",
                             "expert": "expert"},
                       train_kw={"zero1": True},
                       overrides={"remat_policy": "collectives",
                                  "param_dtype": "bfloat16"},
                       microbatch=16),
}


def variant_mesh(v: Dict[str, Any], multi_pod: bool):
    from repro.launch.mesh import make_mesh, make_production_mesh
    if "mesh_shape" not in v:
        return make_production_mesh(multi_pod=multi_pod)
    shape, axes = v["mesh_shape"], v["mesh_axes"]
    if multi_pod:
        shape = (2,) + tuple(shape)
        axes = ("pod",) + tuple(axes)
    return make_mesh(shape, axes)
