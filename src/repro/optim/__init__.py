from repro.optim.adamw import (AdamWConfig, AdamWState, clip_by_global_norm,
                               global_norm, init, schedule, update)
from repro.optim.compression import compress_tree, init_error

__all__ = ["AdamWConfig", "AdamWState", "init", "update", "schedule",
           "global_norm", "clip_by_global_norm", "compress_tree",
           "init_error"]
