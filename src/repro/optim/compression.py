"""Int8 error-feedback gradient compression (cross-pod collective trick).

At 1000+ node scale the gradient all-reduce over the pod axis (DCI links)
dominates the collective term; int8 quantization cuts those bytes 4x
(vs f32) while error feedback keeps convergence (the residual of each
quantization is added back before the next one — standard EF-SGD result).

In the pjit program the compression brackets the cross-pod psum:
grads are quantized per-leaf with a shared absmax scale, summed in int32
across pods, then dequantized.  On the dry-run mesh the byte reduction is
visible directly in the collective term (EXPERIMENTS.md §Perf).
"""
from __future__ import annotations

from typing import Any, Optional, Tuple

import jax
import jax.numpy as jnp

PyTree = Any


def quantize_leaf(g: jax.Array, err: Optional[jax.Array]
                  ) -> Tuple[jax.Array, jax.Array, jax.Array]:
    """g (+err) -> (int8 q, f32 scale, new error residual)."""
    gf = g.astype(jnp.float32)
    if err is not None:
        gf = gf + err
    scale = jnp.maximum(jnp.max(jnp.abs(gf)), 1e-12) / 127.0
    q = jnp.clip(jnp.round(gf / scale), -127, 127).astype(jnp.int8)
    deq = q.astype(jnp.float32) * scale
    return q, scale, gf - deq


def compress_tree(grads: PyTree, err: Optional[PyTree]
                  ) -> Tuple[PyTree, PyTree]:
    """Quantize+dequantize each leaf with error feedback.

    Returns (dequantized grads, new error buffers).  The int8 tensors are
    what cross the pod axis; end-to-end this function models their effect
    on the *values* (the byte count enters the roofline analytically).
    """
    flat, tdef = jax.tree_util.tree_flatten(grads)
    errs = (jax.tree_util.tree_leaves(err) if err is not None
            else [None] * len(flat))
    outs, new_errs = [], []
    for g, e in zip(flat, errs):
        q, s, r = quantize_leaf(g, e)
        outs.append(q.astype(jnp.float32) * s)
        new_errs.append(r)
    return (jax.tree_util.tree_unflatten(tdef, outs),
            jax.tree_util.tree_unflatten(tdef, new_errs))


def init_error(params: PyTree) -> PyTree:
    return jax.tree_util.tree_map(
        lambda p: jnp.zeros(p.shape, jnp.float32), params)


def compressed_bytes(params: PyTree) -> int:
    return sum(int(np.prod(p.shape)) for p in
               jax.tree_util.tree_leaves(params))


import numpy as np  # noqa: E402  (used above in compressed_bytes)
