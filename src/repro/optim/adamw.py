"""AdamW + global-norm clipping + warmup-cosine schedule (pure JAX).

Optimizer state is a pytree mirroring params (f32 moments regardless of
param dtype — mixed-precision-safe); everything shards with the params
under pjit (moments inherit the param PartitionSpecs).
"""
from __future__ import annotations

from dataclasses import dataclass
from typing import Any, NamedTuple, Tuple

import jax
import jax.numpy as jnp

PyTree = Any


@dataclass(frozen=True)
class AdamWConfig:
    lr: float = 3e-4
    b1: float = 0.9
    b2: float = 0.95
    eps: float = 1e-8
    weight_decay: float = 0.1
    clip_norm: float = 1.0
    warmup_steps: int = 100
    total_steps: int = 10_000
    min_lr_frac: float = 0.1


class AdamWState(NamedTuple):
    count: jax.Array
    mu: PyTree
    nu: PyTree


def init(params: PyTree) -> AdamWState:
    def zeros(p):
        return jnp.zeros(p.shape, jnp.float32)
    return AdamWState(count=jnp.zeros((), jnp.int32),
                      mu=jax.tree_util.tree_map(zeros, params),
                      nu=jax.tree_util.tree_map(zeros, params))


def schedule(cfg: AdamWConfig, step) -> jax.Array:
    step = step.astype(jnp.float32)
    warm = jnp.minimum(1.0, (step + 1) / max(cfg.warmup_steps, 1))
    prog = jnp.clip((step - cfg.warmup_steps) /
                    max(cfg.total_steps - cfg.warmup_steps, 1), 0.0, 1.0)
    cos = 0.5 * (1 + jnp.cos(jnp.pi * prog))
    frac = cfg.min_lr_frac + (1 - cfg.min_lr_frac) * cos
    return cfg.lr * warm * frac


def global_norm(tree: PyTree) -> jax.Array:
    leaves = [jnp.sum(jnp.square(x.astype(jnp.float32)))
              for x in jax.tree_util.tree_leaves(tree)]
    return jnp.sqrt(jnp.sum(jnp.stack(leaves)))


def clip_by_global_norm(grads: PyTree, max_norm: float
                        ) -> Tuple[PyTree, jax.Array]:
    norm = global_norm(grads)
    scale = jnp.minimum(1.0, max_norm / jnp.maximum(norm, 1e-9))
    return jax.tree_util.tree_map(lambda g: g * scale, grads), norm


def update(cfg: AdamWConfig, grads: PyTree, state: AdamWState,
           params: PyTree) -> Tuple[PyTree, AdamWState, dict]:
    grads = jax.tree_util.tree_map(lambda g: g.astype(jnp.float32), grads)
    if cfg.clip_norm:
        grads, gnorm = clip_by_global_norm(grads, cfg.clip_norm)
    else:
        gnorm = global_norm(grads)
    count = state.count + 1
    lr = schedule(cfg, count)
    b1, b2 = cfg.b1, cfg.b2
    mu = jax.tree_util.tree_map(lambda m, g: b1 * m + (1 - b1) * g,
                                state.mu, grads)
    nu = jax.tree_util.tree_map(lambda v, g: b2 * v + (1 - b2) * g * g,
                                state.nu, grads)
    c = count.astype(jnp.float32)
    bc1 = 1 - b1 ** c
    bc2 = 1 - b2 ** c

    def upd(p, m, v):
        step = (m / bc1) / (jnp.sqrt(v / bc2) + cfg.eps)
        step = step + cfg.weight_decay * p.astype(jnp.float32)
        return (p.astype(jnp.float32) - lr * step).astype(p.dtype)

    new_params = jax.tree_util.tree_map(upd, params, mu, nu)
    return new_params, AdamWState(count, mu, nu), \
        {"grad_norm": gnorm, "lr": lr}
