"""Architecture registry: --arch <id> resolves through here."""
from __future__ import annotations

import importlib

from repro.configs.base import ModelConfig
from repro.configs.shapes import SHAPES, SMOKE_SHAPES, ShapeSpec, applicable

_ARCH_MODULES = {
    "zamba2-1.2b": "repro.configs.zamba2_1p2b",
    "qwen1.5-4b": "repro.configs.qwen1p5_4b",
    "gemma2-2b": "repro.configs.gemma2_2b",
    "mistral-nemo-12b": "repro.configs.mistral_nemo_12b",
    "gemma3-1b": "repro.configs.gemma3_1b",
    "llama4-scout-17b-a16e": "repro.configs.llama4_scout_17b",
    "mixtral-8x7b": "repro.configs.mixtral_8x7b",
    "qwen2-vl-7b": "repro.configs.qwen2_vl_7b",
    "whisper-base": "repro.configs.whisper_base",
    "rwkv6-1.6b": "repro.configs.rwkv6_1p6b",
}

ARCH_NAMES = tuple(_ARCH_MODULES)


def get_config(name: str) -> ModelConfig:
    if name.endswith("-smoke"):
        return get_config(name[: -len("-smoke")]).reduced()
    if name not in _ARCH_MODULES:
        raise KeyError(f"unknown arch {name!r}; known: {sorted(_ARCH_MODULES)}")
    return importlib.import_module(_ARCH_MODULES[name]).CONFIG


__all__ = ["ARCH_NAMES", "SHAPES", "SMOKE_SHAPES", "ModelConfig", "ShapeSpec",
           "applicable", "get_config"]
