"""mixtral-8x7b [moe]: 32L d_model=4096 32H (GQA kv=8) d_ff=14336 vocab=32000.

8 experts top-2, sliding-window attention (4096). [arXiv:2401.04088; hf]
"""
from repro.configs.base import ATTN_LOCAL, ModelConfig, MoEConfig

CONFIG = ModelConfig(
    name="mixtral-8x7b",
    family="moe",
    num_layers=32,
    d_model=4096,
    num_heads=32,
    num_kv_heads=8,
    head_dim=128,
    d_ff=14336,
    vocab_size=32_000,
    layer_pattern=(ATTN_LOCAL,),   # SWA on every layer
    window=4096,
    moe=MoEConfig(num_experts=8, top_k=2, moe_every=1, capacity_factor=1.25),
    rope_theta=1_000_000.0,
    tie_embeddings=False,
)
