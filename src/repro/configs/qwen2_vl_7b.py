"""qwen2-vl-7b [vlm]: 28L d_model=3584 28H (GQA kv=4) d_ff=18944 vocab=152064.

M-RoPE (t,h,w sections), dynamic resolution; vision frontend is a STUB —
``input_specs()`` provides precomputed patch embeddings + 3D positions.
[arXiv:2409.12191; hf]
"""
from repro.configs.base import ATTN_GLOBAL, ModelConfig

CONFIG = ModelConfig(
    name="qwen2-vl-7b",
    family="vlm",
    num_layers=28,
    d_model=3584,
    num_heads=28,
    num_kv_heads=4,
    head_dim=128,
    d_ff=18944,
    vocab_size=152_064,
    layer_pattern=(ATTN_GLOBAL,),
    qkv_bias=True,
    mrope_sections=(16, 24, 24),
    rope_theta=1_000_000.0,
    stub_frontend=True,
    tie_embeddings=False,
)
