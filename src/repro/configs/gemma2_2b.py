"""gemma2-2b [dense]: 26L d_model=2304 8H (GQA kv=4) d_ff=9216 vocab=256000.

Local(4096)/global alternating, attn logit softcap 50, final softcap 30,
GeGLU, pre+post norms, sqrt(d) embedding scale. [arXiv:2408.00118; hf]
"""
from repro.configs.base import ATTN_GLOBAL, ATTN_LOCAL, ModelConfig

CONFIG = ModelConfig(
    name="gemma2-2b",
    family="dense",
    num_layers=26,
    d_model=2304,
    num_heads=8,
    num_kv_heads=4,
    head_dim=256,
    d_ff=9216,
    vocab_size=256_000,
    layer_pattern=(ATTN_LOCAL, ATTN_GLOBAL),
    window=4096,
    attn_softcap=50.0,
    final_softcap=30.0,
    mlp_act="gelu",
    post_norms=True,
    embed_scale=True,
    rope_theta=10_000.0,
    tie_embeddings=True,
)
