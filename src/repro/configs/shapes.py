"""Assigned input shapes (LM family): each (arch x shape) is a dry-run cell."""
from __future__ import annotations

from dataclasses import dataclass


@dataclass(frozen=True)
class ShapeSpec:
    name: str
    seq_len: int
    global_batch: int
    kind: str  # train | prefill | decode


SHAPES = {
    "train_4k": ShapeSpec("train_4k", 4096, 256, "train"),
    "prefill_32k": ShapeSpec("prefill_32k", 32_768, 32, "prefill"),
    "decode_32k": ShapeSpec("decode_32k", 32_768, 128, "decode"),
    "long_500k": ShapeSpec("long_500k", 524_288, 1, "decode"),
}

SMOKE_SHAPES = {
    "train_4k": ShapeSpec("train_4k", 64, 2, "train"),
    "prefill_32k": ShapeSpec("prefill_32k", 64, 2, "prefill"),
    "decode_32k": ShapeSpec("decode_32k", 64, 2, "decode"),
    "long_500k": ShapeSpec("long_500k", 128, 1, "decode"),
}


def applicable(cfg, shape: ShapeSpec) -> tuple[bool, str]:
    """Assignment rules: which cells run vs are recorded as skipped."""
    if shape.name == "long_500k" and not cfg.sub_quadratic():
        return False, "full-attention arch: 512k decode KV inadmissible (see DESIGN.md §6)"
    return True, ""
