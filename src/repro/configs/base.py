"""Config system: ModelConfig covers all assigned architecture families.

Every architecture in the assignment maps to one ModelConfig instance
(``src/repro/configs/<arch>.py``).  ``reduced()`` derives the small
same-family config used by CPU smoke tests; full configs are only ever
lowered via the dry-run (ShapeDtypeStruct, no allocation).
"""
from __future__ import annotations

import dataclasses
from dataclasses import dataclass
from typing import Optional, Tuple

# Layer kinds (per-layer static metadata; drives block construction).
ATTN_GLOBAL = 0
ATTN_LOCAL = 1
MAMBA2 = 2
RWKV6 = 3


@dataclass(frozen=True)
class MoEConfig:
    num_experts: int = 8
    top_k: int = 2
    capacity_factor: float = 1.25
    shared_expert: bool = False      # llama4-style always-on shared expert
    moe_every: int = 1               # a MoE FFN every k-th layer (else dense)
    router_z_coef: float = 1e-3
    aux_coef: float = 1e-2
    combine_first: bool = False      # fold gates in before the w2 matmul


@dataclass(frozen=True)
class SSMConfig:
    # Mamba2 (SSD)
    state_dim: int = 64
    head_dim: int = 64
    expand: int = 2
    conv_kernel: int = 4
    chunk: int = 128
    # RWKV6
    rwkv_head_dim: int = 64
    rwkv_decay_lora: int = 64
    rwkv_chunk: int = 16


@dataclass(frozen=True)
class ModelConfig:
    name: str
    family: str                      # dense | moe | hybrid | ssm | vlm | audio
    num_layers: int
    d_model: int
    num_heads: int
    num_kv_heads: int
    d_ff: int
    vocab_size: int
    head_dim: int = 0                # 0 -> d_model // num_heads
    # attention behaviour
    layer_pattern: Tuple[int, ...] = ()   # repeating pattern of layer kinds
    window: int = 0                  # local-attention window (0 = full)
    attn_softcap: float = 0.0        # gemma2-style logit soft capping
    final_softcap: float = 0.0
    qkv_bias: bool = False
    qk_norm: bool = False            # gemma3
    attn_scale: float = 0.0          # 0 -> 1/sqrt(head_dim)
    # rope
    rope_theta: float = 10_000.0
    rope_theta_local: float = 0.0    # gemma3 uses a different theta for local layers
    mrope_sections: Tuple[int, ...] = ()  # qwen2-vl M-RoPE (t,h,w) sections
    # norm / mlp
    norm_eps: float = 1e-6
    use_layernorm: bool = False      # whisper uses LayerNorm, rest RMSNorm
    post_norms: bool = False         # gemma2/3 post-attn/ffn norms
    mlp_act: str = "silu"            # silu (SwiGLU) | gelu (GeGLU) | gelu_plain
    gated_mlp: bool = True
    embed_scale: bool = False        # gemma multiplies embeddings by sqrt(d)
    tie_embeddings: bool = True
    # mixtures / ssm
    moe: Optional[MoEConfig] = None
    ssm: Optional[SSMConfig] = None
    shared_attn_every: int = 0       # zamba2: shared (tied) attn block cadence
    # encoder-decoder (whisper)
    is_encdec: bool = False
    enc_layers: int = 0
    dec_layers: int = 0
    max_target_len: int = 448
    # stub modality frontend (vlm/audio): inputs are precomputed embeddings
    stub_frontend: bool = False
    # numerics
    dtype: str = "bfloat16"
    param_dtype: str = "float32"
    remat: bool = True
    remat_policy: str = "full"       # full | dots | none
    loss_chunk: int = 512            # chunked softmax-xent over sequence
    attn_chunk: int = 512            # KV-chunk of the online-softmax SW path

    # ------------------------------------------------------------------
    @property
    def resolved_head_dim(self) -> int:
        return self.head_dim or self.d_model // self.num_heads

    @property
    def attn_free(self) -> bool:
        return self.family == "ssm" and self.shared_attn_every == 0

    def layer_kinds(self) -> Tuple[int, ...]:
        """Per-layer kind for all num_layers, from the repeating pattern."""
        pat = self.layer_pattern or (ATTN_GLOBAL,)
        n = self.num_layers
        reps = (n + len(pat) - 1) // len(pat)
        return tuple((pat * reps)[:n])

    def sub_quadratic(self) -> bool:
        """True if long-context decode is admissible (assignment rule)."""
        kinds = set(self.layer_kinds())
        if kinds <= {MAMBA2, RWKV6}:
            return self.shared_attn_every == 0 or True  # hybrid allowed
        # attention archs: sub-quadratic iff every attn layer is windowed
        return ATTN_GLOBAL not in kinds and self.window > 0

    def reduced(self) -> "ModelConfig":
        """Small same-family config for CPU smoke tests."""
        changes = dict(
            name=self.name + "-smoke",
            num_layers=max(2, min(4, self.num_layers // 8)),
            d_model=128,
            num_heads=4,
            num_kv_heads=max(1, min(self.num_kv_heads, 4 * self.num_kv_heads // max(self.num_heads, 1), 4)),
            head_dim=32,
            d_ff=256,
            vocab_size=512,
            loss_chunk=64,
            remat=False,
        )
        if self.num_kv_heads == self.num_heads:
            changes["num_kv_heads"] = 4
        if self.mrope_sections:
            changes["mrope_sections"] = (8, 4, 4)
        if self.window:
            changes["window"] = 16
        if self.moe is not None:
            changes["moe"] = dataclasses.replace(self.moe, num_experts=4,
                                                 top_k=min(self.moe.top_k, 2))
        if self.ssm is not None:
            changes["ssm"] = dataclasses.replace(
                self.ssm, state_dim=16, head_dim=16, chunk=16,
                rwkv_head_dim=32, rwkv_decay_lora=16, rwkv_chunk=8)
        if self.shared_attn_every:
            changes["shared_attn_every"] = 2
        if self.is_encdec:
            changes["enc_layers"] = 2
            changes["dec_layers"] = 2
            changes["num_layers"] = 2
            changes["max_target_len"] = 32
        return dataclasses.replace(self, **changes)

    # approximate parameter counts (for roofline MODEL_FLOPS) -----------
    def param_count(self) -> int:
        d, f, v = self.d_model, self.d_ff, self.vocab_size
        hd = self.resolved_head_dim
        emb = v * d * (1 if self.tie_embeddings else 2)
        total = emb
        kinds = self.layer_kinds()
        for k in kinds:
            if k in (ATTN_GLOBAL, ATTN_LOCAL):
                attn = d * hd * (self.num_heads + 2 * self.num_kv_heads) + self.num_heads * hd * d
                total += attn + self._ffn_params()
            elif k == MAMBA2:
                di = self.ssm.expand * d
                nh = di // self.ssm.head_dim
                total += d * (2 * di + 2 * self.ssm.state_dim + nh) + di * d
                total += self._ffn_params()
            elif k == RWKV6:
                hK = self.ssm.rwkv_head_dim
                nh = d // hK
                total += 4 * d * d + 2 * d * self.ssm.rwkv_decay_lora  # time-mix
                total += 2 * d * f // 2  # channel-mix (r, k, v proj approx)
        if self.shared_attn_every:
            attn = d * hd * (self.num_heads + 2 * self.num_kv_heads) + self.num_heads * hd * d
            total += attn + d * f * 3  # one shared block
        if self.is_encdec:
            attn = 4 * d * d
            total += (self.enc_layers + 2 * self.dec_layers) * attn
            total += (self.enc_layers + self.dec_layers) * 2 * d * f
        return total

    def active_param_count(self) -> int:
        """Active params per token (MoE uses top_k of num_experts)."""
        if self.moe is None:
            return self.param_count()
        d, f = self.d_model, self.d_ff
        dense = self.param_count()
        n_moe = len([i for i in range(self.num_layers)
                     if i % self.moe.moe_every == 0])
        expert_params = 3 * d * f
        total_expert = n_moe * self.moe.num_experts * expert_params
        active_expert = n_moe * self.moe.top_k * expert_params
        shared = n_moe * expert_params if self.moe.shared_expert else 0
        return dense - total_expert - shared + active_expert + shared

    def _ffn_params(self) -> int:
        d, f = self.d_model, self.d_ff
        base = 3 * d * f if self.gated_mlp else 2 * d * f
        if self.moe is not None:
            return self.moe.num_experts * base + (base if self.moe.shared_expert else 0)
        return base
