"""rwkv6-1.6b [ssm] "Finch": 24L d_model=2048 (attn-free) d_ff=7168 vocab=65536.

Data-dependent decay WKV recurrence. [arXiv:2404.05892; unverified]
"""
from repro.configs.base import RWKV6, ModelConfig, SSMConfig

CONFIG = ModelConfig(
    name="rwkv6-1.6b",
    family="ssm",
    num_layers=24,
    d_model=2048,
    num_heads=32,            # d_model / rwkv_head_dim
    num_kv_heads=32,
    head_dim=64,
    d_ff=7168,
    vocab_size=65_536,
    layer_pattern=(RWKV6,),
    ssm=SSMConfig(rwkv_head_dim=64, rwkv_decay_lora=64, rwkv_chunk=16),
    gated_mlp=False,         # rwkv channel-mix is its own structure
    tie_embeddings=False,
)
