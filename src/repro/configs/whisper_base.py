"""whisper-base [audio]: 6L enc + 6L dec, d_model=512 8H d_ff=2048 vocab=51865.

Encoder-decoder; conv frontend is a STUB (``input_specs()`` provides
precomputed frame embeddings). LayerNorm + plain GELU MLPs, sinusoidal /
learned positions. [arXiv:2212.04356; unverified]
"""
from repro.configs.base import ATTN_GLOBAL, ModelConfig

CONFIG = ModelConfig(
    name="whisper-base",
    family="audio",
    num_layers=6,
    d_model=512,
    num_heads=8,
    num_kv_heads=8,
    head_dim=64,
    d_ff=2048,
    vocab_size=51_865,
    layer_pattern=(ATTN_GLOBAL,),
    use_layernorm=True,
    norm_eps=1e-5,
    mlp_act="gelu_plain",
    gated_mlp=False,
    is_encdec=True,
    enc_layers=6,
    dec_layers=6,
    max_target_len=448,
    stub_frontend=True,
    tie_embeddings=True,
)
