"""gemma3-1b [dense]: 26L d_model=1152 4H (GQA kv=1) d_ff=6912 vocab=262144.

5:1 local:global (window 512), qk-norm, dual rope thetas, 128k context.
[hf:google/gemma-3-1b-pt; unverified]
"""
from repro.configs.base import ATTN_GLOBAL, ATTN_LOCAL, ModelConfig

CONFIG = ModelConfig(
    name="gemma3-1b",
    family="dense",
    num_layers=26,
    d_model=1152,
    num_heads=4,
    num_kv_heads=1,
    head_dim=256,
    d_ff=6912,
    vocab_size=262_144,
    layer_pattern=(ATTN_LOCAL,) * 5 + (ATTN_GLOBAL,),
    window=512,
    qk_norm=True,
    final_softcap=0.0,
    mlp_act="gelu",
    post_norms=True,
    embed_scale=True,
    rope_theta=1_000_000.0,
    rope_theta_local=10_000.0,
    tie_embeddings=True,
)
