"""zamba2-1.2b [hybrid]: Mamba2 backbone + shared (tied) attention blocks.

38L d_model=2048 32H (GQA kv=32) d_ff=8192 vocab=32000 ssm_state=64
[arXiv:2411.15242; hf]
"""
from repro.configs.base import MAMBA2, ModelConfig, SSMConfig

CONFIG = ModelConfig(
    name="zamba2-1.2b",
    family="hybrid",
    num_layers=38,
    d_model=2048,
    num_heads=32,
    num_kv_heads=32,
    head_dim=64,
    d_ff=8192,
    vocab_size=32_000,
    layer_pattern=(MAMBA2,),
    ssm=SSMConfig(state_dim=64, head_dim=64, expand=2, conv_kernel=4, chunk=128),
    shared_attn_every=6,      # one tied attention+MLP block applied every 6 mamba layers
    rope_theta=10_000.0,
    tie_embeddings=True,
)
