"""Sharded checkpointing with elastic restore (fault-tolerance substrate).

Layout: <dir>/step_<N>/ with one .npy per leaf + manifest.json holding the
treedef, leaf paths, dtypes and the logical-axis names used at save time.
Restores work onto ANY mesh: arrays are device_put with the *target*
shardings (elastic re-shard after losing/gaining replicas or pods).

``save_async`` overlaps serialization with the next train step (double
buffering: the arrays are snapshotted to host first, so donation in the
train step is safe).  Integrity: a checksum (the paper's Fig.-4 popcount)
per leaf is stored and verified on restore — detects torn writes and the
SDC-on-persist failure mode.
"""
from __future__ import annotations

import concurrent.futures
import json
import os
import shutil
import tempfile
from typing import Any, Dict, Optional

import ml_dtypes
import numpy as np

import jax

PyTree = Any

# numpy can't natively save/load ml_dtypes (bfloat16, fp8); store those as
# same-width unsigned views and record the logical dtype in the manifest.
_EXOTIC = {"bfloat16": np.uint16, "float8_e4m3fn": np.uint8,
           "float8_e5m2": np.uint8, "float16": None}


def _to_storage(arr: np.ndarray):
    name = arr.dtype.name if hasattr(arr.dtype, "name") else str(arr.dtype)
    if name in _EXOTIC and _EXOTIC[name] is not None:
        return arr.view(_EXOTIC[name]), name
    return arr, name


def _from_storage(arr: np.ndarray, logical: str) -> np.ndarray:
    if logical in _EXOTIC and _EXOTIC[logical] is not None:
        return arr.view(np.dtype(getattr(ml_dtypes, logical)))
    return arr


def _leaf_paths(tree) -> Dict[str, Any]:
    flat = jax.tree_util.tree_flatten_with_path(tree)[0]
    out = {}
    for path, leaf in flat:
        key = "/".join(str(getattr(p, "key", getattr(p, "idx", p)))
                       for p in path)
        out[key] = leaf
    return out


def _checksum_np(a: np.ndarray) -> int:
    return int(np.frombuffer(a.tobytes(), np.uint8).astype(np.uint64).sum()
               % (1 << 32))


class CheckpointManager:
    def __init__(self, directory: str, *, keep: int = 3):
        self.dir = directory
        self.keep = keep
        os.makedirs(directory, exist_ok=True)
        self._pool = concurrent.futures.ThreadPoolExecutor(max_workers=1)
        self._pending: Optional[concurrent.futures.Future] = None

    # ------------------------------------------------------------- save
    def save(self, step: int, tree: PyTree, *, extra: Optional[dict] = None):
        host = jax.tree_util.tree_map(lambda x: np.asarray(x), tree)
        self._write(step, host, extra or {})

    def save_async(self, step: int, tree: PyTree,
                   *, extra: Optional[dict] = None):
        self.wait()
        host = jax.tree_util.tree_map(lambda x: np.asarray(x), tree)
        self._pending = self._pool.submit(self._write, step, host,
                                          extra or {})

    def wait(self):
        if self._pending is not None:
            self._pending.result()
            self._pending = None

    def _write(self, step: int, host_tree, extra: dict):
        final = os.path.join(self.dir, f"step_{step:08d}")
        tmp = tempfile.mkdtemp(dir=self.dir, prefix=".tmp_")
        leaves = _leaf_paths(host_tree)
        manifest = {"step": step, "extra": extra, "leaves": {}}
        for key, arr in leaves.items():
            arr = np.asarray(arr)
            stored, logical = _to_storage(arr)
            fn = key.replace("/", "__") + ".npy"
            np.save(os.path.join(tmp, fn), stored)
            manifest["leaves"][key] = {
                "file": fn, "shape": list(arr.shape), "dtype": logical,
                "checksum": _checksum_np(stored)}
        with open(os.path.join(tmp, "manifest.json"), "w") as f:
            json.dump(manifest, f)
        if os.path.exists(final):
            shutil.rmtree(final)
        os.rename(tmp, final)
        self._gc()

    def _gc(self):
        steps = self.steps()
        for s in steps[:-self.keep]:
            shutil.rmtree(os.path.join(self.dir, f"step_{s:08d}"),
                          ignore_errors=True)

    # ---------------------------------------------------------- restore
    def steps(self):
        out = []
        for d in os.listdir(self.dir):
            if d.startswith("step_"):
                out.append(int(d[5:]))
        return sorted(out)

    def latest_step(self) -> Optional[int]:
        s = self.steps()
        return s[-1] if s else None

    def restore(self, step: int, like: PyTree, *, shardings: PyTree = None,
                verify: bool = True) -> PyTree:
        """Restore into the structure of ``like``; place with ``shardings``
        (a pytree of jax.sharding.Sharding or None) — elastic re-shard."""
        path = os.path.join(self.dir, f"step_{step:08d}")
        with open(os.path.join(path, "manifest.json")) as f:
            manifest = json.load(f)
        leaves = _leaf_paths(like)
        shard_leaves = (_leaf_paths(shardings)
                        if shardings is not None else {})
        out = {}
        for key in leaves:
            meta = manifest["leaves"][key]
            arr = np.load(os.path.join(path, meta["file"]))
            if verify and _checksum_np(arr) != meta["checksum"]:
                raise IOError(f"checkpoint corruption in leaf {key}")
            arr = _from_storage(arr, meta["dtype"])
            sh = shard_leaves.get(key)
            out[key] = (jax.device_put(arr, sh) if sh is not None
                        else jax.numpy.asarray(arr))
        # rebuild the tree
        flat, tdef = jax.tree_util.tree_flatten(like)
        keys = list(_leaf_paths(like).keys())
        return jax.tree_util.tree_unflatten(tdef, [out[k] for k in keys])

    def extra(self, step: int) -> dict:
        path = os.path.join(self.dir, f"step_{step:08d}")
        with open(os.path.join(path, "manifest.json")) as f:
            return json.load(f)["extra"]
