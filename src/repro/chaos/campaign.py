"""Drive seeded chaos schedules through live fleets.

Three harnesses, one report shape:

- ``serve_campaign``  -- a ``FleetServeEngine`` under open-loop traffic
  (``serve.frontend`` virtual clock), the schedule injected mid-run via
  the session event path.  Stage faults are *value-level*: the
  probation classifier's canary genuinely fails because a ``LaneFault``
  is armed around each canary probe (see :class:`ChaosCanary`), so the
  transient/persistent verdict is earned, not scripted.
- ``train_campaign``  -- a data-parallel ``FleetTrainRunner`` with
  probation and checksummed checkpoints; transient guard trips
  re-execute, device losses migrate, host losses restore-then-continue.
- ``coordinator_campaign`` -- a ``KVCoordinator`` against a stalling
  fake coordination-service client: a silent peer must surface as a
  typed ``HostTimeoutError`` after bounded retries (MTTR is the wall
  time to that error, nowhere near the legacy 120 s block).

``run_campaign`` composes all three plus a deterministic
measured-vs-DegradationModel closure scenario and rolls the invariant
verdicts up; ``benchmarks/chaos_bench.py`` is a thin CLI over it.
"""
from __future__ import annotations

import time
from typing import Dict, List, Optional, Sequence, Tuple

import jax
import numpy as np

from repro import optim
from repro.chaos import invariants as inv
from repro.chaos.schedule import (COORD_STALL, DEVICE_LOSS, HOST_LOSS,
                                  LANE_FAULT, PERSISTENT_STAGE, SERVE_KINDS,
                                  SPARE_EXHAUSTION, TRAIN_KINDS,
                                  TRANSIENT_STAGE, ChaosEvent, draw_schedule,
                                  horizon_of)
from repro.configs import get_config
from repro.core.datacenter import DegradationModel
from repro.core.fault import (CanaryChecker, FaultClassifier,
                              ProbationPolicy)
from repro.core.routing import FleetPlan
from repro.data import DataConfig, SyntheticLM
from repro.launch.distributed import (FleetEvent, HostTimeoutError,
                                      HostTopology, KVCoordinator,
                                      fleet_fingerprint, replay_log)
from repro.models import build_model
from repro.obs import metrics as obs_metrics
from repro.obs import trace as obs_trace
from repro.serve import (BLOCK, RECOMPILE, RESIDENT, FleetConfig,
                         FleetServeEngine, Frontend, FrontendConfig,
                         LengthModel, Poisson, ServeConfig)
from repro.train import TrainConfig
from repro.train.runner import (FleetTrainConfig, FleetTrainRunner,
                                canary_stages, model_stage_names)
from repro.viscosity import INTERPRET, lanefault
from repro.viscosity.lanefault import STUCK, LaneFault

ARCH = "qwen1.5-4b"
#: interpreted healthy lowering so reroutes/rungs are *real* route
#: changes (interpret -> DEGRADED / SW), same rationale as traffic_bench
HW_ROUTE = INTERPRET
MAX_LEN = 48
SLOTS = 3
STEP_TIME_S = 0.05
N_DEVICES = 4
N_SPARES = 2

#: minor-axis lane width of each kernel family's *canary* port
#: (``train.runner.canary_stages``) -- a LaneFault only applies where
#: widths match, so chaos injections must use these, and the canary
#: width differing from the serving width is what keeps probe-time
#: injections from ever touching production compute
CANARY_WIDTHS = {"flash_attention": 32, "swiglu_mlp": 64,
                 "mamba2_ssd": 16, "rwkv6_wkv": 16}


def canary_fault(stage_name: str, *, lane: int = 1,
                 value: float = 7.5) -> LaneFault:
    """A stuck-lane fault sized to the stage family's canary width."""
    width = CANARY_WIDTHS.get(stage_name)
    if width is None:
        raise ValueError(f"no canary width for stage {stage_name!r}; "
                         f"known: {sorted(CANARY_WIDTHS)}")
    return LaneFault(kind=STUCK, lanes=(lane % width,), width=width,
                     value=value)


class ChaosCanary:
    """Canary checker with campaign-controlled value-level faults.

    The injection registry is process-global and keyed by stage *name*,
    so a fault armed for the whole run would corrupt every device's
    production compute whenever canary and serving widths collide (the
    reduced config's attention head_dim equals the canary width).  This
    wrapper instead arms the ``LaneFault`` only around each canary
    probe: detection is genuinely value-level -- the canary's HW lane
    really is stuck against the SW oracle -- while serving kernels
    never observe the injection.  That is also what gives faults
    per-*probe* (hence per-device) semantics the global registry cannot
    express.

    ``fails=N`` models a transient upset: the fault clears itself after
    N failing probes (probation then finds a clean canary -> HW route
    restored).  ``fails=None`` is a hard fault: every probe fails until
    the ladder routes the stage away.  Repeated ``arm`` calls *queue*,
    and a probation episode's successive probes drain the queue in
    order -- so a campaign must never stack a second spec behind a
    transient on the same stage (the episode's later probes would hit
    it and earn a spurious persistent verdict).  ``draw_schedule`` keeps
    transient and persistent stage sets disjoint and ``serve_campaign``
    arms each stage at most once to honor that.
    """

    def __init__(self, checker: CanaryChecker):
        self.checker = checker
        # name -> FIFO of [fault, fails-left]; head is the live fault
        self._faults: Dict[str, List[list]] = {}

    @property
    def stages(self):
        return self.checker.stages

    def arm(self, stage_name: str, fault: LaneFault, *,
            fails: Optional[int] = None):
        self._faults.setdefault(stage_name, []).append([fault, fails])

    def disarm(self, stage_name: str):
        self._faults.pop(stage_name, None)

    def armed(self) -> List[str]:
        return sorted(self._faults)

    def check_stage(self, stage) -> bool:
        queue = self._faults.get(stage.name)
        if not queue:
            return self.checker.check_stage(stage)
        fault, fails = queue[0]
        lanefault.set_injection(stage.name, fault)
        try:
            ok = self.checker.check_stage(stage)
        finally:
            lanefault.clear_injection(stage.name)
        if not ok and fails is not None:
            queue[0][1] = fails - 1
            if queue[0][1] <= 0:
                queue.pop(0)
                if not queue:
                    self._faults.pop(stage.name, None)
        return ok


def _classifier(cfg, *, retries: int = 3) -> FaultClassifier:
    canary = ChaosCanary(CanaryChecker(canary_stages(cfg),
                                       route_hw=HW_ROUTE))
    # virtual-clock campaigns never wall-sleep between probes
    return FaultClassifier(canary,
                           ProbationPolicy(retries=retries,
                                           backoff_base_s=0.0),
                           sleep=lambda _s: None)


def _lengths(cfg) -> LengthModel:
    return LengthModel(vocab_size=cfg.vocab_size, min_prompt=6,
                       max_prompt=12, min_new=4, max_new=9,
                       dist="pareto", alpha=1.8, clamp_len=MAX_LEN)


def _schedule_row(ev: ChaosEvent) -> Dict:
    return {"step": ev.step, "kind": ev.kind, "device": ev.device,
            "host": ev.host, "stage": ev.stage,
            "devices": list(ev.devices)}


def _replay_fingerprint(eng: FleetServeEngine) -> str:
    """Fingerprint of the healthy plan re-folded over the engine's own
    applied event log -- what any host replaying the agreed log would
    compute."""
    evs = [FleetEvent.from_engine(e["step"], 0, i, tuple(e["event"]))
           for i, e in enumerate(eng.event_log) if not e.get("dropped")]
    plan = FleetPlan.healthy(eng.fcfg.n_devices, eng.stage_names,
                             target=eng.scfg.hw_route,
                             n_spares=eng.fcfg.n_spares)
    replayed, _dropped = replay_log(plan, evs, eng.stage_names,
                                    target=eng.scfg.hw_route,
                                    topology=eng.topology)
    return fleet_fingerprint(replayed)


def _settle_steps(capacity: Sequence[int], step: int, stop: int) -> int:
    """Steps from ``step`` until the fleet capacity trace stops moving
    (bounded by ``stop``): the plan-change MTTR window."""
    lo = min(step, max(len(capacity) - 1, 0))
    hi = min(stop, len(capacity))
    last = 0
    for j in range(lo + 1, hi):
        if capacity[j] != capacity[j - 1]:
            last = j - lo
    return max(last, 1)


def serve_campaign(seed: int, *, failover: str = RESIDENT,
                   n_events: int = 7, n_requests: int = 60,
                   params=None, cfg=None) -> Dict:
    """Soak one serve fleet under saturating open-loop traffic while the
    schedule fires; returns the invariant verdict, per-event MTTR, and
    the run's traffic stats."""
    lanefault.reset()
    cfg = cfg if cfg is not None else get_config(ARCH).reduced()
    if params is None:
        params = build_model(cfg).init(jax.random.PRNGKey(seed))
    names = model_stage_names(cfg)
    schedule = draw_schedule(seed, n_events=n_events, n_devices=N_DEVICES,
                             stage_names=names, n_spares=N_SPARES,
                             kinds=SERVE_KINDS)
    clf = _classifier(cfg)
    canary: ChaosCanary = clf.checker
    scfg = ServeConfig(max_len=MAX_LEN, max_slots=SLOTS,
                       hw_route=HW_ROUTE, failover=failover)
    fcfg = FleetConfig(n_devices=N_DEVICES, n_spares=N_SPARES,
                       model=DegradationModel())
    eng = FleetServeEngine(cfg, params, scfg, fcfg, classifier=clf)

    events: Dict[int, List[Tuple]] = {}
    expected: List[Tuple[int, Tuple]] = []
    transients: List[ChaosEvent] = []
    stalls: List[ChaosEvent] = []
    persistent_keys: set = set()
    armed: set = set()
    try:
        for ev in schedule:
            if ev.kind == TRANSIENT_STAGE:
                # arm at most once per stage: the first episode consumes
                # the spec, later suspects on the stage probe clean (an
                # instant-transient verdict) -- stacking specs would make
                # one episode's probes eat the next event's fault
                if ev.stage not in armed:
                    canary.arm(ev.stage, canary_fault(ev.stage), fails=1)
                    armed.add(ev.stage)
                events.setdefault(ev.step, []).append(
                    ("suspect", ev.device, ev.stage))
                expected += [(ev.step, ("stage", ev.device, ev.stage)),
                             (ev.step, ("recover", ev.device, ev.stage))]
                transients.append(ev)
            elif ev.kind in (PERSISTENT_STAGE, LANE_FAULT):
                fault = canary_fault(ev.stage)
                canary.arm(ev.stage, fault, fails=None)
                if ev.kind == LANE_FAULT:
                    # localized fault: the ladder's DEGRADED rungs apply
                    lanefault.known_map(ev.stage, fault, base=HW_ROUTE)
                events.setdefault(ev.step, []).append(
                    ("suspect", ev.device, ev.stage))
                expected.append((ev.step, ("stage", ev.device, ev.stage)))
                persistent_keys.add(ev.stage)
            elif ev.kind == DEVICE_LOSS:
                events.setdefault(ev.step, []).append(("device", ev.device))
                expected.append((ev.step, ("device", ev.device)))
            elif ev.kind == SPARE_EXHAUSTION:
                for d in ev.devices:
                    events.setdefault(ev.step, []).append(("device", d))
                    expected.append((ev.step, ("device", d)))
            elif ev.kind == HOST_LOSS:
                events.setdefault(ev.step, []).append(("host", ev.host))
                expected.append((ev.step, ("host", ev.host)))
            elif ev.kind == COORD_STALL:
                # drilled after the traffic run (the coordinator is not
                # on the serve data path); the engine sees nothing
                stalls.append(ev)

        # saturating, deadline-free arrivals: the soak measures survival
        # and capacity accounting, not tails (traffic_bench owns those)
        wl = Poisson(n_requests=n_requests, rate=40.0, lengths=_lengths(cfg))
        reqs = wl.build(seed)
        fe = Frontend(eng, FrontendConfig(step_time_s=STEP_TIME_S,
                                          max_queue=2 * n_requests,
                                          shed=BLOCK))
        comps, stats = fe.run(reqs, events=events)
    finally:
        lanefault.reset()

    # coordinator-stall drills ride alongside the traffic run, so the
    # KV-retry spike lands in this campaign's telemetry scope
    drills = {ev.step: _stall_drill(f"serve-{ev.step}") for ev in stalls}

    # ---------------------------------------------------------- metrics
    applied = {(e["step"], tuple(e["event"])) for e in eng.event_log
               if not e.get("dropped")}
    missing = [x for x in expected if x not in applied]
    capacity = stats["engine"]["capacity"]
    logs = [w.fault_state.log for w in eng.workers
            if hasattr(w, "fault_state")]
    mttrs: List[Dict] = []
    for ev in schedule:
        if ev.kind == TRANSIENT_STAGE:
            # one probation_retry note per probe attempt (the clean
            # closing probe included), so the count IS the attempt count
            attempts = sum(1 for log in logs for e in log
                           if e.get("kind") == "probation_retry"
                           and e.get("stage") == ev.stage
                           and e.get("step") == ev.step)
            mttr = max(attempts, 1) * STEP_TIME_S
        elif ev.kind == COORD_STALL:
            # wall time to the typed HostTimeoutError, not a step count
            mttr = drills[ev.step]["mttr_s"]
        else:
            nxt = min((e.step for e in schedule if e.step > ev.step),
                      default=len(capacity))
            mttr = _settle_steps(capacity, ev.step, nxt) * STEP_TIME_S
        mttrs.append({"step": ev.step, "kind": ev.kind,
                      "stage": ev.stage, "device": ev.device,
                      "mttr_s": round(mttr, 4)})

    residual_check = [ev for ev in transients
                      if ev.stage not in persistent_keys]
    reports = [
        inv.check_no_dropped(reqs, comps),
        inv.check_fingerprints([fleet_fingerprint(eng.fleet),
                                _replay_fingerprint(eng)]),
        inv.check_ladder(eng.fleet, names, healthy=HW_ROUTE),
        inv.check_transients(eng.fleet, residual_check, logs),
        {"invariant": "events_applied", "ok": not missing,
         "expected": len(expected), "missing": missing,
         "detail": f"{len(missing)} scheduled event(s) never applied: "
                   f"{missing[:4]}"},
    ]
    if stalls:
        bad = [x for d in drills.values() for x in d["details"]]
        reports.append({"invariant": "coordinator_stall",
                        "ok": not bad, "n_stalls": len(stalls),
                        "detail": "; ".join(bad)
                                  or "typed timeout + isolation"})
    for m in mttrs:
        obs_metrics.observe("mttr_seconds", m["mttr_s"])
    return {
        "failover": failover,
        "seed": seed,
        "n_events": len(schedule),
        "schedule": [_schedule_row(e) for e in schedule],
        "invariants": inv.verdict(reports),
        "mttr": mttrs,
        "mttr_summary": inv.mttr_summary(mttrs),
        "traffic": {
            "requests": len(reqs),
            "completed": stats["completed"],
            "expired": stats["expired"],
            "requeued": stats["engine"]["requeued"],
            "throughput_tok_s": round(stats["throughput_tok_s"], 2),
            "virtual_time_s": round(stats["virtual_time_s"], 2),
        },
        "quarantined": list(eng.fleet.quarantined),
    }


def closure_scenario(seed: int, *, failover: str = RESIDENT,
                     n_requests: int = 40, params=None,
                     cfg=None) -> Dict:
    """Deterministic measured-vs-DegradationModel closure: under
    saturating load, a mid-run device loss must shrink measured
    tokens/step by the same ratio as the engine's analytic capacity
    trace (slot-quantized DegradationModel), within 15%."""
    cfg = cfg if cfg is not None else get_config(ARCH).reduced()
    if params is None:
        params = build_model(cfg).init(jax.random.PRNGKey(seed))
    fault_step = 12
    scfg = ServeConfig(max_len=MAX_LEN, max_slots=SLOTS,
                       hw_route=HW_ROUTE, failover=failover)
    fcfg = FleetConfig(n_devices=2, n_spares=0, model=DegradationModel())
    eng = FleetServeEngine(cfg, params, scfg, fcfg)
    wl = Poisson(n_requests=n_requests, rate=60.0, lengths=_lengths(cfg))
    reqs = wl.build(seed)
    fe = Frontend(eng, FrontendConfig(step_time_s=STEP_TIME_S,
                                      max_queue=2 * n_requests,
                                      shed=BLOCK))
    comps, stats = fe.run(reqs,
                          events={fault_step: [("device", 0)]})
    pst = stats["engine"]["per_step_tokens"]
    cap = stats["engine"]["capacity"]

    def window(xs, lo, hi):
        w = xs[lo:hi]
        return float(np.mean(w)) if w else 0.0

    h_lo, h_hi = 4, fault_step
    f_lo = fault_step + 2
    f_hi = min(f_lo + 20, int(0.8 * len(pst)))
    measured = window(pst, f_lo, f_hi) / max(window(pst, h_lo, h_hi), 1e-9)
    analytic = window(cap, f_lo, f_hi) / max(window(cap, h_lo, h_hi), 1e-9)
    obs_metrics.set_gauge("closure_ratio", measured, source="measured")
    obs_metrics.set_gauge("closure_ratio", analytic, source="analytic")
    report = inv.check_closure(measured, analytic)
    report["dropped"] = inv.check_no_dropped(reqs, comps)["missing"]
    report["ok"] = report["ok"] and not report["dropped"]
    return report


def train_campaign(seed: int, *, n_events: int = 4,
                   ckpt_dir: Optional[str] = None) -> Dict:
    """Soak the data-parallel fleet train loop: transient guard trips
    probate and re-execute, device losses quarantine-and-migrate, host
    losses restore the latest checkpoint onto the survivor mesh."""
    from repro.viscosity.lang import SW

    cfg = get_config(ARCH).reduced()
    data = SyntheticLM(DataConfig(vocab_size=cfg.vocab_size, batch=8,
                                  seq_len=16))
    names = model_stage_names(cfg)
    topo = HostTopology(num_hosts=2, devices_per_host=2)
    schedule = draw_schedule(seed + 101, n_events=n_events, n_devices=4,
                             stage_names=names, n_spares=1, topology=topo,
                             kinds=TRAIN_KINDS, start=2, min_gap=2,
                             max_gap=4, min_serving=2)
    steps = horizon_of(schedule, settle=3)
    transient = {e.step: e.device for e in schedule
                 if e.kind == TRANSIENT_STAGE}
    poison = {e.step: e.device for e in schedule if e.kind == DEVICE_LOSS}
    host_loss = {e.step: e.host for e in schedule if e.kind == HOST_LOSS}
    stalls = [e for e in schedule if e.kind == COORD_STALL]
    tcfg = TrainConfig(steps=steps, hw_route=SW, probation_retries=2,
                       ckpt_every=2, ckpt_dir=ckpt_dir)
    r = FleetTrainRunner(
        cfg, optim.AdamWConfig(lr=1e-3, warmup_steps=2, total_steps=200),
        tcfg, data, FleetTrainConfig(n_devices=4, n_spares=1,
                                     topology=topo))
    params, opt = r.init_state()
    r.run(params, opt, steps=steps, transient=dict(transient),
          poison=dict(poison), host_loss=dict(host_loss))
    drills = {e.step: _stall_drill(f"train-{e.step}") for e in stalls}

    live = fleet_fingerprint(r.fleet)
    healthy = FleetPlan.healthy(4, names, target=tcfg.hw_route, n_spares=1)
    replayed, _ = replay_log(healthy, r.fleet_log, names,
                             target=tcfg.hw_route, topology=topo)
    kinds = [e.get("kind") for e in r.fault_state.log]
    n_recovered = kinds.count("transient_recovered")
    mean_dt = float(np.mean([h["dt"] for h in r.history])) if r.history \
        else 0.0
    mttrs: List[Dict] = []
    for ev in schedule:
        if ev.kind == TRANSIENT_STAGE:
            attempts = sum(1 for e in r.fault_state.log
                           if e.get("kind") == "probation_retry"
                           and e.get("step") == ev.step)
            mttr = max(attempts, 1) * mean_dt
        elif ev.kind == HOST_LOSS and ckpt_dir:
            # rewind cost: re-run from the restored checkpoint step
            rewind = max(ev.step % tcfg.ckpt_every, 1)
            mttr = (rewind + 1) * mean_dt
        elif ev.kind == COORD_STALL:
            mttr = drills[ev.step]["mttr_s"]
        else:
            mttr = mean_dt
        mttrs.append({"step": ev.step, "kind": ev.kind,
                      "device": ev.device, "mttr_s": round(mttr, 4)})
    reports = [
        {"invariant": "finite_loss",
         "ok": bool(r.history) and all(np.isfinite(h["loss"])
                                       for h in r.history),
         "steps": len(r.history),
         "detail": "non-finite loss in history"},
        inv.check_fingerprints([live, fleet_fingerprint(replayed)]),
        {"invariant": "transients", "ok": n_recovered >= len(transient),
         "expected": len(transient), "recovered": n_recovered,
         "detail": f"{n_recovered}/{len(transient)} transient guard "
                   f"trips recovered without quarantine"},
    ]
    if host_loss and ckpt_dir:
        reports.append(
            {"invariant": "checkpoint_restored",
             "ok": "checkpoint_restored" in kinds,
             "detail": "host loss did not restore a checkpoint"})
    if stalls:
        bad = [x for d in drills.values() for x in d["details"]]
        reports.append({"invariant": "coordinator_stall",
                        "ok": not bad, "n_stalls": len(stalls),
                        "detail": "; ".join(bad)
                                  or "typed timeout + isolation"})
    for m in mttrs:
        obs_metrics.observe("mttr_seconds", m["mttr_s"])
    return {
        "seed": seed,
        "n_events": len(schedule),
        "schedule": [_schedule_row(e) for e in schedule],
        "invariants": inv.verdict(reports),
        "mttr": mttrs,
        "mttr_summary": inv.mttr_summary(mttrs),
        "guard_trips": r.guard_trips,
        "quarantined": list(r.fleet.quarantined),
        "steps": len(r.history),
    }


class StallingKVClient:
    """Fake coordination-service KV client whose ``stalled`` hosts never
    publish: every get for their keys burns its timeout and raises (the
    client-error taxonomy the retry path catches).  ``stall_s`` stands
    in for the attempt timeout so tests stay fast."""

    def __init__(self, stalled: Sequence[int] = (), *,
                 stall_s: float = 0.001):
        self.store: Dict[str, str] = {}
        self.stalled = {int(h) for h in stalled}
        self.stall_s = stall_s
        self.gets = 0
        self.deletes: List[str] = []

    def key_value_set(self, key: str, value: str):
        self.store[key] = value

    def blocking_key_value_get(self, key: str, timeout_ms: int) -> str:
        self.gets += 1
        host = int(key.rsplit("/", 1)[1])
        if host not in self.stalled and key in self.store:
            return self.store[key]
        time.sleep(min(self.stall_s, timeout_ms / 1000.0))
        raise RuntimeError(f"BlockingKeyValueGet timed out for {key}")

    def key_value_delete(self, key: str):
        self.deletes.append(key)
        self.store.pop(key, None)


def _stall_drill(tag, *, max_attempts: int = 4) -> Dict:
    """One coordinator-stall drill: host 1 never publishes, so the
    exchange must surface a typed ``HostTimeoutError(1)`` within the
    bounded retry budget, and after ``mark_dead`` the survivor's next
    exchange proceeds with ``None`` in the dead slot.  The bounded
    retries land in ``kv_retries_total`` / ``coord_timeouts_total`` (the
    KV-retry spike a scheduled ``coord_stall`` makes visible in the
    campaign snapshot); wall time to the typed error is the MTTR."""
    client = StallingKVClient(stalled=[1])
    coord = KVCoordinator(num_hosts=2, host_id=0, client=client,
                          timeout_ms=2_000, attempt_timeout_ms=10,
                          max_attempts=max_attempts,
                          backoff_base_s=0.001)
    details: List[str] = []
    t0 = time.perf_counter()
    try:
        coord.exchange(f"stall-{tag}")
        mttr = time.perf_counter() - t0
        details.append(f"stall {tag}: exchange succeeded unexpectedly")
    except HostTimeoutError as e:
        mttr = time.perf_counter() - t0
        if e.host_id != 1:
            details.append(f"stall {tag}: wrong host_id {e.host_id}")
    if client.gets > max_attempts:
        details.append(f"stall {tag}: {client.gets} gets > budget "
                       f"{max_attempts}")
    coord.mark_dead(1)
    after = coord.exchange(f"post-{tag}")
    if after[0] != f"post-{tag}" or after[1] is not None:
        details.append(f"stall {tag}: post-mark_dead exchange {after}")
    return {"ok": not details, "details": details,
            "mttr_s": round(mttr, 4), "gets": client.gets}


def coordinator_campaign(n_stalls: int = 2, *,
                         max_attempts: int = 4) -> Dict:
    """Coordinator-stall drills: a silent peer must surface as a typed
    ``HostTimeoutError(host_id)`` after bounded retries, and after
    ``mark_dead`` the survivors' exchanges proceed with ``None`` in the
    dead slot."""
    mttrs: List[Dict] = []
    details: List[str] = []
    for i in range(n_stalls):
        d = _stall_drill(i, max_attempts=max_attempts)
        details += d["details"]
        mttrs.append({"step": i, "kind": COORD_STALL,
                      "mttr_s": d["mttr_s"]})
    for m in mttrs:
        obs_metrics.observe("mttr_seconds", m["mttr_s"])
    report = {"invariant": "coordinator_stall", "ok": not details,
              "detail": "; ".join(details) or "typed timeout + isolation",
              "n_stalls": n_stalls}
    return {"n_events": n_stalls,
            "invariants": inv.verdict([report]),
            "mttr": mttrs,
            "mttr_summary": inv.mttr_summary(mttrs)}


def run_campaign(seed: int = 0, *, smoke: bool = False,
                 ckpt_dir: Optional[str] = None,
                 raise_on_failure: bool = False) -> Dict:
    """The full soak: serve campaigns in both failover modes, the train
    campaign, coordinator stalls, and the deterministic closure check.
    Default sizing lands >= 20 randomized fault events."""
    serve_events = 3 if smoke else 7
    train_events = 2 if smoke else 4
    n_stalls = 1 if smoke else 2
    n_requests = 30 if smoke else 60
    # one campaign = one registry + one tracer: every layer's telemetry
    # scopes into a single snapshot, sectioned by label_scope
    reg = obs_metrics.Registry()
    tracer = obs_trace.Tracer(origin=0)
    with obs_metrics.use(reg), obs_trace.use(tracer):
        cfg = get_config(ARCH).reduced()
        params = build_model(cfg).init(jax.random.PRNGKey(seed))
        serve = {}
        for mode in (RECOMPILE, RESIDENT):
            with obs_metrics.label_scope(section=f"serve_{mode}"):
                serve[mode] = serve_campaign(
                    seed, failover=mode, n_events=serve_events,
                    n_requests=n_requests, params=params, cfg=cfg)
        with obs_metrics.label_scope(section="train"):
            train = train_campaign(seed, n_events=train_events,
                                   ckpt_dir=ckpt_dir)
        with obs_metrics.label_scope(section="coordinator"):
            coordinator = coordinator_campaign(n_stalls)
        with obs_metrics.label_scope(section="closure"):
            closure = closure_scenario(seed,
                                       n_requests=24 if smoke else 40,
                                       params=params, cfg=cfg)
    sections = [serve[RECOMPILE]["invariants"],
                serve[RESIDENT]["invariants"],
                train["invariants"], coordinator["invariants"]]
    all_ok = all(s["ok"] for s in sections) and closure["ok"]
    events_total = (sum(s["n_events"] for s in serve.values())
                    + train["n_events"] + coordinator["n_events"])
    out = {
        "seed": seed,
        "smoke": smoke,
        "events_total": events_total,
        "serve": serve,
        "train": train,
        "coordinator": coordinator,
        "closure": closure,
        "invariants": {"ok": all_ok,
                       "failed": [f for s in sections
                                  for f in s.get("failed", [])]
                       + ([] if closure["ok"] else ["closure"])},
        "telemetry": {"metrics": reg.snapshot(),
                      "trace": [e.to_wire() for e in tracer.events]},
    }
    if raise_on_failure and not all_ok:
        raise inv.InvariantViolation(
            [r for s in sections for r in s.get("reports", [])
             if not r.get("ok")] + ([] if closure["ok"] else [closure]))
    return out
