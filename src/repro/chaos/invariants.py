"""Post-campaign invariant checkers.

Each checker returns a report dict with ``ok: bool`` plus the evidence
it judged, so a failing campaign explains itself; ``verdict`` rolls a
set of reports up and (optionally) raises :class:`InvariantViolation`
listing every failure at once.  The invariants are the paper's
fault-tolerance contract, checked over *randomized* schedules instead
of hand-picked ones:

- **no_dropped**  -- every admitted request either completes or expires
  against its own deadline; none vanish (§II constant-aggregate-
  throughput is vacuous if work is silently shed).
- **fingerprints** -- the live ``FleetPlan`` equals the plan replayed
  from the agreed event log: every host folding that log lands on the
  same fingerprint, so routing never desyncs.
- **ladder**      -- persistent faults sit on the rung the degradation
  ladder prescribes (DEGRADED for lane-mapped stages, binary fallback
  otherwise; quarantine only via migration/loss).
- **transients**  -- probation returned every transient fault to the HW
  route with zero residual quarantines or stage-fault counts.
- **closure**     -- measured post-fault throughput ratio matches the
  DegradationModel analytic ratio within tolerance (default 15%).
"""
from __future__ import annotations

from typing import Dict, List, Mapping, Optional, Sequence

from repro.viscosity import lanefault


class InvariantViolation(AssertionError):
    """A chaos invariant failed; ``.reports`` holds every failing
    checker's evidence."""

    def __init__(self, reports: Sequence[Mapping]):
        self.reports = tuple(reports)
        lines = [f"- {r.get('invariant', '?')}: {r.get('detail', r)}"
                 for r in reports]
        super().__init__("chaos invariant(s) failed:\n" + "\n".join(lines))


def check_no_dropped(requests, completions: Mapping[int, object]) -> Dict:
    """Every request has a completion; 'expired' is an allowed verdict
    (the request's own deadline), disappearance is not."""
    missing = sorted(r.rid for r in requests if r.rid not in completions)
    return {"invariant": "no_dropped", "ok": not missing,
            "requests": len(list(requests)), "missing": missing,
            "detail": f"{len(missing)} request(s) vanished: {missing[:8]}"}


def check_fingerprints(fingerprints: Sequence[str]) -> Dict:
    """All hosts/replicas agreed on the same FleetPlan digest."""
    uniq = sorted(set(fingerprints))
    return {"invariant": "fingerprints", "ok": len(uniq) <= 1,
            "fingerprints": list(fingerprints),
            "detail": f"{len(uniq)} distinct fingerprint(s): {uniq}"}


def check_ladder(fleet, stage_names: Sequence[str], *,
                 healthy: Optional[str] = None) -> Dict:
    """Every *serving* device's routed target matches what its recorded
    per-stage fault count prescribes: ``rung_for(n)`` when the stage has
    a registered lane map, off the ``healthy`` route otherwise."""
    wrong: List[Dict] = []
    for d in fleet.serving():
        plan = fleet.plans[d]
        for s in stage_names:
            n = fleet.stage_fault_count(d, s)
            if n < 1:
                continue
            got = plan.target_for(s)
            if lanefault.fault_map(s) is not None:
                want = lanefault.rung_for(n)
                if got != want:
                    wrong.append({"device": d, "stage": s, "count": n,
                                  "got": got, "want": want})
            elif healthy is not None and got == healthy:
                wrong.append({"device": d, "stage": s, "count": n,
                              "got": got, "want": "a fallback route"})
    return {"invariant": "ladder", "ok": not wrong, "wrong": wrong,
            "detail": f"{len(wrong)} mis-rung stage route(s): {wrong[:4]}"}


def check_transients(fleet, transient_events, fault_logs:
                     Sequence[Sequence[Mapping]]) -> Dict:
    """Transient faults must leave no trace on the plan: zero residual
    stage-fault count at their (device, stage) and a
    ``transient_recovered`` entry in some fault log for the stage."""
    recovered = {(e.get("stage"), e.get("kind")) for log in fault_logs
                 for e in log}
    residual: List[Dict] = []
    unlogged: List[Dict] = []
    for ev in transient_events:
        if fleet is not None and \
                fleet.stage_fault_count(ev.device, ev.stage) > 0:
            residual.append({"device": ev.device, "stage": ev.stage,
                             "step": ev.step})
        if (ev.stage, "transient_recovered") not in recovered:
            unlogged.append({"device": ev.device, "stage": ev.stage,
                             "step": ev.step})
    ok = not residual and not unlogged
    return {"invariant": "transients", "ok": ok, "residual": residual,
            "unlogged": unlogged,
            "detail": f"{len(residual)} residual fault(s), "
                      f"{len(unlogged)} without a transient_recovered "
                      f"log entry"}


def check_closure(measured_ratio: float, analytic_ratio: float,
                  *, tol: float = 0.15) -> Dict:
    """Measured-vs-DegradationModel throughput-ratio closure."""
    rel_err = abs(measured_ratio - analytic_ratio) / \
        max(abs(analytic_ratio), 1e-9)
    return {"invariant": "closure", "ok": rel_err <= tol,
            "measured_ratio": round(float(measured_ratio), 4),
            "analytic_ratio": round(float(analytic_ratio), 4),
            "rel_err": round(float(rel_err), 4), "tol": tol,
            "detail": f"rel_err {rel_err:.4f} > tol {tol}"}


def verdict(reports: Sequence[Mapping], *,
            raise_on_failure: bool = False) -> Dict:
    """Roll reports up; optionally raise InvariantViolation on any
    failure (benches do -- a broken invariant can never ride a green
    run)."""
    failed = [r for r in reports if not r.get("ok")]
    out = {"ok": not failed, "checked": len(list(reports)),
           "failed": [r.get("invariant") for r in failed],
           "reports": list(reports)}
    if failed and raise_on_failure:
        raise InvariantViolation(failed)
    return out


def mttr_summary(mttrs: Sequence[Mapping]) -> Optional[Dict]:
    """Mean/max recovery time over per-event MTTR records."""
    vals = [float(m["mttr_s"]) for m in mttrs if m.get("mttr_s")
            is not None]
    if not vals:
        return None
    return {"n": len(vals), "mean_s": round(sum(vals) / len(vals), 4),
            "max_s": round(max(vals), 4)}
