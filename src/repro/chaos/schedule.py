"""Seeded randomized fault schedules over the full taxonomy.

A schedule is a tuple of :class:`ChaosEvent` at strictly increasing
engine steps.  ``draw_schedule`` validates every candidate event
against a *shadow* ``FleetPlan`` folded with the same transition
algebra the engines use (``launch.distributed.apply_event``), so a
drawn schedule can never ask the fleet for an inapplicable transition
(a second fault on an already-quarantined device, a host loss that
leaves nothing serving, ...).  Same seed -> same schedule, always.

Taxonomy (``kind``):

========================  =================================================
``transient_stage``       canary-visible stage fault that clears after one
                          failing probe -> probation restores the HW route
``persistent_stage``      stage fault that keeps failing -> ladder rung
``lane_fault``            persistent stage fault with a *localized* lane
                          map registered -> DEGRADED rung, not binary SW
``device_loss``           whole device quarantines (spare-first migration)
``host_loss``             a host's whole device block quarantines at once
``spare_exhaustion``      burst of device losses sized to drain the spare
                          pool -- the last fault finds no spare
``coord_stall``           a peer host stops publishing; the coordinator's
                          bounded retries surface HostTimeoutError
========================  =================================================
"""
from __future__ import annotations

from dataclasses import dataclass, field
from typing import Sequence, Tuple

import numpy as np

from repro.core.routing import FleetPlan
from repro.launch.distributed import FleetEvent, apply_event

TRANSIENT_STAGE = "transient_stage"
PERSISTENT_STAGE = "persistent_stage"
LANE_FAULT = "lane_fault"
DEVICE_LOSS = "device_loss"
HOST_LOSS = "host_loss"
SPARE_EXHAUSTION = "spare_exhaustion"
COORD_STALL = "coord_stall"

ALL_KINDS = (TRANSIENT_STAGE, PERSISTENT_STAGE, LANE_FAULT, DEVICE_LOSS,
             HOST_LOSS, SPARE_EXHAUSTION, COORD_STALL)
#: kinds a serve-under-traffic campaign can inject (host_loss joins when
#: the fleet has a topology); coord_stall fires a coordinator drill
#: alongside the traffic run — visible as a KV-retry counter spike
SERVE_KINDS = (TRANSIENT_STAGE, PERSISTENT_STAGE, LANE_FAULT, DEVICE_LOSS,
               SPARE_EXHAUSTION, COORD_STALL)
#: kinds the data-parallel train loop can inject (stage faults surface as
#: shard guard trips there -- device-granular); coord_stall as above
TRAIN_KINDS = (TRANSIENT_STAGE, DEVICE_LOSS, HOST_LOSS, COORD_STALL)


@dataclass(frozen=True)
class ChaosEvent:
    """One scheduled fault.  ``devices`` is the burst for
    ``spare_exhaustion`` (every other kind targets ``device`` /
    ``host`` / ``stage`` singly)."""
    step: int
    kind: str
    device: int = 0
    host: int = -1
    stage: str = ""
    devices: Tuple[int, ...] = field(default_factory=tuple)

    def __post_init__(self):
        if self.kind not in ALL_KINDS:
            raise ValueError(f"unknown chaos kind {self.kind!r}; one of "
                             f"{ALL_KINDS}")


def _shadow_apply(plan: FleetPlan, wire: Sequence, stage_names,
                  topology) -> Tuple[FleetPlan, bool]:
    ev = FleetEvent.from_engine(0, 0, 0, tuple(wire))
    return apply_event(plan, ev, stage_names, topology=topology)


def draw_schedule(seed: int, *, n_events: int, n_devices: int,
                  stage_names: Sequence[str], n_spares: int = 0,
                  topology=None, kinds: Sequence[str] = SERVE_KINDS,
                  start: int = 4, min_gap: int = 3, max_gap: int = 6,
                  min_serving: int = 2) -> Tuple[ChaosEvent, ...]:
    """Draw ``n_events`` applicable fault events from ``kinds``.

    The shadow plan tracks exactly what the fleet will do (transients
    net out; persistent faults migrate/ladder; losses quarantine), and
    any candidate whose transition would not apply -- or would leave
    fewer than ``min_serving`` devices serving -- is redrawn.  When the
    fleet is too degraded for any destructive kind, the draw falls back
    to transients (always applicable), so the schedule always reaches
    ``n_events``.
    """
    if n_events < 0:
        raise ValueError(f"n_events must be >= 0, got {n_events}")
    if not stage_names:
        raise ValueError("draw_schedule needs at least one stage name")
    rng = np.random.default_rng(seed)
    plan = FleetPlan.healthy(n_devices, stage_names, n_spares=n_spares)
    #: stages armed persistent (a later transient on one would not clear)
    hot_stages: set = set()
    #: stages transients already used -- persistent kinds avoid these
    #: (a probation episode's probes must not cross from a consumed
    #: transient spec into a hard fault queued behind it), and new
    #: transients prefer them so persistent kinds keep fresh stages
    transient_stages: set = set()

    def _pick_transient_stage(cold):
        reuse = sorted(s for s in cold if s in transient_stages)
        pool = reuse if reuse else cold
        return pool[int(rng.integers(0, len(pool)))]
    events = []
    step = start
    while len(events) < n_events:
        kind = kinds[int(rng.integers(0, len(kinds)))]
        serving = list(plan.serving())
        ev = None
        if kind == COORD_STALL:
            ev = ChaosEvent(step=step, kind=kind,
                            host=int(rng.integers(1, 4)))
        elif kind == TRANSIENT_STAGE:
            cold = [s for s in stage_names if s not in hot_stages]
            if cold and serving:
                ev = ChaosEvent(
                    step=step, kind=kind,
                    device=int(serving[rng.integers(0, len(serving))]),
                    stage=_pick_transient_stage(cold))
        elif kind in (PERSISTENT_STAGE, LANE_FAULT):
            # keep >= 1 stage cold so transients (the always-applicable
            # fallback) never run out of clean canaries
            cold = [s for s in stage_names if s not in hot_stages]
            pool = (list(hot_stages) if len(cold) <= 1 else
                    list(stage_names))
            pool = [s for s in pool if s not in transient_stages]
            if serving and pool:
                d = int(serving[rng.integers(0, len(serving))])
                s = sorted(pool)[int(rng.integers(0, len(pool)))]
                nxt, ok = _shadow_apply(plan, ("stage", d, s),
                                        stage_names, topology)
                if ok and len(nxt.serving()) >= min_serving:
                    plan = nxt
                    hot_stages.add(s)
                    ev = ChaosEvent(step=step, kind=kind, device=d,
                                    stage=s)
        elif kind == DEVICE_LOSS:
            if serving:
                d = int(serving[rng.integers(0, len(serving))])
                nxt, ok = _shadow_apply(plan, ("device", d),
                                        stage_names, topology)
                if ok and len(nxt.serving()) >= min_serving:
                    plan = nxt
                    ev = ChaosEvent(step=step, kind=kind, device=d)
        elif kind == HOST_LOSS:
            if topology is not None:
                h = int(rng.integers(0, topology.num_hosts))
                nxt, ok = _shadow_apply(plan, ("host", h),
                                        stage_names, topology)
                if ok and len(nxt.serving()) >= min_serving:
                    plan = nxt
                    ev = ChaosEvent(step=step, kind=kind, host=h)
        elif kind == SPARE_EXHAUSTION:
            burst = len(plan.pool.spares) + 1
            picked = []
            nxt = plan
            for _ in range(burst):
                alive = [d for d in nxt.serving() if d not in picked]
                if not alive:
                    break
                d = int(alive[rng.integers(0, len(alive))])
                cand, ok = _shadow_apply(nxt, ("device", d),
                                         stage_names, topology)
                if not ok or len(cand.serving()) < min_serving:
                    break
                nxt = cand
                picked.append(d)
            if len(picked) == burst:
                plan = nxt
                ev = ChaosEvent(step=step, kind=kind,
                                devices=tuple(picked))
        if ev is None:
            # fleet too degraded (or stages all hot) for this kind:
            # transients keep the campaign dense without eating capacity
            cold = [s for s in stage_names if s not in hot_stages]
            serving = list(plan.serving())
            if not cold or not serving:
                raise RuntimeError(
                    f"schedule seed {seed} wedged after {len(events)} "
                    f"event(s): no applicable fault remains "
                    f"({len(serving)} serving, {len(cold)} cold stages)")
            ev = ChaosEvent(
                step=step, kind=TRANSIENT_STAGE,
                device=int(serving[rng.integers(0, len(serving))]),
                stage=_pick_transient_stage(cold))
        if ev.kind == TRANSIENT_STAGE:
            transient_stages.add(ev.stage)
        events.append(ev)
        step += int(rng.integers(min_gap, max_gap + 1))
    return tuple(events)


def horizon_of(schedule: Sequence[ChaosEvent], *, settle: int = 8) -> int:
    """Engine steps a run must stay busy for so every scheduled event
    lands mid-run (plus ``settle`` steps for the last MTTR window)."""
    return (max((e.step for e in schedule), default=0)) + settle
