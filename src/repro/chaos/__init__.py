"""Chaos campaign layer: seeded randomized fault schedules soaked
against live serve / train fleets, with invariant checkers and
per-event MTTR metrics (ROADMAP "chaos soak").

``schedule``   -- the fault taxonomy + seeded schedule generator
``invariants`` -- post-campaign checkers (drops, fingerprints, ladder,
                  transients, closure); violations raise or report
``campaign``   -- drives schedules through FleetServeEngine-under-
                  traffic and FleetTrainRunner, plus the coordinator
                  stall harness
"""
from repro.chaos.schedule import (ALL_KINDS, COORD_STALL, DEVICE_LOSS,
                                  HOST_LOSS, LANE_FAULT, PERSISTENT_STAGE,
                                  SERVE_KINDS, SPARE_EXHAUSTION,
                                  TRAIN_KINDS, TRANSIENT_STAGE, ChaosEvent,
                                  draw_schedule)
from repro.chaos.invariants import (InvariantViolation, check_closure,
                                    check_fingerprints, check_ladder,
                                    check_no_dropped, check_transients,
                                    verdict)
from repro.chaos.campaign import (ChaosCanary, StallingKVClient,
                                  coordinator_campaign, run_campaign,
                                  serve_campaign, train_campaign)

__all__ = [
    "ALL_KINDS", "COORD_STALL", "DEVICE_LOSS", "HOST_LOSS", "LANE_FAULT",
    "PERSISTENT_STAGE", "SERVE_KINDS", "SPARE_EXHAUSTION", "TRAIN_KINDS",
    "TRANSIENT_STAGE", "ChaosEvent", "draw_schedule",
    "InvariantViolation", "check_closure", "check_fingerprints",
    "check_ladder", "check_no_dropped", "check_transients", "verdict",
    "ChaosCanary", "StallingKVClient", "coordinator_campaign",
    "run_campaign", "serve_campaign", "train_campaign",
]
