"""Mamba2 (SSD) block: in_proj -> causal depthwise conv -> SSD -> gated out.

The SSD core routes through the Viscosity ``mamba2_ssd`` stage.
Decode state per layer: conv tail (B, K-1, conv_dim) + SSM state (B,H,N,P).
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from repro import viscosity
from repro.kernels.mamba2_scan import ops as ssd_ops
from repro.kernels.mamba2_scan import ref as ssd_ref
from repro.launch.sharding import constrain
from repro.models.layers import _he, rms_norm_simple


def dims(cfg):
    d_inner = cfg.ssm.expand * cfg.d_model
    nheads = d_inner // cfg.ssm.head_dim
    conv_dim = d_inner + 2 * cfg.ssm.state_dim
    return d_inner, nheads, conv_dim


def init_mamba2(key, cfg, dtype):
    d = cfg.d_model
    N = cfg.ssm.state_dim
    d_inner, nheads, conv_dim = dims(cfg)
    ks = jax.random.split(key, 5)
    proj_out = 2 * d_inner + 2 * N + nheads        # z, x, B, C, dt
    p = {
        "in_proj": _he(ks[0], (d, proj_out), d, dtype),
        "conv_w": (jax.random.normal(ks[1], (cfg.ssm.conv_kernel, conv_dim))
                   * 0.1).astype(dtype),
        "conv_b": jnp.zeros((conv_dim,), dtype),
        "A_log": jnp.log(jnp.linspace(1.0, 16.0, nheads)).astype(jnp.float32),
        "D": jnp.ones((nheads,), jnp.float32),
        "dt_bias": jnp.log(jnp.expm1(
            jnp.exp(jax.random.uniform(ks[2], (nheads,),
                                       minval=jnp.log(1e-3),
                                       maxval=jnp.log(1e-1))))).astype(jnp.float32),
        "out_proj": _he(ks[3], (d_inner, d), d_inner, dtype),
        "norm_scale": jnp.ones((d_inner,), dtype),
    }
    return p


def _split(cfg, proj):
    d_inner, nheads, _ = dims(cfg)
    N = cfg.ssm.state_dim
    z, xbc_dt = jnp.split(proj, [d_inner], axis=-1)
    xbc, dt = jnp.split(xbc_dt, [d_inner + 2 * N], axis=-1)
    return z, xbc, dt


def _causal_conv(xbc, w, b, *, tail=None):
    """Depthwise causal conv along seq. xbc (B,S,C); w (K,C).

    ``tail`` (B, K-1, C): previous tokens (decode); else zero history.
    Returns (y (B,S,C), new_tail).
    """
    B, S, C = xbc.shape
    K = w.shape[0]
    hist = tail if tail is not None else jnp.zeros((B, K - 1, C), xbc.dtype)
    xx = jnp.concatenate([hist.astype(xbc.dtype), xbc], axis=1)
    y = jnp.zeros((B, S, C), jnp.float32)
    for i in range(K):  # K static and tiny (4)
        y = y + xx[:, i:i + S].astype(jnp.float32) * w[i].astype(jnp.float32)
    y = jax.nn.silu(y + b.astype(jnp.float32)).astype(xbc.dtype)
    new_tail = xx[:, S:S + K - 1] if S >= K - 1 else xx[:, -(K - 1):]
    return y, new_tail


def mamba2_block(p, x, cfg, *, route=viscosity.SW, state=None, step=False):
    """x (B,S,D). step=True: single-token decode using/updating ``state``.

    state = {"conv": (B,K-1,conv_dim), "ssm": (B,H,N,P)}.
    Returns (y, new_state) when state is not None else y.
    """
    B, S, D = x.shape
    d_inner, nheads, conv_dim = dims(cfg)
    N = cfg.ssm.state_dim
    P = cfg.ssm.head_dim
    proj = jnp.einsum("bsd,dk->bsk", x, p["in_proj"].astype(x.dtype))
    z, xbc, dt_raw = _split(cfg, proj)
    xbc = constrain(xbc, "batch", "seq", "ssm_inner")
    conv_tail = state["conv"] if state is not None else None
    xbc, new_tail = _causal_conv(xbc, p["conv_w"], p["conv_b"],
                                 tail=conv_tail)
    xs, B_, C_ = jnp.split(xbc, [d_inner, d_inner + N], axis=-1)
    xs = xs.reshape(B, S, nheads, P)
    dt = jax.nn.softplus(dt_raw.astype(jnp.float32) +
                         p["dt_bias"][None, None, :])
    A = -jnp.exp(p["A_log"])

    if step:
        y, new_ssm = ssd_ref.ssd_step(state["ssm"], xs[:, 0], dt[:, 0],
                                      A, B_[:, 0], C_[:, 0])
        y = y[:, None]
    else:
        y = ssd_ops.ssd(xs, dt, A, B_, C_, route=route, chunk=cfg.ssm.chunk)
        new_ssm = None
        if state is not None:  # prefill: also need the final state
            _, new_ssm = ssd_ref.ssd_chunked(xs, dt, A, B_, C_,
                                             chunk=cfg.ssm.chunk)
    y = y + xs.astype(jnp.float32) * p["D"][None, None, :, None]
    y = y.reshape(B, S, d_inner).astype(x.dtype)
    y = rms_norm_simple(y * jax.nn.silu(z), eps=cfg.norm_eps) * \
        p["norm_scale"].astype(x.dtype)
    out = jnp.einsum("bsk,kd->bsd", y, p["out_proj"].astype(x.dtype))
    out = constrain(out, "batch", "seq", "embed")
    if state is not None:
        return out, {"conv": new_tail, "ssm": new_ssm}
    return out


def init_mamba2_state(B, cfg, dtype):
    d_inner, nheads, conv_dim = dims(cfg)
    return {
        "conv": jnp.zeros((B, cfg.ssm.conv_kernel - 1, conv_dim), dtype),
        "ssm": jnp.zeros((B, nheads, cfg.ssm.state_dim, cfg.ssm.head_dim),
                         jnp.float32),
    }
