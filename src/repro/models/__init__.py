from repro.models.model import (build_model, decode_state_specs, input_specs,
                                params_specs, prefill_batch_specs,
                                train_batch_specs)

__all__ = ["build_model", "input_specs", "params_specs", "train_batch_specs",
           "prefill_batch_specs", "decode_state_specs"]
