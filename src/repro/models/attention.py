"""Attention layer: GQA + RoPE/M-RoPE + local/global windows + softcap.

Stage-wrapped: the score/softmax/PV core routes through the Viscosity
``flash_attention`` op (HW = Pallas kernel, SW = chunked-jnp fallback).

Cache layout (decode): k/v (B, Smax, Hkv, Dh) plus an explicit per-slot
position array ``pos`` (B, Smax) initialized to -1.  Sliding-window archs
allocate Smax = window and write slots round-robin (ring buffer); the
position array makes masking uniform across both cases.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from repro import viscosity
from repro.kernels.flash_attention import ops as attn_ops
from repro.kernels.flash_attention import ref as attn_ref
from repro.launch.sharding import constrain
from repro.models import rope as rope_mod
from repro.models.layers import _he, rms_norm_simple


def init_attention(key, d_model, n_heads, n_kv, head_dim, dtype, *,
                   qkv_bias=False, qk_norm=False):
    ks = jax.random.split(key, 4)
    p = {
        "wq": _he(ks[0], (d_model, n_heads * head_dim), d_model, dtype),
        "wk": _he(ks[1], (d_model, n_kv * head_dim), d_model, dtype),
        "wv": _he(ks[2], (d_model, n_kv * head_dim), d_model, dtype),
        "wo": _he(ks[3], (n_heads * head_dim, d_model),
                  n_heads * head_dim, dtype),
    }
    if qkv_bias:
        p["bq"] = jnp.zeros((n_heads * head_dim,), dtype)
        p["bk"] = jnp.zeros((n_kv * head_dim,), dtype)
        p["bv"] = jnp.zeros((n_kv * head_dim,), dtype)
    if qk_norm:
        p["q_norm"] = jnp.ones((head_dim,), dtype)
        p["k_norm"] = jnp.ones((head_dim,), dtype)
    return p


def _project_q_only(p, x, n_heads, head_dim):
    B, S, _ = x.shape
    q = jnp.einsum("bsd,dh->bsh", x, p["wq"].astype(x.dtype))
    if "bq" in p:
        q = q + p["bq"].astype(x.dtype)
    q = q.reshape(B, S, n_heads, head_dim)
    if "q_norm" in p:
        q = rms_norm_simple(q) * p["q_norm"].astype(x.dtype)
    return constrain(q, "batch", "seq", "heads", "head_dim")


def project_kv(p, x, n_kv, head_dim):
    B, S, _ = x.shape
    k = jnp.einsum("bsd,dh->bsh", x, p["wk"].astype(x.dtype))
    v = jnp.einsum("bsd,dh->bsh", x, p["wv"].astype(x.dtype))
    if "bk" in p:
        k = k + p["bk"].astype(x.dtype)
        v = v + p["bv"].astype(x.dtype)
    k = k.reshape(B, S, n_kv, head_dim)
    v = v.reshape(B, S, n_kv, head_dim)
    if "k_norm" in p:
        k = rms_norm_simple(k) * p["k_norm"].astype(x.dtype)
    return (constrain(k, "batch", "kv_seq", "kv_heads", "head_dim"),
            constrain(v, "batch", "kv_seq", "kv_heads", "head_dim"))


def _project_qkv(p, x, n_heads, n_kv, head_dim, *, qk_norm_eps=1e-6):
    B, S, _ = x.shape
    q = jnp.einsum("bsd,dh->bsh", x, p["wq"].astype(x.dtype))
    k = jnp.einsum("bsd,dh->bsh", x, p["wk"].astype(x.dtype))
    v = jnp.einsum("bsd,dh->bsh", x, p["wv"].astype(x.dtype))
    if "bq" in p:
        q = q + p["bq"].astype(x.dtype)
        k = k + p["bk"].astype(x.dtype)
        v = v + p["bv"].astype(x.dtype)
    q = q.reshape(B, S, n_heads, head_dim)
    k = k.reshape(B, S, n_kv, head_dim)
    v = v.reshape(B, S, n_kv, head_dim)
    if "q_norm" in p:
        q = rms_norm_simple(q, eps=qk_norm_eps) * p["q_norm"].astype(x.dtype)
        k = rms_norm_simple(k, eps=qk_norm_eps) * p["k_norm"].astype(x.dtype)
    q = constrain(q, "batch", "seq", "heads", "head_dim")
    k = constrain(k, "batch", "seq", "kv_heads", "head_dim")
    v = constrain(v, "batch", "seq", "kv_heads", "head_dim")
    return q, k, v


def attn_full(p, x, cos, sin, *, n_heads, n_kv, head_dim, causal=True,
              window=0, softcap=0.0, scale=0.0, route=viscosity.SW,
              kv_out=False, cross_kv=None, precomputed_kv=None,
              kv_chunk=0):
    """Full-sequence attention (train / prefill).

    ``cross_kv``: encoder output (B, S_enc, D) — keys/values are projected
    from it instead of ``x`` (whisper cross-attention).
    ``precomputed_kv``: (k, v) already projected (cached cross-KV during
    serving; avoids re-projecting the encoder output every decode step).
    """
    if precomputed_kv is not None:
        q = _project_q_only(p, x, n_heads, head_dim)
        k, v = precomputed_kv
    elif cross_kv is not None:
        q = _project_q_only(p, x, n_heads, head_dim)
        k, v = project_kv(p, cross_kv.astype(x.dtype), n_kv, head_dim)
    else:
        q, k, v = _project_qkv(p, x, n_heads, n_kv, head_dim)
    if cos is not None and cross_kv is None:
        q = rope_mod.apply_rope(q, cos, sin)
        k = rope_mod.apply_rope(k, cos, sin)
    o = attn_ops.attention(q, k, v, causal=causal, window=window,
                           softcap=softcap, scale=scale, route=route,
                           kv_chunk=kv_chunk)
    o = constrain(o, "batch", "seq", "heads", "head_dim")
    B, S = x.shape[:2]
    out = jnp.einsum("bsh,hd->bsd", o.reshape(B, S, -1),
                     p["wo"].astype(x.dtype))
    out = constrain(out, "batch", "seq", "embed")
    return (out, (k, v)) if kv_out else out


def init_kv_cache(B, smax, n_kv, head_dim, dtype):
    return {
        "k": jnp.zeros((B, smax, n_kv, head_dim), dtype),
        "v": jnp.zeros((B, smax, n_kv, head_dim), dtype),
        "pos": jnp.full((B, smax), -1, jnp.int32),
    }


def cache_write_prefill(cache, k, v):
    """Write a prefill's k/v into the cache.

    S <= Smax: plain write into slots [0, S).  S > Smax (ring buffer,
    windowed attention): keep the last Smax tokens, placed cyclically at
    slot = position % Smax so subsequent decode writes stay consistent.
    """
    B, S = k.shape[:2]
    smax = cache["k"].shape[1]
    c = dict(cache)
    if S <= smax:
        c["k"] = jax.lax.dynamic_update_slice(
            cache["k"], k.astype(cache["k"].dtype), (0, 0, 0, 0))
        c["v"] = jax.lax.dynamic_update_slice(
            cache["v"], v.astype(cache["v"].dtype), (0, 0, 0, 0))
        pos = jnp.broadcast_to(jnp.arange(S, dtype=jnp.int32)[None], (B, S))
        c["pos"] = jax.lax.dynamic_update_slice(cache["pos"], pos, (0, 0))
        return c
    p0 = S - smax                       # first kept absolute position
    idx = (jnp.arange(smax, dtype=jnp.int32) - p0) % smax  # keep-row per slot
    c["k"] = k[:, p0:][:, idx].astype(cache["k"].dtype)
    c["v"] = v[:, p0:][:, idx].astype(cache["v"].dtype)
    pos = jnp.broadcast_to((p0 + idx)[None], (B, smax))
    c["pos"] = pos
    return c


def attn_decode(p, x, cache, t, *, n_heads, n_kv, head_dim, window=0,
                softcap=0.0, scale=0.0, rope_theta=0.0, mrope=None,
                positions3=None, route=viscosity.SW, layer=None):
    """One decode step. x (B,1,D); t: scalar int32 absolute position.

    Writes slot ``t % Smax`` (ring buffer when Smax == window), attends over
    the cache with explicit per-slot positions.

    ``layer``: if given, ``cache`` leaves are LAYER-STACKED (L, B, S, ...)
    and this layer's row is updated with a single in-place
    dynamic-update-slice (the decode path unrolls layers so the donated
    stacked cache is never copied).
    """
    B = x.shape[0]
    stacked = layer is not None
    smax = cache["k"].shape[2 if stacked else 1]
    q, k, v = _project_qkv(p, x, n_heads, n_kv, head_dim)
    tvec = jnp.full((B, 1), t, jnp.int32)
    if mrope is not None:
        cos, sin = rope_mod.mrope_tables(positions3, head_dim,
                                         mrope["theta"], mrope["sections"])
        q = rope_mod.apply_rope(q, cos, sin)
        k = rope_mod.apply_rope(k, cos, sin)
    elif rope_theta:
        cos, sin = rope_tables_b(tvec, head_dim, rope_theta)
        q = rope_mod.apply_rope(q, cos, sin)
        k = rope_mod.apply_rope(k, cos, sin)
    slot = jnp.mod(t, smax)
    c = dict(cache)
    kw = k.astype(cache["k"].dtype)
    vw = v.astype(cache["v"].dtype)
    if stacked:
        c["k"] = jax.lax.dynamic_update_slice(
            cache["k"], kw[None], (layer, 0, slot, 0, 0))
        c["v"] = jax.lax.dynamic_update_slice(
            cache["v"], vw[None], (layer, 0, slot, 0, 0))
        c["pos"] = jax.lax.dynamic_update_slice(
            cache["pos"], tvec[None], (layer, 0, slot))
        k_all = jax.lax.dynamic_slice_in_dim(c["k"], layer, 1, 0)[0]
        v_all = jax.lax.dynamic_slice_in_dim(c["v"], layer, 1, 0)[0]
        pos_all = jax.lax.dynamic_slice_in_dim(c["pos"], layer, 1, 0)[0]
    else:
        c["k"] = jax.lax.dynamic_update_slice(cache["k"], kw, (0, slot, 0, 0))
        c["v"] = jax.lax.dynamic_update_slice(cache["v"], vw, (0, slot, 0, 0))
        c["pos"] = jax.lax.dynamic_update_slice(cache["pos"], tvec, (0, slot))
        k_all, v_all, pos_all = c["k"], c["v"], c["pos"]
    o = attn_ref.attention_naive(
        q, k_all, v_all, causal=True, window=window, softcap=softcap,
        scale=scale, q_offset=jnp.full((B,), t, jnp.int32),
        k_positions=pos_all)
    out = jnp.einsum("bsh,hd->bsd", o.reshape(B, 1, -1),
                     p["wo"].astype(x.dtype))
    return out, c


def rope_tables_b(positions, head_dim, theta):
    return rope_mod.rope_tables(positions, head_dim, theta)
