"""Unified model API: build_model(cfg) + input_specs(cfg, shape).

``input_specs`` returns ShapeDtypeStruct stand-ins for every model input of
a given (arch x shape) cell — weak-type-correct, shardable, no device
allocation — used by the multi-pod dry-run and by jax.eval_shape.
"""
from __future__ import annotations

from typing import Any, Dict, Union

import jax
import jax.numpy as jnp

from repro.configs.base import ModelConfig
from repro.configs.shapes import ShapeSpec
from repro.models.encdec import EncDecModel
from repro.models.transformer import LMModel

Model = Union[LMModel, EncDecModel]


def build_model(cfg: ModelConfig, routes=None) -> Model:
    """Build a model under a routing: ``routes`` is the unified RoutingPlan
    IR (preferred), a mapping of stage -> target / ResidentRoute handle
    (the resident executable builds one inside its trace), or None (every
    stage takes its software path)."""
    if cfg.is_encdec:
        return EncDecModel(cfg, routes=routes)
    return LMModel(cfg, routes=routes)


def _sds(shape, dtype):
    return jax.ShapeDtypeStruct(shape, dtype)


def train_batch_specs(cfg: ModelConfig, B: int, S: int) -> Dict[str, Any]:
    if cfg.is_encdec:
        T = min(cfg.max_target_len, S)
        return {
            "embeds": _sds((B, S, cfg.d_model), jnp.bfloat16),
            "dec_tokens": _sds((B, T), jnp.int32),
            "dec_targets": _sds((B, T), jnp.int32),
        }
    batch = {"tokens": _sds((B, S), jnp.int32),
             "targets": _sds((B, S), jnp.int32)}
    if cfg.stub_frontend:  # vlm: precomputed patch embeddings + 3D positions
        batch["embeds"] = _sds((B, S, cfg.d_model), jnp.bfloat16)
        batch["positions3"] = _sds((B, S, 3), jnp.int32)
        del batch["tokens"]
    return batch


def prefill_batch_specs(cfg: ModelConfig, model: Model, B: int, S: int):
    cache = jax.eval_shape(lambda: model.init_cache(B, S))
    if cfg.is_encdec:
        T = min(cfg.max_target_len, S)
        return {"embeds": _sds((B, S, cfg.d_model), jnp.bfloat16),
                "dec_tokens": _sds((B, T), jnp.int32),
                "cache": cache}
    batch = {"tokens": _sds((B, S), jnp.int32), "cache": cache}
    if cfg.stub_frontend and not cfg.is_encdec:
        batch["embeds"] = _sds((B, S, cfg.d_model), jnp.bfloat16)
        batch["positions3"] = _sds((B, S, 3), jnp.int32)
        del batch["tokens"]
    return batch


def decode_state_specs(cfg: ModelConfig, model: Model, B: int, S: int):
    """Decode-mode stand-ins: (cache/state, tokens, t)."""
    if cfg.is_encdec:
        cache = jax.eval_shape(lambda: model.init_cache(B, S))
        cross = jax.eval_shape(
            lambda: model.cross_kv_cache(
                jax.eval_shape(lambda k: model.init(k),
                               jax.ShapeDtypeStruct((2,), jnp.uint32)),
                jnp.zeros((B, S, cfg.d_model), jnp.bfloat16)))
        state = {"cross": cross, "self": cache}
    else:
        state = jax.eval_shape(lambda: model.init_cache(B, S))
    return state, _sds((B, 1), jnp.int32), _sds((), jnp.int32)


def params_specs(model: Model):
    return jax.eval_shape(model.init, jax.ShapeDtypeStruct((2,), jnp.uint32))


def input_specs(cfg: ModelConfig, shape: ShapeSpec, model: Model = None):
    """All input stand-ins for one dry-run cell."""
    model = model or build_model(cfg)
    B, S = shape.global_batch, shape.seq_len
    if shape.kind == "train":
        return {"batch": train_batch_specs(cfg, B, S)}
    if shape.kind == "prefill":
        return {"batch": prefill_batch_specs(cfg, model, B, S)}
    if shape.kind == "decode":
        state, tok, t = decode_state_specs(cfg, model, B, S)
        return {"cache": state, "tokens": tok, "t": t}
    raise ValueError(shape.kind)
