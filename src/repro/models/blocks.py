"""Decoder blocks: attention+FFN (dense/MoE), RWKV6, Mamba2(+shared attn).

Blocks are assembled by transformer.py inside pattern-grouped scans: the
repeating layer pattern is unrolled inside the scan body so per-layer
attributes (window, rope theta, FFN kind) stay *static* — required by the
Pallas kernels' block-skipping and cheap for compile size (body length =
pattern length, not num_layers).
"""
from __future__ import annotations

from dataclasses import dataclass

import jax
import jax.numpy as jnp
from jax import ad_checkpoint

from repro import viscosity
from repro.configs.base import ATTN_LOCAL, ModelConfig
from repro.models import attention as attn_mod
from repro.models import layers as L
from repro.models import moe as moe_mod
from repro.models import rwkv6 as rwkv_mod
from repro.models import mamba2 as mamba_mod


@dataclass(frozen=True)
class LayerMeta:
    kind: int
    window: int          # 0 = full attention
    theta: float         # rope theta for this layer
    local: bool          # uses the local rope table (gemma3)


def make_metas(cfg: ModelConfig):
    """One LayerMeta per *pattern position* (layer i uses i % len(pattern))."""
    pat = cfg.layer_pattern or (0,)
    metas = []
    for k in pat:
        local = (k == ATTN_LOCAL) and bool(cfg.rope_theta_local)
        metas.append(LayerMeta(
            kind=k,
            window=cfg.window if k == ATTN_LOCAL else 0,
            theta=(cfg.rope_theta_local if local else cfg.rope_theta),
            local=local))
    return metas


# ------------------------------------------------------------------ init
def init_attn_block(key, cfg: ModelConfig, dtype):
    ks = jax.random.split(key, 4)
    hd = cfg.resolved_head_dim
    p = {
        "ln1": L.init_norm(cfg.d_model, dtype, cfg.use_layernorm),
        "attn": attn_mod.init_attention(
            ks[0], cfg.d_model, cfg.num_heads, cfg.num_kv_heads, hd, dtype,
            qkv_bias=cfg.qkv_bias, qk_norm=cfg.qk_norm),
        "ln2": L.init_norm(cfg.d_model, dtype, cfg.use_layernorm),
    }
    if cfg.moe is not None:
        p["moe"] = moe_mod.init_moe(ks[1], cfg.d_model, cfg.d_ff,
                                    cfg.moe.num_experts, dtype,
                                    shared=cfg.moe.shared_expert)
    else:
        p["mlp"] = L.init_mlp(ks[1], cfg.d_model, cfg.d_ff, dtype,
                              gated=cfg.gated_mlp)
    if cfg.post_norms:
        p["post_ln1"] = L.init_norm(cfg.d_model, dtype, cfg.use_layernorm)
        p["post_ln2"] = L.init_norm(cfg.d_model, dtype, cfg.use_layernorm)
    return p


def init_rwkv_block(key, cfg: ModelConfig, dtype):
    k1, _ = jax.random.split(key)
    return {
        "ln1": L.init_norm(cfg.d_model, dtype, cfg.use_layernorm),
        "tm": rwkv_mod.init_rwkv6(k1, cfg, dtype),
        "ln2": L.init_norm(cfg.d_model, dtype, cfg.use_layernorm),
    }


def init_mamba_block(key, cfg: ModelConfig, dtype):
    return {
        "ln1": L.init_norm(cfg.d_model, dtype, cfg.use_layernorm),
        "mix": mamba_mod.init_mamba2(key, cfg, dtype),
    }


# --------------------------------------------------------------- forward
def attn_block(p, x, cfg: ModelConfig, meta: LayerMeta, ropes, routes,
               cache=None, t=None, step=False, layer=None):
    """Returns (x, new_cache, aux) — aux has MoE metrics (zeros if dense)."""
    route_attn = routes.get("flash_attention", viscosity.SW)
    route_mlp = routes.get("swiglu_mlp", viscosity.SW)
    h = L.norm(p["ln1"], x, eps=cfg.norm_eps, layernorm=cfg.use_layernorm)
    new_cache = cache
    if step:
        mrope = None
        if cfg.mrope_sections:
            mrope = {"theta": meta.theta, "sections": cfg.mrope_sections}
        attn_out, new_cache = attn_mod.attn_decode(
            p["attn"], h, cache, t, n_heads=cfg.num_heads,
            n_kv=cfg.num_kv_heads, head_dim=cfg.resolved_head_dim,
            window=meta.window, softcap=cfg.attn_softcap,
            scale=cfg.attn_scale, rope_theta=0.0 if cfg.mrope_sections else meta.theta,
            mrope=mrope,
            positions3=(jnp.full((x.shape[0], 1, 3), t, jnp.int32)
                        if cfg.mrope_sections else None),
            route=route_attn, layer=layer)
    else:
        cos, sin = ropes["local" if meta.local else "global"]
        res = attn_mod.attn_full(
            p["attn"], h, cos, sin, n_heads=cfg.num_heads,
            n_kv=cfg.num_kv_heads, head_dim=cfg.resolved_head_dim,
            causal=True, window=meta.window, softcap=cfg.attn_softcap,
            scale=cfg.attn_scale, route=route_attn,
            kv_out=cache is not None, kv_chunk=cfg.attn_chunk)
        if cache is not None:
            attn_out, (k, v) = res
            new_cache = attn_mod.cache_write_prefill(cache, k, v)
        else:
            attn_out = res
    if cfg.post_norms:
        attn_out = L.norm(p["post_ln1"], attn_out, eps=cfg.norm_eps)
    # tagged so remat_policy="collectives" keeps the post-all-reduce value
    attn_out = ad_checkpoint.checkpoint_name(attn_out, "attn_out")
    x = x + attn_out

    h = L.norm(p["ln2"], x, eps=cfg.norm_eps, layernorm=cfg.use_layernorm)
    aux = {"aux_loss": jnp.float32(0), "z_loss": jnp.float32(0),
           "drop_frac": jnp.float32(0)}
    if cfg.moe is not None:
        ffn_out, aux = moe_mod.moe_ffn(p["moe"], h, top_k=cfg.moe.top_k,
                                       capacity_factor=cfg.moe.capacity_factor,
                                       act=cfg.mlp_act,
                                       combine_first=cfg.moe.combine_first)
    else:
        ffn_out = L.mlp(p["mlp"], h, act=cfg.mlp_act, route=route_mlp)
    if cfg.post_norms:
        ffn_out = L.norm(p["post_ln2"], ffn_out, eps=cfg.norm_eps)
    ffn_out = ad_checkpoint.checkpoint_name(ffn_out, "ffn_out")
    x = x + ffn_out
    return x, new_cache, aux


def rwkv_block(p, x, cfg: ModelConfig, routes, state=None, step=False):
    route = routes.get("rwkv6_wkv", viscosity.SW)
    h = L.norm(p["ln1"], x, eps=cfg.norm_eps)
    new_state = state
    if state is not None:
        tm_out, st_tm = rwkv_mod.time_mix(p["tm"], h, cfg, route=route,
                                          state=state, step=step)
    else:
        tm_out = rwkv_mod.time_mix(p["tm"], h, cfg, route=route)
    x = x + tm_out
    h = L.norm(p["ln2"], x, eps=cfg.norm_eps)
    if state is not None:
        cm_out, st_cm = rwkv_mod.channel_mix(p["tm"], h, state=state)
        new_state = {**st_tm, **st_cm}
    else:
        cm_out = rwkv_mod.channel_mix(p["tm"], h)
    x = x + cm_out
    return x, new_state


def mamba_block(p, x, cfg: ModelConfig, routes, state=None, step=False):
    route = routes.get("mamba2_ssd", viscosity.SW)
    h = L.norm(p["ln1"], x, eps=cfg.norm_eps)
    if state is not None:
        out, new_state = mamba_mod.mamba2_block(p["mix"], h, cfg, route=route,
                                                state=state, step=step)
        return x + out, new_state
    out = mamba_mod.mamba2_block(p["mix"], h, cfg, route=route)
    return x + out, None
