"""RWKV-6 "Finch" block: time-mix (WKV w/ data-dependent decay) + channel-mix.

Faithful pieces: per-channel static token-shift mixes, the LoRA'd
data-dependent decay (the Finch contribution), bonus ``u``, per-head group
norm, squared-ReLU channel-mix.  Simplification (documented in DESIGN.md):
the data-dependent ddlerp on token-shift mixes is reduced to static mixes.

Decay clamp: lw = -exp(...) clamped to [-4, 0] so the chunked factorized
WKV stays inside f32 range at chunk 16 (see kernels/rwkv6_scan).
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from repro import viscosity
from repro.kernels.rwkv6_scan import ops as wkv_ops
from repro.kernels.rwkv6_scan import ref as wkv_ref
from repro.launch.sharding import constrain
from repro.models.layers import _he

LW_MIN = -4.0


def init_rwkv6(key, cfg, dtype):
    d = cfg.d_model
    hK = cfg.ssm.rwkv_head_dim
    H = d // hK
    lora = cfg.ssm.rwkv_decay_lora
    ks = jax.random.split(key, 12)
    f = cfg.d_ff
    return {
        # time-mix
        "mix_r": jnp.full((d,), 0.5, dtype), "mix_k": jnp.full((d,), 0.5, dtype),
        "mix_v": jnp.full((d,), 0.5, dtype), "mix_g": jnp.full((d,), 0.5, dtype),
        "mix_w": jnp.full((d,), 0.5, dtype),
        "wr": _he(ks[0], (d, d), d, dtype), "wk": _he(ks[1], (d, d), d, dtype),
        "wv": _he(ks[2], (d, d), d, dtype), "wg": _he(ks[3], (d, d), d, dtype),
        "wo": _he(ks[4], (d, d), d, dtype),
        "w0": jnp.zeros((d,), jnp.float32),
        "w_lora_a": _he(ks[5], (d, lora), d, jnp.float32),
        "w_lora_b": (jax.random.normal(ks[6], (lora, d)) * 0.01).astype(jnp.float32),
        "u": (jax.random.normal(ks[7], (H, hK)) * 0.1).astype(jnp.float32),
        "ln_scale": jnp.ones((d,), dtype),
        # channel-mix
        "cmix_r": jnp.full((d,), 0.5, dtype), "cmix_k": jnp.full((d,), 0.5, dtype),
        "cwr": _he(ks[8], (d, d), d, dtype),
        "cwk": _he(ks[9], (d, f), d, dtype),
        "cwv": _he(ks[10], (f, d), f, dtype),
    }


def _shift(x, last=None):
    """Token shift: x_{t-1} (zeros / ``last`` for t=0). x (B,S,D)."""
    if x.shape[1] == 1 and last is not None:
        return last[:, None, :]
    pad = jnp.zeros_like(x[:, :1]) if last is None else last[:, None, :]
    return jnp.concatenate([pad, x[:, :-1]], axis=1)


def _mix(x, xs, m):
    return x + (xs - x) * m.astype(x.dtype)


def time_mix(p, x, cfg, *, route=viscosity.SW, state=None, step=False):
    B, S, d = x.shape
    hK = cfg.ssm.rwkv_head_dim
    H = d // hK
    last = state["shift_tm"] if state is not None else None
    xs = _shift(x, last)
    r = jnp.einsum("bsd,de->bse", _mix(x, xs, p["mix_r"]), p["wr"].astype(x.dtype))
    k = jnp.einsum("bsd,de->bse", _mix(x, xs, p["mix_k"]), p["wk"].astype(x.dtype))
    v = jnp.einsum("bsd,de->bse", _mix(x, xs, p["mix_v"]), p["wv"].astype(x.dtype))
    g = jnp.einsum("bsd,de->bse", _mix(x, xs, p["mix_g"]), p["wg"].astype(x.dtype))
    xw = _mix(x, xs, p["mix_w"]).astype(jnp.float32)
    lw = -jnp.exp(p["w0"][None, None] +
                  jnp.tanh(xw @ p["w_lora_a"]) @ p["w_lora_b"])
    lw = jnp.clip(lw, LW_MIN, -1e-4)

    rh = r.reshape(B, S, H, hK)
    kh = k.reshape(B, S, H, hK)
    vh = v.reshape(B, S, H, hK)
    lwh = lw.reshape(B, S, H, hK).astype(x.dtype)
    rh = constrain(rh, "batch", "seq", "ssm_heads", "head_dim")

    if step:
        o, new_wkv = wkv_ref.wkv6_step(state["wkv"], rh[:, 0], kh[:, 0],
                                       vh[:, 0], lwh[:, 0], p["u"])
        o = o[:, None]
    else:
        o = wkv_ops.wkv6(rh, kh, vh, lwh, p["u"], route=route,
                         chunk=cfg.ssm.rwkv_chunk)
        new_wkv = None
        if state is not None:
            _, new_wkv = wkv_ref.wkv6_chunked(rh, kh, vh, lwh, p["u"],
                                              chunk=cfg.ssm.rwkv_chunk)
    # per-head group norm
    of = o.reshape(B, S, H, hK).astype(jnp.float32)
    mu = jnp.mean(of, -1, keepdims=True)
    var = jnp.var(of, -1, keepdims=True)
    of = (of - mu) * jax.lax.rsqrt(var + 64e-5)
    o = of.reshape(B, S, d).astype(x.dtype) * p["ln_scale"].astype(x.dtype)
    out = jnp.einsum("bsd,de->bse", o * jax.nn.silu(g),
                     p["wo"].astype(x.dtype))
    out = constrain(out, "batch", "seq", "embed")
    if state is not None:
        return out, {"shift_tm": x[:, -1], "wkv": new_wkv}
    return out


def channel_mix(p, x, state=None):
    last = state["shift_cm"] if state is not None else None
    xs = _shift(x, last)
    xr = _mix(x, xs, p["cmix_r"])
    xk = _mix(x, xs, p["cmix_k"])
    r = jax.nn.sigmoid(jnp.einsum("bsd,de->bse", xr, p["cwr"].astype(x.dtype)))
    k = jnp.einsum("bsd,df->bsf", xk, p["cwk"].astype(x.dtype))
    k = jnp.square(jax.nn.relu(k))
    k = constrain(k, "batch", "seq", "mlp")
    out = r * jnp.einsum("bsf,fd->bsd", k, p["cwv"].astype(x.dtype))
    out = constrain(out, "batch", "seq", "embed")
    if state is not None:
        return out, {"shift_cm": x[:, -1]}
    return out


def init_rwkv6_state(B, cfg, dtype):
    d = cfg.d_model
    hK = cfg.ssm.rwkv_head_dim
    H = d // hK
    return {
        "shift_tm": jnp.zeros((B, d), dtype),
        "shift_cm": jnp.zeros((B, d), dtype),
        "wkv": jnp.zeros((B, H, hK, hK), jnp.float32),
    }
