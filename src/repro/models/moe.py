"""Capacity-based top-k MoE with group-local gather/scatter dispatch.

Design (scales to the production mesh):
  * tokens keep their (B, S) grouping; B is the data-sharded axis, so all
    dispatch indexing is *group-local* — no all-to-all in the baseline.
  * expert FFN weights are (E, D, F) with F sharded over "model" (TP inside
    every expert).  The beyond-paper EP variant re-factors the model axis
    into (expert, tp) — see launch/sharding.py and EXPERIMENTS.md §Perf.
  * dispatch avoids the O(tokens * E * C) one-hot tensor entirely:
    positions-within-expert come from a cumsum over the (B, S, E)
    assignment mask, tokens are gathered into (B, E, C, D) via
    take_along_axis, and combined back by a (B, S, K) gather.

Losses: switch-style load-balance aux loss + router z-loss.
"""
from __future__ import annotations

from typing import Tuple

import jax
import jax.numpy as jnp

from repro.launch.sharding import constrain
from repro.models.layers import _he


def init_moe(key, d, f, num_experts, dtype, *, shared=False):
    ks = jax.random.split(key, 5)
    p = {
        "router": _he(ks[0], (d, num_experts), d, jnp.float32),
        "w1": _he(ks[1], (num_experts, d, f), d, dtype),
        "w3": _he(ks[2], (num_experts, d, f), d, dtype),
        "w2": _he(ks[3], (num_experts, f, d), f, dtype),
    }
    if shared:
        kk = jax.random.split(ks[4], 3)
        p["shared"] = {"w1": _he(kk[0], (d, f), d, dtype),
                       "w3": _he(kk[1], (d, f), d, dtype),
                       "w2": _he(kk[2], (f, d), f, dtype)}
    return p


def moe_ffn(p, x, *, top_k: int, capacity_factor: float, act: str = "silu",
            combine_first: bool = False) -> Tuple[jax.Array, dict]:
    """x (B, S, D) -> (y (B, S, D), aux metrics dict).

    ``combine_first`` (§Perf HC-B): gather expert *hidden* states back to
    token order and fold the gates in BEFORE the second FFN matmul, so the
    f-contraction (and its TP all-reduce) runs once over (B,S,D) instead
    of over the (B,E,C,D) capacity buffer — trades extra gather/einsum
    FLOPs for E/(K*cf) fewer all-reduce bytes.
    """
    B, S, D = x.shape
    E = p["router"].shape[1]
    C = int(max(top_k, round(S * top_k * capacity_factor / E)))
    C = min(C, S * top_k)

    logits = jnp.einsum("bsd,de->bse", x.astype(jnp.float32), p["router"])
    probs = jax.nn.softmax(logits, axis=-1)
    gate_vals, gate_idx = jax.lax.top_k(probs, top_k)          # (B,S,K)
    gate_vals = gate_vals / jnp.maximum(
        jnp.sum(gate_vals, -1, keepdims=True), 1e-9)

    # position of token s within expert e's capacity buffer (group-local)
    assign = jax.nn.one_hot(gate_idx, E, dtype=jnp.int32)      # (B,S,K,E)
    assign_se = jnp.sum(assign, axis=2)                        # (B,S,E)
    # priority: earlier tokens first; k-th choice after (k-1)-th
    cum = jnp.cumsum(assign_se, axis=1) - assign_se            # tokens before s
    # per-(s,k) position: tokens before s with expert e, plus this token's
    # earlier choices of the same expert (rare duplicate-expert case)
    pos_k = jnp.take_along_axis(cum, gate_idx, axis=2)         # (B,S,K)
    intra = jnp.cumsum(assign, axis=2) - assign                # (B,S,K,E)
    pos_k = pos_k + jnp.take_along_axis(
        intra, gate_idx[..., None], axis=3)[..., 0]
    keep = pos_k < C                                           # capacity drop
    gate_vals = gate_vals * keep.astype(gate_vals.dtype)

    # scatter token indices into (B, E, C) slot table
    b_idx = jnp.arange(B, dtype=jnp.int32)[:, None, None]
    s_idx = jnp.broadcast_to(jnp.arange(S, dtype=jnp.int32)[None, :, None],
                             (B, S, top_k))
    slot_tok = jnp.full((B, E, C), S, jnp.int32)               # S = "empty"
    # dropped tokens write to position C (out of bounds) -> mode="drop"
    slot_tok = slot_tok.at[
        b_idx, gate_idx, jnp.where(keep, pos_k, C)
    ].set(s_idx, mode="drop")
    # gather tokens -> (B, E, C, D); empty slots read a zero row
    x_pad = jnp.concatenate([x, jnp.zeros((B, 1, D), x.dtype)], axis=1)
    xe = jnp.take_along_axis(
        x_pad[:, :, None, :],
        slot_tok[..., None].reshape(B, E * C, 1, 1), axis=1,
    ).reshape(B, E, C, D)
    xe = constrain(xe, "batch", "experts", "expert_cap", "embed")

    h1 = jnp.einsum("becd,edf->becf", xe, p["w1"].astype(xe.dtype))
    h3 = jnp.einsum("becd,edf->becf", xe, p["w3"].astype(xe.dtype))
    g = jax.nn.silu(h1) if act == "silu" else jax.nn.gelu(h1, approximate=True)
    h = constrain(g * h3, "batch", "experts", "expert_cap", "mlp")
    gidx = (gate_idx * C + jnp.clip(pos_k, 0, C - 1))          # (B,S,K)
    if combine_first:
        F = h.shape[-1]
        hflat = h.reshape(B, E * C, F)
        hk = jnp.take_along_axis(
            hflat[:, :, None, :].reshape(B, E * C, 1, F),
            gidx.reshape(B, S * top_k, 1, 1),
            axis=1).reshape(B, S, top_k, F)
        onehot_g = jax.nn.one_hot(gate_idx, E, dtype=hk.dtype) * \
            gate_vals[..., None].astype(hk.dtype)              # (B,S,K,E)
        Gm = jnp.einsum("bske,bskf->bsef", onehot_g, hk)
        y = jnp.einsum("bsef,efd->bsd", Gm, p["w2"].astype(hk.dtype))
    else:
        ye = jnp.einsum("becf,efd->becd", h, p["w2"].astype(xe.dtype))
        ye = constrain(ye, "batch", "experts", "expert_cap", "embed")
        # combine: for each (s, k), read expert gate_idx at slot pos_k
        flat = ye.reshape(B, E * C, D)
        yk = jnp.take_along_axis(
            flat[:, :, None, :].reshape(B, E * C, 1, D),
            gidx.reshape(B, S * top_k, 1, 1),
            axis=1).reshape(B, S, top_k, D)
        y = jnp.sum(yk * gate_vals[..., None].astype(yk.dtype), axis=2)

    if "shared" in p:
        sh = p["shared"]
        h1 = jnp.einsum("bsd,df->bsf", x, sh["w1"].astype(x.dtype))
        h3 = jnp.einsum("bsd,df->bsf", x, sh["w3"].astype(x.dtype))
        gg = jax.nn.silu(h1) if act == "silu" else jax.nn.gelu(h1, approximate=True)
        y = y + jnp.einsum("bsf,fd->bsd", gg * h3, sh["w2"].astype(x.dtype))

    # aux losses (switch-transformer style)
    me = jnp.mean(probs, axis=(0, 1))                          # (E,)
    fe = jnp.mean(
        jax.nn.one_hot(gate_idx[..., 0], E, dtype=jnp.float32), axis=(0, 1))
    aux = E * jnp.sum(me * fe)
    z = jnp.mean(jnp.square(jax.nn.logsumexp(logits, axis=-1)))
    dropped = 1.0 - jnp.mean(keep.astype(jnp.float32))
    y = constrain(y.astype(x.dtype), "batch", "seq", "embed")
    return y, {"aux_loss": aux, "z_loss": z, "drop_frac": dropped}
