"""Rotary position embeddings: standard RoPE and Qwen2-VL M-RoPE."""
from __future__ import annotations

from typing import Sequence

import jax.numpy as jnp


def rope_tables(positions, head_dim: int, theta: float):
    """positions (..., S) int -> cos/sin (..., S, head_dim//2) f32."""
    half = head_dim // 2
    freqs = theta ** (-jnp.arange(0, half, dtype=jnp.float32) / half)
    ang = positions.astype(jnp.float32)[..., None] * freqs
    return jnp.cos(ang), jnp.sin(ang)


def apply_rope(x, cos, sin):
    """x (B, S, H, D); cos/sin (B, S, D/2) or (S, D/2)."""
    half = x.shape[-1] // 2
    x1, x2 = x[..., :half], x[..., half:]
    if cos.ndim == 2:
        cos = cos[None, :, None, :]
        sin = sin[None, :, None, :]
    else:
        cos = cos[:, :, None, :]
        sin = sin[:, :, None, :]
    xf1, xf2 = x1.astype(jnp.float32), x2.astype(jnp.float32)
    return jnp.concatenate(
        [xf1 * cos - xf2 * sin, xf2 * cos + xf1 * sin], -1).astype(x.dtype)


def mrope_tables(positions3, head_dim: int, theta: float,
                 sections: Sequence[int]):
    """Qwen2-VL M-RoPE: positions3 (B, S, 3) = (t, h, w) coordinates.

    The head_dim/2 frequency channels are partitioned into ``sections``
    (summing to head_dim/2); section i rotates by coordinate i.
    """
    half = head_dim // 2
    assert sum(sections) == half, (sections, half)
    freqs = theta ** (-jnp.arange(0, half, dtype=jnp.float32) / half)
    coords = []
    start = 0
    for i, sec in enumerate(sections):
        coords.append(jnp.broadcast_to(positions3[..., i:i + 1],
                                       positions3.shape[:-1] + (sec,)))
        start += sec
    coord = jnp.concatenate(coords, -1).astype(jnp.float32)   # (B,S,half)
    ang = coord * freqs
    return jnp.cos(ang), jnp.sin(ang)


def positions_default(B: int, S: int, offset=None):
    pos = jnp.arange(S, dtype=jnp.int32)[None, :]
    if offset is not None:
        pos = pos + jnp.asarray(offset, jnp.int32).reshape(-1, 1)
    return jnp.broadcast_to(pos, (B, S))
