"""Whisper-style encoder-decoder backbone (conv frontend STUBBED).

Per the assignment, [audio] entries specify the transformer backbone only:
``input_specs()`` provides precomputed frame embeddings (B, S_enc, D) in
place of the mel/conv frontend.  Encoder: bidirectional attention,
sinusoidal positions.  Decoder: causal self-attn + cross-attn, learned
positions, LayerNorm, plain-GELU MLPs.
"""
from __future__ import annotations

from typing import Any

import jax
import jax.numpy as jnp

from repro import viscosity
from repro.configs.base import ModelConfig
from repro.core.routing import as_routes
from repro.models import attention as attn_mod
from repro.models import layers as L

PyTree = Any


def _sinusoid(S, D):
    pos = jnp.arange(S, dtype=jnp.float32)[:, None]
    dim = jnp.arange(D // 2, dtype=jnp.float32)[None, :]
    ang = pos / jnp.power(10_000.0, 2 * dim / D)
    return jnp.concatenate([jnp.sin(ang), jnp.cos(ang)], -1)


class EncDecModel:
    def __init__(self, cfg: ModelConfig, routes=None):
        assert cfg.is_encdec
        self.cfg = cfg
        self.routes = as_routes(routes)
        self.compute_dtype = jnp.dtype(cfg.dtype)
        self.param_dtype = jnp.dtype(cfg.param_dtype)

    # ------------------------------------------------------------- init
    def _init_enc_layer(self, key):
        cfg = self.cfg
        dt = self.param_dtype
        k1, k2 = jax.random.split(key)
        return {
            "ln1": L.init_norm(cfg.d_model, dt, True),
            "attn": attn_mod.init_attention(k1, cfg.d_model, cfg.num_heads,
                                            cfg.num_kv_heads,
                                            cfg.resolved_head_dim, dt,
                                            qkv_bias=True),
            "ln2": L.init_norm(cfg.d_model, dt, True),
            "mlp": L.init_mlp(k2, cfg.d_model, cfg.d_ff, dt, gated=False),
        }

    def _init_dec_layer(self, key):
        cfg = self.cfg
        dt = self.param_dtype
        k1, k2, k3 = jax.random.split(key, 3)
        return {
            "ln1": L.init_norm(cfg.d_model, dt, True),
            "self_attn": attn_mod.init_attention(
                k1, cfg.d_model, cfg.num_heads, cfg.num_kv_heads,
                cfg.resolved_head_dim, dt, qkv_bias=True),
            "ln_x": L.init_norm(cfg.d_model, dt, True),
            "cross_attn": attn_mod.init_attention(
                k2, cfg.d_model, cfg.num_heads, cfg.num_kv_heads,
                cfg.resolved_head_dim, dt, qkv_bias=True),
            "ln2": L.init_norm(cfg.d_model, dt, True),
            "mlp": L.init_mlp(k3, cfg.d_model, cfg.d_ff, dt, gated=False),
        }

    def init(self, key) -> PyTree:
        cfg = self.cfg
        dt = self.param_dtype
        ks = jax.random.split(key, 5)
        enc = jax.vmap(self._init_enc_layer)(
            jax.random.split(ks[0], cfg.enc_layers))
        dec = jax.vmap(self._init_dec_layer)(
            jax.random.split(ks[1], cfg.dec_layers))
        return {
            "embed": L.init_embed(ks[2], cfg.vocab_size, cfg.d_model, dt),
            "dec_pos": (jax.random.normal(ks[3], (cfg.max_target_len,
                                                  cfg.d_model)) * 0.01
                        ).astype(dt),
            "enc": enc,
            "dec": dec,
            "enc_norm": L.init_norm(cfg.d_model, dt, True),
            "dec_norm": L.init_norm(cfg.d_model, dt, True),
        }

    # ---------------------------------------------------------- encoder
    def encode(self, params, enc_embeds):
        cfg = self.cfg
        x = enc_embeds.astype(self.compute_dtype)
        S = x.shape[1]
        x = x + _sinusoid(S, cfg.d_model).astype(x.dtype)[None]
        route = self.routes.get("flash_attention", viscosity.SW)

        def body(xx, p):
            h = L.norm(p["ln1"], xx, eps=cfg.norm_eps, layernorm=True)
            a = attn_mod.attn_full(p["attn"], h, None, None,
                                   n_heads=cfg.num_heads,
                                   n_kv=cfg.num_kv_heads,
                                   head_dim=cfg.resolved_head_dim,
                                   causal=False, route=route)
            xx = xx + a
            h = L.norm(p["ln2"], xx, eps=cfg.norm_eps, layernorm=True)
            xx = xx + L.mlp(p["mlp"], h, act="gelu_plain")
            return xx, None

        from repro.models.transformer import remat_wrap
        x, _ = jax.lax.scan(remat_wrap(cfg, body), x, params["enc"])
        return L.norm(params["enc_norm"], x, eps=cfg.norm_eps, layernorm=True)

    # ---------------------------------------------------------- decoder
    def _dec_layer(self, p, x, enc_out, *, cache=None, t=None, step=False,
                   cross=None):
        cfg = self.cfg
        route = self.routes.get("flash_attention", viscosity.SW)
        h = L.norm(p["ln1"], x, eps=cfg.norm_eps, layernorm=True)
        new_cache = cache
        if step:
            a, new_cache = attn_mod.attn_decode(
                p["self_attn"], h, cache, t, n_heads=cfg.num_heads,
                n_kv=cfg.num_kv_heads, head_dim=cfg.resolved_head_dim,
                rope_theta=0.0, route=route)
        else:
            res = attn_mod.attn_full(p["self_attn"], h, None, None,
                                     n_heads=cfg.num_heads,
                                     n_kv=cfg.num_kv_heads,
                                     head_dim=cfg.resolved_head_dim,
                                     causal=True, route=route,
                                     kv_out=cache is not None)
            if cache is not None:
                a, (k, v) = res
                new_cache = attn_mod.cache_write_prefill(cache, k, v)
            else:
                a = res
        x = x + a
        h = L.norm(p["ln_x"], x, eps=cfg.norm_eps, layernorm=True)
        # cross attention over encoder output (no positions, bidirectional);
        # serving passes precomputed per-layer cross-KV (cached at prefill)
        c = attn_mod.attn_full(p["cross_attn"], h, None, None,
                               n_heads=cfg.num_heads, n_kv=cfg.num_kv_heads,
                               head_dim=cfg.resolved_head_dim, causal=False,
                               route=route,
                               cross_kv=None if cross is not None else enc_out,
                               precomputed_kv=cross)
        x = x + c
        h = L.norm(p["ln2"], x, eps=cfg.norm_eps, layernorm=True)
        x = x + L.mlp(p["mlp"], h, act="gelu_plain")
        return x, new_cache

    def cross_kv_cache(self, params, enc_out):
        """Per-decoder-layer cross-attention K/V, computed once at prefill."""
        cfg = self.cfg

        def body(_, p):
            kv = attn_mod.project_kv(p["cross_attn"], enc_out,
                                     cfg.num_kv_heads, cfg.resolved_head_dim)
            return None, kv

        _, kvs = jax.lax.scan(body, None, params["dec"])
        return kvs

    def decode(self, params, enc_out, dec_tokens, *, caches=None, t=None,
               step=False, cross=None):
        cfg = self.cfg
        x = L.embed(params["embed"], dec_tokens,
                    compute_dtype=self.compute_dtype)
        if step:
            pe = jax.lax.dynamic_slice_in_dim(params["dec_pos"], t, 1)
            x = x + pe[None].astype(x.dtype)
        else:
            x = x + params["dec_pos"][None, :x.shape[1]].astype(x.dtype)

        def body(xx, xs):
            p, c, ckv = xs
            xx, c2 = self._dec_layer(p, xx, enc_out, cache=c, t=t, step=step,
                                     cross=ckv)
            return xx, (c2 if c is not None else jnp.float32(0))

        from repro.models.transformer import remat_wrap
        body_w = body if (step or caches is not None) else \
            remat_wrap(cfg, body)
        (x, new_caches) = jax.lax.scan(
            body_w, x, (params["dec"], caches, cross))
        x = L.norm(params["dec_norm"], x, eps=cfg.norm_eps, layernorm=True)
        return x, (new_caches if caches is not None else None)

    # ------------------------------------------------------------ modes
    def forward(self, params, batch):
        cfg = self.cfg
        enc_out = self.encode(params, batch["embeds"])
        h, _ = self.decode(params, enc_out, batch["dec_tokens"])
        loss, denom = L.chunked_xent(
            h, batch["dec_targets"], params["embed"]["table"], tied=True,
            chunk=cfg.loss_chunk, mask=batch.get("loss_mask"))
        return loss, {"xent": loss, "tokens": denom, "loss": loss}

    def logits_all(self, params, batch) -> jax.Array:
        enc_out = self.encode(params, batch["embeds"])
        h, _ = self.decode(params, enc_out, batch["dec_tokens"])
        return self._logits(params, h)

    def init_cache(self, Bt, max_len):
        cfg = self.cfg
        smax = min(max_len, cfg.max_target_len)
        def kv():
            return attn_mod.init_kv_cache(Bt, smax, cfg.num_kv_heads,
                                          cfg.resolved_head_dim,
                                          self.compute_dtype)
        return jax.tree_util.tree_map(
            lambda *xs: jnp.stack(xs),
            *[kv() for _ in range(cfg.dec_layers)])

    def prefill(self, params, batch):
        """Encode + run decoder prompt; returns (last logits, state).

        state = {"cross": per-layer cross-KV, "self": self-attn caches}.
        """
        enc_out = self.encode(params, batch["embeds"])
        cross = self.cross_kv_cache(params, enc_out)
        h, caches = self.decode(params, enc_out, batch["dec_tokens"],
                                caches=batch["cache"], cross=cross)
        logits = self._logits(params, h[:, -1:])
        return logits, {"cross": cross, "self": caches}

    def decode_step(self, params, state, tokens, t):
        h, caches = self.decode(params, None, tokens,
                                caches=state["self"], t=t, step=True,
                                cross=state["cross"])
        logits = self._logits(params, h)
        return logits, {"cross": state["cross"], "self": caches}

    def _logits(self, params, h):
        return L.logits_from_embed(params["embed"]["table"], h)
