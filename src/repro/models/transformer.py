"""Decoder-only LM assembly: train forward, prefill, decode, caches.

Covers families: dense / moe / vlm (uniform attention blocks), ssm (RWKV6),
hybrid (Zamba2: Mamba2 backbone + shared tied attention block).

Layer stacking: params are stacked (L, ...) pytrees; the forward pass scans
over *pattern groups* — the repeating layer pattern is unrolled inside the
scan body so per-layer static attributes survive jit (see blocks.py).
"""
from __future__ import annotations

from typing import Any, Dict, Tuple

import jax
import jax.numpy as jnp

from repro.configs.base import ATTN_GLOBAL, RWKV6, ModelConfig
from repro.core.routing import as_routes
from repro.models import attention as attn_mod
from repro.models import blocks as B
from repro.models import layers as L
from repro.models import mamba2 as mamba_mod
from repro.models import rope as rope_mod
from repro.models import rwkv6 as rwkv_mod

PyTree = Any


def _stack_init(fn, key, n):
    return jax.vmap(fn)(jax.random.split(key, n))


def _slice_tree(tree, idx):
    return jax.tree_util.tree_map(lambda a: a[idx], tree)


def _group_tree(tree, g, plen):
    return jax.tree_util.tree_map(
        lambda a: a[: g * plen].reshape((g, plen) + a.shape[1:]), tree)


def _tail_tree(tree, g, plen):
    return jax.tree_util.tree_map(lambda a: a[g * plen:], tree)


def _stack_layers(trees):
    return jax.tree_util.tree_map(lambda *xs: jnp.stack(xs), *trees)


def ZERO_AUX():
    return {"aux_loss": jnp.float32(0), "z_loss": jnp.float32(0),
            "drop_frac": jnp.float32(0)}


def remat_wrap(cfg, body):
    """Activation checkpointing for a scanned layer-group body.

    Policies: "full" recomputes everything (min memory);
    "collectives" saves the post-all-reduce activations (tagged
    ``checkpoint_name`` in blocks.py) so the backward recompute never
    re-runs the TP collectives — the §Perf HC-A optimization;
    "dots" saves matmul outputs (max compute savings, max memory).
    """
    if not cfg.remat or cfg.remat_policy == "none":
        return body
    if cfg.remat_policy == "dots":
        return jax.checkpoint(
            body,
            policy=jax.checkpoint_policies.dots_with_no_batch_dims_saveable)
    if cfg.remat_policy == "collectives":
        return jax.checkpoint(
            body,
            policy=jax.checkpoint_policies.save_only_these_names(
                "attn_out", "ffn_out"))
    return jax.checkpoint(body)  # "full": recompute everything


def _add_aux(a, b):
    return {k: a[k] + b[k] for k in a}


class LMModel:
    """Functional model: all methods take params explicitly.

    ``routes`` is the Oobleck RoutingPlan (stage -> lowering target); it is
    static — a new routing means a reconfiguration (recompile), exactly as
    in the paper.  The resident (hot-spare) executable instead passes a
    mapping of ResidentRoute handles built inside its trace.
    """

    def __init__(self, cfg: ModelConfig, routes=None):
        assert not cfg.is_encdec, "use encdec.EncDecModel"
        self.cfg = cfg
        self.routes = as_routes(routes)
        self.metas = B.make_metas(cfg)
        self.pattern = cfg.layer_pattern or (ATTN_GLOBAL,)
        self.plen = len(self.pattern)
        if cfg.family == "hybrid":
            self.n_groups = cfg.num_layers // cfg.shared_attn_every
            self.n_tail = cfg.num_layers % cfg.shared_attn_every
        else:
            self.n_groups = cfg.num_layers // self.plen
            self.n_tail = cfg.num_layers % self.plen
        self.compute_dtype = jnp.dtype(cfg.dtype)
        self.param_dtype = jnp.dtype(cfg.param_dtype)

    # ------------------------------------------------------------- init
    def init(self, key) -> PyTree:
        cfg = self.cfg
        dt = self.param_dtype
        ks = jax.random.split(key, 6)
        params: Dict[str, PyTree] = {
            "embed": L.init_embed(ks[0], cfg.vocab_size, cfg.d_model, dt),
            "final_norm": L.init_norm(cfg.d_model, dt, cfg.use_layernorm),
        }
        kind0 = self.metas[0].kind
        if cfg.family == "hybrid":
            def init_l(k):
                return B.init_mamba_block(k, cfg, dt)
            params["shared"] = B.init_attn_block(ks[2], cfg, dt)
        elif kind0 == RWKV6:
            def init_l(k):
                return B.init_rwkv_block(k, cfg, dt)
        else:
            def init_l(k):
                return B.init_attn_block(k, cfg, dt)
        params["layers"] = _stack_init(init_l, ks[1], cfg.num_layers)
        if not cfg.tie_embeddings:
            params["lm_head"] = L.init_lm_head(ks[3], cfg.d_model,
                                               cfg.vocab_size, dt)
        return params

    # --------------------------------------------------------- backbone
    def _ropes(self, positions, positions3=None):
        cfg = self.cfg
        hd = cfg.resolved_head_dim
        if cfg.mrope_sections:
            if positions3 is None:
                positions3 = jnp.repeat(positions[..., None], 3, axis=-1)
            cs = rope_mod.mrope_tables(positions3, hd, cfg.rope_theta,
                                       cfg.mrope_sections)
            return {"global": cs, "local": cs}
        ropes = {"global": rope_mod.rope_tables(positions, hd, cfg.rope_theta)}
        ropes["local"] = (rope_mod.rope_tables(positions, hd, cfg.rope_theta_local)
                          if cfg.rope_theta_local else ropes["global"])
        return ropes

    def _embed_in(self, params, batch):
        cfg = self.cfg
        if "embeds" in batch:  # stub modality frontend (vlm/audio)
            x = batch["embeds"].astype(self.compute_dtype)
        else:
            x = L.embed(params["embed"], batch["tokens"],
                        scale_by_dim=cfg.embed_scale,
                        compute_dtype=self.compute_dtype)
        return x

    def _logits(self, params, h):
        cfg = self.cfg
        h = L.norm(params["final_norm"], h, eps=cfg.norm_eps,
                   layernorm=cfg.use_layernorm)
        if cfg.tie_embeddings:
            return L.logits_from_embed(params["embed"]["table"], h,
                                       softcap=cfg.final_softcap)
        return L.lm_head(params["lm_head"], h, softcap=cfg.final_softcap)

    def _run_layers(self, params, x, ropes, caches=None, t=None, step=False):
        """Shared layer driver.

        Cache structure (uniform-attention & rwkv families):
          {"grp": tuple_j of stacked (G, ...) caches for pattern position j,
           "tail": tuple_j of single caches for the tail layers}
        Per-position tuples let local/global layers carry different cache
        lengths (ring buffers vs full KV) through one scan.
        """
        cfg = self.cfg
        if cfg.family == "hybrid":
            return self._run_hybrid(params, x, ropes, caches, t, step)
        plen, G, tail = self.plen, self.n_groups, self.n_tail
        metas = self.metas
        kind0 = metas[0].kind
        aux = ZERO_AUX()

        def block_j(j_meta, pj, xx, cj):
            if kind0 == RWKV6:
                xx, cj = B.rwkv_block(pj, xx, cfg, self.routes, state=cj,
                                      step=step)
                return xx, cj, ZERO_AUX()
            return B.attn_block(pj, xx, cfg, j_meta, ropes, self.routes,
                                cache=cj, t=t, step=step)

        if step:
            # decode: unroll the layers.  Scanning would carry the stacked
            # KV caches through the loop and double-buffer them (a full
            # cache copy per layer); unrolled, every update is a single
            # in-place dynamic-update-slice on the donated stacked cache.
            grp = list(caches["grp"]) if G > 0 else []
            for g in range(G):
                for j in range(plen):
                    pj = _slice_tree(params["layers"], g * plen + j)
                    if kind0 == RWKV6:
                        cj = _slice_tree(grp[j], g)
                        x, cj = B.rwkv_block(pj, x, cfg, self.routes,
                                             state=cj, step=True)
                        grp[j] = jax.tree_util.tree_map(
                            lambda full, s: full.at[g].set(s), grp[j], cj)
                    else:
                        x, grp[j], aux_j = B.attn_block(
                            pj, x, cfg, metas[j], ropes, self.routes,
                            cache=grp[j], t=t, step=True, layer=g)
                        aux = _add_aux(aux, aux_j)
            new_tail = []
            for j in range(tail):
                pj = _slice_tree(params["layers"], G * plen + j)
                x, cj, aux_j = block_j(metas[j], pj, x, caches["tail"][j])
                aux = _add_aux(aux, aux_j)
                new_tail.append(cj)
            new_caches = {"grp": tuple(grp) if G > 0 else None,
                          "tail": tuple(new_tail)}
            return x, new_caches, aux

        def group_body(carry, xs):
            xx, aux_c = carry
            p_g, c_g = xs
            new_cs = []
            for j in range(plen):
                pj = _slice_tree(p_g, j)
                cj = c_g[j] if c_g is not None else None
                xx, cj, aux_j = block_j(metas[j], pj, xx, cj)
                aux_c = _add_aux(aux_c, aux_j)
                new_cs.append(cj)
            ys = tuple(new_cs) if c_g is not None else jnp.float32(0)
            return (xx, aux_c), ys

        body = group_body if step else remat_wrap(cfg, group_body)

        new_grp = None
        if G > 0:
            p_groups = _group_tree(params["layers"], G, plen)
            c_grp = caches["grp"] if caches is not None else None
            (x, aux), ys = jax.lax.scan(body, (x, aux), (p_groups, c_grp))
            if caches is not None:
                new_grp = ys
        new_tail = []
        if tail:
            p_tail = _tail_tree(params["layers"], G, plen)
            for j in range(tail):
                pj = _slice_tree(p_tail, j)
                cj = caches["tail"][j] if caches is not None else None
                x, cj, aux_j = block_j(metas[j], pj, x, cj)
                aux = _add_aux(aux, aux_j)
                new_tail.append(cj)
        new_caches = None
        if caches is not None:
            new_caches = {"grp": new_grp, "tail": tuple(new_tail)}
        return x, new_caches, aux

    def _run_hybrid(self, params, x, ropes, caches, t, step):
        """Zamba2: groups of ``shared_attn_every`` mamba layers, each group
        followed by one application of the shared (tied) attention block."""
        cfg = self.cfg
        per = cfg.shared_attn_every
        G, tail = self.n_groups, self.n_tail
        aux = ZERO_AUX()
        meta = B.LayerMeta(kind=ATTN_GLOBAL, window=0, theta=cfg.rope_theta,
                           local=False)
        shared_p = params["shared"]
        m_caches = caches["mamba"] if caches is not None else None
        a_caches = caches["attn"] if caches is not None else None

        if step:
            # unrolled decode (see the uniform path for the rationale)
            for g in range(G):
                for j in range(per):
                    li = g * per + j
                    pj = _slice_tree(params["layers"], li)
                    cj = _slice_tree(m_caches, li)
                    x, cj = B.mamba_block(pj, x, cfg, self.routes,
                                          state=cj, step=True)
                    m_caches = jax.tree_util.tree_map(
                        lambda full, s: full.at[li].set(s), m_caches, cj)
                x, a_caches, aux_j = B.attn_block(
                    shared_p, x, cfg, meta, ropes, self.routes,
                    cache=a_caches, t=t, step=True, layer=g)
                aux = _add_aux(aux, aux_j)
            for j in range(tail):
                li = G * per + j
                pj = _slice_tree(params["layers"], li)
                cj = _slice_tree(m_caches, li)
                x, cj = B.mamba_block(pj, x, cfg, self.routes, state=cj,
                                      step=True)
                m_caches = jax.tree_util.tree_map(
                    lambda full, s: full.at[li].set(s), m_caches, cj)
            return x, {"mamba": m_caches, "attn": a_caches}, aux

        def group_body(carry, xs):
            xx, aux_c = carry
            p_g, mc_g, ac = xs
            new_ms = []
            for j in range(per):
                pj = _slice_tree(p_g, j)
                cj = _slice_tree(mc_g, j) if mc_g is not None else None
                xx, cj = B.mamba_block(pj, xx, cfg, self.routes, state=cj,
                                       step=step)
                new_ms.append(cj)
            xx, ac_new, aux_j = B.attn_block(shared_p, xx, cfg, meta, ropes,
                                             self.routes, cache=ac, t=t,
                                             step=step)
            aux_c = _add_aux(aux_c, aux_j)
            ys = (_stack_layers(new_ms) if mc_g is not None else jnp.float32(0),
                  ac_new if ac is not None else jnp.float32(0))
            return (xx, aux_c), ys

        body = group_body if step else remat_wrap(cfg, group_body)

        p_groups = _group_tree(params["layers"], G, per)
        mc_groups = _group_tree(m_caches, G, per) if caches is not None else None
        (x, aux), (new_mc, new_ac) = jax.lax.scan(
            body, (x, aux), (p_groups, mc_groups, a_caches))

        new_caches = None
        if caches is not None:
            new_m = jax.tree_util.tree_map(
                lambda a: a.reshape((G * per,) + a.shape[2:]), new_mc)
        if tail:
            p_tail = _tail_tree(params["layers"], G, per)
            mc_tail = _tail_tree(m_caches, G, per) if caches is not None else None
            tails = []
            for j in range(tail):
                pj = _slice_tree(p_tail, j)
                cj = _slice_tree(mc_tail, j) if caches is not None else None
                x, cj = B.mamba_block(pj, x, cfg, self.routes, state=cj,
                                      step=step)
                tails.append(cj)
            if caches is not None:
                new_m = jax.tree_util.tree_map(
                    lambda a, b: jnp.concatenate([a, b], 0),
                    new_m, _stack_layers(tails))
        if caches is not None:
            new_caches = {"mamba": new_m, "attn": new_ac}
        return x, new_caches, aux

    # ----------------------------------------------------------- modes
    def forward(self, params, batch) -> Tuple[jax.Array, Dict[str, jax.Array]]:
        """Training forward: returns (loss, metrics)."""
        cfg = self.cfg
        x = self._embed_in(params, batch)
        Bt, S = x.shape[:2]
        positions = rope_mod.positions_default(Bt, S)
        ropes = self._ropes(positions, batch.get("positions3"))
        x, _, aux = self._run_layers(params, x, ropes)
        h = L.norm(params["final_norm"], x, eps=cfg.norm_eps,
                   layernorm=cfg.use_layernorm)
        table = (params["embed"]["table"] if cfg.tie_embeddings
                 else params["lm_head"]["w"])
        loss, denom = L.chunked_xent(
            h, batch["targets"], table, tied=cfg.tie_embeddings,
            softcap=cfg.final_softcap, chunk=cfg.loss_chunk,
            mask=batch.get("loss_mask"))
        metrics = {"xent": loss, "tokens": denom}
        if cfg.moe is not None:
            n = max(1, cfg.num_layers)
            loss = loss + cfg.moe.aux_coef * aux["aux_loss"] / n \
                + cfg.moe.router_z_coef * aux["z_loss"] / n
            metrics.update({k: v / n for k, v in aux.items()})
        metrics["loss"] = loss
        return loss, metrics

    def logits_all(self, params, batch) -> jax.Array:
        """Full (B, S, V) teacher-forced logits (tests / tiny models only)."""
        x = self._embed_in(params, batch)
        Bt, S = x.shape[:2]
        positions = rope_mod.positions_default(Bt, S)
        ropes = self._ropes(positions, batch.get("positions3"))
        x, _, _ = self._run_layers(params, x, ropes)
        return self._logits(params, x)

    def init_cache(self, Bt: int, max_len: int) -> PyTree:
        cfg = self.cfg
        dt = self.compute_dtype
        hd = cfg.resolved_head_dim

        def kv(smax):
            return attn_mod.init_kv_cache(Bt, smax, cfg.num_kv_heads, hd, dt)

        def smax_for(window):
            return min(max_len, window) if window else max_len

        if cfg.family == "hybrid":
            m = _stack_layers([mamba_mod.init_mamba2_state(Bt, cfg, dt)
                               for _ in range(cfg.num_layers)])
            a = _stack_layers([kv(smax_for(0))
                               for _ in range(self.n_groups)])
            return {"mamba": m, "attn": a}
        G, tail, plen = self.n_groups, self.n_tail, self.plen
        if self.metas[0].kind == RWKV6:
            def mk(j):
                return rwkv_mod.init_rwkv6_state(Bt, cfg, dt)
        else:
            def mk(j):
                return kv(smax_for(self.metas[j].window))
        grp = (tuple(_stack_layers([mk(j) for _ in range(G)])
                     for j in range(plen)) if G > 0 else None)
        return {"grp": grp, "tail": tuple(mk(j) for j in range(tail))}

    def prefill(self, params, batch) -> Tuple[jax.Array, PyTree]:
        """Prefill: runs the full prompt, returns (last-token logits, cache).

        The cache must be passed in ``batch['cache']`` (pre-allocated to the
        serving max length) so shardings are explicit at the jit boundary.
        """
        x = self._embed_in(params, batch)
        Bt, S = x.shape[:2]
        positions = rope_mod.positions_default(Bt, S)
        ropes = self._ropes(positions, batch.get("positions3"))
        x, caches, _ = self._run_layers(params, x, ropes,
                                        caches=batch["cache"])
        logits = self._logits(params, x[:, -1:])
        return logits, caches

    def decode_step(self, params, cache, tokens, t) -> Tuple[jax.Array, PyTree]:
        """One token: tokens (B, 1), t scalar int32 absolute position."""
        x = self._embed_in(params, {"tokens": tokens})
        ropes = None  # decode blocks compute their own tables from t
        x, caches, _ = self._run_layers(params, x, ropes, caches=cache,
                                        t=t, step=True)
        logits = self._logits(params, x)
        return logits, caches
