"""Shared layers: norms, embeddings, gated MLP (via the SwiGLU stage).

All functions are functional: ``f(params, x, ...)`` with params as nested
dicts.  Logical-axis sharding constraints are applied through
``repro.launch.sharding.constrain`` (no-op outside a mesh context).
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from repro import viscosity
from repro.kernels.swiglu import ops as swiglu_ops
from repro.launch.sharding import constrain


def _he(key, shape, fan_in, dtype):
    return (jax.random.normal(key, shape) / jnp.sqrt(fan_in)).astype(dtype)


# ---------------------------------------------------------------- norms
def init_norm(d, dtype, layernorm=False):
    p = {"scale": jnp.ones((d,), dtype)}
    if layernorm:
        p["bias"] = jnp.zeros((d,), dtype)
    return p


def norm(p, x, *, eps=1e-6, layernorm=False):
    xf = x.astype(jnp.float32)
    if layernorm:
        mu = jnp.mean(xf, -1, keepdims=True)
        xf = xf - mu
    var = jnp.mean(jnp.square(xf), -1, keepdims=True)
    y = xf * jax.lax.rsqrt(var + eps)
    y = y * p["scale"].astype(jnp.float32)
    if "bias" in p:
        y = y + p["bias"].astype(jnp.float32)
    return y.astype(x.dtype)


def rms_norm_simple(x, *, eps=1e-6):
    xf = x.astype(jnp.float32)
    var = jnp.mean(jnp.square(xf), -1, keepdims=True)
    return (xf * jax.lax.rsqrt(var + eps)).astype(x.dtype)


# ------------------------------------------------------------ embeddings
def init_embed(key, vocab, d, dtype):
    return {"table": (jax.random.normal(key, (vocab, d)) * 0.02).astype(dtype)}


def embed(p, tokens, *, scale_by_dim=False, compute_dtype=jnp.bfloat16):
    x = jnp.take(p["table"], tokens, axis=0).astype(compute_dtype)
    if scale_by_dim:
        x = x * jnp.sqrt(jnp.array(p["table"].shape[1], compute_dtype))
    return constrain(x, "batch", "seq", "embed")


def logits_from_embed(table, x, *, softcap=0.0):
    out = jnp.einsum("...d,vd->...v", x, table.astype(x.dtype))
    if softcap:
        out = jnp.tanh(out / softcap) * softcap
    return constrain(out, "batch", "seq", "vocab")


def init_lm_head(key, d, vocab, dtype):
    return {"w": _he(key, (d, vocab), d, dtype)}


def lm_head(p, x, *, softcap=0.0):
    out = jnp.einsum("...d,dv->...v", x, p["w"].astype(x.dtype))
    if softcap:
        out = jnp.tanh(out / softcap) * softcap
    return constrain(out, "batch", "seq", "vocab")


# -------------------------------------------------------------- gated MLP
def init_mlp(key, d, f, dtype, *, gated=True):
    k1, k2, k3 = jax.random.split(key, 3)
    p = {"w1": _he(k1, (d, f), d, dtype), "w2": _he(k2, (f, d), f, dtype)}
    if gated:
        p["w3"] = _he(k3, (d, f), d, dtype)
    return p


def mlp(p, x, *, act="silu", route=viscosity.SW):
    """Gated MLP through the Viscosity SwiGLU stage; plain MLP otherwise."""
    if "w3" in p:
        cd = x.dtype
        lead = x.shape[:-1]
        act_name = "gelu" if act in ("gelu", "gelu_plain") else "silu"
        y = swiglu_ops.swiglu(
            x.reshape(-1, x.shape[-1]),
            p["w1"].astype(cd), p["w3"].astype(cd), p["w2"].astype(cd),
            act=act_name, route=route)
        y = y.reshape(*lead, -1)
    else:
        h = jnp.einsum("...d,df->...f", x, p["w1"].astype(x.dtype))
        h = jax.nn.gelu(h, approximate=True) if act.startswith("gelu") \
            else jax.nn.silu(h)
        h = constrain(h, "batch", "seq", "mlp")
        y = jnp.einsum("...f,fd->...d", h, p["w2"].astype(x.dtype))
    return constrain(y, "batch", "seq", "embed")


# -------------------------------------------------- chunked cross-entropy
def chunked_xent(h, targets, table_or_w, *, tied: bool, softcap=0.0,
                 chunk=512, mask=None):
    """Cross-entropy without materializing full (B,S,V) logits.

    h (B,S,D) final hidden; targets (B,S) int32; returns (mean_loss, denom).
    """
    B, S, D = h.shape
    C = min(chunk, S)
    if S % C:
        pad = C - S % C
        h = jnp.pad(h, ((0, 0), (0, pad), (0, 0)))
        targets = jnp.pad(targets, ((0, 0), (0, pad)), constant_values=-1)
        if mask is not None:
            mask = jnp.pad(mask, ((0, 0), (0, pad)))
    Sp = h.shape[1]
    nc = Sp // C
    hc = h.reshape(B, nc, C, D).transpose(1, 0, 2, 3)
    tc = targets.reshape(B, nc, C).transpose(1, 0, 2)
    mc = (mask.reshape(B, nc, C).transpose(1, 0, 2) if mask is not None
          else (tc >= 0))

    def body(carry, xs):
        tot, cnt = carry
        hh, tt, mm = xs
        if tied:
            logits = jnp.einsum("bcd,vd->bcv", hh,
                                table_or_w.astype(hh.dtype))
        else:
            logits = jnp.einsum("bcd,dv->bcv", hh,
                                table_or_w.astype(hh.dtype))
        if softcap:
            logits = jnp.tanh(logits / softcap) * softcap
        logits = logits.astype(jnp.float32)
        lse = jax.nn.logsumexp(logits, axis=-1)
        tgt = jnp.take_along_axis(
            logits, jnp.clip(tt, 0)[..., None], axis=-1)[..., 0]
        nll = (lse - tgt) * mm.astype(jnp.float32)
        return (tot + jnp.sum(nll), cnt + jnp.sum(mm.astype(jnp.float32))), None

    (tot, cnt), _ = jax.lax.scan(body, (jnp.float32(0), jnp.float32(0)),
                                 (hc, tc, mc))
    return tot / jnp.maximum(cnt, 1.0), cnt
