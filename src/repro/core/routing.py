"""RoutingPlan: the unified routing IR (paper §III queue configuration).

Every layer of the stack used to carry its own ad-hoc ``stage -> "hw"/"sw"``
string dict and re-interpret it locally (viscosity, stage, oobleck, models,
train, serve each had a private translation shim).  ``RoutingPlan`` replaces
all of them with one first-class object:

  * a **hashable, frozen per-stage mapping** ``stage -> lowering target``
    (targets are the Viscosity lowerings: HW / SW / INTERPRET) — hashable so
    it keys ``Dispatcher`` compile caches directly (the paper's "one
    executable per queue configuration");
  * **explicit fallback semantics**: a stage whose HW lowering does not
    exist resolves to its SW oracle (``resolve``), and stages absent from
    the plan fall back to ``default`` (or the call site's default when
    ``default`` is None) — never an implicit re-interpretation;
  * **derivation from fault state**: ``from_signature`` maps a
    ``FaultSignature`` (healthy/faulty bits) to targets — healthy stages
    get the deployment's optimized target, quarantined stages their
    fallback;
  * **validation against the registry** (``validate``): unknown targets and
    unknown stage names fail loudly at plan-construction time, not deep
    inside a trace;
  * **resident lowering** (the paper's hot-spare mode): ``resident_routes``
    turns a plan plus a traced ``health_mask`` into per-stage
    ``ResidentRoute`` handles — both lowerings live in one executable
    behind ``lax.cond``; failover is flipping one input bit, no recompile.

The plan is *static* per compilation: changing a route is a reconfiguration
(a new plan, a new cache key, one recompile), exactly mirroring the paper's
per-sub-accelerator queue (re)configuration.
"""
from __future__ import annotations

from dataclasses import dataclass
from typing import Any, Callable, Dict, Iterable, Mapping, Optional, Sequence, Tuple

from repro.viscosity import lanefault
from repro.viscosity.lang import (DEGRADED_TARGETS, HW, INTERPRET, SW)

# Every target a plan may assign: the three Viscosity lowerings plus the
# DEGRADED route family (partial degradation; requires a localized lane map
# — ``validate`` enforces that).
TARGETS = (HW, SW, INTERPRET) + DEGRADED_TARGETS


@dataclass(frozen=True)
class RoutingPlan:
    """Frozen, hashable ``stage -> lowering target`` mapping.

    ``assignments`` is kept sorted so equal mappings are equal plans (and
    hash equal — two FaultSignatures that induce the same routing share one
    compiled executable).  ``default`` is the target for stages not listed;
    None defers to the consumer's own default (models fall back to SW).
    """

    assignments: Tuple[Tuple[str, str], ...] = ()
    default: Optional[str] = None

    def __post_init__(self):
        object.__setattr__(self, "assignments",
                           tuple(sorted(dict(self.assignments).items())))
        for stage, target in self.assignments:
            if target not in TARGETS:
                raise ValueError(
                    f"unknown lowering target {target!r} for stage "
                    f"{stage!r}; expected one of {TARGETS}")
        if self.default is not None and self.default not in TARGETS:
            raise ValueError(f"unknown default target {self.default!r}")

    # ------------------------------------------------------- constructors
    @staticmethod
    def make(mapping: Mapping[str, str],
             default: Optional[str] = None) -> "RoutingPlan":
        return RoutingPlan(tuple(mapping.items()), default)

    @staticmethod
    def for_stages(stage_names: Sequence[str], target: str = HW,
                   default: Optional[str] = None) -> "RoutingPlan":
        return RoutingPlan(tuple((s, target) for s in stage_names), default)

    @staticmethod
    def from_signature(signature, healthy: str = HW, fallback: str = SW,
                       default: Optional[str] = None) -> "RoutingPlan":
        """Derive a plan from a FaultSignature (duck-typed: anything with a
        ``.routes`` tuple of (stage, HW-or-not) pairs).

        Healthy stages are assigned ``healthy`` (the deployment's optimized
        target — HW on TPU, SW/INTERPRET on CPU hosts); quarantined stages
        are assigned ``fallback``.
        """
        return RoutingPlan(
            tuple((s, healthy if r == HW else fallback)
                  for s, r in signature.routes), default)

    # ------------------------------------------------------------ queries
    def as_dict(self) -> Dict[str, str]:
        return dict(self.assignments)

    def stages(self) -> Tuple[str, ...]:
        return tuple(s for s, _ in self.assignments)

    def target_for(self, stage: str) -> str:
        """The lowering target for ``stage``; KeyError when the plan has no
        entry and no default (a complete plan is the caller's contract)."""
        for s, t in self.assignments:
            if s == stage:
                return t
        if self.default is not None:
            return self.default
        raise KeyError(f"stage {stage!r} not in routing plan "
                       f"{self.stages()} (and no default target)")

    def get(self, stage: str, fallback: Optional[str] = None):
        """dict-compatible lookup (models consult routes via ``.get``)."""
        for s, t in self.assignments:
            if s == stage:
                return t
        return self.default if self.default is not None else fallback

    def fallback_stages(self, fallback: str = SW) -> Tuple[str, ...]:
        return tuple(s for s, t in self.assignments if t == fallback)

    # ------------------------------------------------------------ updates
    def with_target(self, stage: str, target: str) -> "RoutingPlan":
        d = self.as_dict()
        d[stage] = target
        return RoutingPlan(tuple(d.items()), self.default)

    def with_fault(self, stage: str, fallback: str = SW) -> "RoutingPlan":
        """Quarantine one stage: route it through its fallback lowering."""
        return self.with_target(stage, fallback)

    # --------------------------------------------------------- validation
    def validate(self, *, registry=None,
                 stages: Optional[Iterable[str]] = None) -> "RoutingPlan":
        """Check the plan against the Viscosity registry and/or an explicit
        stage universe; returns self so call sites can chain."""
        known = set(stages) if stages is not None else None
        for stage, target in self.assignments:
            if registry is not None and known is None and stage not in registry:
                raise ValueError(
                    f"routing plan names unknown viscosity op {stage!r}; "
                    f"registered: {registry.names()}")
            if known is not None and stage not in known:
                raise ValueError(
                    f"routing plan names unknown stage {stage!r}; "
                    f"known: {sorted(known)}")
            if (target in DEGRADED_TARGETS
                    and lanefault.fault_map(stage) is None):
                raise ValueError(
                    f"stage {stage!r} routed to {target!r} but no lane map "
                    "is registered; detection must localize the fault first "
                    "(lanefault.set_map / known_map)")
        return self

    # ----------------------------------------------------- lowering hooks
    def resolve(self, spec) -> Callable[..., Any]:
        """Lower one OpSpec under this plan (explicit fallback semantics:
        an HW target with no kernel resolves to the SW oracle)."""
        return spec.lower(self.target_for(spec.name))

    def resident_routes(self, health_mask, stage_names: Sequence[str]
                        ) -> Dict[str, "ResidentRoute"]:
        """Per-stage resident route handles for the hot-spare executable.

        ``health_mask`` is a traced ``(len(stage_names),)`` bool array;
        bit i selects stage i's planned target (healthy) vs its SW oracle
        (quarantined) at *runtime* — both paths are resident in the program.
        """
        return {s: ResidentRoute(hw=self.target_for(s), healthy=health_mask[i])
                for i, s in enumerate(stage_names)}


@dataclass
class ResidentRoute:
    """Runtime route handle: the paper's hot-spare residency, per stage.

    Unlike a plan target (a static string baked into the trace), a
    ResidentRoute carries a traced health bit; ``select`` lowers an OpSpec
    to ``lax.cond(healthy, optimized, oracle)`` so failover never
    recompiles.  Not hashable on purpose — it lives inside a traced
    function, never in a Dispatcher cache key (the enclosing executable is
    keyed by the static RoutingPlan it was derived from).
    """

    hw: str                 # target selected while the stage is healthy
    healthy: Any            # scalar bool (typically a tracer)

    def select(self, spec) -> Callable[..., Any]:
        import jax

        hw_fn = spec.lower(self.hw)
        sw_fn = spec.ref
        if hw_fn is sw_fn:      # plan already routes software: nothing to cond
            return sw_fn
        healthy = self.healthy

        def resident(*args, **kw):
            return jax.lax.cond(healthy,
                                lambda ops: hw_fn(*ops, **kw),
                                lambda ops: sw_fn(*ops, **kw),
                                args)
        return resident


# --------------------------------------------------------------------------
# Fleet layer: device-indexed plans + hot-spare pool (paper §II Fig. 2,
# §V Fig. 8).  A FleetPlan lifts RoutingPlan from "one plan per process" to
# a frozen device_index -> RoutingPlan table with explicit spare semantics:
# a faulted device's work migrates to a hot spare *before* any stage drops
# to its SW oracle; only once spares are exhausted does a device degrade in
# place (per-stage SW fallback), and at device death with no spare left its
# capacity is simply lost.  All transitions are pure (each returns a new
# FleetPlan), so fleet health history is a value, exactly like RoutingPlan.
# --------------------------------------------------------------------------


@dataclass(frozen=True)
class SparePool:
    """Hot-spare bookkeeping (paper Fig. 8 semantics).

    ``spares`` is the reserved device-index pool; ``assignments`` maps each
    migrated-away device to the spare now carrying its traffic.  Invariant:
    no spare ever serves two devices (each target appears at most once).
    """

    spares: Tuple[int, ...] = ()
    assignments: Tuple[Tuple[int, int], ...] = ()

    def __post_init__(self):
        object.__setattr__(self, "spares", tuple(sorted(set(self.spares))))
        object.__setattr__(self, "assignments",
                           tuple(sorted(self.assignments)))
        targets = [s for _, s in self.assignments]
        if len(set(targets)) != len(targets):
            raise ValueError(
                f"spare pool maps two devices to one spare: {self.assignments}")
        sources = [d for d, _ in self.assignments]
        if len(set(sources)) != len(sources):
            raise ValueError(
                f"device migrated to two spares: {self.assignments}")
        for _, s in self.assignments:
            if s not in self.spares:
                raise ValueError(f"assignment target {s} is not in the spare "
                                 f"pool {self.spares}")

    # ------------------------------------------------------------ queries
    def free(self) -> Tuple[int, ...]:
        """Spares not yet carrying anyone's traffic (lowest index first)."""
        used = {s for _, s in self.assignments}
        return tuple(s for s in self.spares if s not in used)

    def in_service(self) -> Tuple[int, ...]:
        """Spares currently carrying a migrated device's traffic."""
        return tuple(s for _, s in self.assignments)

    def spare_for(self, device: int) -> Optional[int]:
        for d, s in self.assignments:
            if d == device:
                return s
        return None

    # ------------------------------------------------------- transitions
    def assign(self, device: int, exclude: Sequence[int] = ()
               ) -> Tuple["SparePool", Optional[int]]:
        """Claim the lowest free spare for ``device``; (self, None) when the
        pool is exhausted.  ``exclude`` holds spares that must not be handed
        out (quarantined spares released back by a recovery)."""
        free = tuple(s for s in self.free() if s not in exclude)
        if not free:
            return self, None
        spare = free[0]
        return SparePool(self.spares,
                         self.assignments + ((device, spare),)), spare

    def release(self, device: int) -> "SparePool":
        """Return ``device``'s spare to the pool (fault-then-recover)."""
        return SparePool(self.spares, tuple((d, s) for d, s in
                                            self.assignments if d != device))


def _plan_sort_key(plan: RoutingPlan):
    return (plan.assignments, plan.default or "")


@dataclass(frozen=True)
class FleetPlan:
    """Frozen, hashable ``device_index -> RoutingPlan`` table + spare pool.

    ``plans[i]`` is the routing plan device ``i`` runs *when serving*;
    ``pool`` carries the hot spares; ``quarantined`` lists devices out of
    service (migrated away or dead).  A device is **serving** iff it is not
    quarantined and not an idle spare.  Equality/hash are exact-table (two
    identical fleet histories are one value); ``compile_key()`` is the
    *multiset* of serving plans — the Dispatcher key — so two fleets whose
    devices route the same way (in any device order) share executables.
    """

    plans: Tuple[RoutingPlan, ...] = ()
    pool: SparePool = SparePool()
    quarantined: Tuple[int, ...] = ()
    # Physical faults accumulated per device — independent of the route
    # strings (with hw_route=SW a faulted stage's target does not change,
    # but the silicon is still degraded and the capacity model must know).
    fault_counts: Tuple[int, ...] = ()
    # Per-(device, stage) fault counts: the index into the degradation
    # ladder (fault 1 -> remap, 2 -> reduced width, >=3 -> SW oracle).
    # Sparse — only nonzero entries are stored.
    stage_faults: Tuple[Tuple[Tuple[int, str], int], ...] = ()

    def __post_init__(self):
        object.__setattr__(self, "plans", tuple(self.plans))
        object.__setattr__(self, "quarantined",
                           tuple(sorted(set(self.quarantined))))
        n = len(self.plans)
        if not self.fault_counts:
            object.__setattr__(self, "fault_counts", (0,) * n)
        else:
            object.__setattr__(self, "fault_counts",
                               tuple(self.fault_counts))
        if len(self.fault_counts) != n:
            raise ValueError(f"fault_counts has {len(self.fault_counts)} "
                             f"entries for a {n}-device fleet")
        sf = {tuple(k): int(v) for k, v in self.stage_faults if int(v) > 0}
        object.__setattr__(self, "stage_faults", tuple(sorted(sf.items())))
        for (d, _stage), _v in self.stage_faults:
            if not 0 <= d < n:
                raise ValueError(f"stage_faults device index {d} out of "
                                 f"range for a {n}-device fleet")
        for p in self.plans:
            if not isinstance(p, RoutingPlan):
                raise TypeError(f"FleetPlan entries must be RoutingPlans; "
                                f"got {type(p)!r}")
        for d in self.quarantined + self.pool.spares:
            if not 0 <= d < n:
                raise ValueError(f"device index {d} out of range for a "
                                 f"{n}-device fleet")

    # ------------------------------------------------------- constructors
    @staticmethod
    def healthy(n_devices: int, stage_names: Sequence[str], *,
                target: str = HW, n_spares: int = 0,
                default: Optional[str] = None) -> "FleetPlan":
        """All-healthy fleet; the last ``n_spares`` devices are the hot-
        spare pool (idle until a worker faults)."""
        if n_spares >= n_devices:
            raise ValueError(f"fleet of {n_devices} cannot reserve "
                             f"{n_spares} spares")
        plan = RoutingPlan.for_stages(stage_names, target=target,
                                      default=default)
        return FleetPlan(plans=(plan,) * n_devices,
                         pool=SparePool(tuple(range(n_devices - n_spares,
                                                    n_devices))))

    # ------------------------------------------------------------ queries
    @property
    def n_devices(self) -> int:
        return len(self.plans)

    def serving(self) -> Tuple[int, ...]:
        """Devices currently taking traffic: active workers + in-service
        spares, minus everything quarantined."""
        idle = set(self.pool.free())
        quarantined = set(self.quarantined)
        return tuple(d for d in range(self.n_devices)
                     if d not in idle and d not in quarantined)

    def device_mask(self) -> Tuple[bool, ...]:
        """Explicit health mask over *all* devices (True = serving) — the
        view launch/mesh.py and sharding.py consume."""
        serving = set(self.serving())
        return tuple(d in serving for d in range(self.n_devices))

    def plan_for(self, device: int) -> RoutingPlan:
        """The RoutingPlan ``device`` consults; KeyError when it is not
        serving (quarantined or an idle spare)."""
        if device not in self.serving():
            raise KeyError(f"device {device} is not serving (quarantined="
                           f"{self.quarantined}, idle spares="
                           f"{self.pool.free()})")
        return self.plans[device]

    def n_faults(self, device: int) -> int:
        """Physical faults device ``device`` has accumulated — the index
        into the VFA degradation curve (route-string independent)."""
        return self.fault_counts[device]

    def stage_fault_count(self, device: int, stage: str) -> int:
        """Faults accumulated on one (device, stage) — the degradation-
        ladder rung index for that stage."""
        for key, v in self.stage_faults:
            if key == (device, stage):
                return v
        return 0

    def compile_key(self) -> Tuple[Tuple[Tuple[str, str], ...], ...]:
        """Multiset (sorted tuple) of serving plans: the Dispatcher cache
        key.  Two fleets with the same per-device routing multiset share
        one compiled-executable set regardless of device numbering."""
        return tuple(tuple(_plan_sort_key(self.plans[d]))
                     for d in sorted(self.serving(),
                                     key=lambda d: _plan_sort_key(
                                         self.plans[d])))

    # ------------------------------------------------------- transitions
    def _set_plan(self, device: int, plan: RoutingPlan
                  ) -> Tuple[RoutingPlan, ...]:
        return self.plans[:device] + (plan,) + self.plans[device + 1:]

    def _bump(self, device: int) -> Tuple[int, ...]:
        return (self.fault_counts[:device]
                + (self.fault_counts[device] + 1,)
                + self.fault_counts[device + 1:])

    def _bump_stage(self, device: int, stage: str
                    ) -> Tuple[Tuple[Tuple[int, str], int], ...]:
        sf = dict(self.stage_faults)
        key = (device, stage)
        sf[key] = sf.get(key, 0) + 1
        return tuple(sorted(sf.items()))

    def with_stage_fault(self, device: int, stage: str,
                         fallback: str = SW) -> "FleetPlan":
        """One stage of ``device`` faults.  Paper Fig. 8 semantics: migrate
        the device's work to a free hot spare first; only with the pool
        exhausted does the stage degrade in place.  In-place degradation
        walks the ladder when detection has localized a lane map for the
        stage (fault 1 -> DEGRADED remap, 2 -> reduced width, >=3 -> the
        SW oracle); without a map it drops straight to ``fallback``."""
        if device not in self.serving():
            raise ValueError(f"device {device} is not serving; cannot fault "
                             f"stage {stage!r} there")
        n = self.stage_fault_count(device, stage) + 1
        if lanefault.fault_map(stage) is not None:
            fb = lanefault.rung_for(n)
        else:
            fb = fallback
        pool, spare = self.pool.assign(device, exclude=self.quarantined)
        plans = self._set_plan(device,
                               self.plans[device].with_fault(stage, fb))
        counts = self._bump(device)
        sfaults = self._bump_stage(device, stage)
        if spare is not None:
            return FleetPlan(plans=plans, pool=pool,
                             quarantined=self.quarantined + (device,),
                             fault_counts=counts, stage_faults=sfaults)
        return FleetPlan(plans=plans, pool=self.pool,
                         quarantined=self.quarantined, fault_counts=counts,
                         stage_faults=sfaults)

    def with_device_fault(self, device: int, *,
                          exclude: Sequence[int] = ()) -> "FleetPlan":
        """Whole-device loss: migrate to a spare when one is free,
        otherwise the device's capacity is simply gone.  ``exclude``
        holds spares that must not take the work (devices dying in the
        same transition — a host loss must not migrate onto the dying
        host's own spares)."""
        if device not in self.serving():
            raise ValueError(f"device {device} is not serving; cannot fail "
                             f"it")
        pool, _spare = self.pool.assign(
            device, exclude=tuple(self.quarantined) + tuple(exclude))
        return FleetPlan(plans=self.plans, pool=pool,
                         quarantined=self.quarantined + (device,),
                         fault_counts=self._bump(device),
                         stage_faults=self.stage_faults)

    def with_host_fault(self, devices: Sequence[int]) -> "FleetPlan":
        """A whole host drops out: every serving device in ``devices``
        quarantines in ONE transition (the multi-host runtime's host-loss
        event).  Each migrates to a free hot spare *outside* the dying
        block when one exists; the block's own idle spares leave the pool
        (they are unreachable hardware, not capacity)."""
        devices = tuple(sorted(set(devices)))
        for d in devices:
            if not 0 <= d < self.n_devices:
                raise ValueError(f"device index {d} out of range for a "
                                 f"{self.n_devices}-device fleet")
        fp = self
        for d in devices:
            if d in fp.serving():
                fp = fp.with_device_fault(d, exclude=devices)
        lost_idle = tuple(s for s in fp.pool.free() if s in devices)
        if lost_idle:
            pool = SparePool(tuple(s for s in fp.pool.spares
                                   if s not in lost_idle),
                             fp.pool.assignments)
            fp = FleetPlan(plans=fp.plans, pool=pool,
                           quarantined=fp.quarantined + lost_idle,
                           fault_counts=fp.fault_counts,
                           stage_faults=fp.stage_faults)
        return fp

    def with_recovery(self, device: int, stage_names: Sequence[str], *,
                      target: str = HW) -> "FleetPlan":
        """Repaired device rejoins healthy; its spare (if any) drains back
        to the idle pool.  Covers both quarantined devices and devices
        degraded in place (stage faults riding the degradation ladder
        with no quarantine — their serve capacity recovers too)."""
        degraded = (self.fault_counts[device] > 0
                    or any(k[0] == device for k, _ in self.stage_faults))
        if device not in self.quarantined and not degraded:
            raise ValueError(f"device {device} is neither quarantined nor "
                             f"degraded; nothing to recover")
        plans = self._set_plan(
            device, RoutingPlan.for_stages(stage_names, target=target,
                                           default=self.plans[device].default))
        counts = (self.fault_counts[:device] + (0,)
                  + self.fault_counts[device + 1:])
        sfaults = tuple((k, v) for k, v in self.stage_faults
                        if k[0] != device)
        return FleetPlan(plans=plans, pool=self.pool.release(device),
                         quarantined=tuple(d for d in self.quarantined
                                           if d != device),
                         fault_counts=counts, stage_faults=sfaults)

    def with_stage_recovery(self, device: int, stage: str, *,
                            target: str = HW) -> "FleetPlan":
        """Undo exactly one ``with_stage_fault`` on (device, stage): the
        probation verdict came back transient, so the detection that walked
        the ladder steps back up one rung.  At count 0 the stage's route
        restores to ``target`` (the HW path — the hardware probed clean);
        with residual faults and a localized lane map it re-lands on
        ``rung_for(n-1)``.  A device quarantined by that fault returns to
        service and releases its spare; other devices' and stages' faults
        are untouched (contrast ``with_recovery``, the full-device repair).
        """
        n = self.stage_fault_count(device, stage)
        if n < 1:
            raise ValueError(f"device {device} has no fault on stage "
                             f"{stage!r}; nothing to recover")
        sf = dict(self.stage_faults)
        key = (device, stage)
        if n == 1:
            sf.pop(key, None)
        else:
            sf[key] = n - 1
        counts = (self.fault_counts[:device]
                  + (max(0, self.fault_counts[device] - 1),)
                  + self.fault_counts[device + 1:])
        if n == 1:
            route = target
        elif lanefault.fault_map(stage) is not None:
            route = lanefault.rung_for(n - 1)
        else:
            route = self.plans[device].get(stage, target)
        plans = self._set_plan(device,
                               self.plans[device].with_target(stage, route))
        if device in self.quarantined:
            return FleetPlan(plans=plans, pool=self.pool.release(device),
                             quarantined=tuple(d for d in self.quarantined
                                               if d != device),
                             fault_counts=counts,
                             stage_faults=tuple(sorted(sf.items())))
        return FleetPlan(plans=plans, pool=self.pool,
                         quarantined=self.quarantined, fault_counts=counts,
                         stage_faults=tuple(sorted(sf.items())))

    # --------------------------------------------------------- validation
    def validate(self, *, registry=None,
                 stages: Optional[Iterable[str]] = None) -> "FleetPlan":
        for p in self.plans:
            p.validate(registry=registry, stages=stages)
        return self


def rung_occupancy(fleet: "FleetPlan") -> Dict[str, int]:
    """Degradation-ladder occupancy of a fleet, for the
    ``fleet_rung_devices`` telemetry gauge: per routing target, the
    number of serving (device, stage) assignments routed there, plus
    device-granular ``quarantined`` / ``spare`` counts.  Standard rungs
    are always present (zeroed) so gauge updates overwrite stale
    values."""
    occ: Dict[str, int] = {t: 0 for t in
                           (HW, INTERPRET, SW) + DEGRADED_TARGETS}
    for d in fleet.serving():
        plan = fleet.plans[d]
        for _stage, target in plan.assignments:
            occ[target] = occ.get(target, 0) + 1
    occ["quarantined"] = len(fleet.quarantined)
    occ["spare"] = len(fleet.pool.free())
    return occ


def as_routes(routes) -> Any:
    """Normalize a build_model ``routes`` argument.

    Accepts None (empty plan: every stage uses the consumer default),
    a RoutingPlan, or a plain dict of targets / ResidentRoute handles
    (the resident executable builds the dict inside its trace).  Anything
    with a ``.get`` is returned as-is; models only ever call ``.get``.
    """
    if routes is None:
        return RoutingPlan()
    if hasattr(routes, "get"):
        return routes
    raise TypeError(f"routes must be None, a RoutingPlan, or a mapping; "
                    f"got {type(routes)!r}")
