"""RoutingPlan: the unified routing IR (paper §III queue configuration).

Every layer of the stack used to carry its own ad-hoc ``stage -> "hw"/"sw"``
string dict and re-interpret it locally (viscosity, stage, oobleck, models,
train, serve each had a private translation shim).  ``RoutingPlan`` replaces
all of them with one first-class object:

  * a **hashable, frozen per-stage mapping** ``stage -> lowering target``
    (targets are the Viscosity lowerings: HW / SW / INTERPRET) — hashable so
    it keys ``Dispatcher`` compile caches directly (the paper's "one
    executable per queue configuration");
  * **explicit fallback semantics**: a stage whose HW lowering does not
    exist resolves to its SW oracle (``resolve``), and stages absent from
    the plan fall back to ``default`` (or the call site's default when
    ``default`` is None) — never an implicit re-interpretation;
  * **derivation from fault state**: ``from_signature`` maps a
    ``FaultSignature`` (healthy/faulty bits) to targets — healthy stages
    get the deployment's optimized target, quarantined stages their
    fallback;
  * **validation against the registry** (``validate``): unknown targets and
    unknown stage names fail loudly at plan-construction time, not deep
    inside a trace;
  * **resident lowering** (the paper's hot-spare mode): ``resident_routes``
    turns a plan plus a traced ``health_mask`` into per-stage
    ``ResidentRoute`` handles — both lowerings live in one executable
    behind ``lax.cond``; failover is flipping one input bit, no recompile.

The plan is *static* per compilation: changing a route is a reconfiguration
(a new plan, a new cache key, one recompile), exactly mirroring the paper's
per-sub-accelerator queue (re)configuration.
"""
from __future__ import annotations

from dataclasses import dataclass
from typing import Any, Callable, Dict, Iterable, Mapping, Optional, Sequence, Tuple

from repro.viscosity.lang import HW, INTERPRET, SW

# Every target a plan may assign (the three Viscosity lowerings).
TARGETS = (HW, SW, INTERPRET)


@dataclass(frozen=True)
class RoutingPlan:
    """Frozen, hashable ``stage -> lowering target`` mapping.

    ``assignments`` is kept sorted so equal mappings are equal plans (and
    hash equal — two FaultSignatures that induce the same routing share one
    compiled executable).  ``default`` is the target for stages not listed;
    None defers to the consumer's own default (models fall back to SW).
    """

    assignments: Tuple[Tuple[str, str], ...] = ()
    default: Optional[str] = None

    def __post_init__(self):
        object.__setattr__(self, "assignments",
                           tuple(sorted(dict(self.assignments).items())))
        for stage, target in self.assignments:
            if target not in TARGETS:
                raise ValueError(
                    f"unknown lowering target {target!r} for stage "
                    f"{stage!r}; expected one of {TARGETS}")
        if self.default is not None and self.default not in TARGETS:
            raise ValueError(f"unknown default target {self.default!r}")

    # ------------------------------------------------------- constructors
    @staticmethod
    def make(mapping: Mapping[str, str],
             default: Optional[str] = None) -> "RoutingPlan":
        return RoutingPlan(tuple(mapping.items()), default)

    @staticmethod
    def for_stages(stage_names: Sequence[str], target: str = HW,
                   default: Optional[str] = None) -> "RoutingPlan":
        return RoutingPlan(tuple((s, target) for s in stage_names), default)

    @staticmethod
    def from_signature(signature, healthy: str = HW, fallback: str = SW,
                       default: Optional[str] = None) -> "RoutingPlan":
        """Derive a plan from a FaultSignature (duck-typed: anything with a
        ``.routes`` tuple of (stage, HW-or-not) pairs).

        Healthy stages are assigned ``healthy`` (the deployment's optimized
        target — HW on TPU, SW/INTERPRET on CPU hosts); quarantined stages
        are assigned ``fallback``.
        """
        return RoutingPlan(
            tuple((s, healthy if r == HW else fallback)
                  for s, r in signature.routes), default)

    # ------------------------------------------------------------ queries
    def as_dict(self) -> Dict[str, str]:
        return dict(self.assignments)

    def stages(self) -> Tuple[str, ...]:
        return tuple(s for s, _ in self.assignments)

    def target_for(self, stage: str) -> str:
        """The lowering target for ``stage``; KeyError when the plan has no
        entry and no default (a complete plan is the caller's contract)."""
        for s, t in self.assignments:
            if s == stage:
                return t
        if self.default is not None:
            return self.default
        raise KeyError(f"stage {stage!r} not in routing plan "
                       f"{self.stages()} (and no default target)")

    def get(self, stage: str, fallback: Optional[str] = None):
        """dict-compatible lookup (models consult routes via ``.get``)."""
        for s, t in self.assignments:
            if s == stage:
                return t
        return self.default if self.default is not None else fallback

    def fallback_stages(self, fallback: str = SW) -> Tuple[str, ...]:
        return tuple(s for s, t in self.assignments if t == fallback)

    # ------------------------------------------------------------ updates
    def with_target(self, stage: str, target: str) -> "RoutingPlan":
        d = self.as_dict()
        d[stage] = target
        return RoutingPlan(tuple(d.items()), self.default)

    def with_fault(self, stage: str, fallback: str = SW) -> "RoutingPlan":
        """Quarantine one stage: route it through its fallback lowering."""
        return self.with_target(stage, fallback)

    # --------------------------------------------------------- validation
    def validate(self, *, registry=None,
                 stages: Optional[Iterable[str]] = None) -> "RoutingPlan":
        """Check the plan against the Viscosity registry and/or an explicit
        stage universe; returns self so call sites can chain."""
        known = set(stages) if stages is not None else None
        for stage, _ in self.assignments:
            if registry is not None and known is None and stage not in registry:
                raise ValueError(
                    f"routing plan names unknown viscosity op {stage!r}; "
                    f"registered: {registry.names()}")
            if known is not None and stage not in known:
                raise ValueError(
                    f"routing plan names unknown stage {stage!r}; "
                    f"known: {sorted(known)}")
        return self

    # ----------------------------------------------------- lowering hooks
    def resolve(self, spec) -> Callable[..., Any]:
        """Lower one OpSpec under this plan (explicit fallback semantics:
        an HW target with no kernel resolves to the SW oracle)."""
        return spec.lower(self.target_for(spec.name))

    def resident_routes(self, health_mask, stage_names: Sequence[str]
                        ) -> Dict[str, "ResidentRoute"]:
        """Per-stage resident route handles for the hot-spare executable.

        ``health_mask`` is a traced ``(len(stage_names),)`` bool array;
        bit i selects stage i's planned target (healthy) vs its SW oracle
        (quarantined) at *runtime* — both paths are resident in the program.
        """
        return {s: ResidentRoute(hw=self.target_for(s), healthy=health_mask[i])
                for i, s in enumerate(stage_names)}


@dataclass
class ResidentRoute:
    """Runtime route handle: the paper's hot-spare residency, per stage.

    Unlike a plan target (a static string baked into the trace), a
    ResidentRoute carries a traced health bit; ``select`` lowers an OpSpec
    to ``lax.cond(healthy, optimized, oracle)`` so failover never
    recompiles.  Not hashable on purpose — it lives inside a traced
    function, never in a Dispatcher cache key (the enclosing executable is
    keyed by the static RoutingPlan it was derived from).
    """

    hw: str                 # target selected while the stage is healthy
    healthy: Any            # scalar bool (typically a tracer)

    def select(self, spec) -> Callable[..., Any]:
        import jax

        hw_fn = spec.lower(self.hw)
        sw_fn = spec.ref
        if hw_fn is sw_fn:      # plan already routes software: nothing to cond
            return sw_fn
        healthy = self.healthy

        def resident(*args, **kw):
            return jax.lax.cond(healthy,
                                lambda ops: hw_fn(*ops, **kw),
                                lambda ops: sw_fn(*ops, **kw),
                                args)
        return resident


def as_routes(routes) -> Any:
    """Normalize a build_model ``routes`` argument.

    Accepts None (empty plan: every stage uses the consumer default),
    a RoutingPlan, or a plain dict of targets / ResidentRoute handles
    (the resident executable builds the dict inside its trace).  Anything
    with a ``.get`` is returned as-is; models only ever call ``.get``.
    """
    if routes is None:
        return RoutingPlan()
    if hasattr(routes, "get"):
        return routes
    raise TypeError(f"routes must be None, a RoutingPlan, or a mapping; "
                    f"got {type(routes)!r}")
