"""Stage: the Oobleck sub-accelerator abstraction (paper §III-A).

A Stage wraps one step of ``f = f_n ∘ … ∘ f_1`` with the two interfaces the
paper prescribes:
  * the *fast path* (``hw``): the optimized lowering — a Pallas kernel or a
    fused XLA computation;
  * the *software-visible path* (``sw``): the jnp oracle — logically
    equivalent (a Viscosity contract), runnable anywhere.

``ports`` are the latency-insensitive interface (activation specs); the
runtime uses them for canary generation and checkpoint hand-off.
"""
from __future__ import annotations

from dataclasses import dataclass
from typing import Callable, Optional, Tuple

import jax
import jax.numpy as jnp

from repro.viscosity.lang import HW, INTERPRET, SW, OpSpec


@dataclass
class Stage:
    name: str
    spec: Optional[OpSpec] = None            # viscosity op (preferred)
    hw: Optional[Callable] = None            # explicit pair (case studies)
    sw: Optional[Callable] = None
    ports: Tuple[jax.ShapeDtypeStruct, ...] = ()
    tol: float = 2e-2

    def __post_init__(self):
        if self.spec is not None:
            self.hw = self.hw or (lambda *a, **k: self.spec(*a, route=HW, **k))
            self.sw = self.sw or (lambda *a, **k: self.spec(*a, route=SW, **k))
        assert self.sw is not None, f"stage {self.name} needs a software path"
        if self.hw is None:
            self.hw = self.sw   # pure-sw stage (no optimized lowering)

    def run(self, *args, route=HW, **kw):
        """Run one stage under a route: a target string or a RoutingPlan
        (the stage resolves its own entry — the single lookup point that
        replaced the per-layer string shims)."""
        if hasattr(route, "target_for"):
            route = route.target_for(self.name)
        if route == INTERPRET and self.spec is not None:
            return self.spec(*args, route=INTERPRET, **kw)
        fn = self.hw if route == HW else self.sw
        return fn(*args, **kw)

    def canary_inputs(self, seed: int = 0):
        """Deterministic inputs drawn from the port specs."""
        key = jax.random.PRNGKey(seed)
        outs = []
        for i, sds in enumerate(self.ports):
            k = jax.random.fold_in(key, i)
            if jnp.issubdtype(sds.dtype, jnp.floating):
                outs.append(jax.random.normal(k, sds.shape, sds.dtype))
            else:
                outs.append(jax.random.randint(k, sds.shape, 0, 128
                                               ).astype(sds.dtype))
        return tuple(outs)
