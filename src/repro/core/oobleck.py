"""The Oobleck methodology: staged accelerators + fault routing (paper §III).

``StagedAccelerator`` composes Stages ``f = f_n ∘ … ∘ f_1``.  Two failover
mechanisms, mirroring the paper:

  * **static routing** (the paper's queue reconfiguration): the executable
    is compiled for one FaultSignature; a new fault → ``Dispatcher``
    compiles the re-routed program (LRU-cached — signatures are few and
    monotone).  Zero overhead in the no-fault fast path (stage boundaries
    fuse away: the paper's queue *bypass*).

  * **resident routing** (the hot-spare analogue): both lowerings of every
    stage live in one executable behind ``lax.cond`` on a health-mask
    input; failover = flipping one bit in an input array (O(µs), no
    recompile), at the cost of a larger program.
"""
from __future__ import annotations

import collections
import hashlib
import time
from dataclasses import dataclass
from typing import Callable, Hashable, List, Optional, Sequence

import jax

from repro.core.fault import FaultSignature
from repro.core.routing import RoutingPlan
from repro.core.stage import Stage
from repro.kernels import tuning
from repro.obs import metrics
from repro.viscosity.lang import HW, SW


def _key_digest(cache_key: Hashable) -> str:
    """Stable short digest of a compile key — the telemetry label for
    per-key hit/miss/compile-time without unbounded cardinality."""
    return hashlib.sha256(repr(cache_key).encode()).hexdigest()[:10]


class StagedAccelerator:
    """f = f_n ∘ … ∘ f_1 with per-stage dual paths."""

    def __init__(self, name: str, stages: Sequence[Stage]):
        self.name = name
        self.stages = list(stages)
        names = [s.name for s in self.stages]
        assert len(set(names)) == len(names), f"duplicate stages: {names}"

    @property
    def stage_names(self) -> List[str]:
        return [s.name for s in self.stages]

    def healthy_signature(self) -> FaultSignature:
        return FaultSignature.healthy(self.stage_names)

    def healthy_plan(self, target: str = HW) -> RoutingPlan:
        return RoutingPlan.for_stages(self.stage_names, target=target,
                                      default=HW)

    def plan_for(self, signature: Optional[FaultSignature]) -> RoutingPlan:
        """Signature -> RoutingPlan (also accepts a plan, passed through)."""
        if signature is None:
            return self.healthy_plan()
        if isinstance(signature, RoutingPlan):
            return signature
        return RoutingPlan.from_signature(signature, default=HW).validate(
            stages=self.stage_names)

    def run(self, x, signature=None):
        """Run under a FaultSignature or a RoutingPlan (one IR, one path)."""
        plan = self.plan_for(signature)
        for s in self.stages:
            x = s.run(x, route=plan)
        return x

    def run_reference(self, x):
        """All-software oracle (the paper's 'purely software' baseline)."""
        for s in self.stages:
            x = s.run(x, route=SW)
        return x

    def run_resident(self, x, health_mask: jax.Array):
        """Hot-spare variant: health_mask (n_stages,) bool, traced.

        Both paths are present in the program; ``lax.cond`` selects at
        runtime — failover without reconfiguration.
        """
        for i, s in enumerate(self.stages):
            x = jax.lax.cond(health_mask[i],
                             lambda xx, s=s: s.run(xx, route=HW),
                             lambda xx, s=s: s.run(xx, route=SW),
                             x)
        return x


@dataclass
class _Entry:
    fn: Callable
    n_calls: int = 0


class Dispatcher:
    """Compile-per-plan LRU cache (the paper's reconfiguration engine).

    ``build(key) -> callable`` is user-supplied (e.g. jit of a train step
    with the model rebuilt for those routes).  Keys are any hashable —
    canonically a ``RoutingPlan`` (two fault signatures that induce the
    same routing share one executable); the case studies key raw
    ``FaultSignature``s.  A key exposing ``compile_key()`` (``FleetPlan``)
    is canonicalized through it before lookup, so two fleets with the same
    per-device routing *multiset* share compiles even when the device
    numbering differs.  Reconfiguration cost = one compile, paid once per
    new key; monotone fault accumulation keeps the key set tiny
    (≤ n_stages + 1 in practice).  Eviction is LRU at ``capacity``.
    """

    def __init__(self, build: Callable[[Hashable], Callable],
                 capacity: int = 8):
        self.build = build
        self.capacity = capacity
        self._cache: "collections.OrderedDict[Hashable, _Entry]" = \
            collections.OrderedDict()
        self.compiles = 0

    def get(self, key: Hashable) -> Callable:
        cache_key = (key.compile_key()
                     if hasattr(key, "compile_key") else key)
        if cache_key in self._cache:
            self._cache.move_to_end(cache_key)
            e = self._cache[cache_key]
            e.n_calls += 1
            metrics.inc("dispatch_cache_hits_total",
                        key=_key_digest(cache_key))
            return e.fn
        metrics.inc("dispatch_cache_misses_total",
                    key=_key_digest(cache_key))
        # Build AND trace under the plan scope: any kernel traced while
        # this executable compiles looks up tuned block sizes under this
        # plan's key first (degraded plans may carry different tiles).
        t0 = time.perf_counter()
        with tuning.plan_scope(cache_key):
            fn = tuning.scoped(cache_key, self.build(key))
        metrics.observe("dispatch_compile_seconds",
                        time.perf_counter() - t0,
                        key=_key_digest(cache_key))
        self.compiles += 1
        self._cache[cache_key] = _Entry(fn=fn, n_calls=1)
        if len(self._cache) > self.capacity:
            self._cache.popitem(last=False)
        return fn

    def cached_keys(self) -> List[Hashable]:
        """Current residents, least- to most-recently used (tests/metrics)."""
        return list(self._cache)

    def __call__(self, key: Hashable, *args, **kw):
        return self.get(key)(*args, **kw)
