"""Data-center fleet models (paper §II, Fig. 2; §V-G cost evaluation).

Fixed-size model: N chips, T ticks, per-tick per-chip fault probability p.
  * SFA (single-fault accelerator): first fault -> chip replaced.
  * VFA (variable-fault accelerator): dies after ``max_faults`` faults;
    intermediate faults multiply chip throughput by the degradation curve
    (derived from the latency model's throughput_factor, e.g. the FFT case
    study gives [1.0, 0.38, ...]).

Both a vectorized Monte-Carlo simulation and closed-form expectations are
provided; Fig. 2's claims are asserted against the analytic curves in
tests (MC agrees within sampling error).

Fixed-throughput model (§II, §V-G): chips needed to restore the fleet's
aggregate throughput scale linearly with per-fault performance retention.
"""
from __future__ import annotations

from dataclasses import dataclass
from typing import List, Sequence, Tuple

import numpy as np


@dataclass
class FleetResult:
    replacements: float
    throughput: float          # mean aggregate throughput / max possible
    faults_total: float


# ------------------------------------------------------------ Monte Carlo
def simulate_fleet(n_chips: int, ticks: int, p_fault: float, *,
                   mode: str = "vfa", max_faults: int = 3,
                   degradation: Sequence[float] = (1.0, 0.38, 0.19),
                   replace_failed: bool = True, seed: int = 0,
                   ) -> FleetResult:
    """Vectorized fleet simulation.

    degradation[k] = relative throughput with k faults (k < max_faults);
    at ``max_faults`` the chip fails (throughput 0) and is replaced.
    SFA is the special case max_faults=1.
    """
    if mode == "sfa":
        max_faults = 1
    rng = np.random.default_rng(seed)
    deg = np.asarray(list(degradation)[:max_faults], np.float64)
    assert deg.shape[0] == max_faults
    faults = np.zeros(n_chips, np.int64)
    replacements = 0
    faults_total = 0
    tp_acc = 0.0
    for _ in range(ticks):
        hit = rng.random(n_chips) < p_fault
        faults_total += int(hit.sum())
        faults = faults + hit
        dead = faults >= max_faults
        n_dead = int(dead.sum())
        if n_dead and replace_failed:
            replacements += n_dead
            faults[dead] = 0
        elif n_dead:
            faults[dead] = max_faults  # pin
        tp_acc += float(deg[np.minimum(faults, max_faults - 1)].sum())
    return FleetResult(replacements=float(replacements),
                       throughput=tp_acc / (ticks * n_chips),
                       faults_total=float(faults_total))


# ---------------------------------------------------------------- analytic
def expected_replacements(n_chips: int, ticks: int, p: float,
                          max_faults: int = 3) -> float:
    """Renewal-process expectation of chip replacements over the horizon.

    A chip is replaced each time it accumulates ``max_faults`` faults; fault
    arrivals are Bernoulli(p) per tick.  Expected replacements per chip =
    E[floor(Binomial(T, p) / max_faults)] (faults carry across replacement
    boundaries only within a chip's own renewal chain, which this floor
    captures exactly for memoryless Bernoulli arrivals).
    """
    mean = ticks * p
    if mean > 50 * max_faults:   # deep-normal regime: floor(X/k) ~ X/k
        return n_chips * mean / max_faults
    # exact-ish: sum over Poisson-approximated fault counts
    from math import exp, lgamma, log
    lam = -ticks * np.log1p(-p) if p < 1 else float("inf")
    total = 0.0
    kmax = int(lam + 12 * np.sqrt(lam) + 3 * max_faults + 10)
    logp = -lam
    for k in range(kmax + 1):
        if k > 0:
            logp += log(lam) - log(k)
        total += (k // max_faults) * exp(logp)
    return n_chips * total


def expected_throughput(ticks: int, p: float, *, max_faults: int = 3,
                        degradation: Sequence[float] = (1.0, 0.38, 0.19),
                        ) -> float:
    """Mean relative throughput of one chip over the horizon (replacement
    resets; Markov chain over fault-count states 0..max_faults-1)."""
    deg = list(degradation)[:max_faults]
    state = np.zeros(max_faults)
    state[0] = 1.0
    tp = 0.0
    M = np.zeros((max_faults, max_faults))
    for i in range(max_faults):
        M[i, i] += 1 - p
        j = i + 1
        M[(j if j < max_faults else 0), i] += p   # overflow -> replaced (new)
    for _ in range(ticks):
        tp += float(np.dot(deg, state))
        state = M @ state
    return tp / ticks


# ------------------------------------------------- fixed-throughput model
def chips_to_buy(n_faulted: int, retention: float) -> float:
    """§II: chips bought to restore throughput when ``n_faulted`` chips each
    retain ``retention`` of their performance.  SFA: retention=0 -> buy all.
    Linear in (1 - retention), as the paper states."""
    return n_faulted * (1.0 - retention)


def fig2_sweep(fault_rates: Sequence[float], *, n_chips: int = 10_000,
               ticks: int = 1460, max_faults: int = 3,
               degradation: Sequence[float] = (1.0, 0.38, 0.19),
               monte_carlo: bool = False, seed: int = 0):
    """Reproduces Fig. 2(a,b): returns rows of
    (rate, sfa_repl, vfa_repl, sfa_tp, vfa_tp)."""
    rows = []
    for p in fault_rates:
        if monte_carlo:
            sfa = simulate_fleet(n_chips, ticks, p, mode="sfa", seed=seed)
            vfa = simulate_fleet(n_chips, ticks, p, mode="vfa",
                                 max_faults=max_faults,
                                 degradation=degradation, seed=seed)
            rows.append((p, sfa.replacements, vfa.replacements,
                         sfa.throughput, vfa.throughput))
        else:
            rows.append((
                p,
                expected_replacements(n_chips, ticks, p, 1),
                expected_replacements(n_chips, ticks, p, max_faults),
                expected_throughput(ticks, p, max_faults=1,
                                    degradation=(1.0,)),
                expected_throughput(ticks, p, max_faults=max_faults,
                                    degradation=degradation),
            ))
    return rows
