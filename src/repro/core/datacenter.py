"""Data-center fleet models (paper §II, Fig. 2; §V-G cost evaluation).

Fixed-size model: N chips, T ticks, per-tick per-chip fault probability p.
  * SFA (single-fault accelerator): first fault -> chip replaced.
  * VFA (variable-fault accelerator): dies after ``max_faults`` faults;
    intermediate faults multiply chip throughput by the degradation curve
    (derived from the latency model's throughput_factor, e.g. the FFT case
    study gives [1.0, 0.38, ...]).

Both a vectorized Monte-Carlo simulation and closed-form expectations are
provided; Fig. 2's claims are asserted against the analytic curves in
tests (MC agrees within sampling error).

Fixed-throughput model (§II, §V-G): chips needed to restore the fleet's
aggregate throughput scale linearly with per-fault performance retention.
"""
from __future__ import annotations

from dataclasses import dataclass
from typing import Any, Dict, List, Mapping, Optional, Sequence, Tuple

import numpy as np

from repro.viscosity.lang import (DEGRADED_REDUCED, DEGRADED_REMAP,
                                  DEGRADED_TARGETS)


@dataclass(frozen=True)
class DegradationModel:
    """VFA degradation refined to per-(stage, rung) partial throughput.

    The scalar curve (``curve[k]`` = relative throughput with k SW-
    quarantined faults) stays the coarse backbone, but a fault absorbed by
    the DEGRADED route family costs a *partial* factor instead of a full
    curve step: a remapped stage runs the kernel at full width plus an
    oracle patch (mild overhead), a reduced-width stage loses lanes
    proportionally.  ``factor`` composes the two: rung-absorbed faults are
    removed from the curve index (remap absorbs 1 fault, reduced-width 2 —
    its ladder position) and charged their per-stage partial factor
    instead.  With no rungs this reduces exactly to the legacy scalar
    model, so existing Fig. 2 curves are unchanged.
    """

    curve: Tuple[float, ...] = (1.0, 0.38, 0.19)
    # ((stage, rung), factor) overrides; rung is a DEGRADED target string.
    partial: Tuple[Tuple[Tuple[str, str], float], ...] = ()
    remap_default: float = 0.85
    reduced_default: float = 0.6

    # Ladder position of each rung = faults it has absorbed.
    RUNG_WEIGHTS = {DEGRADED_REMAP: 1, DEGRADED_REDUCED: 2}

    def __post_init__(self):
        object.__setattr__(self, "curve", tuple(self.curve))
        object.__setattr__(self, "partial",
                           tuple(sorted((tuple(k), float(v))
                                        for k, v in self.partial)))
        for (_, rung), _f in self.partial:
            if rung not in DEGRADED_TARGETS:
                raise ValueError(f"partial factor names unknown rung "
                                 f"{rung!r}; expected {DEGRADED_TARGETS}")

    def partial_factor(self, stage: str, rung: str) -> float:
        for (s, r), f in self.partial:
            if s == stage and r == rung:
                return f
        return (self.remap_default if rung == DEGRADED_REMAP
                else self.reduced_default)

    def factor(self, n_faults: int,
               rungs: Sequence[Tuple[str, str]] = ()) -> float:
        """Relative throughput of a device with ``n_faults`` total faults
        of which ``rungs`` (stage, DEGRADED-target) pairs are absorbed by
        the ladder; the remainder are full SW quarantines on the curve."""
        absorbed = sum(self.RUNG_WEIGHTS.get(r, 0) for _, r in rungs)
        k_sw = max(0, int(n_faults) - absorbed)
        f = self.curve[min(k_sw, len(self.curve) - 1)]
        for s, r in rungs:
            f *= self.partial_factor(s, r)
        return f

    def slot_cap(self, slots_per_device: int, n_faults: int,
                 rungs: Sequence[Tuple[str, str]] = ()) -> int:
        """Serve-engine slot quantization of ``factor`` (same rounding as
        the legacy scalar path, so the analytic twin stays slot-exact)."""
        return round(slots_per_device * self.factor(n_faults, rungs))

    @staticmethod
    def rungs_of(plan) -> Tuple[Tuple[str, str], ...]:
        """The (stage, rung) pairs a RoutingPlan currently assigns to the
        DEGRADED family (the ``rungs`` argument ``factor`` expects)."""
        return tuple((s, t) for s, t in plan.assignments
                     if t in DEGRADED_TARGETS)


@dataclass
class FleetResult:
    replacements: float
    throughput: float          # mean aggregate throughput / max possible
    faults_total: float
    # (tick, chip) fault events in draw order — the Monte-Carlo trace the
    # FleetHarness replays through the real engines (record_trace=True).
    trace: Tuple[Tuple[int, int], ...] = ()


# ------------------------------------------------------------ Monte Carlo
def simulate_fleet(n_chips: int, ticks: int, p_fault: float, *,
                   mode: str = "vfa", max_faults: int = 3,
                   degradation: Sequence[float] = (1.0, 0.38, 0.19),
                   replace_failed: bool = True, seed: int = 0,
                   record_trace: bool = False,
                   ) -> FleetResult:
    """Vectorized fleet simulation.

    degradation[k] = relative throughput with k faults (k < max_faults);
    at ``max_faults`` the chip fails (throughput 0) and is replaced.
    SFA is the special case max_faults=1.
    """
    if mode == "sfa":
        max_faults = 1
    rng = np.random.default_rng(seed)
    deg = np.asarray(list(degradation)[:max_faults], np.float64)
    assert deg.shape[0] == max_faults
    faults = np.zeros(n_chips, np.int64)
    replacements = 0
    faults_total = 0
    tp_acc = 0.0
    trace: List[Tuple[int, int]] = []
    for t in range(ticks):
        hit = rng.random(n_chips) < p_fault
        faults_total += int(hit.sum())
        if record_trace:
            trace.extend((t, int(c)) for c in np.flatnonzero(hit))
        faults = faults + hit
        dead = faults >= max_faults
        n_dead = int(dead.sum())
        if n_dead and replace_failed:
            replacements += n_dead
            faults[dead] = 0
        elif n_dead:
            faults[dead] = max_faults  # pin
        tp_acc += float(deg[np.minimum(faults, max_faults - 1)].sum())
    return FleetResult(replacements=float(replacements),
                       throughput=tp_acc / (ticks * n_chips),
                       faults_total=float(faults_total),
                       trace=tuple(trace))


# ---------------------------------------------------------------- analytic
def expected_replacements(n_chips: int, ticks: int, p: float,
                          max_faults: int = 3) -> float:
    """Renewal-process expectation of chip replacements over the horizon.

    A chip is replaced each time it accumulates ``max_faults`` faults; fault
    arrivals are Bernoulli(p) per tick.  Expected replacements per chip =
    E[floor(Binomial(T, p) / max_faults)] (faults carry across replacement
    boundaries only within a chip's own renewal chain, which this floor
    captures exactly for memoryless Bernoulli arrivals).
    """
    mean = ticks * p
    if mean > 50 * max_faults:   # deep-normal regime: floor(X/k) ~ X/k
        return n_chips * mean / max_faults
    # exact-ish: sum over Poisson-approximated fault counts
    from math import exp, log
    lam = -ticks * np.log1p(-p) if p < 1 else float("inf")
    total = 0.0
    kmax = int(lam + 12 * np.sqrt(lam) + 3 * max_faults + 10)
    logp = -lam
    for k in range(kmax + 1):
        if k > 0:
            logp += log(lam) - log(k)
        total += (k // max_faults) * exp(logp)
    return n_chips * total


def expected_throughput(ticks: int, p: float, *, max_faults: int = 3,
                        degradation: Sequence[float] = (1.0, 0.38, 0.19),
                        ) -> float:
    """Mean relative throughput of one chip over the horizon (replacement
    resets; Markov chain over fault-count states 0..max_faults-1)."""
    deg = list(degradation)[:max_faults]
    state = np.zeros(max_faults)
    state[0] = 1.0
    tp = 0.0
    M = np.zeros((max_faults, max_faults))
    for i in range(max_faults):
        M[i, i] += 1 - p
        j = i + 1
        M[(j if j < max_faults else 0), i] += p   # overflow -> replaced (new)
    for _ in range(ticks):
        tp += float(np.dot(deg, state))
        state = M @ state
    return tp / ticks


# ------------------------------------------------- fixed-throughput model
def chips_to_buy(n_faulted: int, retention: float) -> float:
    """§II: chips bought to restore throughput when ``n_faulted`` chips each
    retain ``retention`` of their performance.  SFA: retention=0 -> buy all.
    Linear in (1 - retention), as the paper states."""
    return n_faulted * (1.0 - retention)


# ------------------------------------------------ trace -> fleet scenario
@dataclass
class TraceReplay:
    """One Monte-Carlo fault trace turned into an executable fleet
    scenario: per-engine-step fault events plus the analytic per-tick
    capacity curve they imply."""

    events: Dict[int, List[Tuple]]        # engine step -> fleet events
    capacity: np.ndarray                  # (ticks,) analytic fleet capacity
    healthy_capacity: float               # capacity with zero faults
    n_dropped: int                        # trace faults on already-dead HW

    @property
    def mean_ratio(self) -> float:
        """Mean aggregate throughput relative to the healthy fleet — the
        analytic VFA degradation prediction for this trace."""
        return float(np.mean(self.capacity) / self.healthy_capacity)


def replay_trace(trace: Sequence[Tuple[int, int]], *, n_workers: int,
                 ticks: int, stage_names: Sequence[str],
                 degradation: Sequence[float] = (1.0, 0.38, 0.19),
                 max_faults: int = 3, n_spares: int = 0,
                 slots_per_device: int = 1,
                 steps_per_tick: int = 1,
                 n_hosts: int = 1,
                 host_loss: Optional[Mapping[int, int]] = None,
                 model: Optional[DegradationModel] = None,
                 lane_mapped: Sequence[str] = ()
                 ) -> TraceReplay:
    """Mirror of the FleetPlan transition semantics over a fault trace.

    A fault on a serving device migrates its work to a free hot spare
    (paper Fig. 8) before anything degrades; with the pool dry, fault k
    quarantines ``stage_names[k]`` in place (VFA degradation); at
    ``max_faults`` the device dies.  Returns both the engine event
    schedule and the analytic capacity curve in *slots* (quantized the
    same way ``FleetConfig.capacity_for`` quantizes the serve engine),
    so measured-vs-analytic comparisons are slot-exact.

    With a ``model`` (DegradationModel) and ``lane_mapped`` stages the
    mirror walks the same degradation ladder ``FleetPlan.with_stage_fault``
    walks: repeated faults land on an already-degraded lane-mapped stage
    first (remap -> reduced width -> SW oracle), each rung charged its
    partial factor instead of a full curve step; unmapped stages quarantine
    binarily as before.  Device death still triggers at ``max_faults``
    total faults.

    ``n_hosts`` adds the multi-host axis: the ``n_workers + n_spares``
    devices partition into contiguous per-host blocks (must divide
    evenly) and ``host_loss[tick] = host`` drops a whole block at that
    tick — mirroring ``FleetPlan.with_host_fault``: serving devices
    migrate to free spares *outside* the block, the block's idle spares
    leave the pool, everything else is lost capacity.  The emitted
    ``("host", h)`` event replays through ``FleetServeEngine`` with a
    matching ``HostTopology``, so the analytic twin and the measured
    engine fold the same event log.
    """
    deg = list(degradation)
    if model is None and max_faults > len(stage_names) + 1:
        # Ladder runs absorb several faults on one stage, so the one-
        # stage-per-fault headroom guard only applies to the binary path.
        raise ValueError(
            f"max_faults={max_faults} needs at least {max_faults - 1} "
            f"stages to quarantine one per fault before device death; "
            f"model has {len(stage_names)}: {list(stage_names)}")
    lane_mapped = tuple(lane_mapped)
    n_rungs = len(DegradationModel.RUNG_WEIGHTS) + 1   # remap/reduced/SW
    n_devices = n_workers + n_spares
    if n_hosts < 1 or n_devices % n_hosts:
        raise ValueError(f"{n_devices} device(s) do not partition into "
                         f"{n_hosts} equal host block(s)")
    per_host = n_devices // n_hosts
    host_loss = dict(host_loss or {})
    for h in host_loss.values():
        if not 0 <= h < n_hosts:
            raise ValueError(f"host {h} out of range for {n_hosts} "
                             f"host(s)")

    def slot_cap(k: int) -> float:
        return round(slots_per_device * deg[min(k, len(deg) - 1)])

    faults = {d: 0 for d in range(n_devices)}     # fallback stages per dev
    scounts: Dict[int, Dict[str, int]] = {d: {} for d in range(n_devices)}

    def _pick(c: int) -> str:
        """Stage the next fault on device ``c`` hits — mirrors the engine:
        an already-degraded lane-mapped stage keeps absorbing faults until
        its ladder bottoms out at SW, then the next untouched stage."""
        if model is not None:
            for s in stage_names:
                if s in lane_mapped and 0 < scounts[c].get(s, 0) < n_rungs:
                    return s
            for s in stage_names:
                if scounts[c].get(s, 0) == 0:
                    return s
            return stage_names[-1]
        return stage_names[min(faults[c], len(stage_names) - 1)]

    def _rungs(c: int) -> Tuple[Tuple[str, str], ...]:
        """(stage, rung) pairs currently DEGRADED on device ``c`` (counts
        past the ladder are full SW quarantines, not rungs)."""
        out = []
        for s, k in sorted(scounts[c].items()):
            if s in lane_mapped and 0 < k < n_rungs:
                out.append((s, (DEGRADED_REMAP, DEGRADED_REDUCED)[k - 1]))
        return tuple(out)

    def device_cap(d: int) -> float:
        if model is not None:
            return model.slot_cap(slots_per_device, faults[d], _rungs(d))
        return slot_cap(faults[d])

    serving = set(range(n_workers))
    free_spares = list(range(n_workers, n_devices))
    dead: set = set()
    events: Dict[int, List[Tuple]] = {}
    capacity = np.zeros(ticks)
    n_dropped = 0
    by_tick: Dict[int, List[int]] = {}
    for t, c in trace:
        by_tick.setdefault(t, []).append(c)
    for t in range(ticks):
        if t in host_loss:
            h = host_loss[t]
            block = set(range(h * per_host, (h + 1) * per_host))
            events.setdefault(t * steps_per_tick, []).append(("host", h))
            for d in sorted(block & serving):
                off_host = [s for s in free_spares if s not in block]
                serving.discard(d)
                if off_host:                  # migrate outside the block
                    free_spares.remove(off_host[0])
                    serving.add(off_host[0])
                else:
                    dead.add(d)
            free_spares = [s for s in free_spares if s not in block]
            dead |= block - serving
        for c in by_tick.get(t, ()):
            if c >= n_devices or c not in serving:
                n_dropped += 1            # fault on quarantined/dead HW
                continue
            step = t * steps_per_tick
            if free_spares:               # migrate before degrading
                spare = free_spares.pop(0)
                serving.discard(c)
                serving.add(spare)
                stage = _pick(c)
                events.setdefault(step, []).append(("stage", c, stage))
                scounts[c][stage] = scounts[c].get(stage, 0) + 1
                faults[c] += 1
            elif faults[c] + 1 >= max_faults:
                serving.discard(c)
                dead.add(c)
                events.setdefault(step, []).append(("device", c))
            else:
                stage = _pick(c)
                events.setdefault(step, []).append(("stage", c, stage))
                scounts[c][stage] = scounts[c].get(stage, 0) + 1
                faults[c] += 1
        capacity[t] = sum(device_cap(d) for d in serving)
    healthy_slot = (model.slot_cap(slots_per_device, 0) if model is not None
                    else slot_cap(0))
    return TraceReplay(events=events, capacity=capacity,
                       healthy_capacity=float(n_workers * healthy_slot),
                       n_dropped=n_dropped)


class FleetHarness:
    """Close the loop on Fig. 2 / Fig. 8: replay a ``simulate_fleet``
    Monte-Carlo fault trace through the *real* serve engine and compare
    measured aggregate throughput against the analytic VFA degradation
    curve, while every completion stays bit-identical to the healthy
    single-device reference.

    The engine is passed in (built by the caller from ``repro.serve``), so
    the analytic layer never imports the serving stack.  Throughput is
    measured as decoded tokens per engine step over the fault horizon,
    normalized by a healthy run of the same workload — the same ratio the
    analytic capacity curve predicts.

    ``num_hosts`` is the fleet's host axis: with a host-partitioned
    engine (``FleetConfig.topology``) and a ``replay_trace(n_hosts=...)``
    schedule, the same event log — including whole-host losses — replays
    through both the measured and the analytic side.
    """

    def __init__(self, engine, replay: TraceReplay, *, horizon: int,
                 num_hosts: int = 1):
        self.engine = engine
        self.replay = replay
        self.horizon = horizon
        self.num_hosts = num_hosts

    def _mean_tokens(self, stats) -> float:
        per_step = stats["per_step_tokens"][:self.horizon]
        if len(per_step) < self.horizon:
            raise ValueError(
                f"engine finished after {len(per_step)} steps, before the "
                f"{self.horizon}-step fault horizon — the measured and "
                "analytic windows would not match; use a longer / more "
                "saturated workload")
        return float(np.mean(per_step))

    def run(self, requests) -> Dict[str, Any]:
        healthy_done, healthy_stats = self.engine.serve(requests)
        healthy_tps = self._mean_tokens(healthy_stats)
        faulted_done, faulted_stats = self.engine.serve(
            requests, events=self.replay.events)
        measured = self._mean_tokens(faulted_stats) / healthy_tps
        analytic = self.replay.mean_ratio
        return {
            "num_hosts": self.num_hosts,
            "measured_ratio": measured,
            "analytic_ratio": analytic,
            "rel_err": abs(measured - analytic) / analytic,
            "healthy_tokens_per_step": healthy_tps,
            "faulted_tokens_per_step": self._mean_tokens(faulted_stats),
            "requeued": faulted_stats["requeued"],
            "quarantined": faulted_stats["quarantined"],
            "spares_in_service": faulted_stats["spares_in_service"],
            "completions": (healthy_done, faulted_done),
            "stats": (healthy_stats, faulted_stats),
        }


def fig2_sweep(fault_rates: Sequence[float], *, n_chips: int = 10_000,
               ticks: int = 1460, max_faults: int = 3,
               degradation: Sequence[float] = (1.0, 0.38, 0.19),
               monte_carlo: bool = False, seed: int = 0):
    """Reproduces Fig. 2(a,b): returns rows of
    (rate, sfa_repl, vfa_repl, sfa_tp, vfa_tp)."""
    rows = []
    for p in fault_rates:
        if monte_carlo:
            sfa = simulate_fleet(n_chips, ticks, p, mode="sfa", seed=seed)
            vfa = simulate_fleet(n_chips, ticks, p, mode="vfa",
                                 max_faults=max_faults,
                                 degradation=degradation, seed=seed)
            rows.append((p, sfa.replacements, vfa.replacements,
                         sfa.throughput, vfa.throughput))
        else:
            rows.append((
                p,
                expected_replacements(n_chips, ticks, p, 1),
                expected_replacements(n_chips, ticks, p, max_faults),
                expected_throughput(ticks, p, max_faults=1,
                                    degradation=(1.0,)),
                expected_throughput(ticks, p, max_faults=max_faults,
                                    degradation=degradation),
            ))
    return rows
