"""Fault model, detection, and injection (paper §III-A; detection pluggable).

Fault granularity mirrors the paper: a *non-transient* fault quarantines one
(stage, replica) — the runtime must stop using the optimized path for that
stage there.  ``FaultSignature`` is the frozen stage->route map that keys a
compiled executable (the Cohort 2-bit queue config, lifted to SPMD).

Detectors (any can drive the runtime; "Oobleck does not dictate a
particular method of fault detection"):
  * CanaryChecker  — runs each stage's HW path against its SW oracle on
    deterministic canaries; compares via the Fig.-4 checksum kernel
    (bit-exact detection of integer/stuck-at faults) or allclose for
    floating-point contract violations.
  * StepGuard      — NaN/Inf validity predicates on step outputs.
  * StragglerWatchdog — robust-quantile step-time outlier detection.

Injection: ``FaultInjector`` corrupts a stage's HW path deterministically
(bitflip / stuck-at-zero / gain error) to emulate a datapath defect.
"""
from __future__ import annotations

import time
from dataclasses import dataclass
from typing import (Callable, Dict, FrozenSet, Iterable, List, Mapping,
                    Optional, Sequence, Tuple)

import jax
import jax.numpy as jnp
import numpy as np

from repro.kernels.checksum import checksum_tree
from repro.obs import metrics
from repro.obs import trace as obs_trace
from repro.obs.logging import get_logger
from repro.viscosity import lanefault
from repro.viscosity.lang import HW, SW
from repro.core.stage import Stage

log = get_logger("core.fault")

OK = "ok"
FAULT = "fault"

# Probation verdicts (FaultClassifier).  A detection enters *probation*:
# the stage's canary is re-executed on the same replica under exponential
# backoff, and the verdict decides which ladder the runtime walks —
# ``transient_recovered`` restores the HW route, ``persistent`` proceeds
# HW -> DEGRADED -> SW as before.  ``intermittent_promoted`` marks a
# clean probe overridden by the frequency threshold: the stage kept
# flapping transient, so it is treated as persistent anyway.
TRANSIENT_RECOVERED = "transient_recovered"
PERSISTENT = "persistent"
INTERMITTENT_PROMOTED = "intermittent_promoted"

# Errors a detector may legitimately *interpret as a fault* when a stage's
# HW path raises them (numeric/shape breakage of the kind a defective
# datapath produces).  Anything else propagates — a fail-open
# ``except Exception`` here once swallowed genuine bugs silently.
EXPECTED_STAGE_ERRORS = (ValueError, TypeError, ArithmeticError)


@dataclass(frozen=True)
class FaultSignature:
    """Frozen stage -> route map. Healthy stages route HW, faulty SW."""
    routes: Tuple[Tuple[str, str], ...] = ()

    @staticmethod
    def healthy(stage_names: Sequence[str] = ()) -> "FaultSignature":
        return FaultSignature(tuple((s, HW) for s in stage_names))

    def as_dict(self) -> Dict[str, str]:
        return dict(self.routes)

    def with_fault(self, stage: str) -> "FaultSignature":
        d = self.as_dict()
        d[stage] = SW
        return FaultSignature(tuple(sorted(d.items())))

    def faulty(self) -> FrozenSet[str]:
        return frozenset(s for s, r in self.routes if r != HW)

    def n_faults(self) -> int:
        return len(self.faulty())


def _log_key(entry: Mapping) -> Tuple[int, str, int]:
    """Total order over fault-log entries: (step, origin, seq).  Logical —
    no wall clock anywhere, so two runs that observe the same events in any
    interleaving produce identical merged logs."""
    return (int(entry.get("step", 0)), str(entry.get("origin", "")),
            int(entry.get("seq", 0)))


class FaultState:
    """Mutable fleet-side health registry: (stage, replica) -> status.

    Log entries carry **logical stamps** ``(step, origin, seq)`` — the same
    total order FleetEvent uses — never wall-clock time: a fault log must
    be a deterministic function of the event sequence, reproducible across
    replays and identical across replicas that saw the same events.
    """

    def __init__(self, origin: str = "local"):
        self._bad: Dict[Tuple[str, int], str] = {}
        self._counts: Dict[Tuple[str, int], int] = {}
        self.log: List[dict] = []
        self.origin = origin
        self._seq = 0

    def _stamp(self, step: int) -> Dict:
        self._seq += 1
        return {"step": int(step), "origin": self.origin, "seq": self._seq}

    def mark(self, stage: str, replica: int = 0, kind: str = "detected",
             step: int = 0) -> dict:
        self._bad[(stage, replica)] = FAULT
        self._counts[(stage, replica)] = self.count(stage, replica) + 1
        entry = {"stage": stage, "replica": replica, "kind": kind,
                 **self._stamp(step)}
        self.log.append(entry)
        metrics.inc("fault_events_total", kind=kind, stage=stage)
        return entry

    def note(self, stage: str, replica: int = 0, kind: str = "note",
             step: int = 0) -> dict:
        """Log-only event (no quarantine, no fault count) with the same
        deterministic stamp — e.g. a nan-guard trip the runner handles."""
        entry = {"stage": stage, "replica": replica, "kind": kind,
                 **self._stamp(step)}
        self.log.append(entry)
        metrics.inc("fault_events_total", kind=kind, stage=stage)
        return entry

    def observe(self, entry: Mapping) -> dict:
        """Fold one remote replica's log entry into this registry (marks
        the (stage, replica) and appends the entry verbatim — the remote
        origin/seq stamp is preserved so merged logs dedup exactly)."""
        e = dict(entry)
        self._bad[(e["stage"], e.get("replica", 0))] = FAULT
        self._counts[(e["stage"], e.get("replica", 0))] = (
            self.count(e["stage"], e.get("replica", 0)) + 1)
        self.log.append(e)
        return e

    def clear(self, stage: str, replica: int = 0,
              kind: str = TRANSIENT_RECOVERED, step: int = 0) -> dict:
        """Undo exactly one ``mark`` on (stage, replica): the probation
        verdict came back transient, so the fault count steps back down one
        rung and — when that was the only outstanding fault — the
        quarantine lifts.  Logged with the same deterministic stamp so the
        recovery replays identically on every host."""
        key = (stage, replica)
        n = self.count(stage, replica)
        if n <= 1:
            self._counts.pop(key, None)
            self._bad.pop(key, None)
        else:
            self._counts[key] = n - 1
        entry = {"stage": stage, "replica": replica, "kind": kind,
                 **self._stamp(step)}
        self.log.append(entry)
        metrics.inc("fault_events_total", kind=kind, stage=stage)
        return entry

    def is_faulty(self, stage: str, replica: int = 0) -> bool:
        return self._bad.get((stage, replica)) == FAULT

    def count(self, stage: str, replica: int = 0) -> int:
        """Faults accumulated on one (stage, replica) — the degradation-
        ladder rung index."""
        return self._counts.get((stage, replica), 0)

    def counts(self, stage_names: Optional[Iterable[str]] = None,
               replica: int = 0) -> Dict[str, int]:
        """Per-stage fault counts for ``replica`` (the input to
        ``lanefault.degraded_plan``)."""
        if stage_names is not None:
            return {s: self.count(s, replica) for s in stage_names}
        return {s: c for (s, r), c in sorted(self._counts.items())
                if r == replica}

    def signature(self, stage_names: Sequence[str], replica: int = 0
                  ) -> FaultSignature:
        sig = FaultSignature.healthy(stage_names)
        for s in stage_names:
            if self.is_faulty(s, replica):
                sig = sig.with_fault(s)
        return sig

    def n_faults(self, replica: int = 0) -> int:
        return sum(1 for (s, r), v in self._bad.items()
                   if r == replica and v == FAULT)

    @staticmethod
    def merge_logs(*logs: Sequence[Mapping]) -> List[dict]:
        """Deterministic union of per-replica logs: sorted by the logical
        (step, origin, seq) stamp, deduplicated on it.  Any interleaving of
        the same events merges to the identical list."""
        seen, out = set(), []
        for e in sorted((dict(e) for lg in logs for e in lg), key=_log_key):
            k = _log_key(e)
            if k not in seen:
                seen.add(k)
                out.append(e)
        return out


# ------------------------------------------------------------- probation
@dataclass(frozen=True)
class ProbationPolicy:
    """Retry budget for probation re-execution (RedMulE-FT style
    re-execution-on-demand: the cheap recovery rung *before* any capacity
    is surrendered).  ``retries`` canary re-runs, exponentially backed off
    from ``backoff_base_s`` by ``backoff_factor`` and capped at
    ``max_backoff_s``.  The default base of 0 keeps tests and virtual-clock
    runs wall-time free; production sets a real base."""

    retries: int = 3
    backoff_base_s: float = 0.0
    backoff_factor: float = 2.0
    max_backoff_s: float = 1.0

    def __post_init__(self):
        if self.retries < 1:
            raise ValueError(f"retries must be >= 1, got {self.retries}")
        if self.backoff_base_s < 0 or self.max_backoff_s < 0:
            raise ValueError("backoff must be >= 0")
        if self.backoff_factor < 1.0:
            raise ValueError(f"backoff_factor must be >= 1, got "
                             f"{self.backoff_factor}")

    def backoff_schedule(self) -> Tuple[float, ...]:
        """Seconds to wait before each retry attempt (deterministic)."""
        return tuple(min(self.max_backoff_s,
                         self.backoff_base_s * self.backoff_factor ** i)
                     for i in range(self.retries))


@dataclass(frozen=True)
class IntermittentPolicy:
    """Frequency threshold for promoting a *flapping* stage to
    persistent (ROADMAP chaos headroom; the related work's wear-out
    model): when one (stage, replica) collects ``threshold`` transient
    verdicts within the trailing ``window_steps`` engine steps, the next
    clean probe is overridden — recurring upsets on the same silicon are
    a defect signature, not noise, and the runtime stops burning
    probation budget on them."""

    threshold: int = 3
    window_steps: int = 20

    def __post_init__(self):
        if self.threshold < 2:
            raise ValueError(f"threshold must be >= 2, got "
                             f"{self.threshold}")
        if self.window_steps < 1:
            raise ValueError(f"window_steps must be >= 1, got "
                             f"{self.window_steps}")


@dataclass(frozen=True)
class ProbationResult:
    """Outcome of one probation: ``transient`` when the canary went clean
    within the retry budget (at re-run ``attempts``), else persistent.
    ``promoted`` marks the intermittent override — the probe came back
    clean but the IntermittentPolicy frequency threshold forced the
    persistent ladder anyway.  ``backoff_s`` is the total back-off
    actually scheduled."""

    stage: str
    replica: int
    transient: bool
    attempts: int
    backoff_s: float
    promoted: bool = False

    @property
    def verdict(self) -> str:
        if self.promoted:
            return INTERMITTENT_PROMOTED
        return TRANSIENT_RECOVERED if self.transient else PERSISTENT


class FaultClassifier:
    """Transient-vs-persistent probation over a detection (paper §III-A
    splits the fault model; arxiv 1806.09679 finds most datapath upsets
    transient, so acting on the split recovers real capacity).

    On a detection, the stage's canary is re-executed on the same replica
    up to ``policy.retries`` times with exponential backoff: a clean canary
    means the upset did not persist — the caller restores the HW route and
    the log records ``transient_recovered``; all-red means a real defect —
    the caller walks the existing HW -> DEGRADED -> SW ladder.

    ``sleep`` is injectable (tests pass a recorder; the default zero-base
    policy never waits, so virtual-clock runs stay wall-time free)."""

    def __init__(self, checker: "CanaryChecker",
                 policy: Optional[ProbationPolicy] = None, *,
                 intermittent: Optional[IntermittentPolicy] = None,
                 sleep: Optional[Callable[[float], None]] = None):
        self.checker = checker
        self.policy = policy or ProbationPolicy()
        self.intermittent = intermittent
        # (stage, replica) -> steps of recent transient verdicts (the
        # telemetry counter is monotone; the window lives here)
        self._transients: Dict[Tuple[str, int], List[int]] = {}
        self._sleep = sleep if sleep is not None else time.sleep

    def _flapping(self, stage: str, replica: int, step: int) -> bool:
        """Record one transient verdict and report whether it crosses
        the intermittent-promotion frequency threshold."""
        metrics.inc("probation_transients_total", stage=stage)
        if self.intermittent is None:
            return False
        key = (stage, replica)
        lo = step - self.intermittent.window_steps
        recent = [s for s in self._transients.get(key, ()) if s >= lo]
        recent.append(step)
        self._transients[key] = recent
        return len(recent) >= self.intermittent.threshold

    def _stage_named(self, name: str) -> Optional[Stage]:
        for s in self.checker.stages:
            if s.name == name:
                return s
        return None

    def probate(self, probe: Callable[[], bool], *, stage: str,
                replica: int = 0, step: int = 0,
                state: Optional[FaultState] = None) -> ProbationResult:
        """Core retry loop over an arbitrary health probe (True = clean).
        ``classify`` wraps the stage canary in this; the train runner
        probes by re-executing the tripped shard directly."""
        waited = 0.0
        attempts = 0
        for backoff in self.policy.backoff_schedule():
            if backoff > 0:
                self._sleep(backoff)
            waited += backoff
            attempts += 1
            clean = bool(probe())
            if state is not None:
                state.note(stage, replica,
                           kind="probation_retry", step=step)
            if clean:
                if self._flapping(stage, replica, step):
                    # clean probe, but the stage keeps flapping: the
                    # frequency threshold promotes it to persistent
                    res = ProbationResult(stage=stage, replica=replica,
                                          transient=False,
                                          attempts=attempts,
                                          backoff_s=waited,
                                          promoted=True)
                    metrics.inc("probation_verdicts_total",
                                verdict=INTERMITTENT_PROMOTED)
                    obs_trace.emit(step, name="probation", stage=stage,
                                   replica=replica,
                                   verdict=INTERMITTENT_PROMOTED)
                    log.warning("intermittent fault promoted to "
                                "persistent", stage=stage,
                                replica=replica, step=step,
                                window=self.intermittent.window_steps,
                                threshold=self.intermittent.threshold)
                    if state is not None:
                        state.note(stage, replica,
                                   kind=INTERMITTENT_PROMOTED, step=step)
                    return res
                res = ProbationResult(stage=stage, replica=replica,
                                      transient=True, attempts=attempts,
                                      backoff_s=waited)
                metrics.inc("probation_verdicts_total",
                            verdict=TRANSIENT_RECOVERED)
                obs_trace.emit(step, name="probation", stage=stage,
                               replica=replica,
                               verdict=TRANSIENT_RECOVERED,
                               attempts=attempts)
                if state is not None:
                    state.note(stage, replica,
                               kind=TRANSIENT_RECOVERED, step=step)
                return res
        res = ProbationResult(stage=stage, replica=replica,
                              transient=False, attempts=attempts,
                              backoff_s=waited)
        metrics.inc("probation_verdicts_total", verdict=PERSISTENT)
        obs_trace.emit(step, name="probation", stage=stage,
                       replica=replica, verdict=PERSISTENT,
                       attempts=attempts)
        if state is not None:
            state.note(stage, replica, kind=PERSISTENT, step=step)
        return res

    def classify(self, stage_name: str, *, replica: int = 0, step: int = 0,
                 state: Optional[FaultState] = None) -> ProbationResult:
        """Probate ``stage_name`` by re-running its canary.  Unknown stages
        (not in the checker's list) cannot be probed — treated persistent,
        the safe direction."""
        s = self._stage_named(stage_name)
        if s is None:
            log.warning("probation: no canary stage; treating the "
                        "fault as persistent", stage=stage_name)
            if state is not None:
                state.note(stage_name, replica, kind=PERSISTENT, step=step)
            return ProbationResult(stage=stage_name, replica=replica,
                                   transient=False, attempts=0,
                                   backoff_s=0.0)
        return self.probate(lambda: self.checker.check_stage(s),
                            stage=stage_name, replica=replica, step=step,
                            state=state)


# ------------------------------------------------------------- injection
class InjectionNoOpError(RuntimeError):
    """An injected corruption left the output bit-identical to the clean
    run.  A silent no-op injection (bitflip of a zero element, stuck-zero
    on an already-zero lane) makes a detection test vacuous — it "passes"
    because nothing was ever wrong.  Raised eagerly so the harness knows
    the experiment is invalid, not green."""


@dataclass
class FaultInjector:
    """Wraps a stage's HW path with a deterministic corruption."""
    kind: str = "bitflip"     # bitflip | stuck_zero | gain
    magnitude: float = 1e-2

    def corrupt(self, out):
        def f(x):
            if not hasattr(x, "dtype") or not jnp.issubdtype(
                    x.dtype, jnp.inexact):   # floats AND complex
                return x
            if self.kind == "stuck_zero":
                return x.at[..., 0].set(0.0) if x.ndim else x * 0
            if self.kind == "gain":
                return x * (1.0 + self.magnitude)
            # bitflip: corrupt one fixed element.  Sign-flip alone is a
            # silent no-op on a zero element, so zeros flip to ``magnitude``
            # instead — the corruption is guaranteed to change the value.
            flat = x.reshape(-1)
            i = flat.shape[0] // 2
            v = flat[i]
            bad = jnp.where(v == 0, jnp.asarray(self.magnitude, x.dtype), -v)
            return flat.at[i].set(bad).reshape(x.shape)
        return jax.tree_util.tree_map(f, out)

    def wrap(self, fn: Callable) -> Callable:
        def bad(*a, **kw):
            clean = fn(*a, **kw)
            out = self.corrupt(clean)
            leaves = (jax.tree_util.tree_leaves(clean)
                      + jax.tree_util.tree_leaves(out))
            if not any(isinstance(x, jax.core.Tracer) for x in leaves):
                # Eager call: assert the corruption actually corrupted.
                same = all(
                    np.array_equal(np.asarray(c), np.asarray(o))
                    for c, o in zip(jax.tree_util.tree_leaves(clean),
                                    jax.tree_util.tree_leaves(out)))
                if same:
                    raise InjectionNoOpError(
                        f"{self.kind!r} injection left the output "
                        "bit-identical to the clean run (zero-valued "
                        "target?); the experiment would be vacuous")
            return out
        return bad


def inject(stage: Stage, kind: str = "bitflip",
           magnitude: float = 1e-2) -> Stage:
    inj = FaultInjector(kind=kind, magnitude=magnitude)
    return Stage(name=stage.name, spec=None, hw=inj.wrap(stage.hw),
                 sw=stage.sw, ports=stage.ports, tol=stage.tol)


# -------------------------------------------------------------- detectors
class CanaryChecker:
    """Per-stage HW-vs-SW canary compare (checksum or allclose).

    With ``localize=True`` a failing sweep additionally diffs the two
    lowerings lane-by-lane and, when the mismatch is confined to a strict
    subset of output lanes, registers a ``LaneFault`` map
    (``lanefault.set_map``) — unlocking the DEGRADED route family for
    that stage instead of a binary drop to the SW oracle.
    """

    def __init__(self, stages: Sequence[Stage], *, seed: int = 0,
                 route_hw: str = HW, localize: bool = False):
        self.stages = list(stages)
        self.seed = seed
        self.route_hw = route_hw
        self.auto_localize = localize

    def _run_both(self, stage: Stage):
        args = stage.canary_inputs(self.seed)
        return (stage.run(*args, route=self.route_hw),
                stage.run(*args, route=SW))

    def check_stage(self, stage: Stage) -> bool:
        """True = healthy."""
        try:
            hw_out, sw_out = self._run_both(stage)
        except EXPECTED_STAGE_ERRORS as e:
            # Numeric/shape breakage on the HW path is itself the fault
            # signal; anything unexpected re-raises (no fail-open except).
            log.warning("canary: stage raised; treating as a fault",
                        stage=stage.name, error=type(e).__name__,
                        detail=e)
            return False
        if stage.tol == 0.0:
            return bool(checksum_tree(hw_out) == checksum_tree(sw_out))
        ok = True
        for a, b in zip(jax.tree_util.tree_leaves(hw_out),
                        jax.tree_util.tree_leaves(sw_out)):
            ok = ok and bool(jnp.all(jnp.isfinite(a))) and bool(
                jnp.max(jnp.abs(a.astype(jnp.float32) -
                                b.astype(jnp.float32))) <= stage.tol)
        return ok

    def localize(self, stage: Stage) -> Optional[lanefault.LaneFault]:
        """Lane-level localization: diff HW vs SW on the canary inputs and
        return a LaneFault when the mismatch is confined to a strict subset
        of the output's lane (minor) axis; None when the fault is not
        lane-shaped (whole-tile breakage -> binary SW quarantine)."""
        try:
            hw_out, sw_out = self._run_both(stage)
        except EXPECTED_STAGE_ERRORS as e:
            log.warning("canary: localize raised; not lane-shaped",
                        stage=stage.name, error=type(e).__name__,
                        detail=e)
            return None
        for a, b in zip(jax.tree_util.tree_leaves(hw_out),
                        jax.tree_util.tree_leaves(sw_out)):
            if (not hasattr(a, "dtype")
                    or not jnp.issubdtype(a.dtype, jnp.inexact)
                    or a.ndim < 1 or a.shape != b.shape):
                continue
            width = a.shape[-1]
            if width < 2:
                continue
            af = np.asarray(a, np.float32).reshape(-1, width)
            bf = np.asarray(b, np.float32).reshape(-1, width)
            diff = np.abs(af - bf)
            diff = np.where(np.isnan(diff), np.inf, diff)
            per_lane = diff.max(axis=0)
            bad = np.flatnonzero(per_lane > stage.tol)
            if bad.size == 0 or bad.size >= width:
                continue
            lanes = tuple(int(i) for i in bad)
            kind, value, gain = self._classify(af, bf, lanes)
            return lanefault.LaneFault(kind=kind, lanes=lanes, width=width,
                                       value=value, gain=gain)
        return None

    @staticmethod
    def _classify(hw: np.ndarray, sw: np.ndarray, lanes: Tuple[int, ...]):
        """Best-effort fault taxonomy from the observed lane values (only
        lanes/width drive routing; the kind is diagnostic)."""
        col = hw[:, lanes[0]]
        ref = sw[:, lanes[0]]
        if np.allclose(col, 0.0):
            return lanefault.DROPPED_MAC, 1.5, 1.25
        if col.size > 1 and np.allclose(col, col[0]):
            return lanefault.STUCK, float(col[0]), 1.25
        denom = np.where(np.abs(ref) > 1e-6, ref, 1.0)
        ratio = np.where(np.abs(ref) > 1e-6, col / denom, np.nan)
        g = float(np.nanmedian(ratio)) if np.isfinite(
            np.nanmedian(ratio)) else 1.25
        return lanefault.GAIN, 1.5, g

    def sweep(self, state: FaultState, replica: int = 0,
              step: int = 0) -> List[str]:
        found = []
        for s in self.stages:
            if not self.check_stage(s):
                kind = "canary"
                if self.auto_localize:
                    f = self.localize(s)
                    if f is not None:
                        lanefault.set_map(s.name, f, base=self.route_hw)
                        kind = "canary_localized"
                state.mark(s.name, replica, kind=kind, step=step)
                found.append(s.name)
        return found


class StepGuard:
    """NaN/Inf guard over step outputs (loss, grads)."""

    @staticmethod
    def ok(tree) -> bool:
        for leaf in jax.tree_util.tree_leaves(tree):
            if hasattr(leaf, "dtype") and jnp.issubdtype(leaf.dtype,
                                                         jnp.floating):
                if not bool(jnp.all(jnp.isfinite(leaf))):
                    return False
        return True


class StragglerWatchdog:
    """Flags replicas whose step time exceeds median * threshold."""

    def __init__(self, threshold: float = 2.0, window: int = 32):
        self.threshold = threshold
        self.window = window
        self.times: Dict[int, List[float]] = {}

    def record(self, replica: int, dt: float):
        self.times.setdefault(replica, []).append(dt)
        self.times[replica] = self.times[replica][-self.window:]

    def stragglers(self) -> List[int]:
        if not self.times:
            return []
        med = {r: float(np.median(v)) for r, v in self.times.items()}
        fleet_med = float(np.median(list(med.values())))
        if fleet_med <= 0:
            return []
        return [r for r, m in med.items() if m > self.threshold * fleet_med]
