"""Fault model, detection, and injection (paper §III-A; detection pluggable).

Fault granularity mirrors the paper: a *non-transient* fault quarantines one
(stage, replica) — the runtime must stop using the optimized path for that
stage there.  ``FaultSignature`` is the frozen stage->route map that keys a
compiled executable (the Cohort 2-bit queue config, lifted to SPMD).

Detectors (any can drive the runtime; "Oobleck does not dictate a
particular method of fault detection"):
  * CanaryChecker  — runs each stage's HW path against its SW oracle on
    deterministic canaries; compares via the Fig.-4 checksum kernel
    (bit-exact detection of integer/stuck-at faults) or allclose for
    floating-point contract violations.
  * StepGuard      — NaN/Inf validity predicates on step outputs.
  * StragglerWatchdog — robust-quantile step-time outlier detection.

Injection: ``FaultInjector`` corrupts a stage's HW path deterministically
(bitflip / stuck-at-zero / gain error) to emulate a datapath defect.
"""
from __future__ import annotations

import time
from dataclasses import dataclass
from typing import Callable, Dict, FrozenSet, List, Sequence, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from repro.kernels.checksum import checksum_tree
from repro.viscosity.lang import HW, SW
from repro.core.stage import Stage

OK = "ok"
FAULT = "fault"


@dataclass(frozen=True)
class FaultSignature:
    """Frozen stage -> route map. Healthy stages route HW, faulty SW."""
    routes: Tuple[Tuple[str, str], ...] = ()

    @staticmethod
    def healthy(stage_names: Sequence[str] = ()) -> "FaultSignature":
        return FaultSignature(tuple((s, HW) for s in stage_names))

    def as_dict(self) -> Dict[str, str]:
        return dict(self.routes)

    def with_fault(self, stage: str) -> "FaultSignature":
        d = self.as_dict()
        d[stage] = SW
        return FaultSignature(tuple(sorted(d.items())))

    def faulty(self) -> FrozenSet[str]:
        return frozenset(s for s, r in self.routes if r != HW)

    def n_faults(self) -> int:
        return len(self.faulty())


class FaultState:
    """Mutable fleet-side health registry: (stage, replica) -> status."""

    def __init__(self):
        self._bad: Dict[Tuple[str, int], str] = {}
        self.log: List[dict] = []

    def mark(self, stage: str, replica: int = 0, kind: str = "detected"):
        self._bad[(stage, replica)] = FAULT
        self.log.append({"stage": stage, "replica": replica, "kind": kind,
                         "t": time.time()})

    def is_faulty(self, stage: str, replica: int = 0) -> bool:
        return self._bad.get((stage, replica)) == FAULT

    def signature(self, stage_names: Sequence[str], replica: int = 0
                  ) -> FaultSignature:
        sig = FaultSignature.healthy(stage_names)
        for s in stage_names:
            if self.is_faulty(s, replica):
                sig = sig.with_fault(s)
        return sig

    def n_faults(self, replica: int = 0) -> int:
        return sum(1 for (s, r), v in self._bad.items()
                   if r == replica and v == FAULT)


# ------------------------------------------------------------- injection
@dataclass
class FaultInjector:
    """Wraps a stage's HW path with a deterministic corruption."""
    kind: str = "bitflip"     # bitflip | stuck_zero | gain
    magnitude: float = 1e-2

    def corrupt(self, out):
        def f(x):
            if not hasattr(x, "dtype") or not jnp.issubdtype(
                    x.dtype, jnp.inexact):   # floats AND complex
                return x
            if self.kind == "stuck_zero":
                return x.at[..., 0].set(0.0) if x.ndim else x * 0
            if self.kind == "gain":
                return x * (1.0 + self.magnitude)
            # bitflip: flip the sign of one fixed element
            flat = x.reshape(-1)
            flat = flat.at[flat.shape[0] // 2].multiply(-1.0)
            return flat.reshape(x.shape)
        return jax.tree_util.tree_map(f, out)

    def wrap(self, fn: Callable) -> Callable:
        def bad(*a, **kw):
            return self.corrupt(fn(*a, **kw))
        return bad


def inject(stage: Stage, kind: str = "bitflip",
           magnitude: float = 1e-2) -> Stage:
    inj = FaultInjector(kind=kind, magnitude=magnitude)
    return Stage(name=stage.name, spec=None, hw=inj.wrap(stage.hw),
                 sw=stage.sw, ports=stage.ports, tol=stage.tol)


# -------------------------------------------------------------- detectors
class CanaryChecker:
    """Per-stage HW-vs-SW canary compare (checksum or allclose)."""

    def __init__(self, stages: Sequence[Stage], *, seed: int = 0,
                 route_hw: str = HW):
        self.stages = list(stages)
        self.seed = seed
        self.route_hw = route_hw

    def check_stage(self, stage: Stage) -> bool:
        """True = healthy."""
        args = stage.canary_inputs(self.seed)
        try:
            hw_out = stage.run(*args, route=self.route_hw)
            sw_out = stage.run(*args, route=SW)
        except Exception:
            return False
        if stage.tol == 0.0:
            return bool(checksum_tree(hw_out) == checksum_tree(sw_out))
        ok = True
        for a, b in zip(jax.tree_util.tree_leaves(hw_out),
                        jax.tree_util.tree_leaves(sw_out)):
            ok = ok and bool(jnp.all(jnp.isfinite(a))) and bool(
                jnp.max(jnp.abs(a.astype(jnp.float32) -
                                b.astype(jnp.float32))) <= stage.tol)
        return ok

    def sweep(self, state: FaultState, replica: int = 0) -> List[str]:
        found = []
        for s in self.stages:
            if not self.check_stage(s):
                state.mark(s.name, replica, kind="canary")
                found.append(s.name)
        return found


class StepGuard:
    """NaN/Inf guard over step outputs (loss, grads)."""

    @staticmethod
    def ok(tree) -> bool:
        for leaf in jax.tree_util.tree_leaves(tree):
            if hasattr(leaf, "dtype") and jnp.issubdtype(leaf.dtype,
                                                         jnp.floating):
                if not bool(jnp.all(jnp.isfinite(leaf))):
                    return False
        return True


class StragglerWatchdog:
    """Flags replicas whose step time exceeds median * threshold."""

    def __init__(self, threshold: float = 2.0, window: int = 32):
        self.threshold = threshold
        self.window = window
        self.times: Dict[int, List[float]] = {}

    def record(self, replica: int, dt: float):
        self.times.setdefault(replica, []).append(dt)
        self.times[replica] = self.times[replica][-self.window:]

    def stragglers(self) -> List[int]:
        if not self.times:
            return []
        med = {r: float(np.median(v)) for r, v in self.times.items()}
        fleet_med = float(np.median(list(med.values())))
        if fleet_med <= 0:
            return []
        return [r for r, m in med.items() if m > self.threshold * fleet_med]
