"""Oobleck core: staged accelerators, fault routing, latency & fleet models."""
from repro.core.fault import (FaultInjector, FaultSignature, FaultState,
                              CanaryChecker, StepGuard, StragglerWatchdog,
                              inject)
from repro.core.oobleck import Dispatcher, StagedAccelerator
from repro.core.routing import (FleetPlan, ResidentRoute, RoutingPlan,
                                SparePool)
from repro.core.stage import Stage

__all__ = ["Stage", "StagedAccelerator", "Dispatcher", "FaultSignature",
           "FaultState", "FaultInjector", "CanaryChecker", "StepGuard",
           "StragglerWatchdog", "inject", "RoutingPlan", "ResidentRoute",
           "FleetPlan", "SparePool"]
