"""The paper's latency/performance model (§III-A, §V, Figs. 5–8).

Model (the paper's own statement): under k faulty stages, execution time is

    T = Σ_healthy hw_stage_i  +  Σ_faulty fb_stage_i  +  crossings · t_q

where ``fb_stage`` is the *fallback* time of the faulty stage (software, or
software/fpga_speedup for a hot-spare FPGA), ``t_q`` the Cohort-queue
transmission latency per software hand-off, and the crossing count is
2 (operands in / results out) plus 2 per contiguous faulty segment.

Identifiability note (documented honestly): the paper does not publish
t_q or per-stage fallback cycles for every case study; where needed we FIT
(fb_stage, t_q) to the two reported operating points of each case study and
check plausibility (Σ fb_stage within ~0.6–1.2× of the monolithic software
time — per-stage fallbacks are cache-hot and tighter than the monolithic
baseline, which is why e.g. FFT's reported numbers imply Σ fb < T_sw).
All qualitative claims of Figs. 6–8 are reproduced without fitting.
"""
from __future__ import annotations

from dataclasses import dataclass
from typing import Sequence, Tuple



@dataclass(frozen=True)
class AccelModel:
    name: str
    n_stages: int
    sw_total: float                   # monolithic software cycles (baseline)
    hw_stage: Tuple[float, ...]       # per-stage hardware cycles
    fb_stage: Tuple[float, ...]       # per-stage software-fallback cycles
    t_q: float                        # transmission cycles per crossing

    @staticmethod
    def uniform(name, n_stages, sw_total, *, hw_total=None, fb_total=None,
                t_q=0.0, hw_speedup=100.0):
        hw_total = hw_total if hw_total is not None else sw_total / hw_speedup
        fb_total = fb_total if fb_total is not None else sw_total
        return AccelModel(
            name=name, n_stages=n_stages, sw_total=float(sw_total),
            hw_stage=tuple([hw_total / n_stages] * n_stages),
            fb_stage=tuple([fb_total / n_stages] * n_stages),
            t_q=float(t_q))


def _crossings(n_stages: int, faulty: Sequence[int]) -> int:
    """2 base crossings + 2 per contiguous faulty segment."""
    segs = 0
    prev = False
    for i in range(n_stages):
        f = i in faulty
        if f and not prev:
            segs += 1
        prev = f
    return 2 + 2 * segs


def exec_time(m: AccelModel, faulty: Sequence[int] = (),
              fallback_speedup: float = 1.0,
              direct_fallback: bool = False) -> float:
    """Cycles for one invocation with ``faulty`` stages on the fallback.

    ``fallback_speedup`` > 1 models the hot-spare FPGA (§V-F): the faulty
    stage runs at fb_stage / fallback_speedup.  By default the data is
    routed *through software* (Fig. 8: extra crossings — the paper's
    bottleneck); ``direct_fallback`` models the §V-G "connected directly"
    hot spare (no extra crossings), which is what reaches ~80% of the
    original accelerator speed.
    """
    faulty = set(faulty)
    assert all(0 <= i < m.n_stages for i in faulty)
    t = 0.0
    for i in range(m.n_stages):
        if i in faulty:
            t += m.fb_stage[i] / fallback_speedup
        else:
            t += m.hw_stage[i]
    crossings = 2 if direct_fallback else _crossings(m.n_stages, faulty)
    return t + crossings * m.t_q


def speedup_vs_sw(m: AccelModel, faulty: Sequence[int] = (),
                  fallback_speedup: float = 1.0,
                  direct_fallback: bool = False) -> float:
    return m.sw_total / exec_time(m, faulty, fallback_speedup,
                                  direct_fallback)


def throughput_factor(m: AccelModel, n_faults: int,
                      fallback_speedup: float = 1.0) -> float:
    """Relative throughput (vs. no-fault accelerator) under n worst-case
    distinct-stage faults — the VFA degradation curve for the fleet model."""
    if n_faults >= m.n_stages:
        return 0.0
    faulty = list(range(n_faults))  # uniform stages: placement irrelevant
    return exec_time(m, ()) / exec_time(m, faulty, fallback_speedup)


# ------------------------------------------------------- case studies (§V)
def fit_two_point(name: str, n_stages: int, frac_nofault: float,
                  frac_onefault: float, sw_total: float = 1.0,
                  t_q_frac: float = 0.005) -> AccelModel:
    """Solve (hw_stage, fb_stage) from the two reported operating points:
    T0 = sw_total*frac_nofault,  T1 = sw_total*frac_onefault, given t_q."""
    t_q = t_q_frac * sw_total
    T0 = frac_nofault * sw_total
    T1 = frac_onefault * sw_total
    hw_total = T0 - 2 * t_q
    hw_stage = hw_total / n_stages
    # T1 = (n-1)*hw_stage + fb + 4*t_q
    fb = T1 - (n_stages - 1) * hw_stage - 4 * t_q
    assert hw_stage > 0 and fb > 0, (name, hw_stage, fb)
    return AccelModel(name=name, n_stages=n_stages, sw_total=sw_total,
                      hw_stage=tuple([hw_stage] * n_stages),
                      fb_stage=tuple([fb] * n_stages), t_q=t_q)


# Reported operating points (Fig. 5): exec time as % of software.
FFT_REPORTED = dict(n_stages=6, frac_nofault=0.074, frac_onefault=0.193)
DCT_REPORTED = dict(n_stages=10, frac_nofault=0.189,
                    frac_onefault=1.0 / 2.87)
AES_REPORTED = dict(n_stages=3, frac_onefault=0.58)   # no-fault frac not given


def fft_model() -> AccelModel:
    return fit_two_point("fft", **FFT_REPORTED)


def dct_model() -> AccelModel:
    return fit_two_point("dct", **DCT_REPORTED)


def aes_model(n_stages: int = 3) -> AccelModel:
    """AES: per-stage fallback given in the paper (~17,788 cycles for the
    3-stage config; ~5,000 for 11-stage); accelerator latency is small and
    transmission dominates ("stage count has generally no effect")."""
    fb = 17_788.0 if n_stages == 3 else 5_000.0
    sw_total = fb * n_stages if n_stages == 3 else 55_000.0
    # Cohort hand-off cycles at 67 MHz, calibrated so BOTH configs hit the
    # paper's "58% of software under one fault / stage count has generally
    # no effect" claim (the 11-stage build crosses more queue hops).
    t_q = 3_200.0 if n_stages == 3 else 6_400.0
    hw_stage = 120.0
    return AccelModel(name=f"aes{n_stages}", n_stages=n_stages,
                      sw_total=sw_total,
                      hw_stage=tuple([hw_stage] * n_stages),
                      fb_stage=tuple([fb] * n_stages), t_q=t_q)


# --------------------------------------------------- pass-through sweeps
def passthrough_model(op_cycles: float, n_stages: int, *,
                      hw_stage_cycles: float = 100.0,
                      fb_frac: float = 1.0, t_q: float = 1200.0
                      ) -> AccelModel:
    """Fig. 6/7 pass-through accelerator: each hw stage ~100 cycles;
    fallback per stage = fb_frac * op/n (fb_frac < 1: cache-hot stage
    binaries, as implied by the case-study data)."""
    return AccelModel(
        name=f"pt{op_cycles}x{n_stages}", n_stages=n_stages,
        sw_total=float(op_cycles),
        hw_stage=tuple([hw_stage_cycles] * n_stages),
        fb_stage=tuple([fb_frac * op_cycles / n_stages] * n_stages),
        t_q=t_q)
