"""The paper's case studies (§V) as staged JAX accelerators.

Each case study is a StagedAccelerator whose stage decomposition follows
the paper: FFT = 6 butterfly stages (radix-2 DIT, N=64); AES-128 = 11
stages (initial AddRoundKey + 9 full rounds + final round) or 3 stages
(keyexp+2 rounds / 4 rounds / 4 rounds + final); DCT = 10-stage 2-D 8x8
butterfly pipeline (rows -> transpose -> cols -> transpose -> scale).

Here both lowerings of a stage are the same jnp math (the Viscosity
equivalence contract is trivially exact); what distinguishes HW from SW at
runtime is the *latency model* (core/latency.py) and fault injection —
exactly the role the pass-through accelerator plays in the paper.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
import numpy as np

from repro.core.oobleck import StagedAccelerator
from repro.core.stage import Stage


# ================================================================== FFT
def _bit_reverse_perm(n: int) -> np.ndarray:
    bits = n.bit_length() - 1
    idx = np.arange(n)
    rev = np.zeros_like(idx)
    for b in range(bits):
        rev |= ((idx >> b) & 1) << (bits - 1 - b)
    return rev


def _fft_stage(x: jax.Array, stage: int, n: int) -> jax.Array:
    """One radix-2 DIT butterfly stage on (..., n) complex."""
    m = 2 << stage                     # butterfly span after this stage
    half = m // 2
    k = jnp.arange(half)
    tw = jnp.exp(-2j * jnp.pi * k / m).astype(x.dtype)
    xs = x.reshape(x.shape[:-1] + (n // m, m))
    even = xs[..., :half]
    odd = xs[..., half:] * tw
    out = jnp.concatenate([even + odd, even - odd], axis=-1)
    return out.reshape(x.shape)


def fft_accelerator(n: int = 64) -> StagedAccelerator:
    stages_n = n.bit_length() - 1
    perm = jnp.asarray(_bit_reverse_perm(n))
    port = (jax.ShapeDtypeStruct((4, n), jnp.complex64),)

    def mk(idx):
        if idx == 0:
            def f(x):
                return _fft_stage(jnp.take(x, perm, axis=-1), 0, n)
        else:
            f = functools.partial(_fft_stage, stage=idx, n=n)
        return Stage(name=f"fft_s{idx}", sw=f, hw=f, ports=port, tol=1e-4)

    return StagedAccelerator("fft", [mk(i) for i in range(stages_n)])


def fft_reference(x):
    return jnp.fft.fft(x, axis=-1)


# ================================================================== AES
_SBOX = np.array([
    0x63,0x7c,0x77,0x7b,0xf2,0x6b,0x6f,0xc5,0x30,0x01,0x67,0x2b,0xfe,0xd7,0xab,0x76,
    0xca,0x82,0xc9,0x7d,0xfa,0x59,0x47,0xf0,0xad,0xd4,0xa2,0xaf,0x9c,0xa4,0x72,0xc0,
    0xb7,0xfd,0x93,0x26,0x36,0x3f,0xf7,0xcc,0x34,0xa5,0xe5,0xf1,0x71,0xd8,0x31,0x15,
    0x04,0xc7,0x23,0xc3,0x18,0x96,0x05,0x9a,0x07,0x12,0x80,0xe2,0xeb,0x27,0xb2,0x75,
    0x09,0x83,0x2c,0x1a,0x1b,0x6e,0x5a,0xa0,0x52,0x3b,0xd6,0xb3,0x29,0xe3,0x2f,0x84,
    0x53,0xd1,0x00,0xed,0x20,0xfc,0xb1,0x5b,0x6a,0xcb,0xbe,0x39,0x4a,0x4c,0x58,0xcf,
    0xd0,0xef,0xaa,0xfb,0x43,0x4d,0x33,0x85,0x45,0xf9,0x02,0x7f,0x50,0x3c,0x9f,0xa8,
    0x51,0xa3,0x40,0x8f,0x92,0x9d,0x38,0xf5,0xbc,0xb6,0xda,0x21,0x10,0xff,0xf3,0xd2,
    0xcd,0x0c,0x13,0xec,0x5f,0x97,0x44,0x17,0xc4,0xa7,0x7e,0x3d,0x64,0x5d,0x19,0x73,
    0x60,0x81,0x4f,0xdc,0x22,0x2a,0x90,0x88,0x46,0xee,0xb8,0x14,0xde,0x5e,0x0b,0xdb,
    0xe0,0x32,0x3a,0x0a,0x49,0x06,0x24,0x5c,0xc2,0xd3,0xac,0x62,0x91,0x95,0xe4,0x79,
    0xe7,0xc8,0x37,0x6d,0x8d,0xd5,0x4e,0xa9,0x6c,0x56,0xf4,0xea,0x65,0x7a,0xae,0x08,
    0xba,0x78,0x25,0x2e,0x1c,0xa6,0xb4,0xc6,0xe8,0xdd,0x74,0x1f,0x4b,0xbd,0x8b,0x8a,
    0x70,0x3e,0xb5,0x66,0x48,0x03,0xf6,0x0e,0x61,0x35,0x57,0xb9,0x86,0xc1,0x1d,0x9e,
    0xe1,0xf8,0x98,0x11,0x69,0xd9,0x8e,0x94,0x9b,0x1e,0x87,0xe9,0xce,0x55,0x28,0xdf,
    0x8c,0xa1,0x89,0x0d,0xbf,0xe6,0x42,0x68,0x41,0x99,0x2d,0x0f,0xb0,0x54,0xbb,0x16],
    dtype=np.uint8)
_SHIFT = np.array([0, 5, 10, 15, 4, 9, 14, 3, 8, 13, 2, 7, 12, 1, 6, 11])
_RCON = np.array([0x01,0x02,0x04,0x08,0x10,0x20,0x40,0x80,0x1b,0x36],
                 dtype=np.uint8)


def aes_key_schedule(key16: np.ndarray) -> np.ndarray:
    """(16,) uint8 -> (11, 16) round keys (host-side, numpy)."""
    w = [key16[i * 4:(i + 1) * 4].copy() for i in range(4)]
    for i in range(4, 44):
        t = w[i - 1].copy()
        if i % 4 == 0:
            t = np.roll(t, -1)
            t = _SBOX[t]
            t[0] ^= _RCON[i // 4 - 1]
        w.append(w[i - 4] ^ t)
    return np.stack([np.concatenate(w[4 * r:4 * r + 4]) for r in range(11)])


def _sub_bytes(x):
    return jnp.take(jnp.asarray(_SBOX), x.astype(jnp.int32)).astype(jnp.uint8)


def _shift_rows(x):
    return x[..., jnp.asarray(_SHIFT)]


def _xtime(b):
    hi = (b >> 7) & 1
    return ((b << 1) & 0xFF) ^ (hi * 0x1B)


def _mix_columns(x):
    s = x.reshape(x.shape[:-1] + (4, 4))           # 4 columns of 4 bytes
    a0, a1, a2, a3 = s[..., 0], s[..., 1], s[..., 2], s[..., 3]
    t = a0 ^ a1 ^ a2 ^ a3
    m0 = a0 ^ t ^ _xtime(a0 ^ a1)
    m1 = a1 ^ t ^ _xtime(a1 ^ a2)
    m2 = a2 ^ t ^ _xtime(a2 ^ a3)
    m3 = a3 ^ t ^ _xtime(a3 ^ a0)
    return jnp.stack([m0, m1, m2, m3], axis=-1).reshape(x.shape)


def _aes_round(x, rk, *, final=False):
    x = _sub_bytes(x)
    x = _shift_rows(x)
    if not final:
        x = _mix_columns(x)
    return x ^ rk


def aes_accelerator(key16: np.ndarray, n_stages: int = 11
                    ) -> StagedAccelerator:
    rks = jnp.asarray(aes_key_schedule(np.asarray(key16, np.uint8)))
    port = (jax.ShapeDtypeStruct((4, 16), jnp.uint8),)

    def round_fn(r):
        def f(x):
            if r == 0:
                return x ^ rks[0]
            return _aes_round(x, rks[r], final=(r == 10))
        return f

    rounds = [round_fn(r) for r in range(11)]
    if n_stages == 11:
        groups = [[r] for r in range(11)]
    elif n_stages == 3:
        # paper: keyexp + first two rounds | 4 rounds | 4 rounds (+final)
        groups = [[0, 1, 2], [3, 4, 5, 6], [7, 8, 9, 10]]
    else:
        raise ValueError(n_stages)

    def compose(idxs):
        def f(x):
            for r in idxs:
                x = rounds[r](x)
            return x
        return f

    stages = [Stage(name=f"aes_s{i}", sw=compose(g), hw=compose(g),
                    ports=port, tol=0.0)
              for i, g in enumerate(groups)]
    return StagedAccelerator(f"aes{n_stages}", stages)


# ================================================================== DCT
_C = np.array([np.cos(np.pi * k / 16) for k in range(8)])  # C_k = cos(k pi/16)


def _dct8_butterfly1(x):
    """x (..., 8): even/odd split butterflies (a = x_i + x_{7-i}, b = diff)."""
    xr = x[..., ::-1]
    a = x[..., :4] + xr[..., :4]
    b = x[..., :4] - xr[..., :4]
    return jnp.concatenate([a, b], axis=-1)


def _dct8_butterfly2(x):
    a, b = x[..., :4], x[..., 4:]
    c0 = a[..., 0] + a[..., 3]
    c1 = a[..., 1] + a[..., 2]
    c2 = a[..., 1] - a[..., 2]
    c3 = a[..., 0] - a[..., 3]
    return jnp.concatenate([jnp.stack([c0, c1, c2, c3], -1), b], axis=-1)


_ODD = np.zeros((4, 4))
for _k, _xk in enumerate((1, 3, 5, 7)):
    for _n in range(4):
        _ODD[_k, _n] = np.cos(np.pi * (2 * _n + 1) * _xk / 16)


def _dct8_rotate(x):
    """Unnormalized 8-pt DCT-II outputs: X_k = sum_n x_n cos(pi(2n+1)k/16)."""
    c, b = x[..., :4], x[..., 4:]
    X0 = c[..., 0] + c[..., 1]
    X4 = (c[..., 0] - c[..., 1]) * _C[4]
    X2 = c[..., 3] * _C[2] + c[..., 2] * _C[6]
    X6 = c[..., 3] * _C[6] - c[..., 2] * _C[2]
    odd = jnp.einsum("...n,kn->...k", b, jnp.asarray(_ODD, np.float32))
    return jnp.stack([X0, odd[..., 0], X2, odd[..., 1], X4, odd[..., 2],
                      X6, odd[..., 3]], axis=-1)


def _transpose88(x):
    return jnp.swapaxes(x, -1, -2)


def dct_accelerator() -> StagedAccelerator:
    """10-stage 2-D 8x8 DCT-II: 3 row butterfly stages, transpose, 3 column
    stages, transpose, 2 scaling stages (JPEG quant-prep split)."""
    port = (jax.ShapeDtypeStruct((4, 8, 8), jnp.float32),)
    def scale1(x):
        return x * 0.5              # row-pass normalization

    def scale2(x):
        return x * 0.5              # column-pass normalization
    fns = [
        _dct8_butterfly1, _dct8_butterfly2, _dct8_rotate, _transpose88,
        _dct8_butterfly1, _dct8_butterfly2, _dct8_rotate, _transpose88,
        scale1, scale2,
    ]
    stages = [Stage(name=f"dct_s{i}", sw=f, hw=f, ports=port, tol=1e-4)
              for i, f in enumerate(fns)]
    return StagedAccelerator("dct", stages)


def dct_reference(x):
    """Direct 2-D DCT-II with the same normalization (x 1/4 overall)."""
    M = np.zeros((8, 8))
    for k in range(8):
        for n in range(8):
            M[k, n] = np.cos(np.pi * (2 * n + 1) * k / 16)
    M = jnp.asarray(M, jnp.float32)
    y = jnp.einsum("kn,...nj->...kj", M, x)   # columns (axis -2)
    y = jnp.einsum("kn,...jn->...jk", M, y)   # rows
    return y * 0.25
