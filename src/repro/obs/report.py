"""Fleet-health reporter over a metrics+trace snapshot.

Reads the deterministic snapshot ``obs.metrics.Registry.snapshot()``
produces (plus, optionally, a merged trace) and renders the rollup the
harnesses used to recompute ad hoc: ladder-rung occupancy vs the
``DegradationModel`` story, per-section MTTR (mean/max — *exactly* the
numbers ``chaos_bench`` previously computed from its private counters,
because histograms keep exact sum/min/max in observation order), and
per-section goodput (*exactly* ``serve.frontend.summarize``'s value,
because ``goodput_tok_s = goodput_tokens_total / max(virtual_time,
1e-9)`` is the same division over the same operands).

``python -m repro.obs.report snapshot.json`` pretty-prints the health
report for a snapshot file written by ``benchmarks/chaos_bench.py
--telemetry`` (either the bare metrics snapshot or the
``{"metrics": ..., "trace": ...}`` wrapper).
"""
from __future__ import annotations

import json
import sys
from typing import Any, Dict, List, Mapping, Optional, Sequence

from repro.obs import trace as _trace


# ------------------------------------------------------ snapshot access
def family(snap: Mapping, name: str) -> Optional[Dict]:
    for fam in snap.get("families", ()):
        if fam.get("name") == name:
            return fam
    return None


def families(snap: Mapping) -> List[str]:
    """Sorted family names present — what ``benchmarks/compare.py``
    checks for missing metric families."""
    return sorted(f.get("name", "") for f in snap.get("families", ()))


def _match(sample: Mapping, labels: Mapping[str, str]) -> bool:
    have = sample.get("labels", {})
    return all(have.get(k) == str(v) for k, v in labels.items())


def counter_value(snap: Mapping, name: str, **labels) -> float:
    fam = family(snap, name)
    if fam is None:
        return 0.0
    return sum(s["value"] for s in fam["samples"] if _match(s, labels))


def gauge_value(snap: Mapping, name: str, default: float = 0.0,
                **labels) -> float:
    fam = family(snap, name)
    if fam is None:
        return default
    vals = [s["value"] for s in fam["samples"] if _match(s, labels)]
    return vals[-1] if vals else default


def hist_stats(snap: Mapping, name: str, **labels) -> Dict[str, Any]:
    """count/sum/min/max for the single histogram child matching
    ``labels`` (exact-reproduction accessor: refuses to merge children,
    whose float sums would not reassociate exactly)."""
    fam = family(snap, name)
    empty = {"count": 0, "sum": 0.0, "min": None, "max": None}
    if fam is None:
        return empty
    rows = [s for s in fam["samples"] if _match(s, labels)]
    if not rows:
        return empty
    if len(rows) > 1:
        raise ValueError(
            f"{name}{dict(labels)} matches {len(rows)} histogram "
            f"children; narrow the labels (exact stats do not merge)")
    r = rows[0]
    return {"count": r["count"], "sum": r["sum"], "min": r["min"],
            "max": r["max"]}


def label_values(snap: Mapping, name: str, label: str) -> List[str]:
    fam = family(snap, name)
    if fam is None:
        return []
    return sorted({s.get("labels", {}).get(label, "")
                   for s in fam["samples"]})


# ------------------------------------------------- derived statistics
def mttr_summary(snap: Mapping, *, section: str = ""
                 ) -> Optional[Dict[str, Any]]:
    """``{"n", "mean_s", "max_s"}`` with the same arithmetic and
    rounding as ``chaos.invariants.mttr_summary`` over the per-event
    records — reproduced from the ``mttr_seconds`` histogram alone."""
    st = hist_stats(snap, "mttr_seconds", section=section)
    if not st["count"]:
        return None
    return {"n": st["count"],
            "mean_s": round(st["sum"] / st["count"], 4),
            "max_s": round(st["max"], 4)}


def goodput_summary(snap: Mapping, *, section: str = ""
                    ) -> Dict[str, Any]:
    """The counters half of ``serve.frontend.summarize`` — goodput /
    throughput are bit-equal to the in-run values (same division over
    the same operands)."""
    span = max(gauge_value(snap, "serve_virtual_time_seconds",
                           section=section), 1e-9)

    def c(name: str) -> float:
        return counter_value(snap, name, section=section)

    return {
        "completed": int(c("serve_completed_total")),
        "deadline_met": int(c("serve_deadline_met_total")),
        "expired": int(c("serve_expired_total")),
        "goodput_tokens": int(c("serve_goodput_tokens_total")),
        "goodput_tok_s": c("serve_goodput_tokens_total") / span,
        "throughput_tok_s": c("serve_tokens_total") / span,
        "virtual_time_s": gauge_value(snap, "serve_virtual_time_seconds",
                                      section=section),
        "admitted": int(c("serve_admitted_total")),
        "shed": int(c("serve_shed_total")),
    }


def rung_occupancy(snap: Mapping) -> Dict[str, int]:
    fam = family(snap, "fleet_rung_devices")
    if fam is None:
        return {}
    return {s["labels"].get("rung", ""): int(s["value"])
            for s in fam["samples"]}


def closure(snap: Mapping, *, tol: float = 0.15
            ) -> Optional[Dict[str, Any]]:
    """Measured-vs-DegradationModel throughput-ratio comparison (the
    gauges ``chaos.campaign.closure_scenario`` records)."""
    fam = family(snap, "closure_ratio")
    if fam is None or not fam["samples"]:
        return None
    measured = gauge_value(snap, "closure_ratio", source="measured")
    analytic = gauge_value(snap, "closure_ratio", source="analytic")
    rel_err = abs(measured - analytic) / max(abs(analytic), 1e-9)
    return {"measured_ratio": round(measured, 4),
            "analytic_ratio": round(analytic, 4),
            "rel_err": round(rel_err, 4), "ok": rel_err <= tol,
            "tol": tol}


def kv_retry_totals(snap: Mapping) -> Dict[str, float]:
    fam = family(snap, "kv_retries_total")
    if fam is None:
        return {}
    return {s["labels"].get("op", ""): s["value"]
            for s in fam["samples"]}


# ------------------------------------------------------- health rollup
def fleet_health(snap: Mapping,
                 trace_events: Sequence[_trace.TraceEvent] = ()
                 ) -> Dict[str, Any]:
    """The full health document: one dict, one schema, consumed by the
    benches and the CI telemetry smoke step."""
    fault_fam = family(snap, "fault_events_total") or {"samples": []}
    verdict_fam = family(snap, "probation_verdicts_total") \
        or {"samples": []}
    sections = sorted(set(label_values(snap, "mttr_seconds", "section")
                          + label_values(snap,
                                         "serve_virtual_time_seconds",
                                         "section")) - {""})
    spans = _trace.spans_of(trace_events) if trace_events else ()
    return {
        "schema": "repro.health.v1",
        "families": families(snap),
        "rungs": rung_occupancy(snap),
        "faults": {
            f'{s["labels"].get("kind", "")}:{s["labels"].get("stage", "")}':
                int(s["value"]) for s in fault_fam["samples"]},
        "probation": {s["labels"].get("verdict", ""): int(s["value"])
                      for s in verdict_fam["samples"]},
        "mttr": {sec: mttr_summary(snap, section=sec)
                 for sec in sections
                 if mttr_summary(snap, section=sec) is not None},
        "serve": {sec: goodput_summary(snap, section=sec)
                  for sec in sections
                  if gauge_value(snap, "serve_virtual_time_seconds",
                                 section=sec) > 0.0},
        "dispatch": {
            "hits": int(counter_value(snap, "dispatch_cache_hits_total")),
            "misses": int(counter_value(snap,
                                        "dispatch_cache_misses_total")),
        },
        "coordination": {
            "kv_retries": kv_retry_totals(snap),
            "timeouts": int(counter_value(snap, "coord_timeouts_total")),
        },
        "closure": closure(snap),
        "trace": {"events": len(trace_events),
                  "spans": len(spans),
                  "open_spans": sum(1 for s in spans if s.end is None)},
    }


def render(health: Mapping) -> str:
    """Human-readable fleet-health text block."""
    out: List[str] = ["== fleet health =="]
    if health.get("rungs"):
        occ = " ".join(f"{k}={v}"
                       for k, v in sorted(health["rungs"].items()))
        out.append(f"ladder      {occ}")
    if health.get("probation"):
        out.append("probation   " + " ".join(
            f"{k}={v}" for k, v in sorted(health["probation"].items())))
    for sec, m in sorted(health.get("mttr", {}).items()):
        out.append(f"mttr[{sec}]  n={m['n']} mean={m['mean_s']}s "
                   f"max={m['max_s']}s")
    for sec, g in sorted(health.get("serve", {}).items()):
        out.append(f"serve[{sec}]  goodput={g['goodput_tok_s']:.2f}tok/s "
                   f"met={g['deadline_met']}/{g['completed']} "
                   f"expired={g['expired']}")
    d = health.get("dispatch", {})
    out.append(f"dispatch    hits={d.get('hits', 0)} "
               f"misses={d.get('misses', 0)}")
    c = health.get("coordination", {})
    retries = sum(c.get("kv_retries", {}).values())
    out.append(f"coord       kv_retries={int(retries)} "
               f"timeouts={c.get('timeouts', 0)}")
    if health.get("closure"):
        cl = health["closure"]
        out.append(f"closure     measured={cl['measured_ratio']} "
                   f"analytic={cl['analytic_ratio']} "
                   f"rel_err={cl['rel_err']} ok={cl['ok']}")
    t = health.get("trace", {})
    if t.get("events"):
        out.append(f"trace       events={t['events']} "
                   f"spans={t['spans']} open={t['open_spans']}")
    return "\n".join(out) + "\n"


def load_snapshot(path: str) -> Dict[str, Any]:
    """Read a telemetry file: either a bare metrics snapshot or the
    ``{"metrics": ..., "trace": "<jsonl>"}`` wrapper the benches
    write; returns ``{"metrics": snap, "trace": (events,)}``."""
    with open(path) as f:
        doc = json.load(f)
    if "families" in doc:
        return {"metrics": doc, "trace": ()}
    tr = doc.get("trace", "")
    events = _trace.from_jsonl(tr) if isinstance(tr, str) else \
        tuple(_trace.TraceEvent.from_wire(e) for e in tr)
    return {"metrics": doc.get("metrics", {"families": []}),
            "trace": events}


def main(argv: Sequence[str] = ()) -> int:
    argv = list(argv) or sys.argv[1:]
    if not argv:
        sys.stdout.write("usage: python -m repro.obs.report "
                         "<telemetry.json> [--json]\n")
        return 2
    doc = load_snapshot(argv[0])
    health = fleet_health(doc["metrics"], doc["trace"])
    if "--json" in argv[1:]:
        sys.stdout.write(json.dumps(health, indent=2, sort_keys=True)
                         + "\n")
    else:
        sys.stdout.write(render(health))
    return 0


if __name__ == "__main__":
    raise SystemExit(main())
