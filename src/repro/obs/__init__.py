"""Fleet telemetry: typed metrics, logical-clock trace spans, and the
health/MTTR reporter.

``obs.metrics``  process-local Counter/Gauge/Histogram registry
                 (JSONL snapshots + Prometheus text format).
``obs.trace``    per-request spans keyed by the ``(step, origin, seq)``
                 logical clock; merges are byte-identical under any
                 arrival interleaving (the FleetEvent-log contract).
``obs.logging``  the one structured logger every layer logs through.
``obs.report``   renders a metrics+trace snapshot into the fleet-health
                 / capacity-vs-DegradationModel comparison the benches
                 consume.
"""
from repro.obs import logging, metrics, report, trace  # noqa: F401

__all__ = ["logging", "metrics", "report", "trace"]
