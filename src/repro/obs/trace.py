"""Logical-clock trace spans.

A trace is an ordered log of :class:`TraceEvent` records keyed by the
same ``(step, origin, seq)`` logical clock the FleetEvent log and
``FaultState`` stamps already use: ``step`` is the engine step the
event belongs to, ``origin`` the emitting host, ``seq`` a per-origin
monotone counter.  Merging traces from different hosts is the same
sorted-dedup union the event log property-tests — so the merged,
serialized trace is **byte-identical regardless of arrival
interleaving** (:func:`merge` + :func:`to_jsonl`).

Span lifecycle (per request)::

    admit                submit              ...ticks...      complete
    span_start ──────────▶ annot ──────────────▶ annot ──────▶ span_end
    (frontend release)    (engine slot)        (decode_tick)  (poll)

plus out-of-band annotations for faults, probation episodes and
ladder-rung transitions.  :func:`spans_of` pairs ``span_start`` /
``span_end`` events by name; detect→recover pairs are how the MTTR
histogram in ``obs.metrics`` is derived.
"""
from __future__ import annotations

import json
from contextlib import contextmanager
from dataclasses import dataclass, field
from typing import (Any, Dict, Iterable, Iterator, List, Optional,
                    Sequence, Tuple)

SPAN_START = "span_start"
SPAN_END = "span_end"
ANNOT = "annot"
_KINDS = (SPAN_START, SPAN_END, ANNOT)


@dataclass(frozen=True, order=True)
class TraceEvent:
    """One trace record.  Ordering/equality is the logical-clock total
    order first — exactly the FleetEvent merge contract."""
    step: int
    origin: int
    seq: int
    kind: str = ANNOT
    name: str = ""
    attrs: Tuple[Tuple[str, Any], ...] = field(default_factory=tuple)

    def __post_init__(self):
        if self.kind not in _KINDS:
            raise ValueError(f"unknown trace kind {self.kind!r}; one "
                             f"of {_KINDS}")

    def to_wire(self) -> Dict[str, Any]:
        return {"step": self.step, "origin": self.origin,
                "seq": self.seq, "kind": self.kind, "name": self.name,
                "attrs": dict(self.attrs)}

    @staticmethod
    def from_wire(doc: Dict[str, Any]) -> "TraceEvent":
        return TraceEvent(step=int(doc["step"]),
                          origin=int(doc["origin"]),
                          seq=int(doc["seq"]), kind=str(doc["kind"]),
                          name=str(doc.get("name", "")),
                          attrs=_freeze(doc.get("attrs", {})))


def _freeze(attrs: Dict[str, Any]) -> Tuple[Tuple[str, Any], ...]:
    out = []
    for k in sorted(attrs):
        v = attrs[k]
        if not isinstance(v, (str, int, float, bool, type(None))):
            v = str(v)
        out.append((str(k), v))
    return tuple(out)


class Tracer:
    """Per-origin emitter: stamps every event with the next ``seq`` so
    intra-host emission order is total, like ``FaultState._stamp``."""

    def __init__(self, origin: int = 0):
        self.origin = int(origin)
        self.seq = 0
        self.events: List[TraceEvent] = []

    def emit(self, step: int, kind: str = ANNOT, name: str = "",
             **attrs) -> TraceEvent:
        ev = TraceEvent(step=int(step), origin=self.origin,
                        seq=self.seq, kind=kind, name=name,
                        attrs=_freeze(attrs))
        self.seq += 1
        self.events.append(ev)
        return ev

    def span_start(self, step: int, name: str, **attrs) -> TraceEvent:
        return self.emit(step, SPAN_START, name, **attrs)

    def span_end(self, step: int, name: str, **attrs) -> TraceEvent:
        return self.emit(step, SPAN_END, name, **attrs)

    def annotate(self, step: int, name: str, **attrs) -> TraceEvent:
        return self.emit(step, ANNOT, name, **attrs)


# ------------------------------------------------------------- merging
def merge(*logs: Iterable[TraceEvent]) -> Tuple[TraceEvent, ...]:
    """Sorted-dedup union over any number of (partial, overlapping)
    per-host logs — same algebra as ``merge_event_logs`` /
    ``FaultState.merge_logs``, so the result is one value no matter how
    the inputs were interleaved or duplicated in transit."""
    seen: Dict[Tuple[int, int, int], TraceEvent] = {}
    for log in logs:
        for ev in log:
            seen.setdefault((ev.step, ev.origin, ev.seq), ev)
    return tuple(seen[k] for k in sorted(seen))


def to_jsonl(events: Sequence[TraceEvent]) -> str:
    """Canonical serialization (sorted keys, no spaces): the byte-
    identity surface the 2-host merge contract is asserted on."""
    return "".join(json.dumps(ev.to_wire(), sort_keys=True,
                              separators=(",", ":")) + "\n"
                   for ev in events)


def from_jsonl(text: str) -> Tuple[TraceEvent, ...]:
    return tuple(TraceEvent.from_wire(json.loads(line))
                 for line in text.splitlines() if line.strip())


def from_fleet_log(events, origin_attr: str = "origin"
                   ) -> Tuple[TraceEvent, ...]:
    """Lift a ``launch.distributed.FleetEvent`` log into trace
    annotations (``fleet:<kind>``) so fault history and request spans
    merge into one ordered trace."""
    out = []
    for ev in events:
        out.append(TraceEvent(
            step=ev.step, origin=ev.origin, seq=ev.seq, kind=ANNOT,
            name=f"fleet:{ev.kind}",
            attrs=_freeze({"device": ev.device, "stage": ev.stage})))
    return tuple(out)


# --------------------------------------------------------------- spans
@dataclass(frozen=True)
class Span:
    """A paired ``span_start``/``span_end`` (``end`` is None while
    open).  ``steps`` is the logical duration — multiply by the run's
    ``step_time_s`` for virtual seconds."""
    name: str
    start: TraceEvent
    end: Optional[TraceEvent] = None

    @property
    def steps(self) -> Optional[int]:
        return None if self.end is None else self.end.step - \
            self.start.step


def spans_of(events: Sequence[TraceEvent]) -> Tuple[Span, ...]:
    """Pair starts with the first matching-name end at or after them
    (logical-clock order).  Unmatched starts yield open spans."""
    open_by_name: Dict[str, List[TraceEvent]] = {}
    spans: List[Span] = []
    for ev in sorted(events):
        if ev.kind == SPAN_START:
            open_by_name.setdefault(ev.name, []).append(ev)
        elif ev.kind == SPAN_END:
            stack = open_by_name.get(ev.name)
            if stack:
                spans.append(Span(ev.name, stack.pop(0), ev))
            else:
                spans.append(Span(ev.name, ev, ev))
    for name in sorted(open_by_name):
        for start in open_by_name[name]:
            spans.append(Span(name, start))
    spans.sort(key=lambda s: (s.start.step, s.start.origin,
                              s.start.seq))
    return tuple(spans)


# ------------------------------------------------------- active tracer
_tracer_stack: List[Tracer] = []


def current() -> Optional[Tracer]:
    return _tracer_stack[-1] if _tracer_stack else None


@contextmanager
def use(tracer: Tracer) -> Iterator[Tracer]:
    """Install ``tracer`` as the destination for module-level
    :func:`emit` calls (instrumented code stays tracer-agnostic; with
    no tracer installed, emission is a no-op)."""
    _tracer_stack.append(tracer)
    try:
        yield tracer
    finally:
        _tracer_stack.pop()


def emit(step: int, kind: str = ANNOT, name: str = "", **attrs) -> None:
    if _tracer_stack:
        _tracer_stack[-1].emit(step, kind, name, **attrs)
