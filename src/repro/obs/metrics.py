"""Process-local typed metrics registry.

Three metric kinds — Counter (monotone float), Gauge (last-write
float), Histogram (fixed log-spaced buckets + exact count/sum/min/max)
— organized into *families* declared once in :data:`SCHEMA` (name →
kind, help, label names).  Instrumented code calls the module-level
``inc`` / ``set_gauge`` / ``observe`` helpers, which write into the
*active* registry (a stack managed by :func:`use`), so a bench or chaos
campaign can scope one run's telemetry into one snapshot without
threading a registry handle through every layer.

Determinism contract: families and samples serialize sorted, bucket
edges are fixed constants, and histograms keep exact ``sum`` (in
observation order), ``min`` and ``max`` — so any statistic a harness
previously computed from its private counters (MTTR mean/max, goodput
= tokens/virtual-time) is reproducible *exactly* from the snapshot.
Two seeded runs performing the same observations produce byte-identical
JSONL snapshots (wall-clock-valued families excepted, by nature).

Label plumbing: families may declare a ``section`` label (or any
other); :func:`label_scope` pushes default label values that apply to
every sample recorded inside the scope, which is how one campaign
snapshot keeps per-section MTTR/goodput separable without the serve
stack knowing it runs inside a campaign.
"""
from __future__ import annotations

import json
from contextlib import contextmanager
from typing import Any, Dict, Iterator, List, Mapping, Optional, Tuple

COUNTER = "counter"
GAUGE = "gauge"
HISTOGRAM = "histogram"


def log_buckets(lo: float, hi: float, n: int) -> Tuple[float, ...]:
    """``n`` log-spaced upper bucket bounds from ``lo`` to ``hi``
    inclusive — deterministic (pure ``**``, edges rounded to 9
    significant digits so snapshots are platform-stable)."""
    if not (lo > 0 and hi > lo and n >= 2):
        raise ValueError(f"need 0 < lo < hi and n >= 2; got "
                         f"lo={lo}, hi={hi}, n={n}")
    ratio = hi / lo
    return tuple(float(f"{lo * ratio ** (i / (n - 1)):.9g}")
                 for i in range(n))


#: default edges: 100us .. 1000s — covers a decode tick, a compile, and
#: a chaos-campaign MTTR window on one ladder
DEFAULT_BUCKETS = log_buckets(1e-4, 1e3, 15)

#: the metric name schema (documented in ARCHITECTURE.md):
#: name -> (kind, help, label names)
SCHEMA: Dict[str, Tuple[str, str, Tuple[str, ...]]] = {
    # dispatcher
    "dispatch_cache_hits_total": (
        COUNTER, "plan-keyed compile cache hits", ("key",)),
    "dispatch_cache_misses_total": (
        COUNTER, "plan-keyed compile cache misses (one compile each)",
        ("key",)),
    "dispatch_compile_seconds": (
        HISTOGRAM, "compile wall time per compile_key", ("key",)),
    # serve: admission front end + engine
    "serve_queue_depth": (
        GAUGE, "released-but-unadmitted requests", ("section",)),
    "serve_released_total": (
        COUNTER, "requests released by the virtual clock", ("section",)),
    "serve_admitted_total": (
        COUNTER, "requests admitted into engine slots", ("section",)),
    "serve_shed_total": (
        COUNTER, "requests shed by the admission policy", ("section",)),
    "serve_evicted_total": (
        COUNTER, "deadline-expiry evictions", ("section", "where")),
    "serve_decode_tick_seconds": (
        HISTOGRAM, "wall time of one engine decode tick", ("section",)),
    "serve_ttft_seconds": (
        HISTOGRAM, "virtual time to first token (deadline-met only)",
        ("section",)),
    "serve_latency_seconds": (
        HISTOGRAM, "virtual end-to-end latency (deadline-met only)",
        ("section",)),
    "serve_completed_total": (
        COUNTER, "completions (non-expired)", ("section",)),
    "serve_deadline_met_total": (
        COUNTER, "completions that met their deadline", ("section",)),
    "serve_expired_total": (
        COUNTER, "requests expired (queued or in flight)", ("section",)),
    "serve_goodput_tokens_total": (
        COUNTER, "tokens of deadline-met completions", ("section",)),
    "serve_tokens_total": (
        COUNTER, "tokens of all completions", ("section",)),
    "serve_virtual_time_seconds": (
        GAUGE, "virtual-clock span of the run", ("section",)),
    # fault / routing
    "fault_events_total": (
        COUNTER, "fault-log entries by kind", ("kind", "stage")),
    "fleet_rung_devices": (
        GAUGE, "degradation-ladder occupancy: serving (device, stage) "
               "assignments per rung, plus quarantined/spare devices",
        ("rung",)),
    "probation_verdicts_total": (
        COUNTER, "probation outcomes", ("verdict",)),
    "probation_transients_total": (
        COUNTER, "transient verdicts per stage (feeds intermittent "
                 "promotion)", ("stage",)),
    "mttr_seconds": (
        HISTOGRAM, "per-event recovery time (detect -> recover); "
                   "per-kind detail lives in the trace annotations",
        ("section",)),
    # train
    "train_step_seconds": (
        HISTOGRAM, "train step wall time", ()),
    "ckpt_save_seconds": (
        HISTOGRAM, "checkpoint save wall time", ()),
    "ckpt_restore_seconds": (
        HISTOGRAM, "checkpoint restore wall time", ()),
    # multi-host coordination
    "kv_retries_total": (
        COUNTER, "coordination-service KV get retries", ("op",)),
    "coord_timeouts_total": (
        COUNTER, "peers surfaced as HostTimeoutError", ("host",)),
    "coord_attempt_timeout_seconds": (
        GAUGE, "per-host KV attempt timeout in force", ("host",)),
    # degradation-model closure (campaign sets, report renders)
    "closure_ratio": (
        GAUGE, "post-fault/healthy throughput ratio", ("source",)),
}


class _Hist:
    __slots__ = ("buckets", "counts", "count", "sum", "min", "max")

    def __init__(self, buckets: Tuple[float, ...]):
        self.buckets = buckets
        self.counts = [0] * (len(buckets) + 1)   # +1: +Inf overflow
        self.count = 0
        self.sum = 0.0
        self.min: Optional[float] = None
        self.max: Optional[float] = None

    def observe(self, v: float):
        v = float(v)
        i = 0
        for i, edge in enumerate(self.buckets):
            if v <= edge:
                break
        else:
            i = len(self.buckets)
        self.counts[i] += 1
        self.count += 1
        self.sum += v
        self.min = v if self.min is None else min(self.min, v)
        self.max = v if self.max is None else max(self.max, v)


class Family:
    """One declared metric family; ``samples`` maps a label-value tuple
    to a float (counter/gauge) or a :class:`_Hist`."""

    def __init__(self, name: str, kind: str, help: str,
                 labels: Tuple[str, ...] = (),
                 buckets: Tuple[float, ...] = DEFAULT_BUCKETS):
        if kind not in (COUNTER, GAUGE, HISTOGRAM):
            raise ValueError(f"unknown metric kind {kind!r}")
        self.name = name
        self.kind = kind
        self.help = help
        self.labels = tuple(labels)
        self.buckets = tuple(buckets)
        self.samples: Dict[Tuple[str, ...], Any] = {}

    def _child(self, key: Tuple[str, ...]):
        if key not in self.samples:
            self.samples[key] = (_Hist(self.buckets)
                                 if self.kind == HISTOGRAM else 0.0)
        return self.samples[key]


class Registry:
    """A set of metric families.  Unknown names resolve through
    :data:`SCHEMA` (lazy declaration); ad-hoc families can be declared
    explicitly with :meth:`declare`."""

    def __init__(self):
        self.families: Dict[str, Family] = {}

    # ------------------------------------------------------ declaration
    def declare(self, name: str, kind: str, help: str = "",
                labels: Tuple[str, ...] = (),
                buckets: Tuple[float, ...] = DEFAULT_BUCKETS) -> Family:
        fam = self.families.get(name)
        if fam is not None:
            if fam.kind != kind:
                raise ValueError(f"family {name!r} already declared as "
                                 f"{fam.kind}, not {kind}")
            return fam
        fam = Family(name, kind, help, labels, buckets)
        self.families[name] = fam
        return fam

    def _resolve(self, name: str, kind: str) -> Family:
        fam = self.families.get(name)
        if fam is None:
            spec = SCHEMA.get(name)
            if spec is None:
                raise KeyError(
                    f"metric family {name!r} is not in obs.metrics.SCHEMA; "
                    f"declare() it or add it to the schema")
            fam = self.declare(name, spec[0], spec[1], spec[2])
        if fam.kind != kind:
            raise TypeError(f"{name!r} is a {fam.kind}, not a {kind}")
        return fam

    def _key(self, fam: Family, labels: Mapping[str, str]
             ) -> Tuple[str, ...]:
        scope = _label_stack[-1] if _label_stack else {}
        return tuple(str(labels.get(k, scope.get(k, "")))
                     for k in fam.labels)

    # ------------------------------------------------------- recording
    def inc(self, name: str, v: float = 1.0, **labels):
        fam = self._resolve(name, COUNTER)
        key = self._key(fam, labels)
        fam.samples[key] = fam._child(key) + float(v)

    def set_gauge(self, name: str, v: float, **labels):
        fam = self._resolve(name, GAUGE)
        key = self._key(fam, labels)
        fam._child(key)
        fam.samples[key] = float(v)

    def observe(self, name: str, v: float, **labels):
        fam = self._resolve(name, HISTOGRAM)
        fam._child(self._key(fam, labels)).observe(v)

    # ---------------------------------------------------- serialization
    def snapshot(self) -> Dict[str, Any]:
        """Deterministic dict form: families sorted by name, samples by
        label values; histograms carry exact count/sum/min/max plus
        per-bucket counts."""
        fams: List[Dict[str, Any]] = []
        for name in sorted(self.families):
            fam = self.families[name]
            samples = []
            for key in sorted(fam.samples):
                row: Dict[str, Any] = {
                    "labels": dict(zip(fam.labels, key))}
                child = fam.samples[key]
                if fam.kind == HISTOGRAM:
                    row.update(count=child.count, sum=child.sum,
                               min=child.min, max=child.max,
                               bucket_counts=list(child.counts))
                else:
                    row["value"] = child
                samples.append(row)
            doc: Dict[str, Any] = {"name": name, "type": fam.kind,
                                   "help": fam.help,
                                   "labels": list(fam.labels),
                                   "samples": samples}
            if fam.kind == HISTOGRAM:
                doc["buckets"] = list(fam.buckets)
            fams.append(doc)
        return {"schema": "repro.metrics.v1", "families": fams}

    def to_jsonl(self) -> str:
        """One canonical-JSON line per family (sorted keys, no spaces)
        — byte-identical across runs that recorded the same values."""
        snap = self.snapshot()
        return "".join(json.dumps(f, sort_keys=True,
                                  separators=(",", ":")) + "\n"
                       for f in snap["families"])

    def to_prometheus(self) -> str:
        """Prometheus text exposition format.  Histograms add the
        non-standard ``_min``/``_max`` gauges the exact-reproduction
        contract needs."""
        out: List[str] = []
        for name in sorted(self.families):
            fam = self.families[name]
            out.append(f"# HELP {name} {fam.help}")
            out.append(f"# TYPE {name} {fam.kind}")
            for key in sorted(fam.samples):
                child = fam.samples[key]
                if fam.kind != HISTOGRAM:
                    out.append(f"{name}{_labelstr(fam.labels, key)} "
                               f"{_fmt(child)}")
                    continue
                cum = 0
                for edge, n in zip(fam.buckets, child.counts):
                    cum += n
                    out.append(
                        f"{name}_bucket"
                        f"{_labelstr(fam.labels + ('le',), key + (_fmt(edge),))}"
                        f" {cum}")
                cum += child.counts[-1]
                out.append(f"{name}_bucket"
                           f"{_labelstr(fam.labels + ('le',), key + ('+Inf',))}"
                           f" {cum}")
                out.append(f"{name}_sum{_labelstr(fam.labels, key)} "
                           f"{_fmt(child.sum)}")
                out.append(f"{name}_count{_labelstr(fam.labels, key)} "
                           f"{child.count}")
                if child.count:
                    out.append(f"{name}_min{_labelstr(fam.labels, key)} "
                               f"{_fmt(child.min)}")
                    out.append(f"{name}_max{_labelstr(fam.labels, key)} "
                               f"{_fmt(child.max)}")
        return "\n".join(out) + ("\n" if out else "")


def _fmt(v: float) -> str:
    """Shortest exact round-trip float rendering (``repr``) — the
    byte-determinism anchor for both text formats."""
    f = float(v)
    return str(int(f)) if f.is_integer() and abs(f) < 1e15 else repr(f)


def _labelstr(names: Tuple[str, ...], values: Tuple[str, ...]) -> str:
    if not names:
        return ""
    pairs = ",".join(f'{k}="{_escape(v)}"'
                     for k, v in zip(names, values))
    return "{" + pairs + "}"


def _escape(v: str) -> str:
    return str(v).replace("\\", r"\\").replace('"', r'\"') \
                 .replace("\n", r"\n")


# ------------------------------------------------------- active registry
_registry_stack: List[Registry] = [Registry()]
_label_stack: List[Dict[str, str]] = []
_disabled = 0


def registry() -> Registry:
    """The registry module-level helpers write into (innermost
    :func:`use` scope; a process-global default otherwise)."""
    return _registry_stack[-1]


@contextmanager
def use(reg: Registry) -> Iterator[Registry]:
    """Scope all telemetry inside the block into ``reg`` — one bench
    run / chaos campaign = one snapshot."""
    _registry_stack.append(reg)
    try:
        yield reg
    finally:
        _registry_stack.pop()


@contextmanager
def label_scope(**labels) -> Iterator[None]:
    """Default label values for every sample recorded in the block
    (only labels a family declares apply to it)."""
    merged = dict(_label_stack[-1]) if _label_stack else {}
    merged.update({k: str(v) for k, v in labels.items()})
    _label_stack.append(merged)
    try:
        yield
    finally:
        _label_stack.pop()


@contextmanager
def disabled() -> Iterator[None]:
    """Turn the module-level helpers into immediate no-ops (the
    telemetry-overhead guard measures against this)."""
    global _disabled
    _disabled += 1
    try:
        yield
    finally:
        _disabled -= 1


def inc(name: str, v: float = 1.0, **labels):
    if not _disabled:
        _registry_stack[-1].inc(name, v, **labels)


def set_gauge(name: str, v: float, **labels):
    if not _disabled:
        _registry_stack[-1].set_gauge(name, v, **labels)


def observe(name: str, v: float, **labels):
    if not _disabled:
        _registry_stack[-1].observe(name, v, **labels)
