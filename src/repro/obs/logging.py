"""The one structured logger every layer logs through.

``get_logger("serve.engine")`` returns a :class:`StructuredLogger`
bound to a *component*; every record renders as::

    [component] event key=value key=value ...

with the bound fields (host_id, and a ``stamp=(step, origin, seq)``
logical-clock triple when the caller has one) appended in a stable
order, so fleet logs from different hosts interleave greppably.  It
wraps stdlib ``logging`` (namespace ``repro.*``) — handler/level
configuration composes with whatever the embedding app set up;
:func:`configure` is the one-liner the CLIs under ``launch/`` call to
get message-only lines on stderr/stdout.

Bare ``print()`` is banned under ``src/repro/`` (ruff T20 ratchet):
human/progress output goes through this module; machine-readable
artifacts (final JSON lines) go through ``sys.stdout.write``.
"""
from __future__ import annotations

import logging
import sys
from typing import Any, Dict, Optional

_ROOT = "repro"
_global_fields: Dict[str, Any] = {}


def set_host(host_id: int) -> None:
    """Bind ``host=<id>`` into every logger process-wide (the
    multi-host runtime calls this once at initialize)."""
    _global_fields["host"] = int(host_id)


def _quote(v: Any) -> str:
    s = str(v)
    return f'"{s}"' if (" " in s or "=" in s) else s


class StructuredLogger:
    """Component-bound, field-carrying logger facade."""

    def __init__(self, component: str,
                 fields: Optional[Dict[str, Any]] = None):
        self.component = component
        self.fields = dict(fields or {})
        self._log = logging.getLogger(f"{_ROOT}.{component}")

    def bind(self, **fields) -> "StructuredLogger":
        """A child logger with extra permanent fields (host_id, rid,
        section ...)."""
        return StructuredLogger(self.component,
                                {**self.fields, **fields})

    def render(self, event: str, fields: Dict[str, Any]) -> str:
        merged = {**_global_fields, **self.fields, **fields}
        stamp = merged.pop("stamp", None)
        if stamp is not None:
            merged["stamp"] = "/".join(str(x) for x in stamp)
        kv = " ".join(f"{k}={_quote(v)}" for k, v in merged.items())
        head = f"[{self.component}] {event}"
        return f"{head} {kv}" if kv else head

    def _emit(self, level: int, event: str, fields: Dict[str, Any],
              exc_info: bool = False):
        if self._log.isEnabledFor(level):
            self._log.log(level, "%s", self.render(event, fields),
                          exc_info=exc_info)

    def debug(self, event: str, **fields):
        self._emit(logging.DEBUG, event, fields)

    def info(self, event: str, **fields):
        self._emit(logging.INFO, event, fields)

    def warning(self, event: str, **fields):
        self._emit(logging.WARNING, event, fields)

    def error(self, event: str, **fields):
        self._emit(logging.ERROR, event, fields)

    def exception(self, event: str, **fields):
        self._emit(logging.ERROR, event, fields, exc_info=True)


def get_logger(component: str, **fields) -> StructuredLogger:
    return StructuredLogger(component, fields)


def configure(level: str = "info", stream=None) -> None:
    """Message-only lines for the ``repro.*`` namespace — what the
    ``launch/`` CLIs call so progress output reaches the terminal
    without double-configuring an embedding app's logging."""
    root = logging.getLogger(_ROOT)
    root.setLevel(getattr(logging, level.upper()))
    if not any(getattr(h, "_repro_obs", False) for h in root.handlers):
        h = logging.StreamHandler(stream or sys.stderr)
        h.setFormatter(logging.Formatter("%(message)s"))
        h._repro_obs = True
        root.addHandler(h)
        root.propagate = False
