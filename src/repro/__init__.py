"""repro: Oobleck fault-tolerant staged acceleration for JAX (see README)."""
