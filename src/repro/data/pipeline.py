"""Deterministic synthetic LM data pipeline (host-side, shardable).

A seeded Markov token stream: ``next = (a * cur + c + noise) mod V`` with a
small noise vocabulary, so the distribution has low conditional entropy —
a real model trained on it shows a clearly decreasing loss (used by the
end-to-end examples and convergence tests).

Batches are keyed by (seed, step): restarts and elastic re-shards replay
the exact same stream (checkpoint stores only the step counter).  Each
host generates only its shard in multi-process deployments; here the
global batch is generated and device_put with the batch sharding.
"""
from __future__ import annotations

from dataclasses import dataclass
from typing import Dict, Iterator

import numpy as np

import jax
import jax.numpy as jnp


@dataclass
class DataConfig:
    vocab_size: int
    batch: int
    seq_len: int
    seed: int = 1234
    noise_vocab: int = 17      # conditional branching factor
    mult: int = 31             # affine transition parameters
    add: int = 7


class SyntheticLM:
    def __init__(self, cfg: DataConfig):
        self.cfg = cfg

    def batch_at(self, step: int) -> Dict[str, np.ndarray]:
        c = self.cfg
        rng = np.random.default_rng((c.seed, step))
        start = rng.integers(0, c.vocab_size, size=(c.batch, 1))
        noise = rng.integers(0, c.noise_vocab, size=(c.batch, c.seq_len))
        toks = np.zeros((c.batch, c.seq_len + 1), np.int64)
        toks[:, :1] = start
        for t in range(c.seq_len):
            toks[:, t + 1] = (toks[:, t] * c.mult + c.add + noise[:, t]) \
                % c.vocab_size
        return {"tokens": toks[:, :-1].astype(np.int32),
                "targets": toks[:, 1:].astype(np.int32)}

    def iterate(self, start_step: int = 0) -> Iterator[Dict[str, np.ndarray]]:
        step = start_step
        while True:
            yield self.batch_at(step)
            step += 1

    def device_batch(self, step: int, sharding=None) -> Dict[str, jax.Array]:
        host = self.batch_at(step)
        if sharding is None:
            return {k: jnp.asarray(v) for k, v in host.items()}
        return {k: jax.device_put(v, sharding) for k, v in host.items()}


def stub_frontend_batch(cfg, B: int, S: int, step: int, d_model: int,
                        *, kind: str) -> Dict[str, np.ndarray]:
    """Precomputed embeddings for stub-frontend archs (vlm/audio)."""
    rng = np.random.default_rng((hash(kind) & 0xFFFF, step))
    out = {"embeds": rng.normal(size=(B, S, d_model)).astype(np.float32) * 0.02}
    if kind == "vlm":
        t = np.arange(S)[None, :].repeat(B, 0)
        out["positions3"] = np.stack([t, t, t], -1).astype(np.int32)
    return out
