from repro.data.pipeline import DataConfig, SyntheticLM, stub_frontend_batch

__all__ = ["DataConfig", "SyntheticLM", "stub_frontend_batch"]
