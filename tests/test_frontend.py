"""Admission front end: virtual-clock release/admission ordering,
deadline-expiry eviction freeing slots on both engines, shedding
policies, fleet flash-crowd + mid-burst quarantine with zero drops, and
the fleet serve()-vs-session bit-identity contract.

Engines are built once per shape and reused across tests/examples
(sessions reset the slot pools), keeping jit compiles to a handful:
every prompt is the same length, so prefill compiles once per plan.
"""
import jax
import numpy as np
import pytest
from _hypothesis_compat import given, settings, st

from repro.configs import get_config
from repro.models import build_model
from repro.serve import (BLOCK, REJECT, SHED_LATEST, FlashCrowd,
                         FleetConfig, FleetServeEngine, Frontend,
                         FrontendConfig, LengthModel, Request,
                         ServeConfig, ServeEngine)

KEY = jax.random.PRNGKey(0)
MAX_LEN = 32
PLEN = 6                             # one prompt length -> one prefill jit
DT = 0.05

_cache = {}


def _setup():
    if "model" not in _cache:
        cfg = get_config("qwen1.5-4b").reduced()
        params = build_model(cfg).init(KEY)
        _cache["model"] = (cfg, params)
    return _cache["model"]


def _engine(slots):
    key = ("eng", slots)
    if key not in _cache:
        cfg, params = _setup()
        _cache[key] = ServeEngine(cfg, params,
                                  ServeConfig(max_len=MAX_LEN,
                                              max_slots=slots))
    return _cache[key]


def _fleet(n_devices, slots, degradation=None):
    key = ("fleet", n_devices, slots, degradation)
    if key not in _cache:
        cfg, params = _setup()
        _cache[key] = FleetServeEngine(
            cfg, params, ServeConfig(max_len=MAX_LEN, max_slots=slots),
            FleetConfig(n_devices=n_devices, degradation=degradation))
    return _cache[key]


def _req(rid, budget, *, arrival_time=None, deadline=None):
    cfg, _ = _setup()
    rng = np.random.default_rng(1000 + rid)
    return Request(rid=rid,
                   prompt=rng.integers(0, cfg.vocab_size, size=PLEN
                                       ).astype(np.int32),
                   max_new_tokens=budget, arrival_time=arrival_time,
                   deadline=deadline)


# ------------------------------------------------------- virtual clock
@settings(max_examples=8, deadline=None)
@given(offsets=st.lists(st.floats(min_value=0.0, max_value=1.2),
                        min_size=2, max_size=5),
       budgets=st.lists(st.integers(min_value=2, max_value=5),
                        min_size=5, max_size=5))
def test_virtual_clock_never_admits_before_arrival(offsets, budgets):
    """Property: a request is never admitted to the engine before the
    virtual clock reaches its arrival_time (admitted_step*dt >= t)."""
    reqs = [_req(i, budgets[i], arrival_time=float(t))
            for i, t in enumerate(offsets)]
    fe = Frontend(_engine(2), FrontendConfig(step_time_s=DT))
    comps, _stats = fe.run(reqs)
    assert set(comps) == {r.rid for r in reqs}
    for r in reqs:
        c = comps[r.rid]
        assert c.admitted_step * DT >= r.arrival_time - 1e-9, \
            (r.rid, r.arrival_time, c.admitted_step)
        assert c.queue_wait_s >= -1e-9
        assert c.ttft_s >= c.queue_wait_s - 1e-9


# ---------------------------------------------------- deadline expiry
def _expiry_scenario(engine):
    """A hog with a tight deadline holds the only slot; a later request
    can only complete if expiry eviction frees that slot."""
    hog = _req(0, 20, arrival_time=0.0, deadline=0.3)
    late = _req(1, 3, arrival_time=0.1, deadline=5.0)
    fe = Frontend(engine, FrontendConfig(step_time_s=DT))
    comps, stats = fe.run([hog, late])
    assert set(comps) == {0, 1}
    assert comps[0].expired and not comps[0].deadline_met
    # partial output: it decoded until the clock passed 0.3s
    assert 0 < len(comps[0].tokens) < 20
    assert comps[1].deadline_met and len(comps[1].tokens) == 3
    # the slot was freed by the eviction, not by the hog finishing
    assert comps[1].admitted_step <= 0.3 / DT + 2
    assert stats["expired_in_flight"] == [0]


def test_deadline_expiry_frees_slots_single_engine():
    _expiry_scenario(_engine(1))


def test_deadline_expiry_frees_slots_fleet_engine():
    _expiry_scenario(_fleet(1, 1))


def test_expired_queued_request_never_reaches_engine():
    """A queued request whose deadline passes before a slot frees is
    shed from the front-end queue with admitted_step == -1."""
    hog = _req(0, 12, arrival_time=0.0, deadline=10.0)
    # arrives after the hog owns the only slot; expires while queued
    doomed = _req(1, 3, arrival_time=0.05, deadline=0.2)
    comps, stats = Frontend(_engine(1), FrontendConfig(
        step_time_s=DT)).run([hog, doomed])
    assert comps[1].expired and comps[1].admitted_step == -1
    assert len(comps[1].tokens) == 0
    assert stats["expired_queued"] == [1]
    assert comps[0].deadline_met


# ------------------------------------------------------ shed policies
def test_shed_reject_policy():
    """Releases hit the bounded queue before this step's admissions
    drain it: 5 simultaneous arrivals into max_queue=2 reject 3."""
    reqs = [_req(i, 4, arrival_time=0.0) for i in range(5)]
    comps, stats = Frontend(_engine(1), FrontendConfig(
        step_time_s=DT, max_queue=2, shed=REJECT)).run(reqs)
    assert len(stats["shed"]) == 3
    for rid in stats["shed"]:
        assert comps[rid].expired and len(comps[rid].tokens) == 0
    done = [c for c in comps.values() if not c.expired]
    assert len(done) == 2 and all(len(c.tokens) == 4 for c in done)


def test_shed_latest_deadline_policy():
    """The victim is whoever can wait longest — an already-queued lax
    request is evicted to make room for the urgent one, and the
    no-deadline request (can wait forever) is refused at the door."""
    lax = _req(0, 3, arrival_time=0.0, deadline=30.0)
    urgent = _req(1, 3, arrival_time=0.0, deadline=0.6)
    lazier = _req(2, 3, arrival_time=0.0)          # no deadline at all
    comps, stats = Frontend(_engine(1), FrontendConfig(
        step_time_s=DT, max_queue=1, shed=SHED_LATEST)).run(
        [lax, urgent, lazier])
    assert stats["shed"] == [0, 2]
    assert 1 not in stats["shed"]
    assert comps[1].deadline_met and len(comps[1].tokens) == 3


def test_block_policy_drops_nothing():
    reqs = [_req(i, 3, arrival_time=0.0) for i in range(6)]
    comps, stats = Frontend(_engine(2), FrontendConfig(
        step_time_s=DT, max_queue=2, shed=BLOCK)).run(reqs)
    assert stats["shed"] == [] and stats["expired"] == 0
    assert all(len(c.tokens) == 3 for c in comps.values())


# ------------------------------------------- fleet: burst + quarantine
def test_flash_crowd_mid_burst_quarantine_drops_nothing():
    """A flash-crowd burst overlapping a stage quarantine: capacity
    halves on the faulted device mid-burst, yet every request completes
    (drain/re-queue, zero non-expired drops) with tokens bit-identical
    to the healthy run."""
    cfg, _ = _setup()
    lm = LengthModel(vocab_size=cfg.vocab_size, min_prompt=PLEN,
                     max_prompt=PLEN, min_new=3, max_new=6)
    wl = FlashCrowd(n_requests=12, base_rate=6.0, burst_factor=8.0,
                    burst_start_s=0.2, burst_dur_s=0.6, lengths=lm,
                    slack_s=30.0)    # generous SLO: nothing may expire
    reqs = wl.build(9)
    eng = _fleet(2, 2, degradation=(1.0, 0.5))
    burst_step = int(0.4 / DT)       # mid-burst
    comps, stats = Frontend(eng, FrontendConfig(step_time_s=DT)).run(
        reqs, events={burst_step: [("stage", 0, "flash_attention")]})
    eng.recover(0)
    assert set(comps) == {r.rid for r in reqs}
    assert stats["expired"] == 0 and stats["shed"] == []
    assert all(c.deadline_met for c in comps.values())
    healthy, _ = Frontend(eng, FrontendConfig(step_time_s=DT)).run(reqs)
    for r in reqs:
        np.testing.assert_array_equal(comps[r.rid].tokens,
                                      healthy[r.rid].tokens)


def test_fleet_serve_is_thin_wrapper_over_session():
    cfg, _ = _setup()
    lm = LengthModel(vocab_size=cfg.vocab_size, min_prompt=PLEN,
                     max_prompt=PLEN, min_new=3, max_new=6)
    reqs = FlashCrowd(n_requests=8, base_rate=20.0, lengths=lm).build(2)
    eng = _fleet(2, 2, degradation=(1.0, 0.5))
    events = {3: [("stage", 1, "flash_attention")]}
    done, stats = eng.serve(reqs, events=dict(events))
    eng.recover(1)

    sess = eng.session()
    for r in sorted(reqs, key=lambda r: (r.arrival, r.rid)):
        sess.submit(r)
    ev = dict(events)
    while sess.pending():
        sess.step(ev.pop(sess.step_count, ()))
    sstats = sess.close(late_events=ev)
    eng.recover(1)
    streamed = {c.rid: c for c in sess.poll()}
    assert set(streamed) == set(done)
    for rid, c in done.items():
        np.testing.assert_array_equal(c.tokens, streamed[rid].tokens)
        assert (c.admitted_step, c.finished_step, c.device) == \
            (streamed[rid].admitted_step, streamed[rid].finished_step,
             streamed[rid].device)
    for k in ("admitted", "steps", "requeued", "per_step_tokens",
              "capacity", "quarantined"):
        assert stats[k] == sstats[k], k


# ------------------------------------------------------------- errors
def test_frontend_interface_validation():
    with pytest.raises(ValueError):
        FrontendConfig(shed="yolo")
    with pytest.raises(ValueError):
        FrontendConfig(order="lifo")
    with pytest.raises(ValueError):
        FrontendConfig(step_time_s=0.0)
    with pytest.raises(ValueError, match="fault_at_step"):
        Frontend(_engine(1)).run([_req(0, 2)],
                                 events={0: [("device", 0)]})
    with pytest.raises(ValueError, match="events"):
        Frontend(_fleet(1, 1)).run([_req(0, 2)],
                                   fault_at_step=(0, "flash_attention"))
    sess = _engine(1).session()
    with pytest.raises(ValueError, match="events"):
        sess.step([("device", 0)])
