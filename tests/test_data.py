"""Data pipeline: determinism, restart replay, learnability structure."""
import numpy as np

from repro.data import DataConfig, SyntheticLM


def test_deterministic_by_step():
    d1 = SyntheticLM(DataConfig(vocab_size=97, batch=4, seq_len=16))
    d2 = SyntheticLM(DataConfig(vocab_size=97, batch=4, seq_len=16))
    b1, b2 = d1.batch_at(5), d2.batch_at(5)
    np.testing.assert_array_equal(b1["tokens"], b2["tokens"])
    assert not np.array_equal(d1.batch_at(5)["tokens"],
                              d1.batch_at(6)["tokens"])


def test_targets_are_shifted_tokens():
    d = SyntheticLM(DataConfig(vocab_size=97, batch=2, seq_len=16))
    b = d.batch_at(0)
    np.testing.assert_array_equal(b["tokens"][:, 1:], b["targets"][:, :-1])


def test_low_conditional_entropy():
    """next token is (a*cur + c + eps) mod V with small eps: the branch
    factor equals noise_vocab, so an oracle gets loss <= log(noise_vocab)
    << log(V) — the stream is genuinely learnable."""
    cfg = DataConfig(vocab_size=1001, batch=8, seq_len=256, noise_vocab=17)
    d = SyntheticLM(cfg)
    b = d.batch_at(0)
    delta = (b["targets"].astype(np.int64) -
             (b["tokens"].astype(np.int64) * cfg.mult + cfg.add)) \
        % cfg.vocab_size
    assert delta.max() < cfg.noise_vocab


def test_iterate_resumes():
    d = SyntheticLM(DataConfig(vocab_size=97, batch=2, seq_len=8))
    it = d.iterate(start_step=3)
    np.testing.assert_array_equal(next(it)["tokens"],
                                  d.batch_at(3)["tokens"])
