"""HLO analyzer: trip-count-aware flops/bytes/collectives."""
import jax
import jax.numpy as jnp
import pytest

from repro.launch.hlo_analysis import HLOModule, analyze


def test_scan_trip_count_flops():
    def f(x, w):
        def body(c, _):
            return c @ w, ()
        y, _ = jax.lax.scan(body, x, None, length=13)
        return y
    comp = jax.jit(f).lower(jax.ShapeDtypeStruct((64, 128), jnp.float32),
                            jax.ShapeDtypeStruct((128, 128), jnp.float32)
                            ).compile()
    st = analyze(comp.as_text())
    assert st.flops == pytest.approx(13 * 2 * 64 * 128 * 128, rel=0.01)


def test_nested_scan_multiplies():
    def f(x):
        def inner(c, _):
            return c * 2.0 + 1.0, ()

        def outer(c, _):
            c, _ = jax.lax.scan(inner, c, None, length=5)
            return c @ c, ()
        y, _ = jax.lax.scan(outer, x, None, length=3)
        return y
    comp = jax.jit(f).lower(
        jax.ShapeDtypeStruct((32, 32), jnp.float32)).compile()
    st = analyze(comp.as_text())
    assert st.flops == pytest.approx(3 * 2 * 32 * 32 * 32, rel=0.01)


def test_dot_general_batched_flops():
    def f(a, b):
        return jnp.einsum("bij,bjk->bik", a, b)
    comp = jax.jit(f).lower(
        jax.ShapeDtypeStruct((4, 16, 32), jnp.float32),
        jax.ShapeDtypeStruct((4, 32, 8), jnp.float32)).compile()
    st = analyze(comp.as_text())
    assert st.flops == pytest.approx(2 * 4 * 16 * 32 * 8, rel=0.01)


def test_shape_parser():
    m = HLOModule("")
    from repro.launch.hlo_analysis import _parse_shape
    e, b = _parse_shape("bf16[4,128]{1,0}")
    assert e == 512 and b == 1024
    e, b = _parse_shape("(s32[], f32[8,8]{1,0}, u8[16]{0})")
    assert e == 1 + 64 + 16 and b == 4 + 256 + 16


def test_dus_counted_as_update_not_buffer():
    """ys-stacking scans write one row per iteration; counting the full
    stacked buffer per trip would overstate traffic by the trip count."""
    def f(x):
        def body(c, _):
            c = c @ c
            return c, c
        _, ys = jax.lax.scan(body, x, None, length=10)
        return ys
    comp = jax.jit(f).lower(
        jax.ShapeDtypeStruct((64, 64), jnp.float32)).compile()
    st = analyze(comp.as_text())
    full_overcount = 10 * 10 * 64 * 64 * 4 * 2
    assert st.bytes_hbm < full_overcount / 2
    assert "in-place-update" in st.bytes_by_kind


def test_collective_accounting_synthetic():
    txt = """
HloModule m

ENTRY %main (p0: f32[64,64]) -> f32[64,64] {
  %p0 = f32[64,64]{1,0} parameter(0)
  %ar = f32[64,64]{1,0} all-reduce(%p0), channel_id=1, replica_groups=[2,4]<=[8], to_apply=%add
  %ag = f32[64,64]{1,0} all-gather(%ar), channel_id=2, replica_groups=[4,2]<=[8], dimensions={0}
  ROOT %cp = f32[64,64]{1,0} collective-permute(%ag), channel_id=3, source_target_pairs={{0,1}}
}
"""
    st = analyze(txt, world=8)
    sz = 64 * 64 * 4
    assert st.coll_bytes["all-reduce"] == pytest.approx(2 * sz * 3 / 4)
    assert st.coll_bytes["all-gather"] == pytest.approx(sz * 1 / 2)
    assert st.coll_bytes["collective-permute"] == pytest.approx(sz)
    assert st.n_coll == {"all-reduce": 1, "all-gather": 1,
                         "collective-permute": 1}
