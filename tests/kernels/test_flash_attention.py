"""Flash-attention kernel: interpret-mode vs the pure-jnp oracle."""
import jax.numpy as jnp
import numpy as np
import pytest
from _hypothesis_compat import given, settings, st

from repro.kernels.flash_attention import (attention, attention_chunked,
                                           attention_naive)


def _mk(rng, B, Sq, Skv, H, Hkv, D, dtype):
    q = jnp.asarray(rng.normal(size=(B, Sq, H, D)), dtype)
    k = jnp.asarray(rng.normal(size=(B, Skv, Hkv, D)), dtype)
    v = jnp.asarray(rng.normal(size=(B, Skv, Hkv, D)), dtype)
    return q, k, v


SHAPES = [
    (1, 128, 128, 1, 1, 32), (2, 256, 256, 4, 2, 64),
    (1, 257, 257, 2, 1, 64),          # non-multiple of block: padding path
    (2, 64, 192, 4, 4, 32),           # cross lengths
    (1, 128, 128, 8, 2, 128),         # GQA 4:1, MXU-width head
]


@pytest.mark.parametrize("B,Sq,Skv,H,Hkv,D", SHAPES)
@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
def test_kernel_matches_oracle(rng, B, Sq, Skv, H, Hkv, D, dtype):
    q, k, v = _mk(rng, B, Sq, Skv, H, Hkv, D, dtype)
    causal = Sq == Skv
    ref = attention_naive(q, k, v, causal=causal)
    out = attention(q, k, v, causal=causal, route="interpret")
    tol = 2e-2 if dtype == jnp.bfloat16 else 2e-5
    np.testing.assert_allclose(np.asarray(out, np.float32),
                               np.asarray(ref, np.float32), atol=tol,
                               rtol=tol)


@pytest.mark.parametrize("window", [16, 64, 100])
@pytest.mark.parametrize("softcap", [0.0, 30.0])
def test_kernel_window_softcap(rng, window, softcap):
    q, k, v = _mk(rng, 2, 192, 192, 4, 2, 64, jnp.float32)
    ref = attention_naive(q, k, v, causal=True, window=window,
                          softcap=softcap)
    out = attention(q, k, v, causal=True, window=window, softcap=softcap,
                    route="interpret")
    np.testing.assert_allclose(np.asarray(out), np.asarray(ref), atol=2e-5,
                               rtol=2e-5)


def test_chunked_matches_naive_all_chunk_sizes(rng):
    q, k, v = _mk(rng, 2, 100, 100, 2, 2, 32, jnp.float32)
    ref = attention_naive(q, k, v, causal=True)
    for c in (16, 32, 37, 100, 512):
        out = attention_chunked(q, k, v, causal=True, kv_chunk=c)
        np.testing.assert_allclose(np.asarray(out), np.asarray(ref),
                                   atol=2e-5, rtol=2e-5)


def test_decode_path_with_positions(rng):
    """Ring-buffer decode masking: explicit k positions, -1 slots masked."""
    B, S, H, D = 2, 32, 2, 16
    q = jnp.asarray(rng.normal(size=(B, 1, H, D)), jnp.float32)
    k = jnp.asarray(rng.normal(size=(B, S, H, D)), jnp.float32)
    v = jnp.asarray(rng.normal(size=(B, S, H, D)), jnp.float32)
    kpos = jnp.tile(jnp.arange(S)[None], (B, 1)).at[:, 20:].set(-1)
    out = attention_naive(q, k, v, causal=True,
                          q_offset=jnp.full((B,), 19, jnp.int32),
                          k_positions=kpos)
    ref = attention_naive(q, k[:, :20], v[:, :20], causal=True,
                          q_offset=jnp.full((B,), 19, jnp.int32))
    np.testing.assert_allclose(np.asarray(out), np.asarray(ref), atol=1e-5)


@settings(max_examples=20, deadline=None)
@given(B=st.integers(1, 3), S=st.sampled_from([64, 96, 160]),
       H=st.sampled_from([1, 2, 4]), gq=st.sampled_from([1, 2]),
       D=st.sampled_from([16, 32]), causal=st.booleans(),
       window=st.sampled_from([0, 24]))
def test_property_kernel_equals_oracle(B, S, H, gq, D, causal, window):
    rng = np.random.default_rng(B * 1000 + S + H + D)
    q, k, v = _mk(rng, B, S, S, H * gq, H, D, jnp.float32)
    ref = attention_naive(q, k, v, causal=causal, window=window)
    out = attention(q, k, v, causal=causal, window=window,
                    route="interpret")
    np.testing.assert_allclose(np.asarray(out), np.asarray(ref), atol=3e-5,
                               rtol=3e-5)
