"""Fused SwiGLU kernel: interpret-mode vs the jnp oracle."""
import jax.numpy as jnp
import numpy as np
import pytest
from _hypothesis_compat import given, settings, st

from repro.kernels.swiglu import swiglu, swiglu_ref


def _mk(rng, M, D, F, dtype=jnp.float32):
    x = jnp.asarray(rng.normal(size=(M, D)), dtype)
    w1 = jnp.asarray(rng.normal(size=(D, F)) * 0.1, dtype)
    w3 = jnp.asarray(rng.normal(size=(D, F)) * 0.1, dtype)
    w2 = jnp.asarray(rng.normal(size=(F, D)) * 0.1, dtype)
    return x, w1, w3, w2


@pytest.mark.parametrize("M,D,F", [(8, 32, 64), (128, 64, 512),
                                   (256, 128, 1024), (64, 96, 160)])
@pytest.mark.parametrize("act", ["silu", "gelu"])
def test_kernel_matches_oracle(rng, M, D, F, act):
    x, w1, w3, w2 = _mk(rng, M, D, F)
    ref = swiglu_ref(x, w1, w3, w2, act=act)
    out = swiglu(x, w1, w3, w2, act=act, route="interpret")
    np.testing.assert_allclose(np.asarray(out), np.asarray(ref), atol=1e-4,
                               rtol=1e-4)


def test_bf16_contract(rng):
    x, w1, w3, w2 = _mk(rng, 128, 64, 256, jnp.bfloat16)
    ref = swiglu_ref(x, w1, w3, w2)
    out = swiglu(x, w1, w3, w2, route="interpret")
    np.testing.assert_allclose(np.asarray(out, np.float32),
                               np.asarray(ref, np.float32), atol=3e-2,
                               rtol=3e-2)


@settings(max_examples=15, deadline=None)
@given(M=st.sampled_from([8, 16, 128]), D=st.sampled_from([32, 64]),
       F=st.sampled_from([128, 256, 512]))
def test_property_matches_oracle(M, D, F):
    rng = np.random.default_rng(M + D + F)
    x, w1, w3, w2 = _mk(rng, M, D, F)
    ref = swiglu_ref(x, w1, w3, w2)
    out = swiglu(x, w1, w3, w2, route="interpret")
    np.testing.assert_allclose(np.asarray(out), np.asarray(ref), atol=1e-4,
                               rtol=1e-4)
