"""Checksum (paper Fig. 4) kernel: bit-exact across lowerings."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest
from _hypothesis_compat import given, settings, st

from repro.kernels.checksum import (checksum, checksum_ref, checksum_tree,
                                    popcount_fig4)


@pytest.mark.parametrize("shape", [(1,), (33, 17), (128,), (5, 7, 3),
                                   (1024, 9)])
@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16, jnp.int32,
                                   jnp.uint8])
def test_kernel_bit_exact(rng, shape, dtype):
    x = jnp.asarray(rng.normal(size=shape) * 100).astype(dtype)
    assert int(checksum_ref(x)) == int(checksum(x, route="interpret"))


def test_fig4_equals_population_count(rng):
    w = jnp.asarray(rng.integers(0, 2**31, size=(512,)), jnp.uint32)
    np.testing.assert_array_equal(np.asarray(popcount_fig4(w)),
                                  np.asarray(jax.lax.population_count(w)))


def test_detects_single_bitflip(rng):
    x = jnp.asarray(rng.normal(size=(64, 64)), jnp.float32)
    y = x.at[13, 7].multiply(-1.0)  # sign-bit flip
    assert int(checksum_ref(x)) != int(checksum_ref(y))


def test_tree_checksum_order_sensitive(rng):
    a = jnp.asarray(rng.normal(size=(8,)), jnp.float32)
    b = jnp.asarray(rng.normal(size=(8,)), jnp.float32)
    assert int(checksum_tree({"x": a, "y": b})) != \
        int(checksum_tree({"x": b, "y": a}))


@settings(max_examples=20, deadline=None)
@given(n=st.integers(1, 4000))
def test_property_matches_ref(n):
    rng = np.random.default_rng(n)
    x = jnp.asarray(rng.integers(0, 2**31, size=(n,)), jnp.uint32)
    assert int(checksum_ref(x)) == int(checksum(x, route="interpret"))
