"""Swept-config parity: every block size the tuner may pick is bit-exact.

The tuner's contract is "a tuning entry costs performance, never
correctness" — so the interpret-mode kernels must match their blocked
jnp oracles **bit-for-bit** for *every* admissible config in the search
space, not just the default.  The oracles are ``jax.jit``'d: interpret
mode executes the kernel body under jit, where XLA fuses multiply-adds;
an eager oracle differs by one ulp, a jitted one does not.

Softcap is the one exception: tanh/divide fuse differently across the
two programs, so those cases assert a 1e-6 tolerance instead.
"""
import functools

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.kernels.flash_attention.kernel import flash_attention_bhsd
from repro.kernels.flash_attention.ref import attention_ref_blocked
from repro.kernels.swiglu.kernel import swiglu_pallas
from repro.kernels.swiglu.ref import swiglu_ref_blocked
from repro.kernels.tuning.space import space_for

# Small shapes: interpret mode jit-compiles per config, so the sweep must
# stay cheap.  (M, D, F) for swiglu; (B, Sq, Skv, H, Hkv, D) for flash —
# Hkv < H exercises the GQA head mapping.
SWIGLU_SHAPE = (16, 32, 256)
FLASH_SHAPE = (1, 32, 32, 2, 1, 8)


def _swiglu_configs():
    M, _D, F = SWIGLU_SHAPE
    seen, out = set(), []
    for cfg in space_for("swiglu_mlp", "hw").configs(SWIGLU_SHAPE):
        # clamp exactly like the kernel does; dedupe the clamped tiles
        bm, bf = min(cfg["bm"], M), min(cfg["bf"], F)
        bs = min(cfg["bs"], bf)
        if (bm, bf, bs) not in seen:
            seen.add((bm, bf, bs))
            out.append(cfg)
    return out


def _flash_configs():
    return list(space_for("flash_attention", "hw").configs(FLASH_SHAPE))


def _swiglu_args(rng):
    M, D, F = SWIGLU_SHAPE
    x = jnp.asarray(rng.normal(size=(M, D)), jnp.float32)
    w1 = jnp.asarray(rng.normal(size=(D, F)) * 0.1, jnp.float32)
    w3 = jnp.asarray(rng.normal(size=(D, F)) * 0.1, jnp.float32)
    w2 = jnp.asarray(rng.normal(size=(F, D)) * 0.1, jnp.float32)
    return x, w1, w3, w2


def _flash_args(rng):
    B, Sq, Skv, H, Hkv, D = FLASH_SHAPE
    q = jnp.asarray(rng.normal(size=(B, H, Sq, D)), jnp.float32)
    k = jnp.asarray(rng.normal(size=(B, Hkv, Skv, D)), jnp.float32)
    v = jnp.asarray(rng.normal(size=(B, Hkv, Skv, D)), jnp.float32)
    return q, k, v


@pytest.mark.parametrize("cfg", _swiglu_configs(),
                         ids=lambda c: f"bm{c['bm']}bf{c['bf']}bs{c['bs']}")
@pytest.mark.parametrize("act", ["silu", "gelu"])
def test_swiglu_bitexact_across_sweep(rng, cfg, act):
    args = _swiglu_args(rng)
    ref = jax.jit(functools.partial(swiglu_ref_blocked, act=act, **cfg))(
        *args)
    out = swiglu_pallas(*args, act=act, interpret=True, **cfg)
    np.testing.assert_array_equal(np.asarray(out), np.asarray(ref))


@pytest.mark.parametrize("cfg", _flash_configs(),
                         ids=lambda c: f"bq{c['bq']}bk{c['bk']}")
@pytest.mark.parametrize("causal", [True, False])
def test_flash_bitexact_across_sweep(rng, cfg, causal):
    args = _flash_args(rng)
    ref = jax.jit(functools.partial(attention_ref_blocked, causal=causal,
                                    **cfg))(*args)
    out = flash_attention_bhsd(*args, causal=causal, interpret=True, **cfg)
    np.testing.assert_array_equal(np.asarray(out), np.asarray(ref))


@pytest.mark.parametrize("cfg", [{"bq": 8, "bk": 16}, {"bq": 32, "bk": 8}])
def test_flash_window_bitexact(rng, cfg):
    args = _flash_args(rng)
    ref = jax.jit(functools.partial(attention_ref_blocked, causal=True,
                                    window=16, **cfg))(*args)
    out = flash_attention_bhsd(*args, causal=True, window=16,
                               interpret=True, **cfg)
    np.testing.assert_array_equal(np.asarray(out), np.asarray(ref))


def test_flash_softcap_close(rng):
    # tanh lowers through different fusions in the two programs: 1 ulp
    # scale differences amplified by exp, so tolerance instead of bitwise
    args = _flash_args(rng)
    cfg = {"bq": 16, "bk": 16}
    ref = jax.jit(functools.partial(attention_ref_blocked, causal=True,
                                    softcap=30.0, **cfg))(*args)
    out = flash_attention_bhsd(*args, causal=True, softcap=30.0,
                               interpret=True, **cfg)
    np.testing.assert_allclose(np.asarray(out), np.asarray(ref),
                               atol=1e-6, rtol=1e-6)
