"""Autotuner subsystem: cache lifecycle, search behavior, admissibility.

Everything here runs with a **synthetic** measure callable and a tmp-dir
cache: no kernel compiles, no wall-clock flakiness.  The real-workload
end of the tuner (jit + time) is exercised by ``benchmarks/kernel_micro``.
"""
import json

import jax.numpy as jnp
import pytest
from _hypothesis_compat import given, settings, st

from repro.core.oobleck import Dispatcher
from repro.kernels import tuning
from repro.kernels.tuning import tuner
from repro.kernels.tuning.cache import TuningCache, plan_digest
from repro.kernels.tuning.space import (MXU_LANE, SPACES, SUBLANE_F32,
                                        VMEM_BUDGET_BYTES, space_for)

SWIGLU_SHAPE = (256, 128, 1024)    # (M, D, F)
FLASH_SHAPE = (2, 128, 128, 8, 2, 64)   # (B, Sq, Skv, H, Hkv, D)


@pytest.fixture
def cache(tmp_path):
    """Process tuning cache pointed at a tmp dir with a pinned
    fingerprint (tests never touch the real artifacts/ cache)."""
    tuning.reset()
    c = TuningCache(str(tmp_path), fingerprint="jax-test/cpu/TestCpu")
    tuning.set_cache(c)
    yield c
    tuning.reset()


def _swiglu_cost(cfg):
    """Synthetic convex-ish surface with the optimum away from defaults."""
    return (abs(cfg["bm"] - 64) + abs(cfg["bf"] - 256) / 8
            + abs(cfg["bs"] - 128) / 16 + 1.0)


# --------------------------------------------------------- cache lifecycle
def test_cold_miss_then_tune_then_warm_hit(cache, tmp_path):
    # cold: no entry anywhere
    assert tuning.lookup("swiglu_mlp", "hw", SWIGLU_SHAPE,
                         jnp.float32) is None
    assert tuning.stats()["misses"] == 1 and tuning.stats()["hits"] == 0

    cfg, us = tuning.tune_kernel("swiglu_mlp", "hw", SWIGLU_SHAPE,
                                 jnp.float32, measure=_swiglu_cost,
                                 budget=200)
    assert cfg == {"bm": 64, "bf": 256, "bs": 128}
    assert us == pytest.approx(_swiglu_cost(cfg))

    # warm: the same process hits
    assert tuning.lookup("swiglu_mlp", "hw", SWIGLU_SHAPE,
                         jnp.float32) == cfg
    assert tuning.stats()["hits"] == 1 and tuning.stats()["tuned"] == 1

    # persisted: a brand-new cache object on the same dir + fingerprint
    # (a later process) reloads the entry from disk
    fresh = TuningCache(str(tmp_path), fingerprint=cache.fingerprint)
    assert fresh.get("swiglu_mlp", "hw", SWIGLU_SHAPE, jnp.float32) == cfg
    doc = json.load(open(cache.path))
    assert cache.fingerprint in doc["by_backend"]


def test_fingerprint_partitions_the_cache(cache, tmp_path):
    cfg = {"bm": 64, "bf": 256, "bs": 128}
    cache.put("swiglu_mlp", "hw", SWIGLU_SHAPE, jnp.float32, cfg, us=1.0)
    other = TuningCache(str(tmp_path), fingerprint="jax-other/tpu/v5e")
    # same file, different backend: cold miss, never a cross-backend leak
    assert other.get("swiglu_mlp", "hw", SWIGLU_SHAPE, jnp.float32) is None
    same = TuningCache(str(tmp_path), fingerprint=cache.fingerprint)
    assert same.get("swiglu_mlp", "hw", SWIGLU_SHAPE, jnp.float32) == cfg


def test_corrupt_cache_fails_open(cache, tmp_path):
    cache.put("swiglu_mlp", "hw", SWIGLU_SHAPE, jnp.float32,
              {"bm": 64, "bf": 256, "bs": 128})
    with open(cache.path, "w") as f:
        f.write("{ not json")
    cache.invalidate()
    # corrupt file == empty cache: lookup is None, nothing raises
    assert tuning.lookup("swiglu_mlp", "hw", SWIGLU_SHAPE,
                         jnp.float32) is None
    # and a put over the corrupt file recovers it
    cache.put("swiglu_mlp", "hw", SWIGLU_SHAPE, jnp.float32,
              {"bm": 32, "bf": 512, "bs": 256})
    assert json.load(open(cache.path))["schema"] == 1


def test_stale_inadmissible_entry_is_ignored(cache):
    # an entry persisted under an older search space that today's kernel
    # would reject (bm=12 breaks M % bm) must be filtered by lookup
    cache.put("swiglu_mlp", "hw", SWIGLU_SHAPE, jnp.float32,
              {"bm": 12, "bf": 256, "bs": 128})
    assert tuning.lookup("swiglu_mlp", "hw", SWIGLU_SHAPE,
                         jnp.float32) is None


def test_plan_scoped_lookup_prefers_plan_entry(cache):
    plan_key = ("stage0:sw", "stage1:hw")
    default_cfg = {"bm": 128, "bf": 512, "bs": 128}
    plan_cfg = {"bm": 64, "bf": 256, "bs": 128}
    cache.put("swiglu_mlp", "hw", SWIGLU_SHAPE, jnp.float32, default_cfg)
    cache.put("swiglu_mlp", "hw", SWIGLU_SHAPE, jnp.float32, plan_cfg,
              plan=plan_digest(plan_key))
    assert tuning.lookup("swiglu_mlp", "hw", SWIGLU_SHAPE,
                         jnp.float32) == default_cfg
    with tuning.plan_scope(plan_key):
        assert tuning.lookup("swiglu_mlp", "hw", SWIGLU_SHAPE,
                             jnp.float32) == plan_cfg
    # a plan with no dedicated entry falls back to the default entry
    with tuning.plan_scope(("some", "other", "plan")):
        assert tuning.lookup("swiglu_mlp", "hw", SWIGLU_SHAPE,
                             jnp.float32) == default_cfg


def test_disabled_by_env(cache, monkeypatch):
    cache.put("swiglu_mlp", "hw", SWIGLU_SHAPE, jnp.float32,
              {"bm": 64, "bf": 256, "bs": 128})
    monkeypatch.setenv("REPRO_TUNER", "off")
    assert tuning.lookup("swiglu_mlp", "hw", SWIGLU_SHAPE,
                         jnp.float32) is None


def test_dispatcher_threads_plan_scope_to_lookups(cache):
    seen = {}

    def build(key):
        seen["build"] = tuning.current_plan_key()

        def fn(x):
            seen["call"] = tuning.current_plan_key()
            return x

        return fn

    d = Dispatcher(build)
    assert d(("planA",), 1) == 1
    assert seen == {"build": ("planA",), "call": ("planA",)}
    assert tuning.current_plan_key() is None   # scope did not leak


# ------------------------------------------------------------- the search
def test_tuner_sweeps_and_hillclimbs_to_optimum(cache):
    cfg, us, evals = tuner.tune("swiglu_mlp", "hw", SWIGLU_SHAPE,
                                measure=_swiglu_cost, budget=500)
    assert cfg == {"bm": 64, "bf": 256, "bs": 128}
    assert evals <= 500


def test_tuner_respects_budget(cache):
    calls = []

    def measure(cfg):
        calls.append(dict(cfg))
        return float(len(calls))

    _, _, evals = tuner.tune("swiglu_mlp", "hw", SWIGLU_SHAPE,
                             measure=measure, budget=5)
    assert evals == 5 and len(calls) == 5


def test_crashing_config_never_aborts_search(cache):
    def measure(cfg):
        if cfg["bm"] != 64:
            raise RuntimeError("simulated tile crash")
        return float(cfg["bf"])

    cfg, us, _ = tuner.tune("swiglu_mlp", "hw", SWIGLU_SHAPE,
                            measure=measure, budget=500)
    assert cfg["bm"] == 64 and cfg["bf"] == 128


def test_tuner_raises_when_nothing_measures(cache):
    def measure(cfg):
        raise RuntimeError("all tiles crash")

    with pytest.raises(RuntimeError, match="no admissible config"):
        tuner.tune("swiglu_mlp", "hw", SWIGLU_SHAPE, measure=measure,
                   budget=10)


def test_seeded_default_bounds_the_result(cache):
    # the kernel default is always in the sweep, so the tuned config can
    # never score worse than it on the same surface
    default = dict(SPACES[("swiglu_mlp", "hw")].defaults)
    _, us, _ = tuner.tune("swiglu_mlp", "hw", SWIGLU_SHAPE,
                          measure=_swiglu_cost, budget=500)
    assert us <= _swiglu_cost(default)


# --------------------------------------------- admissibility (properties)
@settings(max_examples=30, deadline=None)
@given(mi=st.sampled_from([8, 16, 64, 256, 1024]),
       fi=st.sampled_from([128, 256, 1024, 4096]))
def test_swiglu_sweep_configs_are_admissible(mi, fi):
    shape = (mi, 128, fi)
    space = space_for("swiglu_mlp", "hw")
    cfgs = list(space.configs(shape))
    assert cfgs, f"empty sweep for {shape}"
    for cfg in cfgs:
        assert space.admissible(cfg, shape)
        bm, bf = min(cfg["bm"], mi), min(cfg["bf"], fi)
        assert mi % bm == 0 and fi % bf == 0   # grid divisibility
        assert bf % min(cfg["bs"], bf) == 0    # hidden sub-tile streams
        assert space.vmem(cfg, shape) <= VMEM_BUDGET_BYTES


@settings(max_examples=30, deadline=None)
@given(sq=st.sampled_from([8, 32, 128, 512, 2048]),
       skv=st.sampled_from([8, 32, 128, 512, 2048]),
       d=st.sampled_from([64, 128]))
def test_flash_sweep_configs_are_admissible(sq, skv, d):
    shape = (2, sq, skv, 8, 2, d)
    space = space_for("flash_attention", "hw")
    cfgs = list(space.configs(shape))
    assert cfgs, f"empty sweep for {shape}"
    for cfg in cfgs:
        # MXU geometry: sublane-aligned score tiles, VMEM under budget
        assert cfg["bq"] % SUBLANE_F32 == 0
        assert cfg["bk"] % SUBLANE_F32 == 0
        assert cfg["bq"] <= -(-max(sq, 8) // 8) * 8
        assert cfg["bk"] <= -(-max(skv, 8) // 8) * 8
        assert space.vmem(cfg, shape) <= VMEM_BUDGET_BYTES
    assert MXU_LANE % SUBLANE_F32 == 0   # geometry sanity


@settings(max_examples=20, deadline=None)
@given(key=st.sampled_from(sorted(SPACES)),
       i=st.integers(0, 10 ** 6))
def test_neighbors_stay_admissible(key, i):
    space = SPACES[key]
    shape = {"flash_attention": FLASH_SHAPE,
             "swiglu_mlp": SWIGLU_SHAPE,
             "mamba2_ssd": (2, 512, 4, 32, 16),
             "rwkv6_wkv": (2, 256, 4, 16, 16)}[key[0]]
    cfgs = list(space.configs(shape))
    cfg = cfgs[i % len(cfgs)]
    for cand in space.neighbors(cfg, shape):
        assert space.admissible(cand, shape)
        # a neighbor changes exactly one knob by one choice index
        diff = [n for n in space.params if cand[n] != cfg[n]]
        assert len(diff) == 1
        choices = space.params[diff[0]]
        assert abs(choices.index(cand[diff[0]])
                   - choices.index(cfg[diff[0]])) == 1
