"""RWKV6 WKV kernel: interpret-mode + chunked vs the naive recurrence."""
import jax.numpy as jnp
import numpy as np
import pytest
from _hypothesis_compat import given, settings, st

from repro.kernels.rwkv6_scan import (wkv6, wkv6_chunked, wkv6_scan_ref,
                                      wkv6_step)


def _mk(rng, B, S, H, K, V, lw_max=3.0):
    r = jnp.asarray(rng.normal(size=(B, S, H, K)), jnp.float32)
    k = jnp.asarray(rng.normal(size=(B, S, H, K)), jnp.float32)
    v = jnp.asarray(rng.normal(size=(B, S, H, V)), jnp.float32)
    lw = jnp.asarray(-rng.uniform(1e-3, lw_max, size=(B, S, H, K)),
                     jnp.float32)
    u = jnp.asarray(rng.normal(size=(H, K)) * 0.3, jnp.float32)
    return r, k, v, lw, u


@pytest.mark.parametrize("B,S,H,K,V,chunk", [
    (1, 32, 1, 8, 8, 8), (2, 64, 2, 16, 16, 16),
    (1, 70, 2, 16, 16, 16),            # ragged
    (2, 48, 4, 32, 32, 16),
])
def test_kernel_matches_scan(rng, B, S, H, K, V, chunk):
    r, k, v, lw, u = _mk(rng, B, S, H, K, V)
    ref, _ = wkv6_scan_ref(r, k, v, lw, u)
    chk, _ = wkv6_chunked(r, k, v, lw, u, chunk=chunk)
    hw = wkv6(r, k, v, lw, u, route="interpret", chunk=chunk)
    np.testing.assert_allclose(np.asarray(chk), np.asarray(ref), atol=1e-4,
                               rtol=1e-3)
    np.testing.assert_allclose(np.asarray(hw), np.asarray(ref), atol=1e-4,
                               rtol=1e-3)


def test_strong_decay_stays_finite(rng):
    """Clamped decay range at chunk 16 must not overflow f32 (see kernel)."""
    r, k, v, lw, u = _mk(rng, 1, 64, 1, 8, 8, lw_max=4.0)  # the clamp bound
    ref, _ = wkv6_scan_ref(r, k, v, lw, u)
    hw = wkv6(r, k, v, lw, u, route="interpret", chunk=16)
    assert np.isfinite(np.asarray(hw)).all()
    np.testing.assert_allclose(np.asarray(hw), np.asarray(ref), atol=1e-4,
                               rtol=1e-3)


def test_decode_step_consistency(rng):
    r, k, v, lw, u = _mk(rng, 2, 33, 2, 8, 8)
    ref, _ = wkv6_scan_ref(r, k, v, lw, u)
    _, S1 = wkv6_scan_ref(r[:, :32], k[:, :32], v[:, :32], lw[:, :32], u)
    y, _ = wkv6_step(S1, r[:, 32], k[:, 32], v[:, 32], lw[:, 32], u)
    np.testing.assert_allclose(np.asarray(y), np.asarray(ref[:, 32]),
                               atol=1e-5, rtol=1e-4)


@settings(max_examples=15, deadline=None)
@given(S=st.sampled_from([16, 32, 48]), H=st.integers(1, 3),
       K=st.sampled_from([8, 16]), chunk=st.sampled_from([8, 16]))
def test_property_chunk_invariance(S, H, K, chunk):
    rng = np.random.default_rng(S * 7 + H + K)
    r, k, v, lw, u = _mk(rng, 2, S, H, K, K)
    ref, Sref = wkv6_scan_ref(r, k, v, lw, u)
    chk, Schk = wkv6_chunked(r, k, v, lw, u, chunk=chunk)
    np.testing.assert_allclose(np.asarray(chk), np.asarray(ref), atol=1e-4,
                               rtol=1e-3)
    np.testing.assert_allclose(np.asarray(Schk), np.asarray(Sref),
                               atol=1e-4, rtol=1e-3)
