"""Mamba2 SSD kernel: interpret-mode + chunked-XLA vs the naive scan."""
import jax.numpy as jnp
import numpy as np
import pytest
from _hypothesis_compat import given, settings, st

from repro.kernels.mamba2_scan import (ssd, ssd_chunked, ssd_scan_ref,
                                       ssd_step)


def _mk(rng, B, S, H, P, N, dtype=jnp.float32):
    x = jnp.asarray(rng.normal(size=(B, S, H, P)), dtype)
    dt = jnp.asarray(rng.uniform(0.01, 0.3, size=(B, S, H)), dtype)
    A = jnp.asarray(-rng.uniform(0.3, 2.0, size=(H,)), jnp.float32)
    B_ = jnp.asarray(rng.normal(size=(B, S, N)), dtype)
    C = jnp.asarray(rng.normal(size=(B, S, N)), dtype)
    return x, dt, A, B_, C


@pytest.mark.parametrize("B,S,H,P,N,chunk", [
    (1, 64, 1, 8, 4, 16), (2, 128, 3, 16, 8, 32),
    (1, 100, 2, 16, 8, 32),            # ragged: padding path
    (2, 96, 2, 64, 16, 48),
])
def test_kernel_matches_scan(rng, B, S, H, P, N, chunk):
    x, dt, A, B_, C = _mk(rng, B, S, H, P, N)
    ref, _ = ssd_scan_ref(x, dt, A, B_, C)
    chk, _ = ssd_chunked(x, dt, A, B_, C, chunk=chunk)
    hw = ssd(x, dt, A, B_, C, route="interpret", chunk=chunk)
    np.testing.assert_allclose(np.asarray(chk), np.asarray(ref), atol=2e-4,
                               rtol=2e-3)
    np.testing.assert_allclose(np.asarray(hw), np.asarray(ref), atol=2e-4,
                               rtol=2e-3)


def test_bf16_contract(rng):
    x, dt, A, B_, C = _mk(rng, 2, 64, 2, 16, 8, jnp.bfloat16)
    ref, _ = ssd_scan_ref(x, dt, A, B_, C)
    hw = ssd(x, dt, A, B_, C, route="interpret", chunk=32)
    np.testing.assert_allclose(np.asarray(hw, np.float32),
                               np.asarray(ref, np.float32), atol=5e-2,
                               rtol=5e-2)


def test_decode_step_consistency(rng):
    x, dt, A, B_, C = _mk(rng, 2, 65, 2, 8, 4)
    ref, _ = ssd_scan_ref(x, dt, A, B_, C)
    _, h = ssd_scan_ref(x[:, :64], dt[:, :64], A, B_[:, :64], C[:, :64])
    y, _ = ssd_step(h, x[:, 64], dt[:, 64], A, B_[:, 64], C[:, 64])
    np.testing.assert_allclose(np.asarray(y), np.asarray(ref[:, 64]),
                               atol=1e-5, rtol=1e-4)


@settings(max_examples=15, deadline=None)
@given(S=st.sampled_from([32, 48, 64]), H=st.integers(1, 3),
       P=st.sampled_from([8, 16]), N=st.sampled_from([4, 8]),
       chunk=st.sampled_from([16, 32]))
def test_property_chunk_invariance(S, H, P, N, chunk):
    rng = np.random.default_rng(S + H * 10 + P)
    x, dt, A, B_, C = _mk(rng, 2, S, H, P, N)
    ref, href = ssd_scan_ref(x, dt, A, B_, C)
    chk, hchk = ssd_chunked(x, dt, A, B_, C, chunk=chunk)
    np.testing.assert_allclose(np.asarray(chk), np.asarray(ref), atol=2e-4,
                               rtol=2e-3)
    np.testing.assert_allclose(np.asarray(hchk), np.asarray(href),
                               atol=2e-4, rtol=2e-3)
