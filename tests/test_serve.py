"""Continuous-batching serve engine: admission/evict scheduling, per-request
bit-equivalence with single-request reference decode, and mid-stream fault
failover under both modes (dispatcher recompile + resident health mask)."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import get_config
from repro.models import build_model
from repro.serve import (RECOMPILE, RESIDENT, Request, ServeConfig,
                         ServeEngine, reference_decode, synthetic_workload)
from repro.viscosity import INTERPRET, SW

KEY = jax.random.PRNGKey(0)


def _setup(arch="qwen1.5-4b"):
    cfg = get_config(arch).reduced()
    model = build_model(cfg)
    params = model.init(KEY)
    return cfg, params


def _workload(cfg, n, rng, max_prompt=19, max_new=9, arrival_every=2):
    return synthetic_workload(cfg.vocab_size, n, rng, max_prompt=max_prompt,
                              max_new=max_new, arrival_every=arrival_every,
                              per_arrival=2)


# --------------------------------------------------------- fixed-batch API
def test_generate_shapes_and_determinism():
    cfg, params = _setup()
    eng = ServeEngine(cfg, params, ServeConfig(max_len=80))
    prompts = jax.random.randint(KEY, (3, 16), 0, cfg.vocab_size).astype(
        jnp.int32)
    toks1, _ = eng.generate(prompts, 12)
    toks2, _ = eng.generate(prompts, 12)
    assert toks1.shape == (3, 12)
    np.testing.assert_array_equal(toks1, toks2)


def test_fault_midstream_identical_tokens():
    """The paper's functional guarantee, end-to-end on a real LM: a fault
    + reroute mid-generation leaves the decoded tokens unchanged."""
    cfg, params = _setup()
    prompts = jax.random.randint(KEY, (2, 16), 0, cfg.vocab_size).astype(
        jnp.int32)
    eng = ServeEngine(cfg, params, ServeConfig(max_len=80))
    base, _ = eng.generate(prompts, 16)
    eng2 = ServeEngine(cfg, params, ServeConfig(max_len=80))
    faulted, stats = eng2.generate(prompts, 16,
                                   fault_at_step=(8, "flash_attention"))
    np.testing.assert_array_equal(base, faulted)
    assert eng2.fault_state.is_faulty("flash_attention")


def test_fault_midstream_ssm():
    cfg, params = _setup("rwkv6-1.6b")
    prompts = jax.random.randint(KEY, (2, 12), 0, cfg.vocab_size).astype(
        jnp.int32)
    eng = ServeEngine(cfg, params, ServeConfig(max_len=80))
    base, _ = eng.generate(prompts, 8)
    eng2 = ServeEngine(cfg, params, ServeConfig(max_len=80))
    faulted, _ = eng2.generate(prompts, 8, fault_at_step=(4, "rwkv6_wkv"))
    np.testing.assert_array_equal(base, faulted)


# --------------------------------------------------- continuous batching
def test_unequal_lengths_match_reference_decode():
    """Requests of unequal prompt length and budget, decoded together in
    slots, are bit-identical to single-request decode on the bare model."""
    cfg, params = _setup()
    rng = np.random.default_rng(0)
    reqs = _workload(cfg, 6, rng)
    eng = ServeEngine(cfg, params, ServeConfig(max_len=64, max_slots=3))
    done, stats = eng.serve(reqs)
    assert sorted(done) == sorted(r.rid for r in reqs)
    for r in reqs:
        ref = reference_decode(cfg, params, r.prompt, r.max_new_tokens,
                               max_len=64)
        np.testing.assert_array_equal(done[r.rid].tokens, ref)
        assert done[r.rid].prompt_len == len(r.prompt)
        assert len(done[r.rid].tokens) == r.max_new_tokens


def test_staggered_admission_and_slot_reuse():
    """More requests than slots with staggered arrivals: slots are reused
    (continuous batching), nobody is admitted before arrival, and the
    engine ends with everything completed."""
    cfg, params = _setup()
    rng = np.random.default_rng(1)
    reqs = _workload(cfg, 16, rng, arrival_every=3)
    eng = ServeEngine(cfg, params, ServeConfig(max_len=64, max_slots=4))
    done, stats = eng.serve(reqs)
    assert len(done) == 16
    assert stats["admitted"] == 16
    assert max(stats["occupancy"]) <= 4
    for r in reqs:
        assert done[r.rid].admitted_step >= r.arrival
    # with 16 requests on 4 slots the engine must have reused slots
    assert stats["steps"] > max(r.arrival for r in reqs)


@pytest.mark.parametrize("mode", [RECOMPILE, RESIDENT])
def test_fault_mid_decode_completes_in_flight(mode):
    """A stage quarantined while sequences are mid-decode: every in-flight
    request still completes, with outputs bit-identical to the
    single-request reference (and to a fault-free serve)."""
    cfg, params = _setup()
    rng = np.random.default_rng(2)
    reqs = _workload(cfg, 8, rng)
    eng = ServeEngine(cfg, params, ServeConfig(max_len=64, max_slots=3,
                                               failover=mode))
    done, stats = eng.serve(reqs, fault_at_step=(4, "flash_attention"))
    assert len(done) == len(reqs)
    assert eng.fault_state.is_faulty("flash_attention")
    for r in reqs:
        ref = reference_decode(cfg, params, r.prompt, r.max_new_tokens,
                               max_len=64)
        np.testing.assert_array_equal(done[r.rid].tokens, ref)


def test_recompile_mode_reconfigures_once():
    """With a healthy route distinct from the fallback, a fault is exactly
    one reconfiguration (plan-keyed Dispatcher recompile)."""
    cfg, params = _setup()
    rng = np.random.default_rng(3)
    reqs = _workload(cfg, 4, rng, max_new=7)
    eng = ServeEngine(cfg, params, ServeConfig(max_len=64, max_slots=2,
                                               hw_route=INTERPRET,
                                               failover=RECOMPILE))
    done, stats = eng.serve(reqs, fault_at_step=(3, "flash_attention"))
    assert len(done) == len(reqs)
    assert stats["recompiles"] == 1
    assert stats["decode_compiles"] == 2


def test_resident_mode_never_recompiles():
    """Hot-spare residency: the fault flips a health-mask bit; the decode
    executable is compiled exactly once."""
    cfg, params = _setup()
    rng = np.random.default_rng(3)
    reqs = _workload(cfg, 4, rng, max_new=7)
    eng = ServeEngine(cfg, params, ServeConfig(max_len=64, max_slots=2,
                                               hw_route=INTERPRET,
                                               failover=RESIDENT))
    done, stats = eng.serve(reqs, fault_at_step=(3, "flash_attention"))
    assert len(done) == len(reqs)
    assert stats["recompiles"] == 0
    assert stats["decode_compiles"] == 1
    # prefill is resident too: one dispatcher build serves admissions on
    # both sides of the fault (jit re-specializes per prompt length only)
    assert stats["prefill_compiles"] == 1


def test_failover_modes_agree():
    """Same workload, same mid-stream fault: recompile and resident modes
    produce identical tokens (same routing history, two mechanisms)."""
    cfg, params = _setup()
    rng = np.random.default_rng(4)
    reqs = _workload(cfg, 5, rng, max_new=7)
    outs = {}
    for mode in (RECOMPILE, RESIDENT):
        eng = ServeEngine(cfg, params, ServeConfig(max_len=64, max_slots=3,
                                                   hw_route=INTERPRET,
                                                   failover=mode))
        done, _ = eng.serve(reqs, fault_at_step=(3, "flash_attention"))
        outs[mode] = done
    for r in reqs:
        np.testing.assert_array_equal(outs[RECOMPILE][r.rid].tokens,
                                      outs[RESIDENT][r.rid].tokens)


def test_plan_dedupes_identical_routings():
    """When healthy target == fallback, a fault does not change the
    RoutingPlan, so the dispatcher never recompiles — signature-keyed
    caching could not see this."""
    cfg, params = _setup()
    rng = np.random.default_rng(5)
    reqs = _workload(cfg, 3, rng, max_new=6)
    eng = ServeEngine(cfg, params, ServeConfig(max_len=64, max_slots=3,
                                               hw_route=SW))
    done, stats = eng.serve(reqs, fault_at_step=(2, "flash_attention"))
    assert len(done) == len(reqs)
    assert stats["recompiles"] == 0 and stats["decode_compiles"] == 1


def test_serve_is_thin_wrapper_over_session():
    """The closed-loop entry points (serve, generate) are compat
    wrappers over the streaming session API: driving submit/step/poll
    by hand returns bit-identical completions and the same stats."""
    cfg, params = _setup()
    rng = np.random.default_rng(11)
    reqs = _workload(cfg, 8, rng)
    eng = ServeEngine(cfg, params, ServeConfig(max_len=64, max_slots=3))
    done, stats = eng.serve(reqs)

    sess = eng.session()
    for r in sorted(reqs, key=lambda r: (r.arrival, r.rid)):
        sess.submit(r)
    streamed = {}
    while sess.pending():
        sess.step()
        for c in sess.poll():        # poll mid-run: streaming surface
            streamed[c.rid] = c
    sstats = sess.close()
    assert set(streamed) == set(done)
    for rid, c in done.items():
        np.testing.assert_array_equal(c.tokens, streamed[rid].tokens)
        assert c.admitted_step == streamed[rid].admitted_step
        assert c.finished_step == streamed[rid].finished_step
    for k in ("admitted", "steps", "recompiles", "occupancy"):
        assert stats[k] == sstats[k], k
    # prefill_compiles counts per-run jit misses: the serve() run warmed
    # every prompt length, so the session run on the same engine hitting
    # only cache is exactly the shared-dispatcher contract
    assert stats["prefill_compiles"] > 0
    assert sstats["prefill_compiles"] == 0
    # generate() rides the same path
    prompts = np.stack([r.prompt[:6] for r in reqs[:2]])
    toks, _ = eng.generate(prompts, 5)
    done_g, _ = eng.serve([Request(rid=i, prompt=prompts[i],
                                   max_new_tokens=5) for i in range(2)])
    np.testing.assert_array_equal(
        toks, np.stack([done_g[i].tokens for i in range(2)]))


def test_request_validation():
    cfg, params = _setup()
    eng = ServeEngine(cfg, params, ServeConfig(max_len=16, max_slots=2))
    too_long = Request(rid=0, prompt=np.zeros(12, np.int32),
                       max_new_tokens=8)
    with pytest.raises(ValueError):
        eng.serve([too_long])
    with pytest.raises(ValueError):   # would otherwise never finish
        eng.serve([Request(rid=0, prompt=np.zeros(4, np.int32),
                           max_new_tokens=0)])
    with pytest.raises(ValueError):   # would otherwise crash inside jit
        eng.serve([Request(rid=0, prompt=np.zeros(0, np.int32),
                           max_new_tokens=2)])
    with pytest.raises(ValueError):   # unknown stage names fail loudly
        eng.inject_fault("warp_core")
    with pytest.raises(ValueError):
        eng.serve([Request(rid=1, prompt=np.zeros(4, np.int32),
                           max_new_tokens=2),
                   Request(rid=1, prompt=np.zeros(4, np.int32),
                           max_new_tokens=2)])
    with pytest.raises(ValueError):
        ServeEngine(cfg, params, ServeConfig(failover="bogus"))
    # every rejection names the offending request id and field
    with pytest.raises(ValueError, match=r"request 3.*field 'deadline'"):
        eng.serve([Request(rid=3, prompt=np.zeros(4, np.int32),
                           max_new_tokens=2, deadline=-1.0)])
    with pytest.raises(ValueError, match=r"request 4.*field 'deadline'.*"
                                         r"expire before it arrives"):
        eng.serve([Request(rid=4, prompt=np.zeros(4, np.int32),
                           max_new_tokens=2, arrival_time=5.0,
                           deadline=2.0)])
    with pytest.raises(ValueError, match=r"request 5.*field "
                                         r"'arrival_time'"):
        eng.serve([Request(rid=5, prompt=np.zeros(4, np.int32),
                           max_new_tokens=2, arrival_time=-0.5)])
