"""Serving engine: generation, mid-stream fault failover bit-equivalence."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import get_config
from repro.models import build_model
from repro.serve import ServeConfig, ServeEngine

KEY = jax.random.PRNGKey(0)


def _engine(arch="qwen1.5-4b"):
    cfg = get_config(arch).reduced()
    model = build_model(cfg)
    params = model.init(KEY)
    return cfg, params, ServeEngine(cfg, params, ServeConfig(max_len=80))


def test_generate_shapes_and_determinism():
    cfg, params, eng = _engine()
    prompts = jax.random.randint(KEY, (3, 16), 0, cfg.vocab_size).astype(
        jnp.int32)
    toks1, _ = eng.generate(prompts, 12)
    toks2, _ = eng.generate(prompts, 12)
    assert toks1.shape == (3, 12)
    np.testing.assert_array_equal(toks1, toks2)


def test_fault_midstream_identical_tokens():
    """The paper's functional guarantee, end-to-end on a real LM: a fault
    + reroute mid-generation leaves the decoded tokens unchanged."""
    cfg, params, eng = _engine()
    prompts = jax.random.randint(KEY, (2, 16), 0, cfg.vocab_size).astype(
        jnp.int32)
    base, _ = eng.generate(prompts, 16)
    eng2 = ServeEngine(cfg, params, ServeConfig(max_len=80))
    faulted, stats = eng2.generate(prompts, 16,
                                   fault_at_step=(8, "flash_attention"))
    np.testing.assert_array_equal(base, faulted)
    assert stats["recompiles"] == 1


def test_fault_midstream_ssm():
    cfg, params, eng = _engine("rwkv6-1.6b")
    prompts = jax.random.randint(KEY, (2, 12), 0, cfg.vocab_size).astype(
        jnp.int32)
    base, _ = eng.generate(prompts, 8)
    eng2 = ServeEngine(cfg, params, ServeConfig(max_len=80))
    faulted, stats = eng2.generate(prompts, 8,
                                   fault_at_step=(4, "rwkv6_wkv"))
    np.testing.assert_array_equal(base, faulted)
