"""Fleet-scale fault tolerance: scenario matrix, plan algebra, harness.

Multi-fault sequences (single fault, burst, fault-then-recover, fault on a
serving spare, spares exhausted, device loss) x both failover modes run
through the real FleetServeEngine; every scenario asserts the paper's
functional guarantee at fleet scale — no request dropped, completions
bit-identical to the healthy single-device reference.  The matrix serves
SW-routed (cross-lowering argmax ties make bit-compare against the SW
oracle meaningless otherwise — same split the seed serve tests use); the
INTERPRET-routed tests assert real-reroute mode agreement and compile
accounting.  The FleetHarness
test closes the Fig. 2/Fig. 8 loop: a simulate_fleet Monte-Carlo fault
trace replayed through the real engine lands within 15% of the analytic
VFA degradation curve.
"""
import jax
import numpy as np
import pytest
from _hypothesis_compat import given, settings, st

from repro.configs import get_config
from repro.core import Dispatcher, FaultSignature
from repro.core.datacenter import DegradationModel, FleetHarness, replay_trace
from repro.core.routing import FleetPlan, RoutingPlan, SparePool
from repro.launch.distributed import (FleetEvent, HostTopology,
                                      merge_event_logs, replay_log)
from repro.models import build_model
from repro.serve import (RECOMPILE, RESIDENT, FleetConfig, FleetServeEngine,
                         Request, ServeConfig, reference_decode,
                         synthetic_workload)
from repro.train.runner import (FleetTrainConfig, FleetTrainRunner,
                                TrainConfig, model_stage_names)
from repro import optim
from repro.data import DataConfig, SyntheticLM
from repro.viscosity import (DEGRADED_REDUCED, DEGRADED_REMAP, INTERPRET,
                             SW, lanefault)
from repro.viscosity.lanefault import LaneFault

ARCH = "qwen1.5-4b"
STAGES = ["flash_attention", "swiglu_mlp"]   # model_stage_names(ARCH)

# Localized lane maps for the DEGRADED-route scenarios; widths match the
# reduced() model's kernel output lanes (head_dim=32, d_model=128).
LANE_FAULTS = {
    "flash_attention": LaneFault(kind=lanefault.DROPPED_MAC, lanes=(1, 5),
                                 width=32),
    "swiglu_mlp": LaneFault(kind=lanefault.STUCK, lanes=(3,), width=128),
}


@pytest.fixture
def lane_maps():
    """Register a localized lane map per stage (what a canary sweep with
    localize=True would have recorded), base = the SW deployment target."""
    for s, f in LANE_FAULTS.items():
        lanefault.set_map(s, f, base=SW)
    yield dict(LANE_FAULTS)
    lanefault.reset()


@pytest.fixture(scope="module")
def setup():
    cfg = get_config(ARCH).reduced()
    params = build_model(cfg).init(jax.random.PRNGKey(0))
    assert model_stage_names(cfg) == STAGES
    return cfg, params


def _workload(cfg, n=8, seed=0):
    rng = np.random.default_rng(seed)
    # 3 distinct prompt lengths: enough shape diversity to exercise the
    # per-length prefill specializations without compiling six of them
    return synthetic_workload(cfg.vocab_size, n, rng, min_prompt=6,
                              max_prompt=8, min_new=4, max_new=7,
                              arrival_every=1, per_arrival=2)


def _fleet(cfg, params, mode, *, n_devices=3, n_spares=1, slots=2,
           hw_route=SW):
    # Bit-identity to the SW reference is only guaranteed when the healthy
    # target IS the SW oracle (greedy argmax can legitimately flip between
    # lowerings on near-tie logits within the kernel tolerance) — so the
    # matrix serves SW-routed, exactly like the seed's bit-identity tests,
    # and the INTERPRET tests below assert mode agreement + compile counts.
    return FleetServeEngine(
        cfg, params, ServeConfig(max_len=48, max_slots=slots,
                                 hw_route=hw_route, failover=mode),
        FleetConfig(n_devices=n_devices, n_spares=n_spares))


# ------------------------------------------------------- scenario matrix
# name -> (fleet kwargs, events).  Devices: workers 0..n-2, spare = last.
SCENARIOS = {
    "single_fault": (
        dict(), {3: [("stage", 0, "flash_attention")]}),
    "burst_two_same_step": (            # one migrates, pool dry -> other
        dict(), {3: [("stage", 0, "flash_attention"),      # degrades
                     ("stage", 1, "swiglu_mlp")]}),
    "fault_then_recover": (
        dict(), {2: [("stage", 0, "flash_attention")],
                 6: [("recover", 0)]}),
    "fault_on_spare": (                 # spare in service faults too
        dict(), {2: [("stage", 0, "flash_attention")],
                 5: [("stage", 2, "swiglu_mlp")]}),
    "spares_exhausted": (               # 2nd/3rd fault degrade in place
        dict(), {2: [("stage", 0, "flash_attention")],
                 4: [("stage", 1, "flash_attention")],
                 6: [("stage", 1, "swiglu_mlp")]}),
    "device_loss_with_spare": (
        dict(), {3: [("device", 0)]}),
    "device_loss_no_spare": (           # capacity just shrinks
        dict(n_spares=0, n_devices=2), {3: [("device", 1)]}),
    "multi_wave": (
        dict(), {2: [("stage", 0, "flash_attention")],
                 5: [("device", 1)],
                 8: [("recover", 0)]}),
}


@pytest.mark.parametrize("mode", [RECOMPILE, RESIDENT])
@pytest.mark.parametrize("scenario", sorted(SCENARIOS))
def test_scenario_no_drops_bit_identical(setup, scenario, mode):
    """Every multi-fault sequence, in both failover modes: nothing is
    dropped and every completion equals the healthy single-device
    reference decode bit-for-bit."""
    cfg, params = setup
    fleet_kw, events = SCENARIOS[scenario]
    eng = _fleet(cfg, params, mode, **fleet_kw)
    reqs = _workload(cfg)
    done, stats = eng.serve(reqs, events={k: list(v)
                                          for k, v in events.items()})
    assert sorted(done) == sorted(r.rid for r in reqs)     # no drops
    for r in reqs:
        ref = reference_decode(cfg, params, r.prompt, r.max_new_tokens,
                               max_len=48)
        np.testing.assert_array_equal(done[r.rid].tokens, ref)


def test_scenario_fleet_state_single_fault(setup):
    """The single-fault scenario migrates to the spare (Fig. 8): faulted
    device quarantined, spare in service, full capacity retained."""
    cfg, params = setup
    eng = _fleet(cfg, params, RECOMPILE)
    done, stats = eng.serve(_workload(cfg),
                            events={3: [("stage", 0, "flash_attention")]})
    assert stats["quarantined"] == [0]
    assert stats["spares_in_service"] == [2]
    assert eng.fleet.pool.spare_for(0) == 2
    assert eng.fleet.n_faults(0) == 1


def test_scenario_fleet_state_spares_exhausted(setup):
    """Once the pool is dry, faults degrade in place: the second faulted
    device keeps serving on its SW oracle for the faulted stage."""
    cfg, params = setup
    eng = _fleet(cfg, params, RECOMPILE)
    _, stats = eng.serve(_workload(cfg), events={
        2: [("stage", 0, "flash_attention")],
        4: [("stage", 1, "flash_attention")]})
    assert stats["quarantined"] == [0]            # only the first migrated
    assert 1 in eng.fleet.serving()               # second degraded in place
    assert eng.fleet.plans[1].target_for("flash_attention") == SW
    assert eng.fleet.n_faults(1) == 1


def test_events_after_drain_still_apply(setup):
    """A fault/recover scheduled past the point where the workload
    drains must still change fleet health (not be silently lost): the
    next serve() on the same engine sees the updated fleet."""
    cfg, params = setup
    eng = _fleet(cfg, params, RECOMPILE)
    _, stats = eng.serve(_workload(cfg, n=2),
                         events={10_000: [("stage", 0, "flash_attention")]})
    assert stats["late_events"] == 1
    assert eng.fleet.quarantined == (0,)          # migrated to the spare
    done, _ = eng.serve(_workload(cfg, n=2, seed=3))
    assert len(done) == 2                         # fleet still serves


def test_scenario_recovery_returns_spare(setup):
    cfg, params = setup
    eng = _fleet(cfg, params, RECOMPILE)
    eng.serve(_workload(cfg), events={2: [("stage", 0, "flash_attention")],
                                      6: [("recover", 0)]})
    assert eng.fleet.quarantined == ()
    assert eng.fleet.pool.free() == (2,)          # spare back in the pool
    assert eng.fleet.n_faults(0) == 0             # repaired hardware


def test_resident_fleet_shares_one_decode_executable(setup):
    """RESIDENT mode at fleet scale: every device runs the same resident
    decode program (health masks are inputs), so the whole scenario costs
    exactly one decode compile across all devices and faults."""
    cfg, params = setup
    eng = _fleet(cfg, params, RESIDENT, hw_route=INTERPRET)
    _, stats = eng.serve(_workload(cfg), events={
        2: [("stage", 0, "flash_attention")],
        4: [("stage", 1, "swiglu_mlp")]})
    assert stats["decode_compiles"] == 1


def test_recompile_fleet_dedupes_plans(setup):
    """RECOMPILE mode: devices with equal RoutingPlans share executables
    through the shared Dispatcher — a 3-device healthy fleet compiles
    once, and the in-place degraded plan adds exactly one more."""
    cfg, params = setup
    eng = _fleet(cfg, params, RECOMPILE, n_spares=0, hw_route=INTERPRET)
    _, stats = eng.serve(_workload(cfg), events={
        3: [("stage", 1, "flash_attention")]})
    assert stats["decode_compiles"] == 2          # healthy + degraded

    # replaying the same (now degraded) fleet is zero further compiles
    _, stats2 = eng.serve(_workload(cfg, seed=1))
    assert stats2["decode_compiles"] == 0


def test_fleet_failover_modes_agree_on_real_reroute(setup):
    """With distinct healthy/fallback lowerings (a *real* mid-stream
    reroute), recompile and resident fleets produce identical tokens for
    the same scenario — the fleet-scale version of the seed's
    mode-agreement guarantee."""
    cfg, params = setup
    events = {2: [("stage", 0, "flash_attention")],
              4: [("stage", 1, "swiglu_mlp")]}
    outs = {}
    for mode in (RECOMPILE, RESIDENT):
        eng = _fleet(cfg, params, mode, hw_route=INTERPRET)
        done, _ = eng.serve(_workload(cfg), events={k: list(v)
                                                    for k, v in
                                                    events.items()})
        outs[mode] = done
    assert sorted(outs[RECOMPILE]) == sorted(outs[RESIDENT])
    for rid in outs[RECOMPILE]:
        np.testing.assert_array_equal(outs[RECOMPILE][rid].tokens,
                                      outs[RESIDENT][rid].tokens)


# ------------------------------------------------------ host-loss matrix
@pytest.mark.parametrize("mode", [RECOMPILE, RESIDENT])
def test_host_loss_survivors_absorb_bit_identical(setup, mode):
    """A whole host drops out mid-stream (all its devices quarantined in
    ONE transition): the surviving host absorbs the work — one device
    migrates to the off-host spare, the other's capacity is lost — with
    no request dropped and completions bit-identical to the healthy
    single-device reference, in both failover modes."""
    cfg, params = setup
    topo = HostTopology(num_hosts=2, devices_per_host=2)
    eng = FleetServeEngine(
        cfg, params, ServeConfig(max_len=48, max_slots=2, hw_route=SW,
                                 failover=mode),
        FleetConfig(n_devices=4, n_spares=1, topology=topo))
    reqs = _workload(cfg)
    done, stats = eng.serve(reqs, events={3: [("host", 0)]})
    assert sorted(done) == sorted(r.rid for r in reqs)     # no drops
    for r in reqs:
        ref = reference_decode(cfg, params, r.prompt, r.max_new_tokens,
                               max_len=48)
        np.testing.assert_array_equal(done[r.rid].tokens, ref)
    assert stats["quarantined"] == [0, 1]        # the whole block, at once
    assert [e["event"] for e in eng.event_log] == [("host", 0)]
    assert eng.fleet.pool.spare_for(0) == 3      # off-host spare took over
    assert eng.fleet.serving() == (2, 3)         # host 1 re-folded


def test_with_host_fault_one_transition_algebra():
    """with_host_fault semantics: serving devices migrate to spares
    OUTSIDE the dying block, the block's idle spares leave the pool, and
    the whole loss is one pure transition."""
    fp = FleetPlan.healthy(6, STAGES, n_spares=2)          # spares 4, 5
    hf = fp.with_host_fault((0, 1))
    assert hf.quarantined == (0, 1)
    assert hf.pool.spare_for(0) == 4 and hf.pool.spare_for(1) == 5
    assert hf.serving() == (2, 3, 4, 5)

    # a host that contains the fleet's only spare: the spare must not
    # absorb its own host's work, and it leaves the pool with the host
    fp2 = FleetPlan.healthy(4, STAGES, n_spares=1)         # spare 3
    hf2 = fp2.with_host_fault((2, 3))
    assert hf2.quarantined == (2, 3)
    assert hf2.pool.spares == ()
    assert hf2.serving() == (0, 1)
    # idempotent-ish: nothing left to lose on a dead block
    assert hf2.with_host_fault((2, 3)) == hf2


def test_replay_trace_host_loss_matches_engine_semantics():
    """The analytic twin's host-loss accounting mirrors with_host_fault:
    off-block spare absorbs one device, the rest is lost capacity."""
    rep = replay_trace((), n_workers=3, ticks=6, stage_names=STAGES,
                       n_spares=1, slots_per_device=4, n_hosts=2,
                       host_loss={2: 0})
    assert ("host", 0) in rep.events[2]
    # ticks 0,1: 3 workers x 4 slots; ticks 2+: device 0 -> spare 3,
    # device 1 lost -> 2 serving devices
    assert list(rep.capacity) == [12, 12, 8, 8, 8, 8]
    with pytest.raises(ValueError):
        replay_trace((), n_workers=2, ticks=2, stage_names=STAGES,
                     n_hosts=2, host_loss={0: 5})
    with pytest.raises(ValueError):                 # 3 devices, 2 hosts
        replay_trace((), n_workers=3, ticks=2, stage_names=STAGES,
                     n_hosts=2)


# ------------------------------------------- event-log determinism (prop)
@settings(max_examples=25, deadline=None)
@given(order=st.lists(st.integers(0, 10_000), min_size=6, max_size=6),
       cut=st.integers(0, 6))
def test_property_event_log_interleaving_invariant(order, cut):
    """Any interleaving of per-host event arrival — and any split of the
    events across host logs — yields the same merged log and the same
    final FleetPlan (the multi-host agreement property)."""
    events = [
        FleetEvent(2, 0, 0, "stage", 0, STAGES[0]),
        FleetEvent(2, 1, 0, "device", 1),
        FleetEvent(4, 0, 1, "stage", 2, STAGES[1]),
        FleetEvent(4, 1, 1, "host", 1),
        FleetEvent(5, 0, 2, "recover", 0),
        FleetEvent(6, 1, 2, "device", 3),
    ]
    topo = HostTopology(num_hosts=3, devices_per_host=2)
    base = FleetPlan.healthy(6, STAGES, target=INTERPRET, n_spares=2)
    ref_plan, ref_dropped = replay_log(base, events, STAGES,
                                       target=INTERPRET, topology=topo)
    perm = sorted(range(len(events)), key=lambda i: (order[i], i))
    shuffled = [events[i] for i in perm]
    assert merge_event_logs(shuffled[:cut], shuffled[cut:]) == \
        merge_event_logs(events)
    plan, dropped = replay_log(base, shuffled, STAGES, target=INTERPRET,
                               topology=topo)
    assert plan == ref_plan and hash(plan) == hash(ref_plan)
    assert dropped == ref_dropped


# ---------------------------------------------------------- FleetHarness
def test_fleet_harness_tracks_analytic_curve():
    """Acceptance: replaying a simulate_fleet Monte-Carlo fault trace
    through the real serve engine yields aggregate throughput within 15%
    of the analytic VFA degradation curve, with completions bit-identical
    to the healthy single-device reference.  Drives the ONE scenario
    definition in benchmarks/fleet_bench.py (the same one CI smokes and
    the datacenter_sim example prints), so the acceptance assertion can
    never drift from what ships."""
    from benchmarks.fleet_bench import MAX_LEN, run_scenario

    out, reqs, cfg, params = run_scenario(0)
    assert out["trace_faults"] > 0, "seed must produce at least one fault"
    assert out["rel_err"] <= 0.15, out
    assert out["analytic_ratio"] < 0.95           # the trace really bites
    healthy_done, faulted_done = out["completions"]
    assert sorted(faulted_done) == sorted(r.rid for r in reqs)
    ref_cache = {}
    for r in reqs:
        key = (r.prompt.tobytes(), r.max_new_tokens)
        if key not in ref_cache:
            ref_cache[key] = reference_decode(cfg, params, r.prompt,
                                              r.max_new_tokens,
                                              max_len=MAX_LEN)
        np.testing.assert_array_equal(faulted_done[r.rid].tokens,
                                      ref_cache[key])
        np.testing.assert_array_equal(healthy_done[r.rid].tokens,
                                      ref_cache[key])


# ------------------------------------------------- DEGRADED route ladder
def test_with_stage_fault_walks_degradation_ladder():
    """Ladder algebra: a lane-mapped stage degrades remap -> reduced ->
    SW across repeated faults; an unmapped stage still drops straight to
    the binary fallback; recovery clears the ladder position."""
    stage = "flash_attention"
    with lanefault.known_map(stage, LANE_FAULTS[stage], base=SW):
        fp = FleetPlan.healthy(2, STAGES, target=INTERPRET, n_spares=0)
        fp1 = fp.with_stage_fault(0, stage)
        assert fp1.plans[0].target_for(stage) == DEGRADED_REMAP
        fp2 = fp1.with_stage_fault(0, stage)
        assert fp2.plans[0].target_for(stage) == DEGRADED_REDUCED
        fp3 = fp2.with_stage_fault(0, stage)
        assert fp3.plans[0].target_for(stage) == SW
        assert fp3.stage_fault_count(0, stage) == 3
        assert fp3.n_faults(0) == 3
        # the other device and the unmapped stage are untouched
        assert fp3.plans[1].target_for(stage) == INTERPRET
        assert fp3.with_stage_fault(1, "swiglu_mlp") \
                  .plans[1].target_for("swiglu_mlp") == SW   # no map
        # spare-migration still wins over in-place degradation
        sp = FleetPlan.healthy(3, STAGES, target=INTERPRET, n_spares=1)
        sp1 = sp.with_stage_fault(0, stage)
        assert sp1.quarantined == (0,) and sp1.pool.spare_for(0) == 2
        # recovery clears the device's ladder position entirely
        rec = sp1.with_recovery(0, STAGES, target=INTERPRET)
        assert rec.stage_fault_count(0, stage) == 0


@pytest.mark.parametrize("mode", [RECOMPILE, RESIDENT])
def test_degraded_ladder_scenario_bit_identical(setup, mode, lane_maps):
    """The ISSUE scenario, in both failover modes: a lane fault routes the
    stage to DEGRADED remap (NOT straight to SW), a second to reduced-width,
    a third to the full SW oracle — while every completion stays
    bit-identical to the healthy single-device reference."""
    cfg, params = setup
    stage = "flash_attention"
    eng = _fleet(cfg, params, mode, n_devices=2, n_spares=0)
    reqs = _workload(cfg)
    done, stats = eng.serve(reqs, events={2: [("stage", 0, stage)],
                                          4: [("stage", 0, stage)],
                                          6: [("stage", 0, stage)]})
    assert sorted(done) == sorted(r.rid for r in reqs)     # no drops
    for r in reqs:
        ref = reference_decode(cfg, params, r.prompt, r.max_new_tokens,
                               max_len=48)
        np.testing.assert_array_equal(done[r.rid].tokens, ref)
    # the ladder actually walked: three faults accumulated, bottom = SW
    assert eng.fleet.stage_fault_count(0, stage) == 3
    assert eng.fleet.plans[0].target_for(stage) == SW
    assert eng.fleet.plans[1].target_for(stage) == SW      # healthy target
    assert 0 in eng.fleet.serving()                        # never dropped


def test_degraded_ladder_intermediate_rungs_in_plan_cache(setup, lane_maps):
    """RECOMPILE mode dispatches each rung through the plan-keyed compile
    cache: remap and reduced-width are distinct executables; the final
    SW rung dedupes against the healthy all-SW plan (zero new compiles)."""
    cfg, params = setup
    stage = "flash_attention"
    eng = _fleet(cfg, params, RECOMPILE, n_devices=2, n_spares=0)
    eng.serve(_workload(cfg, n=2))                         # healthy warm-up
    eng.inject_stage_fault(0, stage)
    assert eng.fleet.plans[0].target_for(stage) == DEGRADED_REMAP
    _, s1 = eng.serve(_workload(cfg, n=2, seed=1))
    assert s1["decode_compiles"] == 1                      # remap plan
    eng.inject_stage_fault(0, stage)
    assert eng.fleet.plans[0].target_for(stage) == DEGRADED_REDUCED
    _, s2 = eng.serve(_workload(cfg, n=2, seed=2))
    assert s2["decode_compiles"] == 1                      # reduced plan
    eng.inject_stage_fault(0, stage)
    assert eng.fleet.plans[0].target_for(stage) == SW
    _, s3 = eng.serve(_workload(cfg, n=2, seed=3))
    assert s3["decode_compiles"] == 0                      # == healthy SW


def test_fleet_harness_partial_degradation_tracks_model(setup, lane_maps):
    """Acceptance: with a DegradationModel and a lane-mapped stage, the
    measured throughput of a partially-degraded fleet (remap / reduced
    rungs instead of binary SW quarantines) closes against the analytic
    per-rung capacity curve within 15%, completions bit-identical."""
    cfg, params = setup
    model = DegradationModel()
    horizon, slots = 16, 4
    # dev 0 walks flash's ladder twice (remap then reduced); dev 1 takes
    # one remapped fault; pool dry so everything degrades in place
    trace = ((2, 0), (6, 0), (10, 1))
    rep = replay_trace(trace, n_workers=3, ticks=horizon,
                       stage_names=STAGES, n_spares=0,
                       slots_per_device=slots, max_faults=3, model=model,
                       lane_mapped=("flash_attention",))
    eng = FleetServeEngine(
        cfg, params, ServeConfig(max_len=48, max_slots=slots, hw_route=SW,
                                 failover=RECOMPILE),
        FleetConfig(n_devices=3, n_spares=0, model=model))
    rng = np.random.default_rng(5)
    n_reqs = (3 * slots * horizon * 3) // (2 * 8)   # saturate the horizon
    reqs = [Request(rid=i,
                    prompt=rng.integers(0, cfg.vocab_size,
                                        size=8).astype(np.int32),
                    max_new_tokens=8)
            for i in range(n_reqs)]
    out = FleetHarness(eng, rep, horizon=horizon).run(reqs)
    assert out["rel_err"] <= 0.15, out["rel_err"]
    assert out["analytic_ratio"] < 1.0              # the trace bites...
    # ...but partially: better than the binary all-SW accounting
    binary = replay_trace(trace, n_workers=3, ticks=horizon,
                          stage_names=STAGES, n_spares=0,
                          slots_per_device=slots, max_faults=3)
    assert out["analytic_ratio"] > binary.mean_ratio
    # the engine really served on DEGRADED plans, charged per-rung slots
    assert eng.fleet.plans[0].target_for("flash_attention") == \
        DEGRADED_REDUCED
    assert eng.fleet.plans[1].target_for("flash_attention") == \
        DEGRADED_REMAP
    assert eng.fcfg.capacity_for(2, slots, plan=eng.fleet.plans[0]) == \
        model.slot_cap(slots, 2, (("flash_attention", DEGRADED_REDUCED),))
    healthy_done, faulted_done = out["completions"]
    for r in reqs:
        ref = reference_decode(cfg, params, r.prompt, r.max_new_tokens,
                               max_len=48)
        np.testing.assert_array_equal(faulted_done[r.rid].tokens, ref)
        np.testing.assert_array_equal(healthy_done[r.rid].tokens, ref)


def test_replay_trace_spares_absorb_first_faults():
    """Fig. 8 analytics: with a hot spare, the first fault costs no
    capacity at all; without one, it costs per the VFA curve."""
    trace = ((2, 0),)
    with_spare = replay_trace(trace, n_workers=2, ticks=6,
                              stage_names=STAGES, n_spares=1,
                              slots_per_device=4)
    without = replay_trace(trace, n_workers=2, ticks=6,
                           stage_names=STAGES, n_spares=0,
                           slots_per_device=4)
    assert with_spare.mean_ratio == 1.0
    assert without.mean_ratio < 1.0
    assert ("stage", 0, "flash_attention") in with_spare.events[2]


# ------------------------------------------------------ fleet train path
def test_fleet_train_runner_detect_quarantine_migrate():
    """Data-parallel fleet training: a poisoned shard trips the guard,
    the device quarantines, its slice migrates (spare first), training
    continues with finite losses and plan-deduped compiles."""
    cfg = get_config(ARCH).reduced()
    data = SyntheticLM(DataConfig(vocab_size=cfg.vocab_size, batch=8,
                                  seq_len=16))
    r = FleetTrainRunner(
        cfg, optim.AdamWConfig(lr=1e-3, warmup_steps=2, total_steps=20),
        TrainConfig(steps=6, hw_route=SW), data,
        FleetTrainConfig(n_devices=3, n_spares=1))
    params, opt = r.init_state()
    params, opt = r.run(params, opt, steps=2)
    assert all(np.isfinite(h["loss"]) for h in r.history)
    assert r.history[-1]["n_serving"] == 2        # spare idle while healthy
    # one shared compile: both shards run the same (healthy, SW) plan
    assert r.dispatcher.compiles == 1

    params, opt = r.run(params, opt, steps=2, poison={0: 1})
    assert r.guard_trips == 1
    assert 1 in r.fleet.quarantined               # detected & quarantined
    assert 2 in r.fleet.serving()                 # migrated to the spare
    assert all(np.isfinite(h["loss"]) for h in r.history)
    assert r.dispatcher.compiles == 1             # reroute, no new plan


def test_fleet_train_stage_fault_reroutes_one_shard():
    """A stage fault with the pool dry degrades that shard's plan only —
    the other shard keeps the optimized target — and on the SW-routed CPU
    deployment the plan-keyed dispatcher dedupes the reroute to zero new
    compiles (the paper's reconfiguration accounting, at fleet scale)."""
    cfg = get_config(ARCH).reduced()
    data = SyntheticLM(DataConfig(vocab_size=cfg.vocab_size, batch=6,
                                  seq_len=16))
    r = FleetTrainRunner(
        cfg, optim.AdamWConfig(lr=1e-3, warmup_steps=2, total_steps=20),
        TrainConfig(steps=4, hw_route=SW), data,
        FleetTrainConfig(n_devices=2, n_spares=0))
    params, opt = r.init_state()
    params, opt = r.run(params, opt, steps=1)
    assert r.dispatcher.compiles == 1             # both shards share plan
    r.inject_stage_fault(0, "flash_attention")
    params, opt = r.run(params, opt, steps=1)
    assert r.dispatcher.compiles == 1             # SW->SW: plan unchanged
    assert r.fleet.n_faults(0) == 1 and r.fleet.n_faults(1) == 0
    assert all(np.isfinite(h["loss"]) for h in r.history)

    # with distinct healthy/fallback targets the shard plans diverge:
    # exactly the faulted shard reroutes (plan-level check; interpret
    # kernels have no autodiff path to actually train through on CPU)
    r2 = FleetTrainRunner(
        cfg, optim.AdamWConfig(lr=1e-3, warmup_steps=2, total_steps=20),
        TrainConfig(steps=4, hw_route=INTERPRET), data,
        FleetTrainConfig(n_devices=2, n_spares=0))
    r2.inject_stage_fault(0, "flash_attention")
    assert r2.fleet.plan_for(0) != r2.fleet.plan_for(1)
    assert r2.fleet.plans[0].target_for("flash_attention") == SW
    assert r2.fleet.plans[1].target_for("flash_attention") == INTERPRET


def test_fleet_train_host_dropout_refolds_mesh():
    """The FleetTrainRunner host-dropout path: a lost host quarantines
    its whole device block in ONE transition (logged as one host event),
    the faulted block's work migrates to the off-host spare, and the
    surviving hosts re-fold the mesh — training continues finite."""
    cfg = get_config(ARCH).reduced()
    data = SyntheticLM(DataConfig(vocab_size=cfg.vocab_size, batch=8,
                                  seq_len=16))
    topo = HostTopology(num_hosts=2, devices_per_host=2)
    r = FleetTrainRunner(
        cfg, optim.AdamWConfig(lr=1e-3, warmup_steps=2, total_steps=20),
        TrainConfig(steps=4, hw_route=SW), data,
        FleetTrainConfig(n_devices=4, n_spares=1, topology=topo))
    params, opt = r.init_state()
    params, opt = r.run(params, opt, steps=3, host_loss={1: 0})
    assert r.history[0]["n_serving"] == 3         # workers 0,1,2 healthy
    assert r.history[0]["hosts_serving"] == 2
    assert all(h["n_serving"] == 2 for h in r.history[1:])
    assert all(h["hosts_serving"] == 1 for h in r.history[1:])
    assert set(r.fleet.quarantined) == {0, 1}     # the block, at once
    assert r.fleet.pool.spare_for(0) == 3         # off-host spare absorbs
    assert all(np.isfinite(h["loss"]) for h in r.history)
    assert [(e.kind, e.device) for e in r.fleet_log] == [("host", 0)]
    # the re-fold: the same global batch redistributes over survivors
    from repro.launch.sharding import shard_bounds
    assert set(shard_bounds(8, r.fleet.device_mask())) == {2, 3}


# --------------------------------------- dispatcher churn (fleet-keyed)
@pytest.fixture
def compile_counter():
    calls = []

    def build(key):
        calls.append(key)
        return lambda: key

    return Dispatcher(build, capacity=2), calls


def _mini_fleet(order):
    plans = {"sw": RoutingPlan.make({"s": "sw"}),
             "hw": RoutingPlan.make({"s": "hw"}),
             "in": RoutingPlan.make({"s": "interpret"})}
    return FleetPlan(plans=tuple(plans[k] for k in order))


def test_dispatcher_repeated_fleet_plan_zero_recompiles(compile_counter):
    d, calls = compile_counter
    fp = _mini_fleet(["sw", "hw"])
    d.get(fp), d.get(fp)
    assert d.compiles == 1
    # same routing multiset, different device numbering: still a hit
    d.get(_mini_fleet(["hw", "sw"]))
    assert d.compiles == 1


def test_dispatcher_fleet_churn_lru_evicts_and_recompiles_once(
        compile_counter):
    d, calls = compile_counter
    a, b, c = (_mini_fleet(o) for o in (["sw", "sw"], ["sw", "hw"],
                                        ["hw", "hw"]))
    d.get(a), d.get(b)
    assert d.compiles == 2
    d.get(c)                                       # capacity 2: evicts a
    assert d.compiles == 3
    d.get(b)                                       # still resident: hit
    assert d.compiles == 3
    d.get(a)                                       # evicted: exactly one
    assert d.compiles == 4                         # recompile
    assert len(calls) == 4


# ------------------------------------------------- plan algebra (property)
@settings(max_examples=25, deadline=None)
@given(seq=st.lists(st.tuples(st.integers(0, 4), st.booleans()),
                    min_size=0, max_size=10))
def test_property_spare_assignment_injective(seq):
    """Any fault sequence: no spare ever serves two devices, serving and
    quarantined stay disjoint, and the mask counts the serving set."""
    fp = FleetPlan.healthy(5, STAGES, target=INTERPRET, n_spares=2)
    for dev, is_stage in seq:
        if dev not in fp.serving():
            continue
        fp = (fp.with_stage_fault(dev, STAGES[dev % len(STAGES)])
              if is_stage else fp.with_device_fault(dev))
    targets = [s for _, s in fp.pool.assignments]
    assert len(set(targets)) == len(targets)
    assert not set(fp.serving()) & set(fp.quarantined)
    assert sum(fp.device_mask()) == len(fp.serving())


@settings(max_examples=25, deadline=None)
@given(bits=st.lists(st.booleans(), min_size=4, max_size=4))
def test_property_routing_plan_hash_equality_laws(bits):
    """Equal fault histories produce ==, hash-equal plans; from_signature
    is idempotent (same signature -> the same plan value every time) and
    with_fault is idempotent per stage."""
    names = [f"s{i}" for i in range(len(bits))]
    sig = FaultSignature.healthy(names)
    for n, bad in zip(names, bits):
        if bad:
            sig = sig.with_fault(n)
    p1 = RoutingPlan.from_signature(sig, healthy=INTERPRET)
    p2 = RoutingPlan.from_signature(sig, healthy=INTERPRET)
    assert p1 == p2 and hash(p1) == hash(p2)
    for n, bad in zip(names, bits):
        if bad:
            assert p1.with_fault(n) == p1          # already routed SW
    # insertion order never matters
    p3 = RoutingPlan(tuple(reversed(p1.assignments)), p1.default)
    assert p3 == p1 and hash(p3) == hash(p1)


@settings(max_examples=25, deadline=None)
@given(seq=st.lists(st.integers(0, 3), min_size=0, max_size=6),
       n_spares=st.integers(0, 2))
def test_property_fleet_plan_hash_equality_laws(seq, n_spares):
    """Two fleets with the same fault history are the same value (== and
    hash-equal) and share a compile key; the compile key is invariant
    under replaying the same events."""

    def replay():
        fp = FleetPlan.healthy(4, STAGES, target=INTERPRET,
                               n_spares=n_spares)
        for dev in seq:
            if dev in fp.serving():
                fp = fp.with_stage_fault(dev, STAGES[0])
        return fp

    a, b = replay(), replay()
    assert a == b and hash(a) == hash(b)
    assert a.compile_key() == b.compile_key()


@settings(max_examples=25, deadline=None)
@given(perm=st.sampled_from([(0, 1, 2), (0, 2, 1), (1, 0, 2), (1, 2, 0),
                             (2, 0, 1), (2, 1, 0)]))
def test_property_compile_key_permutation_invariant(perm):
    """The Dispatcher key is the routing *multiset*: renumbering devices
    never changes it (while the exact table does distinguish them)."""
    base = (RoutingPlan.make({"s": "sw"}), RoutingPlan.make({"s": "hw"}),
            RoutingPlan.make({"s": "interpret"}))
    fp = FleetPlan(plans=base)
    fq = FleetPlan(plans=tuple(base[i] for i in perm))
    assert fp.compile_key() == fq.compile_key()


def test_spare_pool_rejects_double_assignment():
    with pytest.raises(ValueError):
        SparePool(spares=(3,), assignments=((0, 3), (1, 3)))
    with pytest.raises(ValueError):
        SparePool(spares=(3, 4), assignments=((0, 3), (0, 4)))
    with pytest.raises(ValueError):
        SparePool(spares=(3,), assignments=((0, 7),))


def test_fleet_plan_validates_transitions():
    fp = FleetPlan.healthy(3, STAGES, n_spares=1)
    with pytest.raises(ValueError):
        fp.with_stage_fault(2, STAGES[0])          # idle spare: not serving
    with pytest.raises(ValueError):
        fp.with_recovery(0, STAGES)                # nothing quarantined
    dead = fp.with_device_fault(0)
    with pytest.raises(ValueError):
        dead.with_device_fault(0)                  # already gone
    with pytest.raises(ValueError):
        FleetPlan.healthy(2, STAGES, n_spares=2)   # all-spare fleet
    with pytest.raises(KeyError):
        dead.plan_for(0)
