"""Prefill + decode == teacher-forced forward, per architecture.

The serving path (KV caches, ring buffers, SSM states, cross-KV caches)
must reproduce the training-forward logits token by token.
"""
import dataclasses

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import ARCH_NAMES, get_config
from repro.models import build_model

KEY = jax.random.PRNGKey(0)
B, S, P = 2, 24, 16


def _nodrop(cfg):
    # f32 compute isolates LOGIC errors from bf16 fusion-order noise
    # (scan vs unrolled decode produce different fusions); no-drop MoE
    # capacity makes teacher-forcing and decode see identical routing.
    cfg = dataclasses.replace(cfg, dtype="float32")
    if cfg.moe is not None:
        return dataclasses.replace(
            cfg, moe=dataclasses.replace(cfg.moe, capacity_factor=8.0))
    return cfg


@pytest.mark.parametrize("arch", ARCH_NAMES)
def test_decode_matches_teacher_forced(arch):
    cfg = _nodrop(get_config(arch).reduced())
    model = build_model(cfg)
    params = model.init(KEY)
    tokens = jax.random.randint(KEY, (B, S), 0, cfg.vocab_size)

    if cfg.is_encdec:
        emb = jax.random.normal(KEY, (B, 32, cfg.d_model), jnp.float32)
        full = model.logits_all(params, {"embeds": emb,
                                         "dec_tokens": tokens})
        cache = model.init_cache(B, S)
        lg, state = jax.jit(model.prefill)(
            params, {"embeds": emb, "dec_tokens": tokens[:, :P],
                     "cache": cache})
        errs = [float(jnp.abs(lg[:, 0] - full[:, P - 1]).max())]
        step = jax.jit(model.decode_step)
        for t in range(P, S):
            lg, state = step(params, state, tokens[:, t:t + 1],
                             jnp.int32(t))
            errs.append(float(jnp.abs(lg[:, 0] - full[:, t]).max()))
    elif cfg.stub_frontend:
        # VLM: prefill consumes stub patch embeddings; decode embeds real
        # tokens, so compare prefill logits only (decode-vs-forward would
        # compare different inputs by construction).
        emb = jax.random.normal(KEY, (B, S, cfg.d_model), jnp.float32)
        p3 = jnp.tile(jnp.arange(S)[None, :, None], (B, 1, 3)).astype(
            jnp.int32)
        full = model.logits_all(params, {"embeds": emb, "positions3": p3})
        cache = model.init_cache(B, S)
        lg, state = jax.jit(model.prefill)(
            params, {"embeds": emb[:, :P], "positions3": p3[:, :P],
                     "cache": cache})
        errs = [float(jnp.abs(lg[:, 0] - full[:, P - 1]).max())]
    else:
        full = model.logits_all(params, {"tokens": tokens})
        cache = model.init_cache(B, S)
        lg, state = jax.jit(model.prefill)(
            params, {"tokens": tokens[:, :P], "cache": cache})
        errs = [float(jnp.abs(lg[:, 0] - full[:, P - 1]).max())]
        step = jax.jit(model.decode_step)
        for t in range(P, S):
            lg, state = step(params, state, tokens[:, t:t + 1],
                             jnp.int32(t))
            errs.append(float(jnp.abs(lg[:, 0] - full[:, t]).max()))
    assert max(errs) < 2e-4, f"{arch}: {errs}"


def test_ring_buffer_cache_matches_full_window():
    """Sliding-window arch (mixtral SWA): a ring cache of size=window must
    decode identically to an unbounded cache."""
    cfg = _nodrop(get_config("mixtral-8x7b").reduced())  # window 16
    model = build_model(cfg)
    params = model.init(KEY)
    S2 = 40   # decode well past the window
    P = 24    # prefill LONGER than the window: cyclic placement path
    tokens = jax.random.randint(KEY, (B, S2), 0, cfg.vocab_size)
    full = model.logits_all(params, {"tokens": tokens})
    cache = model.init_cache(B, cfg.window)      # ring: smax == window
    lg, state = jax.jit(model.prefill)(
        params, {"tokens": tokens[:, :P], "cache": cache})
    np.testing.assert_allclose(np.asarray(lg[:, 0]),
                               np.asarray(full[:, P - 1]), atol=2e-4)
    step = jax.jit(model.decode_step)
    for t in range(P, S2):
        lg, state = step(params, state, tokens[:, t:t + 1], jnp.int32(t))
        np.testing.assert_allclose(np.asarray(lg[:, 0]),
                                   np.asarray(full[:, t]), atol=2e-4,
                                   err_msg=f"t={t}")


def test_routes_are_equivalent_for_training():
    """The Oobleck contract on the real model: SW vs interpret(HW-body)
    routes produce allclose losses (Viscosity equivalence)."""
    cfg = get_config("gemma2-2b").reduced()
    tokens = jax.random.randint(KEY, (B, 32), 0, cfg.vocab_size)
    batch = {"tokens": tokens, "targets": tokens}
    losses = {}
    for route in ("sw", "interpret"):
        model = build_model(cfg, routes={"flash_attention": route,
                                         "swiglu_mlp": route})
        params = model.init(KEY)
        loss, _ = model.forward(params, batch)
        losses[route] = float(loss)
    assert losses["sw"] == pytest.approx(losses["interpret"], abs=2e-3)
