"""Chaos campaign layer (repro.chaos): schedule generator, value-level
canary injection, probation classification, coordinator stall drills,
checkpoint restore-then-continue, and the campaign smokes.

The schedule/replay tests are pure plan algebra (fast); the campaign
smokes drive real engines at small sizing — they are the tier-1 slice
of what CI's chaos-smoke job soaks at full sizing.
"""
import time
import types

import jax
import numpy as np
import pytest
from _hypothesis_compat import given, settings, st

from repro import optim
from repro.chaos import (DEVICE_LOSS, LANE_FAULT, PERSISTENT_STAGE,
                         SPARE_EXHAUSTION, TRANSIENT_STAGE, ChaosEvent,
                         draw_schedule)
from repro.chaos.campaign import (ChaosCanary, StallingKVClient,
                                  closure_scenario, coordinator_campaign,
                                  serve_campaign, train_campaign)
from repro.chaos.schedule import COORD_STALL, SERVE_KINDS, TRAIN_KINDS, \
    horizon_of
from repro.configs import get_config
from repro.core.fault import (INTERMITTENT_PROMOTED, PERSISTENT,
                              TRANSIENT_RECOVERED, FaultClassifier,
                              FaultState, IntermittentPolicy,
                              ProbationPolicy)
from repro.obs import metrics as obs_metrics
from repro.obs import report as obs_report
from repro.core.routing import FleetPlan
from repro.data import DataConfig, SyntheticLM
from repro.launch.distributed import (FleetEvent, HostTimeoutError,
                                      HostTopology, KVCoordinator,
                                      fleet_fingerprint, merge_event_logs,
                                      replay_log)
from repro.models import build_model
from repro.train import TrainConfig
from repro.train.runner import FleetTrainConfig, FleetTrainRunner
from repro.viscosity import INTERPRET, lanefault
from repro.viscosity.lanefault import STUCK, LaneFault
from repro.viscosity.lang import SW

ARCH = "qwen1.5-4b"
STAGES = ["flash_attention", "swiglu_mlp"]


@pytest.fixture(scope="module")
def setup():
    cfg = get_config(ARCH).reduced()
    params = build_model(cfg).init(jax.random.PRNGKey(0))
    return cfg, params


# ------------------------------------------------------------- schedule
def test_draw_schedule_deterministic():
    kw = dict(n_events=8, n_devices=4, stage_names=STAGES, n_spares=2)
    a = draw_schedule(3, **kw)
    b = draw_schedule(3, **kw)
    assert a == b
    assert a != draw_schedule(4, **kw)
    steps = [e.step for e in a]
    assert steps == sorted(steps) and len(set(steps)) == len(steps)
    assert horizon_of(a, settle=5) == a[-1].step + 5


def test_draw_schedule_transient_persistent_stages_disjoint():
    """A probation episode's probes drain the armed-fault queue in
    order, so a stage must never carry both a transient and a
    persistent spec (the episode would cross into the hard fault and
    earn a spurious persistent verdict)."""
    for seed in range(12):
        sched = draw_schedule(seed, n_events=7, n_devices=4,
                              stage_names=STAGES, n_spares=2)
        trans = {e.stage for e in sched if e.kind == TRANSIENT_STAGE}
        hard = {e.stage for e in sched
                if e.kind in (PERSISTENT_STAGE, LANE_FAULT)}
        assert not trans & hard, (seed, trans, hard)


def test_draw_schedule_validates():
    with pytest.raises(ValueError):
        draw_schedule(0, n_events=-1, n_devices=2, stage_names=STAGES)
    with pytest.raises(ValueError):
        draw_schedule(0, n_events=1, n_devices=2, stage_names=[])
    with pytest.raises(ValueError):
        ChaosEvent(step=0, kind="meteor_strike")


def _wire_events(sched):
    """The engine-level wire events a campaign applies for ``sched`` —
    a transient is a net-zero (stage, recover) pair."""
    wires = []
    for ev in sched:
        if ev.kind == TRANSIENT_STAGE:
            wires += [("stage", ev.device, ev.stage),
                      ("recover", ev.device, ev.stage)]
        elif ev.kind in (PERSISTENT_STAGE, LANE_FAULT):
            wires.append(("stage", ev.device, ev.stage))
        elif ev.kind == DEVICE_LOSS:
            wires.append(("device", ev.device))
        elif ev.kind == SPARE_EXHAUSTION:
            wires += [("device", d) for d in ev.devices]
    return wires


@settings(max_examples=15, deadline=None)
@given(seed=st.integers(0, 500), cut=st.integers(0, 14))
def test_property_schedule_events_applicable_any_interleaving(seed, cut):
    """Every drawn schedule replays onto the healthy plan with zero
    dropped transitions, and any split of the wire events across two
    host logs merges to the same final FleetPlan (the multi-host
    agreement property, over *randomized* chaos schedules)."""
    sched = draw_schedule(seed, n_events=6, n_devices=5,
                          stage_names=STAGES, n_spares=2, min_serving=1)
    evs = [FleetEvent.from_engine(i, 0, i, w)
           for i, w in enumerate(_wire_events(sched))]
    base = FleetPlan.healthy(5, STAGES, target=INTERPRET, n_spares=2)
    ref, ref_dropped = replay_log(base, evs, STAGES, target=INTERPRET)
    assert not ref_dropped
    cut = min(cut, len(evs))
    merged = merge_event_logs(evs[:cut], evs[cut:])
    plan, dropped = replay_log(base, merged, STAGES, target=INTERPRET)
    assert fleet_fingerprint(plan) == fleet_fingerprint(ref)
    assert dropped == ref_dropped
    assert len(plan.serving()) >= 1


# ----------------------------------------------- ChaosCanary injection
class _SpyChecker:
    """Reports a stage clean exactly when no injection is armed during
    the probe — what the real canary does, minus the kernels."""

    def __init__(self, names):
        self.stages = [types.SimpleNamespace(name=n) for n in names]
        self.seen = []

    def check_stage(self, stage):
        f = lanefault.injection(stage.name)
        self.seen.append((stage.name, f is not None))
        return f is None


def _fault(width=8):
    return LaneFault(kind=STUCK, lanes=(1,), width=width, value=3.0)


def test_chaos_canary_arms_only_around_probe():
    lanefault.reset()
    spy = _SpyChecker(["s0"])
    canary = ChaosCanary(spy)
    canary.arm("s0", _fault(), fails=1)
    stage = spy.stages[0]
    assert canary.check_stage(stage) is False      # armed during probe
    assert lanefault.injection("s0") is None       # never armed outside
    assert canary.check_stage(stage) is True       # transient: consumed
    assert canary.armed() == []
    canary.arm("s0", _fault(), fails=None)         # hard fault
    assert not canary.check_stage(stage)
    assert not canary.check_stage(stage)           # still failing
    canary.disarm("s0")
    assert canary.check_stage(stage) is True
    assert lanefault.injection("s0") is None


# ------------------------------------------------------------ probation
def test_probation_transient_and_persistent_verdicts():
    waits = []
    clf = FaultClassifier(None, ProbationPolicy(retries=3,
                                                backoff_base_s=0.0),
                          sleep=waits.append)
    state = FaultState()
    flaky = iter([False, True])
    res = clf.probate(lambda: next(flaky), stage="x", replica=1, step=5,
                      state=state)
    assert res.transient and res.attempts == 2
    assert res.verdict == TRANSIENT_RECOVERED
    assert [e["kind"] for e in state.log] == \
        ["probation_retry", "probation_retry", TRANSIENT_RECOVERED]

    res = clf.probate(lambda: False, stage="x", state=state)
    assert not res.transient and res.attempts == 3
    assert res.verdict == PERSISTENT
    assert [e["kind"] for e in state.log].count(PERSISTENT) == 1
    assert waits == []                             # zero-base never sleeps


def test_intermittent_flapping_promoted_to_persistent():
    """A (stage, replica) that keeps earning transient verdicts inside
    the frequency window gets its next clean probe overridden to
    persistent (wear-out signature), with the promotion in the fault
    log; a different replica on the same stage is unaffected."""
    clf = FaultClassifier(None, ProbationPolicy(retries=3,
                                                backoff_base_s=0.0),
                          sleep=lambda _s: None,
                          intermittent=IntermittentPolicy(threshold=2,
                                                          window_steps=5))
    state = FaultState()
    res = clf.probate(lambda: True, stage="x", replica=1, step=0,
                      state=state)
    assert res.transient and res.verdict == TRANSIENT_RECOVERED
    res = clf.probate(lambda: True, stage="x", replica=1, step=3,
                      state=state)
    assert not res.transient and res.verdict == INTERMITTENT_PROMOTED
    assert INTERMITTENT_PROMOTED in [e["kind"] for e in state.log]
    # other replicas keep their own window
    res = clf.probate(lambda: True, stage="x", replica=2, step=3,
                      state=state)
    assert res.transient


def test_intermittent_window_expires():
    """Transient verdicts outside the trailing window do not count
    toward promotion — sparse upsets stay transient."""
    clf = FaultClassifier(None, ProbationPolicy(retries=3,
                                                backoff_base_s=0.0),
                          sleep=lambda _s: None,
                          intermittent=IntermittentPolicy(threshold=2,
                                                          window_steps=3))
    for step in (0, 10, 20):
        res = clf.probate(lambda: True, stage="x", replica=0, step=step)
        assert res.transient, step


def test_intermittent_promotion_under_chaos_schedule():
    """Chaos-schedule shape: repeated transient upsets on one stage
    within the window promote on the threshold'th episode, and the
    verdict counters land in telemetry."""
    pol = IntermittentPolicy(threshold=3, window_steps=10)
    clf = FaultClassifier(None, ProbationPolicy(retries=2,
                                                backoff_base_s=0.0),
                          sleep=lambda _s: None, intermittent=pol)
    sched = [ChaosEvent(step=s, kind=TRANSIENT_STAGE, device=0,
                        stage="flash_attention") for s in (2, 5, 8)]
    reg = obs_metrics.Registry()
    verdicts = []
    with obs_metrics.use(reg):
        for ev in sched:
            res = clf.probate(lambda: True, stage=ev.stage,
                              replica=ev.device, step=ev.step)
            verdicts.append(res.verdict)
    assert verdicts == [TRANSIENT_RECOVERED, TRANSIENT_RECOVERED,
                        INTERMITTENT_PROMOTED]
    snap = reg.snapshot()
    assert obs_report.counter_value(
        snap, "probation_verdicts_total",
        verdict=INTERMITTENT_PROMOTED) == 1
    assert obs_report.counter_value(
        snap, "probation_transients_total",
        stage="flash_attention") == 3


def test_probation_backoff_schedule_capped():
    pol = ProbationPolicy(retries=4, backoff_base_s=0.25,
                          backoff_factor=2.0, max_backoff_s=0.6)
    assert pol.backoff_schedule() == (0.25, 0.5, 0.6, 0.6)
    waits = []
    clf = FaultClassifier(None, pol, sleep=waits.append)
    clf.probate(lambda: False, stage="x")
    assert waits == [0.25, 0.5, 0.6, 0.6]


# ---------------------------------------------------------- coordinator
def test_coordinator_stalled_peer_typed_timeout_bounded():
    client = StallingKVClient(stalled=[1])
    coord = KVCoordinator(num_hosts=2, host_id=0, client=client,
                          timeout_ms=5_000, attempt_timeout_ms=10,
                          max_attempts=3, backoff_base_s=0.001)
    t0 = time.perf_counter()
    with pytest.raises(HostTimeoutError) as ei:
        coord.exchange("payload")
    wall = time.perf_counter() - t0
    assert ei.value.host_id == 1
    assert client.gets <= 3                        # bounded retry budget
    assert wall < 5.0                              # nowhere near 120 s

    coord.mark_dead(1)
    client.gets = 0
    assert coord.exchange("again") == ["again", None]
    assert client.gets == 0                        # dead peer not polled


# -------------------------------------------------- train runner drills
def _train_runner(cfg, tcfg, *, n_devices=4, n_spares=1, topo=None):
    data = SyntheticLM(DataConfig(vocab_size=cfg.vocab_size, batch=8,
                                  seq_len=16))
    return FleetTrainRunner(
        cfg, optim.AdamWConfig(lr=1e-3, warmup_steps=2, total_steps=50),
        tcfg, data, FleetTrainConfig(n_devices=n_devices,
                                     n_spares=n_spares, topology=topo))


def test_fleet_train_transient_probation_keeps_capacity(setup):
    cfg, _ = setup
    r = _train_runner(cfg, TrainConfig(steps=3, hw_route=SW,
                                       probation_retries=2))
    params, opt = r.init_state()
    r.run(params, opt, steps=3, transient={1: 0})
    kinds = [e["kind"] for e in r.fault_state.log]
    assert r.guard_trips == 1
    assert not r.fleet.quarantined                 # capacity kept
    assert TRANSIENT_RECOVERED in kinds
    assert all(np.isfinite(h["loss"]) for h in r.history)


def test_fleet_train_ckpt_cadence_and_host_restore(setup, tmp_path):
    cfg, _ = setup
    topo = HostTopology(num_hosts=2, devices_per_host=2)
    r = _train_runner(cfg, TrainConfig(steps=6, hw_route=SW,
                                       ckpt_every=2,
                                       ckpt_dir=str(tmp_path)),
                      topo=topo)
    params, opt = r.init_state()
    r.run(params, opt, steps=6, host_loss={3: 1})
    kinds = [e["kind"] for e in r.fault_state.log]
    assert "checkpoint_restored" in kinds          # restore-then-continue
    assert {2, 3} <= set(r.fleet.quarantined)      # host 1's block gone
    assert r.ckpt.steps() and r.ckpt.steps()[0] == 2   # cadence saves
    assert all(np.isfinite(h["loss"]) for h in r.history)
    # the restore rewinds: some step index re-runs after the host loss
    steps = [h["step"] for h in r.history]
    assert len(steps) > len(set(steps))


# ------------------------------------------------------ campaign smokes
def test_serve_campaign_smoke_invariants_green(setup):
    """Invariants green at small sizing, and the run's telemetry
    snapshot reproduces the campaign's own MTTR/goodput summaries
    exactly (the obs.metrics exact-stats contract).  Seed 2's schedule
    draws a coord_stall, so the drill's bounded KV retries must show as
    a counter spike in the same snapshot."""
    cfg, params = setup
    reg = obs_metrics.Registry()
    with obs_metrics.use(reg), \
            obs_metrics.label_scope(section="serve_resident"):
        r = serve_campaign(2, n_events=2, n_requests=10, params=params,
                           cfg=cfg)
    assert r["invariants"]["ok"], r["invariants"]["reports"]
    assert r["traffic"]["completed"] == r["traffic"]["requests"]
    assert r["mttr_summary"]["n"] == r["n_events"]
    assert lanefault.injection("flash_attention") is None  # cleaned up

    snap = reg.snapshot()
    assert obs_report.mttr_summary(snap, section="serve_resident") \
        == r["mttr_summary"]
    g = obs_report.goodput_summary(snap, section="serve_resident")
    assert g["completed"] == r["traffic"]["completed"]
    assert g["expired"] == r["traffic"]["expired"]
    assert round(g["throughput_tok_s"], 2) == \
        r["traffic"]["throughput_tok_s"]
    assert round(g["virtual_time_s"], 2) == r["traffic"]["virtual_time_s"]
    # the scheduled coord_stall surfaced as bounded KV retries
    assert any(e["kind"] == COORD_STALL for e in r["schedule"])
    assert obs_report.counter_value(snap, "kv_retries_total", op="get") > 0
    assert obs_report.counter_value(snap, "coord_timeouts_total",
                                    host="1") > 0


def test_train_campaign_smoke_invariants_green(tmp_path):
    r = train_campaign(0, n_events=2, ckpt_dir=str(tmp_path))
    assert r["invariants"]["ok"], r["invariants"]["reports"]
    assert r["n_events"] == 2 and r["steps"] > 0


def test_coordinator_campaign_fast_typed_mttr():
    r = coordinator_campaign(1)
    assert r["invariants"]["ok"], r["invariants"]["reports"]
    assert r["mttr_summary"]["max_s"] < 5.0


def test_closure_scenario_tracks_degradation_model(setup):
    cfg, params = setup
    rep = closure_scenario(0, n_requests=24, params=params, cfg=cfg)
    assert rep["ok"], rep
    assert rep["rel_err"] <= 0.15 and not rep["dropped"]
