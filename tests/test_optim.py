"""Optimizer + compression unit tests."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest
from _hypothesis_compat import given, settings, st

from repro import optim
from repro.optim.compression import compress_tree, init_error, quantize_leaf


def test_adamw_reduces_quadratic():
    cfg = optim.AdamWConfig(lr=0.1, warmup_steps=1, total_steps=200,
                            weight_decay=0.0, clip_norm=0.0)
    params = {"w": jnp.asarray([3.0, -2.0, 1.5])}
    state = optim.init(params)
    for _ in range(150):
        grads = {"w": 2 * params["w"]}
        params, state, m = optim.update(cfg, grads, state, params)
    assert float(jnp.abs(params["w"]).max()) < 0.05


def test_schedule_shape():
    cfg = optim.AdamWConfig(lr=1.0, warmup_steps=10, total_steps=100,
                            min_lr_frac=0.1)
    lrs = [float(optim.schedule(cfg, jnp.int32(s))) for s in range(100)]
    assert lrs[0] < lrs[9]                      # warmup
    assert max(lrs) == pytest.approx(1.0, abs=0.02)
    assert lrs[-1] == pytest.approx(0.1, abs=0.05)  # cosine floor


def test_clip_by_global_norm():
    g = {"a": jnp.full((10,), 10.0)}
    clipped, norm = optim.clip_by_global_norm(g, 1.0)
    assert float(norm) == pytest.approx(np.sqrt(1000), rel=1e-5)
    assert float(optim.global_norm(clipped)) == pytest.approx(1.0, rel=1e-4)


def test_quantize_error_feedback_unbiased_over_time():
    """EF property: accumulated dequantized sum tracks the true sum."""
    rng = np.random.default_rng(0)
    err = jnp.zeros((256,))
    true_sum = np.zeros((256,))
    deq_sum = np.zeros((256,))
    for i in range(50):
        g = jnp.asarray(rng.normal(size=(256,)) * (1 + i % 3), jnp.float32)
        q, s, err = quantize_leaf(g, err)
        deq_sum += np.asarray(q, np.float32) * float(s)
        true_sum += np.asarray(g)
    # residual bounded by one quantization step, not growing
    resid = np.abs(true_sum - deq_sum).max()
    assert resid <= float(np.abs(np.asarray(err)).max()) + 1e-4


@settings(max_examples=20, deadline=None)
@given(n=st.integers(1, 300), scale=st.floats(1e-3, 1e3))
def test_property_quantization_error_bounded(n, scale):
    rng = np.random.default_rng(n)
    g = jnp.asarray(rng.normal(size=(n,)) * scale, jnp.float32)
    q, s, resid = quantize_leaf(g, None)
    assert float(jnp.abs(resid).max()) <= float(s) * 0.5 + 1e-6
    assert q.dtype == jnp.int8


def test_compress_tree_roundtrip_structure(rng):
    g = {"a": jnp.asarray(rng.normal(size=(8, 4)), jnp.float32),
         "b": jnp.asarray(rng.normal(size=(3,)), jnp.float32)}
    err = init_error(g)
    deq, err2 = compress_tree(g, err)
    assert jax.tree_util.tree_structure(deq) == \
        jax.tree_util.tree_structure(g)
    for a, b in zip(jax.tree_util.tree_leaves(deq),
                    jax.tree_util.tree_leaves(g)):
        np.testing.assert_allclose(np.asarray(a), np.asarray(b), atol=0.05)
