"""Optional-hypothesis shim (see requirements-dev.txt).

Property tests use hypothesis when it is installed (CI installs it via
requirements-dev.txt, so the real shrinking engine runs there).  Without
it, a deterministic mini engine stands in: each strategy draws from a
seeded PRNG and ``@given`` runs ``max_examples`` sampled cases — the
property tests *run* everywhere instead of skipping, they just lose
shrinking and the adversarial corner-case heuristics.  Import from test
modules as::

    from _hypothesis_compat import given, settings, st

(tests/conftest.py puts this directory on sys.path for the whole tree).
"""
import functools
import inspect
import random

# re-exported for every property-test module (declared in __all__)
__all__ = ["HAVE_HYPOTHESIS", "given", "settings", "st"]

try:
    from hypothesis import given, settings, strategies as st

    HAVE_HYPOTHESIS = True
except ImportError:
    HAVE_HYPOTHESIS = False

    _DEFAULT_MAX_EXAMPLES = 20

    class _Strategy:
        """One sampleable strategy: ``draw(rng)`` produces a value."""

        def __init__(self, draw):
            self._draw = draw

        def draw(self, rng):
            return self._draw(rng)

    class _St:
        """The subset of ``hypothesis.strategies`` the repo's property
        tests use.  Bounds are inclusive, matching hypothesis."""

        @staticmethod
        def booleans():
            return _Strategy(lambda rng: rng.random() < 0.5)

        @staticmethod
        def integers(min_value, max_value):
            return _Strategy(lambda rng: rng.randint(min_value, max_value))

        @staticmethod
        def floats(min_value, max_value):
            return _Strategy(
                lambda rng: rng.uniform(min_value, max_value))

        @staticmethod
        def sampled_from(elements):
            elements = list(elements)
            return _Strategy(lambda rng: elements[rng.randrange(
                len(elements))])

        @staticmethod
        def lists(elements, min_size=0, max_size=10):
            return _Strategy(lambda rng: [
                elements.draw(rng)
                for _ in range(rng.randint(min_size, max_size))])

        @staticmethod
        def tuples(*strategies):
            return _Strategy(lambda rng: tuple(s.draw(rng)
                                               for s in strategies))

    st = _St()

    def settings(max_examples=_DEFAULT_MAX_EXAMPLES, **_ignored):
        """Outer decorator: records max_examples on the given-wrapper."""

        def deco(f):
            f._hc_max_examples = max_examples
            return f

        return deco

    def given(**strategies):
        """Keyword-strategy ``@given``: runs the test on deterministic
        samples (seeded per test name, so failures reproduce)."""

        def deco(f):

            @functools.wraps(f)
            def wrapper(*args, **kw):
                n = getattr(wrapper, "_hc_max_examples",
                            _DEFAULT_MAX_EXAMPLES)
                rng = random.Random(f.__qualname__)
                for i in range(n):
                    drawn = {name: s.draw(rng)
                             for name, s in strategies.items()}
                    try:
                        f(*args, **kw, **drawn)
                    except Exception as e:
                        raise AssertionError(
                            f"property falsified on example {i + 1}/{n}: "
                            f"{drawn!r}") from e

            # pytest must not see the strategy parameters as fixtures:
            # expose only the non-strategy params (real fixtures) in the
            # wrapper's signature, exactly like hypothesis does.
            sig = inspect.signature(f)
            fixture_params = [p for name, p in sig.parameters.items()
                              if name not in strategies]
            del wrapper.__wrapped__
            wrapper.__signature__ = sig.replace(parameters=fixture_params)
            return wrapper

        return deco
