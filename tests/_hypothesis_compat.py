"""Optional-hypothesis shim (see requirements-dev.txt).

Property tests use hypothesis when it is installed (CI installs it);
without it, only the ``@given`` tests skip — every plain test in the
same module still runs.  Import from test modules as::

    from _hypothesis_compat import given, settings, st

(tests/conftest.py puts this directory on sys.path for the whole tree).
"""
import pytest

try:
    from hypothesis import given, settings, strategies as st
except ImportError:
    class _MissingStrategy:
        """Chainable stand-in: any attribute access or call returns
        itself, so module-level strategy expressions still evaluate."""

        def __getattr__(self, name):
            return self

        def __call__(self, *a, **k):
            return self

    st = _MissingStrategy()

    def settings(*a, **k):
        return lambda f: f

    def given(*a, **k):
        return lambda f: pytest.mark.skip(
            reason="hypothesis not installed")(f)
