"""Fleet models (paper §II, Fig. 2; §V-G): claims + MC/analytic agreement."""
import numpy as np
import pytest
from _hypothesis_compat import given, settings, st

from repro.core.datacenter import (chips_to_buy, expected_replacements,
                                   expected_throughput, fig2_sweep,
                                   simulate_fleet)

N, T = 10_000, 1460   # the paper's fleet and horizon


def test_fig2a_vfa_strictly_fewer_replacements():
    rows = fig2_sweep([1e-2, 1e-3, 1e-4, 1e-5, 1e-6])
    for p, sfa_r, vfa_r, _, _ in rows:
        assert vfa_r < sfa_r


def test_fig2a_threshold_claim():
    """Below 0.01%/tick: VFA replaces <1 chip on average where SFA >50."""
    p = 1e-5
    assert expected_replacements(N, T, p, 1) > 50
    assert expected_replacements(N, T, p, 3) < 1


def test_fig2b_throughput_approaches_max():
    tps = [expected_throughput(T, p, max_faults=3,
                               degradation=(1.0, 0.38, 0.19))
           for p in (1e-3, 1e-4, 1e-5, 1e-6)]
    assert all(a < b for a, b in zip(tps, tps[1:]))   # improves as p -> 0
    assert tps[-1] > 0.999
    # and the loss is "extremely small" below the 0.01% threshold
    assert tps[2] > 0.99


def test_monte_carlo_agrees_with_analytic():
    p = 3e-4
    mc = simulate_fleet(N, T, p, mode="sfa", seed=1)
    an = expected_replacements(N, T, p, 1)
    assert mc.replacements == pytest.approx(an, rel=0.1)
    mc3 = simulate_fleet(N, T, p, mode="vfa", max_faults=3, seed=1)
    an3 = expected_replacements(N, T, p, 3)
    assert mc3.replacements == pytest.approx(an3, rel=0.35, abs=3)


def test_fixed_throughput_linear_in_retention():
    """§II: chips bought decrease linearly with per-fault retention; 50%
    retention -> 50% fewer purchases, 1/3 loss -> 1/3 of purchases."""
    assert chips_to_buy(100, 0.5) == pytest.approx(50)
    assert chips_to_buy(100, 2 / 3) == pytest.approx(100 / 3)
    r = np.linspace(0, 1, 11)
    buys = [chips_to_buy(100, x) for x in r]
    diffs = np.diff(buys)
    assert np.allclose(diffs, diffs[0])


@settings(max_examples=10, deadline=None)
@given(p=st.floats(1e-6, 1e-3), mf=st.integers(2, 5))
def test_property_vfa_dominates_sfa(p, mf):
    assert expected_replacements(1000, 500, p, mf) <= \
        expected_replacements(1000, 500, p, 1) + 1e-9


def test_degradation_from_case_study():
    """The fleet degradation curve wires to the latency model's
    throughput_factor (FFT case study)."""
    from repro.core.latency import fft_model, throughput_factor
    m = fft_model()
    deg = tuple(throughput_factor(m, k) for k in range(3))
    assert deg[0] == 1.0 and deg[1] == pytest.approx(0.38, abs=0.02)
    r = simulate_fleet(2000, 200, 5e-4, mode="vfa", max_faults=3,
                       degradation=deg, seed=0)
    assert 0.9 < r.throughput <= 1.0
