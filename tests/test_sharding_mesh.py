"""Small-mesh (8 host devices, subprocess) sharded compile + collectives.

The full 512-device dry-run runs via ``python -m repro.launch.dryrun``;
here we prove the same code path on a (2, 4) mesh inside pytest without
polluting the single-device test process.
"""
import json
import os
import subprocess
import sys
import textwrap

import pytest

SRC = os.path.join(os.path.dirname(__file__), "..", "src")

SCRIPT = textwrap.dedent("""
    import os
    os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
    os.environ.pop("JAX_PLATFORMS", None)
    import json
    import jax, jax.numpy as jnp
    import numpy as np
    from repro.configs import get_config
    from repro.launch.mesh import make_mesh
    from repro.launch import dryrun
    from repro.launch.hlo_analysis import analyze

    mesh = make_mesh((2, 4), ("data", "model"))
    out = {}
    for arch, shape in [("gemma3-1b", "train_4k"),
                        ("rwkv6-1.6b", "decode_32k")]:
        lowered, meta = dryrun.build_lowered(
            arch, shape, mesh,
            overrides={"num_layers": 2, "d_ff": 512, "vocab_size": 4096,
                       "loss_chunk": 128})
        compiled = lowered.compile()
        st = analyze(compiled.as_text(), world=8)
        out[arch] = {"flops": st.flops,
                     "coll": {k: v for k, v in st.coll_bytes.items()},
                     "temp": compiled.memory_analysis().temp_size_in_bytes}
    print(json.dumps(out))
""")


@pytest.mark.slow
def test_small_mesh_compile_and_collectives():
    env = dict(os.environ, PYTHONPATH=SRC)
    res = subprocess.run([sys.executable, "-c", SCRIPT], env=env,
                         capture_output=True, text=True, timeout=900)
    assert res.returncode == 0, res.stderr[-3000:]
    out = json.loads(res.stdout.strip().splitlines()[-1])
    for arch, rec in out.items():
        assert rec["flops"] > 0
        assert rec["temp"] > 0
    # the TP'd train step must communicate (all-reduce over model axis)
    assert sum(out["gemma3-1b"]["coll"].values()) > 0
