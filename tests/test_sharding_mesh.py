"""Small-mesh (8 host devices, subprocess) sharded compile + collectives.

The full 512-device dry-run runs via ``python -m repro.launch.dryrun``;
here we prove the same code path on a (2, 4) mesh inside pytest without
polluting the single-device test process.
"""
import json
import os
import subprocess
import sys
import textwrap

import pytest

SRC = os.path.join(os.path.dirname(__file__), "..", "src")

SCRIPT = textwrap.dedent("""
    import os
    os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
    # Pin the CPU backend: popping JAX_PLATFORMS makes jax probe the TPU
    # backend first, which burns minutes on metadata retries off-TPU (the
    # probe fails and falls back to CPU anyway).
    os.environ["JAX_PLATFORMS"] = "cpu"
    import json
    import jax, jax.numpy as jnp
    import numpy as np
    from repro.configs import get_config
    from repro.launch.mesh import make_mesh
    from repro.launch import dryrun
    from repro.launch.hlo_analysis import analyze

    mesh = make_mesh((2, 4), ("data", "model"))
    out = {}
    for arch, shape in [("gemma3-1b", "train_4k"),
                        ("rwkv6-1.6b", "decode_32k")]:
        lowered, meta = dryrun.build_lowered(
            arch, shape, mesh,
            overrides={"num_layers": 2, "d_ff": 512, "vocab_size": 4096,
                       "loss_chunk": 128})
        compiled = lowered.compile()
        st = analyze(compiled.as_text(), world=8)
        out[arch] = {"flops": st.flops,
                     "coll": {k: v for k, v in st.coll_bytes.items()},
                     "temp": compiled.memory_analysis().temp_size_in_bytes}
    print(json.dumps(out))
""")


@pytest.mark.slow
def test_small_mesh_compile_and_collectives():
    env = dict(os.environ, PYTHONPATH=SRC)
    res = subprocess.run([sys.executable, "-c", SCRIPT], env=env,
                         capture_output=True, text=True, timeout=900)
    assert res.returncode == 0, res.stderr[-3000:]
    out = json.loads(res.stdout.strip().splitlines()[-1])
    for arch, rec in out.items():
        assert rec["flops"] > 0
        assert rec["temp"] > 0
    # the TP'd train step must communicate (all-reduce over model axis)
    assert sum(out["gemma3-1b"]["coll"].values()) > 0


def test_mesh_shortfall_error_names_the_gap():
    """Regression: the device-count error must name the actual shortfall,
    not just the totals (this process has exactly one CPU device)."""
    from repro.launch.mesh import make_mesh

    with pytest.raises(RuntimeError, match=r"short 7 device\(s\)"):
        make_mesh((2, 4), ("data", "model"))


def test_shard_bounds_covers_batch_and_skips_masked():
    from repro.launch.sharding import shard_bounds

    bounds = shard_bounds(10, [True, False, True, True])
    assert sorted(bounds) == [0, 2, 3]                 # device 1 masked out
    sizes = {d: hi - lo for d, (lo, hi) in bounds.items()}
    assert sum(sizes.values()) == 10
    assert max(sizes.values()) - min(sizes.values()) <= 1
    spans = sorted(bounds.values())
    assert spans[0][0] == 0 and spans[-1][1] == 10     # contiguous cover
    for (a, b), (c, d) in zip(spans, spans[1:]):
        assert b == c
    with pytest.raises(ValueError):
        shard_bounds(4, [False, False])


def test_shard_bounds_owned_slice_is_host_aware():
    """``owned`` filters to one host's block without changing the global
    split: every host computes the same partition, takes its own slice,
    and the union over hosts is exactly the unfiltered bounds."""
    from repro.launch.sharding import shard_bounds

    mask = [True, False, True, True, True, False]      # 4 serving of 6
    full = shard_bounds(10, mask)
    host0 = shard_bounds(10, mask, owned=(0, 1, 2))    # host blocks of 3
    host1 = shard_bounds(10, mask, owned=(3, 4, 5))
    assert set(host0) == {0, 2} and set(host1) == {3, 4}
    assert {**host0, **host1} == full
    # a host whose devices are all masked out simply gets no slice
    assert shard_bounds(10, mask, owned=(1, 5)) == {}


def test_fleet_mesh_view_masks_and_errors():
    """FleetMeshView carries quarantined/spare devices explicitly and the
    submesh error names how many serving devices are missing."""
    from repro.core.routing import FleetPlan
    from repro.launch.mesh import FleetMeshView

    fp = FleetPlan.healthy(4, ["flash_attention"], n_spares=1)
    view = FleetMeshView.from_plan(fp.with_device_fault(1))
    assert view.mask == (True, False, True, True)      # spare 3 activated
    assert view.quarantined == (1,)
    assert view.idle_spares == ()
    # this process has 1 device; a 3-serving-device view cannot be built
    with pytest.raises(RuntimeError, match="short"):
        view.serving_devices()


FLEET_SCRIPT = textwrap.dedent("""
    import os
    os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
    # Pin the CPU backend: popping JAX_PLATFORMS makes jax probe the TPU
    # backend first, which burns minutes on metadata retries off-TPU.
    os.environ["JAX_PLATFORMS"] = "cpu"
    import json
    import jax, jax.numpy as jnp
    import numpy as np
    from repro.core.routing import FleetPlan
    from repro.launch.mesh import FleetMeshView
    from repro.launch.sharding import shard_bounds

    # 8 host devices: 6 workers + 2 spares; one device fault migrates to a
    # spare, a second (pool now holding one) also migrates.
    fp = FleetPlan.healthy(8, ["flash_attention"], n_spares=2)
    fp = fp.with_device_fault(1).with_device_fault(4)
    view = FleetMeshView.from_plan(fp)
    mesh = view.submesh(("data", "model"), model=2)
    bounds = shard_bounds(12, view.mask)

    # a sharded psum across the health-masked mesh really runs
    from jax.sharding import NamedSharding, PartitionSpec as P
    x = jax.device_put(jnp.arange(12.0), NamedSharding(mesh, P("data")))
    total = jax.jit(lambda v: jnp.sum(v))(x)
    print(json.dumps({
        "mask": list(view.mask), "quarantined": list(view.quarantined),
        "idle": list(view.idle_spares),
        "mesh_shape": list(mesh.devices.shape),
        "mesh_devices": sorted(int(d.id) for d in mesh.devices.flat),
        "bounds": {str(k): v for k, v in bounds.items()},
        "total": float(total)}))
""")


@pytest.mark.slow
def test_health_masked_mesh_view_8_devices():
    """The fleet mesh view on the 8-device CPU dry-run: quarantined
    devices fall out of the mesh, activated spares join it, and sharded
    computation runs on exactly the serving devices."""
    env = dict(os.environ, PYTHONPATH=SRC)
    res = subprocess.run([sys.executable, "-c", FLEET_SCRIPT], env=env,
                         capture_output=True, text=True, timeout=300)
    assert res.returncode == 0, res.stderr[-3000:]
    out = json.loads(res.stdout.strip().splitlines()[-1])
    # workers 0,2,3,5 + both spares (6, 7) serve; 1 and 4 are out
    assert out["mask"] == [True, False, True, True, False, True, True,
                           True]
    assert out["quarantined"] == [1, 4]
    assert out["idle"] == []
    assert out["mesh_shape"] == [3, 2]
    assert out["mesh_devices"] == [0, 2, 3, 5, 6, 7]
    assert set(map(int, out["bounds"])) == {0, 2, 3, 5, 6, 7}
    assert out["total"] == sum(range(12))
