"""Viscosity layer: registry, dual lowering, contracts."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro import viscosity
from repro.viscosity.lang import OpSpec, Registry


def test_registry_contains_all_kernel_stages():
    import repro.kernels.flash_attention  # noqa: F401
    import repro.kernels.mamba2_scan  # noqa: F401
    import repro.kernels.rwkv6_scan  # noqa: F401
    import repro.kernels.checksum  # noqa: F401
    import repro.kernels.swiglu  # noqa: F401
    names = set(viscosity.REGISTRY.names())
    assert {"flash_attention", "mamba2_ssd", "rwkv6_wkv", "checksum",
            "swiglu_mlp"} <= names


def test_duplicate_registration_rejected():
    r = Registry()
    spec = OpSpec(name="x", ref=lambda a: a)
    r.register(spec)
    with pytest.raises(ValueError, match="duplicate"):
        r.register(spec)


def test_lowering_targets():
    hw_calls, sw_calls = [], []
    spec = OpSpec(name="t", ref=lambda a: sw_calls.append(1) or a * 2,
                  kernel=lambda a: hw_calls.append(1) or a * 2)
    spec(jnp.ones(3), route=viscosity.SW)
    assert sw_calls and not hw_calls
    spec(jnp.ones(3), route=viscosity.HW)
    assert hw_calls
    # interpret falls back to kernel when no dedicated interpret fn
    spec(jnp.ones(3), route=viscosity.INTERPRET)
    assert len(hw_calls) == 2


def test_sw_only_op_serves_all_routes():
    spec = OpSpec(name="swonly", ref=lambda a: a + 1)
    out = spec(jnp.zeros(2), route=viscosity.HW)
    np.testing.assert_array_equal(np.asarray(out), [1, 1])


def test_finite_valid_predicate():
    ok = viscosity.finite_valid({"a": jnp.ones(3)})
    bad = viscosity.finite_valid({"a": jnp.array([1.0, jnp.nan])})
    assert bool(ok) and not bool(bad)


def test_equivalence_contract_all_registered_ops():
    """Every registered op with a kernel satisfies its own tolerance on a
    canary (the Viscosity 'logical equivalence' guarantee)."""
    from repro.train.runner import canary_stages
    from repro.configs import get_config
    for arch in ("gemma2-2b", "zamba2-1.2b", "rwkv6-1.6b"):
        for stage in canary_stages(get_config(arch).reduced()):
            args = stage.canary_inputs(seed=1)
            hw = stage.run(*args, route="interpret")
            sw = stage.run(*args, route=viscosity.SW)
            for a, b in zip(jax.tree_util.tree_leaves(hw),
                            jax.tree_util.tree_leaves(sw)):
                np.testing.assert_allclose(
                    np.asarray(a, np.float32), np.asarray(b, np.float32),
                    atol=stage.tol, rtol=stage.tol)
