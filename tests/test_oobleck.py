"""Oobleck methodology: staged case studies, fault routing, dispatcher."""
import itertools

import jax
import jax.numpy as jnp
import numpy as np
import pytest
from _hypothesis_compat import given, settings, st

from repro.core import (CanaryChecker, Dispatcher, FaultSignature, FaultState,
                        StagedAccelerator, inject)
from repro.core.casestudies import (aes_accelerator, dct_accelerator,
                                    dct_reference, fft_accelerator,
                                    fft_reference)

KEY = np.arange(16, dtype=np.uint8)
FIPS_PT = np.array([0x00, 0x11, 0x22, 0x33, 0x44, 0x55, 0x66, 0x77, 0x88,
                    0x99, 0xaa, 0xbb, 0xcc, 0xdd, 0xee, 0xff], np.uint8)
FIPS_CT = "69c4e0d86a7b0430d8cdb78070b4c55a"


def _fft_input(rng, B=4, n=64):
    return jnp.asarray(rng.normal(size=(B, n)) +
                       1j * rng.normal(size=(B, n))).astype(jnp.complex64)


def test_fft_case_study_correct(rng):
    acc = fft_accelerator(64)
    x = _fft_input(rng)
    np.testing.assert_allclose(np.asarray(acc.run(x)),
                               np.asarray(fft_reference(x)), atol=1e-4)


@pytest.mark.parametrize("n_stages", [11, 3])
def test_aes_fips_197(n_stages):
    acc = aes_accelerator(KEY, n_stages)
    ct = np.asarray(acc.run(jnp.asarray(FIPS_PT[None])))[0]
    assert bytes(ct).hex() == FIPS_CT


def test_dct_case_study_correct(rng):
    acc = dct_accelerator()
    x = jnp.asarray(rng.normal(size=(4, 8, 8)), jnp.float32)
    np.testing.assert_allclose(np.asarray(acc.run(x)),
                               np.asarray(dct_reference(x)), atol=1e-4)


def test_routing_invariance_exhaustive_fft(rng):
    """Every single-fault and double-fault signature yields the reference
    output — the paper's core functional claim."""
    acc = fft_accelerator(64)
    x = _fft_input(rng, B=2)
    ref = np.asarray(acc.run_reference(x))
    names = acc.stage_names
    for k in (1, 2):
        for faulty in itertools.combinations(names, k):
            sig = acc.healthy_signature()
            for f in faulty:
                sig = sig.with_fault(f)
            out = np.asarray(acc.run(x, sig))
            np.testing.assert_allclose(out, ref, atol=1e-4)


@settings(max_examples=25, deadline=None)
@given(mask=st.lists(st.booleans(), min_size=10, max_size=10))
def test_property_resident_routing_dct(mask):
    """Hot-spare (resident lax.cond) routing: ANY health mask -> reference
    output, under jit."""
    rng = np.random.default_rng(sum(mask))
    acc = dct_accelerator()
    x = jnp.asarray(rng.normal(size=(2, 8, 8)), jnp.float32)
    ref = np.asarray(acc.run_reference(x))
    out = np.asarray(jax.jit(acc.run_resident)(x, jnp.asarray(mask)))
    np.testing.assert_allclose(out, ref, atol=1e-4)


def test_canary_detects_injected_fault(rng):
    acc = dct_accelerator()
    stages = list(acc.stages)
    stages[4] = inject(stages[4], kind="bitflip")
    state = FaultState()
    found = CanaryChecker(stages).sweep(state)
    assert found == ["dct_s4"]
    assert state.is_faulty("dct_s4")
    sig = state.signature(acc.stage_names)
    assert sig.faulty() == {"dct_s4"}


def test_canary_passes_healthy():
    acc = dct_accelerator()
    state = FaultState()
    assert CanaryChecker(acc.stages).sweep(state) == []


def test_dispatcher_compiles_once_per_signature():
    calls = []

    def build(sig):
        calls.append(sig)
        return lambda x: x + sig.n_faults()

    d = Dispatcher(build)
    s0 = FaultSignature.healthy(["a", "b"])
    s1 = s0.with_fault("a")
    assert d(s0, 1) == 1
    assert d(s0, 1) == 1
    assert d(s1, 1) == 2
    assert d(s1, 1) == 2
    assert d.compiles == 2 and len(calls) == 2


def test_signature_monotone_and_frozen():
    s = FaultSignature.healthy(["a", "b", "c"])
    s1 = s.with_fault("b")
    assert s.n_faults() == 0 and s1.n_faults() == 1
    assert s1.with_fault("b") == s1          # idempotent
    assert hash(s1) == hash(s.with_fault("b"))


def test_injected_stage_breaks_then_sw_fallback_fixes(rng):
    """End-to-end: injection corrupts the HW path; routing that stage to SW
    restores the reference output."""
    acc = fft_accelerator(64)
    stages = list(acc.stages)
    stages[3] = inject(stages[3], kind="gain", magnitude=0.5)
    bad = StagedAccelerator("fft-bad", stages)
    x = _fft_input(rng, B=2)
    ref = np.asarray(acc.run_reference(x))
    out_bad = np.asarray(bad.run(x))
    assert np.abs(out_bad - ref).max() > 1e-3   # fault visible
    sig = bad.healthy_signature().with_fault("fft_s3")
    out_fixed = np.asarray(bad.run(x, sig))
    np.testing.assert_allclose(out_fixed, ref, atol=1e-4)


# ------------------------------------------------------ dispatcher LRU
def _counting_dispatcher(capacity=2):
    calls = []

    def build(key):
        calls.append(key)
        return lambda: key

    return Dispatcher(build, capacity=capacity), calls


def test_dispatcher_lru_evicts_at_capacity():
    d, calls = _counting_dispatcher(capacity=2)
    d.get("a"), d.get("b")
    assert d.cached_keys() == ["a", "b"]
    d.get("c")                                  # evicts the LRU entry "a"
    assert d.cached_keys() == ["b", "c"]
    assert d.compiles == 3


def test_dispatcher_hit_moves_to_end():
    d, calls = _counting_dispatcher(capacity=2)
    d.get("a"), d.get("b")
    d.get("a")                                  # hit: "a" becomes MRU
    assert d.cached_keys() == ["b", "a"]
    d.get("c")                                  # now "b" is the LRU victim
    assert d.cached_keys() == ["a", "c"]


def test_dispatcher_compiles_monotone_and_recompiles_after_eviction():
    d, calls = _counting_dispatcher(capacity=2)
    seen = []
    for key in ["a", "b", "a", "c", "a"]:       # "a" evicted by "c"? no:
        d.get(key)                              # a,b -> hit a -> c evicts b
        seen.append(d.compiles)
    assert seen == sorted(seen)                 # counter never decreases
    assert d.compiles == 3                      # a, b, c
    d.get("b")                                  # b was evicted: rebuilt
    assert d.compiles == 4
    assert calls == ["a", "b", "c", "b"]


def test_dispatcher_keyed_by_routing_plan():
    """RoutingPlans are hashable dispatcher keys; equal plans (even built
    from different fault histories) share one executable."""
    from repro.core.routing import RoutingPlan

    d, calls = _counting_dispatcher(capacity=4)
    sig = FaultSignature.healthy(["a", "b"])
    p1 = RoutingPlan.from_signature(sig.with_fault("a"))
    p2 = RoutingPlan.from_signature(
        FaultSignature.healthy(["b", "a"]).with_fault("a"))
    d.get(p1), d.get(p2)
    assert p1 == p2 and d.compiles == 1


# -------------------------------------------------------- RoutingPlan IR
def test_routing_plan_from_signature_and_fallbacks():
    from repro.core.routing import RoutingPlan
    from repro.viscosity import HW, INTERPRET, SW

    sig = FaultSignature.healthy(["s0", "s1", "s2"]).with_fault("s1")
    plan = RoutingPlan.from_signature(sig, healthy=INTERPRET)
    assert plan.target_for("s0") == INTERPRET
    assert plan.target_for("s1") == SW
    assert plan.fallback_stages() == ("s1",)
    assert plan.with_fault("s2").target_for("s2") == SW
    assert hash(plan) == hash(RoutingPlan.from_signature(sig,
                                                         healthy=INTERPRET))
    # unlisted stage: explicit default wins, else the call site's
    assert RoutingPlan(default=HW).target_for("anything") == HW
    assert plan.get("missing", HW) == HW
    with pytest.raises(KeyError):
        plan.target_for("missing")


def test_routing_plan_validates():
    from repro.core.routing import RoutingPlan

    with pytest.raises(ValueError):
        RoutingPlan((("s0", "warp-drive"),))
    with pytest.raises(ValueError):
        RoutingPlan((("s0", "sw"),)).validate(stages=["s1"])
    from repro.viscosity import REGISTRY
    with pytest.raises(ValueError):
        RoutingPlan((("not_a_real_op", "sw"),)).validate(registry=REGISTRY)
    # registered ops validate cleanly
    RoutingPlan((("flash_attention", "sw"),)).validate(registry=REGISTRY)


def test_staged_accelerator_accepts_plan(rng):
    """StagedAccelerator.run takes the RoutingPlan IR directly."""
    from repro.core.routing import RoutingPlan

    acc = fft_accelerator(64)
    x = _fft_input(rng, B=2)
    ref = np.asarray(acc.run_reference(x))
    plan = acc.healthy_plan().with_fault("fft_s2").with_fault("fft_s5")
    np.testing.assert_allclose(np.asarray(acc.run(x, plan)), ref, atol=1e-4)


def test_resident_route_conds_between_lowerings():
    """ResidentRoute lowers an op to lax.cond(healthy, hw, sw): with
    observably different lowerings the mask bit selects the path."""
    from repro.core.routing import RoutingPlan
    from repro.viscosity.lang import OpSpec

    spec = OpSpec(name="toy", ref=lambda x: x + 1.0,
                  kernel=lambda x: x + 2.0)
    plan = RoutingPlan((("toy", "hw"),))

    def f(x, mask):
        routes = plan.resident_routes(mask, ["toy"])
        return spec(x, route=routes["toy"])

    x = jnp.float32(10.0)
    assert float(jax.jit(f)(x, jnp.array([True]))) == 12.0   # hw path
    assert float(jax.jit(f)(x, jnp.array([False]))) == 11.0  # sw oracle
