"""Oobleck methodology: staged case studies, fault routing, dispatcher."""
import itertools

import jax
import jax.numpy as jnp
import numpy as np
import pytest
from hypothesis import given, settings, strategies as st

from repro.core import (CanaryChecker, Dispatcher, FaultSignature,
                        FaultState, Stage, StagedAccelerator, inject)
from repro.core.casestudies import (aes_accelerator, dct_accelerator,
                                    dct_reference, fft_accelerator,
                                    fft_reference)

KEY = np.arange(16, dtype=np.uint8)
FIPS_PT = np.array([0x00, 0x11, 0x22, 0x33, 0x44, 0x55, 0x66, 0x77, 0x88,
                    0x99, 0xaa, 0xbb, 0xcc, 0xdd, 0xee, 0xff], np.uint8)
FIPS_CT = "69c4e0d86a7b0430d8cdb78070b4c55a"


def _fft_input(rng, B=4, n=64):
    return jnp.asarray(rng.normal(size=(B, n)) +
                       1j * rng.normal(size=(B, n))).astype(jnp.complex64)


def test_fft_case_study_correct(rng):
    acc = fft_accelerator(64)
    x = _fft_input(rng)
    np.testing.assert_allclose(np.asarray(acc.run(x)),
                               np.asarray(fft_reference(x)), atol=1e-4)


@pytest.mark.parametrize("n_stages", [11, 3])
def test_aes_fips_197(n_stages):
    acc = aes_accelerator(KEY, n_stages)
    ct = np.asarray(acc.run(jnp.asarray(FIPS_PT[None])))[0]
    assert bytes(ct).hex() == FIPS_CT


def test_dct_case_study_correct(rng):
    acc = dct_accelerator()
    x = jnp.asarray(rng.normal(size=(4, 8, 8)), jnp.float32)
    np.testing.assert_allclose(np.asarray(acc.run(x)),
                               np.asarray(dct_reference(x)), atol=1e-4)


def test_routing_invariance_exhaustive_fft(rng):
    """Every single-fault and double-fault signature yields the reference
    output — the paper's core functional claim."""
    acc = fft_accelerator(64)
    x = _fft_input(rng, B=2)
    ref = np.asarray(acc.run_reference(x))
    names = acc.stage_names
    for k in (1, 2):
        for faulty in itertools.combinations(names, k):
            sig = acc.healthy_signature()
            for f in faulty:
                sig = sig.with_fault(f)
            out = np.asarray(acc.run(x, sig))
            np.testing.assert_allclose(out, ref, atol=1e-4)


@settings(max_examples=25, deadline=None)
@given(mask=st.lists(st.booleans(), min_size=10, max_size=10))
def test_property_resident_routing_dct(mask):
    """Hot-spare (resident lax.cond) routing: ANY health mask -> reference
    output, under jit."""
    rng = np.random.default_rng(sum(mask))
    acc = dct_accelerator()
    x = jnp.asarray(rng.normal(size=(2, 8, 8)), jnp.float32)
    ref = np.asarray(acc.run_reference(x))
    out = np.asarray(jax.jit(acc.run_resident)(x, jnp.asarray(mask)))
    np.testing.assert_allclose(out, ref, atol=1e-4)


def test_canary_detects_injected_fault(rng):
    acc = dct_accelerator()
    stages = list(acc.stages)
    stages[4] = inject(stages[4], kind="bitflip")
    state = FaultState()
    found = CanaryChecker(stages).sweep(state)
    assert found == ["dct_s4"]
    assert state.is_faulty("dct_s4")
    sig = state.signature(acc.stage_names)
    assert sig.faulty() == {"dct_s4"}


def test_canary_passes_healthy():
    acc = dct_accelerator()
    state = FaultState()
    assert CanaryChecker(acc.stages).sweep(state) == []


def test_dispatcher_compiles_once_per_signature():
    calls = []

    def build(sig):
        calls.append(sig)
        return lambda x: x + sig.n_faults()

    d = Dispatcher(build)
    s0 = FaultSignature.healthy(["a", "b"])
    s1 = s0.with_fault("a")
    assert d(s0, 1) == 1
    assert d(s0, 1) == 1
    assert d(s1, 1) == 2
    assert d(s1, 1) == 2
    assert d.compiles == 2 and len(calls) == 2


def test_signature_monotone_and_frozen():
    s = FaultSignature.healthy(["a", "b", "c"])
    s1 = s.with_fault("b")
    assert s.n_faults() == 0 and s1.n_faults() == 1
    assert s1.with_fault("b") == s1          # idempotent
    assert hash(s1) == hash(s.with_fault("b"))


def test_injected_stage_breaks_then_sw_fallback_fixes(rng):
    """End-to-end: injection corrupts the HW path; routing that stage to SW
    restores the reference output."""
    acc = fft_accelerator(64)
    stages = list(acc.stages)
    stages[3] = inject(stages[3], kind="gain", magnitude=0.5)
    bad = StagedAccelerator("fft-bad", stages)
    x = _fft_input(rng, B=2)
    ref = np.asarray(acc.run_reference(x))
    out_bad = np.asarray(bad.run(x))
    assert np.abs(out_bad - ref).max() > 1e-3   # fault visible
    sig = bad.healthy_signature().with_fault("fft_s3")
    out_fixed = np.asarray(bad.run(x, sig))
    np.testing.assert_allclose(out_fixed, ref, atol=1e-4)
