"""Training runner: convergence, fault reroute, NaN-guard restart."""
import tempfile

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro import optim
from repro.configs import get_config
from repro.data import DataConfig, SyntheticLM
from repro.train import TrainConfig, TrainRunner

CFG = get_config("qwen1.5-4b").reduced()


def _runner(tmp, steps=40, **kw):
    data = SyntheticLM(DataConfig(vocab_size=CFG.vocab_size, batch=4,
                                  seq_len=64))
    ocfg = optim.AdamWConfig(lr=1e-2, warmup_steps=5, total_steps=200)
    tcfg = TrainConfig(steps=steps, ckpt_every=10, ckpt_dir=tmp, **kw)
    return TrainRunner(CFG, ocfg, tcfg, data)


def test_loss_decreases():
    with tempfile.TemporaryDirectory() as tmp:
        r = _runner(tmp, steps=60)
        state = r.init_state()
        r.run(*state)
        losses = [h["loss"] for h in r.history]
        assert np.mean(losses[-10:]) < np.mean(losses[:10]) - 0.2


def test_fault_reroutes_and_training_continues():
    with tempfile.TemporaryDirectory() as tmp:
        r = _runner(tmp, steps=10)
        params, opt, err = r.init_state()
        params, opt, err = r.run(params, opt, err)
        assert r.dispatcher.compiles == 1
        r.inject_fault("flash_attention")
        params, opt, err = r.run(params, opt, err, start_step=10, steps=10)
        # The CPU deployment's healthy target IS the SW oracle, so the
        # quarantine does not change the RoutingPlan — plan-keyed dispatch
        # dedupes it to zero recompiles (signature-keyed caching paid one).
        assert r.dispatcher.compiles == 1
        assert r.plan() == r.dispatcher.cached_keys()[-1]
        assert r.signature().faulty() == {"flash_attention"}
        assert all(np.isfinite(h["loss"]) for h in r.history)


def test_fault_reconfigures_when_routes_differ():
    """When the healthy target differs from the fallback, a fault is a new
    plan -> exactly one reconfiguration (compile) at the dispatcher."""
    with tempfile.TemporaryDirectory() as tmp:
        r = _runner(tmp, steps=1, hw_route="interpret")
        plan_h = r.plan()
        r.inject_fault("flash_attention")
        plan_f = r.plan()
        assert plan_h != plan_f
        assert plan_f.target_for("flash_attention") == "sw"
        assert plan_f.target_for("swiglu_mlp") == "interpret"


def test_fault_does_not_change_loss_values():
    """Routing a stage to SW is value-equivalent: the next-step loss with
    and without the fault matches (same params, same batch)."""
    with tempfile.TemporaryDirectory() as tmp:
        r = _runner(tmp, steps=5)
        params, opt, err = r.init_state()
        params, opt, err = r.run(params, opt, err)
        batch = r.data.device_batch(99)

        def copies():
            return (jax.tree_util.tree_map(jnp.copy, params),
                    jax.tree_util.tree_map(jnp.copy, opt), jnp.zeros(()))

        healthy_fn = r.dispatcher.get(r.plan())
        out_h = healthy_fn(*copies(), batch)   # donation-safe copies
        loss_h = float(out_h[-1]["loss"])
        r.inject_fault("swiglu_mlp")
        faulty_fn = r.dispatcher.get(r.plan())
        out_f = faulty_fn(*copies(), batch)
        loss_f = float(out_f[-1]["loss"])
        assert loss_h == pytest.approx(loss_f, abs=1e-3)


def test_nan_guard_restores_checkpoint():
    with tempfile.TemporaryDirectory() as tmp:
        r = _runner(tmp, steps=20)
        params, opt, err = r.init_state()
        params, opt, err = r.run(params, opt, err)   # ckpts at 10, 20
        # corrupt the params (simulated SDC) -> next step loss is NaN
        bad = jax.tree_util.tree_map(lambda x: x, params)
        bad["embed"]["table"] = bad["embed"]["table"].at[0, 0].set(
            jnp.nan)
        params2, opt2, err2 = r.run(bad, opt, err, start_step=20, steps=5)
        assert r.guard_trips >= 1
        # training recovered and completed the requested steps
        assert r.history[-1]["step"] == 24
        assert np.isfinite(r.history[-1]["loss"])


def test_compression_error_feedback_converges():
    with tempfile.TemporaryDirectory() as tmp:
        r = _runner(tmp, steps=40, compression=True)
        state = r.init_state()
        r.run(*state)
        losses = [h["loss"] for h in r.history]
        assert np.mean(losses[-10:]) < np.mean(losses[:10]) - 0.15


def test_straggler_watchdog():
    from repro.core.fault import StragglerWatchdog
    w = StragglerWatchdog(threshold=2.0, window=8)
    for _ in range(8):
        for rep in range(4):
            w.record(rep, 0.1 if rep != 2 else 0.35)
    assert w.stragglers() == [2]
