"""End-to-end behaviour tests for the Oobleck system.

The paper's top-level claims, exercised on the real framework:
  1. a staged accelerator survives any single (and double) stage fault with
     unchanged outputs (variable-fault accelerator, not single-fault);
  2. detection -> quarantine -> reconfiguration is automatic and cheap
     (one recompile per new signature);
  3. a full train -> fault -> recover -> checkpoint -> restart cycle works.
"""
import tempfile

import jax.numpy as jnp
import numpy as np

from repro import optim
from repro.configs import get_config
from repro.core import CanaryChecker, FaultState, inject
from repro.core.casestudies import fft_accelerator
from repro.data import DataConfig, SyntheticLM
from repro.train import TrainConfig, TrainRunner


def test_vfa_not_sfa():
    """The defining property: k faults degrade, they don't kill."""
    rng = np.random.default_rng(0)
    acc = fft_accelerator(64)
    x = jnp.asarray(rng.normal(size=(2, 64)) +
                    1j * rng.normal(size=(2, 64))).astype(jnp.complex64)
    ref = np.asarray(acc.run_reference(x))
    sig = acc.healthy_signature()
    for stage in acc.stage_names:      # accumulate faults one by one
        sig = sig.with_fault(stage)
        np.testing.assert_allclose(np.asarray(acc.run(x, sig)), ref,
                                   atol=1e-4)
    assert sig.n_faults() == len(acc.stages)   # fully software, still alive


def test_detect_quarantine_reconfigure_cycle():
    rng = np.random.default_rng(1)
    acc = fft_accelerator(64)
    stages = list(acc.stages)
    stages[2] = inject(stages[2], kind="gain", magnitude=0.3)
    state = FaultState()
    found = CanaryChecker(stages).sweep(state)
    assert found == ["fft_s2"]
    sig = state.signature(acc.stage_names)
    x = jnp.asarray(rng.normal(size=(2, 64)) +
                    1j * rng.normal(size=(2, 64))).astype(jnp.complex64)
    from repro.core.oobleck import StagedAccelerator
    bad = StagedAccelerator("fft", stages)
    np.testing.assert_allclose(np.asarray(bad.run(x, sig)),
                               np.asarray(acc.run_reference(x)), atol=1e-4)


def test_full_lifecycle_train_fault_restart():
    cfg = get_config("gemma3-1b").reduced()
    data = SyntheticLM(DataConfig(vocab_size=cfg.vocab_size, batch=4,
                                  seq_len=48))
    with tempfile.TemporaryDirectory() as tmp:
        ocfg = optim.AdamWConfig(lr=5e-3, warmup_steps=5, total_steps=100)
        r = TrainRunner(cfg, ocfg,
                        TrainConfig(steps=20, ckpt_every=10, ckpt_dir=tmp),
                        data)
        params, opt, err = r.init_state()
        params, opt, err = r.run(params, opt, err)
        # fault mid-life -> reroute, keep training
        r.inject_fault("flash_attention")
        params, opt, err = r.run(params, opt, err, start_step=20, steps=10)
        # healthy target == SW fallback on CPU -> same RoutingPlan, so the
        # plan-keyed dispatcher dedupes the reconfiguration entirely
        assert r.dispatcher.compiles == 1
        # "process restart": a fresh runner restores the async checkpoint
        r2 = TrainRunner(cfg, ocfg,
                         TrainConfig(steps=10, ckpt_every=10, ckpt_dir=tmp),
                         data)
        p2, o2, e2 = r2.init_state()
        step = r2.ckpt.latest_step()
        like = {"params": p2, "opt": o2}
        restored = r2.ckpt.restore(step, like)
        assert step == 30
        r2.run(restored["params"], restored["opt"], e2, start_step=step,
               steps=5)
        losses = [h["loss"] for h in r2.history]
        assert all(np.isfinite(l) for l in losses)


def test_canary_stage_coverage_matches_arch():
    from repro.train import model_stage_names
    assert model_stage_names(get_config("mixtral-8x7b")) == \
        ["flash_attention"]
    assert "mamba2_ssd" in model_stage_names(get_config("zamba2-1.2b"))
    assert model_stage_names(get_config("rwkv6-1.6b")) == ["rwkv6_wkv"]
    assert "swiglu_mlp" in model_stage_names(get_config("qwen1.5-4b"))
