"""Latency model (paper §V, Figs. 5-8): reported numbers + qualitative laws."""
import pytest
from _hypothesis_compat import given, settings, st

from repro.core.latency import (aes_model, dct_model, exec_time, fft_model,
                                passthrough_model, speedup_vs_sw,
                                throughput_factor)


# ----------------------------------------------------- Fig. 5 case studies
def test_fft_reported_numbers():
    m = fft_model()
    assert speedup_vs_sw(m) == pytest.approx(13.5, rel=0.02)       # no fault
    assert speedup_vs_sw(m, [2]) == pytest.approx(5.181, rel=0.02)  # 1 fault
    # plausibility: per-stage fallbacks sum within 0.6-1.2x of monolithic sw
    assert 0.6 <= sum(m.fb_stage) / m.sw_total <= 1.2


def test_dct_reported_numbers():
    m = dct_model()
    assert speedup_vs_sw(m) == pytest.approx(5.3, rel=0.02)
    assert speedup_vs_sw(m, [0]) == pytest.approx(2.87, rel=0.02)


def test_aes_reported_numbers_and_stage_insensitivity():
    """Paper: one fault -> 58% of software; stage count has no effect."""
    f3 = 1.0 / speedup_vs_sw(aes_model(3), [1])
    f11 = 1.0 / speedup_vs_sw(aes_model(11), [5])
    assert f3 == pytest.approx(0.58, abs=0.02)
    assert f11 == pytest.approx(0.58, abs=0.02)


def test_paper_speedup_band_under_single_fault():
    """Abstract claim: 1.7x-5.16x speedup maintained under a single fault."""
    vals = [speedup_vs_sw(fft_model(), [0]), speedup_vs_sw(dct_model(), [0]),
            1.0 / 0.58]
    assert min(vals) >= 1.7 * 0.98
    assert max(vals) <= 5.2


# -------------------------------------------------- Fig. 6 pass-through
def test_fig6_monotone_in_stages_and_size():
    sizes = [30_000, 120_000, 300_000]
    stages = [3, 6, 9, 12]
    grid = {(op, n): speedup_vs_sw(passthrough_model(op, n), [0])
            for op in sizes for n in stages}
    for op in sizes:                       # more stages -> better
        for a, b in zip(stages, stages[1:]):
            assert grid[(op, b)] > grid[(op, a)]
    for n in stages:                       # larger op -> better
        for a, b in zip(sizes, sizes[1:]):
            assert grid[(b, n)] > grid[(a, n)]
    # sensitivity claim: stage count matters more for the large op
    delta_small = grid[(30_000, 9)] - grid[(30_000, 3)]
    delta_large = grid[(300_000, 9)] - grid[(300_000, 3)]
    assert delta_large > delta_small


def test_fig6_reported_corners():
    """Corner values within a calibration band (t_q unpublished; see
    latency.py identifiability note)."""
    assert speedup_vs_sw(passthrough_model(30_000, 9), [0]) == \
        pytest.approx(3.3, rel=0.15)
    assert speedup_vs_sw(passthrough_model(300_000, 12), [0]) == \
        pytest.approx(9.7, rel=0.15)


# ------------------------------------------------------ Fig. 7 two faults
def test_fig7_two_fault_claims():
    # Fig. 7's rig carries a larger (unpublished) per-crossing overhead
    # than the Fig. 6 calibration; with the single global t_q default the
    # small-op corners land within ~35% while every ratio law is exact.
    m6 = passthrough_model(30_000, 6)
    s1 = speedup_vs_sw(m6, [0])
    s2 = speedup_vs_sw(m6, [0, 3])
    assert s1 == pytest.approx(2.17, rel=0.35)
    assert s2 == pytest.approx(1.3, rel=0.45)
    assert s2 > 1.0                       # still beats software
    m12 = passthrough_model(240_000, 12)
    assert speedup_vs_sw(m12, [0, 6]) == pytest.approx(4.30, rel=0.25)
    m10 = passthrough_model(200_000, 10)
    assert speedup_vs_sw(m10, [0, 5]) == pytest.approx(3.65, rel=0.25)
    # large ops keep ~half the 1-fault speedup with 2 faults
    ratio = speedup_vs_sw(m12, [0, 6]) / speedup_vs_sw(m12, [0])
    assert 0.4 <= ratio <= 0.75


def test_many_faults_can_lose_to_software():
    """Paper: 30k/6-stage with 3 faults would likely lose to software,
    while 240k/12-stage tolerates up to 8 faults."""
    m6 = passthrough_model(30_000, 6)
    assert speedup_vs_sw(m6, [0, 2, 4]) < 1.25
    m12 = passthrough_model(240_000, 12)
    assert speedup_vs_sw(m12, list(range(8))) > 1.0


# ---------------------------------------------------- Fig. 8 FPGA fallback
def test_fig8_fpga_fallback():
    m = passthrough_model(60_000, 6)
    sw = speedup_vs_sw(m, [0], fallback_speedup=1.0)
    speedups = [speedup_vs_sw(m, [0], fallback_speedup=f)
                for f in (35, 100, 200)]
    assert all(s > sw for s in speedups)          # FPGA beats sw fallback
    assert speedups[0] < speedups[1] < speedups[2]
    # diminishing returns: transmission bottleneck (the paper's point)
    gain_lo = speedups[1] - speedups[0]
    gain_hi = speedups[2] - speedups[1]
    assert gain_hi < gain_lo
    # and the ceiling: no-fault speedup is not exceeded
    assert speedups[2] <= speedup_vs_sw(m) * 1.001


def test_fpga_recovers_most_of_accelerator_speed():
    """Abstract/§V-G: a hot-spare FPGA *connected directly* (no software
    routing) retains >=80% of the original accelerator speed; the
    software-routed variant saturates lower (Fig. 8's bottleneck)."""
    m = passthrough_model(600_000, 6, t_q=1200.0)
    direct = speedup_vs_sw(m, [0], fallback_speedup=200,
                           direct_fallback=True) / speedup_vs_sw(m)
    routed = speedup_vs_sw(m, [0], fallback_speedup=200) / speedup_vs_sw(m)
    assert direct >= 0.8
    assert routed < direct


# ------------------------------------------------------- properties
@settings(max_examples=30, deadline=None)
@given(op=st.integers(20_000, 500_000), n=st.integers(2, 16),
       k=st.integers(0, 2))
def test_property_more_faults_never_faster(op, n, k):
    m = passthrough_model(op, n)
    faults = list(range(k))
    t_k = exec_time(m, faults)
    t_k1 = exec_time(m, faults + [k]) if k < n - 1 else None
    if t_k1 is not None:
        assert t_k1 >= t_k
    # throughput factor is within (0, 1] and decreasing
    f = [throughput_factor(m, i) for i in range(min(3, n))]
    assert all(0 < x <= 1.0 + 1e-9 for x in f)
    assert all(a >= b for a, b in zip(f, f[1:]))
