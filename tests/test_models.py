"""Per-arch smoke tests (assignment deliverable f): reduced same-family
configs, one forward/train step on CPU, output shapes + no NaNs."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import ARCH_NAMES, SHAPES, applicable, get_config
from repro.models import build_model, input_specs

KEY = jax.random.PRNGKey(0)


def _batch(cfg, B=2, S=32):
    tokens = jax.random.randint(KEY, (B, S), 0, cfg.vocab_size)
    if cfg.is_encdec:
        return {"embeds": jax.random.normal(KEY, (B, S, cfg.d_model),
                                            jnp.bfloat16),
                "dec_tokens": tokens[:, :16], "dec_targets": tokens[:, :16]}
    if cfg.stub_frontend:
        p3 = jnp.tile(jnp.arange(S)[None, :, None], (B, 1, 3)).astype(
            jnp.int32)
        return {"embeds": jax.random.normal(KEY, (B, S, cfg.d_model),
                                            jnp.bfloat16),
                "positions3": p3, "targets": tokens}
    return {"tokens": tokens, "targets": tokens}


@pytest.mark.parametrize("arch", ARCH_NAMES)
def test_smoke_forward_and_grad(arch):
    cfg = get_config(arch).reduced()
    model = build_model(cfg)
    params = model.init(KEY)
    batch = _batch(cfg)
    (loss, metrics), grads = jax.jit(jax.value_and_grad(
        model.forward, has_aux=True))(params, batch)
    assert np.isfinite(float(loss))
    assert 0 < float(loss) < 2 * np.log(cfg.vocab_size)
    gnorm = sum(float(jnp.sum(jnp.abs(g)))
                for g in jax.tree_util.tree_leaves(grads))
    assert np.isfinite(gnorm) and gnorm > 0


@pytest.mark.parametrize("arch", ARCH_NAMES)
def test_smoke_logits_shape(arch):
    cfg = get_config(arch).reduced()
    model = build_model(cfg)
    params = model.init(KEY)
    batch = _batch(cfg)
    logits = model.logits_all(params, batch)
    B = 2
    T = 16 if cfg.is_encdec else 32
    assert logits.shape == (B, T, cfg.vocab_size)
    assert bool(jnp.all(jnp.isfinite(logits.astype(jnp.float32))))


@pytest.mark.parametrize("arch", ARCH_NAMES)
def test_full_config_param_count_sane(arch):
    """Full configs instantiate as specs only (no allocation) and land in
    the expected parameter-count band for their nameplate size."""
    cfg = get_config(arch)
    model = build_model(cfg)
    sds = jax.eval_shape(model.init, jax.ShapeDtypeStruct((2,), jnp.uint32))
    n = sum(int(np.prod(l.shape)) for l in jax.tree_util.tree_leaves(sds))
    bands = {
        "zamba2-1.2b": (0.9e9, 1.7e9), "qwen1.5-4b": (3e9, 5e9),
        "gemma2-2b": (2e9, 3.5e9), "mistral-nemo-12b": (10e9, 14e9),
        "gemma3-1b": (0.7e9, 1.6e9),
        "llama4-scout-17b-a16e": (90e9, 120e9),   # total (16 experts)
        "mixtral-8x7b": (42e9, 50e9),
        "qwen2-vl-7b": (6e9, 9e9), "whisper-base": (6e7, 9e7),
        "rwkv6-1.6b": (1.3e9, 2.2e9),
    }
    lo, hi = bands[arch]
    assert lo <= n <= hi, f"{arch}: {n/1e9:.2f}B params out of band"


def test_shape_applicability_rules():
    """long_500k runs only for sub-quadratic archs (DESIGN.md §6)."""
    runs = {a: applicable(get_config(a), SHAPES["long_500k"])[0]
            for a in ARCH_NAMES}
    assert runs["zamba2-1.2b"] and runs["rwkv6-1.6b"] and \
        runs["mixtral-8x7b"]
    for a in ("qwen1.5-4b", "gemma2-2b", "mistral-nemo-12b", "gemma3-1b",
              "llama4-scout-17b-a16e", "qwen2-vl-7b", "whisper-base"):
        assert not runs[a], a


def test_input_specs_cover_all_cells():
    for arch in ARCH_NAMES:
        cfg = get_config(arch)
        model = build_model(cfg)
        for shape in SHAPES.values():
            if not applicable(cfg, shape)[0]:
                continue
            specs = input_specs(cfg, shape, model)
            leaves = jax.tree_util.tree_leaves(specs)
            assert leaves and all(
                isinstance(l, jax.ShapeDtypeStruct) for l in leaves)


def test_moe_capacity_drop_accounting():
    """MoE drops tokens beyond capacity and reports the fraction."""
    from repro.models import moe as moe_mod
    cfg = get_config("mixtral-8x7b").reduced()
    p = moe_mod.init_moe(KEY, cfg.d_model, cfg.d_ff, 4, jnp.float32)
    x = jax.random.normal(KEY, (2, 32, cfg.d_model), jnp.float32)
    y, aux = moe_mod.moe_ffn(p, x, top_k=2, capacity_factor=0.5)
    assert y.shape == x.shape
    assert 0.0 < float(aux["drop_frac"]) < 1.0
    y2, aux2 = moe_mod.moe_ffn(p, x, top_k=2, capacity_factor=8.0)
    assert float(aux2["drop_frac"]) == 0.0
