"""Telemetry-layer tests: metrics registry determinism, the Prometheus
golden, the cross-host span-merge byte-identity property, the logging
facade render format, and the instrumentation-overhead guard.

The determinism tests pin the exact-reproduction contract the benches
rely on: two runs that record the same observations serialize to
byte-identical JSONL, and histograms carry exact count/sum/min/max so
statistics previously computed harness-side (MTTR mean/max, goodput)
reproduce bit-for-bit from a snapshot.
"""
import logging
import random
import time

import pytest

from _hypothesis_compat import given, settings, st
from repro.obs import metrics, report, trace
from repro.obs.logging import get_logger
from repro.obs.logging import configure as obs_configure


# ------------------------------------------------------- bucket edges
def test_log_buckets_golden():
    """Edges are pure ``**`` rounded to 9 significant digits: fixed
    constants, not wall-clock- or platform-dependent."""
    edges = metrics.log_buckets(1e-4, 1e3, 15)
    assert edges == metrics.DEFAULT_BUCKETS
    assert len(edges) == 15
    assert edges[0] == 1e-4 and edges[-1] == 1e3
    assert all(a < b for a, b in zip(edges, edges[1:]))
    # recomputation is bit-identical (no accumulated-multiply drift)
    assert edges == metrics.log_buckets(1e-4, 1e3, 15)
    # one interior golden value pins the 9-sig-digit rounding rule
    assert edges[7] == float(f"{1e-4 * 1e7 ** (7 / 14):.9g}")


def test_log_buckets_rejects_bad_ranges():
    for lo, hi, n in [(0.0, 1.0, 4), (1.0, 1.0, 4), (2.0, 1.0, 4),
                      (0.1, 1.0, 1)]:
        with pytest.raises(ValueError):
            metrics.log_buckets(lo, hi, n)


# -------------------------------------------------- snapshot determinism
def _record(reg: metrics.Registry):
    with metrics.use(reg):
        with metrics.label_scope(section="unit"):
            for v in [0.0625, 0.5, 0.5, 5.0, 0.0009765625]:
                metrics.observe("mttr_seconds", v)
            metrics.inc("serve_completed_total", 7)
            metrics.inc("kv_retries_total", 3, op="get")
            metrics.set_gauge("serve_virtual_time_seconds", 12.25)


def test_two_registries_byte_identical():
    a, b = metrics.Registry(), metrics.Registry()
    _record(a)
    _record(b)
    assert a.to_jsonl() == b.to_jsonl()
    assert a.to_prometheus() == b.to_prometheus()
    assert a.snapshot() == b.snapshot()


def test_histogram_exact_stats():
    """count/sum/min/max are exact (sum in observation order), and the
    bucket counts partition the observations."""
    reg = metrics.Registry()
    vals = [0.0625, 0.5, 0.5, 5.0, 0.0009765625]
    with metrics.use(reg), metrics.label_scope(section="unit"):
        for v in vals:
            metrics.observe("mttr_seconds", v)
    snap = reg.snapshot()
    st_ = report.hist_stats(snap, "mttr_seconds", section="unit")
    acc = 0.0
    for v in vals:
        acc += v
    assert st_["count"] == len(vals)
    assert st_["sum"] == acc            # bit-exact, not approx
    assert st_["min"] == min(vals) and st_["max"] == max(vals)
    fam = report.family(snap, "mttr_seconds")
    assert sum(fam["samples"][0]["bucket_counts"]) == len(vals)


def test_label_scope_only_applies_declared_labels():
    """A scope's ``section`` reaches only families that declare it;
    explicit labels win over the scope."""
    reg = metrics.Registry()
    with metrics.use(reg), metrics.label_scope(section="outer", op="x"):
        metrics.inc("kv_retries_total")              # op <- scope
        metrics.inc("kv_retries_total", op="put")    # explicit wins
        metrics.observe("train_step_seconds", 0.5)   # declares no labels
    snap = reg.snapshot()
    assert report.counter_value(snap, "kv_retries_total", op="x") == 1
    assert report.counter_value(snap, "kv_retries_total", op="put") == 1
    assert report.hist_stats(snap, "train_step_seconds")["count"] == 1


def test_hist_stats_refuses_to_merge_children():
    """Exact float sums never merge across label children — a query
    matching several must raise, not silently add."""
    reg = metrics.Registry()
    with metrics.use(reg):
        metrics.observe("mttr_seconds", 1.0, section="a")
        metrics.observe("mttr_seconds", 2.0, section="b")
    with pytest.raises(ValueError):
        report.hist_stats(reg.snapshot(), "mttr_seconds")


def test_unknown_family_raises():
    reg = metrics.Registry()
    with pytest.raises(KeyError):
        reg.inc("not_in_schema_total")
    with pytest.raises(TypeError):
        reg.observe("kv_retries_total", 1.0)  # declared counter


# ---------------------------------------------------- Prometheus golden
def test_prometheus_golden():
    """Byte-for-byte exposition golden over all three kinds (dyadic
    values, so every float renders exactly)."""
    reg = metrics.Registry()
    reg.declare("rpc_seconds", metrics.HISTOGRAM, "rpc time", ("op",),
                (0.125, 1.0))
    reg.declare("reqs_total", metrics.COUNTER, "requests", ("code",))
    reg.declare("up", metrics.GAUGE, "liveness")
    reg.inc("reqs_total", code="200")
    reg.inc("reqs_total", 2, code="500")
    reg.set_gauge("up", 1)
    for v in (0.0625, 0.5, 5.0):
        reg.observe("rpc_seconds", v, op="get")
    golden = "\n".join([
        "# HELP reqs_total requests",
        "# TYPE reqs_total counter",
        'reqs_total{code="200"} 1',
        'reqs_total{code="500"} 2',
        "# HELP rpc_seconds rpc time",
        "# TYPE rpc_seconds histogram",
        'rpc_seconds_bucket{op="get",le="0.125"} 1',
        'rpc_seconds_bucket{op="get",le="1"} 2',
        'rpc_seconds_bucket{op="get",le="+Inf"} 3',
        'rpc_seconds_sum{op="get"} 5.5625',
        'rpc_seconds_count{op="get"} 3',
        'rpc_seconds_min{op="get"} 0.0625',
        'rpc_seconds_max{op="get"} 5',
        "# HELP up liveness",
        "# TYPE up gauge",
        "up 1",
    ]) + "\n"
    assert reg.to_prometheus() == golden


# ------------------------------------------------- span-merge property
def _two_host_trace():
    """A fixed 2-host trace with overlapping request spans, fault
    annotations and an identical-name span on both hosts."""
    t0, t1 = trace.Tracer(origin=0), trace.Tracer(origin=1)
    t0.span_start(0, "req:0", rid=0)
    t0.annotate(1, "fault", stage="flash_attention", fault="transient")
    t1.span_start(1, "req:1", rid=1)
    t0.span_end(3, "req:0", tokens=17)
    t1.annotate(3, "probation", verdict="transient_recovered")
    t1.span_end(6, "req:1", tokens=9)
    t0.span_start(4, "ckpt")
    t0.span_end(5, "ckpt")
    return t0.events, t1.events


@settings(max_examples=40)
@given(seed=st.integers(min_value=0, max_value=10 ** 9),
       split=st.integers(min_value=0, max_value=12),
       dups=st.lists(st.integers(min_value=0, max_value=11),
                     min_size=0, max_size=6))
def test_span_merge_byte_identical_any_interleaving(seed, split, dups):
    """ISSUE acceptance: the merged 2-host trace serializes to the same
    bytes regardless of delivery order, partitioning, or duplication —
    the sorted-dedup union over the (step, origin, seq) logical clock
    is one value."""
    a, b = _two_host_trace()
    golden = trace.to_jsonl(trace.merge(a, b))
    delivered = list(a) + list(b)
    delivered += [delivered[i % len(delivered)] for i in dups]
    random.Random(seed).shuffle(delivered)
    cut = min(split, len(delivered))
    merged = trace.merge(delivered[:cut], delivered[cut:])
    assert trace.to_jsonl(merged) == golden
    # and a wire round-trip of the merged trace is the identity
    assert trace.to_jsonl(trace.from_jsonl(golden)) == golden


def test_spans_pair_by_name_in_clock_order():
    a, b = _two_host_trace()
    spans = trace.spans_of(trace.merge(a, b))
    by_name = {s.name: s for s in spans}
    assert by_name["req:0"].steps == 3
    assert by_name["req:1"].steps == 5
    assert by_name["ckpt"].steps == 1
    assert all(s.end is not None for s in spans)


def test_tracer_seq_monotone_and_kinds_checked():
    t = trace.Tracer(origin=2)
    evs = [t.annotate(5, "x"), t.annotate(5, "y"), t.annotate(4, "z")]
    assert [e.seq for e in evs] == [0, 1, 2]
    assert sorted(evs) == [evs[2], evs[0], evs[1]]  # clock order
    with pytest.raises(ValueError):
        trace.TraceEvent(step=0, origin=0, seq=0, kind="bogus")


# ----------------------------------------------------- logging facade
def test_structured_render_format():
    log = get_logger("unit.test", rid=7)
    assert log.render("ev", {}) == "[unit.test] ev rid=7"
    line = log.render("done", {"msg": "two words",
                               "stamp": (3, 0, 9)})
    assert line == '[unit.test] done rid=7 msg="two words" stamp=3/0/9'
    child = log.bind(section="serve")
    assert child.render("ev", {}) == "[unit.test] ev rid=7 section=serve"


def test_configure_is_idempotent_and_message_only(capsys):
    root = logging.getLogger("repro")
    prev = (list(root.handlers), root.propagate, root.level)
    try:
        obs_configure(level="info")
        obs_configure(level="info")      # second call adds no handler
        ours = [h for h in root.handlers
                if getattr(h, "_repro_obs", False)]
        assert len(ours) == 1
        get_logger("unit.test").info("hello", n=3)
        assert "[unit.test] hello n=3" in capsys.readouterr().err
    finally:
        for h in list(root.handlers):
            if getattr(h, "_repro_obs", False):
                root.removeHandler(h)
        root.propagate = prev[1]
        root.setLevel(prev[2])


# ---------------------------------------------------- overhead guard
def test_instrumentation_overhead_bounded():
    """The module-level helpers must stay cheap enough to live on hot
    paths: generous absolute bounds (no cross-timing ratio, which
    flakes on loaded CI machines)."""
    n = 20_000
    reg = metrics.Registry()
    with metrics.use(reg), metrics.label_scope(section="bench"):
        t0 = time.perf_counter()
        for _ in range(n):
            metrics.inc("serve_completed_total")
            metrics.observe("serve_decode_tick_seconds", 0.001)
        enabled = time.perf_counter() - t0
        with metrics.disabled():
            t0 = time.perf_counter()
            for _ in range(n):
                metrics.inc("serve_completed_total")
                metrics.observe("serve_decode_tick_seconds", 0.001)
            off = time.perf_counter() - t0
    # 2 ops per iteration; <50us/op enabled, <5us/op disabled is ~100x
    # headroom over observed cost on a cold CPU container
    assert enabled / (2 * n) < 50e-6
    assert off / (2 * n) < 5e-6
    # disabled() really recorded nothing beyond the enabled loop
    assert report.counter_value(reg.snapshot(), "serve_completed_total",
                                section="bench") == n


# -------------------------------------------------- snapshot loading
def test_load_snapshot_accepts_bare_and_wrapped(tmp_path):
    reg = metrics.Registry()
    with metrics.use(reg), metrics.label_scope(section="s"):
        metrics.observe("mttr_seconds", 0.5)
    snap = reg.snapshot()
    import json
    bare = tmp_path / "bare.json"
    wrapped = tmp_path / "wrapped.json"
    bare.write_text(json.dumps(snap))
    wrapped.write_text(json.dumps({"metrics": snap, "trace": []}))
    for p in (bare, wrapped):
        loaded = report.load_snapshot(str(p))
        assert report.hist_stats(loaded["metrics"], "mttr_seconds",
                                 section="s")["count"] == 1
