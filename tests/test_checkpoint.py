"""Checkpoint manager: roundtrip, corruption detection, async, GC."""
import os
import tempfile

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.checkpoint import CheckpointManager


def _tree(rng):
    return {"a": jnp.asarray(rng.normal(size=(16, 8)), jnp.float32),
            "b": {"c": jnp.arange(10, dtype=jnp.int32),
                  "d": jnp.asarray(rng.normal(size=(4,)), jnp.bfloat16)}}


def test_roundtrip_bitexact(rng):
    with tempfile.TemporaryDirectory() as tmp:
        m = CheckpointManager(tmp)
        t = _tree(rng)
        m.save(7, t, extra={"data_step": 7})
        assert m.latest_step() == 7
        r = m.restore(7, t)
        for a, b in zip(jax.tree_util.tree_leaves(t),
                        jax.tree_util.tree_leaves(r)):
            np.testing.assert_array_equal(np.asarray(a), np.asarray(b))
        assert m.extra(7)["data_step"] == 7


def test_corruption_detected(rng):
    with tempfile.TemporaryDirectory() as tmp:
        m = CheckpointManager(tmp)
        t = _tree(rng)
        m.save(1, t)
        # flip a byte in one leaf file
        d = os.path.join(tmp, "step_00000001")
        fn = [f for f in os.listdir(d) if f.endswith(".npy")][0]
        with open(os.path.join(d, fn), "r+b") as f:
            f.seek(-1, os.SEEK_END)
            byte = f.read(1)
            f.seek(-1, os.SEEK_END)
            f.write(bytes([byte[0] ^ 0xFF]))
        with pytest.raises(IOError, match="corruption"):
            m.restore(1, t)


def test_async_save_and_gc(rng):
    with tempfile.TemporaryDirectory() as tmp:
        m = CheckpointManager(tmp, keep=2)
        t = _tree(rng)
        for s in (1, 2, 3, 4):
            m.save_async(s, t)
        m.wait()
        assert m.steps() == [3, 4]


def test_elastic_restore_with_shardings(rng):
    """Restore with explicit target shardings (single-device here; the
    dry-run exercises the production mesh path)."""
    with tempfile.TemporaryDirectory() as tmp:
        m = CheckpointManager(tmp)
        t = _tree(rng)
        m.save(1, t)
        sh = jax.tree_util.tree_map(
            lambda _: jax.sharding.SingleDeviceSharding(jax.devices()[0]), t)
        r = m.restore(1, t, shardings=sh)
        np.testing.assert_array_equal(np.asarray(r["a"]), np.asarray(t["a"]))
