"""Partial degradation: lane faults, DEGRADED lowerings, deterministic logs.

The value-level fault path end to end, per kernel family: an injected
``LaneFault`` corrupts ONLY its mapped lanes of the kernel's output
(healthy lanes bit-identical), the DEGRADED remap lowering heals the
corruption exactly (bit-identity across injection under the same plan),
reduced-width execution stays within the stage tolerance, and routing /
validation / the capacity model all consult the same lane-map registry.
Plus the two satellite bug classes: wall-clock-free fault logs that merge
identically under any interleaving, and injection no-ops on zero-heavy
inputs failing loudly instead of passing vacuously.
"""
import logging

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import CanaryChecker, FaultState, RoutingPlan, Stage
from repro.core.datacenter import DegradationModel
from repro.core.fault import (EXPECTED_STAGE_ERRORS, FaultInjector,
                              InjectionNoOpError)
from repro.kernels.flash_attention import ops as _fa_ops  # noqa: F401
from repro.kernels.mamba2_scan import ops as _m2_ops      # noqa: F401
from repro.kernels.rwkv6_scan import ops as _rk_ops       # noqa: F401
from repro.kernels.swiglu import ops as _sg_ops           # noqa: F401
from repro.launch import sharding
from repro.viscosity import (DEGRADED_REDUCED, DEGRADED_REMAP, INTERPRET,
                             REGISTRY, SW, lanefault)
from repro.viscosity.lanefault import LaneFault


@pytest.fixture(autouse=True)
def _clean_registries():
    lanefault.reset()
    yield
    lanefault.reset()


# Small canary ports per kernel family (the runner's shapes) + the output
# lane width each family's fault map refers to.
PORTS = {
    "flash_attention": ((2, 64, 4, 32), (2, 64, 2, 32), (2, 64, 2, 32)),
    "swiglu_mlp": ((64, 64), (64, 128), (64, 128), (128, 64)),
    "mamba2_ssd": ((2, 64, 2, 16), (2, 64, 2), (2,), (2, 64, 8),
                   (2, 64, 8)),
    "rwkv6_wkv": ((2, 32, 2, 16), (2, 32, 2, 16), (2, 32, 2, 16),
                  (2, 32, 2, 16), (2, 16)),
}
WIDTH = {"flash_attention": 32, "swiglu_mlp": 64, "mamba2_ssd": 16,
         "rwkv6_wkv": 16}
FAMILIES = sorted(PORTS)


def _stage(name: str) -> Stage:
    spec = REGISTRY.get(name)
    ports = tuple(jax.ShapeDtypeStruct(s, jnp.float32)
                  for s in PORTS[name])
    return Stage(name=name, spec=spec, ports=ports,
                 tol=max(spec.tol, 1e-3))


def _changed_lanes(a: np.ndarray, b: np.ndarray):
    """Lane (minor-axis) indices where two outputs differ at all."""
    d = (a != b).reshape(-1, a.shape[-1])
    return tuple(int(i) for i in np.flatnonzero(d.any(axis=0)))


# ------------------------------------------------------------- descriptor
def test_lane_fault_validation():
    with pytest.raises(ValueError):
        LaneFault(kind="melted", lanes=(0,), width=8)
    with pytest.raises(ValueError):
        LaneFault(kind=lanefault.STUCK, lanes=(), width=8)
    with pytest.raises(ValueError):
        LaneFault(kind=lanefault.STUCK, lanes=(8,), width=8)
    with pytest.raises(ValueError):                  # every lane dead
        LaneFault(kind=lanefault.STUCK, lanes=tuple(range(8)), width=8)
    with pytest.raises(ValueError):
        LaneFault(kind=lanefault.STUCK, lanes=(0,), width=1)
    f = LaneFault(kind=lanefault.GAIN, lanes=(5, 1, 5), width=8)
    assert f.lanes == (1, 5)                         # sorted, deduped
    assert f.survivors() == (0, 2, 3, 4, 6, 7)


def test_lane_fault_apply_is_shape_aware():
    f = LaneFault(kind=lanefault.STUCK, lanes=(1,), width=4, value=9.0)
    x = jnp.ones((3, 4))
    out = np.asarray(f.apply(x))
    assert (out[:, 1] == 9.0).all() and (out[:, [0, 2, 3]] == 1.0).all()
    # wrong minor width, integer dtype, scalar: all untouched
    assert f.apply(jnp.ones((3, 5))) is not None
    np.testing.assert_array_equal(np.asarray(f.apply(jnp.ones((3, 5)))),
                                  np.ones((3, 5)))
    ints = jnp.ones((3, 4), jnp.int32)
    np.testing.assert_array_equal(np.asarray(f.apply(ints)), np.ones((3, 4)))
    assert f.apply(3.0) == 3.0
    # kind semantics on the mapped lane
    z = jnp.full((2, 4), 2.0)
    drop = LaneFault(kind=lanefault.DROPPED_MAC, lanes=(0,), width=4)
    assert np.asarray(drop.apply(z))[0, 0] == 0.0
    gain = LaneFault(kind=lanefault.GAIN, lanes=(0,), width=4, gain=1.5)
    assert np.asarray(gain.apply(z))[0, 0] == 3.0


# ------------------------------------------------- kernel-level injection
@pytest.mark.parametrize("name", FAMILIES)
def test_injection_corrupts_only_mapped_lanes(name):
    """The fault threads into the kernel body: the HW output differs from
    clean ONLY on the mapped lanes, and clearing the injection restores
    bit-identical output (healthy paths compile identically)."""
    stage = _stage(name)
    x = stage.canary_inputs(seed=3)
    w = WIDTH[name]
    fault = LaneFault(kind=lanefault.STUCK, lanes=(1, w - 2), width=w)
    clean = np.asarray(stage.run(*x, route=INTERPRET))
    with lanefault.inject(name, fault):
        bad = np.asarray(stage.run(*x, route=INTERPRET))
    changed = _changed_lanes(bad, clean)
    assert changed, "injection was a silent no-op"
    assert set(changed) <= set(fault.lanes)
    again = np.asarray(stage.run(*x, route=INTERPRET))
    np.testing.assert_array_equal(again, clean)


@pytest.mark.parametrize("name", FAMILIES)
def test_degraded_remap_heals_bit_identically(name):
    """DEGRADED remap under injection == DEGRADED remap without injection,
    bit for bit: corruption confined to mapped lanes is recomputed via the
    oracle and scattered in exactly."""
    stage = _stage(name)
    spec = REGISTRY.get(name)
    x = stage.canary_inputs(seed=3)
    w = WIDTH[name]
    fault = LaneFault(kind=lanefault.DROPPED_MAC, lanes=(0, 3), width=w)
    ref = np.asarray(spec.ref(*x))
    with lanefault.known_map(name, fault, base=INTERPRET):
        fn = spec.lower(DEGRADED_REMAP)
        healed_clean = np.asarray(fn(*x))
        with lanefault.inject(name, fault):
            healed_inj = np.asarray(fn(*x))
    np.testing.assert_array_equal(healed_inj, healed_clean)
    # dead lanes are exactly the oracle; the rest within the contract tol
    np.testing.assert_array_equal(healed_inj[..., list(fault.lanes)],
                                  ref[..., list(fault.lanes)])
    assert np.abs(healed_inj - ref).max() <= stage.tol


@pytest.mark.parametrize("name", FAMILIES)
def test_degraded_reduced_width_matches_oracle(name):
    """Reduced-width execution (kernel on the surviving-lane operand
    window, oracle on the dead lanes) stays within the stage tolerance and
    is insensitive to the full-width injection (the narrow tile no longer
    matches the fault's width — the defect is routed around)."""
    stage = _stage(name)
    spec = REGISTRY.get(name)
    x = stage.canary_inputs(seed=3)
    w = WIDTH[name]
    fault = LaneFault(kind=lanefault.STUCK, lanes=(2, w - 1), width=w)
    ref = np.asarray(spec.ref(*x))
    with lanefault.known_map(name, fault, base=INTERPRET):
        fn = spec.lower(DEGRADED_REDUCED)
        out_clean = np.asarray(fn(*x))
        with lanefault.inject(name, fault):
            out_inj = np.asarray(fn(*x))
    np.testing.assert_array_equal(out_inj, out_clean)
    np.testing.assert_array_equal(out_inj[..., list(fault.lanes)],
                                  ref[..., list(fault.lanes)])
    assert np.abs(out_inj - ref).max() <= stage.tol


# ----------------------------------------------------- routing and ladder
def test_validate_rejects_degraded_without_map():
    plan = RoutingPlan.make({"swiglu_mlp": DEGRADED_REMAP})
    with pytest.raises(ValueError, match="no lane map"):
        plan.validate(registry=REGISTRY)
    f = LaneFault(kind=lanefault.STUCK, lanes=(1,), width=64)
    with lanefault.known_map("swiglu_mlp", f, base=INTERPRET):
        assert plan.validate(registry=REGISTRY) is plan


def test_rung_ladder_and_degraded_plan():
    assert [lanefault.rung_for(n) for n in (1, 2, 3, 7)] == [
        DEGRADED_REMAP, DEGRADED_REDUCED, SW, SW]
    with pytest.raises(ValueError):
        lanefault.rung_for(0)
    base = RoutingPlan.make({"a": INTERPRET, "b": INTERPRET})
    f = LaneFault(kind=lanefault.STUCK, lanes=(1,), width=8)
    with lanefault.known_map("a", f, base=INTERPRET):
        # mapped stage walks the ladder; unmapped keeps its binary route
        p1 = lanefault.degraded_plan(base, {"a": 1, "b": 1})
        assert p1.target_for("a") == DEGRADED_REMAP
        assert p1.target_for("b") == INTERPRET
        p2 = lanefault.degraded_plan(base, {"a": 2})
        assert p2.target_for("a") == DEGRADED_REDUCED
        p3 = lanefault.degraded_plan(base, {"a": 3})
        assert p3.target_for("a") == SW
    assert lanefault.degraded_plan(base, {"a": 1}) == base  # map cleared


def test_set_map_rejects_degraded_base():
    f = LaneFault(kind=lanefault.STUCK, lanes=(1,), width=8)
    with pytest.raises(ValueError):
        lanefault.set_map("s", f, base=DEGRADED_REMAP)


# ------------------------------------------------------- capacity model
def test_degradation_model_legacy_equivalence_and_partials():
    m = DegradationModel(curve=(1.0, 0.38, 0.19))
    # no rungs: exactly the legacy scalar curve (Fig. 2 unchanged)
    assert [m.factor(k) for k in (0, 1, 2, 5)] == [1.0, 0.38, 0.19, 0.19]
    # one remapped fault: absorbed off the curve, charged its partial
    assert m.factor(1, (("s", DEGRADED_REMAP),)) == pytest.approx(0.85)
    assert m.factor(1, (("s", DEGRADED_REDUCED),)) == pytest.approx(0.6)
    # reduced absorbs TWO faults (its ladder position)
    assert m.factor(2, (("s", DEGRADED_REDUCED),)) == pytest.approx(0.6)
    # a third fault on top bottoms out at SW: curve step re-applies
    assert m.factor(3, (("s", DEGRADED_REDUCED),)) == pytest.approx(
        0.38 * 0.6)
    # per-(stage, rung) override wins over the default
    m2 = DegradationModel(partial=((("s", DEGRADED_REMAP), 0.9),))
    assert m2.factor(1, (("s", DEGRADED_REMAP),)) == pytest.approx(0.9)
    assert m2.factor(1, (("t", DEGRADED_REMAP),)) == pytest.approx(0.85)
    with pytest.raises(ValueError):
        DegradationModel(partial=((("s", SW), 0.5),))
    assert m.slot_cap(6, 1, (("s", DEGRADED_REMAP),)) == 5   # round(5.1)


def test_degradation_model_rungs_of_reads_plan():
    f = LaneFault(kind=lanefault.STUCK, lanes=(1,), width=64)
    with lanefault.known_map("swiglu_mlp", f, base=INTERPRET):
        plan = RoutingPlan.make({"swiglu_mlp": DEGRADED_REMAP,
                                 "flash_attention": SW})
        assert DegradationModel.rungs_of(plan) == (
            ("swiglu_mlp", DEGRADED_REMAP),)


# ----------------------------------------------- deterministic fault logs
def test_fault_log_interleavings_merge_identically():
    """Two replicas' events arrive in different cross-origin
    interleavings (each origin's own emission order is what the seq stamp
    encodes, so it stays fixed — exactly FleetEvent's semantics); the
    merged logs are identical lists (the logical-stamp satellite)."""
    def run(order):
        h0, h1 = FaultState(origin="h0"), FaultState(origin="h1")
        events = {
            "a": lambda: h0.mark("flash_attention", step=2, kind="canary"),
            "b": lambda: h1.mark("swiglu_mlp", step=2, kind="injected"),
            "c": lambda: h0.note("<step>", step=3, kind="nan_guard"),
            "d": lambda: h1.mark("flash_attention", step=4),
        }
        for k in order:
            events[k]()
        # cross-observe the other replica's entries (any order)
        for e in list(h1.log):
            h0.observe(e)
        for e in list(h0.log):
            if e["origin"] == "h0":
                h1.observe(e)
        return h0, h1
    h0a, h1a = run("abcd")       # h0 emits a then c; h1 emits b then d
    h0b, h1b = run("badc")       # cross-origin order shuffled
    merged = FaultState.merge_logs(h0a.log, h1a.log)
    assert merged == FaultState.merge_logs(h0b.log, h1b.log)
    assert merged == FaultState.merge_logs(h1b.log, h0b.log)  # arg order
    # no wall-clock anywhere; stamps are exactly (step, origin, seq)
    for e in merged:
        assert set(e) == {"stage", "replica", "kind", "step", "origin",
                          "seq"}
    # observe folds counts identically on both sides
    assert h0a.count("flash_attention") == h0b.count("flash_attention") == 2


def test_fault_counts_drive_ladder_input():
    st = FaultState()
    st.mark("a", step=1)
    st.mark("a", step=2)
    st.mark("b", step=2)
    st.note("a", step=3)                       # log-only: no count
    assert st.counts(["a", "b", "c"]) == {"a": 2, "b": 1, "c": 0}
    assert st.count("a") == 2 and st.n_faults() == 2


# ------------------------------------------------- injection no-op guard
def test_injector_bitflip_corrupts_zero_heavy_input():
    inj = FaultInjector(kind="bitflip", magnitude=0.25)
    bad = inj.wrap(lambda: jnp.zeros((4, 4)))
    out = np.asarray(bad())                    # must not raise: zeros flip
    assert np.count_nonzero(out) == 1 and out.reshape(-1)[8] == 0.25


@pytest.mark.parametrize("kind", ["stuck_zero", "gain"])
def test_injector_noop_on_zeros_fails_loudly(kind):
    inj = FaultInjector(kind=kind)
    with pytest.raises(InjectionNoOpError):
        inj.wrap(lambda: jnp.zeros((4, 4)))()


@pytest.mark.parametrize("kind", ["bitflip", "stuck_zero", "gain"])
def test_injector_corrupts_nonzero_input(kind):
    inj = FaultInjector(kind=kind)
    clean = jnp.arange(1.0, 17.0).reshape(4, 4)
    out = np.asarray(inj.wrap(lambda: clean)())
    assert not np.array_equal(out, np.asarray(clean))


# --------------------------------------------------- narrowed fail-opens
def test_canary_expected_errors_flag_fault_and_log(caplog):
    def boom(x):
        raise ValueError("datapath shape breakage")
    stage = Stage(name="s", hw=boom, sw=lambda x: x,
                  ports=(jax.ShapeDtypeStruct((4,), jnp.float32),),
                  tol=0.0)
    chk = CanaryChecker([stage])
    with caplog.at_level(logging.WARNING, logger="repro.core.fault"):
        assert chk.check_stage(stage) is False
    assert any("treating as a fault" in r.message for r in caplog.records)


def test_canary_unexpected_errors_propagate():
    def bug(x):
        raise RuntimeError("a genuine bug, not a fault signal")
    stage = Stage(name="s", hw=bug, sw=lambda x: x,
                  ports=(jax.ShapeDtypeStruct((4,), jnp.float32),),
                  tol=0.0)
    chk = CanaryChecker([stage])
    with pytest.raises(RuntimeError, match="genuine bug"):
        chk.check_stage(stage)
    assert RuntimeError not in EXPECTED_STAGE_ERRORS


def test_sharding_constrain_narrow_except(monkeypatch):
    x = jnp.ones((4, 4))
    assert sharding.constrain(x, "batch") is x     # no rules: no-op
    with sharding.axis_rules({"batch": None}):
        def spec_error(*a, **k):
            raise ValueError("rank mismatch")
        monkeypatch.setattr(jax.lax, "with_sharding_constraint", spec_error)
        assert sharding.constrain(x, "batch") is x  # expected: swallowed

        def bug(*a, **k):
            raise RuntimeError("not a spec error")
        monkeypatch.setattr(jax.lax, "with_sharding_constraint", bug)
        with pytest.raises(RuntimeError, match="not a spec error"):
            sharding.constrain(x, "batch")


# ------------------------------------------------------ lane localization
@pytest.mark.parametrize("kind,expect", [
    ("stuck", lanefault.STUCK),
    ("dropped", lanefault.DROPPED_MAC),
    ("gain", lanefault.GAIN),
])
def test_canary_localizes_each_fault_kind(kind, expect):
    """An injected lane fault of each kind is detected, localized to the
    right lanes, classified, and registered as a map (unlocking DEGRADED
    routing instead of a binary SW drop)."""
    name = "swiglu_mlp"
    stage = _stage(name)
    lanes, w = (2, 9), WIDTH[name]
    fault = LaneFault(kind={"stuck": lanefault.STUCK,
                            "dropped": lanefault.DROPPED_MAC,
                            "gain": lanefault.GAIN}[kind],
                      lanes=lanes, width=w, value=2.5, gain=3.0)
    state = FaultState()
    chk = CanaryChecker([stage], route_hw=INTERPRET, localize=True)
    with lanefault.inject(name, fault):
        found = chk.sweep(state, step=5)
    assert found == [name]
    assert state.log[-1]["kind"] == "canary_localized"
    assert state.log[-1]["step"] == 5
    located = lanefault.fault_map(name)
    assert located is not None and located.lanes == lanes
    assert located.kind == expect
    assert lanefault.map_base(name) == INTERPRET
def test_canary_whole_tile_breakage_stays_binary():
    """A defect touching EVERY output lane is not lane-shaped: localize
    returns no map and the stage takes the binary SW quarantine."""
    st2 = Stage(name="whole", hw=lambda x: x + 1.0, sw=lambda x: x,
                ports=(jax.ShapeDtypeStruct((4, 8), jnp.float32),),
                tol=1e-3)
    chk = CanaryChecker([st2], localize=True)
    state = FaultState()
    found = chk.sweep(state, step=6)
    assert found == ["whole"]
    assert state.log[-1]["kind"] == "canary"
    assert lanefault.fault_map("whole") is None
